"""Measured-vs-modeled comm calibration.

`core.schedule.simulate_schedule` is an alpha-beta MODEL: per message,
comm = alpha_us + bytes/(gbps*1e3), overlapped against a modeled
backward. Nothing in the repo validated those parameters against the
pipeline we actually execute — the ROADMAP gap this module closes.

`measure_schedule` runs the REAL scheduled wire pipeline (encode →
packed uint8 buffer → decode, the exact graph `--wire` training steps
execute) under a TraceRecorder and reports per-message measured
durations. `fit_alpha_beta` least-squares fits the model's two
parameters to the measured (bytes, duration) samples, per host.
`calibrate` sweeps fusion thresholds for one gradient tree and reports,
per threshold, measured exposed comm next to the model's prediction
under BOTH the default parameters and the fitted ones — the model-error
ratios BENCH_obs.json records.

Honesty note (the repo's standing convention): this is a single-process
measurement of the serialized compress/pack/decode stream — there is no
real network and nothing overlaps, so measured "exposed" comm equals the
measured stream total. Wall-clocks on a shared container are noisy;
reps take medians, and the stable signals remain the counts and byte
totals. The fitted alpha/beta describe THIS host's executed stream, not
a cluster interconnect.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence, Tuple

import jax

from repro.obs.trace import TraceRecorder, validate_chrome_trace

__all__ = ["measure_schedule", "measure_stream", "measure_collective",
           "fit_alpha_beta", "calibrate", "DEFAULT_THRESHOLDS"]

#: the acceptance sweep: per-bucket, 64 KiB Horovod-style buffers, one shot
DEFAULT_THRESHOLDS: Tuple[Tuple[str, float], ...] = (
    ("per_bucket", 0.0),
    ("fused_64kib", float(1 << 16)),
    ("one_shot", math.inf),
)


def _median(vals: Sequence[float]) -> float:
    sv = sorted(vals)
    return sv[len(sv) // 2] if sv else 0.0


def measure_schedule(tree, stacked, comp, fusion_bytes: float, *,
                     granularity: str = "layerwise", reps: int = 3,
                     warmup: int = 1, seed: int = 0) -> Dict:
    """Execute the real wire schedule for (tree, comp, fusion_bytes)
    under a TraceRecorder; return measured per-message durations plus
    stage totals.

    Returns {"n_messages", "wire_bytes" (buffer bytes incl. headers),
    "total_us" (median step wall), "stage_us" {stage: median},
    "per_message": [{"message", "wire_bytes", "dur_us"}]}."""
    from repro.core import build_plan, build_schedule, wire_codec
    from repro.core.granularity import Granularity
    from repro.core.wire import message_layouts

    plan = build_plan(tree, stacked, Granularity(granularity))
    sched = build_schedule(plan, float(fusion_bytes))
    codec = wire_codec(comp)
    layouts = message_layouts(sched, codec)
    rec = TraceRecorder()
    key = jax.random.key(seed)

    fn = jax.jit(lambda t, k: sched.execute(None, t, k, wire=codec,
                                            recorder=rec))
    for _ in range(warmup):
        out, bufs = fn(tree, key)
        jax.block_until_ready(bufs)
        rec.finalize_step()
    rec.events, rec.steps = [], []  # keep only the timed reps

    per_rep_msgs: List[Dict[int, float]] = []
    totals, stage_accum = [], {}
    for r in range(reps):
        out, bufs = fn(tree, key)
        jax.block_until_ready(bufs)
        jax.block_until_ready(out)
        summary = rec.finalize_step(r)
        totals.append(summary["wall_us"])
        for k, v in summary["stage_us"].items():
            stage_accum.setdefault(k, []).append(v)
        durs = {}
        for e in rec.message_spans(step=r):
            durs[int(e["args"]["message"])] = float(e["dur"])
        per_rep_msgs.append(durs)

    per_message = []
    for mi, layout in enumerate(layouts):
        ds = [d[mi] for d in per_rep_msgs if mi in d]
        per_message.append({"message": mi,
                            "wire_bytes": int(layout.total_nbytes),
                            "dur_us": round(_median(ds), 3)})
    return {
        "n_messages": sched.num_messages,
        "wire_bytes": int(sum(l.total_nbytes for l in layouts)),
        "total_us": round(_median(totals), 3),
        "stage_us": {k: round(_median(v), 3)
                     for k, v in sorted(stage_accum.items())},
        "per_message": per_message,
    }


def measure_stream(tree, stacked, comp, fusion_bytes: float, *,
                   mode: str = "ring", granularity: str = "layerwise",
                   chunk_bytes: Optional[float] = None, reps: int = 3,
                   warmup: int = 1, seed: int = 0) -> Dict:
    """Execute the STREAMING ring collective for (tree, comp,
    fusion_bytes) over every local device and report per-hop structure
    plus measured exposed comm.

    Unlike `measure_schedule` (the serialized single-process stream,
    where exposed comm == stream total by construction), this runs
    `CommSchedule.execute_streaming` under a real multi-device
    ``shard_map`` — the chunked-ppermute ring with double-buffered
    compress — and aggregates the recorder's per-hop spans. The stable,
    gateable signals are the COUNTS (hop spans per step ==
    n_messages x (n_workers - 1), deterministic) and BYTES per hop (the
    full message buffer circulates each hop in mode='ring'; packed
    shards in mode='rs'); `hop_us` — the measured exposed-comm proxy the
    ring-vs-serialized comparison in BENCH_stream.json uses — is a
    host-clock wall measurement and carries the usual shared-container
    noise caveat.

    Uses ALL local devices (run under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` for an
    N-worker host ring); with a single device the ring degenerates to
    the serialized wire path (0 hops). The recorder's multi-device
    stamps are collapsed with ``finalize_step(dedupe=True)`` and the
    resulting trace is validated against the Chrome trace-event schema
    before returning.

    Returns {"mode", "n_workers", "n_messages", "n_hops",
    "n_hop_spans_measured", "wire_bytes", "hop_bytes_total",
    "hop_us", "total_us", "stage_us", "per_message":
    [{"message", "wire_bytes", "n_chunks", "hop_bytes"}]}."""
    from jax.sharding import PartitionSpec as P
    from repro.core import build_plan, build_schedule, wire_codec
    from repro.core.granularity import Granularity
    from repro.core.wire import (layout_chunks, message_layouts,
                                 shard_message_layouts)
    from repro.launch.engine import shard_map
    from repro.launch.mesh import make_host_mesh

    n = jax.local_device_count()
    mesh = make_host_mesh(n, 1)
    plan = build_plan(tree, stacked, Granularity(granularity))
    sched = build_schedule(plan, float(fusion_bytes))
    codec = wire_codec(comp)
    layouts = (message_layouts(sched, codec) if mode == "ring"
               else shard_message_layouts(sched, codec, n))
    rec = TraceRecorder()
    key = jax.random.key(seed)

    def f(t):
        return sched.execute_streaming(
            None, t, key, wire=codec, axis_names=("data",), n_workers=n,
            mode=mode, chunk_bytes=chunk_bytes, recorder=rec)

    fn = jax.jit(shard_map(f, mesh, in_specs=(P(),), out_specs=P()))
    for _ in range(warmup):
        out, bufs = fn(tree)
        jax.block_until_ready(bufs)
        rec.finalize_step(dedupe=True)
    rec.events, rec.steps = [], []  # keep only the timed reps

    totals, stage_accum, hop_counts = [], {}, []
    for r in range(reps):
        out, bufs = fn(tree)
        jax.block_until_ready(bufs)
        jax.block_until_ready(out)
        summary = rec.finalize_step(r, dedupe=True)
        totals.append(summary["wall_us"])
        for k, v in summary["stage_us"].items():
            stage_accum.setdefault(k, []).append(v)
        hop_counts.append(sum(1 for e in rec.span_events(step=r)
                              if e["args"].get("stage") == "hop"))
    validate_chrome_trace(rec.chrome_trace())

    per_message = [{"message": mi,
                    "wire_bytes": int(l.total_nbytes),
                    "n_chunks": len(layout_chunks(l, chunk_bytes)),
                    "hop_bytes": int((n - 1) * l.total_nbytes)}
                   for mi, l in enumerate(layouts)]
    stage_us = {k: round(_median(v), 3)
                for k, v in sorted(stage_accum.items())}
    return {
        "mode": mode,
        "n_workers": n,
        "n_messages": sched.num_messages,
        "n_hops": sched.num_messages * (n - 1),
        "n_hop_spans_measured": int(_median(hop_counts)),
        "wire_bytes": int(sum(l.total_nbytes for l in layouts)),
        "hop_bytes_total": int(sum(m["hop_bytes"] for m in per_message)),
        "hop_us": stage_us.get("hop", 0.0),
        "total_us": round(_median(totals), 3),
        "stage_us": stage_us,
        "per_message": per_message,
    }


def measure_collective(tree, stacked, comp, fusion_bytes: float, *,
                       strategy: str = "allgather",
                       granularity: str = "layerwise", reps: int = 3,
                       warmup: int = 1, seed: int = 0) -> Dict:
    """The SERIALIZED wire collective under the same multi-device mesh
    as `measure_stream`: compressed_allreduce(strategy='allgather',
    wire=True) over every local device — compress, pack, one blocking
    gather-everything collective, decode, per message in sequence. Its
    `total_us` is the serialized-stream total that the ring's measured
    exposed hop time is compared against in BENCH_stream.json (same
    device count, same process — the only honest baseline; the
    single-device `measure_schedule` stream is NOT comparable to a ring
    doing n_workers x the decode work). Returns {"n_workers",
    "n_messages", "wire_bytes", "total_us", "stage_us"}."""
    from jax.sharding import PartitionSpec as P
    from repro.core import build_plan, build_schedule, wire_codec
    from repro.core.aggregation import (CompressionConfig,
                                        compressed_allreduce)
    from repro.core.granularity import Granularity
    from repro.core.wire import message_layouts
    from repro.launch.engine import shard_map
    from repro.launch.mesh import make_host_mesh

    n = jax.local_device_count()
    mesh = make_host_mesh(n, 1)
    gran = Granularity(granularity)
    plan = build_plan(tree, stacked, gran)
    sched = build_schedule(plan, float(fusion_bytes))
    layouts = message_layouts(sched, wire_codec(comp))
    cfg = CompressionConfig(qw=comp, granularity=gran, strategy=strategy,
                            fusion_bytes=float(fusion_bytes))
    rec = TraceRecorder()
    key = jax.random.key(seed)

    def f(t):
        out, _ = compressed_allreduce(t, stacked, cfg, ("data",), key, n,
                                      plan=plan, wire=True, recorder=rec)
        return out

    fn = jax.jit(shard_map(f, mesh, in_specs=(P(),), out_specs=P()))
    for _ in range(warmup):
        out = fn(tree)
        jax.block_until_ready(out)
        rec.finalize_step(dedupe=True)
    rec.events, rec.steps = [], []  # keep only the timed reps

    totals, stage_accum = [], {}
    for r in range(reps):
        out = fn(tree)
        jax.block_until_ready(out)
        summary = rec.finalize_step(r, dedupe=True)
        totals.append(summary["wall_us"])
        for k, v in summary["stage_us"].items():
            stage_accum.setdefault(k, []).append(v)
    return {
        "n_workers": n,
        "n_messages": sched.num_messages,
        "wire_bytes": int(sum(l.total_nbytes for l in layouts)),
        "total_us": round(_median(totals), 3),
        "stage_us": {k: round(_median(v), 3)
                     for k, v in sorted(stage_accum.items())},
    }


def fit_alpha_beta(samples: Sequence[Tuple[float, float]],
                   prior_alpha_us: float = 50.0,
                   prior_gbps: float = 12.5) -> Dict:
    """Least-squares fit t_us = alpha_us + nbytes/(gbps*1e3) over
    measured (nbytes, dur_us) samples. Slope is clamped non-negative
    (a negative slope just means latency dominates at these sizes);
    alpha is clamped non-negative likewise.

    Degenerate inputs — fewer than two DISTINCT message sizes (e.g.
    fusion=inf produces exactly one message, so every sample shares one
    x) or non-finite samples — cannot identify two parameters: the
    legacy fit silently dumped the whole duration into alpha (or worse,
    propagated NaN into BENCH_obs.json's model-error ratios). Now such
    inputs return the PRIOR (`prior_alpha_us`, `prior_gbps` — the
    model's defaults) with an explicit ``fit_degenerate: True`` flag,
    and `resid_rms_us` honestly reports the misfit of the prior against
    the samples. Empty samples keep the legacy {alpha 0, gbps None}
    shape (there is nothing to misfit), flagged degenerate likewise."""
    n = len(samples)
    if n == 0:
        return {"alpha_us": 0.0, "gbps": None, "n_samples": 0,
                "resid_rms_us": 0.0, "fit_degenerate": True}
    xs = [float(b) for b, _ in samples]
    ys = [float(t) for _, t in samples]
    finite = all(math.isfinite(v) for v in xs + ys)
    mx = sum(xs) / n if finite else 0.0
    my = sum(ys) / n if finite else 0.0
    sxx = sum((x - mx) ** 2 for x in xs) if finite else 0.0
    degenerate = (not finite or len(set(xs)) < 2 or sxx <= 0.0)
    if degenerate:
        slope = 1.0 / (prior_gbps * 1e3)
        alpha = float(prior_alpha_us)
        gbps = float(prior_gbps)
    else:
        sxy = sum((x - mx) * (y - my) for x, y in zip(xs, ys))
        slope = max(sxy / sxx, 0.0)   # us per byte
        alpha = max(0.0, my - slope * mx)
        gbps = (1.0 / (slope * 1e3)) if slope > 1e-12 else None
        if not all(math.isfinite(v) for v in
                   (slope, alpha) + (() if gbps is None else (gbps,))):
            degenerate, slope, alpha, gbps = (
                True, 1.0 / (prior_gbps * 1e3), float(prior_alpha_us),
                float(prior_gbps))
    resid = [y - (alpha + slope * x) for x, y in zip(xs, ys)
             if math.isfinite(x) and math.isfinite(y)]
    rms = (math.sqrt(sum(r * r for r in resid) / len(resid))
           if resid else 0.0)
    return {"alpha_us": round(alpha, 3),
            "gbps": round(gbps, 3) if gbps is not None else None,
            "us_per_byte": round(slope, 6),
            "n_samples": n,
            "resid_rms_us": round(rms, 3),
            "fit_degenerate": degenerate}


def _predict_us(n_messages: int, nbytes: int, alpha_us: float,
                gbps: Optional[float]) -> float:
    beta = 0.0 if gbps is None else 1.0 / (gbps * 1e3)
    return n_messages * alpha_us + nbytes * beta


def calibrate(name: str, tree, stacked, comp, *,
              thresholds: Sequence[Tuple[str, float]] = DEFAULT_THRESHOLDS,
              granularity: str = "layerwise", reps: int = 3,
              alpha_us: float = 50.0, gbps: float = 12.5,
              compress_gbps: float = 25.0) -> Dict:
    """Measured-vs-modeled calibration report for one gradient tree.

    Per fusion threshold: the measured wire-schedule stream next to the
    alpha-beta model's comm prediction under the DEFAULT parameters and
    under parameters FITTED to this host's measurements (error ratio =
    measured / predicted; the fitted ratio should sit near 1 — that gap
    is the model error the paper's discrepancy argument is about)."""
    from repro.core import build_plan, build_schedule, simulate_schedule
    from repro.core.granularity import Granularity

    plan = build_plan(tree, stacked, Granularity(granularity))
    per_threshold: Dict[str, Dict] = {}
    samples: List[Tuple[float, float]] = []
    for label, fb in thresholds:
        meas = measure_schedule(tree, stacked, comp, fb,
                                granularity=granularity, reps=reps)
        sched = build_schedule(plan, float(fb))
        sim = simulate_schedule(sched, qw=comp, alpha_us=alpha_us,
                                gbps=gbps, compress_gbps=compress_gbps)
        samples.extend((m["wire_bytes"], m["dur_us"])
                       for m in meas["per_message"])
        per_threshold[label] = {
            "fusion_bytes": None if math.isinf(fb) else fb,
            "n_messages": meas["n_messages"],
            "wire_bytes_measured": meas["wire_bytes"],
            "wire_bits_model": sim["wire_bits_total"],
            "exposed_comm_us_measured": meas["total_us"],
            "exposed_comm_us_model": sim["exposed_comm_us"],
            "comm_us_total_model": sim["comm_us_total"],
            "stage_us_measured": meas["stage_us"],
            "per_message_measured": meas["per_message"],
        }

    fit = fit_alpha_beta(samples, prior_alpha_us=alpha_us, prior_gbps=gbps)
    host = str(jax.process_index())
    for label, _ in thresholds:
        t = per_threshold[label]
        pred_default = _predict_us(t["n_messages"], t["wire_bytes_measured"],
                                   alpha_us, gbps)
        pred_fitted = _predict_us(t["n_messages"], t["wire_bytes_measured"],
                                  fit["alpha_us"], fit["gbps"])
        meas_us = t["exposed_comm_us_measured"]
        t["model_error_ratio_default"] = round(
            meas_us / max(pred_default, 1e-9), 3)
        t["model_error_ratio_fitted"] = round(
            meas_us / max(pred_fitted, 1e-9), 3)
    return {
        "config": name,
        "codec": comp.name,
        "granularity": granularity,
        "model_defaults": {"alpha_us": alpha_us, "gbps": gbps,
                           "compress_gbps": compress_gbps},
        "fit_by_host": {host: fit},
        "thresholds": per_threshold,
    }
