"""Observability: tracing, metrics, and measured-vs-modeled calibration.

The execution stack (core.plan / core.schedule / core.wire /
launch.engine) accepts a duck-typed ``recorder=`` and never imports this
package — obs depends on core, not the reverse. See obs.trace for the
zero-overhead contract.
"""
from repro.obs.calibrate import (DEFAULT_THRESHOLDS, calibrate,
                                 fit_alpha_beta, measure_schedule)
from repro.obs.metrics import (METRICS_SCHEMA_VERSION, MetricsRegistry,
                               read_jsonl)
from repro.obs.trace import (TRACE_SCHEMA_VERSION, TraceRecorder, active,
                             count_debug_callbacks, format_step_summary,
                             validate_chrome_trace)

__all__ = [
    "TraceRecorder", "active", "validate_chrome_trace",
    "format_step_summary", "count_debug_callbacks", "TRACE_SCHEMA_VERSION",
    "MetricsRegistry", "read_jsonl", "METRICS_SCHEMA_VERSION",
    "measure_schedule", "fit_alpha_beta", "calibrate",
    "DEFAULT_THRESHOLDS",
]
