"""MetricsRegistry: counters, gauges, and histograms with schema-versioned
JSON-lines export.

The numeric complement of obs.trace's event timeline: cheap host-side
aggregates (steps run, builds/retraces, dispatch and message counts, wire
bytes, per-stage microseconds, replan decisions) that harnesses bump from
ordinary Python — never from inside a jitted function. Snapshots are
plain dicts stamped with a schema version so exported lines stay
joinable with trace output and forward-parseable.

Export format: one JSON object per line (JSON-lines). Every line:

    {"schema_version": 1, "kind": "snapshot", "labels": {...},
     "counters": {...}, "gauges": {...},
     "histograms": {name: {count,min,max,mean,p50,p95,sum}}}

Conventions: counter/gauge names are slash-paths ("train/steps",
"controller/builds"); histograms record raw samples in memory and export
summaries only. A disabled registry (enabled=False) turns every method
into a no-op so call sites need no guards.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional

__all__ = ["MetricsRegistry", "METRICS_SCHEMA_VERSION", "read_jsonl"]

#: bump when the snapshot line layout changes
METRICS_SCHEMA_VERSION = 1


def _percentile(sorted_vals: List[float], q: float) -> float:
    if not sorted_vals:
        return 0.0
    idx = min(len(sorted_vals) - 1, int(round(q * (len(sorted_vals) - 1))))
    return sorted_vals[idx]


class MetricsRegistry:
    def __init__(self, enabled: bool = True):
        self.enabled = bool(enabled)
        self.counters: Dict[str, float] = {}
        self.gauges: Dict[str, float] = {}
        self.histograms: Dict[str, List[float]] = {}
        self._lines: List[Dict] = []

    # ---- instruments -----------------------------------------------------
    def inc(self, name: str, value: float = 1.0) -> None:
        if not self.enabled:
            return
        self.counters[name] = self.counters.get(name, 0.0) + float(value)

    def gauge(self, name: str, value: float) -> None:
        if not self.enabled:
            return
        self.gauges[name] = float(value)

    def observe(self, name: str, value: float) -> None:
        if not self.enabled:
            return
        self.histograms.setdefault(name, []).append(float(value))

    # ---- snapshots -------------------------------------------------------
    def snapshot(self, **labels) -> Dict:
        """The current aggregate state as one schema-versioned dict."""
        hists = {}
        for name, vals in sorted(self.histograms.items()):
            sv = sorted(vals)
            hists[name] = {
                "count": len(sv),
                "min": sv[0] if sv else 0.0,
                "max": sv[-1] if sv else 0.0,
                "mean": (sum(sv) / len(sv)) if sv else 0.0,
                "p50": _percentile(sv, 0.50),
                "p95": _percentile(sv, 0.95),
                "sum": sum(sv),
            }
        return {
            "schema_version": METRICS_SCHEMA_VERSION,
            "kind": "snapshot",
            "labels": dict(labels),
            "counters": dict(sorted(self.counters.items())),
            "gauges": dict(sorted(self.gauges.items())),
            "histograms": hists,
        }

    def record(self, **labels) -> Dict:
        """Append a snapshot line (e.g. once per step or per replan
        window) for a later export_jsonl."""
        line = self.snapshot(**labels)
        if self.enabled:
            self._lines.append(line)
        return line

    # ---- export ----------------------------------------------------------
    def export_jsonl(self, path: str) -> int:
        """Write the recorded snapshot lines (plus a final snapshot when
        none were recorded) as JSON-lines; returns the line count."""
        lines = self._lines or [self.snapshot(final=True)]
        with open(path, "w") as f:
            for line in lines:
                f.write(json.dumps(line, sort_keys=True) + "\n")
        return len(lines)


def read_jsonl(path: str) -> List[Dict]:
    """Parse a JSON-lines metrics export back into dicts (the round-trip
    partner of export_jsonl; tests hold snapshot == parsed line)."""
    out = []
    with open(path) as f:
        for raw in f:
            raw = raw.strip()
            if raw:
                out.append(json.loads(raw))
    return out
