"""TraceRecorder: per-step, per-message event timelines of the executed
compression pipeline.

The paper's complaint is that theory reasons about an idealized pipeline
while implementations run a different one; our own `simulate_schedule` is
exactly such a model. The TraceRecorder records what a step ACTUALLY did:
one span per pipeline stage (compress, pack, decode, collective,
ef_update) per wire message — or one span per message on the unpacked
path, and one per size-class dispatch on the bare-plan path — with
bucket/message/codec attribution, exported as Chrome trace-event JSON
(load in Perfetto / chrome://tracing) plus a compact per-step summary.

Mechanics. Instrumented execution hooks (core.plan / core.schedule /
core.wire accept a duck-typed ``recorder=``; core never imports obs) do
two things at jit-trace time:

  * wrap each stage in ``jax.named_scope`` so XLA profiles carry the
    same ``repro/msg…`` names, and
  * insert a ``jax.debug.callback`` whose operand DATA-DEPENDS on the
    stage's outputs, stamping the host clock when execution reaches the
    end of the stage (per executed step, in execution order).

A span's duration is the gap between consecutive stamps in timestamp
order — an honest host-side view of the serialized CPU stream, not a
device profile (XLA may overlap work; the barrier chain between messages
only pins program order). Trust span STRUCTURE and counts anywhere;
trust durations only for relative comparisons on a quiet machine.

Zero-overhead contract: every hook guards on ``recorder is not None and
recorder.enabled``, so with recording disabled the traced graph is
bit-identical to the uninstrumented one (no callbacks, no scopes, no
extra ops — tests/test_obs.py compares jaxprs).
"""
from __future__ import annotations

import contextlib
import functools
import json
import time
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

__all__ = ["TraceRecorder", "active", "validate_chrome_trace",
           "format_step_summary", "count_debug_callbacks"]

#: bump when the exported chrome-trace "args" layout changes
TRACE_SCHEMA_VERSION = 1

_ALLOWED_PH = {"X", "i", "M"}


def active(recorder) -> Optional["TraceRecorder"]:
    """The one-line guard every instrumented hook runs: the recorder if
    it exists and is enabled, else None (→ the uninstrumented graph).
    Duck-typed so core modules can inline the same check without
    importing obs."""
    if recorder is not None and getattr(recorder, "enabled", False):
        return recorder
    return None


def _dep_token(dep):
    """Collapse the stage outputs a mark depends on into one f32 scalar
    — the debug-callback operand. Cheap (one element per array) and
    un-hoistable: the callback cannot fire before every listed array is
    computed."""
    arrays = dep if isinstance(dep, (list, tuple)) else [dep]
    toks = [jnp.ravel(a)[0].astype(jnp.float32) for a in arrays]
    tok = toks[0]
    for t in toks[1:]:
        tok = tok + t
    return tok


class TraceRecorder:
    """Records stage marks from instrumented execution into Chrome
    trace events. One recorder serves many traced functions and many
    steps; call :meth:`finalize_step` after each blocked-on step to
    convert that step's marks into spans."""

    def __init__(self, enabled: bool = True, pid: int = 0,
                 clock=time.perf_counter_ns):
        self.enabled = bool(enabled)
        self.pid = pid
        self._clock = clock
        self.events: List[Dict] = []      # finalized chrome events
        self.steps: List[Dict] = []       # per-step summaries
        self._meta: List[Dict] = []       # static span metadata (trace time)
        self._marks: List = []            # (meta_id, t_ns) runtime stamps
        self._t0: Optional[int] = None    # trace epoch (first stamp)

    # ---- trace-time hooks (called while jit is tracing) ------------------
    def scope(self, name: str):
        """named_scope wrapper so XLA profiles carry the span names."""
        return jax.named_scope(name)

    def begin(self, dep, label: str = "inputs_ready") -> None:
        """Stamp the moment the instrumented region's INPUTS are
        computed — the baseline the first span's duration is measured
        from (otherwise it would swallow backward time)."""
        self._mark(dep, "begin", cat="begin", label=label)

    def mark(self, dep, stage: str, *, cat: str = "stage",
             message: Optional[int] = None,
             bucket_ids: Optional[Sequence[int]] = None,
             dims: Optional[Sequence[int]] = None,
             n_units: Optional[int] = None,
             codec: Optional[str] = None,
             label: Optional[str] = None) -> None:
        """Register one pipeline-stage end: static attribution now, a
        host-clock stamp (data-dependent on `dep`) per executed step."""
        self._mark(dep, stage, cat=cat, message=message,
                   bucket_ids=bucket_ids, dims=dims, n_units=n_units,
                   codec=codec, label=label)

    def _mark(self, dep, stage: str, **meta) -> None:
        mid = len(self._meta)
        m = {"stage": stage}
        m.update({k: v for k, v in meta.items() if v is not None})
        if "bucket_ids" in m:
            m["bucket_ids"] = tuple(int(b) for b in m["bucket_ids"])
        if "dims" in m:
            m["dims"] = tuple(int(d) for d in m["dims"])
        self._meta.append(m)
        jax.debug.callback(functools.partial(self._stamp, mid),
                           _dep_token(dep))

    def _stamp(self, mid: int, _tok) -> None:
        self._marks.append((mid, self._clock()))

    # ---- host-side spans -------------------------------------------------
    @contextlib.contextmanager
    def host_span(self, name: str, cat: str = "host", **args):
        """Wall-clock span around a host-side region (e.g. a blocked-on
        prefill, a compile). Also enters jax.profiler.TraceAnnotation so
        an XLA profile taken concurrently carries the same name."""
        if not self.enabled:
            yield
            return
        t0 = self._clock()
        with jax.profiler.TraceAnnotation(name):
            yield
        t1 = self._clock()
        if self._t0 is None:
            self._t0 = t0
        self.events.append({
            "name": name, "cat": cat, "ph": "X",
            "ts": round((t0 - self._t0) / 1e3, 3),
            "dur": round((t1 - t0) / 1e3, 3),
            "pid": self.pid, "tid": 0,
            "args": dict(args),
        })

    # ---- finalization ----------------------------------------------------
    def finalize_step(self, step: Optional[int] = None, *,
                      dedupe: bool = False) -> Dict:
        """Convert the marks stamped since the last finalize into spans.
        Call after the step's outputs are blocked on (all callbacks for
        the step have then fired). Returns the per-step summary.

        ``dedupe=True`` collapses repeated stamps of the SAME mark to the
        latest one. Under a multi-device ``shard_map`` each debug
        callback fires once per local device, so every mark stamps
        n_devices times; keeping the last arrival per mark restores the
        one-stamp-per-stage timeline (span end = the moment the slowest
        device finished the stage). Required whenever the traced fn ran
        on >1 local device; a no-op on single-device runs."""
        if dedupe:
            latest: Dict[int, int] = {}
            for mid, t_ns in self._marks:
                if mid not in latest or t_ns > latest[mid]:
                    latest[mid] = t_ns
            self._marks = list(latest.items())
        marks = sorted(self._marks, key=lambda m: m[1])
        self._marks = []
        step = len(self.steps) if step is None else int(step)
        if not marks:
            summary = {"step": step, "n_spans": 0, "n_message_spans": 0,
                       "stage_us": {}, "wall_us": 0.0}
            self.steps.append(summary)
            return summary
        if self._t0 is None:
            self._t0 = marks[0][1]
        spans = []
        prev_ns = None
        for mid, t_ns in marks:
            meta = self._meta[mid]
            if meta["stage"] == "begin":
                prev_ns = t_ns
                continue
            start = prev_ns if prev_ns is not None else t_ns
            spans.append((start, t_ns, meta))
            prev_ns = t_ns
        # direct span events
        msg_seen = set()
        by_msg: Dict[int, List] = {}
        stage_us: Dict[str, float] = {}
        for start, end, meta in spans:
            dur = (end - start) / 1e3
            cat = meta.get("cat", "stage")
            mi = meta.get("message")
            name = meta.get("label") or (
                f"{meta['stage']} m{mi}" if mi is not None
                else meta["stage"])
            args = {"step": step, "stage": meta["stage"],
                    "schema_version": TRACE_SCHEMA_VERSION}
            for k in ("message", "bucket_ids", "dims", "n_units", "codec"):
                if k in meta:
                    args[k] = (list(meta[k])
                               if isinstance(meta[k], tuple) else meta[k])
            self.events.append({
                "name": name, "cat": cat, "ph": "X",
                "ts": round((start - self._t0) / 1e3, 3),
                "dur": round(dur, 3),
                "pid": self.pid, "tid": 0, "args": args,
            })
            stage_us[meta["stage"]] = stage_us.get(meta["stage"], 0.0) + dur
            if mi is not None:
                if cat == "message":
                    msg_seen.add(mi)
                else:
                    by_msg.setdefault(mi, []).append((start, end, meta))
        # synthesize a cat="message" umbrella span per message that only
        # emitted stage spans (the wire path), so span-count == n_messages
        # holds on every instrumented path
        n_message_spans = len(msg_seen)
        for mi in sorted(k for k in by_msg if k not in msg_seen):
            group = by_msg[mi]
            start = min(s for s, _, _ in group)
            end = max(e for _, e, _ in group)
            meta0 = group[0][2]
            args = {"step": step, "stage": "message",
                    "schema_version": TRACE_SCHEMA_VERSION, "message": mi,
                    "stages": sorted({m["stage"] for _, _, m in group})}
            for k in ("bucket_ids", "dims", "n_units", "codec"):
                if k in meta0:
                    args[k] = (list(meta0[k])
                               if isinstance(meta0[k], tuple) else meta0[k])
            self.events.append({
                "name": f"message m{mi}", "cat": "message", "ph": "X",
                "ts": round((start - self._t0) / 1e3, 3),
                "dur": round((end - start) / 1e3, 3),
                "pid": self.pid, "tid": 1, "args": args,
            })
            n_message_spans += 1
        summary = {
            "step": step,
            "n_spans": len(spans),
            "n_message_spans": n_message_spans,
            "stage_us": {k: round(v, 3) for k, v in sorted(stage_us.items())},
            "wall_us": round((marks[-1][1] - marks[0][1]) / 1e3, 3),
        }
        self.steps.append(summary)
        return summary

    # ---- queries ---------------------------------------------------------
    def span_events(self, cat: Optional[str] = None,
                    step: Optional[int] = None) -> List[Dict]:
        out = []
        for e in self.events:
            if e.get("ph") != "X":
                continue
            if cat is not None and e.get("cat") != cat:
                continue
            if step is not None and e.get("args", {}).get("step") != step:
                continue
            out.append(e)
        return out

    def message_spans(self, step: Optional[int] = None) -> List[Dict]:
        """The per-message spans of one step (or all steps) — the
        acceptance-gate count: len == schedule.num_messages per step."""
        return self.span_events(cat="message", step=step)

    # ---- export ----------------------------------------------------------
    def chrome_trace(self) -> Dict:
        """The Chrome trace-event JSON object (Perfetto-loadable)."""
        meta_events = [{
            "name": "process_name", "ph": "M", "pid": self.pid, "tid": 0,
            "args": {"name": "repro"},
        }]
        return {
            "traceEvents": meta_events + self.events,
            "displayTimeUnit": "ms",
            "metadata": {"schema_version": TRACE_SCHEMA_VERSION,
                         "tool": "repro.obs.trace",
                         "steps": self.steps},
        }

    def export(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f, indent=2, sort_keys=True)


def validate_chrome_trace(obj: Any) -> bool:
    """Validate an object against the Chrome trace-event schema subset
    this module emits (dict with a traceEvents list of M/i/X events;
    every X event carries numeric non-negative ts/dur and a name).
    Raises ValueError on the first violation; returns True when valid."""
    if not isinstance(obj, dict):
        raise ValueError(f"trace must be a dict, got {type(obj).__name__}")
    events = obj.get("traceEvents")
    if not isinstance(events, list):
        raise ValueError("trace['traceEvents'] must be a list")
    for i, e in enumerate(events):
        if not isinstance(e, dict):
            raise ValueError(f"traceEvents[{i}] is not a dict")
        ph = e.get("ph")
        if ph not in _ALLOWED_PH:
            raise ValueError(f"traceEvents[{i}]: bad ph {ph!r}")
        if not isinstance(e.get("name"), str):
            raise ValueError(f"traceEvents[{i}]: name must be a string")
        if not isinstance(e.get("pid"), int) or not isinstance(
                e.get("tid"), int):
            raise ValueError(f"traceEvents[{i}]: pid/tid must be ints")
        if ph in ("X", "i"):
            ts = e.get("ts")
            if not isinstance(ts, (int, float)) or ts < 0:
                raise ValueError(f"traceEvents[{i}]: bad ts {ts!r}")
        if ph == "X":
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                raise ValueError(f"traceEvents[{i}]: bad dur {dur!r}")
        if "args" in e and not isinstance(e["args"], dict):
            raise ValueError(f"traceEvents[{i}]: args must be a dict")
    return True


def format_step_summary(summary: Dict) -> str:
    """One human line per step — what quickstart/train print."""
    stages = ", ".join(f"{k} {v:.0f}us"
                       for k, v in summary["stage_us"].items())
    return (f"step {summary['step']}: {summary['n_message_spans']} message "
            f"spans, {summary['n_spans']} stage spans, "
            f"{summary['wall_us']:.0f}us wall ({stages})")


def count_debug_callbacks(fn, *args) -> int:
    """How many debug_callback equations one jit trace of fn(*args)
    stages — the zero-overhead gate's counter (the obs twin of
    kernels.ops.count_pallas_calls): 0 with recording disabled."""
    jaxpr = jax.make_jaxpr(fn)(*args)

    def walk(jx) -> int:
        n = 0
        for eqn in jx.eqns:
            if "debug_callback" in eqn.primitive.name:
                n += 1
            for v in eqn.params.values():
                if hasattr(v, "jaxpr"):
                    n += walk(v.jaxpr)
                elif isinstance(v, (list, tuple)):
                    for u in v:
                        if hasattr(u, "jaxpr"):
                            n += walk(u.jaxpr)
        return n

    return walk(jaxpr.jaxpr)
