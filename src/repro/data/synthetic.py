"""Deterministic synthetic data pipelines (no data gates in this container).

Language modeling: sequences sampled from a fixed random first-order Markov
chain over the vocab — a task with nonzero learnable structure, so loss
decreases measurably within a few hundred steps (the convergence experiments
need a signal, not white noise).

Classification: Gaussian class prototypes + noise at CIFAR-like shapes for
the paper's CNN study.

Everything is a pure function of (seed, step) — shardable by slicing the
batch dimension, reproducible across hosts.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Iterator, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def make_markov(vocab: int, seed: int = 0, concentration: float = 0.3):
    """Row-stochastic transition matrix with low entropy (learnable)."""
    rng = np.random.default_rng(seed)
    logits = rng.gumbel(size=(vocab, vocab)) / concentration
    p = np.exp(logits - logits.max(axis=1, keepdims=True))
    p = p / p.sum(axis=1, keepdims=True)
    return jnp.asarray(p, jnp.float32)


@partial(jax.jit, static_argnums=(2, 3))
def markov_lm_batch(key: Array, trans: Array, batch: int, seq: int):
    """Sample (tokens, targets) from the Markov chain; targets = next token."""
    vocab = trans.shape[0]
    k0, k1 = jax.random.split(key)
    first = jax.random.randint(k0, (batch,), 0, vocab)

    def step(tok, k):
        nxt = jax.random.categorical(k, jnp.log(trans[tok] + 1e-9))
        return nxt, nxt

    keys = jax.random.split(k1, seq)
    _, seqs = jax.lax.scan(step, first, keys)
    seqs = jnp.concatenate([first[None], seqs], axis=0).T  # (B, S+1)
    return {"tokens": seqs[:, :-1].astype(jnp.int32),
            "targets": seqs[:, 1:].astype(jnp.int32)}


def lm_batches(vocab: int, batch: int, seq: int, seed: int = 0
               ) -> Iterator[Dict[str, Array]]:
    """Infinite deterministic LM batch stream."""
    trans = make_markov(vocab, seed)
    step = 0
    base = jax.random.key(seed)
    while True:
        yield markov_lm_batch(jax.random.fold_in(base, step), trans, batch,
                              seq)
        step += 1


def _class_prototypes(classes: int, hw: int, channels: int) -> Array:
    """The fixed smooth class prototypes both the IID and the skewed
    classification samplers draw from (same constants => same task)."""
    coarse = jax.random.normal(jax.random.key(1234),
                               (classes, 4, 4, channels))
    return jax.image.resize(coarse, (classes, hw, hw, channels),
                            method="bilinear") * 2.0


@partial(jax.jit, static_argnums=(1, 2, 3, 4))
def classification_batch(key: Array, batch: int, classes: int = 10,
                         hw: int = 32, channels: int = 3, noise: float = 0.5):
    """(images (B,hw,hw,C), labels (B,)) — smooth (low-frequency) class
    prototypes + pixel noise. Prototypes are 4x4 random grids bilinearly
    upsampled so convolutional nets can detect them locally (white-noise
    prototypes are only separable by pixel-exact templates = MLPs)."""
    kp, kl, kn = jax.random.split(key, 3)
    protos = _class_prototypes(classes, hw, channels)
    labels = jax.random.randint(kl, (batch,), 0, classes)
    x = protos[labels] + noise * jax.random.normal(kn, (batch, hw, hw,
                                                        channels))
    return {"images": x.astype(jnp.float32), "labels": labels.astype(jnp.int32)}


# --------------------------------------------------------------------------
# non-IID worker shards (Dirichlet skew — the federated-learning standard)
# --------------------------------------------------------------------------

def dirichlet_proportions(key: Array, n_workers: int, categories: int,
                          alpha: float) -> Array:
    """(n_workers, categories) row-stochastic shard proportions: each
    worker's category distribution is an independent Dirichlet(alpha)
    draw. Small alpha => near-one-hot shards (hostile skew); large alpha
    => near-uniform (approaches IID). Pure function of the key."""
    conc = jnp.full((categories,), jnp.float32(alpha))
    return jax.random.dirichlet(key, conc, shape=(n_workers,))


@partial(jax.jit, static_argnums=(2, 3, 4, 5, 6))
def noniid_classification_batch(key: Array, proportions: Array,
                                per_worker: int, classes: int = 10,
                                hw: int = 32, channels: int = 3,
                                noise: float = 0.5):
    """Skewed per-worker classification batches: labels of worker w are
    drawn from Categorical(proportions[w]) instead of uniform — same
    prototypes, same noise model as classification_batch, different
    shard composition. Returns {"images": (n, per, hw, hw, C),
    "labels": (n, per)} with the leading worker axis the simulated-
    worker aggregation path expects."""
    n = proportions.shape[0]
    protos = _class_prototypes(classes, hw, channels)

    def worker(wkey, props):
        kl, kn = jax.random.split(wkey)
        labels = jax.random.categorical(kl, jnp.log(props + 1e-9),
                                        shape=(per_worker,))
        x = protos[labels] + noise * jax.random.normal(
            kn, (per_worker, hw, hw, channels))
        return x.astype(jnp.float32), labels.astype(jnp.int32)

    keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(jnp.arange(n))
    images, labels = jax.vmap(worker)(keys, proportions)
    return {"images": images, "labels": labels}


@partial(jax.jit, static_argnums=(3, 4))
def noniid_markov_lm_batch(key: Array, trans: Array, proportions: Array,
                           per_worker: int, seq: int):
    """Skewed per-worker LM batches: worker w's sequences START from
    Categorical(proportions[w]) over the vocab instead of uniform, then
    evolve by the shared Markov chain — each worker sees a different
    slice of the chain's state space (shard skew) while the learnable
    transition structure stays the task. Returns {"tokens": (n, per,
    S), "targets": (n, per, S)}."""
    n = proportions.shape[0]

    def worker(wkey, props):
        k0, k1 = jax.random.split(wkey)
        first = jax.random.categorical(k0, jnp.log(props + 1e-9),
                                       shape=(per_worker,))

        def step(tok, k):
            nxt = jax.random.categorical(k, jnp.log(trans[tok] + 1e-9))
            return nxt, nxt

        keys = jax.random.split(k1, seq)
        _, seqs = jax.lax.scan(step, first, keys)
        seqs = jnp.concatenate([first[None], seqs], axis=0).T
        return (seqs[:, :-1].astype(jnp.int32),
                seqs[:, 1:].astype(jnp.int32))

    keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(jnp.arange(n))
    tokens, targets = jax.vmap(worker)(keys, proportions)
    return {"tokens": tokens, "targets": targets}


def frames_stub(key: Array, batch: int, frames: int, d_model: int) -> Array:
    """Audio frontend stub: precomputed frame embeddings (whisper carve-out)."""
    return 0.02 * jax.random.normal(key, (batch, frames, d_model),
                                    jnp.float32)


def patches_stub(key: Array, batch: int, patches: int, d_model: int) -> Array:
    """Vision frontend stub: projected patch embeddings (VLM carve-out)."""
    return 0.02 * jax.random.normal(key, (batch, patches, d_model),
                                    jnp.float32)
