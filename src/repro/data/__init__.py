from repro.data.synthetic import (lm_batches, markov_lm_batch, make_markov,
                                  classification_batch, frames_stub,
                                  patches_stub, dirichlet_proportions,
                                  noniid_classification_batch,
                                  noniid_markov_lm_batch)
