from repro.data.synthetic import (lm_batches, markov_lm_batch, make_markov,
                                  classification_batch, frames_stub,
                                  patches_stub)
