"""SimCluster: the fault-injection harness around the simulated-worker
Algorithm-1 path.

Design rule (the correctness contract the differential suite pins):
faults NEVER touch the traced aggregation numerics. `aggregate` is a
pass-through to `core.aggregation.aggregate_simulated_workers` — same
args, same graph, bit-identical always, not just at identity settings.
The scenario acts on the three planes around it:

  TIME   `step_accounting` prices one step's communication per worker
         through the deterministic alpha-beta pipeline model
         (core.schedule.simulate_schedule) at that worker's LINK
         parameters, with the comm-schedule fusion threshold chosen per
         link by control.FusionPolicy (a high-alpha link fuses, a fast
         link streams per bucket), plus the scenario's straggler delay
         draws — all charged into exposed-comm. The synchronous
         allreduce waits for the slowest worker, so the step's exposed
         comm is the max over workers.

  SHAPE  `maybe_rescale` applies the scenario's elastic world-size
         events between steps: EF residual state (leading worker axis)
         is round-tripped THROUGH a real ckpt/ checkpoint (the flat-npz
         save/load a deployment would actually restore from) and
         re-bucketed onto the new world size without losing residual
         mass — departing worker i folds its residual into surviving
         slot i % new_n; joining workers start at zero. A rescale to
         the current size returns the state bit-identically (and still
         proves the checkpoint round-trip lossless on the way).

  DATA   non-IID shard skew lives in data/synthetic.py (Dirichlet
         proportions + skewed batch samplers); the campaign runner wires
         it to `scenario.dirichlet_alpha`.
"""
from __future__ import annotations

import os
import tempfile
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.ckpt import load_checkpoint, save_checkpoint
from repro.core.aggregation import (CompressionConfig,
                                    aggregate_simulated_workers)
from repro.core.plan import UnitPlan
from repro.core.schedule import build_schedule, simulate_schedule
from repro.sim.scenario import LinkSpec, Scenario


def init_ef(params_like, n_workers: int):
    """Zero EF residual state with the leading worker axis the
    simulated-worker path threads: one residual per worker per leaf."""
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros((n_workers,) + p.shape, p.dtype), params_like)


def _rebucket_rows(x, new_n: int):
    """Re-bucket one EF leaf (old_n, ...) onto `new_n` worker slots.

    new_n == old_n: the identity (returned untouched — bit-identical).
    Scale down: departing worker i folds into surviving slot i % new_n
    (residual mass conserved: every old row lands in exactly one new
    row). Scale up: surviving slots keep their rows, joiners start at
    zero (mass conserved: zeros add nothing).
    """
    old_n = x.shape[0]
    if new_n == old_n:
        return x
    if new_n > old_n:
        pad = jnp.zeros((new_n - old_n,) + x.shape[1:], x.dtype)
        return jnp.concatenate([x, pad], axis=0)
    extra = (-old_n) % new_n
    if extra:
        x = jnp.concatenate(
            [x, jnp.zeros((extra,) + x.shape[1:], x.dtype)], axis=0)
    return x.reshape((-1, new_n) + x.shape[1:]).sum(axis=0)


class SimCluster:
    """Scenario-driven wrapper over the simulated-worker aggregation.

    `ckpt_dir` hosts the EF-rescale checkpoints (a fresh temp directory
    when omitted). The accounting log accumulates one entry per priced
    step; `accounting` exposes it for the campaign's telemetry export.
    """

    def __init__(self, scenario: Scenario, cfg: CompressionConfig, *,
                 ckpt_dir: Optional[str] = None):
        self.scenario = scenario
        self.cfg = cfg
        self._ckpt_dir = ckpt_dir
        self.accounting: List[Dict] = []

    # ------------------------------------------------------------------
    # numerics plane: the bit-identical pass-through
    # ------------------------------------------------------------------
    def aggregate(self, worker_grads, stacked, key, *, ef_state=None,
                  plan=None, schedule=None, telemetry_plan=None,
                  telemetry_entire_model=True, wire=False, faults=None,
                  alive=None):
        """EXACTLY aggregate_simulated_workers — the scenario never
        reaches into a step's math (tests/test_scenarios.py holds this
        bit for bit across the codec zoo, both granularities, EF and
        wire). Fault injection happens around the step: time via
        step_accounting, shape via maybe_rescale, data via the
        synthetic samplers — with ONE deliberate exception, the wire
        plane: `faults` (an `injector()` built from the scenario's
        CorruptionSpec) corrupts the packed bytes each receiver
        decodes, and `alive` (an `alive_mask(...)` bool vector)
        renormalizes the mean over surviving workers. Both default to
        None = the bit-identical pass-through."""
        return aggregate_simulated_workers(
            worker_grads, stacked, self.cfg, key, ef_state=ef_state,
            plan=plan, schedule=schedule, telemetry_plan=telemetry_plan,
            telemetry_entire_model=telemetry_entire_model, wire=wire,
            faults=faults, alive=alive)

    # ------------------------------------------------------------------
    # wire plane: corruption injection + partial participation
    # ------------------------------------------------------------------
    def injector(self, *, resend: bool = False):
        """The resil.FaultInjector realizing the scenario's
        CorruptionSpec, or None at identity (prob 0) so callers can
        hand it straight to `aggregate(faults=...)` and keep the
        fault-free graph untouched. Build ONE injector per traced step
        function: it accumulates traced verdicts that must be drained
        (take_flags) inside that trace."""
        spec = self.scenario.corruption
        if spec.is_identity():
            return None
        from repro.resil import FaultInjector
        return FaultInjector(spec, resend=resend)

    def alive_mask(self, step: int, timeout_us: Optional[float]):
        """Partial participation under straggler timeout: worker i is
        alive iff its straggler delay draw at `step` is within
        `timeout_us`. None (or all workers timing out — a sync step
        cannot proceed with nobody) returns None = full participation.
        numpy bools, decided OUTSIDE the traced step like every other
        scenario knob."""
        if timeout_us is None:
            return None
        n = self.scenario.world_size_at(step)
        delays = self.scenario.straggler.draws(step, n)
        alive = delays <= float(timeout_us)
        if not alive.any() or alive.all():
            return None
        return alive

    # ------------------------------------------------------------------
    # shape plane: elastic world size through ckpt/
    # ------------------------------------------------------------------
    @property
    def ckpt_dir(self) -> str:
        if self._ckpt_dir is None:
            self._ckpt_dir = tempfile.mkdtemp(prefix="simcluster_ef_")
        return self._ckpt_dir

    def rescale_ef(self, ef_state, new_n: int, *, step: int = 0):
        """Re-bucket EF residuals onto `new_n` workers THROUGH a ckpt/
        round-trip: save the (old_n, ...) state as a real checkpoint,
        restore it, then re-bucket rows. The npz round-trip is lossless
        (f32 exact; bf16 stored as uint16 views), so new_n == old_n
        returns a bit-identical state — the identity contract."""
        if ef_state is None:
            return None
        path = save_checkpoint(self.ckpt_dir, step, ef_state, tag="ef")
        _, restored = load_checkpoint(path, like=ef_state)
        return jax.tree_util.tree_map(
            lambda x: _rebucket_rows(x, new_n), restored)

    def maybe_rescale(self, step: int, ef_state):
        """Apply the scenario's rescale events due exactly at `step`.
        Returns (world_size, ef_state, changed)."""
        n = self.scenario.world_size_at(step)
        due = [ev for ev in self.scenario.rescales if ev.step == step]
        if not due:
            return n, ef_state, False
        prev = (self.scenario.world_size_at(step - 1) if step > 0
                else self.scenario.n_workers)
        if n == prev:
            return n, ef_state, False
        return n, self.rescale_ef(ef_state, n, step=step), True

    # ------------------------------------------------------------------
    # time plane: per-link alpha-beta pricing + straggler draws
    # ------------------------------------------------------------------
    def link_fusion_bytes(self, plan: UnitPlan,
                          link: LinkSpec) -> Optional[float]:
        """The comm-schedule fusion threshold control.FusionPolicy picks
        for THIS link's alpha/beta (None = the config's own threshold,
        for non-layerwise plans where there is nothing to fuse)."""
        from repro.control.policy import CompressionDecision, FusionPolicy
        decision = CompressionDecision.from_config(self.cfg)
        picked = FusionPolicy(alpha_us=link.alpha_us,
                              gbps=link.gbps).decide({}, decision, plan)
        return picked.fusion_bytes

    def step_accounting(self, step: int, plan: UnitPlan, *,
                        backward_us: Optional[float] = None,
                        compress_gbps: float = 25.0) -> Dict:
        """Price one step's communication under the scenario.

        Every worker's wire is priced independently: its link's
        alpha/beta through simulate_schedule on the schedule fused at
        that link's FusionPolicy threshold, plus its straggler delay
        draw (pure exposed time — the worker sits idle). The
        synchronous allreduce completes when the slowest worker does,
        so the step-level exposed comm is the per-worker max. Appends
        and returns the accounting entry (all model numbers —
        deterministic, hand-computable)."""
        n = self.scenario.world_size_at(step)
        delays = self.scenario.straggler.draws(step, n)
        workers = []
        for i in range(n):
            link = self.scenario.link(i)
            fb = self.link_fusion_bytes(plan, link)
            sched = build_schedule(
                plan, fb if fb is not None else 0.0)
            sim = simulate_schedule(sched, qw=self.cfg.qw,
                                    alpha_us=link.alpha_us,
                                    gbps=link.gbps,
                                    compress_gbps=compress_gbps,
                                    backward_us=backward_us)
            workers.append({
                "worker": i,
                "alpha_us": link.alpha_us,
                "gbps": link.gbps,
                "fusion_bytes": fb,
                "n_messages": sim["n_messages"],
                "t_total_us": sim["t_total_us"],
                "model_exposed_us": sim["exposed_comm_us"],
                "straggler_delay_us": float(delays[i]),
                "exposed_us": sim["exposed_comm_us"] + float(delays[i]),
            })
        entry = {
            "step": int(step),
            "world_size": n,
            "workers": workers,
            "exposed_comm_us": max(w["exposed_us"] for w in workers),
            "t_step_us": max(w["t_total_us"] + w["straggler_delay_us"]
                             for w in workers),
            "straggler_hits": int(sum(1 for d in delays if d > 0)),
        }
        self.accounting.append(entry)
        return entry

    def exposed_comm_total_us(self) -> float:
        return sum(e["exposed_comm_us"] for e in self.accounting)
