"""SimCluster: fault-injected scenario harness for the simulated
cluster — hashable Scenario specs (heterogeneous links, stragglers,
elastic world size, non-IID shards) wrapped around the Algorithm-1
aggregation path without ever touching its numerics. See
benchmarks/scenarios.py for the campaign runner."""
from repro.sim.scenario import (DEFAULT_ALPHA_US, DEFAULT_GBPS,
                                CorruptionSpec, LinkSpec, RescaleEvent,
                                Scenario, StragglerSpec)
from repro.sim.cluster import SimCluster, init_ef
