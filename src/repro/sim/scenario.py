"""Scenario: a hashable fault-injection spec for the simulated cluster.

The campaign's question (the paper's, under hostile conditions) is
whether the layerwise-vs-entire-model verdict survives realistic system
behavior: heterogeneous links, stragglers, elastic world size, non-IID
shards. A `Scenario` names one such condition set. It is a frozen value
object — floats and tuples only — so it hashes, keys caches, and prints
itself into BENCH_scenarios.json verbatim.

The contract every knob obeys (tests/test_scenarios.py): at its IDENTITY
setting a knob changes NOTHING — `SimCluster.aggregate` stays bit-
identical to the bare `aggregate_simulated_workers`, and a rescale to
the current world size is a no-op on EF state. Faults act on two planes
only:

  * TIME — per-worker link alpha/beta and straggler delay draws feed the
    deterministic `simulate_schedule` alpha-beta model (exposed-comm
    accounting), never the traced numerics;
  * SHAPE/DATA — elastic rescale changes the worker axis between steps
    (EF residuals re-bucketed through ckpt/), Dirichlet skew changes
    which samples a worker sees (data/synthetic.py), never how a fixed
    set of worker gradients aggregates;
  * WIRE (the one deliberate exception) — `CorruptionSpec` perturbs the
    packed uint8 bytes a RECEIVER decodes (resil.FaultInjector via
    SimCluster.injector()), the regime the integrity checksum +
    recovery policies exist for. At prob 0 it injects nothing and the
    identity contract holds unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

#: the alpha-beta defaults of core.schedule.simulate_schedule — an empty
#: `links` tuple means every worker rides this homogeneous link.
DEFAULT_ALPHA_US = 50.0
DEFAULT_GBPS = 12.5


@dataclasses.dataclass(frozen=True)
class LinkSpec:
    """One worker's network link: per-message latency (alpha, us) and
    bandwidth (beta, GB/s) — the two parameters of the calibrated
    pipeline model."""
    alpha_us: float = DEFAULT_ALPHA_US
    gbps: float = DEFAULT_GBPS

    def __post_init__(self):
        if not (self.alpha_us >= 0 and self.gbps > 0):
            raise ValueError(f"bad link {self!r}")


@dataclasses.dataclass(frozen=True)
class StragglerSpec:
    """Per-step per-worker delay injection: each worker independently
    straggles with probability `prob`, adding `delay_us` of exposed
    (non-overlappable) time to its step. Draws are a pure function of
    (seed, step) — replaying a scenario replays its stragglers."""
    prob: float = 0.0
    delay_us: float = 0.0
    seed: int = 0

    def __post_init__(self):
        if not 0.0 <= self.prob <= 1.0:
            raise ValueError(f"straggler prob must be in [0,1]: {self.prob}")
        if self.delay_us < 0:
            raise ValueError(f"negative straggler delay: {self.delay_us}")

    def draws(self, step: int, n_workers: int) -> np.ndarray:
        """(n_workers,) float64 delay in us charged to each worker at
        `step`. Identity (prob or delay 0) is exact zeros."""
        if self.prob <= 0.0 or self.delay_us <= 0.0:
            return np.zeros((n_workers,))
        rng = np.random.default_rng((self.seed, int(step)))
        hit = rng.random(n_workers) < self.prob
        return np.where(hit, self.delay_us, 0.0)


#: corruption modes resil.faults implements ("bitflip"/"truncate" hit
#: any received message; "drop_hop"/"dup_hop" need the ring topology)
CORRUPTION_MODES = ("bitflip", "truncate", "drop_hop", "dup_hop")


@dataclasses.dataclass(frozen=True)
class CorruptionSpec:
    """Data-plane wire corruption: with probability `prob` per received
    message (or per ring hop), perturb its packed uint8 bytes AFTER
    encode — `n_bits` seeded bit flips, a truncated (zeroed) tail, a
    dropped (zeroed) hop, or a duplicated (stale) hop. Draws are a pure
    function of (step key, seed, message tag): replaying a scenario
    replays its corruption byte for byte. Identity (prob 0) injects
    nothing and must keep the aggregate bit-identical."""
    prob: float = 0.0
    mode: str = "bitflip"
    n_bits: int = 1
    seed: int = 0

    def __post_init__(self):
        if not 0.0 <= self.prob <= 1.0:
            raise ValueError(f"corruption prob must be in [0,1]: "
                             f"{self.prob}")
        if self.mode not in CORRUPTION_MODES:
            raise ValueError(f"unknown corruption mode {self.mode!r}; "
                             f"expected one of {CORRUPTION_MODES}")
        if self.n_bits < 1:
            raise ValueError(f"n_bits must be >= 1: {self.n_bits}")

    def is_identity(self) -> bool:
        return self.prob <= 0.0


@dataclasses.dataclass(frozen=True)
class RescaleEvent:
    """Elastic world-size change: BEFORE running `step`, the cluster
    becomes `world_size` workers (EF state re-bucketed through ckpt/)."""
    step: int
    world_size: int

    def __post_init__(self):
        if self.step < 0 or self.world_size < 1:
            raise ValueError(f"bad rescale event {self!r}")


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One named condition set for the simulated cluster.

    `links` is indexed per worker slot (cycled when shorter than the
    current world size, so elastic rescales keep a well-defined link per
    slot); empty = homogeneous default link. `dirichlet_alpha` is the
    non-IID shard-skew concentration (None = IID split); smaller alpha
    means more skew.
    """
    name: str = "clean"
    n_workers: int = 4
    links: Tuple[LinkSpec, ...] = ()
    straggler: StragglerSpec = StragglerSpec()
    rescales: Tuple[RescaleEvent, ...] = ()
    dirichlet_alpha: Optional[float] = None
    data_seed: int = 0
    corruption: CorruptionSpec = CorruptionSpec()

    def __post_init__(self):
        if self.n_workers < 1:
            raise ValueError(f"n_workers must be >= 1: {self.n_workers}")
        if self.dirichlet_alpha is not None and self.dirichlet_alpha <= 0:
            raise ValueError(
                f"dirichlet_alpha must be > 0 or None: {self.dirichlet_alpha}")
        if list(self.rescales) != sorted(self.rescales,
                                         key=lambda e: e.step):
            raise ValueError("rescale events must be sorted by step")

    # ------------------------------------------------------------------
    def link(self, worker: int) -> LinkSpec:
        if not self.links:
            return LinkSpec()
        return self.links[worker % len(self.links)]

    def world_size_at(self, step: int) -> int:
        """World size in effect while running `step` (a RescaleEvent at
        step s applies from s onward)."""
        n = self.n_workers
        for ev in self.rescales:
            if step >= ev.step:
                n = ev.world_size
        return n

    def is_identity(self) -> bool:
        """True when every knob sits at the setting that must reproduce
        the un-wrapped path bit for bit."""
        return (not self.links
                and (self.straggler.prob <= 0.0
                     or self.straggler.delay_us <= 0.0)
                and all(ev.world_size == self.n_workers
                        for ev in self.rescales)
                and self.dirichlet_alpha is None
                and self.corruption.is_identity())

    def describe(self) -> str:
        parts = [f"n={self.n_workers}"]
        if self.links:
            parts.append(f"links={len(self.links)}")
        if self.straggler.prob > 0 and self.straggler.delay_us > 0:
            parts.append(f"straggle(p={self.straggler.prob},"
                         f"{self.straggler.delay_us}us)")
        if self.rescales:
            parts.append("rescale:" + "->".join(
                str(ev.world_size) for ev in self.rescales))
        if self.dirichlet_alpha is not None:
            parts.append(f"dirichlet={self.dirichlet_alpha}")
        if not self.corruption.is_identity():
            parts.append(f"corrupt({self.corruption.mode},"
                         f"p={self.corruption.prob})")
        return f"{self.name}[{' '.join(parts)}]"
