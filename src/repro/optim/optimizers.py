"""Pure-JAX pytree optimizers (SGD / momentum+Nesterov / Adam).

The paper trains with SGD; Figure 7c adds Nesterov momentum (outside its
theory) — we implement both to reproduce that ablation. States live as
pytrees shaped like the params, so they inherit the parameter sharding
(FSDP-sharded params ⇒ ZeRO-style sharded optimizer state for free).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "sgd"          # sgd | momentum | adam
    lr: float = 0.1            # base lr; schedules multiply it
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    nesterov: bool = False
    grad_clip: float = 0.0     # 0 = off; global-norm clip


def init_opt_state(cfg: OptConfig, params):
    if cfg.name == "sgd":
        return {}
    if cfg.name == "momentum":
        return {"m": jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)}
    if cfg.name == "adam":
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree_util.tree_map(z, params),
                "v": jax.tree_util.tree_map(z, params),
                "count": jnp.zeros((), jnp.int32)}
    raise ValueError(cfg.name)


def _clip(grads, max_norm: float):
    if max_norm <= 0:
        return grads
    gn = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                      for g in jax.tree_util.tree_leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree_util.tree_map(lambda g: g * scale.astype(g.dtype), grads)


def sgd(cfg: OptConfig, params, grads, state, lr: Array):
    grads = _clip(grads, cfg.grad_clip)

    def upd(p, g):
        g32 = g.astype(jnp.float32)
        if cfg.weight_decay:
            g32 = g32 + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * g32).astype(p.dtype)
    return jax.tree_util.tree_map(upd, params, grads), state


def momentum(cfg: OptConfig, params, grads, state, lr: Array):
    grads = _clip(grads, cfg.grad_clip)

    def upd(p, g, m):
        g32 = g.astype(jnp.float32)
        if cfg.weight_decay:
            g32 = g32 + cfg.weight_decay * p.astype(jnp.float32)
        m_new = cfg.beta1 * m + g32
        step = (g32 + cfg.beta1 * m_new) if cfg.nesterov else m_new
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m_new

    flat = jax.tree_util.tree_map(upd, params, grads, state["m"])
    new_p = jax.tree_util.tree_map(lambda t: t[0], flat,
                                   is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree_util.tree_map(lambda t: t[1], flat,
                                   is_leaf=lambda x: isinstance(x, tuple))
    return new_p, {"m": new_m}


def adam(cfg: OptConfig, params, grads, state, lr: Array):
    grads = _clip(grads, cfg.grad_clip)
    count = state["count"] + 1
    b1c = 1 - cfg.beta1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.beta2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        if cfg.weight_decay:
            g32 = g32 + cfg.weight_decay * p.astype(jnp.float32)
        m_new = cfg.beta1 * m + (1 - cfg.beta1) * g32
        v_new = cfg.beta2 * v + (1 - cfg.beta2) * g32 * g32
        step = (m_new / b1c) / (jnp.sqrt(v_new / b2c) + cfg.eps)
        return ((p.astype(jnp.float32) - lr * step).astype(p.dtype),
                m_new, v_new)

    flat = jax.tree_util.tree_map(upd, params, grads, state["m"], state["v"])
    pick = lambda i: jax.tree_util.tree_map(
        lambda t: t[i], flat, is_leaf=lambda x: isinstance(x, tuple))
    return pick(0), {"m": pick(1), "v": pick(2), "count": count}


def apply_updates(cfg: OptConfig, params, grads, state, lr: Array):
    """Dispatch on cfg.name. lr is the scheduled learning rate (traced)."""
    fn = {"sgd": sgd, "momentum": momentum, "adam": adam}[cfg.name]
    return fn(cfg, params, grads, state, lr)
