from repro.optim.optimizers import (OptConfig, init_opt_state, apply_updates,
                                    sgd, momentum, adam)
from repro.optim.schedules import piecewise_linear, constant, cosine
