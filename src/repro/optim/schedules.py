"""Learning-rate schedules. `piecewise_linear` reproduces the paper's
setup: 0.0 -> peak over the first warmup fraction, then linearly to 0."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def piecewise_linear(peak: float, total_steps: int, warmup_steps: int):
    """The paper's schedule: linear 0->peak over warmup, then peak->0."""
    def fn(step):
        s = jnp.asarray(step, jnp.float32)
        up = peak * s / max(1, warmup_steps)
        down = peak * (total_steps - s) / max(1, total_steps - warmup_steps)
        return jnp.clip(jnp.minimum(up, down), 0.0, peak)
    return fn


def cosine(peak: float, total_steps: int, warmup_steps: int = 0,
           floor: float = 0.0):
    def fn(step):
        s = jnp.asarray(step, jnp.float32)
        warm = peak * s / max(1, warmup_steps) if warmup_steps else peak
        t = jnp.clip((s - warmup_steps) / max(1, total_steps - warmup_steps),
                     0.0, 1.0)
        cos = floor + (peak - floor) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(s < warmup_steps, warm, cos) if warmup_steps else cos
    return fn
