"""Bidirectional compressed gradient aggregation (the paper's Algorithm 1)
realized as TPU collectives.

Algorithm 1:
  worker i:  g_i -> Q_W(g_i) -> send
  master  :  Q_M( (1/n) Σ_i Q_W(g_i) ) -> broadcast

The paper notes (§3) that with Q_M = identity this models all_reduce. On a
TPU mesh there is no master; every device plays master deterministically
(identical PRNG key ⇒ identical Q_M output), which is numerically the same.

Strategies (see DESIGN.md §4) trade wire bytes vs generality:

  simulated      compress→decompress densely, then psum.  Paper-faithful
                 numerics for EVERY operator; wire cost = dense allreduce.
  allgather      all_gather the encoded payloads; every device decodes all n
                 and averages. Exact Algorithm-1 numerics; wire = n·payload.
  rs_compress_ag reduce-scatter the dense gradient (bf16 wire), compress the
                 owned shard, all_gather the compressed shards. The shard
                 partition is a finer "layer" partition, covered by Lemma 1.
  shared_random  Random-k with a shared seed: all workers pick the SAME
                 indices, so the collective carries only k values (psum).
                 Exact Random-k semantics; smallest possible wire cost.
  ring           wire-only: the allgather wire path's packed buffers moved
                 by a chunked-ppermute ring with per-hop decode-accumulate
                 and double-buffered compress (core.wire.
                 execute_schedule_stream). Bit-identical to `allgather`
                 with wire=True — only the collective topology differs.
  rs_stream      wire-only: compress→reduce-scatter→allgather — each
                 worker encodes only the shard it owns and the packed
                 SHARDS ride the ring (the FSDP on-demand pattern).
                 Degenerates exactly to the allgather wire path at
                 n_workers == 1; a different (Lemma-1-covered) algorithm
                 beyond that.

All functions here run INSIDE shard_map; `axis_names` are the data-parallel
mesh axes (("data",) or ("pod", "data")). The streaming strategies require
a single DP axis (the ring permutation is per-axis).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.compressors import Compressor, Identity, RandomK, make_compressor
from repro.core.granularity import Granularity
from repro.core.plan import UnitPlan, build_plan
from repro.core.schedule import CommSchedule, build_schedule

Array = jax.Array

STRATEGIES = ("dense", "simulated", "allgather", "rs_compress_ag",
              "shared_random", "ring", "rs_stream")

#: strategies executed by the streaming ring collective (wire=True only)
STREAM_STRATEGIES = ("ring", "rs_stream")


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    """Static configuration of the compressed-communication stack.

    `fusion_bytes` turns on comm scheduling (core.schedule): None keeps
    the unscheduled UnitPlan execution (identical graph to before the
    schedule subsystem existed); a number routes execution through the
    CommSchedule compiled from the active plan — backward-ready message
    order, buckets fused below the threshold (0 = per-bucket messages,
    math.inf = one message). Scheduling never changes numerics.

    `integrity` (wire paths only) adds the Fletcher-32 header word to
    every fused wire message — 4 bytes/message of wire overhead, zero
    change to payloads or decoded numerics — so receivers can verify
    packed bytes before decoding (core.wire.verify_message; what the
    resilience plane's corruption detection rides on).
    """
    qw: Compressor = Identity()
    qm: Compressor = Identity()
    granularity: Granularity = Granularity("layerwise")
    strategy: str = "simulated"
    error_feedback: bool = False
    wire_dtype: str = "float32"  # dense/rs wire format: float32 | bfloat16
    fusion_bytes: Optional[float] = None
    integrity: bool = False

    def __post_init__(self):
        if self.strategy not in STRATEGIES:
            raise ValueError(f"unknown strategy {self.strategy!r}")
        if self.strategy == "shared_random" and not isinstance(self.qw, RandomK):
            raise ValueError("shared_random requires a RandomK worker compressor")
        if self.error_feedback and self.strategy not in (
                "simulated", "allgather", "ring", "rs_stream"):
            raise ValueError("error feedback supports simulated/allgather/"
                             "ring/rs_stream only")
        if self.fusion_bytes is not None and not float(self.fusion_bytes) >= 0:
            raise ValueError(
                f"fusion_bytes must be >= 0 or None, got {self.fusion_bytes!r}")


def no_compression() -> CompressionConfig:
    return CompressionConfig(strategy="dense")


def _wire(x: Array, cfg: CompressionConfig) -> Array:
    return x.astype(jnp.bfloat16) if cfg.wire_dtype == "bfloat16" else x


def _mean_psum(x: Array, axis_names, n_workers: int) -> Array:
    """psum / n with the world size resolved STATICALLY. The legacy
    version learned n from an extra psum(ones) — one redundant collective
    per unit per step (tests/test_stream.py counts the drop via jaxpr
    inspection). Dividing by the static n is bit-identical: psum(ones)
    yields exactly float(n) for any world size representable in the
    dtype, so the divisor value is unchanged."""
    return jax.lax.psum(x, axis_names) / jnp.asarray(n_workers, x.dtype)


def _worker_key(key: Array, axis_names) -> Array:
    return jax.random.fold_in(key, jax.lax.axis_index(axis_names))


def _master_key(key: Array) -> Array:
    return jax.random.fold_in(key, 0x5EED)


# --------------------------------------------------------------------------
# per-unit aggregation closures
# --------------------------------------------------------------------------

def _unit_simulated(cfg: CompressionConfig, axis_names, n_workers: int):
    def fn(x: Array, key: Array) -> Array:
        xw = cfg.qw.sim(x, _worker_key(key, axis_names))
        xm = _mean_psum(_wire(xw, cfg), axis_names,
                        n_workers).astype(x.dtype)
        return cfg.qm.sim(xm, _master_key(key))
    return fn


def _unit_simulated_ef(cfg: CompressionConfig, axis_names,
                       n_workers: int):
    def fn(x: Array, m: Array, key: Array):
        e = x + m
        xw = cfg.qw.sim(e, _worker_key(key, axis_names))
        m_new = e - xw
        xm = _mean_psum(_wire(xw, cfg), axis_names,
                        n_workers).astype(x.dtype)
        return cfg.qm.sim(xm, _master_key(key)), m_new
    return fn


def _cast_payload(payload, cfg):
    """bf16 wire for the float legs of a compressed payload (indices and
    quantized ints are untouched)."""
    if cfg.wire_dtype != "bfloat16":
        return payload
    return jax.tree_util.tree_map(
        lambda v: v.astype(jnp.bfloat16)
        if jnp.issubdtype(v.dtype, jnp.floating) else v, payload)


def _unit_allgather(cfg: CompressionConfig, axis_names):
    def fn(x: Array, key: Array) -> Array:
        d = x.shape[0]
        payload = _cast_payload(cfg.qw.encode(x, _worker_key(key, axis_names)),
                                cfg)
        gathered = jax.lax.all_gather(payload, axis_names, axis=0, tiled=False)
        decoded = jax.vmap(lambda p: cfg.qw.decode(p, d, x.dtype))(gathered)
        xm = jnp.mean(decoded, axis=0)
        return cfg.qm.sim(xm, _master_key(key))
    return fn


def _unit_allgather_ef(cfg: CompressionConfig, axis_names):
    def fn(x: Array, m: Array, key: Array):
        d = x.shape[0]
        e = x + m
        wkey = _worker_key(key, axis_names)
        payload = _cast_payload(cfg.qw.encode(e, wkey), cfg)
        m_new = e - cfg.qw.decode(payload, d, x.dtype)
        gathered = jax.lax.all_gather(payload, axis_names, axis=0, tiled=False)
        decoded = jax.vmap(lambda p: cfg.qw.decode(p, d, x.dtype))(gathered)
        xm = jnp.mean(decoded, axis=0)
        return cfg.qm.sim(xm, _master_key(key)), m_new
    return fn


def _unit_rs_compress_ag(cfg: CompressionConfig, axis_names, n_workers: int):
    def fn(x: Array, key: Array) -> Array:
        d = x.shape[0]
        pad = (-d) % n_workers
        xp = _wire(jnp.pad(x, (0, pad)), cfg)
        # reduce-scatter: each worker owns the mean of its 1/n chunk
        shard = jax.lax.psum_scatter(xp, axis_names, scatter_dimension=0,
                                     tiled=True).astype(x.dtype) / n_workers
        ds = shard.shape[0]
        # Padding discipline (the phantom-tail bugfix): positions >= d are
        # pad, not data. They arrive from psum_scatter as exact zeros, but
        # the mask PINS that contract before encode — sparse codecs must
        # never spend capacity-k records on a phantom tail — and the
        # decoded tail is forced back to zero before the global trim, so
        # a codec that emits a nonzero at a pad slot (e.g. a 0-value topk
        # record dequantized oddly) cannot leak. bits.comm_report charges
        # the TRUE per-worker shard sizes min(ds, d - w*ds), not the
        # padded capacity (hand-computed regression in test_stream.py).
        idx = jax.lax.axis_index(axis_names)
        own_mask = (idx * ds + jnp.arange(ds)) < d
        shard = jnp.where(own_mask, shard, 0.0)
        payload = _cast_payload(
            cfg.qw.encode(shard, _worker_key(key, axis_names)), cfg)
        gathered = jax.lax.all_gather(payload, axis_names, axis=0, tiled=False)
        decoded = jax.vmap(lambda p: cfg.qw.decode(p, ds, x.dtype))(gathered)
        gmask = (jnp.arange(n_workers * ds) < d).reshape(n_workers, ds)
        decoded = jnp.where(gmask, decoded, 0.0)
        xm = decoded.reshape(-1)[:d]
        return cfg.qm.sim(xm, _master_key(key))
    return fn


def _unit_shared_random(cfg: CompressionConfig, axis_names,
                        n_workers: int):
    qw: RandomK = cfg.qw  # validated in __post_init__

    def fn(x: Array, key: Array) -> Array:
        d = x.shape[0]
        idx = qw._indices(d, key)  # SHARED seed: same indices on every worker
        vals = x[idx]
        if qw.scale:
            vals = vals * (d / max(1, min(d, int(round(qw.ratio * d)))))
        vals = _mean_psum(_wire(vals, cfg), axis_names,
                          n_workers).astype(x.dtype)
        xm = jnp.zeros((d,), x.dtype).at[idx].set(vals)
        return cfg.qm.sim(xm, _master_key(key))
    return fn


# --------------------------------------------------------------------------
# public API
# --------------------------------------------------------------------------

def _telemetry_inc(telemetry_plan, cfg, grads, agg, key, entire_model):
    """One-step telemetry increment measured on this aggregation call
    (lazy import: control depends on core, never the reverse)."""
    from repro.control.telemetry import measure
    return measure(telemetry_plan, cfg.qw, grads, key, grads_hat=agg,
                   entire_model=entire_model)


def _wire_codec_for(cfg: CompressionConfig, allgather_available=True):
    """Resolve + validate the wire codec for a config's worker compressor
    (lazy import keeps aggregation importable before wire).
    `allgather_available=False` is the single-device simulated-worker
    harness, which has no allgather wire path to point the caller at."""
    from repro.core.wire import wire_codec
    if cfg.strategy not in ("simulated", "allgather") + STREAM_STRATEGIES:
        raise ValueError(
            f"wire=True supports the simulated/allgather/ring/rs_stream "
            f"strategies, not {cfg.strategy!r}")
    codec = wire_codec(cfg.qw, wire_dtype=cfg.wire_dtype,
                       integrity=cfg.integrity)
    if cfg.strategy == "simulated" and not codec.exact_sim:
        hint = ("run it under strategy='allgather', whose collective "
                "carries the real (capacity-bounded / bf16-cast) payload"
                if allgather_available else "drop wire=True")
        raise ValueError(
            f"{cfg.qw.name}: this wire format is not bit-exact against "
            f"sim (capacity-bounded records, or the lossy bfloat16 value "
            f"cast) while strategy='simulated' promises the exact "
            f"operator — {hint}")
    return codec


def _wire_post(cfg: CompressionConfig, axis_names, codec, n_workers: int):
    """The post-decode leg of the wire pipeline: the collective + master
    compression that _unit_simulated/_unit_allgather run after Q_W —
    identical arithmetic, with Q_W replaced by the bit-exact payload
    round-trip (simulated) or the packed buffer through the collective
    (allgather)."""
    if cfg.strategy == "simulated":
        def post(payload, xhat, key):
            xm = _mean_psum(_wire(xhat, cfg), axis_names,
                            n_workers).astype(xhat.dtype)
            return cfg.qm.sim(xm, _master_key(key))
    else:  # allgather: the REAL uint8 payload crosses the collective
        def post(payload, xhat, key):
            d = xhat.shape[0]
            gathered = jax.lax.all_gather(payload, axis_names, axis=0,
                                          tiled=False)
            decoded = jax.vmap(lambda p: codec.decode(p, d))(gathered)
            return cfg.qm.sim(jnp.mean(decoded, axis=0), _master_key(key))
    return post


def _executor(plan: UnitPlan, cfg: CompressionConfig,
              schedule: Optional[CommSchedule]):
    """What execution runs through: an explicit CommSchedule, the schedule
    compiled from cfg.fusion_bytes, or the bare plan. All three share the
    execute/execute_with_state signature and are bit-identical; scheduling
    only changes program order and message accounting."""
    if schedule is not None:
        return schedule
    if cfg.fusion_bytes is not None:
        return build_schedule(plan, cfg.fusion_bytes)
    return plan


def compressed_allreduce(grads, stacked, cfg: CompressionConfig,
                         axis_names: Sequence[str], key: Array,
                         n_workers: int,
                         ef_state=None,
                         plan: Optional[UnitPlan] = None,
                         schedule: Optional[CommSchedule] = None,
                         telemetry_plan: Optional[UnitPlan] = None,
                         telemetry_entire_model: bool = True,
                         wire: bool = False,
                         recorder=None,
                         stream_chunk_bytes: Optional[float] = None,
                         faults=None,
                         alive=None):
    """Aggregate data-parallel gradients with bidirectional compression.

    Must be called inside shard_map. Returns (grads_hat, new_ef_state) —
    or (grads_hat, new_ef_state, telemetry_inc) when `telemetry_plan` is
    given: a control.telemetry.TelemetryState increment measured on the
    device-local gradient vs the aggregated output (the caller pmean-s it
    across devices). `n_workers` is the static product of the DP axis
    sizes. Pass `plan` (a UnitPlan built once at trace time, e.g. by the
    engine) to skip re-deriving the unit partition; otherwise the cached
    plan for (grads structure, granularity) is fetched. Pass `schedule`
    (or set cfg.fusion_bytes) to stream execution through a CommSchedule
    — same numerics, backward-ready fused message order.

    `wire=True` materializes the worker compression as REAL bit-packed
    payloads (core.wire): execution streams through a CommSchedule
    (cfg.fusion_bytes, default 0 = per-bucket messages) whose fused
    messages are actual uint8 buffers; under `allgather` the packed
    bytes themselves cross the collective. Bit-identical to the
    unpacked path — every codec round-trips exactly to its compressor.

    `recorder` (duck-typed, obs.trace.TraceRecorder) threads through to
    the plan/schedule/wire execution hooks for per-message span
    attribution; None or disabled leaves the traced graph untouched.

    Strategies `ring`/`rs_stream` require wire=True and a single DP
    axis: they execute the schedule through the streaming chunked-
    ppermute collective (CommSchedule.execute_streaming) instead of a
    blocking all_gather — `ring` bit-identical to `allgather`,
    `rs_stream` the compress→reduce-scatter→allgather shard pipeline.
    `stream_chunk_bytes` sets their per-hop dispatch granularity
    (None = whole-message hops).

    `faults` (duck-typed, resil.FaultInjector; wire=True only) corrupts
    the received packed bytes — the caller drains the injector's
    verdict stream (take_flags) inside the same trace. `alive`
    (strategy='dense' only) is a static per-worker participation mask:
    the mean renormalizes over surviving workers (the straggler-timeout
    partial-participation policy); compressed strategies keep full
    participation — exclude workers in the simulated harness instead.
    """
    axis_names = tuple(axis_names)
    if plan is None and schedule is not None:
        plan = schedule.plan
    if cfg.strategy in STREAM_STRATEGIES and not wire:
        raise ValueError(
            f"strategy {cfg.strategy!r} is the streaming collective over "
            f"PACKED wire buffers — pass wire=True (the unpacked payload "
            f"pytrees have no single buffer to ring-permute)")
    if faults is not None and not wire:
        raise ValueError("fault injection acts on PACKED wire bytes — "
                         "pass wire=True")
    if alive is not None and cfg.strategy != "dense":
        raise ValueError(
            "partial participation (alive=...) is implemented for the "
            "dense strategy here; for compressed aggregation use the "
            "simulated-worker harness (aggregate_simulated_workers)")

    def ret(agg, ef):
        if telemetry_plan is None:
            return agg, ef
        return agg, ef, _telemetry_inc(telemetry_plan, cfg, grads, agg, key,
                                       telemetry_entire_model)

    if cfg.strategy == "dense":
        if wire:
            raise ValueError(
                "wire=True with strategy='dense': the dense allreduce "
                "moves raw tensors — there is no compressed payload to "
                "pack; use strategy='simulated' with an identity "
                "compressor for a packed dense-f32 baseline")
        if alive is not None:
            # renormalized mean over survivors: each device scales its
            # contribution by its own alive flag; the divisor is the
            # (static) survivor count
            w = jnp.asarray(alive, jnp.float32)
            me = w[jax.lax.axis_index(axis_names)]
            denom = float(sum(1.0 for a in alive if a))
            agg = jax.tree_util.tree_map(
                lambda g: (jax.lax.psum(
                    _wire(g, cfg) * me.astype(_wire(g, cfg).dtype),
                    axis_names) / denom).astype(g.dtype),
                grads)
            return ret(agg, ef_state)
        agg = jax.tree_util.tree_map(
            lambda g: _mean_psum(_wire(g, cfg), axis_names,
                                 n_workers).astype(g.dtype),
            grads)
        return ret(agg, ef_state)

    if not jax.tree_util.tree_leaves(grads):  # nothing to aggregate
        return ret(grads, ef_state)

    if plan is None:
        plan = build_plan(grads, stacked, cfg.granularity)
    ex = _executor(plan, cfg, schedule)

    if wire:
        codec = _wire_codec_for(cfg)
        sched = (ex if isinstance(ex, CommSchedule)
                 else build_schedule(plan, 0.0))
        wk = partial(_worker_key, axis_names=axis_names)
        if cfg.strategy in STREAM_STRATEGIES:
            mode = "ring" if cfg.strategy == "ring" else "rs"

            def stream_post(xm, ukey):
                return cfg.qm.sim(xm, _master_key(ukey))
            if cfg.error_feedback:
                if ef_state is None:
                    raise ValueError("error_feedback=True requires ef_state")
                agg, ef, _bufs = sched.execute_streaming_with_state(
                    stream_post, grads, ef_state, key, wire=codec,
                    axis_names=axis_names, n_workers=n_workers, mode=mode,
                    wire_key=wk, chunk_bytes=stream_chunk_bytes,
                    recorder=recorder, faults=faults)
                return ret(agg, ef)
            agg, _bufs = sched.execute_streaming(
                stream_post, grads, key, wire=codec, axis_names=axis_names,
                n_workers=n_workers, mode=mode, wire_key=wk,
                chunk_bytes=stream_chunk_bytes, recorder=recorder,
                faults=faults)
            return ret(agg, ef_state)
        post = _wire_post(cfg, axis_names, codec, n_workers)
        if cfg.error_feedback:
            if ef_state is None:
                raise ValueError("error_feedback=True requires ef_state")
            agg, ef, _bufs = sched.execute_with_state(
                post, grads, ef_state, key, wire=codec, wire_key=wk,
                recorder=recorder, faults=faults)
            return ret(agg, ef)
        agg, _bufs = sched.execute(post, grads, key, wire=codec,
                                   wire_key=wk, recorder=recorder,
                                   faults=faults)
        return ret(agg, ef_state)

    if cfg.error_feedback:
        if ef_state is None:
            raise ValueError("error_feedback=True requires ef_state")
        fn = (_unit_simulated_ef(cfg, axis_names, n_workers)
              if cfg.strategy == "simulated"
              else _unit_allgather_ef(cfg, axis_names))
        agg, ef = ex.execute_with_state(fn, grads, ef_state, key,
                                        recorder=recorder)
        return ret(agg, ef)

    if cfg.strategy == "simulated":
        fn = _unit_simulated(cfg, axis_names, n_workers)
    elif cfg.strategy == "allgather":
        fn = _unit_allgather(cfg, axis_names)
    elif cfg.strategy == "rs_compress_ag":
        fn = _unit_rs_compress_ag(cfg, axis_names, n_workers)
    elif cfg.strategy == "shared_random":
        fn = _unit_shared_random(cfg, axis_names, n_workers)
    else:  # pragma: no cover
        raise ValueError(cfg.strategy)
    return ret(ex.execute(fn, grads, key, recorder=recorder), ef_state)


def aggregate_simulated_workers(worker_grads, stacked, cfg: CompressionConfig,
                                key: Array, ef_state=None,
                                plan: Optional[UnitPlan] = None,
                                schedule: Optional[CommSchedule] = None,
                                telemetry_plan: Optional[UnitPlan] = None,
                                telemetry_entire_model: bool = True,
                                wire: bool = False,
                                faults=None,
                                alive=None):
    """Single-device realization of Algorithm 1 for the paper-repro
    experiments: `worker_grads` leaves carry a leading worker axis n.

    Mathematically identical to compressed_allreduce(strategy='simulated')
    on an n-way mesh; runs on one CPU device. One UnitPlan (built from the
    per-worker tree, i.e. without the worker axis) serves both the worker
    and master compression passes. With `telemetry_plan` the return value
    grows a third element: a TelemetryState increment measured on the
    mean worker gradient vs the aggregated output. `schedule` /
    cfg.fusion_bytes stream the worker compression pass through a
    CommSchedule (bit-identical; the vmap over workers batches the
    ordering barriers). `wire=True` materializes each worker's
    compression pass as real bit-packed message buffers (core.wire) —
    bit-identical output; the master Q_M pass stays dense (it never
    leaves the device in Algorithm 1's master step).

    Resilience hooks (both default None = the unchanged graph):

    `faults` (resil.FaultInjector; requires wire=True) corrupts each
    worker's RECEIVED message bytes; with cfg.integrity the Fletcher-32
    verdicts are drained inside the vmapped per-worker pass and the
    return value grows a LAST element, a fault-info dict of traced
    counters {"messages", "corrupt_detected", "resends"} summed over
    workers (resends counts detected-and-replaced messages when the
    injector models resend).

    `alive` (bool (n,), host-side) renormalizes the aggregation mean
    over surviving workers (straggler-timeout partial participation);
    dead workers' EF residuals are FROZEN at their previous value — an
    excluded worker never saw its payload applied, so its error memory
    must not advance.
    """
    n = jax.tree_util.tree_leaves(worker_grads)[0].shape[0]
    if faults is not None and not wire:
        raise ValueError("fault injection acts on PACKED wire bytes — "
                         "pass wire=True")
    if plan is None and schedule is not None:
        plan = schedule.plan
    if plan is None:
        per_worker_tree = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype),
            worker_grads)
        plan = build_plan(per_worker_tree, stacked, cfg.granularity)
    ex = _executor(plan, cfg, schedule)
    codec = None
    if wire:
        codec = _wire_codec_for(
            cfg if cfg.strategy == "simulated"
            else dataclasses.replace(cfg, strategy="simulated"),
            allgather_available=False)
        wire_sched = (ex if isinstance(ex, CommSchedule)
                      else build_schedule(plan, 0.0))

    def per_worker(g_i, i):
        wkey = jax.random.fold_in(key, i)
        if codec is not None:
            out, _bufs = wire_sched.execute(None, g_i, wkey, wire=codec,
                                            faults=faults)
            # drain the integrity verdicts INSIDE the vmapped trace —
            # they are this trace's tracers and leave only as outputs
            flags = (faults.take_flags() if faults is not None
                     else jnp.zeros((0,), jnp.bool_))
            return out, flags

        def fn(x, ukey):
            return cfg.qw.sim(x, ukey)
        return ex.execute(fn, g_i, wkey), jnp.zeros((0,), jnp.bool_)

    if cfg.error_feedback:
        if ef_state is None:
            raise ValueError("error_feedback=True requires ef_state")

        def per_worker_ef(g_i, m_i, i):
            wkey = jax.random.fold_in(key, i)
            if codec is not None:
                out, m_new, _bufs = wire_sched.execute_with_state(
                    None, g_i, m_i, wkey, wire=codec, faults=faults)
                flags = (faults.take_flags() if faults is not None
                         else jnp.zeros((0,), jnp.bool_))
                return out, m_new, flags

            def fn(x, m, ukey):
                e = x + m
                q = cfg.qw.sim(e, ukey)
                return q, e - q
            out, m_new = ex.execute_with_state(fn, g_i, m_i, wkey)
            return out, m_new, jnp.zeros((0,), jnp.bool_)
        compressed, new_ef, flags = jax.vmap(
            per_worker_ef, in_axes=(0, 0, 0))(worker_grads, ef_state,
                                              jnp.arange(n))
        if alive is not None:
            # a timed-out worker's payload never reached the reduce, so
            # its error memory must not advance: freeze its residual
            amask = jnp.asarray(alive, jnp.bool_)
            new_ef = jax.tree_util.tree_map(
                lambda nm, om: jnp.where(
                    amask.reshape((n,) + (1,) * (nm.ndim - 1)), nm, om),
                new_ef, ef_state)
    else:
        compressed, flags = jax.vmap(per_worker, in_axes=(0, 0))(
            worker_grads, jnp.arange(n))
        new_ef = ef_state

    if alive is None:
        mean = jax.tree_util.tree_map(lambda g: jnp.mean(g, axis=0),
                                      compressed)
    else:
        # partial participation: mean renormalized over survivors
        w = jnp.asarray(alive, jnp.float32)
        w = w / jnp.sum(w)
        mean = jax.tree_util.tree_map(
            lambda g: jnp.tensordot(w, g.astype(jnp.float32),
                                    axes=1).astype(g.dtype), compressed)

    def master_fn(x, ukey):
        return cfg.qm.sim(x, _master_key(ukey))
    out = ex.execute(master_fn, mean, key)
    rets = [out, new_ef]
    if telemetry_plan is not None:
        gbar = jax.tree_util.tree_map(lambda g: jnp.mean(g, axis=0),
                                      worker_grads)
        rets.append(_telemetry_inc(telemetry_plan, cfg, gbar, out, key,
                                   telemetry_entire_model))
    if faults is not None:
        detected = jnp.sum(~flags) if flags.size else jnp.zeros((), jnp.int32)
        rets.append({
            "messages": jnp.asarray(flags.size, jnp.int32),
            "corrupt_detected": detected.astype(jnp.int32),
            "resends": (detected.astype(jnp.int32)
                        if getattr(faults, "resend", False)
                        else jnp.zeros((), jnp.int32)),
        })
    return tuple(rets)
