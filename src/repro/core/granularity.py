"""Compression granularity: entire-model vs layer-wise vs block-wise.

This module is the heart of the paper's subject. A *granularity* decides the
unit the compressor sees:

  entire_model : every gradient leaf flattened and concatenated -> ONE unit
                 (what the THEORY of prior work assumes)
  layerwise    : one unit per logical layer tensor (what IMPLEMENTATIONS do).
                 Layer-stacked leaves (leading dim = L, produced by
                 lax.scan-style parameter stacking) are vmapped over L.
  blockwise    : fixed-size blocks of the flattened gradient (beyond-paper;
                 Lemma 1 covers any partition, and this is the partition our
                 Pallas kernels implement natively on TPU tiles)

`apply_unitwise(fn, ...)` maps fn(x_flat_f32, key) -> x_flat over every unit
and reassembles the gradient pytree. fn may contain collectives (they batch
under vmap), which is how aggregation.py builds compressed all-reduce out of
this module.

Execution goes through core.plan.UnitPlan: a static bucketed plan computed
once at trace time, executing one batched compressor dispatch per unit size
class instead of one traced call per leaf. The original per-leaf loops are
kept as `apply_unitwise_reference` / `apply_unitwise_with_state_reference`
— the numerical oracle the plan path is property-tested against
(tests/test_plan.py).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class Granularity:
    kind: str = "layerwise"  # entire_model | layerwise | blockwise
    block_size: int = 65536  # only for blockwise

    def __post_init__(self):
        if self.kind not in ("entire_model", "layerwise", "blockwise"):
            raise ValueError(f"unknown granularity kind {self.kind!r}")


def stacked_mask(params, is_stacked_path: Callable[[Tuple], bool] = None):
    """Pytree of bools marking leaves whose leading axis is a layer-stack.

    Default predicate: any path element named 'blocks' / 'layers' /
    'encoder_blocks' / 'decoder_blocks' marks a scan-stacked subtree.
    """
    names = ("blocks", "layers", "encoder_blocks", "decoder_blocks")

    def default_pred(path):
        for p in path:
            key = getattr(p, "key", getattr(p, "name", None))
            if key in names:
                return True
        return False

    pred = is_stacked_path or default_pred
    return jax.tree_util.tree_map_with_path(lambda p, x: pred(p), params)


def unit_dims(grads, stacked, gran: Granularity) -> List[int]:
    """Static per-unit dimensions d_j — feeds bits.py and theory.py."""
    leaves = jax.tree_util.tree_leaves(grads)
    marks = jax.tree_util.tree_leaves(stacked)
    total = sum(int(l.size) for l in leaves)
    if gran.kind == "entire_model":
        return [total]
    if gran.kind == "blockwise":
        b = gran.block_size
        n_full, rem = divmod(total, b)
        return [b] * n_full + ([rem] if rem else [])
    dims: List[int] = []
    for leaf, s in zip(leaves, marks):
        if s and leaf.ndim >= 1 and leaf.shape[0] > 0:
            L = leaf.shape[0]
            dims.extend([int(leaf.size) // L] * L)
        else:
            dims.append(int(leaf.size))
    return dims


def num_units(grads, stacked, gran: Granularity) -> int:
    return len(unit_dims(grads, stacked, gran))


def _fold_unit(key: Array, uid: int) -> Array:
    return jax.random.fold_in(key, uid)


def apply_unitwise(fn, gran: Granularity, grads, stacked, key: Array,
                   plan=None):
    """Map fn(x_flat: f32[d], key) -> f32[d] over every compression unit.

    Returns a pytree with the structure/dtypes of `grads`. Executes via a
    (cached) UnitPlan: O(#size-classes) batched dispatches, not O(#leaves).
    Pass `plan` to reuse a plan built once at trace time.
    """
    from repro.core.plan import build_plan
    if plan is None:
        plan = build_plan(grads, stacked, gran)
    return plan.execute(fn, grads, key)


def apply_unitwise_reference(fn, gran: Granularity, grads, stacked,
                             key: Array):
    """Legacy per-leaf execution path (the plan's numerical oracle)."""
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    marks = jax.tree_util.tree_leaves(stacked)

    if gran.kind == "entire_model":
        flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])
        out = fn(flat, _fold_unit(key, 0))
        outs, off = [], 0
        for l in leaves:
            outs.append(out[off:off + l.size].reshape(l.shape).astype(l.dtype))
            off += l.size
        return jax.tree_util.tree_unflatten(treedef, outs)

    if gran.kind == "blockwise":
        flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])
        total = flat.shape[0]
        b = gran.block_size
        pad = (-total) % b
        padded = jnp.pad(flat, (0, pad))
        blocks = padded.reshape(-1, b)
        nb = blocks.shape[0]
        keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(jnp.arange(nb))
        out = jax.vmap(fn)(blocks, keys).reshape(-1)[:total]
        outs, off = [], 0
        for l in leaves:
            outs.append(out[off:off + l.size].reshape(l.shape).astype(l.dtype))
            off += l.size
        return jax.tree_util.tree_unflatten(treedef, outs)

    # layerwise
    outs = []
    uid = 0
    for leaf, s in zip(leaves, marks):
        if s and leaf.ndim >= 1 and leaf.shape[0] > 0:
            L = leaf.shape[0]
            x = leaf.reshape(L, -1).astype(jnp.float32)
            base = _fold_unit(key, uid)
            keys = jax.vmap(lambda i: jax.random.fold_in(base, i))(jnp.arange(L))
            y = jax.vmap(fn)(x, keys)
            outs.append(y.reshape(leaf.shape).astype(leaf.dtype))
            uid += L
        else:
            y = fn(leaf.reshape(-1).astype(jnp.float32), _fold_unit(key, uid))
            outs.append(y.reshape(leaf.shape).astype(leaf.dtype))
            uid += 1
    return jax.tree_util.tree_unflatten(treedef, outs)


def apply_unitwise_with_state(fn, gran: Granularity, grads, state, stacked,
                              key: Array, plan=None):
    """Like apply_unitwise, but fn(x, m, key) -> (y, m_new) threads a
    same-shaped per-unit state (error-feedback memory)."""
    from repro.core.plan import build_plan
    if plan is None:
        plan = build_plan(grads, stacked, gran)
    return plan.execute_with_state(fn, grads, state, key)


def apply_unitwise_with_state_reference(fn, gran: Granularity, grads, state,
                                        stacked, key: Array):
    """Legacy per-leaf stateful path (the plan's numerical oracle)."""
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    sleaves = jax.tree_util.tree_leaves(state)
    marks = jax.tree_util.tree_leaves(stacked)

    if gran.kind == "entire_model":
        flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])
        mflat = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in sleaves])
        out, mnew = fn(flat, mflat, _fold_unit(key, 0))
        y_leaves, m_leaves, off = [], [], 0
        for l in leaves:
            y_leaves.append(out[off:off + l.size].reshape(l.shape).astype(l.dtype))
            m_leaves.append(mnew[off:off + l.size].reshape(l.shape).astype(l.dtype))
            off += l.size
        return (jax.tree_util.tree_unflatten(treedef, y_leaves),
                jax.tree_util.tree_unflatten(treedef, m_leaves))

    if gran.kind == "blockwise":
        flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])
        mflat = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in sleaves])
        total = flat.shape[0]
        b = gran.block_size
        pad = (-total) % b
        blocks = jnp.pad(flat, (0, pad)).reshape(-1, b)
        mblocks = jnp.pad(mflat, (0, pad)).reshape(-1, b)
        nb = blocks.shape[0]
        keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(jnp.arange(nb))
        out, mnew = jax.vmap(fn)(blocks, mblocks, keys)
        out = out.reshape(-1)[:total]
        mnew = mnew.reshape(-1)[:total]
        y_leaves, m_leaves, off = [], [], 0
        for l in leaves:
            y_leaves.append(out[off:off + l.size].reshape(l.shape).astype(l.dtype))
            m_leaves.append(mnew[off:off + l.size].reshape(l.shape).astype(l.dtype))
            off += l.size
        return (jax.tree_util.tree_unflatten(treedef, y_leaves),
                jax.tree_util.tree_unflatten(treedef, m_leaves))

    y_leaves, m_leaves = [], []
    uid = 0
    for leaf, mleaf, s in zip(leaves, sleaves, marks):
        if s and leaf.ndim >= 1 and leaf.shape[0] > 0:
            L = leaf.shape[0]
            x = leaf.reshape(L, -1).astype(jnp.float32)
            m = mleaf.reshape(L, -1).astype(jnp.float32)
            base = _fold_unit(key, uid)
            keys = jax.vmap(lambda i: jax.random.fold_in(base, i))(jnp.arange(L))
            y, mnew = jax.vmap(fn)(x, m, keys)
            y_leaves.append(y.reshape(leaf.shape).astype(leaf.dtype))
            m_leaves.append(mnew.reshape(leaf.shape).astype(leaf.dtype))
            uid += L
        else:
            y, mnew = fn(leaf.reshape(-1).astype(jnp.float32),
                         mleaf.reshape(-1).astype(jnp.float32),
                         _fold_unit(key, uid))
            y_leaves.append(y.reshape(leaf.shape).astype(leaf.dtype))
            m_leaves.append(mnew.reshape(leaf.shape).astype(leaf.dtype))
            uid += 1
    return (jax.tree_util.tree_unflatten(treedef, y_leaves),
            jax.tree_util.tree_unflatten(treedef, m_leaves))
