"""WireCodec: real bit-packed wire payloads for every compressor.

The paper's subject is the gap between what theory assumes and what
implementations actually put on the wire. Until this module, the repo's
wire costs were pure accounting (`bits.comm_report`) — analytic bit
counts that nothing forced to be ACHIEVABLE. A `WireCodec` closes that
loop: per compressor, a jit-able `encode(unit) -> uint8 payload` /
`decode(payload) -> unit` pair whose output is a real byte buffer
(`payload.size * 8` is the wire truth) and whose round-trip is
BIT-IDENTICAL to the simulated operator:

    codec.decode(codec.encode(x, key), d)  ==  compressor.sim(x, key)

bit for bit — so routing execution through materialized payloads
(`CommSchedule.execute(..., wire=codec)`) never changes numerics, and
the accounted bits can be differentially tested against measured bytes
(tests/test_wire.py).

Codec formats (all legs little-endian; bit i of a packed leg lands in
uint32 word i//32 at position i%32 — kernels/pack.py is the hot path,
`kernels/ref.pack_bits_ref` the oracle):

  dense      raw f32 bytes                                  32 bits/entry
  qsgd(s)    f32 norm + b-bit offset-binary levels,         b = ceil(
             code = level + s in [0, 2s]                    log2(2s+1))
  terngrad   f32 scale + 2-bit codes (t+1 in {0,1,2})       2 bits/entry
  signsgd    1-bit signs (x >= 0); majority-vote            1 bit/entry
             aggregation operates on the packed words
  natural    9-bit codes: sign*(exponent+128) + 255         9 bits/entry
  topk /     k f32 values + k packed indices of             32 + ceil(
  randomk    ceil(log2(d)) bits each (dim-dependent!)       log2(d))/rec
  threshold  same record format, capacity-bounded count     (not sim-
             (cap_ratio) — wire and sim genuinely differ     exact)

Padding rule (documented + asserted by the differential suite): every
packed leg rounds up to a whole uint32 word, so

    codec.wire_bits(d) == compressor.payload_bits(d) + padding_bits(d)

with padding_bits(d) == (-packed_leg_bits) % 32 < 32 per packed leg and
0 for dense. The accounting can never silently drift from the wire: the
suite asserts the equality for every codec at every granularity.

Fused wire messages: `execute_schedule_wire` streams a CommSchedule
message by message, concatenating each message's packed unit payloads
into ONE uint8 buffer behind a header table of per-bucket byte offsets
(uint32 [n_buckets, offset_0, ..]) — a message is a real buffer whose
size*8 is the wire truth, and decoding reads back OUT OF the buffer so
the bytes are load-bearing in the compiled graph.

The exception that proves the paper's point: threshold_v and
adaptive_threshold have data-dependent kept counts, so their static
wire format (capacity-bounded records) is NOT bit-identical to their
exact-masking `sim` — `exact_sim=False`, and the `simulated`-strategy
wire path refuses them rather than silently changing numerics (their
`allgather` path, which already communicates the capacity-bounded
payload, wires exactly).
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.compressors import (AdaptiveThreshold, Compressor, Identity,
                                    NaturalCompression, QSGD, RandomK,
                                    SignSGD, TernGrad, ThresholdV, TopK,
                                    _k_of, index_bits)
from repro.kernels import ops

Array = jax.Array


def words_for(nbits: int) -> int:
    """uint32 words holding `nbits` packed bits."""
    return -(-nbits // 32)


def word_padding(nbits: int) -> int:
    """Pad-to-word slack of one packed leg: (-nbits) % 32, always < 32."""
    return (-nbits) % 32


# --------------------------------------------------------------------------
# byte-level helpers (bitcasts are exact: float payload legs round-trip
# bit for bit)
# --------------------------------------------------------------------------

def _f32_to_u8(v: Array) -> Array:
    return jax.lax.bitcast_convert_type(v, jnp.uint8).reshape(-1)


def _u8_to_f32(b: Array) -> Array:
    return jax.lax.bitcast_convert_type(b.reshape(-1, 4), jnp.float32)


def _u32_to_u8(w: Array) -> Array:
    return jax.lax.bitcast_convert_type(w, jnp.uint8).reshape(-1)


def _u8_to_u32(b: Array) -> Array:
    return jax.lax.bitcast_convert_type(b.reshape(-1, 4), jnp.uint32)


def _f32_rows_to_u8(v: Array) -> Array:
    """(n, k) f32 -> (n, 4k) uint8, row-wise little-endian bytes."""
    return jax.lax.bitcast_convert_type(v, jnp.uint8).reshape(v.shape[0], -1)


def _u32_rows_to_u8(w: Array) -> Array:
    return jax.lax.bitcast_convert_type(w, jnp.uint8).reshape(w.shape[0], -1)


def _u8_rows_to_f32(b: Array) -> Array:
    """(n, 4k) uint8 -> (n, k) f32."""
    return jax.lax.bitcast_convert_type(
        b.reshape(b.shape[0], -1, 4), jnp.float32)


def _u8_rows_to_u32(b: Array) -> Array:
    return jax.lax.bitcast_convert_type(
        b.reshape(b.shape[0], -1, 4), jnp.uint32)


# --------------------------------------------------------------------------
# wire integrity: in-graph Fletcher-32 over packed bytes
# --------------------------------------------------------------------------
# The per-message checksum lives in the uint32 header (MessageLayout with
# checksum=True). Format: Fletcher-32 over little-endian 16-bit words with
# Adler-style initialization (sum1 starts at 1), so an all-zero buffer —
# e.g. a dropped ring hop — never verifies against a zeroed header word,
# and the length rides in sum2 (truncation-to-zeros is detected). Any
# single bit flip changes its 16-bit word by ±2^k, which is never ≡ 0
# mod 65535, so single-bit corruption in the covered bytes is ALWAYS
# detected (the detection gate bench-faults asserts). Fully vectorized:
# sum2 = 1·L + Σ_i (L−i)·w_i uses weighted products < 2^32 with staged
# mod-65535 chunk reductions instead of the byte-serial reference loop.

_FLETCHER_MOD = 65535
_FLETCHER_CHUNK = 65536  # 65536 addends < 65535 each stay under 2^32


def _mod65535_sum(x: Array) -> Array:
    """Sum of uint32 values each < 65535, mod 65535, without overflow:
    staged chunk sums (each chunk sum < 2^32) reduced mod 65535."""
    while x.size > _FLETCHER_CHUNK:
        pad = (-x.size) % _FLETCHER_CHUNK
        x = jnp.pad(x, (0, pad)).reshape(-1, _FLETCHER_CHUNK)
        x = x.sum(axis=1, dtype=jnp.uint32) % jnp.uint32(_FLETCHER_MOD)
    return x.sum(dtype=jnp.uint32) % jnp.uint32(_FLETCHER_MOD)


def fletcher32(payload_u8: Array) -> Array:
    """In-graph Fletcher-32 (init=1 variant) of a uint8 buffer -> uint32
    scalar. Pure jnp — traced, vmappable, identical on host and device."""
    b = payload_u8.reshape(-1).astype(jnp.uint32)
    if b.size % 2:
        b = jnp.pad(b, (0, 1))
    words = (b[0::2] | (b[1::2] << 8)) % jnp.uint32(_FLETCHER_MOD)
    nw = words.shape[0]
    # with s1_0 = 1, s2_0 = 0 and per word s1 += w, s2 += s1:
    # sum1 = 1 + Σ w_i;  sum2 = Σ_j s1_j = nw + Σ_i (nw - i)·w_i
    coef = jnp.arange(nw, 0, -1, dtype=jnp.uint32) % jnp.uint32(
        _FLETCHER_MOD)
    s1 = (jnp.uint32(1) + _mod65535_sum(words)) % jnp.uint32(_FLETCHER_MOD)
    s2 = (jnp.uint32(nw % _FLETCHER_MOD)
          + _mod65535_sum((coef * words) % jnp.uint32(_FLETCHER_MOD))
          ) % jnp.uint32(_FLETCHER_MOD)
    return (s2 << 16) | s1


# --------------------------------------------------------------------------
# value-record legs: f32, or the bf16 wire cast (wire_dtype="bfloat16")
# --------------------------------------------------------------------------
# The to_f32/to_bf16 idiom: the wire carries bf16 (2 bytes/record, a
# deliberate lossy cast — round-trip is to-bf16-precision, NOT bit-exact),
# compute stays f32. Only the dense and sparse codecs have f32 value
# records to cast; the quantized-code codecs are already sub-16-bit.

def to_f32(t):
    """bf16 leaves -> f32 (everything else untouched)."""
    return jax.tree_util.tree_map(
        lambda x: x.astype(jnp.float32) if x.dtype == jnp.bfloat16 else x, t)


def to_bf16(t):
    """f32 leaves -> bf16 (everything else untouched)."""
    return jax.tree_util.tree_map(
        lambda x: x.astype(jnp.bfloat16) if x.dtype == jnp.float32 else x, t)


def _value_nbytes(k: int, wire_dtype: str) -> int:
    """Bytes of one unit's k-value record leg: raw f32, or bf16 rounded
    up to a whole uint32 word (the same padding rule as packed legs)."""
    return 4 * k if wire_dtype == "float32" else 4 * words_for(16 * k)


def _vals_to_u8(v: Array, wire_dtype: str) -> Array:
    if wire_dtype == "float32":
        return _f32_to_u8(v.reshape(-1).astype(jnp.float32))
    b = jax.lax.bitcast_convert_type(
        to_bf16(v.reshape(-1).astype(jnp.float32)), jnp.uint8).reshape(-1)
    return jnp.pad(b, (0, (-b.size) % 4))


def _u8_to_vals(b: Array, k: int, wire_dtype: str) -> Array:
    if wire_dtype == "float32":
        return _u8_to_f32(b)
    return to_f32(jax.lax.bitcast_convert_type(
        b[:2 * k].reshape(k, 2), jnp.bfloat16))


def _val_rows_to_u8(v: Array, wire_dtype: str) -> Array:
    if wire_dtype == "float32":
        return _f32_rows_to_u8(v.astype(jnp.float32))
    b = jax.lax.bitcast_convert_type(
        to_bf16(v.astype(jnp.float32)), jnp.uint8).reshape(v.shape[0], -1)
    return jnp.pad(b, ((0, 0), (0, (-b.shape[1]) % 4)))


def _u8_rows_to_vals(b: Array, k: int, wire_dtype: str) -> Array:
    if wire_dtype == "float32":
        return _u8_rows_to_f32(b)
    return to_f32(jax.lax.bitcast_convert_type(
        b[:, :2 * k].reshape(b.shape[0], k, 2), jnp.bfloat16))


def _pack_fields(vals: Array, width: int, use_pallas: bool) -> Array:
    """int32 field vector (k,) with values < 2**width -> packed uint8
    bytes (whole uint32 words; LSB-first within each field). Word-wise:
    32-field chunks become `width` uint32 words via compile-time shifts
    (kernels/ref.pack_fields_tile) — the legacy k*width {0,1} int32 bit
    tensor (a 32x memory inflation) never exists. Byte-identical to the
    bit-expansion path (ref.pack_fields_bitexpand_ref pins it)."""
    return _u32_to_u8(ops.pack_fields(vals, width, use_pallas=use_pallas))


def _unpack_fields(payload: Array, k: int, width: int,
                   use_pallas: bool) -> Array:
    """Inverse of _pack_fields -> int32 (k,), word-wise shifts."""
    return ops.unpack_fields(_u8_to_u32(payload), k, width,
                             use_pallas=use_pallas)


# --------------------------------------------------------------------------
# codecs
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class WireCodec:
    """Bit-packed wire format of one compression unit.

    Frozen + a hashable Compressor field => hashable, so a codec is a
    valid static argument under jit and a safe lru_cache key (message
    layouts cache on (schedule, codec)).

    `use_pallas=False` (default) packs with the pure-jnp oracle — safe
    under the vmapped bucket dispatches wire execution runs through;
    `use_pallas=True` routes the word-packing through kernels/pack.py
    (exercised on the non-vmapped entire-model path and in bench-wire).

    `fused=True` (default) routes the BATCH entry points (encode_batch /
    decode_batch / decode_ef_batch — what wire execution dispatches per
    bucket) through the single-launch compress+pack ops of kernels/ops.py:
    a whole bucket's quantize + word-pack is ONE kernel launch, uniforms
    generated in-kernel, the {0,1} bit tensor never materialized — and
    payloads stay BYTE-IDENTICAL to the legacy three-pass per-unit path
    (the differential suite pins it). `fused=False` falls back to
    vmapping the per-unit encode/decode, which remain the reference
    implementations either way.

    `wire_dtype="bfloat16"` casts the f32 VALUE records through the
    to_bf16/to_f32 idiom (2 bytes/record on the wire) — a deliberately
    LOSSY format: exact_sim is False and the simulated-strategy wire
    path refuses it (the real collectives carry it fine). Only the
    dense and sparse codecs have value records to cast; the others
    raise.

    `integrity=True` reserves one extra uint32 header word per fused
    message for a Fletcher-32 checksum (see fletcher32) over everything
    after it (offset table + packed payloads), computed at pack and
    verified at decode on both the serialized and streaming ring paths.
    It changes only the MESSAGE header layout — per-unit payload bytes
    (`nbytes`) and the codec math are untouched, so the decoded numerics
    are bit-identical with integrity on or off.

    `exact_sim`: decode(encode(x, key)) == comp.sim(x, key) bit for bit.
    True for every codec except the capacity-bounded threshold records
    and the bf16 value-cast variants.
    """
    comp: Compressor = Identity()
    use_pallas: bool = False
    fused: bool = True
    wire_dtype: str = "float32"
    integrity: bool = False

    #: codecs whose value-record legs support the bf16 wire cast
    _SUPPORTS_BF16 = False

    def __post_init__(self):
        if self.wire_dtype not in ("float32", "bfloat16"):
            raise ValueError(f"unknown wire_dtype {self.wire_dtype!r}")
        if self.wire_dtype == "bfloat16" and not self._SUPPORTS_BF16:
            raise ValueError(
                f"{type(self).__name__}({self.comp.name}): bfloat16 wire "
                f"casting halves f32 VALUE records — only the dense and "
                f"sparse codecs carry any (quantized-code legs are "
                f"already sub-16-bit)")

    @property
    def exact_sim(self) -> bool:
        """decode(encode(x)) == sim(x) bit for bit — never true for the
        lossy bf16 value cast."""
        return self.wire_dtype == "float32"

    @property
    def name(self) -> str:
        return self.comp.name

    # ---- static layout ---------------------------------------------------
    def nbytes(self, d: int) -> int:
        raise NotImplementedError

    def wire_bits(self, d: int) -> int:
        """8 * nbytes(d): exactly what a measured payload reports."""
        return 8 * self.nbytes(d)

    def payload_bits(self, d: int) -> int:
        """Accounted (pre-padding) bits at this codec's wire dtype: the
        compressor's analytic formula at f32; the bf16-capable codecs
        override to charge 16 bits per value record."""
        return self.comp.payload_bits(d)

    def padding_bits(self, d: int) -> int:
        """Documented word-padding slack: wire_bits - accounted bits."""
        return self.wire_bits(d) - self.payload_bits(d)

    # ---- wire ------------------------------------------------------------
    def encode(self, x: Array, key: Array) -> Array:
        raise NotImplementedError

    def decode(self, payload: Array, d: int) -> Array:
        raise NotImplementedError

    def roundtrip(self, x: Array, key: Array) -> Array:
        return self.decode(self.encode(x, key), x.shape[0])

    # ---- batched wire (one bucket = one dispatch) ------------------------
    # Base implementations mirror the legacy bucket dispatch exactly:
    # n == 1 short-circuits the vmap (the wire-vs-unpacked bit-identity
    # rests on this symmetry). Codecs with fused kernels override these
    # with single-launch kernels/ops.py calls when self.fused.

    def encode_batch(self, x2d: Array, keys: Array) -> Array:
        """(n, d) units + per-unit keys -> (n, nbytes(d)) payload rows."""
        if x2d.shape[0] == 1:
            return self.encode(x2d[0], keys[0])[None]
        return jax.vmap(self.encode)(x2d, keys)

    def decode_batch(self, payloads: Array, d: int) -> Array:
        """(n, nbytes(d)) payload rows -> (n, d) decoded units."""
        if payloads.shape[0] == 1:
            return self.decode(payloads[0], d)[None]
        return jax.vmap(lambda p: self.decode(p, d))(payloads)

    def decode_ef_batch(self, payloads: Array, e2d: Array, d: int):
        """Decode + error-feedback residual: -> (xhat, m = e - xhat).
        The residual subtract runs in the caller's regime on every path
        (kernels/ops.py *_unpack_ef_units explains why it cannot live
        in-kernel), so fused and legacy residuals are bit-identical."""
        xhat = self.decode_batch(payloads, d)
        return xhat, e2d - xhat

    # ---- per-hop streaming (ring collectives) ----------------------------
    # One ring hop delivers one source worker's packed payload rows; the
    # receiver decodes them THE HOP THEY ARRIVE and deposits them into a
    # gathered accumulator. The deposit is a SLOTTED WRITE at the source
    # worker's index, never a running float sum: the executor's final
    # jnp.mean then reduces the same (n_workers, ...) array in the same
    # worker-index order as the allgather path's gathered-decode-mean,
    # which is what makes the streaming ring bit-identical to the
    # allgather wire path (a running sum in ring ARRIVAL order would
    # associate the f32 adds differently on every worker).

    def decode_accumulate(self, payloads: Array, acc: Array, slot,
                          d: int) -> Array:
        """One hop's decode-accumulate: decode (n_units, nbytes(d))
        payload rows from the worker at (traced) index `slot` and write
        them into `acc` (n_workers, n_units, d) at that slot."""
        return acc.at[slot].set(self.decode_batch(payloads, d))

    def decode_accumulate_ef(self, payloads: Array, e2d: Array, acc: Array,
                             slot, d: int):
        """Hop-0 (own payload) decode-accumulate under error feedback:
        also returns the residual m = e - xhat via decode_ef_batch, so
        the EF discipline stays the local encode-leg one — identical to
        the allgather wire path's (EF never depends on the collective
        topology)."""
        xhat, m = self.decode_ef_batch(payloads, e2d, d)
        return acc.at[slot].set(xhat), m


@dataclasses.dataclass(frozen=True)
class DenseCodec(WireCodec):
    """Passthrough: raw f32 bytes (identity / dense reference), or the
    bf16 wire cast at wire_dtype="bfloat16" (16 bits/entry, lossy)."""

    _SUPPORTS_BF16 = True

    def nbytes(self, d: int) -> int:
        return _value_nbytes(d, self.wire_dtype)

    def payload_bits(self, d: int) -> int:
        if self.wire_dtype == "float32":
            return self.comp.payload_bits(d)
        return 16 * d

    def encode(self, x: Array, key: Array) -> Array:
        return _vals_to_u8(x, self.wire_dtype)

    def decode(self, payload: Array, d: int) -> Array:
        return _u8_to_vals(payload, d, self.wire_dtype)

    def encode_batch(self, x2d: Array, keys: Array) -> Array:
        if not self.fused:
            return super().encode_batch(x2d, keys)
        return _val_rows_to_u8(x2d, self.wire_dtype)

    def decode_batch(self, payloads: Array, d: int) -> Array:
        if not self.fused:
            return super().decode_batch(payloads, d)
        return _u8_rows_to_vals(payloads, d, self.wire_dtype)


@dataclasses.dataclass(frozen=True)
class QSGDCodec(WireCodec):
    """f32 unit norm + b-bit offset-binary levels (code = level + s)."""
    comp: Compressor = QSGD()

    @property
    def entry_bits(self) -> int:
        return self.comp.entry_bits  # the accounting's own formula

    def nbytes(self, d: int) -> int:
        return 4 + 4 * words_for(self.entry_bits * d)

    def encode(self, x: Array, key: Array) -> Array:
        q, nrm = self.comp._quantize(x.reshape(-1).astype(jnp.float32), key)
        codes = q.astype(jnp.int32) + self.comp.levels
        return jnp.concatenate([
            _f32_to_u8(nrm[None]),
            _pack_fields(codes, self.entry_bits, self.use_pallas)])

    def decode(self, payload: Array, d: int) -> Array:
        nrm = _u8_to_f32(payload[:4])[0]
        codes = _unpack_fields(payload[4:], d, self.entry_bits,
                               self.use_pallas)
        q = codes - self.comp.levels
        return q.astype(jnp.float32) * (nrm / self.comp.levels)

    def _split(self, payloads: Array):
        """Payload rows -> ((n,) f32 norms, (n, words) uint32)."""
        return (_u8_rows_to_f32(payloads[:, :4])[:, 0],
                _u8_rows_to_u32(payloads[:, 4:]))

    def encode_batch(self, x2d: Array, keys: Array) -> Array:
        if not self.fused:
            return super().encode_batch(x2d, keys)
        w, nrm = ops.qsgd_pack_units(x2d, keys, self.comp.levels,
                                     self.entry_bits,
                                     use_pallas=self.use_pallas)
        return jnp.concatenate(
            [_f32_rows_to_u8(nrm[:, None]), _u32_rows_to_u8(w)], axis=1)

    def decode_batch(self, payloads: Array, d: int) -> Array:
        if not self.fused:
            return super().decode_batch(payloads, d)
        nrm, w = self._split(payloads)
        return ops.qsgd_unpack_units(w, nrm, d, self.comp.levels,
                                     self.entry_bits,
                                     use_pallas=self.use_pallas)

    def decode_ef_batch(self, payloads: Array, e2d: Array, d: int):
        if not self.fused:
            return super().decode_ef_batch(payloads, e2d, d)
        nrm, w = self._split(payloads)
        return ops.qsgd_unpack_ef_units(w, nrm, e2d, d, self.comp.levels,
                                        self.entry_bits,
                                        use_pallas=self.use_pallas)


@dataclasses.dataclass(frozen=True)
class TernGradCodec(WireCodec):
    """f32 unit scale + 2-bit ternary codes (t + 1 in {0, 1, 2})."""
    comp: Compressor = TernGrad()

    def nbytes(self, d: int) -> int:
        return 4 + 4 * words_for(2 * d)

    def encode(self, x: Array, key: Array) -> Array:
        t, s = self.comp._quantize(x.reshape(-1).astype(jnp.float32), key)
        codes = t.astype(jnp.int32) + 1
        return jnp.concatenate([
            _f32_to_u8(s[None]), _pack_fields(codes, 2, self.use_pallas)])

    def decode(self, payload: Array, d: int) -> Array:
        s = _u8_to_f32(payload[:4])[0]
        t = _unpack_fields(payload[4:], d, 2, self.use_pallas) - 1
        return t.astype(jnp.float32) * s

    def _split(self, payloads: Array):
        return (_u8_rows_to_f32(payloads[:, :4])[:, 0],
                _u8_rows_to_u32(payloads[:, 4:]))

    def encode_batch(self, x2d: Array, keys: Array) -> Array:
        if not self.fused:
            return super().encode_batch(x2d, keys)
        w, s = ops.terngrad_pack_units(x2d, keys,
                                       use_pallas=self.use_pallas)
        return jnp.concatenate(
            [_f32_rows_to_u8(s[:, None]), _u32_rows_to_u8(w)], axis=1)

    def decode_batch(self, payloads: Array, d: int) -> Array:
        if not self.fused:
            return super().decode_batch(payloads, d)
        s, w = self._split(payloads)
        return ops.terngrad_unpack_units(w, s, d,
                                         use_pallas=self.use_pallas)

    def decode_ef_batch(self, payloads: Array, e2d: Array, d: int):
        if not self.fused:
            return super().decode_ef_batch(payloads, e2d, d)
        s, w = self._split(payloads)
        return ops.terngrad_unpack_ef_units(w, s, e2d, d,
                                            use_pallas=self.use_pallas)


@dataclasses.dataclass(frozen=True)
class SignSGDCodec(WireCodec):
    """1 bit per entry (x >= 0). `majority_vote` aggregates n workers'
    payloads on the packed words — the real signSGD-with-majority-vote
    wire protocol (Bernstein et al.): only packed signs ever travel."""
    comp: Compressor = SignSGD()

    def nbytes(self, d: int) -> int:
        return 4 * words_for(d)

    def encode(self, x: Array, key: Array) -> Array:
        bits = (x.reshape(-1) >= 0).astype(jnp.int32)
        return _u32_to_u8(ops.pack_words(bits, use_pallas=self.use_pallas))

    def decode(self, payload: Array, d: int) -> Array:
        bits = ops.unpack_words(_u8_to_u32(payload), d,
                                use_pallas=self.use_pallas)
        return (2 * bits - 1).astype(jnp.float32)

    def encode_batch(self, x2d: Array, keys: Array) -> Array:
        if not self.fused:
            return super().encode_batch(x2d, keys)
        return _u32_rows_to_u8(
            ops.sign_pack_units(x2d, use_pallas=self.use_pallas))

    def decode_batch(self, payloads: Array, d: int) -> Array:
        if not self.fused:
            return super().decode_batch(payloads, d)
        return ops.sign_unpack_units(_u8_rows_to_u32(payloads), d,
                                     use_pallas=self.use_pallas)

    def decode_ef_batch(self, payloads: Array, e2d: Array, d: int):
        if not self.fused:
            return super().decode_ef_batch(payloads, e2d, d)
        return ops.sign_unpack_ef_units(_u8_rows_to_u32(payloads), e2d, d,
                                        use_pallas=self.use_pallas)

    def majority_vote(self, payloads: Array, d: int) -> Array:
        """(n_workers, nbytes) packed payloads -> one packed payload whose
        bit i is the majority sign of entry i (ties -> +1, matching the
        x >= 0 convention). Never materializes dense worker vectors.
        Fused: bit-sliced ripple-carry counting DIRECTLY on the packed
        words (ops.majority_words) — even the per-bit counts stay packed;
        zero word-padding bits vote 0 on both paths."""
        n = payloads.shape[0]
        if self.fused:
            maj = ops.majority_words(_u8_rows_to_u32(payloads),
                                     use_pallas=self.use_pallas)
            return _u32_to_u8(maj)
        bits = jax.vmap(lambda p: ops.unpack_words(
            _u8_to_u32(p), d, use_pallas=False))(payloads)
        maj = (2 * bits.sum(axis=0) >= n).astype(jnp.int32)
        return _u32_to_u8(ops.pack_words(maj, use_pallas=self.use_pallas))


@dataclasses.dataclass(frozen=True)
class NaturalCodec(WireCodec):
    """9-bit codes: sign * (exponent + 128), offset by 255 into [0, 510]
    (0 encodes exact zero)."""
    comp: Compressor = NaturalCompression()

    def nbytes(self, d: int) -> int:
        return 4 * words_for(9 * d)

    def encode(self, x: Array, key: Array) -> Array:
        xf = x.reshape(-1).astype(jnp.float32)
        e, sgn, zero = self.comp._exponents(xf, key)
        bias = self.comp._BIAS + 1  # the compressor's own code offset
        code = jnp.where(zero, 0, sgn.astype(jnp.int32) * (e + bias))
        return _pack_fields(code + 255, 9, self.use_pallas)

    def decode(self, payload: Array, d: int) -> Array:
        code = _unpack_fields(payload, d, 9, self.use_pallas) - 255
        return self._dequant(code)

    def _dequant(self, code: Array) -> Array:
        """Elementwise code -> value (shape-polymorphic: same arithmetic
        per unit or per bucket row)."""
        sgn = jnp.sign(code).astype(jnp.float32)
        e = jnp.abs(code) - (self.comp._BIAS + 1)
        val = sgn * jnp.exp2(e.astype(jnp.float32))
        return jnp.where(code == 0, 0.0, val)

    def encode_batch(self, x2d: Array, keys: Array) -> Array:
        if not self.fused:
            return super().encode_batch(x2d, keys)

        def codes_of(row, k):
            e, sgn, zero = self.comp._exponents(
                row.astype(jnp.float32), k)
            bias = self.comp._BIAS + 1
            return jnp.where(zero, 0,
                             sgn.astype(jnp.int32) * (e + bias)) + 255
        if x2d.shape[0] == 1:
            codes = codes_of(x2d[0], keys[0])[None]
        else:
            codes = jax.vmap(codes_of)(x2d, keys)
        return _u32_rows_to_u8(
            ops.fields_pack_units(codes, 9, use_pallas=self.use_pallas))

    def decode_batch(self, payloads: Array, d: int) -> Array:
        if not self.fused:
            return super().decode_batch(payloads, d)
        codes = ops.fields_unpack_units(_u8_rows_to_u32(payloads), d, 9,
                                        use_pallas=self.use_pallas)
        return self._dequant(codes - 255)


@dataclasses.dataclass(frozen=True)
class SparseCodec(WireCodec):
    """k records of (f32 value, ceil(log2(d))-bit index): topk / randomk
    (exact_sim) and the capacity-bounded threshold methods (not). Values
    travel first (4k bytes — or 2k word-padded at wire_dtype="bfloat16"),
    then the packed index leg. Resolves PerDimRatio wrappers per dim, so
    adaptive per-bucket ratios wire with the active k."""
    comp: Compressor = TopK()
    sim_exact: bool = True

    _SUPPORTS_BF16 = True

    @property
    def exact_sim(self) -> bool:  # type: ignore[override]
        return self.sim_exact and self.wire_dtype == "float32"

    def _c(self, d: int) -> Compressor:
        return (self.comp.for_dim(d) if hasattr(self.comp, "for_dim")
                else self.comp)

    def _k(self, d: int) -> int:
        c = self._c(d)
        r = c.ratio if hasattr(c, "ratio") else c.cap_ratio
        return _k_of(r, d)

    def _vb(self, d: int) -> int:
        """Byte size of the value leg at this wire dtype."""
        return _value_nbytes(self._k(d), self.wire_dtype)

    def nbytes(self, d: int) -> int:
        return self._vb(d) + 4 * words_for(self._k(d) * index_bits(d))

    def payload_bits(self, d: int) -> int:
        if self.wire_dtype == "float32":
            return self._c(d).payload_bits(d)
        return self._k(d) * (16 + index_bits(d))

    def encode(self, x: Array, key: Array) -> Array:
        d = x.shape[0]
        payload = self._c(d).encode(x, key)
        return jnp.concatenate([
            _vals_to_u8(payload["val"], self.wire_dtype),
            _pack_fields(payload["idx"].astype(jnp.int32), index_bits(d),
                         self.use_pallas)])

    def decode(self, payload: Array, d: int) -> Array:
        k = self._k(d)
        val = _u8_to_vals(payload[:self._vb(d)], k, self.wire_dtype)
        idx = _unpack_fields(payload[self._vb(d):], k, index_bits(d),
                             self.use_pallas)
        return jnp.zeros((d,), jnp.float32).at[idx].set(val)

    def encode_batch(self, x2d: Array, keys: Array) -> Array:
        if not self.fused:
            return super().encode_batch(x2d, keys)
        d = x2d.shape[1]
        c = self._c(d)

        def records_of(row, k):
            p = c.encode(row.reshape(-1).astype(jnp.float32), k)
            return (p["val"].astype(jnp.float32),
                    p["idx"].astype(jnp.int32))
        if x2d.shape[0] == 1:
            val, idx = records_of(x2d[0], keys[0])
            val, idx = val[None], idx[None]
        else:
            val, idx = jax.vmap(records_of)(x2d, keys)
        words = ops.fields_pack_units(idx, index_bits(d),
                                      use_pallas=self.use_pallas)
        return jnp.concatenate(
            [_val_rows_to_u8(val, self.wire_dtype),
             _u32_rows_to_u8(words)], axis=1)

    def decode_batch(self, payloads: Array, d: int) -> Array:
        if not self.fused:
            return super().decode_batch(payloads, d)
        k = self._k(d)
        vb = self._vb(d)
        val = _u8_rows_to_vals(payloads[:, :vb], k, self.wire_dtype)
        idx = ops.fields_unpack_units(_u8_rows_to_u32(payloads[:, vb:]),
                                      k, index_bits(d),
                                      use_pallas=self.use_pallas)
        scatter = lambda v, i: jnp.zeros((d,), jnp.float32).at[i].set(v)
        if payloads.shape[0] == 1:
            return scatter(val[0], idx[0])[None]
        return jax.vmap(scatter)(val, idx)


# --------------------------------------------------------------------------
# registry
# --------------------------------------------------------------------------

def wire_codec(comp: Compressor, use_pallas: bool = False,
               fused: bool = True,
               wire_dtype: str = "float32",
               integrity: bool = False) -> WireCodec:
    """The WireCodec materializing `comp`'s payloads. Raises ValueError
    for compressors with no static wire realization. `fused=True`
    (default) routes the batch dispatches through the single-launch
    compress+pack kernels; `fused=False` vmaps the per-unit reference.
    `wire_dtype="bfloat16"` casts f32 value records to bf16 on the wire
    (dense/sparse codecs only — the quantized codecs raise).
    `integrity=True` adds the Fletcher-32 header word per fused message
    (4 bytes/message; payloads and numerics unchanged)."""
    kw = dict(use_pallas=use_pallas, fused=fused, wire_dtype=wire_dtype,
              integrity=integrity)
    base = comp.base if hasattr(comp, "base") else comp  # PerDimRatio
    if isinstance(base, (TopK, RandomK)):
        return SparseCodec(comp=comp, **kw)
    if isinstance(base, (ThresholdV, AdaptiveThreshold)):
        return SparseCodec(comp=comp, sim_exact=False, **kw)
    if isinstance(comp, QSGD):
        return QSGDCodec(comp=comp, **kw)
    if isinstance(comp, TernGrad):
        return TernGradCodec(comp=comp, **kw)
    if isinstance(comp, SignSGD):
        return SignSGDCodec(comp=comp, **kw)
    if isinstance(comp, NaturalCompression):
        return NaturalCodec(comp=comp, **kw)
    if isinstance(comp, Identity) or comp.name in ("identity", "dense"):
        return DenseCodec(comp=comp, **kw)
    raise ValueError(f"no wire codec for compressor {comp.name!r}")


def has_wire_codec(comp: Compressor) -> bool:
    try:
        wire_codec(comp)
        return True
    except ValueError:
        return False


# --------------------------------------------------------------------------
# fused message buffers
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MessageLayout:
    """Static byte layout of one fused wire message.

    Buffer = header ++ per-bucket payload regions. The header is a uint32
    table [n_buckets, byte_offset_0, ..., byte_offset_{B-1}] (absolute
    offsets of each bucket's region), so a receiver can locate every
    bucket from the buffer alone. `unit_nbytes[j]` is the per-unit
    payload size of bucket j; its region holds n_units back-to-back
    records.

    With `checksum=True` (codec.integrity) the header is
    [n_buckets, fletcher32, byte_offset_0, ...]: one extra uint32 word
    holding the Fletcher-32 of every byte AFTER it (offset table +
    payloads — see `checksum_span_start`), so a receiver can verify the
    whole message before decoding. Offsets stay absolute, so region
    slicing is layout-agnostic.
    """
    bucket_ids: Tuple[int, ...]
    offsets: Tuple[int, ...]
    unit_nbytes: Tuple[int, ...]
    header_nbytes: int
    total_nbytes: int
    checksum: bool = False

    #: byte offset where the checksummed span begins (after the
    #: [n_buckets, fletcher32] words)
    checksum_span_start = 8

    @property
    def payload_nbytes(self) -> int:
        return self.total_nbytes - self.header_nbytes


@functools.lru_cache(maxsize=256)
def message_layouts(schedule, codec: WireCodec) -> Tuple[MessageLayout, ...]:
    """Static layouts of every fused message of (schedule, codec)."""
    plan = schedule.plan
    outs = []
    for msg in schedule.messages:
        header = 4 * (1 + int(codec.integrity) + len(msg.bucket_ids))
        off = header
        offs, unb = [], []
        for bi in msg.bucket_ids:
            b = plan.buckets[bi]
            nb = codec.nbytes(b.dim)
            offs.append(off)
            unb.append(nb)
            off += b.n * nb
        outs.append(MessageLayout(msg.bucket_ids, tuple(offs), tuple(unb),
                                  header, off, checksum=codec.integrity))
    return tuple(outs)


def _dispatch_encode(codec, b, x, keys, wire_key):
    """One batched encode per bucket, via the codec's batch entry point
    (fused: a single compress+pack kernel launch; legacy: the vmapped
    per-unit reference with the n==1 short-circuit — the wire-vs-unpacked
    bit-identity rests on that symmetry). The wire_key transform mirrors
    the legacy placement: unvmapped for n == 1, vmapped otherwise."""
    kb = keys[jnp.asarray(b.unit_ids, jnp.int32)]
    if wire_key is not None:
        kb = (wire_key(kb[0])[None] if b.n == 1
              else jax.vmap(wire_key)(kb))
    return codec.encode_batch(x, kb)


def _dispatch_decode(codec, b, payload):
    return codec.decode_batch(payload, b.dim)


def _dispatch_post(fn, b, payload, xhat, keys):
    kb = keys[jnp.asarray(b.unit_ids, jnp.int32)]
    if b.n == 1:
        return fn(payload[0], xhat[0], kb[0])[None]
    return jax.vmap(fn)(payload, xhat, kb)


def _message_buffer(layout: MessageLayout, payload_mats) -> Array:
    if not layout.checksum:
        header = jnp.asarray((len(layout.bucket_ids),) + layout.offsets,
                             jnp.uint32)
        return jnp.concatenate([_u32_to_u8(header)]
                               + [p.reshape(-1) for p in payload_mats])
    # integrity layout: [n_buckets, fletcher32 | offsets ++ payloads],
    # the checksum covering everything after its own word
    tail = jnp.concatenate(
        [_u32_to_u8(jnp.asarray(layout.offsets, jnp.uint32))]
        + [p.reshape(-1) for p in payload_mats])
    head = jnp.stack([jnp.uint32(len(layout.bucket_ids)),
                      fletcher32(tail)])
    return jnp.concatenate([_u32_to_u8(head), tail])


def verify_message(buf: Array, layout: MessageLayout) -> Array:
    """In-graph integrity check of one fused message buffer -> bool
    scalar: recompute Fletcher-32 over the covered span and compare to
    the stored header word. Requires layout.checksum."""
    if not layout.checksum:
        raise ValueError("verify_message needs a checksum layout "
                         "(codec.integrity=True)")
    stored = _u8_to_u32(buf[4:8])[0]
    return stored == fletcher32(buf[layout.checksum_span_start:])


def parse_message_header(buf, *, checksum: bool = False):
    """Host-side hardened header parse of one fused message buffer.

    Returns (n_buckets, offsets) after bounds-checking every field a
    receiver would slice with — a malformed header raises ValueError
    instead of decoding garbage: the buffer must hold a whole header,
    the bucket count must be positive and fit, the first offset must
    land exactly past the header, and offsets must be non-decreasing
    and within the buffer. `checksum=True` parses the integrity layout
    ([n_buckets, fletcher32, offsets...]); the checksum VALUE is the
    in-graph verify_message's job — this validates structure only.
    """
    import numpy as np
    b = np.asarray(buf, dtype=np.uint8).reshape(-1)
    total = b.size
    if total < 4 or total % 4:
        raise ValueError(
            f"message buffer must be a whole number of uint32 words and "
            f"hold at least the bucket count; got {total} bytes")
    words = b.view("<u4")
    n_buckets = int(words[0])
    lead = 1 + int(bool(checksum))
    header = 4 * (lead + n_buckets)
    if n_buckets < 1 or header > total:
        raise ValueError(
            f"malformed header: n_buckets={n_buckets} needs "
            f"{header} header bytes but the buffer has {total}")
    offsets = tuple(int(o) for o in words[lead:lead + n_buckets])
    if offsets[0] != header:
        raise ValueError(
            f"malformed header: first bucket offset {offsets[0]} != "
            f"header end {header}")
    prev = offsets[0]
    for j, off in enumerate(offsets[1:], start=1):
        if off < prev:
            raise ValueError(
                f"malformed header: offset[{j}]={off} < "
                f"offset[{j - 1}]={prev} (must be non-decreasing)")
        prev = off
    if prev > total:
        raise ValueError(
            f"malformed header: offset[{n_buckets - 1}]={prev} beyond "
            f"buffer end {total}")
    return n_buckets, offsets


def _bucket_region(buf: Array, layout: MessageLayout, j: int,
                   n: int) -> Array:
    off, nb = layout.offsets[j], layout.unit_nbytes[j]
    return buf[off:off + n * nb].reshape(n, nb)


def _active_recorder(recorder):
    """The duck-typed zero-overhead guard (see obs.trace.active): the
    recorder when enabled, else None → the uninstrumented graph."""
    if recorder is not None and getattr(recorder, "enabled", False):
        return recorder
    return None


def _receive_buffer(buf, layout, faults, key, tag):
    """The receive leg of one fused message under fault injection:
    corrupt the arrived bytes (payload span only — the injector draws
    from its own seeded stream), verify the Fletcher-32 header word,
    optionally model re-encode-and-resend (the sender still holds the
    clean buffer, so a verified-failed message is replaced by it), and
    note the verdict on the injector. `faults=None` (or a pass-through
    injector) returns `buf` unchanged — the traced graph is byte-
    identical to the fault-free path."""
    rbuf = faults.corrupt(buf, key, tag=tag,
                          start=layout.header_nbytes)
    if rbuf is buf:
        return buf
    if layout.checksum:
        ok = verify_message(rbuf, layout)
        if getattr(faults, "resend", False):
            rbuf = jnp.where(ok, rbuf, buf)
        faults.note(tag, ok)
    return rbuf


def execute_schedule_wire(schedule, codec: WireCodec,
                          fn: Optional[Callable], grads, key: Array,
                          wire_key: Optional[Callable] = None,
                          recorder=None, faults=None):
    """Stream a CommSchedule through REAL wire buffers.

    Per message: encode every member bucket's units (per-unit plan keys,
    optionally transformed by `wire_key` — e.g. the worker-key fold),
    concatenate the packed payloads into one uint8 buffer behind the
    header table, then decode each bucket back OUT OF the buffer and
    apply `fn(payload_row, xhat_row, unit_key) -> y_row` (None = return
    the decoded gradient). Messages are barrier-ordered on the previous
    message's BUFFER, so the streaming contract is pinned on the actual
    wire bytes. Returns (tree, buffers) — `8 * buf.size` summed over
    `buffers` is the measured wire truth (headers included; per-payload
    split via message_layouts).

    `recorder` (duck-typed, obs.trace.TraceRecorder) emits per-message
    compress/pack/decode (+ collective when `fn` is given) stage spans;
    None or a disabled recorder leaves the traced graph untouched.

    `faults` (duck-typed, resil.FaultInjector) corrupts each message's
    RECEIVED bytes after pack (see _receive_buffer); the returned
    `buffers` and the streaming token keep the clean sender-side copy.
    None leaves the traced graph untouched.
    """
    from repro.core.schedule import _order_after
    rec = _active_recorder(recorder)
    plan = schedule.plan
    leaves = jax.tree_util.tree_leaves(grads)
    flat = plan.flatten(grads) if plan.needs_flat else None
    keys = plan.unit_keys(key)
    out_leaves = [None] * len(leaves)
    out_flat = (jnp.zeros((plan.exec_total,), jnp.float32)
                if flat is not None else None)
    layouts = message_layouts(schedule, codec)
    buffers = []
    if rec is not None and leaves:
        rec.begin(leaves[0], label="grads_ready")
    token = None
    for mi, (msg, layout) in enumerate(zip(schedule.messages, layouts)):
        attrs = (dict(message=mi, bucket_ids=msg.bucket_ids,
                      dims=tuple(plan.buckets[bi].dim
                                 for bi in msg.bucket_ids),
                      n_units=sum(plan.buckets[bi].n
                                  for bi in msg.bucket_ids),
                      codec=codec.name) if rec is not None else None)

        def _scope(stage):
            return (rec.scope(f"repro/msg{mi}/{stage}")
                    if rec is not None else contextlib.nullcontext())
        xs = [plan._gather_runs(leaves, flat, plan.buckets[bi])
              for bi in msg.bucket_ids]
        xs = _order_after(xs, token)
        with _scope("compress"):
            mats = [_dispatch_encode(codec, plan.buckets[bi], x, keys,
                                     wire_key)
                    for bi, x in zip(msg.bucket_ids, xs)]
        if rec is not None:
            rec.mark(mats, "compress", **attrs)
        with _scope("pack"):
            buf = _message_buffer(layout, mats)
        if rec is not None:
            rec.mark(buf, "pack", **attrs)
        buffers.append(buf)
        token = buf
        rbuf = (buf if faults is None
                else _receive_buffer(buf, layout, faults, key, mi))
        pays, xhats = [], []
        with _scope("decode"):
            for j, bi in enumerate(msg.bucket_ids):
                b = plan.buckets[bi]
                pay = _bucket_region(rbuf, layout, j, b.n)
                pays.append(pay)
                xhats.append(_dispatch_decode(codec, b, pay))
        if rec is not None:
            rec.mark(xhats, "decode", **attrs)
        if fn is None:
            ys = xhats
        else:
            with _scope("collective"):
                ys = [_dispatch_post(fn, plan.buckets[bi], pay, xhat,
                                     keys)
                      for bi, pay, xhat in zip(msg.bucket_ids, pays,
                                               xhats)]
            if rec is not None:
                rec.mark(ys, "collective", **attrs)
        for bi, y in zip(msg.bucket_ids, ys):
            out_flat = plan._scatter_runs(out_leaves, out_flat,
                                          plan.buckets[bi], y)
    return plan._assemble(out_leaves, out_flat), tuple(buffers)


def execute_schedule_wire_with_state(schedule, codec: WireCodec,
                                     fn: Optional[Callable], grads, state,
                                     key: Array,
                                     wire_key: Optional[Callable] = None,
                                     recorder=None, faults=None):
    """Error-feedback twin of execute_schedule_wire: per unit,
    e = x + m is encoded, the residual m' = e - decode(payload) (exactly
    the unpacked EF discipline since the round-trip is bit-exact), and
    y = fn(payload, e_hat, key). Decode and residual thread through
    codec.decode_ef_batch — with a fused codec that is ONE unpack kernel
    launch per bucket plus the caller-regime residual subtract. Returns
    (tree, m_tree, buffers). `recorder` instruments the stream exactly
    as in execute_schedule_wire, plus an `ef_update` span per message.

    `faults` corrupts the RECEIVED bytes only (see _receive_buffer) —
    the EF residual is SENDER-side state and is always computed from the
    clean buffer (the sender knows exactly what it encoded), so wire
    corruption can poison one step's decoded gradient but never the
    error-feedback discipline."""
    from repro.core.schedule import _order_after
    rec = _active_recorder(recorder)
    plan = schedule.plan
    leaves = jax.tree_util.tree_leaves(grads)
    sleaves = jax.tree_util.tree_leaves(state)
    need = plan.needs_flat
    flat = plan.flatten(grads) if need else None
    mflat = plan.flatten(state) if need else None
    keys = plan.unit_keys(key)
    out_leaves = [None] * len(leaves)
    mout_leaves = [None] * len(leaves)
    out_flat = (jnp.zeros((plan.exec_total,), jnp.float32) if need else None)
    mout_flat = (jnp.zeros((plan.exec_total,), jnp.float32) if need
                 else None)
    layouts = message_layouts(schedule, codec)
    buffers = []
    if rec is not None and leaves:
        rec.begin(leaves[0], label="grads_ready")
    token = None
    for mi, (msg, layout) in enumerate(zip(schedule.messages, layouts)):
        attrs = (dict(message=mi, bucket_ids=msg.bucket_ids,
                      dims=tuple(plan.buckets[bi].dim
                                 for bi in msg.bucket_ids),
                      n_units=sum(plan.buckets[bi].n
                                  for bi in msg.bucket_ids),
                      codec=codec.name) if rec is not None else None)

        def _scope(stage):
            return (rec.scope(f"repro/msg{mi}/{stage}")
                    if rec is not None else contextlib.nullcontext())
        pairs = []
        for bi in msg.bucket_ids:
            b = plan.buckets[bi]
            pairs.append(plan._gather_runs(leaves, flat, b))
            pairs.append(plan._gather_runs(sleaves, mflat, b))
        pairs = _order_after(pairs, token)
        es = [pairs[2 * j] + pairs[2 * j + 1]
              for j in range(len(msg.bucket_ids))]
        with _scope("compress"):
            mats = [_dispatch_encode(codec, plan.buckets[bi], e, keys,
                                     wire_key)
                    for bi, e in zip(msg.bucket_ids, es)]
        if rec is not None:
            rec.mark(mats, "compress", **attrs)
        with _scope("pack"):
            buf = _message_buffer(layout, mats)
        if rec is not None:
            rec.mark(buf, "pack", **attrs)
        buffers.append(buf)
        token = buf
        rbuf = (buf if faults is None
                else _receive_buffer(buf, layout, faults, key, mi))
        pays, ehats, mns = [], [], []
        with _scope("decode"):
            for j, bi in enumerate(msg.bucket_ids):
                b = plan.buckets[bi]
                pay = _bucket_region(buf, layout, j, b.n)
                if rbuf is buf:
                    ehat, mn = codec.decode_ef_batch(pay, es[j], b.dim)
                else:
                    # residual from the CLEAN sender-side payload; the
                    # receiver's view decodes the (possibly corrupt,
                    # possibly resent) wire bytes
                    _, mn = codec.decode_ef_batch(pay, es[j], b.dim)
                    pay = _bucket_region(rbuf, layout, j, b.n)
                    ehat = codec.decode_batch(pay, b.dim)
                pays.append(pay)
                ehats.append(ehat)
                mns.append(mn)
        if rec is not None:
            rec.mark(ehats, "decode", **attrs)
            rec.mark(mns, "ef_update", **attrs)
        if fn is None:
            ys = ehats
        else:
            with _scope("collective"):
                ys = [_dispatch_post(fn, plan.buckets[bi], pay, ehat,
                                     keys)
                      for bi, pay, ehat in zip(msg.bucket_ids, pays,
                                               ehats)]
            if rec is not None:
                rec.mark(ys, "collective", **attrs)
        for bi, y, mn in zip(msg.bucket_ids, ys, mns):
            b = plan.buckets[bi]
            out_flat = plan._scatter_runs(out_leaves, out_flat, b, y)
            mout_flat = plan._scatter_runs(mout_leaves, mout_flat, b, mn)
    return (plan._assemble(out_leaves, out_flat),
            plan._assemble(mout_leaves, mout_flat), tuple(buffers))


# --------------------------------------------------------------------------
# streaming collectives: chunked-ppermute ring under shard_map
# --------------------------------------------------------------------------

def _shard_dim(d: int, n_workers: int) -> int:
    """Owned-shard length of a d-entry unit on n workers (ceil; the last
    worker's shard is short when n does not divide d — the TRUE per-worker
    sizes are min(ds, d - w*ds), which is what bits.comm_report charges)."""
    return -(-d // n_workers)


@functools.lru_cache(maxsize=256)
def shard_message_layouts(schedule, codec: WireCodec,
                          n_workers: int) -> Tuple[MessageLayout, ...]:
    """message_layouts for the rs-stream path: each bucket's unit payload
    is sized on the OWNED SHARD (ceil(d/n) entries), because under
    compress→reduce-scatter→allgather each worker encodes only the shard
    it owns — the FSDP on-demand pattern."""
    plan = schedule.plan
    outs = []
    for msg in schedule.messages:
        header = 4 * (1 + int(codec.integrity) + len(msg.bucket_ids))
        off = header
        offs, unb = [], []
        for bi in msg.bucket_ids:
            b = plan.buckets[bi]
            nb = codec.nbytes(_shard_dim(b.dim, n_workers))
            offs.append(off)
            unb.append(nb)
            off += b.n * nb
        outs.append(MessageLayout(msg.bucket_ids, tuple(offs), tuple(unb),
                                  header, off, checksum=codec.integrity))
    return tuple(outs)


@functools.lru_cache(maxsize=1024)
def layout_chunks(layout: MessageLayout,
                  chunk_bytes: Optional[float]) -> Tuple[Tuple, ...]:
    """Static chunk table of one message buffer: tuples of
    (bucket_positions, byte_start, byte_stop). Chunks are what the ring
    ppermutes — runs of whole bucket regions grouped under `chunk_bytes`
    (ops.chunk_runs), so every chunk decodes with whole-bucket unpack
    dispatches the hop it arrives. Chunk 0 absorbs the header bytes
    (they ride along; receivers use the static layout, the header exists
    for the buffer to be self-describing on a real wire)."""
    sizes = [n_bytes_of for n_bytes_of in (
        (layout.offsets[j + 1] if j + 1 < len(layout.offsets)
         else layout.total_nbytes) - layout.offsets[j]
        for j in range(len(layout.bucket_ids)))]
    runs = ops.chunk_runs(sizes, chunk_bytes)
    chunks = []
    for run in runs:
        start = (0 if run[0] == 0 else layout.offsets[run[0]])
        stop = (layout.offsets[run[-1] + 1]
                if run[-1] + 1 < len(layout.offsets)
                else layout.total_nbytes)
        chunks.append((run, start, stop))
    return tuple(chunks)


def execute_schedule_stream(schedule, codec: WireCodec,
                            post: Optional[Callable], grads, state,
                            key: Array, *, axis_names, n_workers: int,
                            mode: str = "ring",
                            wire_key: Optional[Callable] = None,
                            chunk_bytes: Optional[float] = None,
                            recorder=None, faults=None):
    """Stream a CommSchedule through a chunked-ppermute ring collective.

    The real-overlap twin of execute_schedule_wire: per fused message the
    packed uint8 buffer is moved hop-by-hop around the DP ring (n-1
    `ppermute` steps of `chunk_bytes`-granular slices) instead of one
    blocking all_gather, and each arriving chunk is decoded THAT HOP into
    a slotted gathered accumulator (WireCodec.decode_accumulate — see its
    docstring for why slotting, not summing, is what preserves
    bit-identity with the allgather path). The loop is DOUBLE-BUFFERED:
    message i+1's fused compress+pack kernels are emitted before message
    i's hops, with

      * a compute-stream barrier (message i's buffer → message i+1's
        gathers), the same streaming contract as the serialized path, and
      * a collective-stream barrier (message i-1's last hop → message
        i's first hop) modelling one network channel,

    so in program order compress(i+1) interleaves before collective(i)
    completes — the overlap `simulate_schedule` models and the jaxpr
    test in tests/test_stream.py proves.

    mode="ring": every worker's full-unit payload circulates; the reduce
    is mean-over-workers + `post` per unit — bit-identical to the
    allgather wire path for every codec (same payloads, same
    decode-then-mean in the same worker order).

    mode="rs": compress→reduce-scatter→allgather — each bucket's dense
    units are psum_scatter'd (padded to n·ceil(d/n), tiled over the unit
    axis), each worker encodes ONLY the shard it owns (padding masked to
    exact zeros before encode), and the packed SHARDS circulate; the
    gathered shards concatenate (trimmed to the true d) into the mean.
    At n_workers == 1 this degenerates exactly to the allgather wire
    path; at n > 1 it is a genuinely different algorithm (the shard
    partition is a finer "layer" partition, covered by the paper's
    Lemma 1) whose wire cost is ~1/n of ring per direction. The dense
    reduce-scatter is NOT pinned to the hop channel (real fabrics run it
    on its own stream).

    Error feedback (state is not None): e = x + m is encoded and the
    residual m' = e - decode(own payload) — local to the encode leg,
    identical to the serialized wire path's discipline (EF never sees
    the topology). Under mode="rs" only the OWNED slice of each unit's
    residual row is live (updated via dynamic_update_slice at
    axis_index·ds); the other slices stay at their initial value, the
    FSDP on-demand semantics.

    `post(xm_row, unit_key) -> y_row` is the master-compression closure
    applied to the mean (None returns the mean). Requires a single DP
    axis (the ring permutation is defined on one axis). Returns
    (tree, buffers) — or (tree, m_tree, buffers) with state.

    `recorder` emits the serialized path's compress/pack/decode spans
    plus one `hop` span per ring hop (name `hop{h} m{i}`, scope
    `repro/msg{i}/hop{h}`) and a `collective` span for the reduce —
    what obs.calibrate.measure_stream aggregates into measured exposed
    comm. Under a multi-device shard_map every mark stamps once per
    device; finalize_step(dedupe=True) collapses them.

    `faults` (duck-typed, resil.FaultInjector) corrupts each ARRIVING
    hop's bytes (mode="ring"): bit flips / truncation on the permuted
    chunks, drop-to-zeros, or a duplicated (stale) hop; with a checksum
    layout the hop is verified on arrival and optionally "resent"
    (reverted to the clean arrived copy). A duplicated hop is a VALID
    stale message — the checksum passes by construction; catching it
    needs sequence numbers (documented limitation). None leaves the
    traced graph untouched.
    """
    from repro.core.schedule import _order_after
    axis_names = tuple(axis_names)
    if len(axis_names) != 1:
        raise ValueError(
            f"streaming collectives run over ONE data-parallel axis (the "
            f"ring permutation is per-axis); got {axis_names!r}")
    if mode not in ("ring", "rs"):
        raise ValueError(f"mode must be 'ring' or 'rs', got {mode!r}")
    axis = axis_names[0]
    n = int(n_workers)
    with_state = state is not None
    rec = _active_recorder(recorder)
    plan = schedule.plan
    leaves = jax.tree_util.tree_leaves(grads)
    sleaves = jax.tree_util.tree_leaves(state) if with_state else None
    need = plan.needs_flat
    flat = plan.flatten(grads) if need else None
    mflat = plan.flatten(state) if need and with_state else None
    keys = plan.unit_keys(key)
    out_leaves = [None] * len(leaves)
    mout_leaves = [None] * len(leaves)
    out_flat = jnp.zeros((plan.exec_total,), jnp.float32) if need else None
    mout_flat = (jnp.zeros((plan.exec_total,), jnp.float32)
                 if need and with_state else None)
    layouts = (message_layouts(schedule, codec) if mode == "ring"
               else shard_message_layouts(schedule, codec, n))
    perm = [(i, (i + 1) % n) for i in range(n)]
    my = jax.lax.axis_index(axis)
    buffers = []
    if rec is not None and leaves:
        rec.begin(leaves[0], label="grads_ready")

    def _attrs(mi, msg):
        return (dict(message=mi, bucket_ids=msg.bucket_ids,
                     dims=tuple(plan.buckets[bi].dim
                                for bi in msg.bucket_ids),
                     n_units=sum(plan.buckets[bi].n
                                 for bi in msg.bucket_ids),
                     codec=codec.name) if rec is not None else None)

    def _scope(mi, stage):
        return (rec.scope(f"repro/msg{mi}/{stage}")
                if rec is not None else contextlib.nullcontext())

    state_tok = dict(token=None, ctok=None)

    def prepare(mi, msg, layout):
        """The compute leg of one message: gather (barriered on the
        previous message's BUFFER — the serialized path's streaming
        contract), shard-reduce under mode='rs', encode, pack."""
        attrs = _attrs(mi, msg)
        if with_state:
            pairs = []
            for bi in msg.bucket_ids:
                b = plan.buckets[bi]
                pairs.append(plan._gather_runs(leaves, flat, b))
                pairs.append(plan._gather_runs(sleaves, mflat, b))
            pairs = _order_after(pairs, state_tok["token"])
            xs = [pairs[2 * j] for j in range(len(msg.bucket_ids))]
            ms = [pairs[2 * j + 1] for j in range(len(msg.bucket_ids))]
        else:
            xs = [plan._gather_runs(leaves, flat, plan.buckets[bi])
                  for bi in msg.bucket_ids]
            xs = _order_after(xs, state_tok["token"])
            ms = None
        dims, es, mps = [], [], []
        if mode == "ring":
            dims = [plan.buckets[bi].dim for bi in msg.bucket_ids]
            es = ([x + m for x, m in zip(xs, ms)] if with_state else xs)
            mps = [None] * len(xs)
        else:  # rs: reduce-scatter the dense units, keep only our shard
            for j, bi in enumerate(msg.bucket_ids):
                b = plan.buckets[bi]
                ds = _shard_dim(b.dim, n)
                pad = n * ds - b.dim
                xp = jnp.pad(xs[j], ((0, 0), (0, pad)))
                shard = jax.lax.psum_scatter(
                    xp, axis, scatter_dimension=1, tiled=True) / n
                # padding enters psum_scatter as exact zeros; the mask
                # pins the contract (nothing phantom reaches encode)
                mask = (my * ds + jnp.arange(ds)) < b.dim
                shard = jnp.where(mask[None, :], shard, 0.0)
                if with_state:
                    mp = jnp.pad(ms[j], ((0, 0), (0, pad)))
                    m_shard = jax.lax.dynamic_slice(
                        mp, (0, my * ds), (b.n, ds))
                    es.append(shard + m_shard)
                    mps.append(mp)
                else:
                    es.append(shard)
                    mps.append(None)
                dims.append(ds)
        with _scope(mi, "compress"):
            mats = [_dispatch_encode(codec, plan.buckets[bi], e, keys,
                                     wire_key)
                    for bi, e in zip(msg.bucket_ids, es)]
        if rec is not None:
            rec.mark(mats, "compress", **attrs)
        with _scope(mi, "pack"):
            buf = _message_buffer(layout, mats)
        if rec is not None:
            rec.mark(buf, "pack", **attrs)
        buffers.append(buf)
        state_tok["token"] = buf
        return dict(mi=mi, msg=msg, layout=layout, buf=buf, es=es,
                    mps=mps, dims=dims, attrs=attrs)

    def finish(p):
        """The collective leg: own decode (+EF residual), n-1 chunked
        ppermute hops with decode-accumulate on arrival, mean + post."""
        mi, msg, layout = p["mi"], p["msg"], p["layout"]
        buf, dims, attrs = p["buf"], p["dims"], p["attrs"]
        chunks = layout_chunks(layout, chunk_bytes)
        accs, mns = [], []
        with _scope(mi, "decode"):
            for j, bi in enumerate(msg.bucket_ids):
                b = plan.buckets[bi]
                pay = _bucket_region(buf, layout, j, b.n)
                acc0 = jnp.zeros((n, b.n, dims[j]), jnp.float32)
                if with_state:
                    acc, mn = codec.decode_accumulate_ef(
                        pay, p["es"][j], acc0, my, dims[j])
                    mns.append(mn)
                else:
                    acc = codec.decode_accumulate(pay, acc0, my, dims[j])
                accs.append(acc)
        if rec is not None:
            rec.mark(accs, "decode", **attrs)
            if with_state:
                rec.mark(mns, "ef_update", **attrs)
        cur = [buf[s:e] for (_, s, e) in chunks]
        if n > 1:
            cur = _order_after(cur, state_tok["ctok"])
            for h in range(1, n):
                with _scope(mi, f"hop{h}"):
                    stale = cur
                    cur = [jax.lax.ppermute(c, axis, perm) for c in cur]
                    if faults is not None:
                        # fault the arriving hop: chunks tile [0, total),
                        # so their concatenation IS the message buffer;
                        # `stale` (the pre-permute content this worker
                        # already forwarded) models a duplicated hop,
                        # and resend reverts to the clean arrived copy
                        abuf = jnp.concatenate(cur)
                        rbuf = faults.corrupt_hop(
                            abuf, jnp.concatenate(stale), key,
                            tag=(mi << 12) | h,
                            start=layout.header_nbytes)
                        if rbuf is not abuf:
                            if layout.checksum:
                                ok = verify_message(rbuf, layout)
                                if getattr(faults, "resend", False):
                                    rbuf = jnp.where(ok, rbuf, abuf)
                                faults.note((mi << 12) | h, ok)
                            cur = [rbuf[s:e] for (_, s, e) in chunks]
                    src = jnp.mod(my - h, n)
                    for (run, start, _), cbuf in zip(chunks, cur):
                        for j in run:
                            b = plan.buckets[msg.bucket_ids[j]]
                            nb = layout.unit_nbytes[j]
                            off = layout.offsets[j] - start
                            pay = cbuf[off:off + b.n * nb].reshape(b.n, nb)
                            accs[j] = codec.decode_accumulate(
                                pay, accs[j], src, dims[j])
                if rec is not None:
                    rec.mark([cur[-1], accs[-1]], "hop",
                             label=f"hop{h} m{mi}", **attrs)
            state_tok["ctok"] = cur[-1]
        ys, m_news = [], []
        with _scope(mi, "collective"):
            for j, bi in enumerate(msg.bucket_ids):
                b = plan.buckets[bi]
                kb = keys[jnp.asarray(b.unit_ids, jnp.int32)]
                if mode == "ring":
                    def unit_post(g, kk):
                        xm = jnp.mean(g, axis=0)
                        return xm if post is None else post(xm, kk)
                    y = (unit_post(accs[j][:, 0, :], kb[0])[None]
                         if b.n == 1
                         else jax.vmap(unit_post, in_axes=(1, 0))(accs[j],
                                                                  kb))
                    if with_state:
                        m_news.append(mns[j])
                else:
                    ds = dims[j]
                    xm2d = accs[j].transpose(1, 0, 2).reshape(
                        b.n, n * ds)[:, :b.dim]
                    def unit_post(xm, kk):
                        return xm if post is None else post(xm, kk)
                    y = (unit_post(xm2d[0], kb[0])[None] if b.n == 1
                         else jax.vmap(unit_post)(xm2d, kb))
                    if with_state:
                        m_new = jax.lax.dynamic_update_slice(
                            p["mps"][j], mns[j], (0, my * ds))[:, :b.dim]
                        m_news.append(m_new)
                ys.append(y)
        if rec is not None:
            rec.mark(ys, "collective", **attrs)
        nonlocal out_flat, mout_flat
        for j, (bi, y) in enumerate(zip(msg.bucket_ids, ys)):
            b = plan.buckets[bi]
            out_flat = plan._scatter_runs(out_leaves, out_flat, b, y)
            if with_state:
                mout_flat = plan._scatter_runs(mout_leaves, mout_flat, b,
                                               m_news[j])

    # the depth-2 software pipeline: prepare(i+1) is emitted before
    # finish(i), so compress(i+1) sits ahead of collective(i) in program
    # order while the barriers above keep both streams internally ordered
    pending = None
    for mi, (msg, layout) in enumerate(zip(schedule.messages, layouts)):
        p = prepare(mi, msg, layout)
        if pending is not None:
            finish(pending)
        pending = p
    if pending is not None:
        finish(pending)
    tree = plan._assemble(out_leaves, out_flat)
    if with_state:
        return (tree, plan._assemble(mout_leaves, mout_flat),
                tuple(buffers))
    return tree, tuple(buffers)
