"""Gradient compression operators (the paper's Q_W / Q_M instances).

Every operator follows the paper's Assumption 5:  E‖Q(x)‖² ≤ (1+Ω)‖x‖².
Operators are pure functions of (array, PRNGKey); statistics (max, norms,
thresholds) are computed over the WHOLE input array — the *granularity*
module decides what that unit is (entire model / layer / block), which is
exactly the paper's layer-wise vs entire-model distinction.

Two interfaces per operator:
  sim(x, key)        -> dense x_hat           (the mathematical operator,
                                               used by the `simulated` strategy
                                               — matches the paper's artifact)
  encode(x, key)     -> Payload (pytree)      (static-shape wire format)
  decode(payload, d) -> dense x_hat           (used by allgather / RS-AG
                                               strategies; bytes on the wire
                                               are exactly the payload leaves)

All encode/decode shapes are static (TPU requirement). Data-dependent-size
methods (threshold_v, adaptive) use a capacity-bounded payload in wire mode
and exact masking in sim mode; bits.py accounts both.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

Array = jax.Array
Payload = Dict[str, Array]

_EPS = 1e-12


def _flat(x: Array) -> Array:
    return x.reshape(-1)


def _restore(x_flat: Array, like: Array) -> Array:
    return x_flat.reshape(like.shape).astype(like.dtype)


def _k_of(ratio: float, d: int) -> int:
    """Static kept-element count for a sparsification ratio (paper's k%)."""
    return max(1, min(d, int(round(ratio * d))))


def index_bits(d: int) -> int:
    """Wire width of one sparse-record index: ceil(log2(d)) bits address d
    positions (min 1 — a record always carries an index field). This is
    the dim-dependent width the packed codecs (core/wire.py) put on the
    wire, and therefore what payload accounting charges: a 32-bit index
    per kept value — what the first accounting here assumed — overstates
    small layers' sparse payloads by >10x."""
    return max(1, (d - 1).bit_length()) if d > 1 else 1


def pack_signs(bits: Array) -> Array:
    """Pack a {0,1} int32 vector (length multiple-of-8 padded) into uint8."""
    d = bits.shape[0]
    pad = (-d) % 8
    b = jnp.pad(bits, (0, pad)).reshape(-1, 8).astype(jnp.uint8)
    weights = (2 ** jnp.arange(8, dtype=jnp.uint8))
    return (b * weights).sum(axis=1).astype(jnp.uint8)


def unpack_signs(packed: Array, d: int) -> Array:
    """Inverse of pack_signs -> {0,1} int32 vector of length d."""
    bits = (packed[:, None] >> jnp.arange(8, dtype=jnp.uint8)[None, :]) & 1
    return bits.reshape(-1)[:d].astype(jnp.int32)


@dataclasses.dataclass(frozen=True)
class Compressor:
    """Base compression operator. Subclasses are frozen dataclasses so they
    are hashable static args under jit."""

    name: str = "identity"
    unbiased: bool = True

    # ---- mathematical operator (dense in / dense out) --------------------
    def sim(self, x: Array, key: Array) -> Array:
        return x

    # ---- wire format ------------------------------------------------------
    def encode(self, x: Array, key: Array) -> Payload:
        return {"dense": _flat(x)}

    def decode(self, payload: Payload, d: int, dtype=jnp.float32) -> Array:
        return payload["dense"].astype(dtype)

    # ---- accounting / theory ----------------------------------------------
    def payload_bits(self, d: int) -> int:
        """Wire bits for one encoded unit of dimension d."""
        return 32 * d

    def omega(self, d: int) -> Optional[float]:
        """Theoretical Ω in Assumption 5, if known in closed form."""
        return 0.0


@dataclasses.dataclass(frozen=True)
class Identity(Compressor):
    name: str = "identity"
    unbiased: bool = True


@dataclasses.dataclass(frozen=True)
class RandomK(Compressor):
    """Random-k sparsification. `scale=False` is the paper's biased Random k
    (keep the sampled values); `scale=True` multiplies by d/k making it
    unbiased with Ω = d/k - 1."""

    name: str = "randomk"
    ratio: float = 0.01
    scale: bool = False
    unbiased: bool = False

    def __post_init__(self):
        object.__setattr__(self, "unbiased", self.scale)

    def _indices(self, d: int, key: Array) -> Array:
        k = _k_of(self.ratio, d)
        scores = jax.random.uniform(key, (d,))
        _, idx = jax.lax.top_k(scores, k)
        return idx

    def sim(self, x: Array, key: Array) -> Array:
        xf = _flat(x)
        d = xf.shape[0]
        idx = self._indices(d, key)
        out = jnp.zeros_like(xf).at[idx].set(xf[idx])
        if self.scale:
            out = out * (d / _k_of(self.ratio, d))
        return _restore(out, x)

    def encode(self, x: Array, key: Array) -> Payload:
        xf = _flat(x)
        d = xf.shape[0]
        idx = self._indices(d, key)
        vals = xf[idx]
        if self.scale:
            vals = vals * (d / _k_of(self.ratio, d))
        return {"idx": idx.astype(jnp.int32), "val": vals}

    def decode(self, payload: Payload, d: int, dtype=jnp.float32) -> Array:
        out = jnp.zeros((d,), dtype)
        return out.at[payload["idx"]].set(payload["val"].astype(dtype))

    def payload_bits(self, d: int) -> int:
        k = _k_of(self.ratio, d)
        return k * (32 + index_bits(d))

    def omega(self, d: int) -> Optional[float]:
        k = _k_of(self.ratio, d)
        return (d / k - 1.0) if self.scale else 0.0


@dataclasses.dataclass(frozen=True)
class TopK(Compressor):
    """Top-k by magnitude (biased; Ω = 0 since ‖Q(x)‖ ≤ ‖x‖)."""

    name: str = "topk"
    ratio: float = 0.01
    unbiased: bool = False

    def sim(self, x: Array, key: Array) -> Array:
        xf = _flat(x)
        d = xf.shape[0]
        k = _k_of(self.ratio, d)
        _, idx = jax.lax.top_k(jnp.abs(xf), k)
        out = jnp.zeros_like(xf).at[idx].set(xf[idx])
        return _restore(out, x)

    def encode(self, x: Array, key: Array) -> Payload:
        xf = _flat(x)
        d = xf.shape[0]
        k = _k_of(self.ratio, d)
        _, idx = jax.lax.top_k(jnp.abs(xf), k)
        return {"idx": idx.astype(jnp.int32), "val": xf[idx]}

    def decode(self, payload: Payload, d: int, dtype=jnp.float32) -> Array:
        out = jnp.zeros((d,), dtype)
        return out.at[payload["idx"]].set(payload["val"].astype(dtype))

    def payload_bits(self, d: int) -> int:
        return _k_of(self.ratio, d) * (32 + index_bits(d))

    def omega(self, d: int) -> Optional[float]:
        return 0.0


@dataclasses.dataclass(frozen=True)
class ThresholdV(Compressor):
    """Keep elements with |x_i| >= v (paper's Threshold v). Data-dependent
    count: sim mode is exact masking; wire mode keeps the top `cap_ratio`
    among qualifying elements (capacity bound for static shapes)."""

    name: str = "threshold_v"
    v: float = 1e-3
    cap_ratio: float = 0.25
    unbiased: bool = False

    def sim(self, x: Array, key: Array) -> Array:
        xf = _flat(x)
        out = jnp.where(jnp.abs(xf) >= self.v, xf, 0.0)
        return _restore(out, x)

    def encode(self, x: Array, key: Array) -> Payload:
        xf = _flat(x)
        d = xf.shape[0]
        cap = _k_of(self.cap_ratio, d)
        mag = jnp.where(jnp.abs(xf) >= self.v, jnp.abs(xf), -1.0)
        _, idx = jax.lax.top_k(mag, cap)
        vals = jnp.where(mag[idx] >= 0.0, xf[idx], 0.0)
        return {"idx": idx.astype(jnp.int32), "val": vals}

    def decode(self, payload: Payload, d: int, dtype=jnp.float32) -> Array:
        out = jnp.zeros((d,), dtype)
        return out.at[payload["idx"]].set(payload["val"].astype(dtype))

    def payload_bits(self, d: int) -> int:
        return _k_of(self.cap_ratio, d) * (32 + index_bits(d))

    def omega(self, d: int) -> Optional[float]:
        return 0.0


@dataclasses.dataclass(frozen=True)
class AdaptiveThreshold(Compressor):
    """AdaComp-style adaptive threshold (Chen et al. 2018, as used in the
    paper): the threshold is a fraction `alpha` of the unit's max magnitude,
    so it adapts per compression unit — the mechanism whose granularity
    sensitivity the paper highlights (per-layer max vs global max)."""

    name: str = "adaptive_threshold"
    alpha: float = 0.01
    cap_ratio: float = 0.25
    unbiased: bool = False

    def _thr(self, xf: Array) -> Array:
        return self.alpha * jnp.max(jnp.abs(xf))

    def sim(self, x: Array, key: Array) -> Array:
        xf = _flat(x)
        out = jnp.where(jnp.abs(xf) >= self._thr(xf), xf, 0.0)
        return _restore(out, x)

    def encode(self, x: Array, key: Array) -> Payload:
        xf = _flat(x)
        d = xf.shape[0]
        cap = _k_of(self.cap_ratio, d)
        mag = jnp.where(jnp.abs(xf) >= self._thr(xf), jnp.abs(xf), -1.0)
        _, idx = jax.lax.top_k(mag, cap)
        vals = jnp.where(mag[idx] >= 0.0, xf[idx], 0.0)
        return {"idx": idx.astype(jnp.int32), "val": vals}

    def decode(self, payload: Payload, d: int, dtype=jnp.float32) -> Array:
        out = jnp.zeros((d,), dtype)
        return out.at[payload["idx"]].set(payload["val"].astype(dtype))

    def payload_bits(self, d: int) -> int:
        return _k_of(self.cap_ratio, d) * (32 + index_bits(d))

    def omega(self, d: int) -> Optional[float]:
        return 0.0


@dataclasses.dataclass(frozen=True)
class TernGrad(Compressor):
    """TernGrad (Wen et al. 2017): x -> s·sign(x)·b, b ~ Bernoulli(|x|/s),
    s = max|x| over the compression unit. Unbiased. The per-unit scalar s is
    exactly where layer-wise beats entire-model per the paper's §5.3."""

    name: str = "terngrad"
    unbiased: bool = True

    def _quantize(self, xf: Array, key: Array):
        s = jnp.max(jnp.abs(xf)) + _EPS
        p = jnp.abs(xf) / s
        b = jax.random.bernoulli(key, p).astype(jnp.int8)
        t = (jnp.sign(xf).astype(jnp.int8) * b).astype(jnp.int8)
        return t, s.astype(jnp.float32)

    def sim(self, x: Array, key: Array) -> Array:
        xf = _flat(x).astype(jnp.float32)
        t, s = self._quantize(xf, key)
        return _restore(t.astype(jnp.float32) * s, x)

    def encode(self, x: Array, key: Array) -> Payload:
        t, s = self._quantize(_flat(x).astype(jnp.float32), key)
        return {"tern": t, "scale": s[None]}

    def decode(self, payload: Payload, d: int, dtype=jnp.float32) -> Array:
        return (payload["tern"].astype(jnp.float32)
                * payload["scale"][0]).astype(dtype)

    def payload_bits(self, d: int) -> int:
        return 2 * d + 32  # 2-bit ternary + one f32 scale

    def omega(self, d: int) -> Optional[float]:
        # E‖Q(x)‖² = s·‖x‖₁ ≤ √d‖x‖₂·... — bound: ≤ √d·‖x‖² / ‖x‖ ; use the
        # standard worst case Ω ≤ √d (loose); report None to force empirical.
        return None


@dataclasses.dataclass(frozen=True)
class QSGD(Compressor):
    """QSGD (Alistarh et al. 2017) with s quantization levels:
    q_i = ‖x‖₂ · sign(x_i) · ξ_i(x, s) / s where ξ is stochastic rounding of
    s|x_i|/‖x‖₂. Unbiased; Ω = min(d/s², √d/s)."""

    name: str = "qsgd"
    levels: int = 16  # s; payload int8 holds signed levels up to 127
    unbiased: bool = True

    @property
    def entry_bits(self) -> int:
        """Wire bits per quantized entry: offset-binary codes in
        [0, 2s] (the single source both payload_bits and the wire codec
        read — they can never desync)."""
        return max(2, math.ceil(math.log2(2 * self.levels + 1)))

    def _quantize(self, xf: Array, key: Array):
        nrm = jnp.linalg.norm(xf) + _EPS
        y = jnp.abs(xf) / nrm * self.levels
        lo = jnp.floor(y)
        u = jax.random.uniform(key, xf.shape)
        lev = lo + (u < (y - lo)).astype(y.dtype)
        q = (jnp.sign(xf) * lev).astype(jnp.int8)
        return q, nrm.astype(jnp.float32)

    def sim(self, x: Array, key: Array) -> Array:
        xf = _flat(x).astype(jnp.float32)
        q, nrm = self._quantize(xf, key)
        return _restore(q.astype(jnp.float32) * (nrm / self.levels), x)

    def encode(self, x: Array, key: Array) -> Payload:
        q, nrm = self._quantize(_flat(x).astype(jnp.float32), key)
        return {"lev": q, "norm": nrm[None]}

    def decode(self, payload: Payload, d: int, dtype=jnp.float32) -> Array:
        return (payload["lev"].astype(jnp.float32)
                * (payload["norm"][0] / self.levels)).astype(dtype)

    def payload_bits(self, d: int) -> int:
        return self.entry_bits * d + 32

    def omega(self, d: int) -> Optional[float]:
        s = self.levels
        return min(d / s**2, math.sqrt(d) / s)


@dataclasses.dataclass(frozen=True)
class SignSGD(Compressor):
    """signSGD (Bernstein et al. 2018): Q(x) = sign(x) (deterministic,
    biased). Satisfies Assumption 6 with α=1, ‖·‖₁, R_k = O(1/BS).
    Wire format: 1 bit/element (packed uint8)."""

    name: str = "signsgd"
    unbiased: bool = False

    def sim(self, x: Array, key: Array) -> Array:
        xf = _flat(x)
        return _restore(jnp.where(xf >= 0, 1.0, -1.0).astype(xf.dtype), x)

    def encode(self, x: Array, key: Array) -> Payload:
        bits = (_flat(x) >= 0).astype(jnp.int32)
        return {"bits": pack_signs(bits)}

    def decode(self, payload: Payload, d: int, dtype=jnp.float32) -> Array:
        b = unpack_signs(payload["bits"], d)
        return (2.0 * b - 1.0).astype(dtype)

    def payload_bits(self, d: int) -> int:
        return d

    def omega(self, d: int) -> Optional[float]:
        return None  # ‖sign(x)‖² = d; not uniformly bounded by ‖x‖² — empirical.


@dataclasses.dataclass(frozen=True)
class NaturalCompression(Compressor):
    """C_NAT (Horváth et al. 2019): stochastic rounding to powers of two.
    Unbiased with Ω = 1/8. Wire: sign + 8-bit exponent = 9 bits."""

    name: str = "natural"
    unbiased: bool = True
    _BIAS: int = 127

    def _exponents(self, xf: Array, key: Array):
        mag = jnp.abs(xf)
        safe = jnp.where(mag > 0, mag, 1.0)
        e = jnp.floor(jnp.log2(safe))
        low = jnp.exp2(e)
        p_up = (safe - low) / low  # in [0,1): prob of rounding to 2^{e+1}
        up = jax.random.bernoulli(key, p_up)
        e = e + up.astype(e.dtype)
        e = jnp.clip(e, -126, 127)
        e = jnp.where(mag > 0, e, -126.0)
        zero = mag == 0
        return e.astype(jnp.int32), jnp.sign(xf), zero

    def sim(self, x: Array, key: Array) -> Array:
        xf = _flat(x).astype(jnp.float32)
        e, sgn, zero = self._exponents(xf, key)
        out = jnp.where(zero, 0.0, sgn * jnp.exp2(e.astype(jnp.float32)))
        return _restore(out, x)

    def encode(self, x: Array, key: Array) -> Payload:
        xf = _flat(x).astype(jnp.float32)
        e, sgn, zero = self._exponents(xf, key)
        # int16: sign(±1 or 0 for exact zero) * (exponent + bias + 1)
        code = (sgn.astype(jnp.int32) * (e + self._BIAS + 1))
        code = jnp.where(zero, 0, code).astype(jnp.int16)
        return {"code": code}

    def decode(self, payload: Payload, d: int, dtype=jnp.float32) -> Array:
        code = payload["code"].astype(jnp.int32)
        sgn = jnp.sign(code).astype(jnp.float32)
        e = jnp.abs(code) - (self._BIAS + 1)
        val = sgn * jnp.exp2(e.astype(jnp.float32))
        return jnp.where(code == 0, 0.0, val).astype(dtype)

    def payload_bits(self, d: int) -> int:
        return 9 * d

    def omega(self, d: int) -> Optional[float]:
        return 0.125


_REGISTRY = {
    "identity": Identity,
    "randomk": RandomK,
    "topk": TopK,
    "threshold_v": ThresholdV,
    "adaptive_threshold": AdaptiveThreshold,
    "terngrad": TernGrad,
    "qsgd": QSGD,
    "signsgd": SignSGD,
    "natural": NaturalCompression,
}


def make_compressor(name: str, **kwargs: Any) -> Compressor:
    """Build a compressor by name. kwargs are dataclass fields
    (ratio=, levels=, v=, alpha=, scale=, ...)."""
    if name not in _REGISTRY:
        raise ValueError(f"unknown compressor {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name](**kwargs)


def available_compressors():
    return sorted(_REGISTRY)
