"""Core library: the paper's layer-wise bidirectional compressed
communication framework (AAAI'20, Dutta et al.)."""
from repro.core.compressors import (Compressor, Identity, RandomK, TopK,
                                    ThresholdV, AdaptiveThreshold, TernGrad,
                                    QSGD, SignSGD, NaturalCompression,
                                    index_bits, make_compressor,
                                    available_compressors)
from repro.core.granularity import (Granularity, stacked_mask, unit_dims,
                                    num_units, apply_unitwise,
                                    apply_unitwise_with_state,
                                    apply_unitwise_reference,
                                    apply_unitwise_with_state_reference)
from repro.core.plan import UnitPlan, Bucket, build_plan, plan_unit_dims
from repro.core.schedule import (CommSchedule, Message, FUSE_ALL,
                                 build_schedule, message_wire_bits,
                                 simulate_schedule)
from repro.core.aggregation import (CompressionConfig, compressed_allreduce,
                                    aggregate_simulated_workers,
                                    no_compression, STRATEGIES)
from repro.core.bits import (comm_report, CommReport,
                             measured_bits_from_payloads)
from repro.core.wire import (WireCodec, DenseCodec, QSGDCodec, TernGradCodec,
                             SignSGDCodec, NaturalCodec, SparseCodec,
                             MessageLayout, has_wire_codec, message_layouts,
                             to_bf16, to_f32, wire_codec, word_padding)
