"""UnitPlan: a static bucketed compression-execution engine.

The paper's subject is the *granularity* at which compression is applied
(entire model vs layer vs block). The first realization of layer-wise
granularity here was a Python loop over pytree leaves — O(#tensors) traced
compressor calls per step, exactly the per-layer operator-launch overhead
that Agarwal et al. (PAPERS.md) show can erase compression's wall-clock
benefit. This module removes it at the framework level: compute a *plan*
once at trace time, then execute compression as a handful of fused
dispatches.

Plan construction (pure Python, static — cached on the leaf shapes):

  (params treedef, stacked mask, Granularity)
      -> per-unit tables: (offset into the flat gradient, dim, leaf index)
      -> buckets: same-size units grouped into (n_units, dim) matrices
      -> per-unit PRNG fold indices reproducing the legacy key derivation
         bit-for-bit (single fold for loose leaves / blocks, double fold
         for scan-stacked layers)

Execution (traced, per step):

  gather   flat = concat(leaves)        one concat
  compress Y_b = vmap(fn)(X_b, keys_b)  ONE batched dispatch per bucket
  scatter  leaves = split(out_flat)     one split

All three granularities are the same plan shape: entire_model is a 1-unit
plan, blockwise is a fixed-size plan (one bucket), layerwise is the ragged
case bucketed by size class. Buckets whose units tile a contiguous range of
the flat gradient (scan-stacked layers, blockwise) gather by reshape —
no index arrays at all.

Numerical contract: `plan.execute(fn, ...)` produces exactly what the
legacy per-leaf path (`granularity.apply_unitwise_reference`) produces,
including the PRNG stream. tests/test_plan.py holds this property over
the operator zoo x granularities.
"""
from __future__ import annotations

import bisect
import dataclasses
import functools
from typing import Callable, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.granularity import Granularity

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class Bucket:
    """One size class: all units of dimension `dim`, as rows of a matrix.

    `unit_ids` index the plan's per-unit tables (execution order).
    `offsets` are the units' start positions in the flat gradient.
    `runs` decomposes the rows into maximal contiguous segments
    (start_offset, n_units, leaf_index): each run gathers/scatters by
    reshape, never by element index arrays. leaf_index >= 0 means the run
    covers exactly that pytree leaf, so execution reads/writes the leaf
    directly — no flat staging buffer at all (the layerwise case, where
    units never straddle leaves). leaf_index == -1 (entire-model /
    blockwise spans) stages through the flat vector.

    `ready` is the bucket's backward-readiness rank: backward produces
    gradient leaves in reverse leaf order (leaf N-1 first, leaf 0 last),
    so leaf k's gradient is available at time (n_leaves-1-k) and a bucket
    is ready once EVERY leaf any of its units touches has been produced —
    i.e. at (n_leaves-1) - min(touched leaf index). Lower rank = ready
    earlier in backward. core.schedule orders wire messages by it.
    """
    dim: int
    unit_ids: Tuple[int, ...]
    offsets: Tuple[int, ...]
    runs: Tuple[Tuple[int, int, int], ...]
    ready: int = 0

    @property
    def n(self) -> int:
        return len(self.unit_ids)

    @property
    def contiguous(self) -> bool:
        return len(self.runs) == 1

    @property
    def nbytes(self) -> int:
        """Dense f32 bytes of the bucket's units — the size a Horovod-style
        fusion buffer reasons about (compressor-independent)."""
        return 4 * self.n * self.dim


@dataclasses.dataclass(frozen=True)
class UnitPlan:
    """Static compression-execution plan for one (pytree, granularity).

    Frozen + tuples throughout => hashable, so a plan is a valid static
    argument under jit and a safe lru_cache value.
    """
    granularity: Granularity
    treedef: jax.tree_util.PyTreeDef
    leaf_shapes: Tuple[Tuple[int, ...], ...]
    leaf_dtypes: Tuple[jnp.dtype, ...]
    total: int                       # true element count (sum of leaf sizes)
    exec_total: int                  # padded flat length the buckets tile
    unit_dims: Tuple[int, ...]       # ACCOUNTING dims (bits.py / theory.py)
    exec_dims: Tuple[int, ...]       # per exec-unit dim (blockwise pads tail)
    unit_offsets: Tuple[int, ...]    # per exec-unit flat offset
    unit_leaf: Tuple[int, ...]       # per exec-unit leaf index (-1: spans)
    buckets: Tuple[Bucket, ...]
    # PRNG fold tables reproducing the legacy derivation:
    #   double: key_u = fold_in(fold_in(key, base_u), inner_u)   (stacked)
    #   single: key_u = fold_in(key, base_u)                     (otherwise)
    fold_base: Tuple[int, ...]
    fold_inner: Tuple[int, ...]
    fold_double: Tuple[bool, ...]

    # ---- introspection ----------------------------------------------------
    @property
    def num_units(self) -> int:
        """Accounting units (== len(granularity.unit_dims))."""
        return len(self.unit_dims)

    @property
    def num_exec_units(self) -> int:
        return len(self.exec_dims)

    @property
    def num_dispatches(self) -> int:
        """Batched compressor dispatches per execution — one per bucket,
        i.e. O(#size classes), not O(#leaves)."""
        return len(self.buckets)

    @property
    def num_leaves(self) -> int:
        return len(self.leaf_shapes)

    def readiness_order(self) -> Tuple[int, ...]:
        """Bucket indices sorted by backward-readiness (earliest-ready
        first — i.e. the buckets whose gradients backward produces first,
        the late layers). Ties break on bucket index, so the order is
        deterministic and a pure function of the plan."""
        return tuple(sorted(range(len(self.buckets)),
                            key=lambda i: (self.buckets[i].ready, i)))

    def summary(self) -> str:
        bs = ", ".join(f"{b.n}x{b.dim}" for b in self.buckets)
        return (f"UnitPlan({self.granularity.kind}: {self.num_units} units, "
                f"{self.num_dispatches} dispatches [{bs}])")

    # ---- flat <-> tree ----------------------------------------------------
    def flatten(self, tree) -> Array:
        """Pytree -> f32 flat vector of length exec_total (zero-padded)."""
        leaves = jax.tree_util.tree_leaves(tree)
        flat = jnp.concatenate(
            [l.reshape(-1).astype(jnp.float32) for l in leaves]) \
            if len(leaves) > 1 else leaves[0].reshape(-1).astype(jnp.float32)
        if self.exec_total > self.total:
            flat = jnp.pad(flat, (0, self.exec_total - self.total))
        return flat

    def unflatten(self, flat: Array):
        """f32 flat vector -> pytree with the plan's shapes/dtypes."""
        return self._assemble([None] * len(self.leaf_shapes), flat)

    # ---- PRNG -------------------------------------------------------------
    def unit_keys(self, key: Array) -> Array:
        """Per-exec-unit PRNG keys, identical to the legacy per-leaf
        derivation (vectorized over the fold tables)."""
        base = jnp.asarray(self.fold_base, jnp.int32)
        inner = jnp.asarray(self.fold_inner, jnp.int32)
        dbl = jnp.asarray(self.fold_double)
        k1 = jax.vmap(lambda b: jax.random.fold_in(key, b))(base)
        k2 = jax.vmap(lambda k, i: jax.random.fold_in(k, i))(k1, inner)
        typed = jnp.issubdtype(key.dtype, jax.dtypes.prng_key)
        if typed:
            kd = jnp.where(dbl[:, None], jax.random.key_data(k2),
                           jax.random.key_data(k1))
            return jax.random.wrap_key_data(kd,
                                            impl=jax.random.key_impl(key))
        return jnp.where(dbl[:, None], k2, k1)

    # ---- bucket gather / scatter -----------------------------------------
    @property
    def needs_flat(self) -> bool:
        """True when some run spans leaves (entire-model / blockwise):
        execution must stage through the flat vector. Layerwise plans are
        flat-free (every run reads/writes its leaf directly)."""
        return any(r[2] < 0 for b in self.buckets for r in b.runs)

    def _gather_runs(self, leaves, flat, b: Bucket) -> Array:
        mats = []
        for start, k, li in b.runs:
            if li >= 0 and leaves is not None:
                mats.append(leaves[li].reshape(k, b.dim).astype(jnp.float32))
            else:
                mats.append(flat[start:start + k * b.dim].reshape(k, b.dim))
        return mats[0] if len(mats) == 1 else jnp.concatenate(mats, axis=0)

    def gather_bucket(self, flat: Array, b: Bucket) -> Array:
        """(exec_total,) -> (n_units, dim) matrix of the bucket's units.

        Pure reshape per contiguous run — no element index arrays."""
        return self._gather_runs(None, flat, b)

    def scatter_bucket(self, out: Array, b: Bucket, y: Array) -> Array:
        row = 0
        for start, k, _ in b.runs:
            out = jax.lax.dynamic_update_slice(
                out, y[row:row + k].reshape(-1), (start,))
            row += k
        return out

    def _scatter_runs(self, out_leaves, out_flat, b: Bucket, y: Array):
        row = 0
        for start, k, li in b.runs:
            seg = y[row:row + k]
            if li >= 0:
                out_leaves[li] = seg.reshape(
                    self.leaf_shapes[li]).astype(self.leaf_dtypes[li])
            else:
                out_flat = jax.lax.dynamic_update_slice(
                    out_flat, seg.reshape(-1), (start,))
            row += k
        return out_flat

    def _assemble(self, out_leaves, out_flat):
        outs, off = [], 0
        for i, (shape, dtype) in enumerate(zip(self.leaf_shapes,
                                               self.leaf_dtypes)):
            size = 1
            for s in shape:
                size *= s
            if out_leaves[i] is not None:
                outs.append(out_leaves[i])
            else:
                outs.append(out_flat[off:off + size].reshape(shape)
                            .astype(dtype))
            off += size
        return jax.tree_util.tree_unflatten(self.treedef, outs)

    # ---- execution --------------------------------------------------------
    def _dispatch(self, fn, b: Bucket, x: Array, keys: Array) -> Array:
        """ONE batched compressor dispatch for bucket `b` on its gathered
        (n, dim) matrix. The single definition both the plan path and the
        scheduled path (core.schedule) execute through — the scheduled-vs-
        unscheduled bit-identity contract rests on there being one copy of
        this key-indexing/vmap logic."""
        kb = keys[jnp.asarray(b.unit_ids, jnp.int32)]
        if b.n == 1:
            return fn(x[0], kb[0])[None]
        return jax.vmap(fn)(x, kb)

    def _dispatch_with_state(self, fn, b: Bucket, x: Array, m: Array,
                             keys: Array):
        """State-threading twin of _dispatch: fn(x, m, key) -> (y, m')."""
        kb = keys[jnp.asarray(b.unit_ids, jnp.int32)]
        if b.n == 1:
            y, mn = fn(x[0], m[0], kb[0])
            return y[None], mn[None]
        return jax.vmap(fn)(x, m, kb)

    def execute(self, fn: Callable[[Array, Array], Array], grads,
                key: Array, *, recorder=None):
        """Map fn(x_flat f32[d], key) -> f32[d] over every unit, batched
        per size class. Returns a pytree shaped/dtyped like `grads`.

        Leaf-aligned runs (all of layerwise) read/write leaves directly;
        only leaf-spanning plans stage through a flat buffer.

        `recorder` (duck-typed, obs.trace.TraceRecorder) instruments
        each dispatch with a named scope + end-of-stage mark; None or a
        disabled recorder leaves the traced graph untouched."""
        rec = (recorder if recorder is not None
               and getattr(recorder, "enabled", False) else None)
        leaves = jax.tree_util.tree_leaves(grads)
        flat = self.flatten(grads) if self.needs_flat else None
        keys = self.unit_keys(key)
        out_leaves = [None] * len(leaves)
        out_flat = (jnp.zeros((self.exec_total,), jnp.float32)
                    if flat is not None else None)
        if rec is not None and leaves:
            rec.begin(leaves[0], label="grads_ready")
        for bi, b in enumerate(self.buckets):
            x = self._gather_runs(leaves, flat, b)
            if rec is not None:
                with rec.scope(f"repro/dispatch/b{bi}"):
                    y = self._dispatch(fn, b, x, keys)
                rec.mark(y, "dispatch", cat="dispatch",
                         bucket_ids=(bi,), dims=(b.dim,), n_units=b.n,
                         label=f"dispatch b{bi}")
            else:
                y = self._dispatch(fn, b, x, keys)
            out_flat = self._scatter_runs(out_leaves, out_flat, b, y)
        return self._assemble(out_leaves, out_flat)

    def execute_with_state(self, fn, grads, state, key: Array, *,
                           recorder=None):
        """Like execute, but fn(x, m, key) -> (y, m_new) threads a
        same-shaped per-unit state (error-feedback memory)."""
        rec = (recorder if recorder is not None
               and getattr(recorder, "enabled", False) else None)
        leaves = jax.tree_util.tree_leaves(grads)
        sleaves = jax.tree_util.tree_leaves(state)
        need = self.needs_flat
        flat = self.flatten(grads) if need else None
        mflat = self.flatten(state) if need else None
        keys = self.unit_keys(key)
        out_leaves = [None] * len(leaves)
        mout_leaves = [None] * len(leaves)
        out_flat = (jnp.zeros((self.exec_total,), jnp.float32)
                    if need else None)
        mout_flat = (jnp.zeros((self.exec_total,), jnp.float32)
                     if need else None)
        if rec is not None and leaves:
            rec.begin(leaves[0], label="grads_ready")
        for bi, b in enumerate(self.buckets):
            x = self._gather_runs(leaves, flat, b)
            m = self._gather_runs(sleaves, mflat, b)
            if rec is not None:
                with rec.scope(f"repro/dispatch/b{bi}"):
                    y, mn = self._dispatch_with_state(fn, b, x, m, keys)
                rec.mark([y, mn], "dispatch", cat="dispatch",
                         bucket_ids=(bi,), dims=(b.dim,), n_units=b.n,
                         label=f"dispatch b{bi}")
            else:
                y, mn = self._dispatch_with_state(fn, b, x, m, keys)
            out_flat = self._scatter_runs(out_leaves, out_flat, b, y)
            mout_flat = self._scatter_runs(mout_leaves, mout_flat, b, mn)
        return (self._assemble(out_leaves, out_flat),
                self._assemble(mout_leaves, mout_flat))


# ==========================================================================
# plan construction
# ==========================================================================

def _first_touched_leaf(offset: int, unit_leaf_idx: int,
                        leaf_offsets: Sequence[int]) -> int:
    """Lowest-index leaf a unit starting at `offset` touches. Units tagged
    with a leaf use it directly; spanning units (entire-model / blockwise,
    leaf index -1) locate the leaf containing their start offset. Offsets
    landing in blockwise tail padding clamp to the last leaf."""
    if unit_leaf_idx >= 0:
        return unit_leaf_idx
    if not leaf_offsets:
        return 0
    return max(0, bisect.bisect_right(leaf_offsets, offset) - 1)


def _make_buckets(dims: Sequence[int], offsets: Sequence[int],
                  unit_leaf: Sequence[int],
                  leaf_offsets: Sequence[int],
                  leaf_sizes: Sequence[int]) -> Tuple[Bucket, ...]:
    """Group units by dim (first-occurrence order) and split each group
    into contiguous runs. Runs never merge across leaves: a run that
    covers one leaf exactly is tagged with its leaf index, enabling the
    flat-free direct-leaf execution path."""
    n_leaves = len(leaf_sizes)
    by_dim: dict = {}
    order: List[int] = []
    for uid, d in enumerate(dims):
        if d not in by_dim:
            by_dim[d] = []
            order.append(d)
        by_dim[d].append(uid)
    buckets = []
    for d in order:
        ids = by_dim[d]
        offs = [offsets[u] for u in ids]
        runs: List[List[int]] = []   # [start, count, leaf]
        for u, o in zip(ids, offs):
            li = unit_leaf[u]
            if (runs and li == runs[-1][2] and li >= 0
                    and o == runs[-1][0] + runs[-1][1] * d):
                runs[-1][1] += 1
            elif (runs and li < 0 and runs[-1][2] < 0
                    and o == runs[-1][0] + runs[-1][1] * d):
                runs[-1][1] += 1
            else:
                runs.append([o, 1, li])
        frozen = []
        for start, k, li in runs:
            whole = (li >= 0 and start == leaf_offsets[li]
                     and k * d == leaf_sizes[li])
            frozen.append((start, k, li if whole else -1))
        first = min((_first_touched_leaf(o, unit_leaf[u], leaf_offsets)
                     for u, o in zip(ids, offs)), default=0)
        ready = max(0, n_leaves - 1 - first)
        buckets.append(Bucket(dim=d, unit_ids=tuple(ids),
                              offsets=tuple(offs), runs=tuple(frozen),
                              ready=ready))
    return tuple(buckets)


@functools.lru_cache(maxsize=256)
def _build_plan(treedef, shapes: Tuple[Tuple[int, ...], ...],
                dtypes: Tuple[jnp.dtype, ...], marks: Tuple[bool, ...],
                gran: Granularity) -> UnitPlan:
    sizes = []
    for shape in shapes:
        n = 1
        for s in shape:
            n *= s
        sizes.append(n)
    total = sum(sizes)
    leaf_offsets = []
    off = 0
    for n in sizes:
        leaf_offsets.append(off)
        off += n

    exec_dims: List[int] = []
    offsets: List[int] = []
    unit_leaf: List[int] = []
    fold_base: List[int] = []
    fold_inner: List[int] = []
    fold_double: List[bool] = []

    if gran.kind == "entire_model":
        exec_dims, offsets, unit_leaf = [total], [0], [-1]
        fold_base, fold_inner, fold_double = [0], [0], [False]
        acct_dims = [total]
        exec_total = total
    elif gran.kind == "blockwise":
        b = gran.block_size
        nb = -(-total // b) if total else 0
        exec_dims = [b] * nb
        offsets = [i * b for i in range(nb)]
        unit_leaf = [-1] * nb
        fold_base = list(range(nb))
        fold_inner = [0] * nb
        fold_double = [False] * nb
        n_full, rem = divmod(total, b)
        acct_dims = [b] * n_full + ([rem] if rem else [])
        exec_total = nb * b
    else:  # layerwise
        uid = 0
        off = 0
        for li, (shape, size, stacked) in enumerate(zip(shapes, sizes,
                                                        marks)):
            if stacked and len(shape) >= 1 and shape[0] > 0:
                L = shape[0]
                d = size // L
                for i in range(L):
                    exec_dims.append(d)
                    offsets.append(off + i * d)
                    unit_leaf.append(li)
                    fold_base.append(uid)   # legacy: base folded at the
                    fold_inner.append(i)    # leaf's FIRST uid, then by row
                    fold_double.append(True)
                uid += L
            else:
                exec_dims.append(size)
                offsets.append(off)
                unit_leaf.append(li)
                fold_base.append(uid)
                fold_inner.append(0)
                fold_double.append(False)
                uid += 1
            off += size
        acct_dims = list(exec_dims)
        exec_total = total

    return UnitPlan(
        granularity=gran,
        treedef=treedef,
        leaf_shapes=shapes,
        leaf_dtypes=dtypes,
        total=total,
        exec_total=exec_total,
        unit_dims=tuple(acct_dims),
        exec_dims=tuple(exec_dims),
        unit_offsets=tuple(offsets),
        unit_leaf=tuple(unit_leaf),
        buckets=_make_buckets(exec_dims, offsets, unit_leaf,
                              leaf_offsets, sizes),
        fold_base=tuple(fold_base),
        fold_inner=tuple(fold_inner),
        fold_double=tuple(fold_double),
    )


def build_plan(tree, stacked, gran: Granularity) -> UnitPlan:
    """Build (or fetch the cached) UnitPlan for a gradient pytree.

    `tree` may hold arrays, tracers, or ShapeDtypeStructs — only static
    shape/dtype/structure is read, so this is free inside jit tracing
    (the cache key is (treedef, shapes, dtypes, stacked, granularity)).
    """
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    shapes = tuple(tuple(int(s) for s in l.shape) for l in leaves)
    dtypes = tuple(jnp.dtype(l.dtype) for l in leaves)
    marks = tuple(bool(m) for m in jax.tree_util.tree_leaves(stacked))
    if gran.kind == "layerwise" and len(marks) != len(leaves):
        raise ValueError(
            f"stacked mask has {len(marks)} leaves, tree has {len(leaves)}")
    if gran.kind != "layerwise":
        marks = (False,) * len(leaves)  # irrelevant: canonicalize cache key
    return _build_plan(treedef, shapes, dtypes, marks, gran)


def plan_unit_dims(tree, stacked, gran: Granularity) -> List[int]:
    """Accounting dims via the plan (== granularity.unit_dims)."""
    return list(build_plan(tree, stacked, gran).unit_dims)
