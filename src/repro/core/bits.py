"""Communicated-bits accounting for every (operator, granularity, strategy).

These are *analytic* wire sizes computed from static unit dimensions — the
numbers a deployment would actually put on the ICI links. The dry-run
roofline cross-checks them against the collective bytes parsed from HLO.

Payload (beta) bits alone cannot distinguish entire-model from layer-wise
from fused layer-wise communication: what separates them on real links is
the PER-MESSAGE latency (alpha) term — one message for the entire model,
one per unit for naive layer-wise, one per fusion buffer when scheduled.
`comm_report` therefore also reports `n_messages` (the wire-transaction
count) and, when `alpha_bits_per_message` is given, a latency line in
bit-equivalents so the alpha and beta terms add in one unit.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Union

from repro.core.aggregation import CompressionConfig
from repro.core.compressors import Compressor
from repro.core.plan import UnitPlan
from repro.core.schedule import CommSchedule, build_schedule


@dataclasses.dataclass(frozen=True)
class CommReport:
    strategy: str
    n_workers: int
    dense_bits: int              # uncompressed fp32 allreduce reference (per unit sum)
    uplink_bits_per_worker: int  # worker -> aggregation
    downlink_bits_per_worker: int  # aggregation -> worker
    compression_ratio: float     # dense / (up+down), payload only
    n_messages: int = 0          # wire transactions per step (alpha count)
    alpha_bits_per_message: int = 0  # per-message latency, bit-equivalents

    def total_bits_per_worker(self) -> int:
        return self.uplink_bits_per_worker + self.downlink_bits_per_worker

    def latency_bits(self) -> int:
        """The alpha term: n_messages x per-message latency cost."""
        return self.n_messages * self.alpha_bits_per_message

    def total_bits_with_latency(self) -> int:
        """Payload (beta) + latency (alpha) in one number — the quantity
        that actually orders entire-model vs layer-wise vs fused
        layer-wise on a real link."""
        return self.total_bits_per_worker() + self.latency_bits()


def _wire_bits(cfg: CompressionConfig) -> int:
    return 16 if cfg.wire_dtype == "bfloat16" else 32


def measured_bits_from_payloads(payloads) -> int:
    """The wire truth: 8x the byte count of REAL encoded buffers (uint8
    arrays, or any pytree of them). On bare per-unit payloads this
    equals accounted payload bits + the documented word-padding slack,
    exactly (the differential suite's subject). The fused message
    buffers from `CommSchedule.execute(..., wire=codec)` additionally
    carry their uint32 header table — 32*(1+n_buckets) bits per message,
    split out via `wire.message_layouts`."""
    import jax
    return sum(8 * int(leaf.size)
               for leaf in jax.tree_util.tree_leaves(payloads))


def comm_report(cfg: CompressionConfig,
                unit_dims: Union[UnitPlan, Sequence[int]],
                n_workers: int,
                schedule: Optional[CommSchedule] = None,
                alpha_bits_per_message: int = 0,
                measured: bool = False) -> CommReport:
    """Wire cost of one aggregation step.

    `cfg` is a CompressionConfig, or a control.policy.CompressionDecision
    (anything with `.to_config()`): a decision materializes its per-bucket
    ratio overrides as a per-dim compressor, so the reported bits track
    the ACTIVE per-bucket ratios rather than one global config.

    `unit_dims` is either the static per-unit dimension list or a UnitPlan
    (whose accounting dims are used — the canonical source once the engine
    has built its plan). Ring-allreduce reference: each worker
    sends+receives ~2·d elements.

    Message accounting: without a schedule the wire sees one message per
    unit (the unfused layer-wise reality the paper's timing discussion is
    about; entire-model is the 1-unit special case). With `schedule` —
    passed explicitly, or compiled automatically when `unit_dims` is a
    UnitPlan and the config carries `fusion_bytes` — `n_messages` is the
    fused message count. `alpha_bits_per_message` prices each message's
    latency in bit-equivalents (link alpha x bandwidth); it feeds
    `latency_bits()` / `total_bits_with_latency()` and never changes the
    payload fields.

    `measured=True` charges the compressed-payload legs the REAL packed
    wire size (core.wire codec bytes x 8 — exactly what a materialized
    payload measures) instead of the analytic `payload_bits`; the two
    differ only by the documented per-codec word-padding slack.
    """
    if hasattr(cfg, "to_config"):  # CompressionDecision (duck-typed: no
        cfg = cfg.to_config()      # core -> control import)
    if (schedule is None and isinstance(unit_dims, UnitPlan)
            and getattr(cfg, "fusion_bytes", None) is not None):
        schedule = build_schedule(unit_dims, cfg.fusion_bytes)
    if isinstance(unit_dims, UnitPlan):
        unit_dims = list(unit_dims.unit_dims)
    d_total = sum(unit_dims)
    dense_bits = 2 * 32 * d_total
    n_messages = (schedule.num_messages if schedule is not None
                  else len(unit_dims))

    if measured:
        from repro.core.wire import wire_codec
        bits_of = wire_codec(cfg.qw).wire_bits
    else:
        bits_of = cfg.qw.payload_bits

    w = _wire_bits(cfg)
    if cfg.strategy == "dense":
        up = down = w * d_total  # ring AR: d out + d in (per direction ~d)
    elif cfg.strategy == "simulated":
        # numerically compressed but the collective still moves dense grads
        up = down = w * d_total
    elif cfg.strategy == "allgather":
        payload = sum(bits_of(d) for d in unit_dims)
        up = payload                       # contribute own payload
        down = (n_workers - 1) * payload   # receive everyone else's
    elif cfg.strategy in ("rs_compress_ag", "rs_stream"):
        # reduce-scatter dense wire (d elems traverse once) + all-gather of
        # per-shard payloads. Bits are accounted on the TRUE d: the shard
        # partition is ceil(d/n) per worker with a short tail, so the
        # per-unit sizes min(ds, d - w*ds) sum exactly to d — the padded
        # capacity tail is masked out of encode (aggregation/wire) and
        # charged NOTHING here. (The legacy formula charged every worker
        # floor(d/n), which neither matched the wire nor the data for
        # non-divisible dims.) Per-worker figures are the exact worker
        # average of the true totals: own shard = ceil(total/n) on the
        # contribute leg, everyone else's = total - own on the receive
        # leg.
        payload_all = 0
        for d in unit_dims:
            ds = -(-d // n_workers)
            payload_all += sum(bits_of(min(ds, d - wk * ds))
                               for wk in range(n_workers)
                               if d - wk * ds > 0)
        own = -(-payload_all // n_workers)
        up = w * d_total + own
        down = payload_all - own
    elif cfg.strategy == "shared_random":
        kept = sum(max(1, int(round(cfg.qw.ratio * d))) for d in unit_dims)
        up = down = w * kept
    else:  # pragma: no cover
        raise ValueError(cfg.strategy)

    total = up + down
    return CommReport(cfg.strategy, n_workers, dense_bits, up, down,
                      dense_bits / max(1, total),
                      n_messages=n_messages,
                      alpha_bits_per_message=alpha_bits_per_message)
