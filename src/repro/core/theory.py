"""Executable versions of the paper's theory: Assumption 5 / Lemma 1 /
Lemma 2 / the Trace(A) vs L·max noise bound.

These power the property tests (tests/test_theory.py) and the §Repro section
of EXPERIMENTS.md: we *measure* Ω for every operator and *verify* the
layer-wise bound is tighter, which is the paper's Theorem-level claim.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compressors import Compressor

Array = jax.Array


def empirical_omega(comp: Compressor, x: Array, key: Array,
                    trials: int = 64) -> float:
    """Estimate Ω s.t. E‖Q(x)‖² = (1+Ω)‖x‖² by Monte-Carlo over Q's
    internal randomness (Assumption 5)."""
    xf = x.reshape(-1).astype(jnp.float32)
    denom = float(jnp.sum(xf * xf)) + 1e-30

    def one(k):
        q = comp.sim(xf, k)
        return jnp.sum(q * q)

    keys = jax.random.split(key, trials)
    sq = jax.vmap(one)(keys)
    return float(jnp.mean(sq)) / denom - 1.0


def empirical_descent_alignment(comp: Compressor, g: Array, key: Array,
                                trials: int = 64) -> float:
    """Estimate E[Q(g)ᵀ g] (Assumption 6 LHS with ∇f ≈ g)."""
    gf = g.reshape(-1).astype(jnp.float32)

    def one(k):
        return jnp.dot(comp.sim(gf, k), gf)

    keys = jax.random.split(key, trials)
    return float(jnp.mean(jax.vmap(one)(keys)))


def check_unbiasedness(comp: Compressor, x: Array, key: Array,
                       trials: int = 512) -> float:
    """Return relative error ‖E[Q(x)] − x‖ / ‖x‖ (→0 for unbiased ops)."""
    xf = x.reshape(-1).astype(jnp.float32)
    keys = jax.random.split(key, trials)
    mean = jnp.mean(jax.vmap(lambda k: comp.sim(xf, k))(keys), axis=0)
    return float(jnp.linalg.norm(mean - xf) / (jnp.linalg.norm(xf) + 1e-30))


def trace_A(omegas_w: Sequence[float], omegas_m: Sequence[float],
            dims: Sequence[int]) -> float:
    """Layer-wise noise factor: Trace(A) = Σ_j d_j·(1+Ω_M^j)(1+Ω_W^j)
    normalized by d (the paper states Trace(A)=Σ_j(1+Ω_M^j)(1+Ω_W^j) treating
    each layer block as one unit; we keep the dimension-weighted form which
    is what Trace of the d×d diagonal matrix A literally is)."""
    return float(sum(d * (1 + ow) * (1 + om)
                     for d, ow, om in zip(dims, omegas_w, omegas_m)))


def entire_model_bound(omegas_w: Sequence[float], omegas_m: Sequence[float],
                       dims: Sequence[int]) -> float:
    """Entire-model noise factor: d · max_j (1+Ω_M^j)(1+Ω_W^j)."""
    worst = max((1 + ow) * (1 + om)
                for ow, om in zip(omegas_w, omegas_m))
    return float(sum(dims) * worst)


def layerwise_tighter(omegas_w, omegas_m, dims) -> bool:
    """The paper's headline theoretical claim (§4, last paragraph)."""
    return trace_A(omegas_w, omegas_m, dims) <= entire_model_bound(
        omegas_w, omegas_m, dims) + 1e-9


def noise_bounds_from_plan(plan, comp_w: Optional[Compressor] = None,
                           comp_m: Optional[Compressor] = None, *,
                           measured_w: Optional[Sequence[float]] = None,
                           measured_m: Optional[Sequence[float]] = None
                           ) -> Tuple[float, float]:
    """(Trace(A), entire-model bound) for a UnitPlan's unit partition.

    Per-unit omegas come from the operators' closed forms, or — the
    adaptive-control path — from `measured_w` / `measured_m`: per-unit
    empirical estimates in plan unit order (control.telemetry's
    `unit_omegas`), which is how GranularitySwitchPolicy evaluates the
    paper's bound on live statistics instead of worst cases.

    The plan's accounting dims are the d_j of the paper's §4; this is the
    wire-level counterpart of comm_report reading plan.unit_dims. Raises
    if an operator has no closed-form Ω and no measurement is supplied.
    """
    dims = list(plan.unit_dims)

    def resolve(measured, comp, tag):
        if measured is not None:
            om = [float(o) for o in measured]
            if len(om) != len(dims):
                raise ValueError(
                    f"measured_{tag} has {len(om)} omegas, plan has "
                    f"{len(dims)} units")
            return om
        if comp is None:
            if tag == "w":  # no source for the worker omegas: fail loudly
                raise ValueError(
                    "provide comp_w or measured_w (a zero-noise worker "
                    "bound is never what you want)")
            return [0.0] * len(dims)
        om = [comp.omega(d) for d in dims]
        if any(o is None for o in om):
            raise ValueError(
                "operator has no closed-form Omega; measure empirical_omega "
                "per unit instead")
        return om

    ow = resolve(measured_w, comp_w, "w")
    om = resolve(measured_m, comp_m, "m")
    return (trace_A(ow, om, dims), entire_model_bound(ow, om, dims))


def lemma1_check(comp: Compressor, parts: List[Array], key: Array,
                 trials: int = 64) -> Tuple[float, float, float]:
    """Verify Lemma 1 numerically for the layer-wise operator built from
    `comp` applied to each part. Returns (E‖Q(x)‖², Σ_j(1+Ω_j)‖x_j‖²,
    max_j(1+Ω_j)·‖x‖²). The lemma asserts lhs ≤ mid ≤ rhs."""
    omegas = []
    for j, p in enumerate(parts):
        omegas.append(empirical_omega(comp, p, jax.random.fold_in(key, j),
                                      trials))
    # E‖Q(x)‖² with independent per-part randomness:
    def total(k):
        acc = 0.0
        for j, p in enumerate(parts):
            q = comp.sim(p.reshape(-1), jax.random.fold_in(k, j))
            acc = acc + jnp.sum(q * q)
        return acc
    keys = jax.random.split(key, trials)
    lhs = float(jnp.mean(jax.vmap(total)(keys)))
    mid = float(sum((1 + o) * float(jnp.sum(p.astype(jnp.float32) ** 2))
                    for o, p in zip(omegas, parts)))
    norm2 = float(sum(float(jnp.sum(p.astype(jnp.float32) ** 2))
                      for p in parts))
    rhs = max(1 + o for o in omegas) * norm2
    return lhs, mid, rhs
