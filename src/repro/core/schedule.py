"""CommSchedule: fused, backward-ordered streaming of compressed buckets.

A UnitPlan says *what* the compression units are and batches them into
per-size-class dispatches; it says nothing about *when* the wire sees each
one. Real frameworks do two things the plan alone does not model (Agarwal
et al., "On the Utility of Gradient Compression in Distributed Training
Systems"; Horovod fusion buffers; commfuser's fusion/scheduling passes):

  1. they launch communication for LATE layers while EARLY layers are
     still in backward (gradients arrive in reverse leaf order), and
  2. they FUSE small tensors into one wire message so per-message latency
     (the alpha term) is paid once per buffer, not once per tensor.

A `CommSchedule` compiles both decisions from a plan, statically:

  build_schedule(plan, fusion_bytes)
      -> order   : bucket indices by backward-readiness (Bucket.ready,
                   derived from the treedef's reverse leaf order)
      -> messages: consecutive ready buckets greedily packed until a
                   message's dense bytes reach `fusion_bytes`
                   (0 = one message per bucket; math.inf = one message)

and `schedule.execute(fn, grads, key)` runs the plan's per-bucket batched
dispatches message by message in that order, pinning program order with
`lax.optimization_barrier` so message i's compress -> collective ->
decompress pipeline is issued before message i+1's compression begins
(the streaming contract; XLA may still *overlap* them — the barrier only
forbids reordering message i+1's work ahead of message i's).

Numerical contract: scheduling NEVER changes numerics. Every bucket runs
the identical batched dispatch with the identical per-unit PRNG keys as
`UnitPlan.execute`; only program order differs, bucket outputs land in
disjoint regions, and the barrier is an identity — so the scheduled path
is bit-identical to the unscheduled one. tests/test_schedule.py holds
this property over the operator zoo x granularities x fusion thresholds.

`simulate_schedule` is the deterministic alpha-beta cost model: per
message, comm time = alpha + wire_bytes / bandwidth, overlapped against a
backward pass that emits leaves in reverse order and a sequential
compression stream. It reports exposed-vs-overlapped comm time. It is a
MODEL, not a measurement — wall-clocks on a shared container are noisy;
trust the message/dispatch counts and use the model for relative
comparisons (entire-model vs per-bucket vs fused) only.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.plan import Bucket, UnitPlan

Array = jax.Array

#: fusion_bytes sentinel: never close a message — everything fuses into one.
FUSE_ALL = math.inf

def register_barrier_batching_rule() -> None:
    """jax 0.4.x ships optimization_barrier with no batching rule;
    register the obvious pass-through (operands map 1:1 to outputs) so
    the barrier survives vmap — scheduled execution is vmapped by
    aggregate_simulated_workers, and models.model vmaps barriers in the
    simulated multi-worker grads. This is the ONE copy of the shim
    (models.model calls it too); idempotent, no-op on newer jax where
    the rule exists upstream."""
    try:
        from jax.interpreters import batching as _batching
        from jax._src.lax import lax as _lax_internal
        barrier_p = _lax_internal.optimization_barrier_p
        if barrier_p not in _batching.primitive_batchers:
            def _barrier_batch(args, dims, **params):
                return barrier_p.bind(*args, **params), dims
            _batching.primitive_batchers[barrier_p] = _barrier_batch
    except (ImportError, AttributeError):
        pass


register_barrier_batching_rule()


def _order_after(xs: List[Array], token: Optional[Array]) -> List[Array]:
    """Identity on `xs` that the compiler may not hoist above `token`
    (the previous message's output): one optimization_barrier tying them
    together. token=None (first message) is a no-op."""
    if token is None:
        return xs
    out = jax.lax.optimization_barrier(tuple(xs) + (token,))
    return list(out[:-1])


@dataclasses.dataclass(frozen=True)
class Message:
    """One wire message: a readiness-ordered group of fused buckets.

    `bucket_ids` index the plan's buckets (dispatch order inside the
    message). `nbytes` is the dense f32 payload the fusion decision was
    made on; `ready` the backward-readiness rank of the LAST bucket to
    become available (the message can only depart then).
    """
    bucket_ids: Tuple[int, ...]
    nbytes: int
    ready: int

    @property
    def n_buckets(self) -> int:
        return len(self.bucket_ids)


@dataclasses.dataclass(frozen=True)
class CommSchedule:
    """Static communication schedule for one (UnitPlan, fusion_bytes).

    Frozen + tuples (and a hashable UnitPlan) => hashable, so a schedule
    — like the plan it wraps — is a valid static argument under jit and a
    safe cache key (the controller's decision -> compiled-step cache keys
    on the decision's `fusion_bytes`, which resolves to one of these).
    """
    plan: UnitPlan
    fusion_bytes: float
    order: Tuple[int, ...]          # bucket indices, backward-ready first
    messages: Tuple[Message, ...]

    @property
    def num_messages(self) -> int:
        return len(self.messages)

    def summary(self) -> str:
        ms = ", ".join(f"{m.n_buckets}b/{m.nbytes >> 10}KiB"
                       for m in self.messages)
        fb = ("inf" if math.isinf(self.fusion_bytes)
              else f"{int(self.fusion_bytes)}")
        return (f"CommSchedule(fuse<{fb}B: {self.num_messages} messages "
                f"over {self.plan.num_dispatches} dispatches [{ms}])")

    # ---- execution -------------------------------------------------------
    def execute(self, fn: Callable[[Array, Array], Array], grads,
                key: Array, *, wire=None, wire_key=None, recorder=None,
                faults=None):
        """UnitPlan.execute, streamed: identical per-bucket dispatches and
        PRNG keys, issued message by message in backward-ready order with
        an ordering barrier between consecutive messages. Bit-identical
        output (the equivalence harness's subject).

        `wire` (a core.wire.WireCodec) switches to REAL wire buffers:
        each message's units are encoded to bit-packed payloads and
        concatenated into ONE uint8 buffer (header table of per-bucket
        byte offsets), decoding reads back out of the buffer, and the
        inter-message barrier pins on the buffer itself. In wire mode
        `fn` is the post-decode closure fn(payload_row, xhat_row,
        unit_key) -> y (None = return the decoded gradient), `wire_key`
        optionally transforms the unit key for the encode leg (the
        worker-key fold), and the return value is (tree, buffers) —
        sum(8 * b.size) over `buffers` is the measured wire truth.
        Because every codec round-trips bit-exactly to its compressor's
        `sim`, wire mode never changes numerics either.

        `recorder` (duck-typed, obs.trace.TraceRecorder) instruments the
        stream with per-message spans (or per-stage spans in wire mode);
        None or a disabled recorder leaves the traced graph untouched —
        the zero-overhead contract tests/test_obs.py compares jaxprs
        over.

        `faults` (duck-typed, resil.FaultInjector; wire mode only)
        corrupts each message's received bytes after pack — see
        core.wire.execute_schedule_wire."""
        if wire is not None:
            from repro.core.wire import execute_schedule_wire
            return execute_schedule_wire(self, wire, fn, grads, key,
                                         wire_key=wire_key,
                                         recorder=recorder, faults=faults)
        if faults is not None:
            raise ValueError("fault injection needs the wire path "
                             "(wire=codec): faults act on packed bytes")
        rec = (recorder if recorder is not None
               and getattr(recorder, "enabled", False) else None)
        plan = self.plan
        leaves = jax.tree_util.tree_leaves(grads)
        flat = plan.flatten(grads) if plan.needs_flat else None
        keys = plan.unit_keys(key)
        out_leaves = [None] * len(leaves)
        out_flat = (jnp.zeros((plan.exec_total,), jnp.float32)
                    if flat is not None else None)
        if rec is not None and leaves:
            rec.begin(leaves[0], label="grads_ready")
        token = None
        for mi, msg in enumerate(self.messages):
            ys: List[Tuple[Bucket, Array]] = []
            xs = [plan._gather_runs(leaves, flat, plan.buckets[bi])
                  for bi in msg.bucket_ids]
            xs = _order_after(xs, token)
            if rec is not None:
                with rec.scope(f"repro/msg{mi}"):
                    for bi, x in zip(msg.bucket_ids, xs):
                        b = plan.buckets[bi]
                        ys.append((b, plan._dispatch(fn, b, x, keys)))
                rec.mark([y for _, y in ys], "message", cat="message",
                         message=mi, bucket_ids=msg.bucket_ids,
                         dims=tuple(plan.buckets[bi].dim
                                    for bi in msg.bucket_ids),
                         n_units=sum(plan.buckets[bi].n
                                     for bi in msg.bucket_ids))
            else:
                for bi, x in zip(msg.bucket_ids, xs):
                    b = plan.buckets[bi]
                    ys.append((b, plan._dispatch(fn, b, x, keys)))
            token = ys[-1][1]
            for b, y in ys:
                out_flat = plan._scatter_runs(out_leaves, out_flat, b, y)
        return plan._assemble(out_leaves, out_flat)

    def execute_with_state(self, fn, grads, state, key: Array, *,
                           wire=None, wire_key=None, recorder=None,
                           faults=None):
        """UnitPlan.execute_with_state, streamed (error-feedback memory
        threads through untouched by ordering/fusion: every unit's state
        row is read and written exactly once, in whichever message its
        bucket landed).

        `wire` routes through real buffers exactly as in `execute`; the
        EF discipline is fixed to e = x + m, m' = e - decode(payload)
        (bit-identical to the unpacked path by the round-trip property),
        `fn` is the post-decode closure (or None), and the return value
        grows to (tree, m_tree, buffers). `faults` (wire mode only)
        corrupts received bytes; the EF residual stays sender-side
        clean — see core.wire.execute_schedule_wire_with_state."""
        if wire is not None:
            from repro.core.wire import execute_schedule_wire_with_state
            return execute_schedule_wire_with_state(
                self, wire, fn, grads, state, key, wire_key=wire_key,
                recorder=recorder, faults=faults)
        if faults is not None:
            raise ValueError("fault injection needs the wire path "
                             "(wire=codec): faults act on packed bytes")
        rec = (recorder if recorder is not None
               and getattr(recorder, "enabled", False) else None)
        plan = self.plan
        leaves = jax.tree_util.tree_leaves(grads)
        need = plan.needs_flat
        flat = plan.flatten(grads) if need else None
        mflat = plan.flatten(state) if need else None
        keys = plan.unit_keys(key)
        out_leaves = [None] * len(leaves)
        mout_leaves = [None] * len(leaves)
        out_flat = (jnp.zeros((plan.exec_total,), jnp.float32)
                    if need else None)
        mout_flat = (jnp.zeros((plan.exec_total,), jnp.float32)
                     if need else None)
        sleaves = jax.tree_util.tree_leaves(state)
        if rec is not None and leaves:
            rec.begin(leaves[0], label="grads_ready")
        token = None
        for mi, msg in enumerate(self.messages):
            pairs = []
            for bi in msg.bucket_ids:
                b = plan.buckets[bi]
                pairs.append(plan._gather_runs(leaves, flat, b))
                pairs.append(plan._gather_runs(sleaves, mflat, b))
            pairs = _order_after(pairs, token)
            ys = []
            if rec is not None:
                with rec.scope(f"repro/msg{mi}"):
                    for j, bi in enumerate(msg.bucket_ids):
                        b = plan.buckets[bi]
                        x, m = pairs[2 * j], pairs[2 * j + 1]
                        y, mn = plan._dispatch_with_state(fn, b, x, m,
                                                          keys)
                        ys.append((b, y, mn))
                rec.mark([y for _, y, _ in ys]
                         + [mn for _, _, mn in ys],
                         "message", cat="message", message=mi,
                         bucket_ids=msg.bucket_ids,
                         dims=tuple(plan.buckets[bi].dim
                                    for bi in msg.bucket_ids),
                         n_units=sum(plan.buckets[bi].n
                                     for bi in msg.bucket_ids))
            else:
                for j, bi in enumerate(msg.bucket_ids):
                    b = plan.buckets[bi]
                    x, m = pairs[2 * j], pairs[2 * j + 1]
                    y, mn = plan._dispatch_with_state(fn, b, x, m, keys)
                    ys.append((b, y, mn))
            token = ys[-1][1]
            for b, y, mn in ys:
                out_flat = plan._scatter_runs(out_leaves, out_flat, b, y)
                mout_flat = plan._scatter_runs(mout_leaves, mout_flat, b,
                                               mn)
        return (plan._assemble(out_leaves, out_flat),
                plan._assemble(mout_leaves, mout_flat))

    def execute_streaming(self, post, grads, key: Array, *, wire,
                          axis_names, n_workers: int, mode: str = "ring",
                          wire_key=None, chunk_bytes=None, recorder=None,
                          faults=None):
        """Execute the schedule through a REAL streaming collective: a
        chunked-ppermute ring (mode='ring') or a compress→reduce-scatter→
        allgather shard stream (mode='rs') under shard_map, double-
        buffered so message i+1's fused compress+pack kernels are emitted
        before message i's hops complete. Must run inside shard_map over
        a single DP axis. `wire` is the WireCodec; `post(xm_row,
        unit_key)` the master-compression closure applied to the
        cross-worker mean (None returns the mean); `chunk_bytes` the
        per-hop dispatch granularity (None = whole-message hops).
        Returns (tree, buffers). mode='ring' is bit-identical to
        `execute(..., wire=...)` under the allgather strategy — the
        correctness contract tests/test_stream.py holds differentially.
        See core.wire.execute_schedule_stream for the full mechanics
        (including `faults`, the per-hop corruption injector)."""
        from repro.core.wire import execute_schedule_stream
        return execute_schedule_stream(
            self, wire, post, grads, None, key, axis_names=axis_names,
            n_workers=n_workers, mode=mode, wire_key=wire_key,
            chunk_bytes=chunk_bytes, recorder=recorder, faults=faults)

    def execute_streaming_with_state(self, post, grads, state, key: Array,
                                     *, wire, axis_names, n_workers: int,
                                     mode: str = "ring", wire_key=None,
                                     chunk_bytes=None, recorder=None,
                                     faults=None):
        """Error-feedback twin of execute_streaming: e = x + m is
        encoded, m' = e - decode(own payload) — the same local EF
        discipline as the serialized wire path (EF never depends on the
        collective topology; under mode='rs' only the owned shard slice
        of each residual row is live). Returns (tree, m_tree,
        buffers)."""
        from repro.core.wire import execute_schedule_stream
        return execute_schedule_stream(
            self, wire, post, grads, state, key, axis_names=axis_names,
            n_workers=n_workers, mode=mode, wire_key=wire_key,
            chunk_bytes=chunk_bytes, recorder=recorder, faults=faults)


# ==========================================================================
# schedule construction
# ==========================================================================

@functools.lru_cache(maxsize=256)
def build_schedule(plan: UnitPlan, fusion_bytes: float) -> CommSchedule:
    """Compile the (cached) CommSchedule for a plan.

    Buckets are taken in backward-readiness order and greedily packed into
    messages Horovod-fusion-buffer style: a message accumulates buckets
    until its dense bytes reach `fusion_bytes`, then closes.

      fusion_bytes == 0        one message per bucket (no fusion; the wire
                               sees exactly the plan's dispatches)
      fusion_bytes == FUSE_ALL one message for everything (the
                               entire-model latency picture even when
                               compression stays layer-wise)

    Free (trace-time) like build_plan: pure Python on static metadata.
    """
    fb = float(fusion_bytes)
    if math.isnan(fb) or fb < 0:
        raise ValueError(f"fusion_bytes must be >= 0, got {fusion_bytes!r}")
    order = plan.readiness_order()
    messages: List[Message] = []
    cur: List[int] = []
    cur_bytes = 0
    cur_ready = 0
    for bi in order:
        b = plan.buckets[bi]
        cur.append(bi)
        cur_bytes += b.nbytes
        cur_ready = max(cur_ready, b.ready)
        if cur_bytes >= fb:
            messages.append(Message(tuple(cur), cur_bytes, cur_ready))
            cur, cur_bytes, cur_ready = [], 0, 0
    if cur:
        messages.append(Message(tuple(cur), cur_bytes, cur_ready))
    return CommSchedule(plan=plan, fusion_bytes=fb, order=order,
                        messages=tuple(messages))


# ==========================================================================
# alpha-beta cost model
# ==========================================================================

def message_wire_bits(schedule: CommSchedule, qw=None,
                      bucket_bits: Optional[Sequence[int]] = None
                      ) -> List[int]:
    """Per-message wire payload bits. With a compressor `qw`, each bucket
    contributes n_units * qw.payload_bits(dim) (the allgather-strategy
    payload); `bucket_bits` overrides with measured/externally-computed
    per-bucket bits (telemetry's view, e.g. under per-dim ratio
    overrides); with neither, dense f32."""
    plan = schedule.plan
    if bucket_bits is not None:
        if len(bucket_bits) != len(plan.buckets):
            raise ValueError(
                f"bucket_bits has {len(bucket_bits)} entries, plan has "
                f"{len(plan.buckets)} buckets")
        per_bucket = [int(v) for v in bucket_bits]
    elif qw is not None:
        per_bucket = [b.n * qw.payload_bits(b.dim) for b in plan.buckets]
    else:
        per_bucket = [32 * b.n * b.dim for b in plan.buckets]
    return [sum(per_bucket[bi] for bi in m.bucket_ids)
            for m in schedule.messages]


def simulate_schedule(schedule: CommSchedule, *, qw=None,
                      bucket_bits: Optional[Sequence[int]] = None,
                      alpha_us: float = 50.0, gbps: float = 12.5,
                      compress_gbps: float = 25.0,
                      backward_us: Optional[float] = None) -> Dict:
    """Deterministic alpha-beta pipeline simulation of one step's comm.

    Model (two streams, one network channel):

      * backward emits gradient leaves in reverse leaf order, uniformly
        over `backward_us` (default: 2x the time to stream the dense
        gradient at `compress_gbps` — a stand-in, not a measurement);
        message m's inputs are complete at backward_us*(ready+1)/n_leaves.
      * the compute stream compresses messages sequentially in schedule
        order: compress(m) = dense_bytes(m) / compress_gbps.
      * the network sends message m for alpha_us + wire_bytes(m)/gbps,
        starting when BOTH its compression is done and the previous
        message has left the wire.

    Returns totals + per-message timelines, including `exposed_comm_us`
    (comm time not hidden behind backward+compression) and
    `overlap_frac`. All numbers are MODEL outputs: on this container
    wall-clocks are too noisy to validate them — trust the message and
    dispatch counts, and use the model only for relative comparisons
    (entire-model vs per-bucket vs fused layer-wise).
    """
    plan = schedule.plan
    n_leaves = max(1, plan.num_leaves)
    dense_bytes = 4 * plan.exec_total
    if backward_us is None:
        backward_us = 2.0 * dense_bytes / (compress_gbps * 1e3)
    wire = message_wire_bits(schedule, qw=qw, bucket_bits=bucket_bits)

    msgs = []
    c = 0.0        # compute-stream head (compression)
    e = 0.0        # network-stream head
    comm_sum = 0.0
    for m, bits in zip(schedule.messages, wire):
        ready_us = backward_us * (m.ready + 1) / n_leaves
        c = max(c, ready_us) + m.nbytes / (compress_gbps * 1e3)
        send_us = alpha_us + (bits / 8.0) / (gbps * 1e3)
        start = max(c, e)
        e = start + send_us
        comm_sum += send_us
        msgs.append({"n_buckets": m.n_buckets, "dense_bytes": m.nbytes,
                     "wire_bits": bits, "ready_rank": m.ready,
                     "ready_us": round(ready_us, 3),
                     "compressed_us": round(c, 3),
                     "sent_us": round(e, 3)})
    compute_end = max(backward_us, c)
    total = max(e, compute_end)
    exposed = max(0.0, total - compute_end)
    return {
        "n_messages": schedule.num_messages,
        "n_dispatches": plan.num_dispatches,
        "fusion_bytes": (None if math.isinf(schedule.fusion_bytes)
                         else schedule.fusion_bytes),
        "alpha_us": alpha_us, "gbps": gbps,
        "compress_gbps": compress_gbps,
        "backward_us": round(backward_us, 3),
        "wire_bits_total": int(sum(wire)),
        "comm_us_total": round(comm_sum, 3),
        "t_total_us": round(total, 3),
        "exposed_comm_us": round(exposed, 3),
        "overlap_frac": round(1.0 - exposed / comm_sum, 4) if comm_sum
        else 1.0,
        "messages": msgs,
    }
