"""Pallas TPU kernel: fused RMSNorm (rowwise) — the per-token hot spot
shared by every assigned architecture."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_R = 64


def _rmsnorm_kernel(x_ref, g_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(ms + eps) * g).astype(o_ref.dtype)


def rmsnorm_pallas(x: jax.Array, gamma: jax.Array, eps: float = 1e-5,
                   *, interpret: bool = True) -> jax.Array:
    """x (R, D) rows normalized over D (D multiple of 128)."""
    R, D = x.shape
    assert R % BLOCK_R == 0 and D % 128 == 0, (R, D)
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(R // BLOCK_R,),
        in_specs=[pl.BlockSpec((BLOCK_R, D), lambda i: (i, 0)),
                  pl.BlockSpec((1, D), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((BLOCK_R, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, D), x.dtype),
        interpret=interpret,
    )(x, gamma.reshape(1, D))
