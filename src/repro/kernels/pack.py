"""Pallas TPU kernels: bit-vector <-> uint32-word packing.

The hot inner loop of every wire codec (core/wire.py) is turning a {0,1}
bit stream into dense uint32 words and back — b-bit quantization levels,
1-bit signs, index records all reduce to it. Pure VPU work: a (rows, 512)
bit tile packs into (rows, 16) words per grid step via one weighted-sum
reduction (bit i of a row lands in word i//32 at position i%32 —
little-endian bit order, the layout `kernels/ref.pack_bits_ref` oracles
and the jnp fallback reproduce bit for bit).

Tiling: 512 bit columns (4 lane groups of 128) so the input side is
lane-aligned; PACK_R rows per grid step. The 16-word output tile is
narrower than one lane group — fine under interpret mode (this CPU
container) and acceptable on TPU since the output is 32x smaller than the
input stream it compresses.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import ref

PACK_R = 8             # rows per grid step (sublane multiple)
PACK_C = 512           # bit columns per row (lane multiple)
WORDS_PER_ROW = PACK_C // 32


def _pack_kernel(b_ref, o_ref):
    b = b_ref[...]                                   # (R, 512) int32 {0,1}
    rows = b.shape[0]
    w = b.reshape(rows, WORDS_PER_ROW, 32).astype(jnp.uint32)
    weights = jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32)
    o_ref[...] = (w * weights).sum(axis=-1).astype(jnp.uint32)


def _unpack_kernel(w_ref, o_ref):
    w = w_ref[...]                                   # (R, 16) uint32
    rows = w.shape[0]
    bits = (w[..., None] >> jnp.arange(32, dtype=jnp.uint32)) & jnp.uint32(1)
    o_ref[...] = bits.reshape(rows, PACK_C).astype(jnp.int32)


def pack_bits_pallas(bits: jax.Array, *, interpret: bool = True) -> jax.Array:
    """(R, 512) {0,1} int32 with R % PACK_R == 0 -> (R, 16) uint32 words."""
    R, C = bits.shape
    assert R % PACK_R == 0 and C == PACK_C, (R, C)
    return pl.pallas_call(
        _pack_kernel,
        grid=(R // PACK_R,),
        in_specs=[pl.BlockSpec((PACK_R, PACK_C), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((PACK_R, WORDS_PER_ROW), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, WORDS_PER_ROW), jnp.uint32),
        interpret=interpret,
    )(bits)


def unpack_bits_pallas(words: jax.Array, *,
                       interpret: bool = True) -> jax.Array:
    """(R, 16) uint32 with R % PACK_R == 0 -> (R, 512) {0,1} int32."""
    R, W = words.shape
    assert R % PACK_R == 0 and W == WORDS_PER_ROW, (R, W)
    return pl.pallas_call(
        _unpack_kernel,
        grid=(R // PACK_R,),
        in_specs=[pl.BlockSpec((PACK_R, WORDS_PER_ROW), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((PACK_R, PACK_C), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, PACK_C), jnp.int32),
        interpret=interpret,
    )(words)


# --------------------------------------------------------------------------
# width-parametric field packing: (R, 512) int32 width-bit fields ->
# (R, 16*width) uint32 words in ONE launch (no {0,1} bit intermediate —
# each 32-field chunk becomes exactly `width` words with compile-time
# shifts, see kernels/ref.pack_fields_tile). This is the single-launch
# pack leg the natural (9-bit) and sparse-index (ceil(log2 d)-bit) codecs
# use; qsgd/terngrad/sign fuse their quantizers in front of the same tile
# packer (kernels/{qsgd,terngrad,sign}.py).
# --------------------------------------------------------------------------

def _fields_pack_kernel(f_ref, o_ref, *, width: int):
    o_ref[...] = ref.pack_fields_tile(f_ref[...], width)


def _fields_unpack_kernel(w_ref, o_ref, *, width: int):
    o_ref[...] = ref.unpack_fields_tile(w_ref[...], width)


def fields_pack_pallas(fields: jax.Array, width: int, *,
                       interpret: bool = True) -> jax.Array:
    """(R, 512) int32 fields (values < 2**width; R % PACK_R == 0) ->
    (R, 16*width) uint32 words."""
    R, C = fields.shape
    assert R % PACK_R == 0 and C == PACK_C, (R, C)
    wpr = (C // 32) * width
    return pl.pallas_call(
        functools.partial(_fields_pack_kernel, width=width),
        grid=(R // PACK_R,),
        in_specs=[pl.BlockSpec((PACK_R, PACK_C), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((PACK_R, wpr), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, wpr), jnp.uint32),
        interpret=interpret,
    )(fields)


def fields_unpack_pallas(words: jax.Array, width: int, *,
                         interpret: bool = True) -> jax.Array:
    """(R, 16*width) uint32 -> (R, 512) int32 fields. Inverse of
    fields_pack_pallas."""
    R, W = words.shape
    wpr = (PACK_C // 32) * width
    assert R % PACK_R == 0 and W == wpr, (R, W, width)
    return pl.pallas_call(
        functools.partial(_fields_unpack_kernel, width=width),
        grid=(R // PACK_R,),
        in_specs=[pl.BlockSpec((PACK_R, wpr), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((PACK_R, PACK_C), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, PACK_C), jnp.int32),
        interpret=interpret,
    )(words)
