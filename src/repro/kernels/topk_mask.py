"""Pallas TPU kernel: block-local Top-k masking via threshold bisection.

Exact global Top-k needs a global sort — a poor fit for the TPU memory
hierarchy. Instead each (row-block) keeps its own top-k by magnitude,
finding the k-th magnitude with a fixed 24-step bisection over
[0, rowmax] (pure VPU compare/reduce per step, no sort, no gather).

Block-local Top-k is a FINER partition than layer-wise — Lemma 1 of the
paper covers any partition, so the convergence theory transfers verbatim
(this is the 'block-wise' granularity in core.granularity).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_R = 8            # rows per grid step; each ROW is one top-k unit
BLOCK_C = 512
ITERS = 24


def _topk_kernel(x_ref, o_ref, *, k: int):
    x = x_ref[...]
    mag = jnp.abs(x)
    hi = jnp.max(mag, axis=-1, keepdims=True)
    lo = jnp.zeros_like(hi)

    def body(i, carry):
        lo, hi = carry
        thr = 0.5 * (lo + hi)
        cnt = jnp.sum((mag >= thr).astype(jnp.int32), axis=-1,
                      keepdims=True)
        pred = cnt > k
        return jnp.where(pred, thr, lo), jnp.where(pred, hi, thr)

    lo, hi = jax.lax.fori_loop(0, ITERS, body, (lo, hi))
    o_ref[...] = x * (mag >= lo).astype(x.dtype)


def topk_mask_pallas(x: jax.Array, k: int, *, interpret: bool = True
                     ) -> jax.Array:
    """x (R, C): per-row top-k mask. R % BLOCK_R == 0, C == BLOCK_C."""
    R, C = x.shape
    assert R % BLOCK_R == 0 and C == BLOCK_C, (R, C)
    return pl.pallas_call(
        functools.partial(_topk_kernel, k=k),
        grid=(R // BLOCK_R,),
        in_specs=[pl.BlockSpec((BLOCK_R, BLOCK_C), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((BLOCK_R, BLOCK_C), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, C), x.dtype),
        interpret=interpret,
    )(x)
