"""jit'd public wrappers around the Pallas kernels.

Handle padding/reshaping from arbitrary flat gradients to the kernels'
(rows, 512) tiled layout, generate the stochastic-rounding uniforms, and
fall back to the pure-jnp reference when pallas is disabled. On this CPU
container the kernels run with interpret=True (body executed in Python —
correctness only); on TPU set REPRO_PALLAS_INTERPRET=0.
"""
from __future__ import annotations

import os
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.qsgd import (BLOCK_C, BLOCK_R, qsgd_pallas,
                                qsgd_pallas_rows)
from repro.kernels.rmsnorm import rmsnorm_pallas
from repro.kernels.terngrad import terngrad_pallas, terngrad_pallas_rows
from repro.kernels.topk_mask import topk_mask_pallas

Array = jax.Array


def _interpret() -> bool:
    return os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


def _tile(x: Array):
    """flat (d,) -> padded (R, 512) with R % BLOCK_R == 0."""
    d = x.size
    cols = BLOCK_C
    rows = -(-d // cols)
    rows = -(-rows // BLOCK_R) * BLOCK_R
    pad = rows * cols - d
    xt = jnp.pad(x.reshape(-1), (0, pad)).reshape(rows, cols)
    return xt, d


def _untile(xt: Array, d: int, shape) -> Array:
    return xt.reshape(-1)[:d].reshape(shape)


@partial(jax.jit, static_argnames=("levels", "use_pallas"))
def qsgd_compress(x: Array, key: Array, levels: int = 16,
                  use_pallas: bool = True) -> Array:
    """Fused QSGD quantize+dequantize over the WHOLE input (the caller
    picks the granularity unit, per the paper)."""
    xf = x.astype(jnp.float32)
    norm = jnp.linalg.norm(xf.reshape(-1))
    xt, d = _tile(xf)
    noise = jax.random.uniform(key, xt.shape)
    if use_pallas:
        out = qsgd_pallas(xt, noise, norm, levels, interpret=_interpret())
    else:
        out = ref.qsgd_ref(xt, noise, norm, levels)
    return _untile(out, d, x.shape).astype(x.dtype)


@partial(jax.jit, static_argnames=("use_pallas",))
def terngrad_compress(x: Array, key: Array, use_pallas: bool = True) -> Array:
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf))
    xt, d = _tile(xf)
    noise = jax.random.uniform(key, xt.shape)
    if use_pallas:
        out = terngrad_pallas(xt, noise, scale, interpret=_interpret())
    else:
        out = ref.terngrad_ref(xt, noise, scale)
    return _untile(out, d, x.shape).astype(x.dtype)


@partial(jax.jit, static_argnames=("k_per_block", "use_pallas"))
def blockwise_topk(x: Array, k_per_block: int,
                   use_pallas: bool = True) -> Array:
    """Block-local top-k mask: each 512-element row keeps its k largest
    magnitudes (the 'blockwise' granularity of core.granularity, realized
    natively on TPU tiles)."""
    xf = x.astype(jnp.float32)
    xt, d = _tile(xf)
    if use_pallas:
        out = topk_mask_pallas(xt, k_per_block, interpret=_interpret())
    else:
        out = ref.topk_mask_ref(xt, k_per_block)
    return _untile(out, d, x.shape).astype(x.dtype)


# --------------------------------------------------------------------------
# UnitPlan bucket entry points: a bucket matrix (n_units, d) compresses in
# ONE kernel dispatch. Each unit's statistic (l2 norm / max) is computed per
# row, tiled into the kernels' (R, 512) layout alongside the data, and the
# per-row-scale kernel variants consume it — the batched counterpart of the
# scalar-statistic wrappers above.
# --------------------------------------------------------------------------

def _tile_units(x2d: Array):
    """(n, d) bucket -> ((R, 512) tiles, live_rows, tile_rows_per_unit)."""
    n, d = x2d.shape
    rpu = -(-d // BLOCK_C)
    xp = jnp.pad(x2d, ((0, 0), (0, rpu * BLOCK_C - d)))
    rows = n * rpu
    R = -(-rows // BLOCK_R) * BLOCK_R
    xt = jnp.pad(xp.reshape(rows, BLOCK_C), ((0, R - rows), (0, 0)))
    return xt, rows, rpu


def _unit_noise(keys: Array, n: int, rpu: int, R: int) -> Array:
    """Per-unit uniforms over the padded tile span, one key per unit."""
    noise = jax.vmap(
        lambda k: jax.random.uniform(k, (rpu * BLOCK_C,)))(keys)
    return jnp.pad(noise.reshape(n * rpu, BLOCK_C), ((0, R - n * rpu),
                                                     (0, 0)))


def _row_scales(stat: Array, rpu: int, R: int) -> Array:
    """(n,) per-unit statistic -> (R, 1) per-tile-row scale column."""
    rows = stat.shape[0] * rpu
    s = jnp.repeat(stat, rpu)
    return jnp.pad(s, (0, R - rows), constant_values=1.0)[:, None]


@partial(jax.jit, static_argnames=("levels", "use_pallas"))
def qsgd_compress_units(x2d: Array, keys: Array, levels: int = 16,
                        use_pallas: bool = True) -> Array:
    """Fused QSGD over a whole bucket: rows of `x2d` are compression units
    (each with its own l2 norm), `keys` one PRNG key per unit. One Pallas
    dispatch regardless of the number of units."""
    xf = x2d.astype(jnp.float32)
    n, d = xf.shape
    norms = jnp.linalg.norm(xf, axis=1)
    xt, rows, rpu = _tile_units(xf)
    R = xt.shape[0]
    noise = _unit_noise(keys, n, rpu, R)
    scales = _row_scales(norms, rpu, R)
    if use_pallas:
        out = qsgd_pallas_rows(xt, noise, scales, levels,
                               interpret=_interpret())
    else:
        out = ref.qsgd_ref(xt, noise, scales, levels)  # (R,1) broadcasts
    return out[:rows].reshape(n, rpu * BLOCK_C)[:, :d].astype(x2d.dtype)


@partial(jax.jit, static_argnames=("use_pallas",))
def terngrad_compress_units(x2d: Array, keys: Array,
                            use_pallas: bool = True) -> Array:
    """Fused TernGrad over a whole bucket (per-row max scale)."""
    xf = x2d.astype(jnp.float32)
    n, d = xf.shape
    scales_u = jnp.max(jnp.abs(xf), axis=1)
    xt, rows, rpu = _tile_units(xf)
    R = xt.shape[0]
    noise = _unit_noise(keys, n, rpu, R)
    scales = _row_scales(scales_u, rpu, R)
    if use_pallas:
        out = terngrad_pallas_rows(xt, noise, scales,
                                   interpret=_interpret())
    else:
        out = ref.terngrad_ref(xt, noise, scales)
    return out[:rows].reshape(n, rpu * BLOCK_C)[:, :d].astype(x2d.dtype)


_UNIT_KERNELS = {
    "qsgd": lambda x, k, kw: qsgd_compress_units(
        x, k, kw.get("levels", 16), kw.get("use_pallas", True)),
    "terngrad": lambda x, k, kw: terngrad_compress_units(
        x, k, kw.get("use_pallas", True)),
}


def plan_compress(plan, grads, key: Array, kind: str = "qsgd", **kw):
    """Compress a gradient pytree through the Pallas kernels, driven by a
    core.plan.UnitPlan: gather each bucket, ONE fused kernel dispatch per
    bucket, scatter back.

    The per-unit PRNG KEYS come from the plan's fold tables (same keys as
    the jnp execution path), but the uniform draws differ: the kernel
    wrappers draw noise over the padded (rows, 512) tile span, while
    Compressor.sim draws exactly d uniforms — so outputs are the same
    operator family with the same per-unit statistics, NOT bit-identical
    to plan.execute(comp.sim, ...)."""
    if kind not in _UNIT_KERNELS:
        raise ValueError(f"no bucket kernel for {kind!r}; "
                         f"have {sorted(_UNIT_KERNELS)}")
    run = _UNIT_KERNELS[kind]
    flat = plan.flatten(grads)
    keys = plan.unit_keys(key)
    out = jnp.zeros((plan.exec_total,), jnp.float32)
    for b in plan.buckets:
        x = plan.gather_bucket(flat, b)
        kb = keys[jnp.asarray(b.unit_ids, jnp.int32)]
        out = plan.scatter_bucket(out, b, run(x, kb, kw))
    return plan.unflatten(out)


# --------------------------------------------------------------------------
# bit-vector <-> uint32-word packing (the wire codecs' hot inner loop)
# --------------------------------------------------------------------------

def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


@partial(jax.jit, static_argnames=("use_pallas",))
def pack_words(bits: Array, use_pallas: bool = False) -> Array:
    """{0,1} int32 flat bit vector (n,) -> uint32 words (ceil(n/32),).

    Bit i lands in word i//32 at position i%32 (little-endian bit order).
    `use_pallas=False` (the default — safe under vmap, which is how wire
    codecs run inside bucket dispatches) packs with the pure-jnp oracle;
    `use_pallas=True` tiles to (rows, 512) and runs the kernels/pack.py
    word-packing kernel.
    """
    n = bits.shape[0]
    if n == 0:
        return jnp.zeros((0,), jnp.uint32)
    W = _cdiv(n, 32)
    if use_pallas:
        from repro.kernels.pack import PACK_C, PACK_R, pack_bits_pallas
        rows = _cdiv(_cdiv(n, PACK_C), PACK_R) * PACK_R
        bt = jnp.pad(bits.astype(jnp.int32),
                     (0, rows * PACK_C - n)).reshape(rows, PACK_C)
        words = pack_bits_pallas(bt, interpret=_interpret()).reshape(-1)
    else:
        pad = (-n) % 32
        bt = jnp.pad(bits.astype(jnp.int32), (0, pad)).reshape(-1, 32)
        words = ref.pack_bits_ref(bt).reshape(-1)
    return words[:W]


@partial(jax.jit, static_argnames=("n", "use_pallas"))
def unpack_words(words: Array, n: int, use_pallas: bool = False) -> Array:
    """uint32 words -> the first `n` bits as a {0,1} int32 vector.
    Inverse of pack_words (same bit order, same pallas/jnp switch)."""
    if n == 0:
        return jnp.zeros((0,), jnp.int32)
    if use_pallas:
        from repro.kernels.pack import (PACK_R, WORDS_PER_ROW,
                                        unpack_bits_pallas)
        W = words.shape[0]
        rows = _cdiv(_cdiv(W, WORDS_PER_ROW), PACK_R) * PACK_R
        wt = jnp.pad(words, (0, rows * WORDS_PER_ROW - W)).reshape(
            rows, WORDS_PER_ROW)
        bits = unpack_bits_pallas(wt, interpret=_interpret()).reshape(-1)
    else:
        bits = ref.unpack_bits_ref(words.reshape(-1, 1)).reshape(-1)
    return bits[:n]


@partial(jax.jit, static_argnames=("eps", "use_pallas"))
def rmsnorm(x: Array, gamma: Array, eps: float = 1e-5,
            use_pallas: bool = True) -> Array:
    """(..., D) rowwise RMSNorm with D % 128 == 0."""
    shape = x.shape
    D = shape[-1]
    xr = x.reshape(-1, D)
    R = xr.shape[0]
    from repro.kernels.rmsnorm import BLOCK_R as NR
    pad = (-R) % NR
    xp = jnp.pad(xr, ((0, pad), (0, 0)))
    if use_pallas:
        out = rmsnorm_pallas(xp, gamma, eps, interpret=_interpret())
    else:
        out = ref.rmsnorm_ref(xp, gamma, eps)
    return out[:R].reshape(shape)
