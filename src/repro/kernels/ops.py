"""jit'd public wrappers around the Pallas kernels.

Handle padding/reshaping from arbitrary flat gradients to the kernels'
(rows, 512) tiled layout, generate the stochastic-rounding uniforms, and
fall back to the pure-jnp reference when pallas is disabled. On this CPU
container the kernels run with interpret=True (body executed in Python —
correctness only); on TPU set REPRO_PALLAS_INTERPRET=0.
"""
from __future__ import annotations

import os
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.qsgd import BLOCK_C, BLOCK_R, qsgd_pallas
from repro.kernels.rmsnorm import rmsnorm_pallas
from repro.kernels.terngrad import terngrad_pallas
from repro.kernels.topk_mask import topk_mask_pallas

Array = jax.Array


def _interpret() -> bool:
    return os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


def _tile(x: Array):
    """flat (d,) -> padded (R, 512) with R % BLOCK_R == 0."""
    d = x.size
    cols = BLOCK_C
    rows = -(-d // cols)
    rows = -(-rows // BLOCK_R) * BLOCK_R
    pad = rows * cols - d
    xt = jnp.pad(x.reshape(-1), (0, pad)).reshape(rows, cols)
    return xt, d


def _untile(xt: Array, d: int, shape) -> Array:
    return xt.reshape(-1)[:d].reshape(shape)


@partial(jax.jit, static_argnames=("levels", "use_pallas"))
def qsgd_compress(x: Array, key: Array, levels: int = 16,
                  use_pallas: bool = True) -> Array:
    """Fused QSGD quantize+dequantize over the WHOLE input (the caller
    picks the granularity unit, per the paper)."""
    xf = x.astype(jnp.float32)
    norm = jnp.linalg.norm(xf.reshape(-1))
    xt, d = _tile(xf)
    noise = jax.random.uniform(key, xt.shape)
    if use_pallas:
        out = qsgd_pallas(xt, noise, norm, levels, interpret=_interpret())
    else:
        out = ref.qsgd_ref(xt, noise, norm, levels)
    return _untile(out, d, x.shape).astype(x.dtype)


@partial(jax.jit, static_argnames=("use_pallas",))
def terngrad_compress(x: Array, key: Array, use_pallas: bool = True) -> Array:
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf))
    xt, d = _tile(xf)
    noise = jax.random.uniform(key, xt.shape)
    if use_pallas:
        out = terngrad_pallas(xt, noise, scale, interpret=_interpret())
    else:
        out = ref.terngrad_ref(xt, noise, scale)
    return _untile(out, d, x.shape).astype(x.dtype)


@partial(jax.jit, static_argnames=("k_per_block", "use_pallas"))
def blockwise_topk(x: Array, k_per_block: int,
                   use_pallas: bool = True) -> Array:
    """Block-local top-k mask: each 512-element row keeps its k largest
    magnitudes (the 'blockwise' granularity of core.granularity, realized
    natively on TPU tiles)."""
    xf = x.astype(jnp.float32)
    xt, d = _tile(xf)
    if use_pallas:
        out = topk_mask_pallas(xt, k_per_block, interpret=_interpret())
    else:
        out = ref.topk_mask_ref(xt, k_per_block)
    return _untile(out, d, x.shape).astype(x.dtype)


@partial(jax.jit, static_argnames=("eps", "use_pallas"))
def rmsnorm(x: Array, gamma: Array, eps: float = 1e-5,
            use_pallas: bool = True) -> Array:
    """(..., D) rowwise RMSNorm with D % 128 == 0."""
    shape = x.shape
    D = shape[-1]
    xr = x.reshape(-1, D)
    R = xr.shape[0]
    from repro.kernels.rmsnorm import BLOCK_R as NR
    pad = (-R) % NR
    xp = jnp.pad(xr, ((0, pad), (0, 0)))
    if use_pallas:
        out = rmsnorm_pallas(xp, gamma, eps, interpret=_interpret())
    else:
        out = ref.rmsnorm_ref(xp, gamma, eps)
    return out[:R].reshape(shape)
