"""jit'd public wrappers around the Pallas kernels.

Handle padding/reshaping from arbitrary flat gradients to the kernels'
(rows, 512) tiled layout, generate the stochastic-rounding uniforms, and
fall back to the pure-jnp reference when pallas is disabled. On this CPU
container the kernels run with interpret=True (body executed in Python —
correctness only); on TPU set REPRO_PALLAS_INTERPRET=0.
"""
from __future__ import annotations

import os
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import prng, ref
from repro.kernels.pack import PACK_R, fields_pack_pallas, fields_unpack_pallas
from repro.kernels.qsgd import (BLOCK_C, BLOCK_R, qsgd_pack_pallas_rows,
                                qsgd_pallas, qsgd_pallas_rows,
                                qsgd_unpack_pallas_rows)
from repro.kernels.rmsnorm import rmsnorm_pallas
from repro.kernels.sign import (MAJ_C, majority_pallas, sign_pack_pallas_rows,
                                sign_unpack_pallas_rows)
from repro.kernels.terngrad import (terngrad_pack_pallas_rows,
                                    terngrad_pallas, terngrad_pallas_rows,
                                    terngrad_unpack_pallas_rows)
from repro.kernels.topk_mask import topk_mask_pallas

Array = jax.Array


def _interpret() -> bool:
    return os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


def _tile(x: Array):
    """flat (d,) -> padded (R, 512) with R % BLOCK_R == 0."""
    d = x.size
    cols = BLOCK_C
    rows = -(-d // cols)
    rows = -(-rows // BLOCK_R) * BLOCK_R
    pad = rows * cols - d
    xt = jnp.pad(x.reshape(-1), (0, pad)).reshape(rows, cols)
    return xt, d


def _untile(xt: Array, d: int, shape) -> Array:
    return xt.reshape(-1)[:d].reshape(shape)


@partial(jax.jit, static_argnames=("levels", "use_pallas"))
def qsgd_compress(x: Array, key: Array, levels: int = 16,
                  use_pallas: bool = True) -> Array:
    """Fused QSGD quantize+dequantize over the WHOLE input (the caller
    picks the granularity unit, per the paper)."""
    xf = x.astype(jnp.float32)
    norm = jnp.linalg.norm(xf.reshape(-1))
    xt, d = _tile(xf)
    noise = jax.random.uniform(key, xt.shape)
    if use_pallas:
        out = qsgd_pallas(xt, noise, norm, levels, interpret=_interpret())
    else:
        out = ref.qsgd_ref(xt, noise, norm, levels)
    return _untile(out, d, x.shape).astype(x.dtype)


@partial(jax.jit, static_argnames=("use_pallas",))
def terngrad_compress(x: Array, key: Array, use_pallas: bool = True) -> Array:
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf))
    xt, d = _tile(xf)
    noise = jax.random.uniform(key, xt.shape)
    if use_pallas:
        out = terngrad_pallas(xt, noise, scale, interpret=_interpret())
    else:
        out = ref.terngrad_ref(xt, noise, scale)
    return _untile(out, d, x.shape).astype(x.dtype)


@partial(jax.jit, static_argnames=("k_per_block", "use_pallas"))
def blockwise_topk(x: Array, k_per_block: int,
                   use_pallas: bool = True) -> Array:
    """Block-local top-k mask: each 512-element row keeps its k largest
    magnitudes (the 'blockwise' granularity of core.granularity, realized
    natively on TPU tiles)."""
    xf = x.astype(jnp.float32)
    xt, d = _tile(xf)
    if use_pallas:
        out = topk_mask_pallas(xt, k_per_block, interpret=_interpret())
    else:
        out = ref.topk_mask_ref(xt, k_per_block)
    return _untile(out, d, x.shape).astype(x.dtype)


# --------------------------------------------------------------------------
# UnitPlan bucket entry points: a bucket matrix (n_units, d) compresses in
# ONE kernel dispatch. Each unit's statistic (l2 norm / max) is computed per
# row, tiled into the kernels' (R, 512) layout alongside the data, and the
# per-row-scale kernel variants consume it — the batched counterpart of the
# scalar-statistic wrappers above.
# --------------------------------------------------------------------------

def _tile_units(x2d: Array):
    """(n, d) bucket -> ((R, 512) tiles, live_rows, tile_rows_per_unit)."""
    n, d = x2d.shape
    rpu = -(-d // BLOCK_C)
    xp = jnp.pad(x2d, ((0, 0), (0, rpu * BLOCK_C - d)))
    rows = n * rpu
    R = -(-rows // BLOCK_R) * BLOCK_R
    xt = jnp.pad(xp.reshape(rows, BLOCK_C), ((0, R - rows), (0, 0)))
    return xt, rows, rpu


def _unit_noise(keys: Array, n: int, rpu: int, R: int) -> Array:
    """Per-unit uniforms over the padded tile span, one key per unit."""
    noise = jax.vmap(
        lambda k: jax.random.uniform(k, (rpu * BLOCK_C,)))(keys)
    return jnp.pad(noise.reshape(n * rpu, BLOCK_C), ((0, R - n * rpu),
                                                     (0, 0)))


def _row_scales(stat: Array, rpu: int, R: int) -> Array:
    """(n,) per-unit statistic -> (R, 1) per-tile-row scale column."""
    rows = stat.shape[0] * rpu
    s = jnp.repeat(stat, rpu)
    return jnp.pad(s, (0, R - rows), constant_values=1.0)[:, None]


@partial(jax.jit, static_argnames=("levels", "use_pallas"))
def qsgd_compress_units(x2d: Array, keys: Array, levels: int = 16,
                        use_pallas: bool = True) -> Array:
    """Fused QSGD over a whole bucket: rows of `x2d` are compression units
    (each with its own l2 norm), `keys` one PRNG key per unit. One Pallas
    dispatch regardless of the number of units."""
    xf = x2d.astype(jnp.float32)
    n, d = xf.shape
    norms = jnp.linalg.norm(xf, axis=1)
    xt, rows, rpu = _tile_units(xf)
    R = xt.shape[0]
    noise = _unit_noise(keys, n, rpu, R)
    scales = _row_scales(norms, rpu, R)
    if use_pallas:
        out = qsgd_pallas_rows(xt, noise, scales, levels,
                               interpret=_interpret())
    else:
        out = ref.qsgd_ref(xt, noise, scales, levels)  # (R,1) broadcasts
    return out[:rows].reshape(n, rpu * BLOCK_C)[:, :d].astype(x2d.dtype)


@partial(jax.jit, static_argnames=("use_pallas",))
def terngrad_compress_units(x2d: Array, keys: Array,
                            use_pallas: bool = True) -> Array:
    """Fused TernGrad over a whole bucket (per-row max scale)."""
    xf = x2d.astype(jnp.float32)
    n, d = xf.shape
    scales_u = jnp.max(jnp.abs(xf), axis=1)
    xt, rows, rpu = _tile_units(xf)
    R = xt.shape[0]
    noise = _unit_noise(keys, n, rpu, R)
    scales = _row_scales(scales_u, rpu, R)
    if use_pallas:
        out = terngrad_pallas_rows(xt, noise, scales,
                                   interpret=_interpret())
    else:
        out = ref.terngrad_ref(xt, noise, scales)
    return out[:rows].reshape(n, rpu * BLOCK_C)[:, :d].astype(x2d.dtype)


_UNIT_KERNELS = {
    "qsgd": lambda x, k, kw: qsgd_compress_units(
        x, k, kw.get("levels", 16), kw.get("use_pallas", True)),
    "terngrad": lambda x, k, kw: terngrad_compress_units(
        x, k, kw.get("use_pallas", True)),
}


def plan_compress(plan, grads, key: Array, kind: str = "qsgd", **kw):
    """Compress a gradient pytree through the Pallas kernels, driven by a
    core.plan.UnitPlan: gather each bucket, ONE fused kernel dispatch per
    bucket, scatter back.

    The per-unit PRNG KEYS come from the plan's fold tables (same keys as
    the jnp execution path), but the uniform draws differ: the kernel
    wrappers draw noise over the padded (rows, 512) tile span, while
    Compressor.sim draws exactly d uniforms — so outputs are the same
    operator family with the same per-unit statistics, NOT bit-identical
    to plan.execute(comp.sim, ...)."""
    if kind not in _UNIT_KERNELS:
        raise ValueError(f"no bucket kernel for {kind!r}; "
                         f"have {sorted(_UNIT_KERNELS)}")
    run = _UNIT_KERNELS[kind]
    flat = plan.flatten(grads)
    keys = plan.unit_keys(key)
    out = jnp.zeros((plan.exec_total,), jnp.float32)
    for b in plan.buckets:
        x = plan.gather_bucket(flat, b)
        kb = keys[jnp.asarray(b.unit_ids, jnp.int32)]
        out = plan.scatter_bucket(out, b, run(x, kb, kw))
    return plan.unflatten(out)


# --------------------------------------------------------------------------
# bit-vector <-> uint32-word packing (the wire codecs' hot inner loop)
# --------------------------------------------------------------------------

def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


@partial(jax.jit, static_argnames=("use_pallas",))
def pack_words(bits: Array, use_pallas: bool = False) -> Array:
    """{0,1} int32 flat bit vector (n,) -> uint32 words (ceil(n/32),).

    Bit i lands in word i//32 at position i%32 (little-endian bit order).
    `use_pallas=False` (the default — safe under vmap, which is how wire
    codecs run inside bucket dispatches) packs with the pure-jnp oracle;
    `use_pallas=True` tiles to (rows, 512) and runs the kernels/pack.py
    word-packing kernel.
    """
    n = bits.shape[0]
    if n == 0:
        return jnp.zeros((0,), jnp.uint32)
    W = _cdiv(n, 32)
    if use_pallas:
        from repro.kernels.pack import PACK_C, PACK_R, pack_bits_pallas
        rows = _cdiv(_cdiv(n, PACK_C), PACK_R) * PACK_R
        bt = jnp.pad(bits.astype(jnp.int32),
                     (0, rows * PACK_C - n)).reshape(rows, PACK_C)
        words = pack_bits_pallas(bt, interpret=_interpret()).reshape(-1)
    else:
        pad = (-n) % 32
        bt = jnp.pad(bits.astype(jnp.int32), (0, pad)).reshape(-1, 32)
        words = ref.pack_bits_ref(bt).reshape(-1)
    return words[:W]


@partial(jax.jit, static_argnames=("n", "use_pallas"))
def unpack_words(words: Array, n: int, use_pallas: bool = False) -> Array:
    """uint32 words -> the first `n` bits as a {0,1} int32 vector.
    Inverse of pack_words (same bit order, same pallas/jnp switch)."""
    if n == 0:
        return jnp.zeros((0,), jnp.int32)
    if use_pallas:
        from repro.kernels.pack import (PACK_R, WORDS_PER_ROW,
                                        unpack_bits_pallas)
        W = words.shape[0]
        rows = _cdiv(_cdiv(W, WORDS_PER_ROW), PACK_R) * PACK_R
        wt = jnp.pad(words, (0, rows * WORDS_PER_ROW - W)).reshape(
            rows, WORDS_PER_ROW)
        bits = unpack_bits_pallas(wt, interpret=_interpret()).reshape(-1)
    else:
        bits = ref.unpack_bits_ref(words.reshape(-1, 1)).reshape(-1)
    return bits[:n]


# --------------------------------------------------------------------------
# fused single-launch compress+pack ops (the wire hot path).
#
# A bucket matrix (n, d) becomes packed uint32 payload words in ONE kernel
# launch per bucket: quantize + word-pack fused, stochastic-rounding
# uniforms generated in-kernel from per-row threefry key columns
# (kernels/prng.py, bit-exact to jax.random), the {0,1} bit tensor of the
# legacy quantize -> bit-expand -> word-pack pipeline never materialized.
# The jnp fallbacks run the IDENTICAL arithmetic (same tile helpers from
# kernels/ref.py) outside pallas_call, so payloads are byte-identical on
# both paths. Decode mirrors encode; the *_unpack_ef variants chain the
# single decode launch with the error-feedback residual m = e - xhat
# formed in the CALLER's regime — in-kernel the CPU backend's LLVM
# fp-contraction turns the mul+sub into an FMA through every JAX-level
# barrier, silently changing the low bits the EF discipline is pinned to.
# --------------------------------------------------------------------------

def words_per_unit(d: int, width: int) -> int:
    """uint32 payload words of one unit's packed field leg."""
    return _cdiv(d * width, 32)


def _tile_rows(x2d: Array, row_mult: int):
    """(n, d) bucket -> ((R, 512) tiles, live_rows, rows_per_unit), with
    R % row_mult == 0 (the pack kernels use row_mult=PACK_R=8, far tighter
    than the legacy BLOCK_R=256 padding)."""
    n, d = x2d.shape
    rpu = _cdiv(d, BLOCK_C)
    xp = jnp.pad(x2d, ((0, 0), (0, rpu * BLOCK_C - d)))
    rows = n * rpu
    R = _cdiv(rows, row_mult) * row_mult
    xt = jnp.pad(xp.reshape(rows, BLOCK_C), ((0, R - rows), (0, 0)))
    return xt, rows, rpu


def _tile_word_rows(w2d: Array, width: int, rpu: int, row_mult: int):
    """(n, words_per_unit) payload words -> (R, 16*width) word tiles."""
    n, wpu = w2d.shape
    wpr = (BLOCK_C // 32) * width
    wp = jnp.pad(w2d, ((0, 0), (0, rpu * wpr - wpu)))
    rows = n * rpu
    R = _cdiv(rows, row_mult) * row_mult
    return jnp.pad(wp.reshape(rows, wpr), ((0, R - rows), (0, 0))), rows


def _untile_words(wt: Array, n: int, rows: int, wpu: int) -> Array:
    """(R, 16*width) word tiles -> (n, words_per_unit), dropping the
    in-unit word padding and the row padding."""
    return wt[:rows].reshape(n, -1)[:, :wpu]


def _untile_rows(xt: Array, n: int, rows: int, d: int) -> Array:
    return xt[:rows].reshape(n, -1)[:, :d]


def _unit_col(v: Array, rpu: int, R: int) -> Array:
    """(n,) per-unit value -> (R, 1) per-tile-row column (repeat rpu,
    zero-pad the dead rows)."""
    s = jnp.repeat(v, rpu)
    return jnp.pad(s, (0, R - s.shape[0]))[:, None]


def _key_cols(keys: Array, rpu: int, R: int):
    """Per-unit PRNG keys (typed or raw uint32 (n, 2)) -> two (R, 1)
    uint32 key-word columns for the in-kernel threefry."""
    if jnp.issubdtype(keys.dtype, jax.dtypes.prng_key):
        kd = jax.random.key_data(keys)
    else:
        kd = keys
    kd = kd.astype(jnp.uint32)
    return (_unit_col(kd[:, 0], rpu, R), _unit_col(kd[:, 1], rpu, R))


def _tile_positions(R: int, rpu: int) -> Array:
    """In-unit flat position of every (row, lane) of a full tile — the
    jnp-fallback twin of the kernels' _row_positions."""
    row = jnp.arange(R, dtype=jnp.int32)[:, None]
    col = jnp.arange(BLOCK_C, dtype=jnp.int32)[None, :]
    return (row % rpu) * BLOCK_C + col


def qsgd_pack_units(x2d: Array, keys: Array, levels: int, width: int,
                    use_pallas: bool = True):
    """Fused QSGD encode of a whole bucket: (n, d) f32 + per-unit keys ->
    ((n, words_per_unit(d, width)) uint32 payload words, (n,) f32 norms).

    The norms already include the compressor's +1e-12 and are EXACTLY the
    payload norm field; words are byte-identical to the legacy
    quantize -> offset-code -> bit-expand -> pack pipeline."""
    xf = x2d.astype(jnp.float32)
    n, d = xf.shape
    nrms = jnp.linalg.norm(xf, axis=1) + 1e-12
    xt, rows, rpu = _tile_rows(xf, PACK_R)
    R = xt.shape[0]
    k0, k1 = _key_cols(keys, rpu, R)
    nc = _unit_col(nrms, rpu, R)
    if use_pallas:
        wt = qsgd_pack_pallas_rows(xt, k0, k1, nc, levels, width,
                                   d=d, rpu=rpu, interpret=_interpret())
    else:
        pos = _tile_positions(R, rpu)
        u = prng.uniform_at(k0, k1, pos, d)
        codes = jnp.where(pos < d,
                          ref.qsgd_codes_ref(xt, u, nc, levels), 0)
        wt = ref.pack_fields_tile(codes, width)
    return _untile_words(wt, n, rows, words_per_unit(d, width)), nrms


def qsgd_unpack_units(words: Array, nrms: Array, d: int, levels: int,
                      width: int, use_pallas: bool = True) -> Array:
    """Fused QSGD decode: (n, words_per_unit) uint32 + payload norms ->
    (n, d) f32 — unpack + dequantize in one launch."""
    n = words.shape[0]
    rpu = _cdiv(d, BLOCK_C)
    wt, rows = _tile_word_rows(words, width, rpu, PACK_R)
    # divide in the CALLER's regime (ref.qsgd_decode_ref explains why)
    fc = _unit_col(nrms.astype(jnp.float32) / levels, rpu, wt.shape[0])
    if use_pallas:
        xt = qsgd_unpack_pallas_rows(wt, fc, levels, width,
                                     interpret=_interpret())
    else:
        codes = ref.unpack_fields_tile(wt, width)
        xt = ref.qsgd_decode_ref(codes, fc, levels)
    return _untile_rows(xt, n, rows, d)


def qsgd_unpack_ef_units(words: Array, nrms: Array, e2d: Array, d: int,
                         levels: int, width: int, use_pallas: bool = True):
    """QSGD decode + error-feedback: ONE unpack+dequantize launch, then
    the residual m = e - xhat formed in the caller's regime -> (xhat, m).

    The subtract deliberately stays OUTSIDE the kernel: LLVM fp-contraction
    on the CPU backend fuses an in-kernel mul+sub into an FMA through
    every JAX-expressible barrier (optimization_barrier, bitcast
    laundering, fast-math flags — all verified ineffective), which flips
    the residual's low bits versus the two-step rounding the wire EF
    discipline (core/wire.py with-state path) is bitwise-pinned to."""
    xhat = qsgd_unpack_units(words, nrms, d, levels, width, use_pallas)
    return xhat, e2d.astype(jnp.float32) - xhat


def terngrad_pack_units(x2d: Array, keys: Array, use_pallas: bool = True):
    """Fused TernGrad encode: (n, d) f32 + per-unit keys -> ((n,
    words_per_unit(d, 2)) uint32 words, (n,) f32 scales incl. +1e-12)."""
    xf = x2d.astype(jnp.float32)
    n, d = xf.shape
    scales = jnp.max(jnp.abs(xf), axis=1) + 1e-12
    xt, rows, rpu = _tile_rows(xf, PACK_R)
    R = xt.shape[0]
    k0, k1 = _key_cols(keys, rpu, R)
    sc = _unit_col(scales, rpu, R)
    if use_pallas:
        wt = terngrad_pack_pallas_rows(xt, k0, k1, sc, d=d, rpu=rpu,
                                       interpret=_interpret())
    else:
        pos = _tile_positions(R, rpu)
        u = prng.uniform_at(k0, k1, pos, d)
        codes = jnp.where(pos < d, ref.terngrad_codes_ref(xt, u, sc), 0)
        wt = ref.pack_fields_tile(codes, 2)
    return _untile_words(wt, n, rows, words_per_unit(d, 2)), scales


def terngrad_unpack_units(words: Array, scales: Array, d: int,
                          use_pallas: bool = True) -> Array:
    """Fused TernGrad decode: words + payload scales -> (n, d) f32."""
    n = words.shape[0]
    rpu = _cdiv(d, BLOCK_C)
    wt, rows = _tile_word_rows(words, 2, rpu, PACK_R)
    sc = _unit_col(scales.astype(jnp.float32), rpu, wt.shape[0])
    if use_pallas:
        xt = terngrad_unpack_pallas_rows(wt, sc, interpret=_interpret())
    else:
        xt = ref.terngrad_decode_ref(ref.unpack_fields_tile(wt, 2), sc)
    return _untile_rows(xt, n, rows, d)


def terngrad_unpack_ef_units(words: Array, scales: Array, e2d: Array,
                             d: int, use_pallas: bool = True):
    """TernGrad decode + EF residual (caller-regime subtract, see
    qsgd_unpack_ef_units for the fp-contraction rationale) -> (xhat, m)."""
    xhat = terngrad_unpack_units(words, scales, d, use_pallas)
    return xhat, e2d.astype(jnp.float32) - xhat


def sign_pack_units(x2d: Array, use_pallas: bool = True) -> Array:
    """Fused signSGD encode: (n, d) f32 -> (n, words_per_unit(d, 1))
    uint32 sign words (bit = x >= 0). No statistic, no randomness."""
    xf = x2d.astype(jnp.float32)
    n, d = xf.shape
    xt, rows, rpu = _tile_rows(xf, PACK_R)
    if use_pallas:
        wt = sign_pack_pallas_rows(xt, d=d, rpu=rpu,
                                   interpret=_interpret())
    else:
        pos = _tile_positions(xt.shape[0], rpu)
        codes = jnp.where(pos < d, ref.sign_codes_ref(xt), 0)
        wt = ref.pack_fields_tile(codes, 1)
    return _untile_words(wt, n, rows, words_per_unit(d, 1))


def sign_unpack_units(words: Array, d: int,
                      use_pallas: bool = True) -> Array:
    """Fused signSGD decode: sign words -> (n, d) f32 in {-1, +1}."""
    n = words.shape[0]
    rpu = _cdiv(d, BLOCK_C)
    wt, rows = _tile_word_rows(words, 1, rpu, PACK_R)
    if use_pallas:
        xt = sign_unpack_pallas_rows(wt, interpret=_interpret())
    else:
        xt = ref.sign_decode_ref(ref.unpack_fields_tile(wt, 1))
    return _untile_rows(xt, n, rows, d)


def sign_unpack_ef_units(words: Array, e2d: Array, d: int,
                         use_pallas: bool = True):
    """signSGD decode + EF residual (caller-regime subtract, see
    qsgd_unpack_ef_units for the fp-contraction rationale) -> (xhat, m)."""
    xhat = sign_unpack_units(words, d, use_pallas)
    return xhat, e2d.astype(jnp.float32) - xhat


def fields_pack_units(f2d: Array, width: int,
                      use_pallas: bool = True) -> Array:
    """Generic word-wise field packing of a bucket: (n, k) int32 fields
    (values < 2**width) -> (n, words_per_unit(k, width)) uint32 words,
    each unit's leg separately word-padded (the wire padding rule). The
    single-launch pack leg of the natural and sparse-index codecs."""
    n, k = f2d.shape
    ft, rows, rpu = _tile_rows(f2d.astype(jnp.int32), PACK_R)
    pos = _tile_positions(ft.shape[0], rpu)
    ft = jnp.where(pos < k, ft, 0)                   # zero word padding
    if use_pallas:
        wt = fields_pack_pallas(ft, width, interpret=_interpret())
    else:
        wt = ref.pack_fields_tile(ft, width)
    return _untile_words(wt, n, rows, words_per_unit(k, width))


def fields_unpack_units(words: Array, k: int, width: int,
                        use_pallas: bool = True) -> Array:
    """Inverse of fields_pack_units -> (n, k) int32."""
    n = words.shape[0]
    rpu = _cdiv(k, BLOCK_C)
    wt, rows = _tile_word_rows(words, width, rpu, PACK_R)
    if use_pallas:
        ft = fields_unpack_pallas(wt, width, interpret=_interpret())
    else:
        ft = ref.unpack_fields_tile(wt, width)
    return _untile_rows(ft, n, rows, k)


def pack_fields(vals: Array, width: int,
                use_pallas: bool = False) -> Array:
    """(k,) int32 fields -> (ceil(k*width/32),) uint32 words via WORD-WISE
    shifts — the jnp fallback of the wire codecs' field legs. Replaces the
    legacy bit-expansion path (ref.pack_fields_bitexpand_ref, kept as the
    byte-identity oracle), whose k*width int32 bit tensor was a 32x
    memory inflation even with Pallas off."""
    return fields_pack_units(vals[None], width, use_pallas=use_pallas)[0]


def unpack_fields(words: Array, k: int, width: int,
                  use_pallas: bool = False) -> Array:
    """Inverse of pack_fields -> int32 (k,)."""
    return fields_unpack_units(words[None], k, width,
                               use_pallas=use_pallas)[0]


def majority_words(words2d: Array, use_pallas: bool = False) -> Array:
    """(n_workers, W) uint32 packed sign words -> (W,) majority-vote
    words (ties -> +1), computed DIRECTLY on the packed words via
    bit-sliced ripple-carry counting — the {0,1} bit tensor never exists
    (kernels/ref.majority_words_ref is the arithmetic on both paths)."""
    n, W = words2d.shape
    if use_pallas:
        Wp = _cdiv(W, MAJ_C) * MAJ_C
        wp = jnp.pad(words2d, ((0, 0), (0, Wp - W)))
        return majority_pallas(wp, interpret=_interpret())[:W]
    return ref.majority_words_ref(words2d)


# --------------------------------------------------------------------------
# chunk-granular dispatch (the streaming collective's unit of wire motion)
# --------------------------------------------------------------------------

def chunk_runs(sizes, chunk_bytes):
    """Partition consecutive payload regions into dispatch chunks.

    `sizes` are per-region byte counts (one fused message's per-bucket
    payload regions, in buffer order); the return value is a tuple of
    runs — tuples of region indices — covering 0..len(sizes)-1 in order.
    A run accumulates consecutive regions until its bytes reach
    `chunk_bytes`, then closes (the same greedy rule build_schedule uses
    for message fusion, one level down). `chunk_bytes` None or inf means
    one chunk for the whole message; 0 means one chunk per region. A
    single region larger than the threshold still gets its own chunk —
    regions are never split, so every chunk decodes with whole-bucket
    pack/unpack dispatches (chunk boundaries align with bucket regions,
    which is what lets the streaming executor decode each chunk the hop
    it arrives).
    """
    sizes = [int(s) for s in sizes]
    if not sizes:
        return ()
    if chunk_bytes is None or chunk_bytes != chunk_bytes or \
            chunk_bytes == float("inf"):
        return (tuple(range(len(sizes))),)
    cb = float(chunk_bytes)
    if cb < 0:
        raise ValueError(f"chunk_bytes must be >= 0, got {chunk_bytes!r}")
    runs, cur, cur_bytes = [], [], 0
    for i, s in enumerate(sizes):
        cur.append(i)
        cur_bytes += s
        if cur_bytes >= cb:
            runs.append(tuple(cur))
            cur, cur_bytes = [], 0
    if cur:
        runs.append(tuple(cur))
    return tuple(runs)


# --------------------------------------------------------------------------
# bytes-moved accounting (from the kernel specs, NOT wall-clocks: on this
# interpret-mode container microseconds measure Python, so BENCH artifacts
# gate on deterministic traffic counts — the repo's standing convention).
# --------------------------------------------------------------------------

def pack_bytes_moved(width: int, fused: bool, stochastic: bool = True):
    """Per-ELEMENT memory traffic of one bucket encode, from the kernel
    specs. Fused: the single launch reads the f32 tile (+ the per-row
    key/statistic columns, 12 bytes per 512-lane row) and writes width/8
    payload bytes; nothing else exists. Legacy (three-pass): quantize
    writes + re-reads an int32 code vector, bit-expansion writes +
    re-reads a width*4-byte {0,1} int32 tensor per element — the 32x
    inflation the fused path deletes. The per-unit statistic reduction
    (norm / max|x|) reads the input once on BOTH paths and is reported
    separately as stat_read so the kernel-proper gate stays honest."""
    cols = 12 if stochastic else 0                   # k0,k1,stat per row
    if fused:
        return {
            "read_bytes_per_elt": 4.0 + cols / BLOCK_C,
            "write_bytes_per_elt": width / 8.0,
            "intermediate_bytes_per_elt": 0.0,
            "stat_read_bytes_per_elt": 4.0 if stochastic else 0.0,
            "passes_over_data": 1,
            "launches_per_bucket": 1,
        }
    # legacy compose-of-passes: quantize -> {0,1} bit-expand -> word-pack
    inter = 4.0 + width * 4.0                        # codes + bit tensor
    return {
        "read_bytes_per_elt": 4.0 + inter,
        "write_bytes_per_elt": width / 8.0 + inter,
        "intermediate_bytes_per_elt": inter,
        "stat_read_bytes_per_elt": 4.0 if stochastic else 0.0,
        "passes_over_data": 3,
        "launches_per_bucket": 3,
    }


def unpack_bytes_moved(width: int, fused: bool, ef: bool = False):
    """Per-element decode traffic: fused reads width/8 payload bytes and
    writes the 4-byte f32 in ONE launch; legacy re-materializes the {0,1}
    bit tensor then the code vector before dequantizing. EF adds the
    residual pass m = e - xhat, which on BOTH paths runs in the caller's
    regime (re-read xhat + read e, write m — fp-contraction forbids an
    in-kernel subtract, see the *_unpack_ef_units docstrings), so the
    fused EF decode is 1 kernel launch + 1 elementwise pass."""
    if fused:
        return {
            "read_bytes_per_elt": width / 8.0 + (8.0 if ef else 0.0),
            "write_bytes_per_elt": 4.0 + (4.0 if ef else 0.0),
            "intermediate_bytes_per_elt": 0.0,
            "passes_over_data": 1 + (1 if ef else 0),
            "launches_per_bucket": 1,
        }
    inter = 4.0 + width * 4.0
    return {
        "read_bytes_per_elt": width / 8.0 + inter + (8.0 if ef else 0.0),
        "write_bytes_per_elt": 4.0 + inter + (4.0 if ef else 0.0),
        "intermediate_bytes_per_elt": inter,
        "passes_over_data": 3 + (1 if ef else 0),
        "launches_per_bucket": 3,
    }


def count_pallas_calls(fn, *args, **kwargs) -> int:
    """Number of pallas_call equations in fn's jaxpr (recursively) — the
    dispatch count BENCH_kernels.json records per op."""
    jaxpr = jax.make_jaxpr(lambda *a: fn(*a, **kwargs))(*args)

    def walk(jx) -> int:
        total = 0
        for eqn in jx.eqns:
            if "pallas_call" in eqn.primitive.name:
                total += 1
            for v in eqn.params.values():
                objs = v if isinstance(v, (tuple, list)) else (v,)
                for o in objs:
                    inner = getattr(o, "jaxpr", None)
                    if inner is not None:
                        total += walk(inner)
        return total

    return walk(jaxpr.jaxpr)


@partial(jax.jit, static_argnames=("eps", "use_pallas"))
def rmsnorm(x: Array, gamma: Array, eps: float = 1e-5,
            use_pallas: bool = True) -> Array:
    """(..., D) rowwise RMSNorm with D % 128 == 0."""
    shape = x.shape
    D = shape[-1]
    xr = x.reshape(-1, D)
    R = xr.shape[0]
    from repro.kernels.rmsnorm import BLOCK_R as NR
    pad = (-R) % NR
    xp = jnp.pad(xr, ((0, pad), (0, 0)))
    if use_pallas:
        out = rmsnorm_pallas(xp, gamma, eps, interpret=_interpret())
    else:
        out = ref.rmsnorm_ref(xp, gamma, eps)
    return out[:R].reshape(shape)
