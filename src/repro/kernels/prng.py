"""Elementwise threefry2x32 — jax.random.uniform, reproduced in-kernel.

The fused compress+pack kernels must draw the SAME stochastic-rounding
uniforms as `Compressor._quantize` (which calls jax.random.uniform /
jax.random.bernoulli) or their payloads stop being byte-identical to the
legacy three-pass wire path. jax.random can't be called inside a Pallas
kernel body, but its threefry2x32 generator is 20 rounds of uint32
add/xor/rotate — pure VPU work — so we reproduce it here as elementwise
jnp ops usable both inside kernel bodies and as a jit-able oracle.

`uniform_at(k0, k1, pos, n)` returns `jax.random.uniform(key, (n,))[pos]`
BIT-exactly (tests/test_fused_kernels.py pins this against jax itself,
so a jax upgrade that changes the generator fails loudly instead of
silently corrupting payload identity). The positional form is what a
tiled kernel needs: each (row, lane) knows its flat position inside the
compression unit and evaluates only its own counter pair.

Counter layout (jax's non-partitionable threefry path): a length-n draw
evaluates threefry2x32(key, [0..n-1] zero-padded to even length, split
into half-arrays x1/x2), so position p < h := ceil(n/2) is output word 0
of the pair (p, p+h) — with the odd-n pad folding the last x2 slot to 0
— and position p >= h is output word 1 of the pair (p-h, p).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

_ROTATIONS = ((13, 15, 26, 6), (17, 29, 16, 24))
_PARITY = np.uint32(0x1BD11BDA)
_ONE_F32 = np.uint32(0x3F800000)


def _rotl(x: Array, r: int) -> Array:
    return (x << np.uint32(r)) | (x >> np.uint32(32 - r))


def threefry2x32(k0: Array, k1: Array, x0: Array, x1: Array):
    """20-round threefry2x32 on broadcastable uint32 arrays — the exact
    arithmetic of jax's threefry2x32 primitive."""
    ks = (k0, k1, k0 ^ k1 ^ _PARITY)
    x0 = x0 + ks[0]
    x1 = x1 + ks[1]
    for i in range(5):
        for r in _ROTATIONS[i % 2]:
            x0 = x0 + x1
            x1 = _rotl(x1, r) ^ x0
        x0 = x0 + ks[(i + 1) % 3]
        x1 = x1 + ks[(i + 2) % 3] + np.uint32(i + 1)
    return x0, x1


def random_bits_at(k0: Array, k1: Array, pos: Array, n: int) -> Array:
    """Bits of jax.random.bits(key, (n,))[pos] for uint32 keys (k0, k1).

    `pos` int32/uint32, any shape (values >= n are computed but
    meaningless — mask them downstream); `n` the static draw length.
    """
    p = pos.astype(jnp.uint32)
    h = np.uint32((n + 1) // 2)
    first = p < h
    j = jnp.where(first, p, p - h)
    # the odd-n zero pad occupies the last x2 slot
    x2 = jnp.where(h + j < np.uint32(n), h + j, np.uint32(0))
    o1, o2 = threefry2x32(k0, k1, j, x2)
    return jnp.where(first, o1, o2)


def bits_to_uniform(bits: Array) -> Array:
    """uint32 bits -> f32 uniforms in [0, 1), jax.random.uniform's exact
    mantissa construction: (bits >> 9 | 0x3F800000) as float, minus 1."""
    fb = (bits >> np.uint32(9)) | _ONE_F32
    u = jax.lax.bitcast_convert_type(fb, jnp.float32) - 1.0
    return jnp.maximum(jnp.float32(0.0), u)


def uniform_at(k0: Array, k1: Array, pos: Array, n: int) -> Array:
    """jax.random.uniform(key, (n,))[pos], bit for bit, elementwise."""
    return bits_to_uniform(random_bits_at(k0, k1, pos, n))
