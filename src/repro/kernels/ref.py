"""Pure-jnp oracles for the compression kernels.

Each oracle is bit-compatible with its Pallas kernel given the same uniform
noise: the kernels are deterministic functions of (x, noise, params).
Shapes here are the kernels' canonical 2-D tiled layout (rows, 128·m).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array
_EPS = 1e-12


def qsgd_ref(x: Array, noise: Array, norm: Array, levels: int) -> Array:
    """Fused QSGD quantize+dequantize against a unit-level l2 norm.

    x (R, C) f32; noise (R, C) uniforms in [0,1); norm scalar f32.
    q_i = norm * sign(x_i) * floor(|x_i|/norm * s + u_i) / s
    """
    n = jnp.maximum(norm, _EPS)
    y = jnp.abs(x) / n * levels
    lev = jnp.floor(y + noise)
    return jnp.sign(x) * lev * (n / levels)


def terngrad_ref(x: Array, noise: Array, scale: Array) -> Array:
    """TernGrad quantize+dequantize: b_i ~ Bernoulli(|x_i|/scale);
    out = scale * sign(x) * b."""
    s = jnp.maximum(scale, _EPS)
    b = (noise < jnp.abs(x) / s).astype(x.dtype)
    return jnp.sign(x) * b * s


def topk_mask_ref(x: Array, k: int, iters: int = 24) -> Array:
    """Block-local top-k by magnitude via threshold bisection (per ROW).

    Keeps the elements with |x| >= thr where thr is the bisection estimate
    of the k-th largest magnitude (count(|x| >= thr) >= k >= count(> thr)).
    Identical arithmetic to the Pallas kernel: 'iters' halvings of
    [0, rowmax]. Ties at the threshold may keep slightly more than k.
    """
    mag = jnp.abs(x)
    hi = jnp.max(mag, axis=-1, keepdims=True)
    lo = jnp.zeros_like(hi)

    def body(i, carry):
        lo, hi = carry
        thr = 0.5 * (lo + hi)
        cnt = jnp.sum(mag >= thr, axis=-1, keepdims=True)
        new_lo = jnp.where(cnt > k, thr, lo)
        new_hi = jnp.where(cnt > k, hi, thr)
        return new_lo, new_hi

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    keep = mag >= lo
    return x * keep.astype(x.dtype)


def pack_bits_ref(bits: Array) -> Array:
    """(R, C) {0,1} int32 with C % 32 == 0 -> (R, C//32) uint32 words.

    Bit i of a row lands in word i//32 at position i%32 (little-endian bit
    order) — the layout the pack Pallas kernel and every wire codec
    (core/wire.py) share bit for bit.
    """
    R, C = bits.shape
    w = bits.reshape(R, C // 32, 32).astype(jnp.uint32)
    weights = jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32)
    return (w * weights).sum(axis=-1).astype(jnp.uint32)


def unpack_bits_ref(words: Array) -> Array:
    """(R, W) uint32 -> (R, 32*W) {0,1} int32. Inverse of pack_bits_ref."""
    R, W = words.shape
    bits = (words[..., None] >> jnp.arange(32, dtype=jnp.uint32)) & jnp.uint32(1)
    return bits.reshape(R, W * 32).astype(jnp.int32)


def rmsnorm_ref(x: Array, gamma: Array, eps: float = 1e-5) -> Array:
    """Row-wise RMSNorm (every arch's hot spot)."""
    ms = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(ms + eps)
            * gamma.astype(jnp.float32)).astype(x.dtype)
