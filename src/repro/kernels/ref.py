"""Pure-jnp oracles for the compression kernels.

Each oracle is bit-compatible with its Pallas kernel given the same uniform
noise: the kernels are deterministic functions of (x, noise, params).
Shapes here are the kernels' canonical 2-D tiled layout (rows, 128·m).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array
_EPS = 1e-12


def qsgd_ref(x: Array, noise: Array, norm: Array, levels: int) -> Array:
    """Fused QSGD quantize+dequantize against a unit-level l2 norm.

    x (R, C) f32; noise (R, C) uniforms in [0,1); norm scalar f32.
    q_i = norm * sign(x_i) * floor(|x_i|/norm * s + u_i) / s
    """
    n = jnp.maximum(norm, _EPS)
    y = jnp.abs(x) / n * levels
    lev = jnp.floor(y + noise)
    return jnp.sign(x) * lev * (n / levels)


def terngrad_ref(x: Array, noise: Array, scale: Array) -> Array:
    """TernGrad quantize+dequantize: b_i ~ Bernoulli(|x_i|/scale);
    out = scale * sign(x) * b."""
    s = jnp.maximum(scale, _EPS)
    b = (noise < jnp.abs(x) / s).astype(x.dtype)
    return jnp.sign(x) * b * s


def topk_mask_ref(x: Array, k: int, iters: int = 24) -> Array:
    """Block-local top-k by magnitude via threshold bisection (per ROW).

    Keeps the elements with |x| >= thr where thr is the bisection estimate
    of the k-th largest magnitude (count(|x| >= thr) >= k >= count(> thr)).
    Identical arithmetic to the Pallas kernel: 'iters' halvings of
    [0, rowmax]. Ties at the threshold may keep slightly more than k.
    """
    mag = jnp.abs(x)
    hi = jnp.max(mag, axis=-1, keepdims=True)
    lo = jnp.zeros_like(hi)

    def body(i, carry):
        lo, hi = carry
        thr = 0.5 * (lo + hi)
        cnt = jnp.sum(mag >= thr, axis=-1, keepdims=True)
        new_lo = jnp.where(cnt > k, thr, lo)
        new_hi = jnp.where(cnt > k, hi, thr)
        return new_lo, new_hi

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    keep = mag >= lo
    return x * keep.astype(x.dtype)


# --------------------------------------------------------------------------
# quantize-to-codes oracles (the integer field streams the wire packs).
# Byte-identity contract: given the same uniforms as Compressor._quantize
# (jax.random.uniform / bernoulli — see kernels/prng.py), these produce the
# exact offset-binary codes the legacy three-pass wire path packs.
# --------------------------------------------------------------------------

def qsgd_codes_ref(x: Array, u: Array, nrm: Array, levels: int) -> Array:
    """QSGD offset-binary codes in [0, 2*levels]: stochastic-round
    |x|/nrm*levels with uniform u, then sign*level + levels. `nrm` is the
    unit l2 norm WITH the compressor's +1e-12 already added (broadcasts:
    scalar or per-row column)."""
    y = jnp.abs(x) / nrm * levels
    lo = jnp.floor(y)
    lev = lo + (u < (y - lo)).astype(y.dtype)
    return (jnp.sign(x) * lev).astype(jnp.int32) + levels


def terngrad_codes_ref(x: Array, u: Array, scale: Array) -> Array:
    """TernGrad codes in {0, 1, 2}: sign(x)*Bernoulli(|x|/scale) + 1.
    `scale` is max|x| WITH the compressor's +1e-12 already added."""
    b = (u < jnp.abs(x) / scale).astype(jnp.int32)
    return jnp.sign(x).astype(jnp.int32) * b + 1


def sign_codes_ref(x: Array) -> Array:
    """signSGD 1-bit codes: x >= 0."""
    return (x >= 0).astype(jnp.int32)


def qsgd_decode_ref(codes: Array, fac: Array, levels: int) -> Array:
    """Inverse of qsgd_codes_ref: (codes - levels) * fac where
    fac = nrm / levels is precomputed in the CALLER's compilation regime.
    (XLA strength-reduces division by a compile-time constant, so a
    kernel-side nrm / levels would not be bit-identical to the codec's
    eager dequant; the in-kernel multiply is a single exact IEEE op.)"""
    return (codes - levels).astype(jnp.float32) * fac


def terngrad_decode_ref(codes: Array, scale: Array) -> Array:
    return (codes - 1).astype(jnp.float32) * scale


def sign_decode_ref(codes: Array) -> Array:
    return (2 * codes - 1).astype(jnp.float32)


# --------------------------------------------------------------------------
# word-wise field packing: chunks of 32 width-bit fields -> exactly `width`
# uint32 words, with compile-time shift constants. Every 32-field chunk
# spans 32*width bits == width whole words, so ANY width packs without
# cross-chunk straddle — the core trick that removes the {0,1} bit-tensor
# (a 32x memory inflation) from both the jnp fallback and the kernels.
# Shared by the Pallas kernel bodies (pure jnp => identical arithmetic).
# --------------------------------------------------------------------------

def pack_fields_tile(fields: Array, width: int) -> Array:
    """(R, C) int32 fields with C % 32 == 0, values < 2**width ->
    (R, C*width//32) uint32 words (little-endian bit order: field i's low
    bit lands at bit-stream position i*width)."""
    R, C = fields.shape
    nc = C // 32
    v = fields.reshape(R, nc, 32).astype(jnp.uint32)
    words = []
    for t in range(width):
        w = jnp.zeros((R, nc), jnp.uint32)
        for j in range(32):
            lo, hi = j * width, (j + 1) * width      # field j's bit span
            if hi <= 32 * t or lo >= 32 * (t + 1):   # no overlap w/ word t
                continue
            s = lo - 32 * t
            f = v[:, :, j]
            w = w | (f << jnp.uint32(s) if s >= 0 else f >> jnp.uint32(-s))
        words.append(w)
    return jnp.stack(words, axis=2).reshape(R, nc * width)


def unpack_fields_tile(words: Array, width: int) -> Array:
    """(R, nc*width) uint32 words -> (R, nc*32) int32 fields. Inverse of
    pack_fields_tile."""
    R, W = words.shape
    nc = W // width
    v = words.reshape(R, nc, width)
    mask = jnp.uint32((1 << width) - 1)
    fields = []
    for j in range(32):
        lo = j * width
        t0, s = lo // 32, lo % 32
        f = v[:, :, t0] >> jnp.uint32(s)
        if lo + width > 32 * (t0 + 1):               # straddles into t0+1
            f = f | (v[:, :, t0 + 1] << jnp.uint32(32 - s))
        fields.append(f & mask)
    return jnp.stack(fields, axis=2).reshape(R, nc * 32).astype(jnp.int32)


def pack_fields_bitexpand_ref(vals: Array, width: int) -> Array:
    """The PRE-FUSION packing path, kept verbatim as the byte-identity
    oracle: expand each field to `width` {0,1} int32 bits (the 32x
    intermediate the fused paths eliminate), then weighted-sum into
    words. (k,) int32 -> (ceil(k*width/32),) uint32."""
    k = vals.shape[0]
    bits = ((vals[:, None] >> jnp.arange(width, dtype=jnp.int32)) & 1)
    flat = bits.reshape(k * width)
    pad = (-flat.shape[0]) % 32
    b = jnp.pad(flat, (0, pad)).reshape(-1, 32)
    return pack_bits_ref(b).reshape(-1)


# --------------------------------------------------------------------------
# bit-sliced majority vote on packed sign words: per-bit-position counts
# kept as word-wide bit PLANES (a ripple-carry adder over words), compared
# against ceil(n/2) with a borrow chain — O(n log n) word ops, and no
# {0,1} bit tensor ever exists. Ties resolve to +1 (2*count >= n), the
# x >= 0 sign convention.
# --------------------------------------------------------------------------

def majority_words_ref(words: Array) -> Array:
    """(n_workers, W) uint32 packed sign words -> (W,) majority words."""
    n, _ = words.shape
    planes = [jnp.zeros_like(words[0]) for _ in range(max(1, n.bit_length()))]
    for i in range(n):
        c = words[i]
        for pi in range(len(planes)):                # ripple-carry add 1 bit
            planes[pi], c = planes[pi] ^ c, planes[pi] & c
    thr = (n + 1) // 2                               # 2*count >= n
    borrow = jnp.zeros_like(words[0])
    for pi, a in enumerate(planes):                  # borrow of count - thr
        if (thr >> pi) & 1:
            borrow = ~a | borrow
        else:
            borrow = ~a & borrow
    return ~borrow                                   # count >= thr


def pack_bits_ref(bits: Array) -> Array:
    """(R, C) {0,1} int32 with C % 32 == 0 -> (R, C//32) uint32 words.

    Bit i of a row lands in word i//32 at position i%32 (little-endian bit
    order) — the layout the pack Pallas kernel and every wire codec
    (core/wire.py) share bit for bit.
    """
    R, C = bits.shape
    w = bits.reshape(R, C // 32, 32).astype(jnp.uint32)
    weights = jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32)
    return (w * weights).sum(axis=-1).astype(jnp.uint32)


def unpack_bits_ref(words: Array) -> Array:
    """(R, W) uint32 -> (R, 32*W) {0,1} int32. Inverse of pack_bits_ref."""
    R, W = words.shape
    bits = (words[..., None] >> jnp.arange(32, dtype=jnp.uint32)) & jnp.uint32(1)
    return bits.reshape(R, W * 32).astype(jnp.int32)


def rmsnorm_ref(x: Array, gamma: Array, eps: float = 1e-5) -> Array:
    """Row-wise RMSNorm (every arch's hot spot)."""
    ms = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(ms + eps)
            * gamma.astype(jnp.float32)).astype(x.dtype)
