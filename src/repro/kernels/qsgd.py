"""Pallas TPU kernels: fused QSGD quantize + dequantize, and the fused
single-launch quantize+PACK wire kernels.

Elementwise + per-element stochastic rounding — pure VPU work. The unit
norm (layer-wise or entire-model, per the paper's granularity) is computed
outside and broadcast in as a scalar, so the SAME kernel serves both
granularities: the statistics unit is the caller's choice, which is
exactly the paper's subject.

Tiling: the flat gradient is reshaped to (rows, 128·LANES) and the grid
walks row-blocks of 8·SUBLANES — (8,128)-aligned VMEM tiles.

The `qsgd_pack_pallas_rows` / `qsgd_unpack_pallas_rows` family is
the wire hot path: ONE launch turns a whole UnitPlan bucket's gradient
tile into packed uint32 payload words (and back). Per element the pack
kernel reads 1 f32 and writes width/32 of a uint32 word — nothing else
touches memory: the stochastic-rounding uniforms are generated
IN-KERNEL from per-row threefry key columns (kernels/prng.py, bit-exact
to the jax.random.uniform draw of Compressor._quantize, so payloads stay
byte-identical to the legacy three-pass path), and the {0,1} bit tensor
of the old quantize -> bit-expand -> word-pack pipeline never exists
(kernels/ref.pack_fields_tile packs 32-field chunks with compile-time
shifts).

The error-feedback residual m = e - decode(words) deliberately does NOT
live in the unpack kernel: on the CPU backend LLVM's fp-contraction
fuses an in-kernel multiply+subtract into an FMA through every JAX-level
barrier (lax.optimization_barrier, bitcast laundering, fast-math flags —
all verified ineffective), which changes the residual's low bits versus
the two-step rounding the wire EF discipline is pinned to. ops.py forms
the residual in the caller's regime instead (see qsgd_unpack_ef_units).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import prng, ref
from repro.kernels.pack import PACK_R

BLOCK_R = 256          # rows per grid step (multiple of 8)
BLOCK_C = 512          # lane columns (multiple of 128)
_EPS = 1e-12


def _qsgd_kernel(x_ref, u_ref, norm_ref, o_ref, *, levels: int):
    x = x_ref[...]
    u = u_ref[...]
    n = jnp.maximum(norm_ref[0, 0], _EPS)
    y = jnp.abs(x) / n * levels
    lev = jnp.floor(y + u)
    o_ref[...] = jnp.sign(x) * lev * (n / levels)


def _qsgd_rows_kernel(x_ref, u_ref, norm_ref, o_ref, *, levels: int):
    x = x_ref[...]
    u = u_ref[...]
    n = jnp.maximum(norm_ref[...], _EPS)       # (BLOCK_R, 1): per-row scale
    y = jnp.abs(x) / n * levels
    lev = jnp.floor(y + u)
    o_ref[...] = jnp.sign(x) * lev * (n / levels)


def qsgd_pallas_rows(x: jax.Array, noise: jax.Array, norms: jax.Array,
                     levels: int, *, interpret: bool = True) -> jax.Array:
    """Per-ROW-scale QSGD: one fused dispatch for a whole UnitPlan bucket.

    x, noise: (R, C) f32 with R % BLOCK_R == 0, C == BLOCK_C; norms:
    (R, 1) f32 — the l2 norm of the compression unit each tile row belongs
    to (a unit spanning k tile rows repeats its norm k times). This is the
    batched form of qsgd_pallas: same arithmetic, unit statistics resolved
    per row instead of one scalar per launch."""
    R, C = x.shape
    assert R % BLOCK_R == 0 and C == BLOCK_C, (R, C)
    assert norms.shape == (R, 1), norms.shape
    return pl.pallas_call(
        functools.partial(_qsgd_rows_kernel, levels=levels),
        grid=(R // BLOCK_R,),
        in_specs=[
            pl.BlockSpec((BLOCK_R, BLOCK_C), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_R, BLOCK_C), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_R, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_R, BLOCK_C), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, C), x.dtype),
        interpret=interpret,
    )(x, noise, norms)


# --------------------------------------------------------------------------
# fused single-launch quantize + word-pack (the wire encode hot path)
# --------------------------------------------------------------------------

def _row_positions(block_shape, rpu: int):
    """Flat position of every (row, lane) inside its compression unit: a
    unit spans `rpu` consecutive tile rows of BLOCK_C lanes."""
    R, C = block_shape
    row = pl.program_id(0) * R + jax.lax.broadcasted_iota(jnp.int32,
                                                          (R, C), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (R, C), 1)
    return (row % rpu) * C + col


def _qsgd_pack_kernel(x_ref, k0_ref, k1_ref, nrm_ref, o_ref, *,
                      levels: int, width: int, d: int, rpu: int):
    x = x_ref[...]                                   # (R, 512) f32
    pos = _row_positions(x.shape, rpu)
    u = prng.uniform_at(k0_ref[...], k1_ref[...], pos, d)
    codes = ref.qsgd_codes_ref(x, u, nrm_ref[...], levels)
    codes = jnp.where(pos < d, codes, 0)             # zero word padding
    o_ref[...] = ref.pack_fields_tile(codes, width)


def _qsgd_unpack_kernel(w_ref, fac_ref, o_ref, *, levels: int, width: int):
    codes = ref.unpack_fields_tile(w_ref[...], width)
    o_ref[...] = ref.qsgd_decode_ref(codes, fac_ref[...], levels)


def qsgd_pack_pallas_rows(x: jax.Array, k0: jax.Array, k1: jax.Array,
                          nrms: jax.Array, levels: int, width: int, *,
                          d: int, rpu: int,
                          interpret: bool = True) -> jax.Array:
    """Fused quantize+pack over a bucket tile: x (R, 512) f32 with
    R % PACK_R == 0 (units of dim `d` spanning `rpu` rows each), per-row
    threefry key columns k0/k1 (R, 1) uint32 and unit norms nrms (R, 1)
    f32 (+1e-12 already added) -> (R, 16*width) uint32 payload words.
    ONE launch, 1 f32 read + 1 packed-word write per element."""
    R, C = x.shape
    assert R % PACK_R == 0 and C == BLOCK_C, (R, C)
    assert k0.shape == k1.shape == nrms.shape == (R, 1)
    wpr = (C // 32) * width
    return pl.pallas_call(
        functools.partial(_qsgd_pack_kernel, levels=levels, width=width,
                          d=d, rpu=rpu),
        grid=(R // PACK_R,),
        in_specs=[
            pl.BlockSpec((PACK_R, C), lambda i: (i, 0)),
            pl.BlockSpec((PACK_R, 1), lambda i: (i, 0)),
            pl.BlockSpec((PACK_R, 1), lambda i: (i, 0)),
            pl.BlockSpec((PACK_R, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((PACK_R, wpr), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, wpr), jnp.uint32),
        interpret=interpret,
    )(x, k0, k1, nrms)


def qsgd_unpack_pallas_rows(words: jax.Array, facs: jax.Array, levels: int,
                            width: int, *,
                            interpret: bool = True) -> jax.Array:
    """Fused unpack+dequantize: words (R, 16*width) uint32 + per-row
    dequant factors facs = norm/levels (R, 1), division done by the
    CALLER (see ref.qsgd_decode_ref) -> (R, 512) f32."""
    R, W = words.shape
    wpr = (BLOCK_C // 32) * width
    assert R % PACK_R == 0 and W == wpr, (R, W, width)
    return pl.pallas_call(
        functools.partial(_qsgd_unpack_kernel, levels=levels, width=width),
        grid=(R // PACK_R,),
        in_specs=[
            pl.BlockSpec((PACK_R, wpr), lambda i: (i, 0)),
            pl.BlockSpec((PACK_R, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((PACK_R, BLOCK_C), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, BLOCK_C), jnp.float32),
        interpret=interpret,
    )(words, facs)


def qsgd_pallas(x: jax.Array, noise: jax.Array, norm: jax.Array,
                levels: int, *, interpret: bool = True) -> jax.Array:
    """x, noise: (R, C) f32 with R % BLOCK_R == 0, C == BLOCK_C.
    norm: () f32. interpret=True runs the kernel body on CPU (validation);
    on TPU pass interpret=False."""
    R, C = x.shape
    assert R % BLOCK_R == 0 and C == BLOCK_C, (R, C)
    grid = (R // BLOCK_R,)
    return pl.pallas_call(
        functools.partial(_qsgd_kernel, levels=levels),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_R, BLOCK_C), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_R, BLOCK_C), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_R, BLOCK_C), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, C), x.dtype),
        interpret=interpret,
    )(x, noise, norm.reshape(1, 1))
