"""Pallas TPU kernel: fused QSGD quantize + dequantize.

Elementwise + per-element stochastic rounding — pure VPU work. The unit
norm (layer-wise or entire-model, per the paper's granularity) is computed
outside and broadcast in as a scalar, so the SAME kernel serves both
granularities: the statistics unit is the caller's choice, which is
exactly the paper's subject.

Tiling: the flat gradient is reshaped to (rows, 128·LANES) and the grid
walks row-blocks of 8·SUBLANES — (8,128)-aligned VMEM tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_R = 256          # rows per grid step (multiple of 8)
BLOCK_C = 512          # lane columns (multiple of 128)
_EPS = 1e-12


def _qsgd_kernel(x_ref, u_ref, norm_ref, o_ref, *, levels: int):
    x = x_ref[...]
    u = u_ref[...]
    n = jnp.maximum(norm_ref[0, 0], _EPS)
    y = jnp.abs(x) / n * levels
    lev = jnp.floor(y + u)
    o_ref[...] = jnp.sign(x) * lev * (n / levels)


def _qsgd_rows_kernel(x_ref, u_ref, norm_ref, o_ref, *, levels: int):
    x = x_ref[...]
    u = u_ref[...]
    n = jnp.maximum(norm_ref[...], _EPS)       # (BLOCK_R, 1): per-row scale
    y = jnp.abs(x) / n * levels
    lev = jnp.floor(y + u)
    o_ref[...] = jnp.sign(x) * lev * (n / levels)


def qsgd_pallas_rows(x: jax.Array, noise: jax.Array, norms: jax.Array,
                     levels: int, *, interpret: bool = True) -> jax.Array:
    """Per-ROW-scale QSGD: one fused dispatch for a whole UnitPlan bucket.

    x, noise: (R, C) f32 with R % BLOCK_R == 0, C == BLOCK_C; norms:
    (R, 1) f32 — the l2 norm of the compression unit each tile row belongs
    to (a unit spanning k tile rows repeats its norm k times). This is the
    batched form of qsgd_pallas: same arithmetic, unit statistics resolved
    per row instead of one scalar per launch."""
    R, C = x.shape
    assert R % BLOCK_R == 0 and C == BLOCK_C, (R, C)
    assert norms.shape == (R, 1), norms.shape
    return pl.pallas_call(
        functools.partial(_qsgd_rows_kernel, levels=levels),
        grid=(R // BLOCK_R,),
        in_specs=[
            pl.BlockSpec((BLOCK_R, BLOCK_C), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_R, BLOCK_C), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_R, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_R, BLOCK_C), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, C), x.dtype),
        interpret=interpret,
    )(x, noise, norms)


def qsgd_pallas(x: jax.Array, noise: jax.Array, norm: jax.Array,
                levels: int, *, interpret: bool = True) -> jax.Array:
    """x, noise: (R, C) f32 with R % BLOCK_R == 0, C == BLOCK_C.
    norm: () f32. interpret=True runs the kernel body on CPU (validation);
    on TPU pass interpret=False."""
    R, C = x.shape
    assert R % BLOCK_R == 0 and C == BLOCK_C, (R, C)
    grid = (R // BLOCK_R,)
    return pl.pallas_call(
        functools.partial(_qsgd_kernel, levels=levels),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_R, BLOCK_C), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_R, BLOCK_C), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_R, BLOCK_C), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, C), x.dtype),
        interpret=interpret,
    )(x, noise, norm.reshape(1, 1))
