"""Pallas TPU kernel: fused TernGrad quantize + dequantize.

out = scale · sign(x) · 1[u < |x|/scale], with the per-unit scale
(max |x| over the compression unit) computed outside — same
granularity-polymorphic design as the QSGD kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_R = 256
BLOCK_C = 512
_EPS = 1e-12


def _terngrad_kernel(x_ref, u_ref, scale_ref, o_ref):
    x = x_ref[...]
    u = u_ref[...]
    s = jnp.maximum(scale_ref[0, 0], _EPS)
    b = (u < jnp.abs(x) / s).astype(x.dtype)
    o_ref[...] = jnp.sign(x) * b * s


def _terngrad_rows_kernel(x_ref, u_ref, scale_ref, o_ref):
    x = x_ref[...]
    u = u_ref[...]
    s = jnp.maximum(scale_ref[...], _EPS)      # (BLOCK_R, 1): per-row scale
    b = (u < jnp.abs(x) / s).astype(x.dtype)
    o_ref[...] = jnp.sign(x) * b * s


def terngrad_pallas_rows(x: jax.Array, noise: jax.Array, scales: jax.Array,
                         *, interpret: bool = True) -> jax.Array:
    """Per-ROW-scale TernGrad: one fused dispatch for a whole UnitPlan
    bucket. scales: (R, 1) — max|x| of the unit each tile row belongs to."""
    R, C = x.shape
    assert R % BLOCK_R == 0 and C == BLOCK_C, (R, C)
    assert scales.shape == (R, 1), scales.shape
    return pl.pallas_call(
        _terngrad_rows_kernel,
        grid=(R // BLOCK_R,),
        in_specs=[
            pl.BlockSpec((BLOCK_R, BLOCK_C), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_R, BLOCK_C), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_R, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_R, BLOCK_C), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, C), x.dtype),
        interpret=interpret,
    )(x, noise, scales)


def terngrad_pallas(x: jax.Array, noise: jax.Array, scale: jax.Array,
                    *, interpret: bool = True) -> jax.Array:
    R, C = x.shape
    assert R % BLOCK_R == 0 and C == BLOCK_C, (R, C)
    return pl.pallas_call(
        _terngrad_kernel,
        grid=(R // BLOCK_R,),
        in_specs=[
            pl.BlockSpec((BLOCK_R, BLOCK_C), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_R, BLOCK_C), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_R, BLOCK_C), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, C), x.dtype),
        interpret=interpret,
    )(x, noise, scale.reshape(1, 1))
