"""Pallas TPU kernel: fused TernGrad quantize + dequantize, and the fused
single-launch ternarize+PACK wire kernels.

out = scale · sign(x) · 1[u < |x|/scale], with the per-unit scale
(max |x| over the compression unit) computed outside — same
granularity-polymorphic design as the QSGD kernel.

`terngrad_pack_pallas_rows` / `terngrad_unpack_pallas_rows` are the
wire hot path: ONE launch per bucket turning gradient tiles into 2-bit
codes packed as uint32 words (1 f32 read + 1/16 word write per element),
Bernoulli draws generated in-kernel from per-row threefry key columns
(kernels/prng.py — bit-exact to jax.random.bernoulli, so payloads stay
byte-identical to the legacy three-pass path). See kernels/qsgd.py for
the design notes; this module is its 2-bit mirror.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import prng, ref
from repro.kernels.pack import PACK_R

BLOCK_R = 256
BLOCK_C = 512
_EPS = 1e-12


def _terngrad_kernel(x_ref, u_ref, scale_ref, o_ref):
    x = x_ref[...]
    u = u_ref[...]
    s = jnp.maximum(scale_ref[0, 0], _EPS)
    b = (u < jnp.abs(x) / s).astype(x.dtype)
    o_ref[...] = jnp.sign(x) * b * s


def _terngrad_rows_kernel(x_ref, u_ref, scale_ref, o_ref):
    x = x_ref[...]
    u = u_ref[...]
    s = jnp.maximum(scale_ref[...], _EPS)      # (BLOCK_R, 1): per-row scale
    b = (u < jnp.abs(x) / s).astype(x.dtype)
    o_ref[...] = jnp.sign(x) * b * s


def terngrad_pallas_rows(x: jax.Array, noise: jax.Array, scales: jax.Array,
                         *, interpret: bool = True) -> jax.Array:
    """Per-ROW-scale TernGrad: one fused dispatch for a whole UnitPlan
    bucket. scales: (R, 1) — max|x| of the unit each tile row belongs to."""
    R, C = x.shape
    assert R % BLOCK_R == 0 and C == BLOCK_C, (R, C)
    assert scales.shape == (R, 1), scales.shape
    return pl.pallas_call(
        _terngrad_rows_kernel,
        grid=(R // BLOCK_R,),
        in_specs=[
            pl.BlockSpec((BLOCK_R, BLOCK_C), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_R, BLOCK_C), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_R, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_R, BLOCK_C), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, C), x.dtype),
        interpret=interpret,
    )(x, noise, scales)


# --------------------------------------------------------------------------
# fused single-launch ternarize + word-pack (the wire encode hot path)
# --------------------------------------------------------------------------

TERN_WIDTH = 2


def _tern_pack_kernel(x_ref, k0_ref, k1_ref, s_ref, o_ref, *,
                      d: int, rpu: int):
    from repro.kernels.qsgd import _row_positions
    x = x_ref[...]                                   # (R, 512) f32
    pos = _row_positions(x.shape, rpu)
    u = prng.uniform_at(k0_ref[...], k1_ref[...], pos, d)
    codes = ref.terngrad_codes_ref(x, u, s_ref[...])
    codes = jnp.where(pos < d, codes, 0)             # zero word padding
    o_ref[...] = ref.pack_fields_tile(codes, TERN_WIDTH)


def _tern_unpack_kernel(w_ref, s_ref, o_ref):
    codes = ref.unpack_fields_tile(w_ref[...], TERN_WIDTH)
    o_ref[...] = ref.terngrad_decode_ref(codes, s_ref[...])


def terngrad_pack_pallas_rows(x: jax.Array, k0: jax.Array, k1: jax.Array,
                              scales: jax.Array, *, d: int, rpu: int,
                              interpret: bool = True) -> jax.Array:
    """Fused ternarize+pack over a bucket tile: x (R, 512) f32 with
    R % PACK_R == 0, per-row threefry key columns k0/k1 (R, 1) uint32 and
    unit scales (max|x| + 1e-12 already added) scales (R, 1) f32 ->
    (R, 32) uint32 payload words. ONE launch."""
    R, C = x.shape
    assert R % PACK_R == 0 and C == BLOCK_C, (R, C)
    assert k0.shape == k1.shape == scales.shape == (R, 1)
    wpr = (C // 32) * TERN_WIDTH
    return pl.pallas_call(
        functools.partial(_tern_pack_kernel, d=d, rpu=rpu),
        grid=(R // PACK_R,),
        in_specs=[
            pl.BlockSpec((PACK_R, C), lambda i: (i, 0)),
            pl.BlockSpec((PACK_R, 1), lambda i: (i, 0)),
            pl.BlockSpec((PACK_R, 1), lambda i: (i, 0)),
            pl.BlockSpec((PACK_R, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((PACK_R, wpr), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, wpr), jnp.uint32),
        interpret=interpret,
    )(x, k0, k1, scales)


def terngrad_unpack_pallas_rows(words: jax.Array, scales: jax.Array, *,
                                interpret: bool = True) -> jax.Array:
    """Fused unpack+dequantize: words (R, 32) uint32 + per-row payload
    scales (R, 1) -> (R, 512) f32."""
    R, W = words.shape
    wpr = (BLOCK_C // 32) * TERN_WIDTH
    assert R % PACK_R == 0 and W == wpr, (R, W)
    return pl.pallas_call(
        _tern_unpack_kernel,
        grid=(R // PACK_R,),
        in_specs=[
            pl.BlockSpec((PACK_R, wpr), lambda i: (i, 0)),
            pl.BlockSpec((PACK_R, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((PACK_R, BLOCK_C), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, BLOCK_C), jnp.float32),
        interpret=interpret,
    )(words, scales)


def terngrad_pallas(x: jax.Array, noise: jax.Array, scale: jax.Array,
                    *, interpret: bool = True) -> jax.Array:
    R, C = x.shape
    assert R % BLOCK_R == 0 and C == BLOCK_C, (R, C)
    return pl.pallas_call(
        _terngrad_kernel,
        grid=(R // BLOCK_R,),
        in_specs=[
            pl.BlockSpec((BLOCK_R, BLOCK_C), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_R, BLOCK_C), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_R, BLOCK_C), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, C), x.dtype),
        interpret=interpret,
    )(x, noise, scale.reshape(1, 1))
