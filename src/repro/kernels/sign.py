"""Pallas TPU kernels: fused signSGD sign+PACK wire kernels and the
majority-vote kernel on packed words.

signSGD's wire format is the purest case: 1 bit per entry (x >= 0), no
statistic leg, no randomness. The pack kernel reads the (R, 512) f32
gradient tile and writes 16 uint32 words per row in ONE launch (1 f32
read + 1/32 word write per element); unpack mirrors it (the EF residual
rides outside the kernel — see kernels/qsgd.py on fp-contraction).

`majority_pallas` is the signSGD-with-majority-vote aggregation
(Bernstein et al.) operating DIRECTLY on the packed words: per-bit
worker counts are kept as word-wide bit planes via a ripple-carry adder
and compared against ceil(n/2) with a borrow chain
(kernels/ref.majority_words_ref) — the {0,1} bit tensor never exists on
either side of the vote, and ties resolve to +1 (the x >= 0 convention).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import ref
from repro.kernels.pack import PACK_R

BLOCK_C = 512
MAJ_C = 512            # majority-vote word columns per grid step


def _sign_pack_kernel(x_ref, o_ref, *, d: int, rpu: int):
    from repro.kernels.qsgd import _row_positions
    x = x_ref[...]                                   # (R, 512) f32
    pos = _row_positions(x.shape, rpu)
    codes = jnp.where(pos < d, ref.sign_codes_ref(x), 0)
    o_ref[...] = ref.pack_fields_tile(codes, 1)


def _sign_unpack_kernel(w_ref, o_ref):
    codes = ref.unpack_fields_tile(w_ref[...], 1)
    o_ref[...] = ref.sign_decode_ref(codes)


def _majority_kernel(w_ref, o_ref):
    o_ref[...] = ref.majority_words_ref(w_ref[...])[None, :]


def sign_pack_pallas_rows(x: jax.Array, *, d: int, rpu: int,
                          interpret: bool = True) -> jax.Array:
    """Fused sign+pack over a bucket tile: x (R, 512) f32 with
    R % PACK_R == 0 (units of dim `d` spanning `rpu` rows each) ->
    (R, 16) uint32 sign words. ONE launch, no noise, no statistic."""
    R, C = x.shape
    assert R % PACK_R == 0 and C == BLOCK_C, (R, C)
    wpr = C // 32
    return pl.pallas_call(
        functools.partial(_sign_pack_kernel, d=d, rpu=rpu),
        grid=(R // PACK_R,),
        in_specs=[pl.BlockSpec((PACK_R, C), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((PACK_R, wpr), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, wpr), jnp.uint32),
        interpret=interpret,
    )(x)


def sign_unpack_pallas_rows(words: jax.Array, *,
                            interpret: bool = True) -> jax.Array:
    """Fused unpack+decode: words (R, 16) uint32 -> (R, 512) f32 signs."""
    R, W = words.shape
    wpr = BLOCK_C // 32
    assert R % PACK_R == 0 and W == wpr, (R, W)
    return pl.pallas_call(
        _sign_unpack_kernel,
        grid=(R // PACK_R,),
        in_specs=[pl.BlockSpec((PACK_R, wpr), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((PACK_R, BLOCK_C), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, BLOCK_C), jnp.float32),
        interpret=interpret,
    )(words)


def majority_pallas(words: jax.Array, *,
                    interpret: bool = True) -> jax.Array:
    """(n_workers, W) uint32 packed sign words with W % MAJ_C == 0 ->
    (W,) majority words, never unpacking to bits. Zero-padded word
    columns vote 0 everywhere and are truncated by the caller."""
    n, W = words.shape
    assert W % MAJ_C == 0, (n, W)
    out = pl.pallas_call(
        _majority_kernel,
        grid=(W // MAJ_C,),
        in_specs=[pl.BlockSpec((n, MAJ_C), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, MAJ_C), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, W), jnp.uint32),
        interpret=interpret,
    )(words)
    return out[0]
