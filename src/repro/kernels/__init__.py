"""Pallas TPU kernels for the compression hot path (+ fused RMSNorm).

Kernels run with interpret=True on this CPU container (validation); on a
real TPU set REPRO_PALLAS_INTERPRET=0.

The wire hot path is the fused single-launch compress+pack family in
`ops` — one kernel launch per UnitPlan bucket, payloads byte-identical
to the legacy three-pass (quantize -> bit-expand -> word-pack) pipeline:

- `ops.qsgd_pack_units` / `ops.qsgd_unpack_units` /
  `ops.qsgd_unpack_ef_units` — QSGD quantize+pack, unpack+dequantize,
  and decode+error-feedback (residual formed in the caller's regime;
  see the fp-contraction note in `kernels/qsgd.py`).
- `ops.terngrad_pack_units` / `ops.terngrad_unpack_units` /
  `ops.terngrad_unpack_ef_units` — 2-bit ternary.
- `ops.sign_pack_units` / `ops.sign_unpack_units` /
  `ops.sign_unpack_ef_units` — 1-bit sign.
- `ops.majority_words` — signSGD majority vote DIRECTLY on packed
  uint32 words (bit-sliced ripple-carry counting, never unpacking).
- `ops.fields_pack_units` / `ops.fields_unpack_units` (and the flat
  `ops.pack_fields` / `ops.unpack_fields`) — generic word-wise field
  packing for the natural / sparse-index codec legs.
- `ops.pack_bytes_moved` / `ops.unpack_bytes_moved` /
  `ops.count_pallas_calls` — the deterministic traffic + dispatch
  accounting BENCH_kernels.json gates on.

Every fused op has a pure-jnp fallback running the identical tile
arithmetic (`kernels/ref.py`), so payloads match bit-for-bit with
pallas on or off; in-kernel stochastic rounding draws come from
`kernels/prng.py` (bit-exact threefry reimplementation of the
`jax.random.uniform` draw the simulated compressors make).
"""
from repro.kernels.ops import (qsgd_compress, terngrad_compress,
                               blockwise_topk, rmsnorm)
