"""Pallas TPU kernels for the compression hot path (+ fused RMSNorm).

Kernels run with interpret=True on this CPU container (validation); on a
real TPU set REPRO_PALLAS_INTERPRET=0.
"""
from repro.kernels.ops import (qsgd_compress, terngrad_compress,
                               blockwise_topk, rmsnorm)
