"""Flat-npz checkpointing for param/optimizer pytrees.

Leaves are keyed by their tree path; metadata (step, structure) rides in a
JSON sidecar entry. On multi-host deployments each host would save its
addressable shards (path pattern includes a shard tag); in this container
there is one host, so shard 0 holds everything.
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf
    return flat


def save_checkpoint(directory: str, step: int, tree, *, tag: str = "ckpt",
                    shard: int = 0) -> str:
    os.makedirs(directory, exist_ok=True)
    flat = _flatten_with_paths(tree)
    arrays = {}
    meta = {"step": int(step), "keys": [], "dtypes": {}}
    for i, (k, v) in enumerate(sorted(flat.items())):
        name = f"a{i}"
        arr = np.asarray(v)
        if arr.dtype == jnp.bfloat16:
            meta["dtypes"][name] = "bfloat16"
            arr = arr.view(np.uint16)
        arrays[name] = arr
        meta["keys"].append(k)
    path = os.path.join(directory, f"{tag}_{step:08d}_s{shard}.npz")
    np.savez(path, __meta__=json.dumps(meta), **arrays)
    return path


def latest_checkpoint(directory: str, tag: str = "ckpt") -> Optional[str]:
    if not os.path.isdir(directory):
        return None
    pat = re.compile(rf"{tag}_(\d+)_s0\.npz")
    best, best_step = None, -1
    for f in os.listdir(directory):
        m = pat.match(f)
        if m and int(m.group(1)) > best_step:
            best, best_step = os.path.join(directory, f), int(m.group(1))
    return best


def load_checkpoint(path: str, like) -> Tuple[int, Any]:
    """Restore into the structure of `like` (a pytree of arrays or
    ShapeDtypeStructs). Returns (step, tree)."""
    with np.load(path, allow_pickle=False) as z:
        meta = json.loads(str(z["__meta__"]))
        flat = {}
        for i, k in enumerate(meta["keys"]):
            arr = z[f"a{i}"]
            if meta["dtypes"].get(f"a{i}") == "bfloat16":
                arr = arr.view(jnp.bfloat16)
            flat[k] = jnp.asarray(arr)
    ref = _flatten_with_paths(like)
    missing = set(ref) - set(flat)
    if missing:
        raise ValueError(f"checkpoint missing keys: {sorted(missing)[:5]}...")
    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    vals = []
    for path_t, _ in leaves_with_paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path_t)
        vals.append(flat[key])
    return meta["step"], jax.tree_util.tree_unflatten(treedef, vals)
