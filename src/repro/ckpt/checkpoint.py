"""Flat-npz checkpointing for param/optimizer pytrees.

Leaves are keyed by their tree path; metadata (step, structure) rides in a
JSON sidecar entry. On multi-host deployments each host would save its
addressable shards (path pattern includes a shard tag); in this container
there is one host, so shard 0 holds everything.

Resilience contract: writes are ATOMIC (tmp file + os.replace, so a kill
mid-save never leaves a half-written file at the final path) and carry a
CRC32 content digest over every stored array + the key list; `load`
verifies the digest and wraps any truncation/garbage into a clear
ValueError instead of handing back corrupt leaves. Round-trips are
bit-exact (f32 raw; bf16 stored as uint16 views), which is what the
bitwise resume-replay contract (resil.train_resilient) stands on.
"""
from __future__ import annotations

import json
import os
import re
import zlib
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf
    return flat


def _digest(arrays: Dict[str, np.ndarray], keys) -> int:
    """CRC32 over every stored array's (name, dtype, shape, bytes) plus
    the key list — computed on the AS-STORED views (bf16 already viewed
    as uint16), so save and load hash identical bytes."""
    crc = zlib.crc32(json.dumps(list(keys)).encode())
    for name in sorted(arrays):
        a = np.ascontiguousarray(arrays[name])
        crc = zlib.crc32(
            f"{name}|{a.dtype.str}|{a.shape}".encode(), crc)
        crc = zlib.crc32(a.tobytes(), crc)
    return crc & 0xFFFFFFFF


def save_checkpoint(directory: str, step: int, tree, *, tag: str = "ckpt",
                    shard: int = 0) -> str:
    """Atomically write one checkpoint; returns its final path. The
    payload is staged to `<path>.tmp.npz` and os.replace-d into place
    (same directory, hence same filesystem — the rename is atomic), so
    readers only ever see whole files and `latest_checkpoint` never
    picks up a partial write (the tmp suffix doesn't match its
    pattern)."""
    os.makedirs(directory, exist_ok=True)
    flat = _flatten_with_paths(tree)
    arrays = {}
    meta = {"step": int(step), "keys": [], "dtypes": {}}
    for i, (k, v) in enumerate(sorted(flat.items())):
        name = f"a{i}"
        arr = np.asarray(v)
        if arr.dtype == jnp.bfloat16:
            meta["dtypes"][name] = "bfloat16"
            arr = arr.view(np.uint16)
        arrays[name] = arr
        meta["keys"].append(k)
    meta["digest"] = _digest(arrays, meta["keys"])
    path = os.path.join(directory, f"{tag}_{step:08d}_s{shard}.npz")
    # np.savez appends ".npz" when missing — keep it on the tmp name so
    # the staged file is exactly what os.replace moves
    tmp = path + ".tmp.npz"
    np.savez(tmp, __meta__=json.dumps(meta), **arrays)
    os.replace(tmp, path)
    return path


def latest_checkpoint(directory: str, tag: str = "ckpt") -> Optional[str]:
    if not os.path.isdir(directory):
        return None
    pat = re.compile(rf"{tag}_(\d+)_s0\.npz$")
    best, best_step = None, -1
    for f in os.listdir(directory):
        m = pat.match(f)
        if m and int(m.group(1)) > best_step:
            best, best_step = os.path.join(directory, f), int(m.group(1))
    return best


def load_checkpoint(path: str, like) -> Tuple[int, Any]:
    """Restore into the structure of `like` (a pytree of arrays or
    ShapeDtypeStructs). Returns (step, tree).

    A truncated, overwritten, or otherwise corrupt file raises
    ValueError naming the path — never garbage leaves: the zip/npz
    structure, the metadata entry, and (when present — pre-digest
    checkpoints still load) the CRC32 content digest are all checked
    before anything is handed back."""
    try:
        with np.load(path, allow_pickle=False) as z:
            meta = json.loads(str(z["__meta__"]))
            raw = {}
            for i, _k in enumerate(meta["keys"]):
                raw[f"a{i}"] = np.asarray(z[f"a{i}"])
    except Exception as e:
        raise ValueError(
            f"corrupt or truncated checkpoint {path!r}: "
            f"{type(e).__name__}: {e}") from e
    want = meta.get("digest")
    if want is not None:
        got = _digest(raw, meta["keys"])
        if got != want:
            raise ValueError(
                f"corrupt checkpoint {path!r}: content digest mismatch "
                f"(stored {want:#010x}, recomputed {got:#010x})")
    flat = {}
    for i, k in enumerate(meta["keys"]):
        arr = raw[f"a{i}"]
        if meta["dtypes"].get(f"a{i}") == "bfloat16":
            arr = arr.view(jnp.bfloat16)
        flat[k] = jnp.asarray(arr)
    ref = _flatten_with_paths(like)
    missing = set(ref) - set(flat)
    if missing:
        raise ValueError(f"checkpoint missing keys: {sorted(missing)[:5]}...")
    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    vals = []
    for path_t, _ in leaves_with_paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path_t)
        vals.append(flat[key])
    return meta["step"], jax.tree_util.tree_unflatten(treedef, vals)
