"""Recovery policies: wiring corruption DETECTION to ACTION.

RecoveryConfig names the four policies the resilience plane implements:

  resend                 a message whose Fletcher-32 check fails is
                         replaced by the sender's clean re-encode
                         (in-graph: the sender still holds the clean
                         buffer — core.wire._receive_buffer);
  dense_fallback_after   after N CONSECUTIVE steps with detected
                         corruption, drop the compressed wire entirely
                         and aggregate dense (no packed bytes => nothing
                         for the fault plane to corrupt);
  step_guard             a non-finite loss or aggregated gradient skips
                         the parameter update AND rolls the EF residual
                         back to its pre-step value (a skipped step must
                         not advance error memory);
  straggler_timeout_us   workers whose straggler delay exceeds the
                         timeout are dropped from the step; the mean
                         renormalizes over survivors and their EF rows
                         freeze (partial participation).

RecoveryManager is the host-side controller: it drains the per-step
fault counters, applies the fallback policy, feeds the obs counters
(resil/corrupt_detected, resil/resends, resil/steps_skipped), and
exposes its decision state as a checkpointable dict.

`train_resilient` is the reference loop threading all of it through
SimCluster, with atomic checkpoints (params + EF + PRNG key + manager
state) and the bitwise replay contract: train N steps == train k,
kill, resume, train N-k — asserted leaf-for-leaf by the fault suite
and BENCH_faults.json.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class RecoveryConfig:
    resend: bool = True
    dense_fallback_after: Optional[int] = None
    step_guard: bool = True
    straggler_timeout_us: Optional[float] = None

    def __post_init__(self):
        if (self.dense_fallback_after is not None
                and self.dense_fallback_after < 1):
            raise ValueError(f"dense_fallback_after must be >= 1 or None:"
                             f" {self.dense_fallback_after}")
        if (self.straggler_timeout_us is not None
                and self.straggler_timeout_us < 0):
            raise ValueError(f"negative straggler timeout: "
                             f"{self.straggler_timeout_us}")


class RecoveryManager:
    """Host-side recovery controller + obs counter sink.

    Lives OUTSIDE the traced step (like every SimCluster decision): it
    consumes concrete per-step counters, keeps running totals, and
    flips `fallback_active` once `dense_fallback_after` consecutive
    corrupted steps have been seen — a Python-static decision, so the
    fallback switches to a different compiled step function rather than
    branching in-graph. `metrics` is the duck-typed obs.MetricsRegistry
    (None = counters kept locally only).
    """

    _COUNTERS = ("resil/corrupt_detected", "resil/resends",
                 "resil/steps_skipped")

    def __init__(self, config: RecoveryConfig, *, metrics=None):
        self.config = config
        self.metrics = metrics
        self.counters: Dict[str, int] = {k: 0 for k in self._COUNTERS}
        self.consecutive_failures = 0
        self.fallback_active = False

    def observe(self, *, detected: int = 0, resends: int = 0,
                skipped: int = 0) -> None:
        """Fold one step's concrete counters in and update the
        fallback decision."""
        detected, resends, skipped = (int(detected), int(resends),
                                      int(skipped))
        self.counters["resil/corrupt_detected"] += detected
        self.counters["resil/resends"] += resends
        self.counters["resil/steps_skipped"] += skipped
        if self.metrics is not None:
            self.metrics.inc("resil/corrupt_detected", detected)
            self.metrics.inc("resil/resends", resends)
            self.metrics.inc("resil/steps_skipped", skipped)
        if not self.fallback_active:
            self.consecutive_failures = (self.consecutive_failures + 1
                                         if detected > 0 else 0)
            after = self.config.dense_fallback_after
            if after is not None and self.consecutive_failures >= after:
                self.fallback_active = True

    # ---- checkpointable decision state -----------------------------------
    def state(self) -> Dict[str, np.ndarray]:
        """The manager's full decision state as an int64 leaf dict —
        checkpointed next to params/EF so a resumed run replays the
        SAME fallback decisions (part of the bitwise contract)."""
        s = {k.replace("/", "_"): np.asarray(v, np.int64)
             for k, v in self.counters.items()}
        s["consecutive_failures"] = np.asarray(self.consecutive_failures,
                                               np.int64)
        s["fallback_active"] = np.asarray(int(self.fallback_active),
                                          np.int64)
        return s

    def restore(self, state: Dict) -> None:
        for k in self._COUNTERS:
            self.counters[k] = int(np.asarray(state[k.replace("/", "_")]))
        self.consecutive_failures = int(
            np.asarray(state["consecutive_failures"]))
        self.fallback_active = bool(
            int(np.asarray(state["fallback_active"])))


# --------------------------------------------------------------------------
# the resilient training loop
# --------------------------------------------------------------------------

_CKPT_TAG = "resil"
_STEP_RE = re.compile(r"_(\d+)_s\d+\.npz$")


def _finite_tree(tree):
    ok = jnp.array(True)
    for leaf in jax.tree_util.tree_leaves(tree):
        ok = ok & jnp.all(jnp.isfinite(leaf))
    return ok


def train_resilient(runner, scenario, comp, *, steps: int, lr: float = 0.02,
                    seed: int = 0, recovery: RecoveryConfig = None,
                    ckpt_dir: Optional[str] = None, ckpt_every: int = 0,
                    resume: bool = False, metrics=None,
                    grad_hook=None):
    """Train `steps` steps of simulated-multi-worker compressed SGD under
    `scenario`, with the full recovery stack.

    `runner` follows the campaign protocol (benchmarks/scenarios.py):
    `categories`, `global_batch`, `init(key)`, `loss(params, batch,
    key)`, `worker_batch(key, props, per)`. `comp` is the
    CompressionConfig (wire path: the aggregate runs wire=True so the
    scenario's CorruptionSpec has real packed bytes to corrupt).

    Every random draw is a pure function of (seed, step index) — no
    iterator state — so resuming from a checkpoint at step k replays
    steps k..N byte-for-byte: `train_resilient(..., steps=N)` ==
    `train_resilient(..., steps=k, ckpt_every=k)` then
    `train_resilient(..., steps=N, resume=True)`, leaf-for-leaf bitwise
    (the fault suite asserts it). Checkpoints (atomic, digest-verified)
    carry params, EF residuals, the PRNG key, and the RecoveryManager's
    decision state under tag "resil" in `ckpt_dir`.

    `grad_hook(worker_grads, step_key)` optionally perturbs the
    per-worker gradients before aggregation in-graph (the step-guard
    tests inject a non-finite step through it). Returns a result dict
    (final params, EF, per-step losses, counters, manager).
    """
    from repro.ckpt import (latest_checkpoint, load_checkpoint,
                            save_checkpoint)
    from repro.core import build_plan, stacked_mask
    from repro.data import dirichlet_proportions
    from repro.sim import SimCluster, init_ef

    recovery = RecoveryConfig() if recovery is None else recovery
    manager = RecoveryManager(recovery, metrics=metrics)
    cluster = SimCluster(scenario, comp)
    key0 = jax.random.key(seed)

    params = runner.init(key0)
    sm = stacked_mask(params)
    n_max = max([scenario.n_workers]
                + [ev.world_size for ev in scenario.rescales])
    alpha = scenario.dirichlet_alpha
    props_all = (dirichlet_proportions(jax.random.fold_in(key0, 0xD),
                                       n_max, runner.categories, alpha)
                 if alpha is not None
                 else jnp.full((n_max, runner.categories),
                               1.0 / runner.categories))
    start = 0
    ef = init_ef(params, scenario.n_workers)

    if resume:
        if not ckpt_dir:
            raise ValueError("resume=True requires ckpt_dir")
        path = latest_checkpoint(ckpt_dir, tag=_CKPT_TAG)
        if path is None:
            raise ValueError(f"resume=True but no '{_CKPT_TAG}' "
                             f"checkpoint under {ckpt_dir!r}")
        m = _STEP_RE.search(path)
        start = int(m.group(1))
        like = {
            "params": params,
            "ef": init_ef(params, scenario.world_size_at(start - 1)
                          if start > 0 else scenario.n_workers),
            "key": jax.random.key_data(key0),
            "manager": manager.state(),
        }
        ck_step, restored = load_checkpoint(path, like=like)
        assert ck_step == start
        params = restored["params"]
        ef = restored["ef"]
        key0 = jax.random.wrap_key_data(restored["key"])
        manager.restore(restored["manager"])

    # ONE injector serves every trace: its verdict stream is drained
    # inside aggregate_simulated_workers' vmapped per-worker pass, so
    # no tracer outlives its trace
    injector = cluster.injector(resend=recovery.resend)

    step_cache: Dict = {}

    def build_step(n, per, fallback, alive_key):
        ck = (n, per, fallback, alive_key)
        if ck in step_cache:
            return step_cache[ck]
        alive = (None if alive_key is None
                 else np.asarray(alive_key, bool))

        @jax.jit
        def step(params, ef, wbatch, key):
            def one(b, k):
                return jax.value_and_grad(
                    lambda p: runner.loss(p, b, k))(params)
            wkeys = jax.vmap(lambda i: jax.random.fold_in(key, i))(
                jnp.arange(n))
            losses, wg = jax.vmap(one)(wbatch, wkeys)
            if grad_hook is not None:
                wg = grad_hook(wg, key)
            akey = jax.random.fold_in(key, 0xA)
            zero = jnp.zeros((), jnp.int32)
            info = {"messages": zero, "corrupt_detected": zero,
                    "resends": zero}
            if fallback:
                # dense fallback: the compressed wire is abandoned, so
                # Algorithm 1 degenerates to the (survivor-weighted)
                # plain mean — no packed bytes, nothing to corrupt; EF
                # residuals are carried untouched (a dense gradient has
                # no compression error to remember)
                if alive is None:
                    g = jax.tree_util.tree_map(
                        lambda x: jnp.mean(x, axis=0), wg)
                else:
                    w = jnp.asarray(alive, jnp.float32)
                    w = w / jnp.sum(w)
                    g = jax.tree_util.tree_map(
                        lambda x: jnp.tensordot(
                            w, x.astype(jnp.float32),
                            axes=1).astype(x.dtype), wg)
                new_ef = ef
            else:
                out = cluster.aggregate(
                    wg, sm, akey,
                    ef_state=ef if comp.error_feedback else None,
                    wire=True, faults=injector, alive=alive)
                g = out[0]
                new_ef = out[1] if comp.error_feedback else ef
                if injector is not None:
                    info = out[-1]
            loss = jnp.mean(losses)
            new_params = jax.tree_util.tree_map(
                lambda p, u: p - lr * u, params, g)
            if recovery.step_guard:
                finite = jnp.isfinite(loss) & _finite_tree(g)
                new_params = jax.tree_util.tree_map(
                    lambda a, b: jnp.where(finite, a, b), new_params,
                    params)
                new_ef = jax.tree_util.tree_map(
                    lambda a, b: jnp.where(finite, a, b), new_ef, ef)
                skipped = (1 - finite).astype(jnp.int32)
            else:
                skipped = jnp.zeros((), jnp.int32)
            return new_params, new_ef, loss, skipped, info

        step_cache[ck] = step
        return step

    losses = []
    for i in range(start, steps):
        n, ef, _changed = cluster.maybe_rescale(i, ef)
        per = max(1, runner.global_batch // n)
        wbatch = runner.worker_batch(jax.random.fold_in(key0, 100 + i),
                                     props_all[:n], per)
        alive = cluster.alive_mask(i, recovery.straggler_timeout_us)
        alive_key = None if alive is None else tuple(bool(a) for a in alive)
        fb = manager.fallback_active
        step = build_step(n, per, fb, alive_key)
        params, ef, loss, skipped, info = step(
            params, ef, wbatch, jax.random.fold_in(key0, 10_000 + i))
        losses.append(float(loss))
        manager.observe(detected=int(info["corrupt_detected"]),
                        resends=int(info["resends"]),
                        skipped=int(skipped))
        if metrics is not None:
            metrics.observe("resil/loss", float(loss))
            metrics.inc("resil/steps")
        if ckpt_dir and ckpt_every and (i + 1) % ckpt_every == 0:
            save_checkpoint(ckpt_dir, i + 1, {
                "params": params, "ef": ef,
                "key": jax.random.key_data(key0),
                "manager": manager.state(),
            }, tag=_CKPT_TAG)

    return {
        "params": params,
        "ef": ef,
        "losses": losses,
        "counters": dict(manager.counters),
        "fallback_active": manager.fallback_active,
        "manager": manager,
        "accounting": cluster.accounting,
    }
