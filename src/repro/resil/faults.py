"""In-graph data-plane fault injection for packed wire buffers.

A FaultInjector perturbs the uint8 bytes a receiver SEES — after
encode+pack, before decode — exactly where a real fabric corrupts them.
The sender-side buffer (measured wire truth, EF residuals, the
streaming token) always stays clean; see core.wire._receive_buffer.

Draws are pure functions of (step key, spec.seed, message/hop tag), so
a faulted run is exactly reproducible and two runs sharing keys corrupt
the same bytes — what lets the fault suite compare faulted-with-resend
against clean runs bitwise.

The injector is DUCK-TYPED against sim.scenario.CorruptionSpec (fields
prob / mode / n_bits / seed) so this module never imports repro.sim.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

#: modes acting on any received message (serialized or ring)
BYTE_MODES = ("bitflip", "truncate")
#: modes only meaningful for a ring hop (need the ring's topology)
HOP_MODES = ("drop_hop", "dup_hop")
CORRUPTION_MODES = BYTE_MODES + HOP_MODES


class FaultInjector:
    """Stateful per-trace injector: corrupt buffers in-graph, collect
    the integrity verdicts the executors note, and hand them back as
    one stacked bool array via `take_flags()`.

    `note()` appends TRACED booleans, so `take_flags()` MUST be called
    inside the same trace (e.g. inside the vmapped per-worker closure)
    — flags returned functionally, never smuggled across a jit/vmap
    boundary.

    `resend=True` models re-encode-and-resend: a message whose checksum
    fails is replaced by the sender's clean copy (the sender still
    holds it), so the decoded numerics match the clean run bitwise
    while the verdict stream still records the detection.
    """

    def __init__(self, spec, *, resend: bool = False):
        if spec.mode not in CORRUPTION_MODES:
            raise ValueError(f"unknown corruption mode {spec.mode!r}; "
                             f"expected one of {CORRUPTION_MODES}")
        self.spec = spec
        self.resend = bool(resend)
        self._events = []

    # ---- seeded draws ----------------------------------------------------
    def _key(self, key, tag: int):
        k = jax.random.fold_in(key, 0xFA17)
        k = jax.random.fold_in(k, int(self.spec.seed) & 0x7FFFFFFF)
        return jax.random.fold_in(k, int(tag))

    def _bitflip(self, buf, k, start: int):
        k1, k2 = jax.random.split(k)
        nb = int(self.spec.n_bits)
        pos = jax.random.randint(k1, (nb,), start, buf.size)
        bit = jax.random.randint(k2, (nb,), 0, 8).astype(jnp.uint8)
        return buf.at[pos].set(buf[pos] ^ (jnp.uint8(1) << bit))

    def _truncate(self, buf, k, start: int):
        cut = jax.random.randint(k, (), start, buf.size)
        return jnp.where(jnp.arange(buf.size) < cut, buf,
                         jnp.uint8(0))

    # ---- executor hooks --------------------------------------------------
    def corrupt(self, buf, key, *, tag: int, start: int = 0):
        """Maybe-corrupt one received message buffer (uint8 1-D).
        `start` floors the perturbed span (the header words before the
        checksummed region stay intact). Returns `buf` ITSELF (same
        object) when this injector cannot touch it — prob 0 or a
        hop-only mode — which is the executors' no-op fast path."""
        if float(self.spec.prob) <= 0.0 or self.spec.mode in HOP_MODES:
            return buf
        k = self._key(key, tag)
        k0, kd = jax.random.split(k)
        hit = jax.random.bernoulli(k0, float(self.spec.prob))
        dirty = (self._bitflip(buf, kd, start)
                 if self.spec.mode == "bitflip"
                 else self._truncate(buf, kd, start))
        return jnp.where(hit, dirty, buf)

    def corrupt_hop(self, arrived, stale, key, *, tag: int,
                    start: int = 0):
        """Maybe-corrupt one ARRIVING ring hop. `arrived` is the
        post-ppermute buffer, `stale` the pre-permute content this
        worker already held (what a duplicated hop re-delivers).
        drop_hop zeroes the whole message — detected because the
        Fletcher init=1 checksum of an all-zero span is nonzero;
        dup_hop delivers `stale`, a VALID stale message whose checksum
        passes (catching it needs sequence numbers)."""
        if float(self.spec.prob) <= 0.0:
            return arrived
        if self.spec.mode in BYTE_MODES:
            return self.corrupt(arrived, key, tag=tag, start=start)
        k0 = self._key(key, tag)
        hit = jax.random.bernoulli(k0, float(self.spec.prob))
        dirty = (jnp.zeros_like(arrived)
                 if self.spec.mode == "drop_hop" else stale)
        return jnp.where(hit, dirty, arrived)

    # ---- verdict stream --------------------------------------------------
    def note(self, tag: int, ok):
        """Record one integrity verdict (traced bool; True = passed)."""
        self._events.append(ok)

    def take_flags(self):
        """Drain the verdict stream -> bool[ n_noted ] (True = message
        verified clean). Call INSIDE the trace that produced the notes;
        an empty stream returns a zero-length array so callers can
        reduce it unconditionally."""
        ev, self._events = self._events, []
        if not ev:
            return jnp.zeros((0,), jnp.bool_)
        return jnp.stack(ev)
