"""Resilience plane: wire-fault injection, detection bookkeeping, and
recovery policies for the compressed pipeline.

`faults.FaultInjector` turns a CorruptionSpec (sim/scenario.py) into
in-graph perturbations of packed uint8 wire buffers — the hook
core.wire's executors call on every received message/hop — plus the
Fletcher-32 verdict stream the caller drains in-trace.

`recovery` wires detection to action: RecoveryConfig/RecoveryManager
(resend, dense fallback after repeated failures, non-finite step-guard,
partial participation) and `train_resilient`, the checkpointed training
loop with the bitwise train-N == train-k/resume/train-(N-k) contract.
"""
from repro.resil.faults import FaultInjector
from repro.resil.recovery import (RecoveryConfig, RecoveryManager,
                                  train_resilient)

__all__ = ["FaultInjector", "RecoveryConfig", "RecoveryManager",
           "train_resilient"]
