"""In-step compression telemetry over UnitPlan size-class buckets.

The control plane's sensors. A `TelemetryState` is a small pytree carried
through the jitted train step; `measure` produces a one-step increment by
ONE extra vmapped compressor pass per size-class bucket of a fixed
*measurement plan* (always the layerwise plan of the gradient tree, so the
state's shapes never change when the controller switches the *execution*
granularity) plus one pass on the flat gradient (the entire-model
counterfactual). No per-leaf loops anywhere: the gather/scatter machinery
is the UnitPlan's reshape-only run decomposition.

Measured per size class b (all sums over the bucket's (n_units, dim) rows):

  grad_sum / grad_sumsq    Σx, Σx²  — gradient norm & entry variance
  qw_sumsq                 Σ Q_W(x)²  — empirical Ω̂ = qw_sumsq/grad_sumsq−1
  qw_errsq                 Σ (Q_W(x)−x)² — per-unit compression error
  agg_errsq                Σ (ŷ−x)²   — end-to-end pipeline error (ŷ = the
                                        aggregated gradient the step applied)

plus the same three second moments for the whole flat gradient compressed
as ONE unit (`em_*`) — the signal `GranularitySwitchPolicy` compares
against the layer-wise trace.

Everything here is traceable (jit/vmap/shard_map-safe); `summarize` runs
on the host at re-plan boundaries and produces plain-Python JSON.
"""
from __future__ import annotations

import json
from typing import Dict, List, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.compressors import Compressor
from repro.core.granularity import Granularity
from repro.core.plan import UnitPlan, build_plan

Array = jax.Array

_EPS = 1e-30

#: version stamp of the controller's exported JSON (report()/--telemetry-out).
#: v2: added schema_version + the self-describing "active" decision block.
TELEMETRY_SCHEMA_VERSION = 2


class TelemetryState(NamedTuple):
    """Accumulated per-size-class statistics (a pytree of f32 arrays).

    `B` below is the number of size-class buckets of the measurement plan;
    scalars are 0-d. All fields are running sums over the accumulation
    window except `steps` (the window length).
    """
    steps: Array        # ()  number of accumulated steps
    grad_sum: Array     # (B,) Σ x
    grad_sumsq: Array   # (B,) Σ x²        (== Σ_units ‖x_u‖²)
    qw_sumsq: Array     # (B,) Σ Q_W(x)²
    qw_errsq: Array     # (B,) Σ (Q_W(x) − x)²
    agg_errsq: Array    # (B,) Σ (ŷ − x)²  (zero when ŷ not supplied)
    em_sumsq: Array     # ()  ‖x_flat‖²
    em_qw_sumsq: Array  # ()  ‖Q_W(x_flat)‖²
    em_errsq: Array     # ()  ‖Q_W(x_flat) − x_flat‖²


def measurement_plan(tree, stacked) -> UnitPlan:
    """The fixed layer-wise UnitPlan telemetry is measured over.

    Independent of the *active* execution granularity, so TelemetryState
    shapes are stable across controller decisions (no retrace on switch).
    """
    return build_plan(tree, stacked, Granularity("layerwise"))


def init_telemetry(mplan: UnitPlan) -> TelemetryState:
    b = mplan.num_dispatches
    z = jnp.zeros((b,), jnp.float32)
    s = jnp.zeros((), jnp.float32)
    return TelemetryState(steps=s, grad_sum=z, grad_sumsq=z, qw_sumsq=z,
                          qw_errsq=z, agg_errsq=z, em_sumsq=s,
                          em_qw_sumsq=s, em_errsq=s)


def accumulate(state: TelemetryState, inc: TelemetryState) -> TelemetryState:
    return jax.tree_util.tree_map(jnp.add, state, inc)


def measure(mplan: UnitPlan, qw: Compressor, grads, key: Array,
            grads_hat=None, entire_model: bool = True) -> TelemetryState:
    """One-step telemetry increment for `grads` (and optionally the
    aggregated output `grads_hat` the step actually applied).

    Uses the plan's own PRNG fold tables, so when the active decision IS
    layerwise the measured Q_W stream matches the executed one.
    `entire_model=False` skips the flat counterfactual compression pass
    (its `em_*` fields stay zero) — only GranularitySwitchPolicy and
    telemetry export consume it, and it is the expensive leg (one
    full-model Q_W per step).
    """
    leaves = jax.tree_util.tree_leaves(grads)
    hat_leaves = (jax.tree_util.tree_leaves(grads_hat)
                  if grads_hat is not None else None)
    flat = mplan.flatten(grads) if mplan.needs_flat else None
    hat_flat = (mplan.flatten(grads_hat)
                if grads_hat is not None and mplan.needs_flat else None)
    keys = mplan.unit_keys(key)

    gsum, gsq, qsq, qerr, aerr = [], [], [], [], []
    for b in mplan.buckets:
        x = mplan._gather_runs(leaves, flat, b)
        # the plan's OWN dispatch (one copy of the key-indexing/vmap
        # logic): the measured Q_W stream is the executed one
        q = mplan._dispatch(lambda v, k: qw.sim(v, k), b, x, keys)
        gsum.append(jnp.sum(x))
        gsq.append(jnp.sum(x * x))
        qsq.append(jnp.sum(q * q))
        qerr.append(jnp.sum((q - x) ** 2))
        if hat_leaves is not None:
            y = mplan._gather_runs(hat_leaves, hat_flat, b)
            aerr.append(jnp.sum((y - x) ** 2))
        else:
            aerr.append(jnp.zeros((), jnp.float32))

    if entire_model:
        # entire-model counterfactual: the flat gradient as ONE unit, with
        # the legacy entire_model key derivation (fold_in(key, 0)).
        em = (flat if flat is not None
              else jnp.concatenate([l.reshape(-1).astype(jnp.float32)
                                    for l in leaves])
              if len(leaves) > 1
              else leaves[0].reshape(-1).astype(jnp.float32))
        q_em = qw.sim(em, jax.random.fold_in(key, 0))
        em_sumsq = jnp.sum(em * em)
        em_qw_sumsq = jnp.sum(q_em * q_em)
        em_errsq = jnp.sum((q_em - em) ** 2)
    else:
        em_sumsq = em_qw_sumsq = em_errsq = jnp.zeros((), jnp.float32)
    return TelemetryState(
        steps=jnp.ones((), jnp.float32),
        grad_sum=jnp.stack(gsum),
        grad_sumsq=jnp.stack(gsq),
        qw_sumsq=jnp.stack(qsq),
        qw_errsq=jnp.stack(qerr),
        agg_errsq=jnp.stack(aerr),
        em_sumsq=em_sumsq,
        em_qw_sumsq=em_qw_sumsq,
        em_errsq=em_errsq,
    )


def _codec_or_none(qw: Compressor):
    from repro.core.wire import wire_codec
    try:
        return wire_codec(qw)
    except ValueError:
        return None


def payload_bits_per_step(mplan: UnitPlan, qw: Compressor,
                          measured: bool = True) -> int:
    """Static uplink payload bits per step, summed bucket-by-bucket
    (n_units × per-unit payload). Deliberately a different summation
    order than bits.comm_report's per-unit walk — the tests assert the
    two agree.

    `measured=True` (the default since the wire subsystem landed)
    charges each bucket the REAL packed wire size of its codec
    (core.wire, 8 x payload bytes — what schedule wire execution
    materializes; the differential suite proves the equality), falling
    back to the analytic accounting for compressors without a codec.
    `measured=False` keeps the pure accounting.
    """
    codec = _codec_or_none(qw) if measured else None
    total = 0
    for b in mplan.buckets:
        total += b.n * (codec.wire_bits(b.dim) if codec is not None
                        else qw.payload_bits(b.dim))
    return total


def summarize(state: TelemetryState, mplan: UnitPlan,
              qw: Optional[Compressor] = None) -> Dict:
    """Host-side window summary: plain Python floats, JSON-exportable.

    Per bucket: mean-per-step gradient energy, entry variance, empirical
    Ω̂ (= E‖Q(x)‖²/‖x‖² − 1), relative compression error, end-to-end
    relative aggregation error, and (when `qw` is given) the static
    payload bits the active compressor puts on the wire per step.
    """
    steps = float(state.steps)
    out: Dict = {"steps": steps, "buckets": [], "entire_model": {}}
    if steps == 0:
        return out
    gsum = [float(v) for v in state.grad_sum]
    gsq = [float(v) for v in state.grad_sumsq]
    qsq = [float(v) for v in state.qw_sumsq]
    qerr = [float(v) for v in state.qw_errsq]
    aerr = [float(v) for v in state.agg_errsq]
    codec = _codec_or_none(qw) if qw is not None else None
    total_payload = 0
    total_wire = 0
    for i, b in enumerate(mplan.buckets):
        n_elems = steps * b.n * b.dim
        mean = gsum[i] / n_elems
        var = max(0.0, gsq[i] / n_elems - mean * mean)
        entry = {
            "dim": b.dim,
            "n_units": b.n,
            "grad_norm_sq": gsq[i] / steps,
            "grad_var": var,
            "omega_hat": qsq[i] / (gsq[i] + _EPS) - 1.0,
            "rel_err": qerr[i] / (gsq[i] + _EPS),
            "agg_rel_err": aerr[i] / (gsq[i] + _EPS),
        }
        if qw is not None:
            entry["payload_bits"] = b.n * qw.payload_bits(b.dim)
            total_payload += entry["payload_bits"]
            if codec is not None:
                # measured leg: the REAL packed bytes x 8 (accounted +
                # word-padding slack — the wire truth)
                entry["wire_bits"] = b.n * codec.wire_bits(b.dim)
                total_wire += entry["wire_bits"]
        out["buckets"].append(entry)
    if qw is not None:
        out["payload_bits_per_step"] = total_payload
        if codec is not None:
            out["wire_bits_per_step"] = total_wire
    em_sq = float(state.em_sumsq)
    if em_sq > 0.0:  # counterfactual leg was measured (entire_model=True)
        out["entire_model"] = {
            "dim": mplan.total,
            "grad_norm_sq": em_sq / steps,
            "omega_hat": float(state.em_qw_sumsq) / (em_sq + _EPS) - 1.0,
            "rel_err": float(state.em_errsq) / (em_sq + _EPS),
        }
    return out


def unit_omegas(summary: Dict, mplan: UnitPlan,
                metric: str = "rel_err") -> List[float]:
    """Expand a window summary's per-bucket statistic to one value per
    accounting unit, in the plan's unit order (feeds the measured-omega
    form of theory.noise_bounds_from_plan)."""
    per_unit = [0.0] * mplan.num_exec_units
    for entry, b in zip(summary["buckets"], mplan.buckets):
        for uid in b.unit_ids:
            per_unit[uid] = float(entry[metric])
    return per_unit


def to_json(payload: Dict, path: str) -> None:
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
