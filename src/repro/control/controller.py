"""The adaptive compression controller: telemetry → policy → decision →
plan/step cache.

The Controller is harness-agnostic: it owns the *control plane* (what to
compress, how hard, at which granularity) and delegates the *data plane*
to a `build_step(decision) -> step_fn` factory supplied by the harness
(launch.engine for the sharded LM engine, benchmarks.common for the
simulated-worker CNN study). Compiled steps are cached per decision, so a
policy that revisits a decision NEVER retraces — the acceptance property
`builds == number of distinct decisions` is exposed as `self.builds`.

Lifecycle per step i:

    fn = ctrl.step_fn()              # cached jitted step for the decision
    ... run fn, threading ctrl.telemetry if ctrl.collect ...
    ctrl.observe(new_telem, i)       # store window; re-plan every K steps

At a re-plan boundary the controller summarizes the telemetry window on
the host, asks the policy for a decision, records the window + any switch
for JSON export, and resets the window.
"""
from __future__ import annotations

import math
import warnings
from typing import Callable, Dict, List, Optional

from repro.core.plan import UnitPlan

from repro.control.policy import CompressionDecision, Policy
from repro.control.telemetry import (TELEMETRY_SCHEMA_VERSION,
                                     TelemetryState, init_telemetry,
                                     summarize, to_json)


class Controller:
    def __init__(self, policy: Policy, build_step: Callable,
                 base: CompressionDecision, mplan: UnitPlan, *,
                 replan_every: int = 20,
                 collect_telemetry: Optional[bool] = None,
                 cache: Optional[dict] = None, cache_tag=None,
                 metrics=None):
        """`cache` may be shared between controllers (e.g. a sweep) — it
        is keyed on (decision, telemetry-enabled, cache_tag) so steps
        with different build shapes never collide; harnesses pass their
        extra build flags (e.g. the entire-model telemetry leg) as
        `cache_tag`. `metrics` (duck-typed, obs.metrics.MetricsRegistry)
        receives builds/switch/retrace counters."""
        self.policy = policy
        self.build_step = build_step
        self.mplan = mplan
        self.replan_every = max(1, int(replan_every))
        self.collect = (policy.needs_telemetry if collect_telemetry is None
                        else bool(collect_telemetry))
        self.decision = base
        self.telemetry: Optional[TelemetryState] = (
            init_telemetry(mplan) if self.collect else None)
        self._cache = {} if cache is None else cache
        self._cache_tag = cache_tag
        self.metrics = metrics
        self.builds = 0            # build_step invocations == retraces
        self.retraces_unexpected = 0   # rebuilds of previously-built keys
        self.jit_recompiles = 0    # extra jit signatures (informational)
        self._built_keys: set = set()
        self.switches: List[Dict] = []
        self.windows: List[Dict] = []

    # ---- data plane ------------------------------------------------------
    def step_fn(self):
        """The compiled step for the current decision (cached)."""
        return self._bundle(self.decision)

    def _bundle(self, decision: CompressionDecision):
        key = (decision, self.collect, self._cache_tag)
        if key not in self._cache:
            if key in self._built_keys:
                # retrace watchdog: revisiting a cached decision must be
                # a dict hit (the no-retrace acceptance property). A
                # rebuild here means the shared cache was cleared or
                # evicted behind our back — surface it, don't hide it.
                self.retraces_unexpected += 1
                if self.metrics is not None:
                    self.metrics.inc("controller/retraces_unexpected")
                warnings.warn(
                    f"unexpected retrace: decision "
                    f"{decision.describe()!r} was built before but is "
                    f"missing from the step cache (cleared or evicted?) "
                    f"— rebuilding", RuntimeWarning, stacklevel=3)
            self._cache[key] = self.build_step(decision)
            self.builds += 1
            self._built_keys.add(key)
            if self.metrics is not None:
                self.metrics.inc("controller/builds")
        return self._cache[key]

    def check_retraces(self) -> int:
        """The watchdog's unexpected-recompile count (cache-evicted
        rebuilds of previously-built decisions) — 0 on every healthy run
        (the regression test's gate). Also probes each cached step's
        jit for extra compiled signatures and stores the sum in
        `self.jit_recompiles`: that leg is informational, NOT folded
        into the return value, because one extra signature per step fn
        is normal — the first optimized step re-specializes once on the
        settled (donated) output shardings."""
        extra = 0
        for key in self._built_keys:
            fn = self._cache.get(key)
            size_fn = getattr(fn, "_cache_size", None)
            if callable(size_fn):
                try:
                    extra += max(0, int(size_fn()) - 1)
                except Exception:  # jax internals moved — skip the probe
                    continue
        self.jit_recompiles = extra
        if self.metrics is not None:
            self.metrics.gauge("controller/retraces_unexpected_total",
                               self.retraces_unexpected)
            self.metrics.gauge("controller/jit_recompiles", extra)
        return self.retraces_unexpected

    def config(self):
        return self.decision.to_config()

    def set_decision(self, decision: CompressionDecision) -> None:
        """Force a decision (sweeps / tests). Keeps the cache."""
        self.decision = decision
        if self.collect:
            self.telemetry = init_telemetry(self.mplan)

    # ---- control plane ---------------------------------------------------
    def observe(self, telemetry: Optional[TelemetryState],
                step_idx: int) -> bool:
        """Record the step's returned telemetry state; at a re-plan
        boundary summarize the window and consult the policy. Returns
        True when the decision changed."""
        if self.collect and telemetry is not None:
            self.telemetry = telemetry
        if (step_idx + 1) % self.replan_every:
            return False
        return self._replan(step_idx)

    def _replan(self, step_idx: int) -> bool:
        summary = (summarize(self.telemetry, self.mplan,
                             qw=self.config().qw)
                   if self.collect else {})
        self.windows.append({"step": step_idx,
                             "decision": self.decision.describe(),
                             "summary": summary})
        new = self.policy.decide(summary, self.decision, self.mplan)
        changed = new != self.decision
        if self.metrics is not None:
            self.metrics.inc("controller/replans")
        if changed:
            self.switches.append({"step": step_idx,
                                  "from": self.decision.describe(),
                                  "to": new.describe()})
            self.decision = new
            if self.metrics is not None:
                self.metrics.inc("controller/switches")
        if self.collect:  # fresh window per re-plan interval
            self.telemetry = init_telemetry(self.mplan)
        return changed

    # ---- export ----------------------------------------------------------
    def active_decision(self) -> Dict:
        """The current decision as a self-describing plain dict (the
        `active` block of report()/--telemetry-out: policy name,
        compressors, granularity, fusion_bytes, ratios) — joinable with
        trace/metrics exports without parsing describe() strings."""
        d = self.decision
        fb = d.fusion_bytes
        return {
            "policy": self.policy.name,
            "decision": d.describe(),
            "granularity": d.granularity.kind,
            "compressor": d.qw.name,
            "master_compressor": d.qm.name,
            "strategy": d.strategy,
            "error_feedback": d.error_feedback,
            "wire_dtype": d.wire_dtype,
            "ratio": getattr(d.qw, "ratio", None),
            "ratio_overrides": {str(dim): r
                                for dim, r in d.ratio_overrides},
            "fusion_bytes": (None if fb is None
                             else "inf" if math.isinf(fb) else fb),
        }

    def report(self) -> Dict:
        return {
            "schema_version": TELEMETRY_SCHEMA_VERSION,
            "policy": self.policy.name,
            "replan_every": self.replan_every,
            "decision": self.decision.describe(),
            "active": self.active_decision(),
            "builds": self.builds,
            "retraces_unexpected": self.check_retraces(),
            "jit_recompiles": self.jit_recompiles,
            "switches": self.switches,
            "windows": self.windows,
        }

    def export(self, path: str) -> None:
        to_json(self.report(), path)


def engine_controller(engine, policy: Policy, *, lr_schedule=None,
                      base: Optional[CompressionDecision] = None,
                      replan_every: int = 20,
                      collect_telemetry: Optional[bool] = None,
                      cache: Optional[dict] = None,
                      metrics=None, tracer=None) -> Controller:
    """Controller over launch.engine.Engine's sharded train step. The
    step factory threads the decision's CompressionConfig (and, when
    telemetry is on, the TelemetryState leg) through
    Engine.build_train_step. `metrics`/`tracer` (duck-typed obs
    registry/recorder) instrument the built steps and the controller's
    own counters."""
    from repro.core.aggregation import no_compression
    if base is None:
        base = CompressionDecision.from_config(
            engine.comp if engine.comp is not None else no_compression())
    collect = (policy.needs_telemetry if collect_telemetry is None
               else bool(collect_telemetry))
    em = getattr(policy, "needs_entire_model", True)

    def build(decision: CompressionDecision):
        return engine.build_train_step(lr_schedule,
                                       comp=decision.to_config(),
                                       telemetry=collect,
                                       telemetry_entire_model=em,
                                       tracer=tracer, metrics=metrics)

    # the tag carries every build input besides the decision, so a cache
    # shared across controllers never hands back a step compiled for a
    # different engine/schedule/telemetry shape (the tracer embeds
    # callbacks in the traced graph, so it is part of the build shape)
    return Controller(policy, build, base, engine.measurement_plan(),
                      replan_every=replan_every, collect_telemetry=collect,
                      cache=cache, metrics=metrics,
                      cache_tag=("engine", engine, lr_schedule, em, tracer))
