"""The adaptive compression controller: telemetry → policy → decision →
plan/step cache.

The Controller is harness-agnostic: it owns the *control plane* (what to
compress, how hard, at which granularity) and delegates the *data plane*
to a `build_step(decision) -> step_fn` factory supplied by the harness
(launch.engine for the sharded LM engine, benchmarks.common for the
simulated-worker CNN study). Compiled steps are cached per decision, so a
policy that revisits a decision NEVER retraces — the acceptance property
`builds == number of distinct decisions` is exposed as `self.builds`.

Lifecycle per step i:

    fn = ctrl.step_fn()              # cached jitted step for the decision
    ... run fn, threading ctrl.telemetry if ctrl.collect ...
    ctrl.observe(new_telem, i)       # store window; re-plan every K steps

At a re-plan boundary the controller summarizes the telemetry window on
the host, asks the policy for a decision, records the window + any switch
for JSON export, and resets the window.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional

from repro.core.plan import UnitPlan

from repro.control.policy import CompressionDecision, Policy
from repro.control.telemetry import (TelemetryState, init_telemetry,
                                     summarize, to_json)


class Controller:
    def __init__(self, policy: Policy, build_step: Callable,
                 base: CompressionDecision, mplan: UnitPlan, *,
                 replan_every: int = 20,
                 collect_telemetry: Optional[bool] = None,
                 cache: Optional[dict] = None, cache_tag=None):
        """`cache` may be shared between controllers (e.g. a sweep) — it
        is keyed on (decision, telemetry-enabled, cache_tag) so steps
        with different build shapes never collide; harnesses pass their
        extra build flags (e.g. the entire-model telemetry leg) as
        `cache_tag`."""
        self.policy = policy
        self.build_step = build_step
        self.mplan = mplan
        self.replan_every = max(1, int(replan_every))
        self.collect = (policy.needs_telemetry if collect_telemetry is None
                        else bool(collect_telemetry))
        self.decision = base
        self.telemetry: Optional[TelemetryState] = (
            init_telemetry(mplan) if self.collect else None)
        self._cache = {} if cache is None else cache
        self._cache_tag = cache_tag
        self.builds = 0            # build_step invocations == retraces
        self.switches: List[Dict] = []
        self.windows: List[Dict] = []

    # ---- data plane ------------------------------------------------------
    def step_fn(self):
        """The compiled step for the current decision (cached)."""
        return self._bundle(self.decision)

    def _bundle(self, decision: CompressionDecision):
        key = (decision, self.collect, self._cache_tag)
        if key not in self._cache:
            self._cache[key] = self.build_step(decision)
            self.builds += 1
        return self._cache[key]

    def config(self):
        return self.decision.to_config()

    def set_decision(self, decision: CompressionDecision) -> None:
        """Force a decision (sweeps / tests). Keeps the cache."""
        self.decision = decision
        if self.collect:
            self.telemetry = init_telemetry(self.mplan)

    # ---- control plane ---------------------------------------------------
    def observe(self, telemetry: Optional[TelemetryState],
                step_idx: int) -> bool:
        """Record the step's returned telemetry state; at a re-plan
        boundary summarize the window and consult the policy. Returns
        True when the decision changed."""
        if self.collect and telemetry is not None:
            self.telemetry = telemetry
        if (step_idx + 1) % self.replan_every:
            return False
        return self._replan(step_idx)

    def _replan(self, step_idx: int) -> bool:
        summary = (summarize(self.telemetry, self.mplan,
                             qw=self.config().qw)
                   if self.collect else {})
        self.windows.append({"step": step_idx,
                             "decision": self.decision.describe(),
                             "summary": summary})
        new = self.policy.decide(summary, self.decision, self.mplan)
        changed = new != self.decision
        if changed:
            self.switches.append({"step": step_idx,
                                  "from": self.decision.describe(),
                                  "to": new.describe()})
            self.decision = new
        if self.collect:  # fresh window per re-plan interval
            self.telemetry = init_telemetry(self.mplan)
        return changed

    # ---- export ----------------------------------------------------------
    def report(self) -> Dict:
        return {
            "policy": self.policy.name,
            "replan_every": self.replan_every,
            "decision": self.decision.describe(),
            "builds": self.builds,
            "switches": self.switches,
            "windows": self.windows,
        }

    def export(self, path: str) -> None:
        to_json(self.report(), path)


def engine_controller(engine, policy: Policy, *, lr_schedule=None,
                      base: Optional[CompressionDecision] = None,
                      replan_every: int = 20,
                      collect_telemetry: Optional[bool] = None,
                      cache: Optional[dict] = None) -> Controller:
    """Controller over launch.engine.Engine's sharded train step. The
    step factory threads the decision's CompressionConfig (and, when
    telemetry is on, the TelemetryState leg) through
    Engine.build_train_step."""
    from repro.core.aggregation import no_compression
    if base is None:
        base = CompressionDecision.from_config(
            engine.comp if engine.comp is not None else no_compression())
    collect = (policy.needs_telemetry if collect_telemetry is None
               else bool(collect_telemetry))
    em = getattr(policy, "needs_entire_model", True)

    def build(decision: CompressionDecision):
        return engine.build_train_step(lr_schedule,
                                       comp=decision.to_config(),
                                       telemetry=collect,
                                       telemetry_entire_model=em)

    # the tag carries every build input besides the decision, so a cache
    # shared across controllers never hands back a step compiled for a
    # different engine/schedule/telemetry shape
    return Controller(policy, build, base, engine.measurement_plan(),
                      replan_every=replan_every, collect_telemetry=collect,
                      cache=cache,
                      cache_tag=("engine", engine, lr_schedule, em))
