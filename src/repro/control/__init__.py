"""Adaptive compression control plane: in-step telemetry, pluggable
policies, and a decision -> (UnitPlan, compiled step) cache. See
README.md §"Adaptive control loop"."""
from repro.control.telemetry import (TelemetryState, accumulate,
                                     init_telemetry, measure,
                                     measurement_plan, payload_bits_per_step,
                                     summarize, to_json, unit_omegas)
from repro.control.policy import (FUSION_LADDER, POLICIES, RATIO_LADDER,
                                  AdaptiveKPolicy, BitBudgetPolicy,
                                  CompressionDecision, FusionPolicy,
                                  GranularitySwitchPolicy, PerDimRatio,
                                  Policy, StaticPolicy, VarianceBudgetPolicy,
                                  make_policy)
from repro.control.controller import Controller, engine_controller
