"""Pluggable compression policies: telemetry window in, decision out.

Policies run in plain Python at re-plan boundaries (every K steps) — they
never appear inside the jitted step. A `CompressionDecision` is a frozen,
hashable value object: the controller keys its (UnitPlan, compiled step)
cache on it, so a policy that oscillates between a small set of decisions
never retraces twice.

  StaticPolicy             today's behavior: one fixed decision.
  VarianceBudgetPolicy     per-bucket sparsification ratio chosen to keep
                           relative compression error under a budget
                           (Tsuzuku et al.'s variance-based compression,
                           applied per size class).
  GranularitySwitchPolicy  layer-wise vs entire-model by the paper's
                           Trace(A) bound evaluated on MEASURED omegas
                           (theory.noise_bounds_from_plan) against the
                           measured entire-model counterfactual.
  BitBudgetPolicy          greedy per-bucket ratio allocation maximizing
                           captured gradient energy under a total
                           uplink-bits/step budget.
  AdaptiveKPolicy          Shi et al.'s layer-wise adaptive-k: split a
                           flat top-k element budget across buckets
                           proportionally to measured gradient energy.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Protocol, Sequence, Tuple, runtime_checkable

from repro.core.aggregation import CompressionConfig
from repro.core.compressors import Compressor, Identity
from repro.core.granularity import Granularity
from repro.core.plan import UnitPlan
from repro.core.schedule import build_schedule, simulate_schedule
from repro.core import theory

from repro.control.telemetry import unit_omegas

RATIO_LADDER = (0.005, 0.01, 0.02, 0.05, 0.1, 0.2, 0.5, 1.0)

#: fusion_bytes candidates FusionPolicy picks from: per-bucket messages,
#: Horovod-ish small/medium/large fusion buffers, one fused message.
FUSION_LADDER = (0.0, 4096.0, 65536.0, float(1 << 20), math.inf)


@dataclasses.dataclass(frozen=True)
class PerDimRatio(Compressor):
    """Wrap a ratio-bearing compressor with a per-unit-dimension ratio
    table. Inside plan execution every unit arrives flat with a static
    dimension, so the lookup is trace-time static; payload/omega
    accounting resolves per dim the same way (which is how comm_report
    tracks per-bucket ratios without knowing about decisions)."""

    name: str = "per_dim_ratio"
    base: Compressor = Identity()
    table: Tuple[Tuple[int, float], ...] = ()  # (unit dim, ratio)

    def __post_init__(self):
        object.__setattr__(self, "name", f"{self.base.name}[adaptive]")
        object.__setattr__(self, "unbiased", self.base.unbiased)

    def for_dim(self, d: int) -> Compressor:
        for dim, r in self.table:
            if dim == d:
                return dataclasses.replace(self.base, ratio=r)
        return self.base

    def sim(self, x, key):
        return self.for_dim(x.shape[0]).sim(x, key)

    def encode(self, x, key):
        return self.for_dim(x.shape[0]).encode(x, key)

    def decode(self, payload, d, dtype=None):
        c = self.for_dim(d)
        return (c.decode(payload, d) if dtype is None
                else c.decode(payload, d, dtype))

    def payload_bits(self, d: int) -> int:
        return self.for_dim(d).payload_bits(d)

    def omega(self, d: int) -> Optional[float]:
        return self.for_dim(d).omega(d)


@dataclasses.dataclass(frozen=True)
class CompressionDecision:
    """A policy's output: everything needed to materialize a
    CompressionConfig (and therefore a UnitPlan + jitted step). Frozen +
    tuple fields => hashable, the controller's cache key. `fusion_bytes`
    (None = unscheduled; a float incl. math.inf = stream through the
    CommSchedule fused at that threshold) is an ordinary hashable field,
    so decisions carrying a schedule keep the never-retrace guarantee:
    revisiting a (.., fusion_bytes) combination hits the step cache."""

    granularity: Granularity = Granularity("layerwise")
    qw: Compressor = Identity()
    qm: Compressor = Identity()
    strategy: str = "simulated"
    error_feedback: bool = False
    wire_dtype: str = "float32"
    ratio_overrides: Tuple[Tuple[int, float], ...] = ()  # unit dim -> ratio
    fusion_bytes: Optional[float] = None  # comm schedule fusion threshold

    def compressor_for_dim(self, d: int) -> Compressor:
        for dim, r in self.ratio_overrides:
            if dim == d and hasattr(self.qw, "ratio"):
                return dataclasses.replace(self.qw, ratio=r)
        return self.qw

    def to_config(self) -> CompressionConfig:
        qw = self.qw
        if (self.ratio_overrides and hasattr(qw, "ratio")
                and self.strategy != "shared_random"):
            # shared_random's collective requires the bare RandomK (its
            # shared-seed index trick reads qw directly); overrides are
            # ignored there — the ratio policies also decline to emit them.
            qw = PerDimRatio(base=qw, table=self.ratio_overrides)
        return CompressionConfig(
            qw=qw, qm=self.qm, granularity=self.granularity,
            strategy=self.strategy, error_feedback=self.error_feedback,
            wire_dtype=self.wire_dtype, fusion_bytes=self.fusion_bytes)

    @classmethod
    def from_config(cls, cfg: CompressionConfig) -> "CompressionDecision":
        qw, overrides = cfg.qw, ()
        if isinstance(qw, PerDimRatio):
            qw, overrides = qw.base, qw.table
        return cls(granularity=cfg.granularity, qw=qw, qm=cfg.qm,
                   strategy=cfg.strategy, error_feedback=cfg.error_feedback,
                   wire_dtype=cfg.wire_dtype, ratio_overrides=overrides,
                   fusion_bytes=cfg.fusion_bytes)

    def payload_bits(self, unit_dims: Sequence[int]) -> int:
        """Uplink payload bits/step under this decision's per-dim ratios."""
        return sum(self.compressor_for_dim(d).payload_bits(d)
                   for d in unit_dims)

    def describe(self) -> str:
        ov = (f" overrides={len(self.ratio_overrides)}"
              if self.ratio_overrides else "")
        fb = ""
        if self.fusion_bytes is not None:
            fb = (" fuse=inf" if math.isinf(self.fusion_bytes)
                  else f" fuse={int(self.fusion_bytes)}B")
        return (f"{self.granularity.kind}/{self.qw.name}"
                f"/{self.strategy}{ov}{fb}")


@runtime_checkable
class Policy(Protocol):
    """decide() runs on the host at a re-plan boundary. `summary` is the
    telemetry window summary (telemetry.summarize), `current` the active
    decision, `mplan` the measurement plan. Must be pure: same inputs,
    same decision."""

    name: str
    needs_telemetry: bool

    def decide(self, summary: Dict, current: CompressionDecision,
               mplan: Optional[UnitPlan] = None) -> CompressionDecision:
        ...


@dataclasses.dataclass(frozen=True)
class StaticPolicy:
    """Today's behavior: never deviates from the active decision."""

    name: str = "static"
    needs_telemetry: bool = False
    needs_entire_model: bool = True  # for telemetry-export-only runs

    def decide(self, summary, current, mplan=None):
        return current


def _base_ratio(decision: CompressionDecision, dim: int) -> float:
    c = decision.compressor_for_dim(dim)
    return float(getattr(c, "ratio", 1.0))


def _pick_ratio(ladder: Sequence[float], threshold: float) -> float:
    """Smallest ladder ratio >= threshold (max ladder entry if none)."""
    for r in sorted(ladder):
        if r >= threshold:
            return r
    return max(ladder)


@dataclasses.dataclass(frozen=True)
class VarianceBudgetPolicy:
    """Per-bucket ratio to keep predicted relative compression error
    within `budget` (à la Tsuzuku et al.: compress only as much as the
    gradient's noise floor allows). The error model is the monotone
    first-order one: rel_err(r) ≈ rel_err_measured · r_current / r, so a
    tighter budget always selects an equal-or-larger ratio — i.e. never
    fewer bits (property-tested)."""

    budget: float = 0.1
    ladder: Tuple[float, ...] = RATIO_LADDER
    name: str = "variance_budget"
    needs_telemetry: bool = True
    needs_entire_model: bool = False

    def decide(self, summary, current, mplan=None):
        if (not summary.get("buckets") or not hasattr(current.qw, "ratio")
                or current.strategy == "shared_random"):
            return current
        overrides = []
        for entry in summary["buckets"]:
            dim = entry["dim"]
            r_cur = _base_ratio(current, dim)
            need = entry["rel_err"] * r_cur / max(self.budget, 1e-12)
            overrides.append((dim, _pick_ratio(self.ladder, need)))
        return dataclasses.replace(current,
                                   ratio_overrides=tuple(sorted(overrides)))


@dataclasses.dataclass(frozen=True)
class GranularitySwitchPolicy:
    """The paper's framework-should-choose conclusion, executed: compare
    the layer-wise noise trace Σ_j d_j(1+Ω̂_j) (Trace(A) on measured
    per-unit omegas, via theory.noise_bounds_from_plan) against the
    measured entire-model trace d·(1+Ω̂_em), and pick the smaller.
    `margin` is switch hysteresis (relative advantage required to move
    away from the current granularity)."""

    margin: float = 0.05
    name: str = "granularity_switch"
    needs_telemetry: bool = True
    needs_entire_model: bool = True

    def decide(self, summary, current, mplan=None):
        if (mplan is None or not summary.get("buckets")
                or current.granularity.kind == "blockwise"):
            return current
        em = summary.get("entire_model")
        if not em:  # counterfactual leg not measured this window
            return current
        omegas = unit_omegas(summary, mplan, metric="rel_err")
        lw_trace, _ = theory.noise_bounds_from_plan(mplan,
                                                    measured_w=omegas)
        em_trace = em["dim"] * (1.0 + em["rel_err"])
        if current.granularity.kind == "layerwise":
            better = em_trace < lw_trace * (1.0 - self.margin)
            target = "entire_model" if better else "layerwise"
        else:
            better = lw_trace < em_trace * (1.0 - self.margin)
            target = "layerwise" if better else "entire_model"
        if target == current.granularity.kind:
            return current
        return dataclasses.replace(current, granularity=Granularity(target))


@dataclasses.dataclass(frozen=True)
class BitBudgetPolicy:
    """Maximize captured gradient energy subject to a total uplink
    bits/step budget: start every bucket at the smallest ladder ratio,
    then greedily upgrade the bucket with the best marginal
    energy-per-bit until the budget is exhausted.

    The smallest ladder ratio is the floor: when even the floor
    allocation exceeds `bits_per_step`, the floor decision is returned
    anyway (the policy compresses as hard as it can rather than stalling
    training) — size the ladder/budget so the floor fits."""

    bits_per_step: int = 1 << 22
    ladder: Tuple[float, ...] = RATIO_LADDER
    name: str = "bit_budget"
    needs_telemetry: bool = True
    needs_entire_model: bool = False

    def _bits(self, decision, dim, n, r):
        c = dataclasses.replace(decision.qw, ratio=r)
        return n * c.payload_bits(dim)

    def decide(self, summary, current, mplan=None):
        buckets = summary.get("buckets")
        if (not buckets or not hasattr(current.qw, "ratio")
                or current.strategy == "shared_random"):
            return current
        ladder = sorted(self.ladder)
        level = {e["dim"]: 0 for e in buckets}
        info = {e["dim"]: e for e in buckets}

        def energy(entry, r):
            r_cur = _base_ratio(current, entry["dim"])
            rel_err = min(1.0, entry["rel_err"] * r_cur / max(r, 1e-12))
            return (1.0 - rel_err) * entry["grad_norm_sq"]

        total = sum(self._bits(current, d, info[d]["n_units"], ladder[0])
                    for d in level)
        while True:
            best, best_gain = None, 0.0
            for d, lv in level.items():
                if lv + 1 >= len(ladder):
                    continue
                e = info[d]
                extra = (self._bits(current, d, e["n_units"], ladder[lv + 1])
                         - self._bits(current, d, e["n_units"], ladder[lv]))
                if total + extra > self.bits_per_step:
                    continue
                # extra == 0: rounding kept k identical — a free upgrade
                gain = (float("inf") if extra <= 0 else
                        (energy(e, ladder[lv + 1]) - energy(e, ladder[lv]))
                        / extra)
                if gain > best_gain:
                    best, best_gain, best_extra = d, gain, extra
            if best is None:
                break
            level[best] += 1
            total += best_extra
        overrides = tuple(sorted((d, ladder[lv]) for d, lv in level.items()))
        return dataclasses.replace(current, ratio_overrides=overrides)


@dataclasses.dataclass(frozen=True)
class AdaptiveKPolicy:
    """Shi et al.'s layer-wise adaptive-k sparsification (arXiv
    1911.08727): keep the GLOBAL element budget of a flat `avg_ratio`
    top-k (budget = avg_ratio · total elements) but split it across
    buckets proportionally to each bucket's share of the measured
    gradient energy — layers currently carrying more of the gradient
    norm get a larger per-layer k, quiet layers get squeezed. Ratios
    snap to the ladder, so the emitted decisions form a small closed
    set and revisiting one hits the controller's compiled-step cache
    (never retraces).

    With no measured energy (all-zero window) every bucket falls back
    to the flat `avg_ratio` — the policy degrades to uniform top-k
    rather than emitting NaN shares."""

    avg_ratio: float = 0.05
    ladder: Tuple[float, ...] = RATIO_LADDER
    name: str = "adaptive_k"
    needs_telemetry: bool = True
    needs_entire_model: bool = False

    def decide(self, summary, current, mplan=None):
        buckets = summary.get("buckets")
        if (not buckets or not hasattr(current.qw, "ratio")
                or current.strategy == "shared_random"):
            return current
        elems = {e["dim"]: e["n_units"] * e["dim"] for e in buckets}
        budget = self.avg_ratio * sum(elems.values())
        total_energy = sum(e["grad_norm_sq"] for e in buckets)
        overrides = []
        for entry in buckets:
            dim = entry["dim"]
            if total_energy <= 0.0:
                want = self.avg_ratio
            else:
                share = entry["grad_norm_sq"] / total_energy
                want = budget * share / elems[dim]
            overrides.append((dim, _pick_ratio(self.ladder, want)))
        return dataclasses.replace(current,
                                   ratio_overrides=tuple(sorted(overrides)))


@dataclasses.dataclass(frozen=True)
class FusionPolicy:
    """Pick the comm-schedule fusion threshold from telemetry: for each
    candidate `fusion_bytes` in the ladder, price the window's measured
    per-bucket payload bits through the deterministic alpha-beta pipeline
    model (core.schedule.simulate_schedule) and choose the threshold with
    the smallest modeled step-completion time. High link alpha pushes
    toward one fused message (pay latency once); alpha ~ 0 pushes toward
    per-bucket messages (start streaming the moment backward produces a
    bucket). Ties break toward the earlier ladder entry (less fusion).

    Only the fusion_bytes field of the decision ever changes, and the
    ladder is finite — so the controller's decision -> compiled-step
    cache sees a small closed set of keys and revisiting a threshold
    never retraces (the builds-counter test).

    Modeled on the layer-wise measurement plan; non-layerwise decisions
    pass through unchanged (entire-model / blockwise plans are a single
    wire unit — there is nothing to fuse).
    """

    alpha_us: float = 50.0
    gbps: float = 12.5            # link bandwidth, GB/s (100 Gb/s)
    compress_gbps: float = 25.0   # compression-stream throughput, GB/s
    ladder: Tuple[float, ...] = FUSION_LADDER
    name: str = "fusion"
    needs_telemetry: bool = True
    needs_entire_model: bool = False

    def decide(self, summary, current, mplan=None):
        if mplan is None or current.granularity.kind != "layerwise":
            return current
        buckets = summary.get("buckets") or []
        bucket_bits = None
        if len(buckets) == len(mplan.buckets) and all(
                "payload_bits" in e for e in buckets):
            bucket_bits = [e["payload_bits"] for e in buckets]
        else:  # no measured window: static bits from the active decision
            qw = current.to_config().qw
            bucket_bits = [b.n * qw.payload_bits(b.dim)
                           for b in mplan.buckets]
        best, best_t = None, None
        for fb in self.ladder:
            sim = simulate_schedule(
                build_schedule(mplan, fb), bucket_bits=bucket_bits,
                alpha_us=self.alpha_us, gbps=self.gbps,
                compress_gbps=self.compress_gbps)
            if best_t is None or sim["t_total_us"] < best_t:
                best, best_t = fb, sim["t_total_us"]
        if best == current.fusion_bytes:
            return current
        return dataclasses.replace(current, fusion_bytes=best)


POLICIES = ("static", "variance_budget", "granularity_switch", "bit_budget",
            "adaptive_k", "fusion")


def make_policy(name: str, **kw) -> Policy:
    """Build a policy by CLI name. kw are dataclass fields (budget=,
    bits_per_step=, margin=, ladder=, alpha_us=, avg_ratio=)."""
    table = {"static": StaticPolicy, "variance_budget": VarianceBudgetPolicy,
             "granularity_switch": GranularitySwitchPolicy,
             "bit_budget": BitBudgetPolicy, "adaptive_k": AdaptiveKPolicy,
             "fusion": FusionPolicy}
    if name not in table:
        raise ValueError(f"unknown policy {name!r}; have {sorted(table)}")
    return table[name](**kw)
