"""Llama-3.1 405B — dense, GQA, 128k vocab [arXiv:2407.21783]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b", arch_type="dense",
    n_layers=126, d_model=16384, vocab=128256,
    n_heads=128, n_kv_heads=8, d_head=128, rope_theta=5e5,
    d_ff=53248,
    use_fsdp=True,
    train_microbatch=16,
)

SMOKE = ModelConfig(
    name="llama3-smoke", arch_type="dense",
    n_layers=2, d_model=128, vocab=512,
    n_heads=4, n_kv_heads=2, d_head=32, d_ff=256,
    dtype="float32",
)
