"""Phi-4-mini 3.8B — dense, RoPE SwiGLU GQA [arXiv:2412.08905].

24 heads do not divide the 16-way TP axis; the runtime pads to 32 heads
(zero-masked outputs). LONG_CONTEXT is the sliding-window variant that
qualifies this dense arch for long_500k per the assignment's carve-out.
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b", arch_type="dense",
    n_layers=32, d_model=3072, vocab=200064,
    n_heads=24, n_kv_heads=8, d_head=128, rope_theta=1e4,
    d_ff=8192,
)

LONG_CONTEXT = dataclasses.replace(CONFIG, name="phi4-mini-3.8b-swa",
                                   sliding_window=8192, swa_pattern=0)

SMOKE = ModelConfig(
    name="phi4-smoke", arch_type="dense",
    n_layers=2, d_model=96, vocab=512,
    n_heads=3, n_kv_heads=1, d_head=32, d_ff=256,
    dtype="float32",
)
