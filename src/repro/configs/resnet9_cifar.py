"""The paper's own benchmark models (DAWNBench CNNs) at CPU scale.

Used by the §Repro experiments (benchmarks/fig*.py): layer-wise vs
entire-model compression on image classification, mirroring the paper's
AlexNet / ResNet-9 study on CIFAR-10 (synthetic CIFAR-shaped data here —
no dataset gates in this container)."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class CNNConfig:
    name: str
    widths: tuple = (16, 32, 64)   # channels per stage
    classes: int = 10
    hw: int = 32
    channels: int = 3
    kind: str = "resnet9"          # resnet9 | alexnet | mlp


RESNET9 = CNNConfig(name="resnet9-cifar", widths=(16, 32, 64))
ALEXNET = CNNConfig(name="alexnet-cifar", widths=(16, 32, 64),
                    kind="alexnet")
MLP = CNNConfig(name="mlp-cifar", widths=(256, 128), kind="mlp")
