"""MiniCPM3-4B — dense with Multi-head Latent Attention
[hf:openbmb/MiniCPM3-4B]: q_lora_rank=768, kv_lora_rank=256,
qk_nope/rope=64/32, v_head=64. Decode uses the absorbed-MLA trick against
a latent cache (256+32 floats per position instead of 40 full kv heads)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b", arch_type="dense", attention="mla",
    n_layers=62, d_model=2560, vocab=73448,
    n_heads=40, n_kv_heads=40, d_head=96, rope_theta=1e4,
    d_ff=6400,
    q_lora_rank=768, kv_lora_rank=256,
    qk_nope_dim=64, qk_rope_dim=32, v_head_dim=64,
)

SMOKE = ModelConfig(
    name="minicpm3-smoke", arch_type="dense", attention="mla",
    n_layers=2, d_model=128, vocab=512,
    n_heads=4, n_kv_heads=4, d_head=48, d_ff=256,
    q_lora_rank=48, kv_lora_rank=32,
    qk_nope_dim=32, qk_rope_dim=16, v_head_dim=32,
    dtype="float32",
)
