"""Mamba2-1.3B — attention-free SSD (state-space duality)
[arXiv:2405.21060]. d_inner=4096, 64 heads of 64, state N=128, chunk 64.
Decode state is O(1) in sequence length: long_500k runs natively."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b", arch_type="ssm", attention="none",
    n_layers=48, d_model=2048, vocab=50280,
    d_ff=0, tie_embeddings=True,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_chunk=64,
)

SMOKE = ModelConfig(
    name="mamba2-smoke", arch_type="ssm", attention="none",
    n_layers=2, d_model=128, vocab=512,
    d_ff=0, tie_embeddings=True,
    ssm_state=16, ssm_expand=2, ssm_head_dim=32, ssm_chunk=8,
    dtype="float32",
)
