"""Granite-20B (code) — dense llama-arch with MQA (kv=1)
[arXiv:2405.04324]. The single kv head is TP-replicated (tp_shared grad
sync); the decode cache is sequence-sharded over the model axis."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b", arch_type="dense",
    n_layers=52, d_model=6144, vocab=49152,
    n_heads=48, n_kv_heads=1, d_head=128, rope_theta=1e4,
    d_ff=24576,
)

SMOKE = ModelConfig(
    name="granite-smoke", arch_type="dense",
    n_layers=2, d_model=128, vocab=512,
    n_heads=4, n_kv_heads=1, d_head=32, d_ff=256,
    dtype="float32",
)
