"""--arch registry: full configs, smoke variants, long-context variants,
and per-(arch × shape) applicability (which pairs the dry-run runs)."""
from __future__ import annotations

import importlib
from typing import Dict, Optional, Tuple

from repro.models.config import INPUT_SHAPES, InputShape, ModelConfig

_MODULES = {
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "llama3-405b": "llama3_405b",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "zamba2-7b": "zamba2_7b",
    "whisper-base": "whisper_base",
    "internvl2-2b": "internvl2_2b",
    "granite-20b": "granite_20b",
    "minicpm3-4b": "minicpm3_4b",
    "mamba2-1.3b": "mamba2_1_3b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
}

ARCH_NAMES = tuple(_MODULES)


def _mod(arch: str):
    if arch not in _MODULES:
        raise ValueError(f"unknown arch {arch!r}; have {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _mod(arch).CONFIG


def get_smoke(arch: str) -> ModelConfig:
    return _mod(arch).SMOKE


def get_long_context(arch: str) -> Optional[ModelConfig]:
    """Sliding-window variant for long_500k, if the arch defines one."""
    return getattr(_mod(arch), "LONG_CONTEXT", None)


def config_for_shape(arch: str, shape_name: str
                     ) -> Tuple[Optional[ModelConfig], str]:
    """Resolve the config used for a given input shape.

    Returns (config|None, note). None = pair skipped per the assignment
    (long_500k on pure full-attention archs without a SWA variant)."""
    shape = INPUT_SHAPES[shape_name]
    cfg = get_config(arch)
    if shape.name != "long_500k":
        return cfg, ""
    if cfg.supports_long_context():
        if cfg.arch_type in ("ssm", "hybrid"):
            return cfg, "native sub-quadratic (SSM state)"
        return cfg, "sliding-window attention"
    lc = get_long_context(arch)
    if lc is not None:
        return lc, "sliding-window variant (assignment carve-out)"
    return None, ("skipped: pure full-attention arch, no sub-quadratic "
                  "variant (see DESIGN.md §Arch-applicability)")


def all_pairs():
    """The 10 x 4 assignment grid with resolved configs."""
    for arch in ARCH_NAMES:
        for shape_name in INPUT_SHAPES:
            cfg, note = config_for_shape(arch, shape_name)
            yield arch, shape_name, cfg, note
