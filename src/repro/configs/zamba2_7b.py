"""Zamba2-7B — hybrid: Mamba2 backbone + shared attention block every 6
layers [arXiv:2411.15242]. 81 SSM layers = 13 groups of 6 + 3 tail."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", arch_type="hybrid",
    n_layers=81, d_model=3584, vocab=32000,
    n_heads=32, n_kv_heads=32, d_head=112, rope_theta=1e4,
    d_ff=14336,
    ssm_state=64, ssm_expand=2, ssm_head_dim=64, ssm_chunk=64,
    attn_every=6,
)

SMOKE = ModelConfig(
    name="zamba2-smoke", arch_type="hybrid",
    n_layers=5, d_model=128, vocab=512,
    n_heads=4, n_kv_heads=4, d_head=32, d_ff=256,
    ssm_state=16, ssm_expand=2, ssm_head_dim=32, ssm_chunk=8,
    attn_every=2, dtype="float32",
)
