"""InternVL2-2B — VLM: InternViT vision encoder (STUB per assignment —
input_specs provides projected patch embeddings) + InternLM2-1.8B language
backbone [arXiv:2404.16821]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b", arch_type="vlm",
    n_layers=24, d_model=2048, vocab=92553,
    n_heads=16, n_kv_heads=8, d_head=128, rope_theta=1e6,
    d_ff=8192,
    frontend="vision_stub", frontend_seq=256,
)

SMOKE = ModelConfig(
    name="internvl2-smoke", arch_type="vlm",
    n_layers=2, d_model=128, vocab=512,
    n_heads=4, n_kv_heads=2, d_head=32, d_ff=256,
    frontend="vision_stub", frontend_seq=8, dtype="float32",
)
