from repro.configs.registry import (ARCH_NAMES, get_config, get_smoke,
                                    get_long_context, config_for_shape,
                                    all_pairs)
