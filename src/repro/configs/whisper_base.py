"""Whisper-base — encoder-decoder audio model [arXiv:2212.04356].

The mel-spectrogram + conv frontend is a STUB per the assignment:
input_specs provides precomputed frame embeddings (B, 1500, 512). The
transformer backbone (encoder + decoder with cross-attention) is real.
Deviation: decoder uses sinusoidal positions (whisper uses learned) so
decode shapes beyond 448 positions remain well-defined."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", arch_type="audio",
    n_layers=6, encoder_layers=6, d_model=512, vocab=51865,
    n_heads=8, n_kv_heads=8, d_head=64,
    d_ff=2048, mlp="gelu", norm="layernorm", use_rope=False,
    tie_embeddings=True,
    frontend="audio_stub", frontend_seq=1500,
)

SMOKE = ModelConfig(
    name="whisper-smoke", arch_type="audio",
    n_layers=2, encoder_layers=2, d_model=96, vocab=512,
    n_heads=4, n_kv_heads=4, d_head=24,
    d_ff=192, mlp="gelu", norm="layernorm", use_rope=False,
    tie_embeddings=True,
    frontend="audio_stub", frontend_seq=24, dtype="float32",
)
