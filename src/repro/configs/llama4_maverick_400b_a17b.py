"""Llama-4 Maverick 400B-A17B — MoE 128 experts top-1 + shared expert,
iRoPE-style attention (every 4th layer full-attention, the rest sliding
window 8192), early fusion [hf:meta-llama/Llama-4-Scout-17B-16E family].

40 heads pad to 48 for the 16-way TP axis. LONG_CONTEXT makes every layer
sliding-window (ring cache) so long_500k decode keeps O(window) state."""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b", arch_type="moe",
    n_layers=48, d_model=5120, vocab=202048,
    n_heads=40, n_kv_heads=8, d_head=128, rope_theta=5e5,
    d_ff=8192, n_experts=128, experts_per_token=1,
    moe_shared_expert=True, moe_every=2,
    sliding_window=8192, swa_pattern=4,
    use_fsdp=True,
    train_microbatch=8,
)

LONG_CONTEXT = dataclasses.replace(CONFIG,
                                   name="llama4-maverick-400b-a17b-swa",
                                   swa_pattern=0)

SMOKE = ModelConfig(
    name="llama4-smoke", arch_type="moe",
    n_layers=2, d_model=128, vocab=512,
    n_heads=4, n_kv_heads=2, d_head=32,
    d_ff=96, n_experts=4, experts_per_token=1, moe_shared_expert=True,
    moe_every=2, sliding_window=16, swa_pattern=2, dtype="float32",
)
