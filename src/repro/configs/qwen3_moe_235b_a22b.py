"""Qwen3-235B-A22B — MoE, 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B family,
scaled per assignment: 94L d_model=4096 64H (GQA kv=4) expert d_ff=1536
vocab=151936]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", arch_type="moe",
    n_layers=94, d_model=4096, vocab=151936,
    n_heads=64, n_kv_heads=4, d_head=128, rope_theta=1e6,
    d_ff=1536, n_experts=128, experts_per_token=8,
    use_fsdp=True,
    train_microbatch=2,
)

SMOKE = ModelConfig(
    name="qwen3-moe-smoke", arch_type="moe",
    n_layers=2, d_model=128, vocab=512,
    n_heads=4, n_kv_heads=2, d_head=32,
    d_ff=96, n_experts=4, experts_per_token=2,
    dtype="float32",
)
