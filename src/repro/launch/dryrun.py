import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# The two lines above MUST run before any jax-importing module: jax locks
# the device count at first backend initialization. Everything below is a
# normal import block.
import argparse          # noqa: E402
import dataclasses       # noqa: E402
import json              # noqa: E402
import sys               # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs.registry import (ARCH_NAMES, config_for_shape)  # noqa: E402
from repro.core import CompressionConfig, Granularity, make_compressor  # noqa: E402
from repro.launch.analysis import analyze_compiled, save_roofline  # noqa: E402
from repro.launch.engine import Engine  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models.config import INPUT_SHAPES  # noqa: E402
from repro.optim import OptConfig  # noqa: E402

"""Multi-pod dry-run: .lower().compile() for every (arch x shape x mesh).

No arrays are allocated — inputs are ShapeDtypeStructs; the compiled
artifact yields memory_analysis (fits-in-HBM proof), cost_analysis
(FLOPs/bytes) and the per-device HLO whose collective ops feed the
roofline (EXPERIMENTS.md §Dry-run / §Roofline).
"""


def build_compression(args) -> CompressionConfig:
    if args.compressor == "none":
        return CompressionConfig(strategy="dense")
    kw = {}
    if args.compressor in ("randomk", "topk"):
        kw["ratio"] = args.ratio
    if args.compressor == "qsgd":
        kw["levels"] = args.levels
    return CompressionConfig(
        qw=make_compressor(args.compressor, **kw),
        qm=(make_compressor(args.qm) if args.qm != "identity"
            else make_compressor("identity")),
        granularity=Granularity(args.granularity, args.block_size),
        strategy=args.strategy,
        wire_dtype=args.wire_dtype)


def run_one(arch: str, shape_name: str, multi_pod: bool, comp, opt,
            out_dir: str, remat: bool = True, save_hlo: bool = False,
            microbatch: int = 0, tag_suffix: str = "",
            capacity_factor: float = 0.0, mesh_shape=None,
            kv_int8: bool = False):
    shape = INPUT_SHAPES[shape_name]
    cfg, note = config_for_shape(arch, shape_name)
    if cfg is not None and microbatch:
        cfg = dataclasses.replace(cfg, train_microbatch=microbatch)
    if cfg is not None and capacity_factor:
        cfg = dataclasses.replace(cfg, moe_capacity_factor=capacity_factor)
    if cfg is not None and kv_int8 and cfg.attention == "gqa":
        cfg = dataclasses.replace(cfg, kv_cache_dtype="int8")
    mesh_name = "2x16x16" if multi_pod else "16x16"
    tag = f"{arch}__{shape_name}__{mesh_name}{tag_suffix}"
    if cfg is None:
        print(f"[skip] {tag}: {note}")
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skipped", "note": note}
    t0 = time.time()
    if mesh_shape:
        from repro.launch.mesh import make_mesh
        mesh = make_mesh(mesh_shape, ("data", "model"))
        mesh_name = "x".join(str(s) for s in mesh_shape)
        tag = f"{arch}__{shape_name}__{mesh_name}{tag_suffix}"
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    eng = Engine(cfg, mesh, comp=comp, opt=opt, remat=remat)
    with jax.sharding.use_mesh(mesh) if hasattr(jax.sharding, "use_mesh") \
            else mesh:
        if shape.kind == "train":
            step = eng.build_train_step()
            args_sds, _ = eng.train_input_specs(shape)
            lowered = step.lower(*args_sds)
        elif shape.kind == "prefill":
            step = eng.build_prefill(shape)
            params = eng._sharded_sds(eng.model.param_shapes(),
                                      eng.model.param_pspecs())
            (batch,), _ = eng.input_specs(shape)
            lowered = step.lower(params, batch)
        else:
            step = eng.build_serve_step(shape)
            params = eng._sharded_sds(eng.model.param_shapes(),
                                      eng.model.param_pspecs())
            (batch, cache), _ = eng.input_specs(shape)
            lowered = step.lower(params, batch, cache)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    roof = analyze_compiled(compiled, arch=arch, shape=shape,
                            mesh_name=mesh_name, chips=chips, cfg=cfg)
    est = eng.memory_estimate(shape)
    roof.memory_per_device["tpu_estimate_total"] = est["total"]
    roof.memory_per_device["tpu_estimate_fits_16g"] = float(est["fits_16g"])
    print(compiled.memory_analysis())
    print("tpu_estimate:", {k: (round(v / 1e9, 3) if isinstance(v, float)
                                else v) for k, v in est.items()})
    ca = compiled.cost_analysis()
    ca = ca[0] if isinstance(ca, list) else ca
    print({k: v for k, v in ca.items()
           if k in ("flops", "bytes accessed")})
    os.makedirs(out_dir, exist_ok=True)
    save_roofline(roof, os.path.join(out_dir, f"{tag}.json"))
    if save_hlo:
        with open(os.path.join(out_dir, f"{tag}.hlo.txt"), "w") as f:
            f.write(compiled.as_text())
    d = roof.to_dict()
    d.update(status="ok", note=note, lower_s=round(t_lower, 1),
             compile_s=round(t_compile, 1))
    print(f"[ok] {tag}: bottleneck={roof.bottleneck} "
          f"t=({roof.t_compute:.4f},{roof.t_memory:.4f},"
          f"{roof.t_collective:.4f})s useful={roof.useful_flops_ratio:.3f} "
          f"lower={t_lower:.0f}s compile={t_compile:.0f}s")
    return d


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all",
                    help=f"one of {ARCH_NAMES} or 'all'")
    ap.add_argument("--shape", default="all",
                    help=f"one of {tuple(INPUT_SHAPES)} or 'all'")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--compressor", default="topk",
                    help="none|randomk|topk|threshold_v|adaptive_threshold|"
                         "terngrad|qsgd|signsgd|natural")
    ap.add_argument("--ratio", type=float, default=0.01)
    ap.add_argument("--levels", type=int, default=16)
    ap.add_argument("--qm", default="identity")
    ap.add_argument("--granularity", default="layerwise",
                    choices=["layerwise", "entire_model", "blockwise"])
    ap.add_argument("--block-size", type=int, default=65536)
    ap.add_argument("--strategy", default="simulated")
    ap.add_argument("--wire-dtype", default="float32")
    ap.add_argument("--optimizer", default="sgd")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--capacity-factor", type=float, default=0.0)
    ap.add_argument("--mesh-shape", default="",
                    help="override: 'data,model' e.g. '64,4' (analysis runs)")
    ap.add_argument("--kv-int8", action="store_true",
                    help="int8-quantized KV cache (GQA archs)")
    ap.add_argument("--tag", default="")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--fail-fast", action="store_true")
    args = ap.parse_args(argv)

    comp = build_compression(args)
    opt = OptConfig(name=args.optimizer)
    archs = ARCH_NAMES if args.arch == "all" else (args.arch,)
    shapes = tuple(INPUT_SHAPES) if args.shape == "all" else (args.shape,)
    meshes = {"single": (False,), "multi": (True,),
              "both": (False, True)}[args.mesh]

    results, failures = [], 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    results.append(run_one(arch, shape, mp, comp, opt,
                                           args.out,
                                           remat=not args.no_remat,
                                           save_hlo=args.save_hlo,
                                           microbatch=args.microbatch,
                                           tag_suffix=args.tag,
                                           capacity_factor=args.capacity_factor,
                                           mesh_shape=tuple(
                                               int(x) for x in
                                               args.mesh_shape.split(","))
                                           if args.mesh_shape else None,
                                           kv_int8=args.kv_int8))
                except Exception:
                    failures += 1
                    tagm = "2x16x16" if mp else "16x16"
                    print(f"[FAIL] {arch}__{shape}__{tagm}")
                    traceback.print_exc()
                    if args.fail_fast:
                        raise
    os.makedirs(args.out, exist_ok=True)
    with open(os.path.join(args.out, "summary.json"), "a") as f:
        json.dump(results, f, indent=1)
        f.write("\n")
    print(f"\n{len(results)} ok / {failures} failed")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
