"""Production mesh builders.

Functions (not module-level constants) so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS before any jax
device initialization.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2x16x16 = 512 chips across two pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (smoke tests use (1,1) or (2,2) host meshes)."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_host_mesh(data: int = 1, model: int = 1, pod: Optional[int] = None):
    """Small mesh over host CPU devices for tests."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
