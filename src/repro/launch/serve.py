"""Serving launcher: prefill a batch of prompts and decode N tokens on a
device mesh (CPU host mesh for development; dryrun.py lowers the same
serve_step on the production meshes).

Example:
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
  PYTHONPATH=src python -m repro.launch.serve --arch granite-20b --smoke \\
      --batch 8 --prompt 24 --gen 16 --data 4 --model 2
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCH_NAMES, get_config, get_smoke
from repro.data import frames_stub, patches_stub
from repro.launch.engine import Engine
from repro.launch.mesh import make_host_mesh
from repro.models.config import InputShape


def pack_request(token, pos):
    """Serving wire format: one decode request as a single uint8 buffer
    — uint32 words [batch, pos, token_0, ..., token_{B-1}] bitcast to
    bytes (little-endian, like the gradient wire codecs in core/wire.py).
    The launcher round-trips its first decode request through it (outside
    the timed region) and tests/test_serve.py holds the round-trip
    bit-identical through a real decode step."""
    b = token.shape[0]
    words = jnp.concatenate([
        jnp.asarray([b], jnp.uint32),
        jnp.asarray(pos, jnp.uint32)[None],
        token.astype(jnp.uint32)])
    return jax.lax.bitcast_convert_type(words, jnp.uint8).reshape(-1)


def unpack_request(buf):
    """Inverse of pack_request -> {"token": int32[B], "pos": int32}."""
    words = jax.lax.bitcast_convert_type(buf.reshape(-1, 4), jnp.uint32)
    return {"token": words[2:].astype(jnp.int32),
            "pos": words[1].astype(jnp.int32)}


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="granite-20b", choices=ARCH_NAMES)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt", type=int, default=24)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--greedy", action="store_true", default=True)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace-out", default="",
                    help="record host-side prefill/decode spans "
                         "(obs.TraceRecorder) and write a Chrome "
                         "trace-event JSON (open in Perfetto)")
    ap.add_argument("--metrics-out", default="",
                    help="write serve counters (requests, tokens) and "
                         "the per-token decode-latency histogram as "
                         "JSON lines (obs.MetricsRegistry)")
    args = ap.parse_args(argv)
    rec = reg = None
    if args.trace_out or args.metrics_out:
        from repro.obs import MetricsRegistry, TraceRecorder
        rec = TraceRecorder() if args.trace_out else None
        reg = MetricsRegistry() if args.metrics_out else None

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_host_mesh(data=args.data, model=args.model)
    eng = Engine(cfg, mesh)
    params, _ = eng.init_state(args.seed)
    cache_len = args.prompt + args.gen
    dshape = InputShape("serve", cache_len, args.batch, "decode")
    serve = eng.build_serve_step(dshape)
    # the engine's shard_map'd prefill (a bare jit(model.prefill) has no
    # bound TP axes), cache sized for the generation budget
    pshape = InputShape("prefill", args.prompt, args.batch, "prefill")
    prefill = eng.build_prefill(pshape, cache_len=cache_len)

    key = jax.random.key(args.seed)
    prompts = jax.random.randint(key, (args.batch, args.prompt), 0, cfg.vocab)
    batch = {"tokens": prompts}
    if cfg.arch_type == "vlm":
        batch["patch_embeds"] = patches_stub(key, args.batch,
                                             cfg.frontend_seq, cfg.d_model)
    if cfg.arch_type == "audio":
        batch["frames"] = frames_stub(key, args.batch, cfg.frontend_seq,
                                      cfg.d_model)

    import contextlib

    def span(name, **kw):
        return (rec.host_span(name, **kw) if rec is not None
                else contextlib.nullcontext())

    with mesh:
        t0 = time.time()
        with span("prefill", batch=args.batch, prompt=args.prompt):
            logits, cache = prefill(params, batch)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            if rec is not None:  # honest span: close over finished work
                jax.block_until_ready(tok)
        out = [tok]
        t_prefill = time.time() - t0
        # exercise the serving wire format on the first request, OUTSIDE
        # the timed region (the round-trip is measurement-neutral
        # scaffolding; tests/test_serve.py holds its bit-identity)
        req = unpack_request(pack_request(tok, jnp.int32(args.prompt)))
        t0 = time.time()
        for t in range(args.gen - 1):
            td = time.perf_counter()
            with span("decode", pos=args.prompt + t):
                logits, cache = serve(params, req, cache)
                tok = jnp.argmax(logits, -1).astype(jnp.int32)
                if rec is not None:
                    jax.block_until_ready(tok)
            if reg is not None:
                reg.observe("serve/decode_us",
                            (time.perf_counter() - td) * 1e6)
                reg.inc("serve/tokens", args.batch)
            out.append(tok)
            req = {"token": tok, "pos": jnp.int32(args.prompt + t + 1)}
        gen = jnp.stack(out, axis=1)
        t_decode = time.time() - t0
    if rec is not None:
        rec.finalize_step(0)
    if reg is not None:
        reg.inc("serve/requests")
        reg.gauge("serve/prefill_us", t_prefill * 1e6)
        reg.record(arch=cfg.name, batch=args.batch)
    print(f"arch={cfg.name} mesh={dict(eng.sizes)} batch={args.batch}")
    print(f"prefill({args.prompt} tok): {t_prefill*1e3:.0f} ms   "
          f"decode: {t_decode/max(1, args.gen-1)*1e3:.1f} ms/token")
    print("sample continuation:", gen[0].tolist())
    if rec is not None:
        rec.export(args.trace_out)
        print(f"trace -> {args.trace_out} ({len(rec.events)} events)")
    if reg is not None:
        n_lines = reg.export_jsonl(args.metrics_out)
        print(f"metrics -> {args.metrics_out} ({n_lines} lines)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
