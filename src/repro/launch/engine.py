"""The distributed execution engine: shard_map'd train / prefill / serve
steps with the paper's compressed gradient aggregation wired in.

train_step (per device, inside shard_map over the full mesh):
  1. forward/backward on the local batch shard (TP collectives inside;
     FSDP leaves aggregate their grads in the backward hook with Q_W)
  2. paper's Algorithm 1 on the remaining gradient leaves:
     Q_W per worker -> collective over the DP axes -> Q_M
  3. Q_M on the FSDP-scattered leaves (layer-wise, deterministic key)
  4. optimizer update (state sharded like the params)
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

try:
    from jax import shard_map as _shard_map

    def shard_map(f, mesh, in_specs, out_specs):
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=False)
except ImportError:  # older jax
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, mesh, in_specs, out_specs):
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=False)

from repro.core.aggregation import CompressionConfig, compressed_allreduce
from repro.core.granularity import Granularity
from repro.core.plan import UnitPlan, build_plan
from repro.models.config import InputShape, ModelConfig
from repro.models.dist import DistConfig
from repro.models.model import Model
from repro.optim import OptConfig, apply_updates, init_opt_state

Array = jax.Array


def _partition(tree, mask):
    """Split tree into (true_subtree, false_subtree) with None placeholders."""
    t = jax.tree_util.tree_map(lambda x, m: x if m else None, tree, mask)
    f = jax.tree_util.tree_map(lambda x, m: None if m else x, tree, mask)
    return t, f


def _merge(t, f):
    return jax.tree_util.tree_map(lambda a, b: a if b is None else b, t, f,
                                  is_leaf=lambda x: x is None)


class Engine:
    def __init__(self, cfg: ModelConfig, mesh, *,
                 comp: Optional[CompressionConfig] = None,
                 opt: Optional[OptConfig] = None,
                 remat: bool = True):
        self.cfg = cfg
        self.mesh = mesh
        self.sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        has_pod = "pod" in self.sizes
        dp = (("pod", "data") if has_pod else ("data",))
        self.dist = DistConfig(tp="model",
                               fsdp="data" if cfg.use_fsdp else None,
                               dp=dp, sp=True)
        self.model = Model(cfg, self.dist, self.sizes)
        self.comp = comp
        self.opt = opt or OptConfig()
        self.remat = remat
        self.dp_size = 1
        for a in dp:
            self.dp_size *= self.sizes[a]

    # ------------------------------------------------------------------
    # input specs (ShapeDtypeStruct stand-ins, no allocation)
    # ------------------------------------------------------------------
    def batch_shapes(self, shape: InputShape) -> Dict[str, Any]:
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        if shape.kind == "decode":
            out = {"token": jax.ShapeDtypeStruct((B,), jnp.int32),
                   "pos": jax.ShapeDtypeStruct((), jnp.int32)}
            return out
        out = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        if shape.kind == "train":
            out["targets"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        if cfg.arch_type == "vlm":
            out["patch_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.frontend_seq, cfg.d_model), jnp.dtype(cfg.dtype))
        if cfg.arch_type == "audio":
            out["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.frontend_seq, cfg.d_model), jnp.dtype(cfg.dtype))
        return out

    def _dpp(self, shape: InputShape):
        """Batch-dim partition: the dp axes, or None (replicated) when the
        global batch does not divide them (long_500k, batch=1)."""
        if shape.global_batch % self.dp_size != 0:
            return None
        dp = tuple(self.dist.dp)
        return dp if len(dp) > 1 else dp[0]

    def batch_pspecs(self, shape: InputShape) -> Dict[str, P]:
        dpp = self._dpp(shape)
        if shape.kind == "decode":
            return {"token": P(dpp), "pos": P()}
        out = {"tokens": P(dpp, None)}
        if shape.kind == "train":
            out["targets"] = P(dpp, None)
        if self.cfg.arch_type == "vlm":
            out["patch_embeds"] = P(dpp, None, None)
        if self.cfg.arch_type == "audio":
            out["frames"] = P(dpp, None, None)
        return out

    def _sharded_sds(self, sds_tree, pspec_tree):
        def attach(s, p):
            if s is None:
                return None
            return jax.ShapeDtypeStruct(
                s.shape, s.dtype, sharding=NamedSharding(self.mesh, p))
        return jax.tree_util.tree_map(attach, sds_tree, pspec_tree,
                                      is_leaf=lambda x: x is None)

    def input_specs(self, shape: InputShape):
        """(args_sds, in_specs) for the step of this shape's kind."""
        if shape.kind == "train":
            return self.train_input_specs(shape)
        if shape.kind == "prefill":
            b = self._sharded_sds(self.batch_shapes(shape),
                                  self.batch_pspecs(shape))
            return (b,), (self.batch_pspecs(shape),)
        b = self._sharded_sds(self.batch_shapes(shape),
                              self.batch_pspecs(shape))
        sb = shape.global_batch % self.dp_size == 0
        cache = self._sharded_sds(
            self.model.cache_shapes(shape.seq_len, shape.global_batch),
            self.model.cache_pspecs(sb))
        return (b, cache), (self.batch_pspecs(shape),
                            self.model.cache_pspecs(sb))

    def train_input_specs(self, shape: InputShape):
        params = self._sharded_sds(self.model.param_shapes(),
                                   self.model.param_pspecs())
        opt_sds = jax.eval_shape(partial(init_opt_state, self.opt),
                                 self.model.param_shapes())
        opt_ps = self._opt_pspecs()
        opt = self._sharded_sds(opt_sds, opt_ps)
        batch = self._sharded_sds(self.batch_shapes(shape),
                                  self.batch_pspecs(shape))
        step = jax.ShapeDtypeStruct((), jnp.int32)
        return (params, opt, batch, step), (
            self.model.param_pspecs(), opt_ps, self.batch_pspecs(shape), P())

    def _opt_pspecs(self):
        pp = self.model.param_pspecs()
        if self.opt.name == "sgd":
            return {}
        if self.opt.name == "momentum":
            return {"m": pp}
        return {"m": pp, "v": pp, "count": P()}

    # ------------------------------------------------------------------
    # train step
    # ------------------------------------------------------------------
    def _local_sds(self, sds, pspec):
        """Per-device shard ShapeDtypeStruct for one leaf (the shapes the
        train step sees INSIDE shard_map)."""
        shape = list(sds.shape)
        if pspec is not None:
            for i, ax in enumerate(pspec):
                if ax is None or i >= len(shape):
                    continue
                names = ax if isinstance(ax, tuple) else (ax,)
                f = 1
                for nm in names:
                    f *= self.sizes.get(nm, 1)
                shape[i] //= f
        return jax.ShapeDtypeStruct(tuple(shape), sds.dtype)

    def _local_param_sds(self):
        """Per-device shard ShapeDtypeStructs of the full parameter tree
        (the gradient shapes the train step sees inside shard_map)."""
        return jax.tree_util.tree_map(self._local_sds,
                                      self.model.param_shapes(),
                                      self.model.param_pspecs())

    def measurement_plan(self):
        """The layer-wise UnitPlan telemetry is measured over (the full
        local gradient tree, independent of the active execution
        granularity — so a controller's TelemetryState keeps its shape
        across decisions). Cached: the same object the traced step uses.
        """
        from repro.control.telemetry import measurement_plan
        return measurement_plan(self._local_param_sds(),
                                self.model.stacked())

    def comm_plans(self, comp: Optional[CompressionConfig] = None):
        """(rest_plan, fsdp_plan): the static UnitPlans the train step
        executes compression through.

        Built from per-device SHARD ShapeDtypeStructs (param shapes with
        the tp/fsdp partition applied) — the same shapes _aggregate_grads
        traces inside shard_map — and cached on (structure, shapes,
        granularity), so the first train-step trace and any pre-trace
        caller (train.py summary, bits.comm_report, comm_sched) share one
        plan object. `comp` overrides the engine config (the decision →
        step path). fsdp_plan is None when no leaf is fsdp-aggregated or
        the master compressor is identity (no Q_M pass runs on those
        leaves).
        """
        comp = comp or self.comp or CompressionConfig(strategy="dense")
        stacked = self.model.stacked()
        fsdp_mask = self.model.fsdp_mask()
        shapes = self._local_param_sds()
        g_fsdp, g_rest = _partition(shapes, fsdp_mask)
        s_fsdp, s_rest = _partition(stacked, fsdp_mask)
        rest_plan = (build_plan(g_rest, s_rest, comp.granularity)
                     if jax.tree_util.tree_leaves(g_rest) else None)
        master_runs = (comp.qm is not None and comp.qm.name != "identity")
        fsdp_plan = (build_plan(g_fsdp, s_fsdp, comp.granularity)
                     if master_runs and jax.tree_util.tree_leaves(g_fsdp)
                     else None)
        return rest_plan, fsdp_plan

    def _aggregate_grads(self, grads, key,
                         comp: Optional[CompressionConfig] = None,
                         schedule=None, wire: bool = False,
                         recorder=None):
        """Paper's Algorithm 1 over the DP axes, executed through the
        static UnitPlans (one batched compressor dispatch per unit size
        class — built once at jit-trace time, cached thereafter). With
        `schedule` (a CommSchedule for the rest plan) or comp.fusion_bytes
        set, the rest leaves stream through the backward-ordered fused
        message schedule — bit-identical numerics. `wire=True`
        materializes the rest leaves' worker compression as real
        bit-packed message buffers (core.wire; the FSDP backward-hook
        leaves are untouched — their Q_W runs inside the hook)."""
        model, dist = self.model, self.dist
        comp = comp if comp is not None else self.comp
        stacked = model.stacked()
        fsdp_mask = model.fsdp_mask()
        g_fsdp, g_rest = _partition(grads, fsdp_mask)
        s_fsdp, s_rest = _partition(stacked, fsdp_mask)

        if comp is None or comp.strategy == "dense":
            agg_rest, _ = compressed_allreduce(
                g_rest, s_rest,
                comp or CompressionConfig(strategy="dense"),
                dist.dp, key, self.dp_size, wire=wire)
            return _merge(g_fsdp, agg_rest)

        rest_plan = build_plan(g_rest, s_rest, comp.granularity)
        # rest leaves: full bidirectional pipeline
        agg_rest, _ = compressed_allreduce(g_rest, s_rest, comp, dist.dp,
                                           key, self.dp_size,
                                           plan=rest_plan,
                                           schedule=schedule, wire=wire,
                                           recorder=recorder)
        # fsdp leaves: Q_W already applied in the backward hook; grads are
        # scattered+averaged. Apply Q_M layer-wise (identical key on every
        # device -> consistent master compression).
        if comp.qm is not None and comp.qm.name != "identity":
            mkey = jax.random.fold_in(key, 0x5EED)

            def master(x, ukey):
                return comp.qm.sim(x, ukey)
            fsdp_plan = build_plan(g_fsdp, s_fsdp, comp.granularity)
            g_fsdp = fsdp_plan.execute(master, g_fsdp, mkey,
                                       recorder=recorder)
        return _merge(g_fsdp, agg_rest)

    def build_train_step(self, lr_schedule=None, *,
                         comp: Optional[CompressionConfig] = None,
                         telemetry: bool = False,
                         telemetry_entire_model: bool = True,
                         schedule=None, wire: bool = False,
                         collective: Optional[str] = None,
                         tracer=None, metrics=None,
                         step_guard: bool = False):
        """The sharded, jitted train step.

        `comp` overrides the engine's CompressionConfig for THIS step
        (the controller's decision → step path; `None` keeps engine
        default — identical graph to the pre-controller behavior).
        `schedule` streams the DP gradient aggregation through a
        CommSchedule: pass a fusion-bytes number (compiled against the
        engine's cached rest plan; 0 = per-bucket messages, math.inf =
        one fused message) or a prebuilt CommSchedule from
        launch.comm_sched.engine_schedule. Scheduling is bit-identical —
        it changes program order and wire-message accounting, never
        numerics (the comp.fusion_bytes field is the decision-carried
        equivalent; an explicit `schedule` wins). With
        `telemetry=True` the step takes and returns a
        control.telemetry.TelemetryState as an extra (replicated)
        argument: (params, opt, batch, step, telem) -> (params, opt,
        metrics, telem'), where telem' accumulates this step's
        measurement pmean'd over ALL devices. Semantics of that mean:
        each device measures its LOCAL shard, so absolute second moments
        are per-device-shard averages, not global sums — ratio statistics
        (omega_hat, rel_err — all any policy consumes) are exact, since
        the uniform 1/n_devices factor cancels.
        `telemetry_entire_model=False` drops the flat counterfactual
        compression pass (only GranularitySwitchPolicy reads it).
        `wire=True` routes the DP gradient aggregation through REAL
        bit-packed wire buffers (core.wire; requires a codec-bearing
        worker compressor and the simulated/allgather strategy) —
        bit-identical numerics, but every wire message is a materialized
        uint8 buffer whose size*8 is the wire truth.
        `collective` picks the wire collective's topology: None keeps the
        config's strategy; 'allgather' forces the serialized
        gather-all-payloads stream; 'ring' routes the same messages
        through the streaming chunked-ppermute ring
        (CommSchedule.execute_streaming — bit-identical to 'allgather',
        with real compress/collective overlap in program order). Both
        require `wire=True` and a compression config (the dense path has
        no wire messages to stream).
        `tracer` (duck-typed, obs.trace.TraceRecorder) instruments the
        gradient-aggregation pipeline with per-message/stage spans (the
        step's marks fire per executed step; block on the step's outputs
        then call tracer.finalize_step). Note marks fire once per DEVICE
        under shard_map — trace on a 1-device mesh for a clean timeline.
        `metrics` (obs.metrics.MetricsRegistry) receives build counters
        and static plan/schedule gauges. Both default to None — the
        traced graph is then bit-identical to the uninstrumented one.
        `step_guard=True` makes the update self-protecting: if the loss
        or ANY aggregated-gradient leaf is non-finite, the whole update
        is dropped (params and optimizer state keep their pre-step
        values) and the returned metrics carry `skipped=1.0`. The flag
        is pmin-reduced over ALL mesh axes so every rank (including TP
        peers that would otherwise diverge) takes the same branch.
        """
        model, cfg, opt = self.model, self.cfg, self.opt
        dist = self.dist
        comp_eff = comp if comp is not None else self.comp
        if collective is not None:
            if collective not in ("allgather", "ring"):
                raise ValueError(
                    f"collective must be None, 'allgather' or 'ring'; "
                    f"got {collective!r}")
            if not wire or comp_eff is None or comp_eff.strategy == "dense":
                raise ValueError(
                    "collective= picks the wire collective's topology: it "
                    "requires wire=True and a compression config")
            comp_eff = dataclasses.replace(comp_eff, strategy=collective)
        if schedule is not None:
            from repro.launch.comm_sched import resolve_schedule
            rest_plan, _ = self.comm_plans(comp_eff)
            schedule = resolve_schedule(rest_plan, schedule)
        sched = lr_schedule or (lambda s: jnp.float32(self.opt.lr))
        all_axes = tuple(self.mesh.axis_names)
        if telemetry:
            from repro.control.telemetry import accumulate, measure
            mplan = self.measurement_plan()

        mb = max(1, cfg.train_microbatch)

        def step_fn(params, opt_state, batch, step, telem=None):
            key = jax.random.fold_in(jax.random.key(42), step)
            comp_hook = comp_eff if dist.fsdp is not None else None

            def loss_fn(p, b):
                return model.loss(p, b, key, comp=comp_hook,
                                  remat=self.remat)

            mb_eff = min(mb, batch["tokens"].shape[0])
            if mb_eff == 1:
                loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            else:
                # gradient accumulation: split the LOCAL batch into mb
                # microbatches; grads accumulate in param dtype. The FSDP
                # backward hook compresses + reduce-scatters per microbatch
                # (a finer worker partition — covered by Lemma 1).
                mbatch = jax.tree_util.tree_map(
                    lambda x: x.reshape((mb_eff, x.shape[0] // mb_eff)
                                        + x.shape[1:]), batch)

                def mb_body(carry, b_i):
                    acc, lsum = carry
                    l, g = jax.value_and_grad(loss_fn)(params, b_i)
                    acc = jax.tree_util.tree_map(jnp.add, acc, g)
                    return (acc, lsum + l), None

                zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
                (grads, lsum), _ = jax.lax.scan(
                    mb_body, (zeros, jnp.zeros((), jnp.float32)), mbatch)
                inv = 1.0 / mb_eff
                grads = jax.tree_util.tree_map(
                    lambda g: (g * jnp.asarray(inv, g.dtype)), grads)
                loss = lsum * inv
            agg = self._aggregate_grads(grads, key, comp_eff,
                                        schedule=schedule, wire=wire,
                                        recorder=tracer)
            if telemetry:
                qw = (comp_eff or CompressionConfig(strategy="dense")).qw
                inc = measure(mplan, qw, grads, key, grads_hat=agg,
                              entire_model=telemetry_entire_model)
                inc = jax.tree_util.tree_map(
                    lambda v: jax.lax.pmean(v, all_axes), inc)
                telem = accumulate(telem, inc)
            lr = sched(step)
            new_params, new_opt = apply_updates(opt, params, agg,
                                                opt_state, lr)
            if step_guard:
                finite = jnp.isfinite(loss)
                for leaf in jax.tree_util.tree_leaves(agg):
                    finite = finite & jnp.all(jnp.isfinite(leaf))
                # every rank must take the same branch: a TP peer with a
                # finite shard would otherwise diverge from one that saw
                # the NaN
                finite = jax.lax.pmin(finite.astype(jnp.int32),
                                      all_axes) > 0
                keep = lambda n, o: jnp.where(finite, n, o)
                new_params = jax.tree_util.tree_map(keep, new_params,
                                                    params)
                new_opt = jax.tree_util.tree_map(keep, new_opt, opt_state)
            params, opt_state = new_params, new_opt
            loss = jax.lax.pmean(loss, dist.dp)
            metrics = {"loss": loss, "lr": lr}
            if step_guard:
                metrics["skipped"] = 1.0 - finite.astype(jnp.float32)
            if telemetry:
                return params, opt_state, metrics, telem
            return params, opt_state, metrics

        pp = self.model.param_pspecs()
        ops = self._opt_pspecs()
        # training batches always shard over the dp axes (global batch is a
        # multiple of the dp degree for every assigned train shape)
        bs = self.batch_pspecs(
            InputShape("train", 1, self.dp_size, "train"))
        metrics_spec = {"loss": P(), "lr": P()}
        if step_guard:
            metrics_spec["skipped"] = P()
        if telemetry:
            mapped = shard_map(
                step_fn, self.mesh,
                in_specs=(pp, ops, bs, P(), P()),
                out_specs=(pp, ops, metrics_spec, P()))
        else:
            mapped = shard_map(
                step_fn, self.mesh,
                in_specs=(pp, ops, bs, P()),
                out_specs=(pp, ops, metrics_spec))
        if metrics is not None and getattr(metrics, "enabled", False):
            metrics.inc("engine/step_builds")
            rest_plan, _ = self.comm_plans(comp_eff)
            if rest_plan is not None:
                metrics.gauge("engine/n_dispatches",
                              rest_plan.num_dispatches)
                metrics.gauge("engine/n_units", rest_plan.num_units)
                sched_eff = schedule   # explicit schedule wins; else the
                if sched_eff is None and comp_eff is not None and \
                        comp_eff.fusion_bytes is not None:
                    from repro.core.schedule import \
                        build_schedule  # decision-carried fusion_bytes
                    sched_eff = build_schedule(rest_plan,
                                               comp_eff.fusion_bytes)
                if sched_eff is not None:
                    metrics.gauge("engine/n_messages",
                                  sched_eff.num_messages)
                    metrics.gauge("engine/fusion_bytes",
                                  min(sched_eff.fusion_bytes, 2.0 ** 63))
                if comp_eff is not None and comp_eff.strategy != "dense":
                    from repro.control.telemetry import \
                        payload_bits_per_step
                    metrics.gauge(
                        "engine/wire_bits_per_step",
                        payload_bits_per_step(rest_plan, comp_eff.qw))
        return jax.jit(mapped, donate_argnums=(0, 1))

    # ------------------------------------------------------------------
    # inference steps
    # ------------------------------------------------------------------
    def build_prefill(self, shape: InputShape, cache_len: int = None):
        """The sharded prefill step. `cache_len` sizes the returned KV
        cache beyond the prompt (generation slots for a following
        decode loop — the serve launcher's path); default: prompt
        length. Must be used instead of a bare jit(model.prefill): the
        model's TP collectives only have their axes bound inside
        shard_map."""
        model = self.model
        dpp = self._dpp(shape)

        def step_fn(params, batch):
            return model.prefill(params, batch, jax.random.key(0),
                                 remat=self.remat, cache_len=cache_len)

        pp = model.param_pspecs()
        bs = self.batch_pspecs(shape)
        sb = shape.global_batch % self.dp_size == 0
        mapped = shard_map(
            step_fn, self.mesh, in_specs=(pp, bs),
            out_specs=((P(dpp, "model"), model.cache_pspecs(sb))))
        return jax.jit(mapped)

    def build_serve_step(self, shape: InputShape):
        model = self.model
        dpp = self._dpp(shape)

        def step_fn(params, batch, cache):
            logits, new_cache = model.decode_step(params, batch["token"],
                                                  batch["pos"], cache)
            return logits, new_cache

        pp = model.param_pspecs()
        cs = model.cache_pspecs(shape.global_batch % self.dp_size == 0)
        bs = self.batch_pspecs(shape)
        mapped = shard_map(step_fn, self.mesh, in_specs=(pp, bs, cs),
                           out_specs=(P(dpp, "model"), cs))
        return jax.jit(mapped, donate_argnums=(2,))

    # ------------------------------------------------------------------
    def memory_estimate(self, shape: InputShape) -> Dict[str, float]:
        """Analytic per-device HBM estimate for the TPU target.

        The CPU backend's buffer assignment promotes bf16 compute to f32
        (no native bf16 on CPU), inflating temp_size ~2-3x; this estimate
        is the documented fits-in-HBM proof, with the CPU number reported
        alongside as a (loose) upper bound. Terms:
          params + optimizer state + gradients (train) + saved residual
          stack (train, seq-parallel) + per-layer transients (FSDP
          weight gathers, gathered activations, loss chunks) + KV cache.
        """
        cfg = self.cfg
        bt = 2 if cfg.dtype == "bfloat16" else 4
        tp = self.sizes.get("model", 1)
        dpn = self.dp_size
        chips = tp * dpn
        n_params = cfg.param_count()
        shard = tp * (dpn if cfg.use_fsdp else 1)
        params = n_params * bt / shard
        opt_mult = {"sgd": 0, "momentum": 1, "adam": 2}[self.opt.name]
        opt = n_params * 4 * opt_mult / shard
        B_l = max(1, shape.global_batch // dpn)
        d = cfg.d_model
        est = {"params": params, "opt_state": opt}
        if shape.kind == "train":
            est["grads"] = params
            mb = max(1, cfg.train_microbatch)
            B_mb = max(1, B_l // mb)
            S_l = shape.seq_len // tp  # sequence-parallel residual stack
            est["residual_stack"] = cfg.n_layers * B_mb * S_l * d * bt
            # transients: gathered per-layer weights (fsdp) + ~4 copies of
            # the gathered (B,S,d) activation + one loss chunk
            layer_params = (n_params - 2 * cfg.vocab * d) / max(1, cfg.n_layers)
            gathered_w = (layer_params * bt / tp) if cfg.use_fsdp else 0
            est["layer_transients"] = gathered_w + 4 * B_mb * shape.seq_len * d * bt
            est["loss_chunk"] = 8192 * (self.model.vocab_padded // tp) * 4 * 2
        elif shape.kind == "prefill":
            est["activations"] = 4 * B_l * shape.seq_len * d * bt
            cache = self.model.cache_shapes(shape.seq_len, shape.global_batch)
            est["cache"] = sum(
                (x.size * x.dtype.itemsize) / chips
                for x in jax.tree_util.tree_leaves(cache) if x is not None)
            if cfg.use_fsdp:
                est["layer_transients"] =                     (n_params - 2 * cfg.vocab * d) / max(1, cfg.n_layers)                     * bt / tp
        else:  # decode: weights stay sharded (2D TP), cache dominates
            cache = self.model.cache_shapes(shape.seq_len, shape.global_batch)
            est["cache"] = sum(
                (x.size * x.dtype.itemsize) / chips
                for x in jax.tree_util.tree_leaves(cache) if x is not None)
            est["activations"] = 8 * B_l * d * 4
        est["total"] = sum(est.values())
        est["fits_16g"] = est["total"] <= 16e9
        return est

    def init_state(self, seed: int = 0):
        """Materialize params + optimizer state (small meshes / smoke)."""
        params = self.model.init(jax.random.key(seed))
        opt_state = init_opt_state(self.opt, params)
        return params, opt_state
