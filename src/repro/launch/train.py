"""Training launcher: compressed data-parallel training of any --arch on
the current device set (host CPU mesh for development; the same code path
lowers on the production mesh via dryrun.py).

Example:
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
  PYTHONPATH=src python -m repro.launch.train --arch mamba2-1.3b --smoke \\
      --steps 50 --data 4 --model 2 --compressor topk --ratio 0.1 \\
      --granularity layerwise
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCH_NAMES, get_config, get_smoke
from repro.control import POLICIES, engine_controller, make_policy
from repro.core import CompressionConfig, Granularity, make_compressor
from repro.data import lm_batches, frames_stub, patches_stub
from repro.launch.engine import Engine
from repro.launch.mesh import make_host_mesh
from repro.ckpt import latest_checkpoint, load_checkpoint, save_checkpoint
from repro.optim import OptConfig, piecewise_linear


def build_controller(args, eng, sched, *, metrics=None, tracer=None):
    kw = {}
    if args.policy == "variance_budget":
        kw["budget"] = args.variance_budget
    if args.policy == "bit_budget":
        kw["bits_per_step"] = args.bit_budget
    if args.policy == "fusion":
        kw["alpha_us"] = args.alpha_us
    policy = make_policy(args.policy, **kw)
    collect = policy.needs_telemetry or bool(args.telemetry_out)
    return engine_controller(eng, policy, lr_schedule=sched,
                             replan_every=args.replan_every,
                             collect_telemetry=collect,
                             metrics=metrics, tracer=tracer)


def build_compression(args) -> CompressionConfig:
    if args.compressor == "none":
        return CompressionConfig(strategy="dense")
    kw = {}
    if args.compressor in ("randomk", "topk"):
        kw["ratio"] = args.ratio
    if args.compressor == "qsgd":
        kw["levels"] = args.levels
    return CompressionConfig(
        qw=make_compressor(args.compressor, **kw),
        qm=make_compressor(args.qm),
        granularity=Granularity(args.granularity, args.block_size),
        strategy=args.strategy,
        error_feedback=args.error_feedback,
        fusion_bytes=args.fusion_bytes)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="mamba2-1.3b", choices=ARCH_NAMES)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--compressor", default="none")
    ap.add_argument("--ratio", type=float, default=0.01)
    ap.add_argument("--levels", type=int, default=16)
    ap.add_argument("--qm", default="identity")
    ap.add_argument("--granularity", default="layerwise",
                    choices=["layerwise", "entire_model", "blockwise"])
    ap.add_argument("--block-size", type=int, default=65536)
    ap.add_argument("--strategy", default="simulated")
    ap.add_argument("--error-feedback", action="store_true")
    ap.add_argument("--fusion-bytes", type=float, default=None,
                    help="comm-schedule fusion threshold in bytes: stream "
                         "aggregation through the backward-ordered "
                         "CommSchedule, fusing buckets below this size "
                         "into one wire message (0 = per-bucket messages, "
                         "inf = one message; default: unscheduled)")
    ap.add_argument("--alpha-us", type=float, default=50.0,
                    help="per-message link latency for the fusion policy "
                         "and the modeled comm report")
    ap.add_argument("--wire", action="store_true",
                    help="materialize compression as real bit-packed wire "
                         "payloads (core.wire): every message is an actual "
                         "uint8 buffer, bit-identical numerics; prints "
                         "accounted vs measured wire bits (static path "
                         "only — not combined with --policy)")
    ap.add_argument("--collective", default=None,
                    choices=("allgather", "ring"),
                    help="wire-collective topology (requires --wire): "
                         "'allgather' = the serialized gather-everything "
                         "stream, 'ring' = the streaming chunked-ppermute "
                         "ring with per-hop decode-accumulate — "
                         "bit-identical numerics, real compress/collective "
                         "overlap in program order")
    ap.add_argument("--policy", default=None, choices=list(POLICIES),
                    help="adaptive compression policy; routes the run "
                         "through the control.Controller (default: the "
                         "static engine path without telemetry)")
    ap.add_argument("--replan-every", type=int, default=20,
                    help="policy re-plan boundary, in steps")
    ap.add_argument("--telemetry-out", default="",
                    help="write the controller's per-window telemetry "
                         "summaries + switch log as JSON (implies "
                         "--policy static when no policy is given)")
    ap.add_argument("--trace-out", default="",
                    help="record per-step/per-message spans with the "
                         "obs.TraceRecorder and write a Chrome trace-event "
                         "JSON (open in Perfetto). Forces per-step host "
                         "sync — timings are honest, throughput is not")
    ap.add_argument("--metrics-out", default="",
                    help="write engine/controller/train counters and "
                         "gauges as JSON lines (obs.MetricsRegistry)")
    ap.add_argument("--variance-budget", type=float, default=0.1,
                    help="variance_budget policy: max relative "
                         "compression error per bucket")
    ap.add_argument("--bit-budget", type=int, default=1 << 22,
                    help="bit_budget policy: uplink payload bits/step")
    ap.add_argument("--optimizer", default="momentum")
    ap.add_argument("--lr", type=float, default=0.2)
    ap.add_argument("--nesterov", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true",
                    help="restore params/optimizer from the newest "
                         "checkpoint in --ckpt-dir and continue from its "
                         "step; the data stream is replayed to that step, "
                         "so an uninterrupted run and a killed-and-resumed "
                         "run produce bitwise-identical states")
    ap.add_argument("--step-guard", action="store_true",
                    help="drop any update whose loss or aggregated "
                         "gradient is non-finite (params/optimizer keep "
                         "their pre-step values); skipped steps are "
                         "counted under resil/steps_skipped when "
                         "--metrics-out is set")
    args = ap.parse_args(argv)
    if args.resume and not args.ckpt_dir:
        ap.error("--resume restores from --ckpt-dir; set it")
    if args.telemetry_out and not args.policy:
        args.policy = "static"  # telemetry collection needs the controller

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_host_mesh(data=args.data, model=args.model)
    comp = build_compression(args)
    opt = OptConfig(name=args.optimizer, lr=args.lr, nesterov=args.nesterov)
    eng = Engine(cfg, mesh, comp=comp, opt=opt)
    sched = piecewise_linear(args.lr, args.steps, max(1, args.steps // 10))
    if args.wire and args.policy:
        ap.error("--wire is the static engine path; drop --policy")
    if args.step_guard and args.policy:
        ap.error("--step-guard is the static engine path; drop --policy")
    if args.collective and not args.wire:
        ap.error("--collective picks the wire collective's topology; "
                 "add --wire")
    if args.collective and comp.strategy == "dense":
        ap.error("--collective needs a compressor (the dense path has no "
                 "wire messages to stream); add --compressor")
    rec = reg = None
    if args.trace_out or args.metrics_out:
        from repro.obs import MetricsRegistry, TraceRecorder
        rec = TraceRecorder() if args.trace_out else None
        reg = MetricsRegistry() if args.metrics_out else None
    ctrl = (build_controller(args, eng, sched, metrics=reg, tracer=rec)
            if args.policy else None)
    step_fn = None if ctrl else eng.build_train_step(
        sched, wire=args.wire, collective=args.collective, tracer=rec,
        metrics=reg, step_guard=args.step_guard)
    params, opt_state = eng.init_state(args.seed)
    start = 0
    if args.resume:
        ck = latest_checkpoint(args.ckpt_dir)
        if ck is not None:
            start, state = load_checkpoint(
                ck, like={"params": params, "opt": opt_state})
            params, opt_state = state["params"], state["opt"]
            print(f"resume: {ck} -> step {start}")
        else:
            print(f"resume: no checkpoint under {args.ckpt_dir!r}, "
                  f"starting fresh")
    n = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"arch={cfg.name} params={n/1e6:.2f}M mesh={dict(eng.sizes)} "
          f"comp={comp.strategy}/{comp.qw.name}/{comp.granularity.kind}"
          + (f" collective={args.collective}" if args.collective else "")
          + (f" policy={args.policy}/replan={args.replan_every}"
             if ctrl else ""))
    # the static compression-execution plan the jitted step will run with
    # (same cached object: built here from ShapeDtypeStructs, reused at
    # trace time by Engine._aggregate_grads)
    rest_plan, fsdp_plan = eng.comm_plans()
    for tag, p in (("dp", rest_plan), ("fsdp", fsdp_plan)):
        if p is not None:
            print(f"plan[{tag}]: {p.summary()}")
    if args.wire and rest_plan is not None and comp.strategy != "dense":
        # accounted vs measured wire bits of the active codec (the
        # differential suite holds these equal modulo word padding)
        from repro.core.wire import wire_codec
        codec = wire_codec(comp.qw)
        acct = sum(comp.qw.payload_bits(d) for d in rest_plan.unit_dims)
        meas = sum(codec.wire_bits(d) for d in rest_plan.unit_dims)
        print(f"wire[dp]: codec={codec.name} accounted={acct} bits "
              f"measured={meas} bits (padding {meas - acct})")
    if args.fusion_bytes is not None and rest_plan is not None:
        from repro.launch.comm_sched import engine_schedule, schedule_report
        s = engine_schedule(eng, args.fusion_bytes)
        rep = schedule_report(s, comp, eng.dp_size, alpha_us=args.alpha_us)
        print(f"schedule[dp]: {s.summary()}")
        print(f"schedule[dp]: modeled exposed comm "
              f"{rep['model']['exposed_comm_us']:.0f}us of "
              f"{rep['model']['comm_us_total']:.0f}us "
              f"(overlap {rep['model']['overlap_frac']:.0%}; model, not "
              f"measurement — trust the message counts)")

    it = lm_batches(cfg.vocab, args.batch, args.seq, seed=args.seed)
    for _ in range(start):   # replay the stream to the resume point: the
        next(it)             # resumed run sees the exact batches the
    key = jax.random.key(args.seed)  # uninterrupted run would have
    with mesh:
        t0 = time.time()
        for i in range(start, args.steps):
            batch = next(it)
            if cfg.arch_type == "vlm":
                batch["patch_embeds"] = patches_stub(
                    jax.random.fold_in(key, i), args.batch,
                    cfg.frontend_seq, cfg.d_model)
            if cfg.arch_type == "audio":
                batch["frames"] = frames_stub(
                    jax.random.fold_in(key, i), args.batch,
                    cfg.frontend_seq, cfg.d_model)
            if ctrl is not None:
                fn = ctrl.step_fn()
                if ctrl.collect:
                    params, opt_state, m, telem = fn(
                        params, opt_state, batch, jnp.int32(i),
                        ctrl.telemetry)
                else:
                    params, opt_state, m = fn(params, opt_state, batch,
                                              jnp.int32(i))
                    telem = None
                if ctrl.observe(telem, i):
                    print(f"step {i:5d} replan -> "
                          f"{ctrl.decision.describe()}")
            else:
                params, opt_state, m = step_fn(params, opt_state, batch,
                                               jnp.int32(i))
            if rec is not None:
                # span stamps arrive via host callbacks — close the step
                # before cutting it (honest timings, serialized steps)
                jax.block_until_ready(m["loss"])
                rec.finalize_step(i)
            if reg is not None:
                reg.inc("train/steps")
                if args.step_guard:
                    reg.inc("resil/steps_skipped", float(m["skipped"]))
                reg.record(step=i)
            if i % max(1, args.steps // 20) == 0 or i == args.steps - 1:
                print(f"step {i:5d} loss {float(m['loss']):.4f} "
                      f"lr {float(m['lr']):.4f} "
                      f"({time.time()-t0:.1f}s)")
            if args.ckpt_dir and args.ckpt_every and \
                    (i + 1) % args.ckpt_every == 0:
                save_checkpoint(args.ckpt_dir, i + 1,
                                {"params": params, "opt": opt_state})
    if ctrl is not None:
        print(f"controller: decision={ctrl.decision.describe()} "
              f"builds={ctrl.builds} switches={len(ctrl.switches)}")
        if args.telemetry_out:
            ctrl.export(args.telemetry_out)
            print(f"telemetry -> {args.telemetry_out}")
    if rec is not None:
        from repro.obs import format_step_summary
        if rec.steps:
            print(format_step_summary(rec.steps[-1]))
        rec.export(args.trace_out)
        print(f"trace -> {args.trace_out} "
              f"({len(rec.events)} events, {len(rec.steps)} steps)")
    if reg is not None:
        if ctrl is not None:
            ctrl.check_retraces()  # stamp the final retrace gauge
        n_lines = reg.export_jsonl(args.metrics_out)
        print(f"metrics -> {args.metrics_out} ({n_lines} lines)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
