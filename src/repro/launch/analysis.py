"""Compiled-artifact analysis: collective-byte parsing and the three-term
roofline (compute / memory / collective) from the dry-run.

Hardware model (TPU v5e target, per assignment):
  peak bf16        197 TFLOP/s per chip
  HBM bandwidth    819 GB/s per chip
  ICI link         ~50 GB/s per link
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, Optional, Tuple

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Sum bytes over every 'dtype[dims]' group in an HLO result type."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-op-kind result bytes of every collective in (per-device) HLO.

    We take the RESULT shape as the wire proxy: for all-reduce it equals the
    payload; for all-gather it is the received total; for reduce-scatter the
    sent total is result x n (we report result — conservative).
    'xxx-start' variants (async) are counted; '-done' are not.
    """
    out = {k: 0 for k in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        s = line.strip()
        if "fusion" in s.split("=")[0]:
            continue
        m = re.match(r"%?[\w.\-]+ = (.+?) (" + "|".join(COLLECTIVE_OPS) +
                     r")(-start)?\(", s)
        if m:
            out[m.group(2)] += _shape_bytes(m.group(1))
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops_per_device: float
    hlo_bytes_per_device: float
    collective_bytes_per_device: float
    collective_breakdown: Dict[str, float]
    model_flops_global: float
    memory_per_device: Dict[str, float]
    raw_cost_analysis: Optional[Dict[str, float]] = None

    @property
    def t_compute(self) -> float:
        return self.hlo_flops_per_device / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes_per_device / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes_per_device / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        total = self.hlo_flops_per_device * self.chips
        return self.model_flops_global / total if total else 0.0

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(t_compute=self.t_compute, t_memory=self.t_memory,
                 t_collective=self.t_collective, bottleneck=self.bottleneck,
                 useful_flops_ratio=self.useful_flops_ratio)
        return d


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS per step: 6·N·D train, 2·N·D forward (N = active params,
    D = tokens processed globally)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def analyze_compiled(compiled, *, arch: str, shape, mesh_name: str,
                     chips: int, cfg) -> Roofline:
    from repro.launch.hlo_cost import scan_scaled_costs
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0]
    raw = {"flops": float(cost.get("flops", 0.0)),
           "bytes_accessed": float(cost.get("bytes accessed", 0.0))}
    mem = {}
    try:
        ma = compiled.memory_analysis()
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "alias_size_in_bytes",
                     "generated_code_size_in_bytes"):
            v = getattr(ma, attr, None)
            if v is not None:
                mem[attr] = float(v)
    except Exception as e:  # pragma: no cover
        mem["error"] = str(e)
    text = compiled.as_text()
    # scan-scaled per-device costs (XLA's cost_analysis counts while-loop
    # bodies ONCE — useless for scan-over-layers models; see hlo_cost.py)
    sc = scan_scaled_costs(text, default_group=chips)
    return Roofline(
        arch=arch, shape=shape.name, mesh=mesh_name, chips=chips,
        hlo_flops_per_device=sc["flops"], hlo_bytes_per_device=sc["bytes"],
        collective_bytes_per_device=sum(sc["collectives"].values()),
        collective_breakdown=sc["collectives"],
        model_flops_global=model_flops(cfg, shape),
        memory_per_device=mem,
        raw_cost_analysis=raw)


def save_roofline(r: Roofline, path: str):
    with open(path, "w") as f:
        json.dump(r.to_dict(), f, indent=2)
