"""Scan-aware HLO cost analysis.

XLA's HloCostAnalysis (compiled.cost_analysis()) counts a while-loop body
ONCE, so scan-over-layers models under-report FLOPs/bytes/collectives by a
factor of ~n_layers. This module re-derives the three roofline inputs from
compiled.as_text() with loop trip-count scaling:

  flops            2·M·N·K over every `dot` op (matmul-dominated models;
                   elementwise flops are <1% and ignored — documented)
  hbm bytes        an HBM-traffic MODEL (not a measurement): dots count
                   lhs+rhs+result bytes (weight reads dominate); fusions,
                   dynamic-update-slices, gathers/scatters and collectives
                   count 2x their result. Copies/converts/reshapes are
                   EXCLUDED — XLA:CPU materializes loop-carry copies and
                   bf16->f32 promotions every iteration, which a TPU (with
                   native bf16 and in-place loop carries) would not.
  collective bytes per-op wire model from result shape + replica group
                   size (ring allreduce ~2x payload, all-gather ~received,
                   reduce-scatter ~(g-1)x result, all-to-all ~result)

Loop trip counts come from the integer constant in each while condition
computation (jax scans lower to counted loops); multiplicities propagate
through nested whiles / fusions / calls / conditionals.
"""
from __future__ import annotations

import collections
import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*")
_OPS = ("while|conditional|call|fusion|dot|convolution|custom-call|copy|"
        "convert|bitcast|broadcast|reshape|transpose|slice|dynamic-slice|"
        "dynamic-update-slice|concatenate|pad|reduce-window|reduce|select|"
        "compare|add|subtract|multiply|divide|maximum|minimum|exponential|"
        "tanh|rsqrt|sqrt|log|negate|sign|floor|ceil|and|or|not|xor|iota|"
        "rng-bit-generator|rng|constant|parameter|get-tuple-element|tuple|"
        "all-gather-start|all-gather-done|all-gather|all-reduce-start|"
        "all-reduce-done|all-reduce|reduce-scatter|all-to-all|"
        "collective-permute-start|collective-permute-done|"
        "collective-permute|partition-id|replica-id|scatter|gather|sort|"
        "clamp|power|abs|cosine|sine|is-finite|select-and-scatter|"
        "after-all|optimization-barrier|domain|shift-left|"
        "shift-right-logical|shift-right-arithmetic|map|atan2|tan|"
        "stochastic-convert|real|imag|complex|reverse|remainder|"
        "round-nearest-afz|round-nearest-even|cbrt|logistic|expm1|log1p|"
        "popcnt|clz|dynamic-reshape|triangular-solve|cholesky|fft|"
        "batch-norm-training|batch-norm-inference|batch-norm-grad|"
        "infeed|outfeed|send|recv|erf")
# first "  <op>(" occurrence after '=' is the real op (type strings and
# /*index=N*/ comments contain no parens)
_OP_RE = re.compile(r"=\s.*?\s(" + _OPS + r")\(")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_WHILE_RE = re.compile(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_TOAPPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}|"
                        r"true_computation=%?([\w.\-]+), "
                        r"false_computation=%?([\w.\-]+)")
_CONST_RE = re.compile(r"=\s*s32\[\]\s+constant\((\d+)\)")
# dot operand: optional inline type annotation + %name (newer HLO prints
# "dot(f32[128,128]{1,0} %lhs, f32[128,128]{1,0} %rhs)")
_DOT_ARG_RE = re.compile(
    r"(?:([a-z0-9]+\[[0-9,]*\](?:\{[0-9,]*\})?)\s+)?%([\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# ops whose RESULT x2 counts as HBM traffic (TPU-relevant materializers)
_BYTES_OPS = {"fusion", "dynamic-update-slice", "dynamic-slice", "gather",
              "scatter", "reduce", "reduce-window", "sort", "concatenate",
              "pad", "rng-bit-generator", "custom-call", "slice",
              "select-and-scatter"}


def _shape_dims(shape_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _shape_dims(shape_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _numel(shape_str: str) -> int:
    n = 0
    for _, dims in _shape_dims(shape_str):
        m = 1
        for d in dims:
            m *= d
        n += m
    return n


@dataclasses.dataclass
class CompCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Optional[Dict[str, float]] = None
    children: Optional[List[Tuple[str, float]]] = None  # (name, times)


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:  # [G,S]<=[N] : G groups of size S
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return default


def _wire_bytes(kind: str, result_bytes: int, g: int) -> float:
    if g <= 1:
        return 0.0
    if kind == "all-reduce":
        return 2.0 * result_bytes * (g - 1) / g
    if kind == "all-gather":
        return result_bytes * (g - 1) / g
    if kind == "reduce-scatter":
        return result_bytes * (g - 1)
    if kind == "all-to-all":
        return result_bytes * (g - 1) / g
    return float(result_bytes)  # collective-permute


def parse_hlo(text: str, default_group: int):
    """-> dict name -> CompCost, plus entry computation name."""
    comps: Dict[str, CompCost] = {}
    trip_hint: Dict[str, int] = {}   # cond computation -> trip count
    entry = None
    cur = None
    shapes: Dict[str, str] = {}

    for raw in text.splitlines():
        line = raw.rstrip()
        if not line or line.startswith(("HloModule", "  ROOT %tuple")):
            pass
        mc = _COMP_RE.match(line)
        if mc and line.endswith("{"):
            cur = mc.group(1)
            comps[cur] = CompCost(coll={k: 0.0 for k in COLLECTIVES},
                                  children=[])
            shapes = {}
            if line.startswith("ENTRY"):
                entry = cur
            continue
        if cur is None:
            continue
        if line.startswith("}"):
            cur = None
            continue
        mn = _NAME_RE.match(line)
        if not mn:
            continue
        mo = _OP_RE.search(line)
        if not mo:
            continue
        name, op = mn.group(1), mo.group(1)
        rtype = line[mn.end():mo.start(1) - 1].strip()
        shapes[name] = rtype
        cc = comps[cur]

        # integer constants (trip-count hints for cond computations)
        m = _CONST_RE.search(line)
        if m:
            trip_hint[cur] = max(trip_hint.get(cur, 1), int(m.group(1)))

        # child computations
        if op == "while":
            mw = _WHILE_RE.search(line)
            if mw:
                cc.children.append(("__while__:" + mw.group(1) + ":" +
                                    mw.group(2), 1.0))
        elif op in ("fusion", "call"):
            mcalls = _CALLS_RE.search(line) or _TOAPPLY_RE.search(line)
            if mcalls:
                cc.children.append((mcalls.group(1), 1.0))
        elif op == "conditional":
            mb = _BRANCH_RE.search(line)
            if mb:
                names = (mb.group(1).split(",") if mb.group(1)
                         else [mb.group(2), mb.group(3)])
                for nm in names:
                    nm = nm.strip().lstrip("%")
                    if nm:
                        cc.children.append((nm, 1.0))

        # flops: dot ops (+ operand-byte traffic for the memory model)
        if op == "dot":
            argstr = line.split("dot(", 1)[1].split(")", 1)[0]
            args = _DOT_ARG_RE.findall(argstr)
            # inline type annotation wins; fall back to the operand's
            # definition earlier in this computation
            lhs = (args[0][0] or shapes.get(args[0][1])) if args else None
            rhs = (args[1][0] or shapes.get(args[1][1])) \
                if len(args) > 1 else None
            mcd = _CONTRACT_RE.search(line)
            k = 1
            opbytes = 0
            if lhs:
                opbytes += _shape_bytes(lhs)
                if mcd:
                    dims = _shape_dims(lhs)
                    if dims:
                        ldims = dims[0][1]
                        for ci in mcd.group(1).split(","):
                            if ci != "" and int(ci) < len(ldims):
                                k *= ldims[int(ci)]
            if rhs:
                opbytes += _shape_bytes(rhs)
            cc.flops += 2.0 * _numel(rtype) * k
            cc.bytes += opbytes + _shape_bytes(rtype)

        # hbm bytes model
        base_op = op.replace("-start", "").replace("-done", "")
        if op in _BYTES_OPS and not op.endswith("-done"):
            cc.bytes += 2.0 * _shape_bytes(rtype)
        elif base_op in COLLECTIVES and not op.endswith("-done"):
            cc.bytes += 2.0 * _shape_bytes(rtype)

        # collectives
        if base_op in COLLECTIVES and not op.endswith("-done"):
            g = _group_size(line, default_group)
            cc.coll[base_op] += _wire_bytes(base_op, _shape_bytes(rtype), g)

    return comps, trip_hint, entry


def scan_scaled_costs(text: str, default_group: int):
    """Returns dict(flops=..., bytes=..., collectives={kind: bytes}) with
    while-loop trip scaling. All values are PER DEVICE."""
    comps, trip_hint, entry = parse_hlo(text, default_group)
    if entry is None:
        return {"flops": 0.0, "bytes": 0.0,
                "collectives": {k: 0.0 for k in COLLECTIVES}}

    memo: Dict[str, Tuple[float, float, Dict[str, float]]] = {}
    stack = set()

    def total(name: str):
        if name in memo:
            return memo[name]
        if name not in comps or name in stack:
            return 0.0, 0.0, {k: 0.0 for k in COLLECTIVES}
        stack.add(name)
        c = comps[name]
        f, b = c.flops, c.bytes
        coll = dict(c.coll)
        for child, times in c.children:
            if child.startswith("__while__:"):
                _, cond, body = child.split(":")
                trip = trip_hint.get(cond, 1)
                for sub in (cond, body):
                    sf, sb, sc = total(sub)
                    f += sf * trip
                    b += sb * trip
                    for k in coll:
                        coll[k] += sc[k] * trip
            else:
                sf, sb, sc = total(child)
                f += sf * times
                b += sb * times
                for k in coll:
                    coll[k] += sc[k] * times
        stack.discard(name)
        memo[name] = (f, b, coll)
        return memo[name]

    f, b, coll = total(entry)
    return {"flops": f, "bytes": b, "collectives": coll}
