"""Engine-level comm scheduling: compile CommSchedules from an Engine's
static UnitPlans and report the alpha-beta latency picture alongside the
payload bits.

This is the launch-side face of core.schedule: the engine owns the plans
(built from per-device shard ShapeDtypeStructs), this module turns a
fusion threshold into the schedule those plans stream through, and folds
the schedule into the wire accounting (`bits.comm_report` message counts
+ `simulate_schedule`'s modeled exposed comm).

All wall-clock-looking numbers here come from the deterministic alpha-beta
MODEL (see core.schedule.simulate_schedule): on this container real
timings are too noisy to validate them — trust the message and dispatch
counts, and read the modeled times as relative comparisons only.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Union

from repro.core.bits import comm_report
from repro.core.plan import UnitPlan
from repro.core.schedule import (CommSchedule, build_schedule,
                                 simulate_schedule)

ScheduleLike = Union[None, int, float, CommSchedule]


def resolve_schedule(plan: Optional[UnitPlan],
                     schedule: ScheduleLike) -> Optional[CommSchedule]:
    """Normalize build_train_step's `schedule=` argument: None passes
    through, a number is a fusion_bytes threshold compiled against `plan`
    (0 = per-bucket messages, math.inf = one fused message), and a
    CommSchedule is checked against the plan it must have been compiled
    from (a schedule for a different partition would silently misroute
    buckets)."""
    if schedule is None:
        return None
    if isinstance(schedule, CommSchedule):
        return _checked(plan, schedule)
    if plan is None:  # nothing to schedule (e.g. fully-FSDP rest tree)
        return None
    return build_schedule(plan, float(schedule))


def _checked(plan: Optional[UnitPlan],
             schedule: CommSchedule) -> CommSchedule:
    # structural equality, not identity: build_plan's lru_cache can evict
    # and rebuild an equal-but-distinct plan object in a long sweep
    if plan is not None and schedule.plan != plan:
        raise ValueError(
            "CommSchedule was compiled from a different UnitPlan than the "
            "engine's; pass fusion_bytes (a number) to compile against the "
            "engine's plan, or build via engine_schedule(engine, ...)")
    return schedule


def engine_schedule(engine, fusion_bytes: Union[int, float]
                    ) -> Optional[CommSchedule]:
    """The CommSchedule the engine's train step streams its DP-aggregated
    (non-FSDP) gradient leaves through — compiled from the same cached
    rest-plan object `Engine._aggregate_grads` executes with, so the
    pre-trace summary and the traced step share one schedule. None when
    the engine has no rest leaves (fully-FSDP trees)."""
    rest_plan, _ = engine.comm_plans()
    if rest_plan is None:
        return None
    return build_schedule(rest_plan, float(fusion_bytes))


def schedule_report(schedule: CommSchedule, cfg, n_workers: int, *,
                    alpha_us: float = 50.0, gbps: float = 12.5,
                    compress_gbps: float = 25.0,
                    backward_us: Optional[float] = None) -> Dict:
    """One JSON-ready dict joining the two views of a schedule: the
    analytic wire bits (comm_report, with the schedule's message count
    and the alpha term priced at alpha_us x gbps) and the modeled
    exposed-vs-overlapped timeline (simulate_schedule)."""
    if hasattr(cfg, "to_config"):
        cfg = cfg.to_config()
    # alpha in bit-equivalents: bits that could have crossed the link in
    # one message latency (us x GB/s x 8e3 bits/us-GBps)
    alpha_bits = int(alpha_us * gbps * 8e3)
    rep = comm_report(cfg, schedule.plan, n_workers, schedule=schedule,
                      alpha_bits_per_message=alpha_bits)
    sim = simulate_schedule(schedule, qw=cfg.qw, alpha_us=alpha_us,
                            gbps=gbps, compress_gbps=compress_gbps,
                            backward_us=backward_us)
    return {
        "summary": schedule.summary(),
        "fusion_bytes": (None if math.isinf(schedule.fusion_bytes)
                         else schedule.fusion_bytes),
        "n_messages": rep.n_messages,
        "n_dispatches": schedule.plan.num_dispatches,
        "n_units": schedule.plan.num_units,
        "uplink_bits_per_worker": rep.uplink_bits_per_worker,
        "latency_bits": rep.latency_bits(),
        "total_bits_with_latency": rep.total_bits_with_latency(),
        "model": sim,
    }
