"""The paper's own benchmark models at CPU scale: a ResNet-9-style CNN,
an AlexNet-style CNN, and an MLP for CIFAR-shaped classification.

Used by the §Repro benchmarks (layer-wise vs entire-model compression,
Figures 2-8 of the paper) with synthetic CIFAR-shaped data. Params are a
nested dict whose "blocks"-free structure makes every tensor its own
layer-wise compression unit — matching the paper's per-layer setup.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.resnet9_cifar import CNNConfig

Array = jax.Array


def _conv_init(key, kh, kw, cin, cout):
    std = math.sqrt(2.0 / (kh * kw * cin))  # He init (relu nets)
    return std * jax.random.normal(key, (kh, kw, cin, cout))


def _dense_init(key, din, dout):
    std = math.sqrt(2.0 / din)
    return std * jax.random.normal(key, (din, dout))


def init_cnn(cfg: CNNConfig, key) -> Dict:
    ks = iter(jax.random.split(key, 32))
    p: Dict = {}
    cin = cfg.channels
    if cfg.kind == "mlp":
        d = cfg.hw * cfg.hw * cfg.channels
        for i, w in enumerate(cfg.widths):
            p[f"fc{i}_w"] = _dense_init(next(ks), d, w)
            p[f"fc{i}_b"] = jnp.zeros((w,))
            d = w
        p["head_w"] = _dense_init(next(ks), d, cfg.classes)
        p["head_b"] = jnp.zeros((cfg.classes,))
        return p
    for i, w in enumerate(cfg.widths):
        p[f"conv{i}_w"] = _conv_init(next(ks), 3, 3, cin, w)
        p[f"conv{i}_b"] = jnp.zeros((w,))
        if cfg.kind == "resnet9":
            p[f"res{i}a_w"] = _conv_init(next(ks), 3, 3, w, w)
            p[f"res{i}b_w"] = _conv_init(next(ks), 3, 3, w, w)
        cin = w
    p["head_w"] = _dense_init(next(ks), cfg.widths[-1], cfg.classes)
    p["head_b"] = jnp.zeros((cfg.classes,))
    return p


def _chan_rms(x, eps=1e-5):
    """Parameter-free channel RMS normalization (batchnorm stand-in —
    keeps the unnormalized DAWNBench nets trainable at this scale)."""
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps)


def _conv(x, w, b=None, stride=1):
    y = jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y if b is None else y + b


def cnn_forward(cfg: CNNConfig, p: Dict, images: Array) -> Array:
    x = images
    if cfg.kind == "mlp":
        h = x.reshape(x.shape[0], -1)
        for i in range(len(cfg.widths)):
            h = jax.nn.relu(h @ p[f"fc{i}_w"] + p[f"fc{i}_b"])
        return h @ p["head_w"] + p["head_b"]
    for i in range(len(cfg.widths)):
        x = _chan_rms(jax.nn.relu(_conv(x, p[f"conv{i}_w"], p[f"conv{i}_b"])))
        x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                  (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
        if cfg.kind == "resnet9":
            r = _chan_rms(jax.nn.relu(_conv(x, p[f"res{i}a_w"])))
            r = _chan_rms(jax.nn.relu(_conv(r, p[f"res{i}b_w"])))
            x = x + r
    x = jnp.mean(x, axis=(1, 2))
    return x @ p["head_w"] + p["head_b"]


def cnn_loss(cfg: CNNConfig, p: Dict, batch) -> Array:
    logits = cnn_forward(cfg, p, batch["images"])
    labels = jax.nn.one_hot(batch["labels"], cfg.classes)
    return -jnp.mean(jnp.sum(labels * jax.nn.log_softmax(logits), axis=-1))


def cnn_accuracy(cfg: CNNConfig, p: Dict, batch) -> Array:
    logits = cnn_forward(cfg, p, batch["images"])
    return jnp.mean((jnp.argmax(logits, -1) == batch["labels"]
                     ).astype(jnp.float32))
