"""Flash attention in pure JAX with a recompute backward (custom_vjp).

Without this, autodiff through the chunked-attention scan saves every
S x S probability block for the backward pass (8+ GB/device/layer at
train_4k for llama3-405b). The custom VJP stores only (q, k, v, out, lse)
— O(S·H·dh) — and recomputes score blocks in the backward, exactly like
the FlashAttention-2 algorithm. layers.chunked_attention remains the
pure-jnp oracle used by the tests.

Masking supports causal + sliding window (window may be a traced per-layer
value; <= 0 disables). FLOPs note for the roofline: all (q-block, kv-block)
pairs are computed and masked — HLO FLOPs count the full square.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array
NEG_INF = -1e30


def _blocks(x, c):
    B, S, H, D = x.shape
    pad = (-S) % c
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n = x.shape[1] // c
    return x.reshape(B, n, c, H, D).transpose(1, 0, 3, 2, 4)  # (n,B,H,c,D)


def _unblocks(xb, S):
    n, B, H, c, D = xb.shape
    return xb.transpose(1, 0, 3, 2, 4).reshape(B, n * c, H, D)[:, :S]


def _mask(qpos, kpos, Sk, causal, window):
    m = kpos[None, :] < Sk
    if causal:
        m = m & (kpos[None, :] <= qpos[:, None])
    w = jnp.asarray(window)
    m = m & ((qpos[:, None] - kpos[None, :] < w) | (w <= 0))
    return m  # (qc, kc)


@partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6))
def flash_attention(q: Array, k: Array, v: Array, window: Array,
                    causal: bool = True, q_offset: int = 0,
                    chunk: int = 1024) -> Array:
    """q (B,Sq,H,dh), k/v (B,Sk,H,dk/dv) — heads already GQA-expanded.
    window: f32 scalar (may be traced — per-layer SWA patterns); <=0
    disables. Returns (B,Sq,H,dv)."""
    out, _ = _flash_fwd_impl(q, k, v, causal, window, q_offset, chunk)
    return out


def _flash_fwd_impl(q, k, v, causal, window, q_offset, chunk):
    B, Sq, H, dh = q.shape
    Sk = k.shape[1]
    dv = v.shape[-1]
    c = min(chunk, Sq, Sk)
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    qb, kb, vb = _blocks(q, c), _blocks(k, c), _blocks(v, c)
    nq, nk = qb.shape[0], kb.shape[0]
    qpos = q_offset + jnp.arange(nq * c).reshape(nq, c)
    kpos = jnp.arange(nk * c).reshape(nk, c)

    def q_block(args):
        qi, qp = args

        def kv_step(carry, kv):
            m, l, acc = carry
            ki, vi, kp = kv
            s = jnp.einsum("bhqd,bhkd->bhqk", qi.astype(jnp.float32),
                           ki.astype(jnp.float32)) * scale
            s = jnp.where(_mask(qp, kp, Sk, causal, window)[None, None],
                          s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p, vi.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, H, c), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, c), jnp.float32)
        a0 = jnp.zeros((B, H, c, dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (kb, vb, kpos))
        l_safe = jnp.maximum(l, 1e-30)
        o = acc / l_safe[..., None]
        lse = m + jnp.log(l_safe)
        return o, lse

    ob, lse = jax.lax.map(q_block, (qb, qpos))   # (nq,B,H,c,dv),(nq,B,H,c)
    out = _unblocks(ob, Sq).astype(q.dtype)
    return out, (ob, lse)


def _flash_fwd(q, k, v, window, causal, q_offset, chunk):
    out, (ob, lse) = _flash_fwd_impl(q, k, v, causal, window, q_offset,
                                     chunk)
    return out, (q, k, v, window, ob, lse)


def _flash_bwd(causal, q_offset, chunk, res, g):
    q, k, v, window, ob, lse = res
    B, Sq, H, dh = q.shape
    Sk = k.shape[1]
    dv = v.shape[-1]
    c = min(chunk, Sq, Sk)
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    qb, kb, vb = _blocks(q, c), _blocks(k, c), _blocks(v, c)
    gb = _blocks(g, c)                                   # (nq,B,H,c,dv)
    nq, nk = qb.shape[0], kb.shape[0]
    qpos = q_offset + jnp.arange(nq * c).reshape(nq, c)
    kpos = jnp.arange(nk * c).reshape(nk, c)
    # delta_i = sum_j dout_ij * out_ij   (rowwise)
    delta = jnp.sum(gb.astype(jnp.float32) * ob, axis=-1)  # (nq,B,H,c)

    def q_step(carry, xs):
        dk, dv_ = carry                                  # (nk,B,H,c,*) f32
        qi, gi, lsei, di, qp = xs

        def kv_step(dq, kv):
            ki, vi, kp, dk_j, dv_j = kv
            s = jnp.einsum("bhqd,bhkd->bhqk", qi.astype(jnp.float32),
                           ki.astype(jnp.float32)) * scale
            s = jnp.where(_mask(qp, kp, Sk, causal, window)[None, None],
                          s, NEG_INF)
            p = jnp.exp(s - lsei[..., None])             # (B,H,qc,kc)
            gif = gi.astype(jnp.float32)
            dv_new = dv_j + jnp.einsum("bhqk,bhqd->bhkd", p, gif)
            dp = jnp.einsum("bhqd,bhkd->bhqk", gif, vi.astype(jnp.float32))
            ds = p * (dp - di[..., None]) * scale
            dq = dq + jnp.einsum("bhqk,bhkd->bhqd", ds,
                                 ki.astype(jnp.float32))
            dk_new = dk_j + jnp.einsum("bhqk,bhqd->bhkd", ds,
                                       qi.astype(jnp.float32))
            return dq, (dk_new, dv_new)

        dq0 = jnp.zeros(qi.shape, jnp.float32)
        dq, (dk, dv_) = jax.lax.scan(kv_step, dq0, (kb, vb, kpos, dk, dv_))
        return (dk, dv_), dq

    dk0 = jnp.zeros((nk, B, H, c, dh), jnp.float32)
    dv0 = jnp.zeros((nk, B, H, c, dv), jnp.float32)
    (dkb, dvb), dqb = jax.lax.scan(q_step, (dk0, dv0),
                                   (qb, gb, lse, delta, qpos))
    dq = _unblocks(dqb, Sq).astype(q.dtype)
    dk = _unblocks(dkb, Sk).astype(k.dtype)
    dv_out = _unblocks(dvb, Sk).astype(v.dtype)
    return dq, dk, dv_out, jnp.zeros_like(window)


flash_attention.defvjp(_flash_fwd, _flash_bwd)
