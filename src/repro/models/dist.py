"""Distribution primitives used inside shard_map model code.

Megatron-style manual tensor parallelism (column/row parallel matmuls with
the f/g conjugate boundary ops), FSDP parameter gathering with a
*compression hook in the backward pass* (this is where the paper's Q_W
intercepts the data-parallel gradient reduction for FSDP-sharded
architectures), vocab-parallel embedding/loss.

All helpers accept axis=None and degrade to single-device semantics, so the
same model code runs in a plain CPU smoke test and inside the production
shard_map.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.aggregation import CompressionConfig

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class DistConfig:
    """Logical-to-mesh axis mapping.

    tp    : tensor/expert-parallel axis name ("model") or None
    fsdp  : parameter/optimizer sharding axis ("data") or None — used by the
            three >100B architectures so params fit HBM
    dp    : gradient-aggregation (data-parallel) axes, e.g. ("data",) or
            ("pod", "data"). When fsdp is set it must be dp[-1].
    sp    : sequence parallelism (Korthikanti et al.): the residual stream
            between blocks is sharded over tp on the sequence dim; block
            entry all-gathers it, block exit reduce-scatters. Cuts the
            saved-activation stack by the TP degree (decisive for the
            >100B archs at train_4k). Train/prefill only.
    """
    tp: Optional[str] = None
    fsdp: Optional[str] = None
    dp: Tuple[str, ...] = ()
    sp: bool = False

    def __post_init__(self):
        if self.fsdp is not None and (not self.dp or self.dp[-1] != self.fsdp):
            raise ValueError("fsdp axis must be the last dp axis")

    @property
    def extra_dp(self) -> Tuple[str, ...]:
        """DP axes other than the fsdp axis (e.g. ('pod',))."""
        if self.fsdp is None:
            return tuple(self.dp)
        return tuple(self.dp[:-1])


# --------------------------------------------------------------------------
# axis-optional collective helpers
# --------------------------------------------------------------------------

def psum(x, axis):
    return x if axis in (None, ()) else jax.lax.psum(x, axis)


def pmax(x, axis):
    return x if axis in (None, ()) else jax.lax.pmax(x, axis)


def pmean(x, axis):
    return x if axis in (None, ()) else jax.lax.pmean(x, axis)


def axis_index(axis):
    return jnp.zeros((), jnp.int32) if axis is None else jax.lax.axis_index(axis)


def axis_size_static(mesh_axis_sizes: dict, axis) -> int:
    if axis in (None, ()):
        return 1
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= mesh_axis_sizes.get(a, 1)
        return n
    return mesh_axis_sizes.get(axis, 1)


def all_gather(x, axis, gather_axis=0, tiled=True):
    if axis in (None, ()):
        return x
    return jax.lax.all_gather(x, axis, axis=gather_axis, tiled=tiled)


@partial(jax.custom_vjp, nondiff_argnums=(1,))
def pmax_sg(x, axis):
    """pmax with zero gradient (softmax-stabilizer use only — jax has no
    differentiation rule for pmax)."""
    return pmax(x, axis)


def _pmax_sg_fwd(x, axis):
    return pmax(x, axis), x.shape


def _pmax_sg_bwd(axis, shape, g):
    return (jnp.zeros(shape, g.dtype),)


pmax_sg.defvjp(_pmax_sg_fwd, _pmax_sg_bwd)


# --------------------------------------------------------------------------
# Megatron f/g boundary ops
# --------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(1,))
def tp_region_in(x, axis):
    """Identity forward / psum backward — enter a column-parallel region.

    Inserted on the activation flowing into column-parallel matmuls so that
    gradients of everything upstream (norms, embeddings, residual stream)
    are correctly summed over the TP axis."""
    return x


def _tpin_fwd(x, axis):
    return x, None


def _tpin_bwd(axis, _, g):
    return (psum(g, axis),)


tp_region_in.defvjp(_tpin_fwd, _tpin_bwd)


@partial(jax.custom_vjp, nondiff_argnums=(1,))
def tp_region_out(x, axis):
    """psum forward / identity backward — exit a row-parallel region.

    The custom identity transpose matters: inside shard_map a plain psum
    transposes to psum, which double-counts gradients whenever the psum'd
    value is consumed replicated-identically on every rank (loss, logits,
    embeddings)."""
    return psum(x, axis)


def _tpout_fwd(x, axis):
    return psum(x, axis), None


def _tpout_bwd(axis, _, g):
    return (g,)


tp_region_out.defvjp(_tpout_fwd, _tpout_bwd)


@partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def gather_replicated(x, axis, dim):
    """all_gather whose output is consumed REPLICATED-identically on every
    rank (e.g. the residual gathered before the final norm): the correct
    adjoint is 'take my shard', not reduce-scatter (which would sum n
    identical cotangents)."""
    return all_gather(x, axis, gather_axis=dim, tiled=True)


def _gr_fwd(x, axis, dim):
    return gather_replicated(x, axis, dim), x.shape[dim]


def _gr_bwd(axis, dim, local, g):
    r = jax.lax.axis_index(axis)
    return (jax.lax.dynamic_slice_in_dim(g, r * local, local, axis=dim),)


gather_replicated.defvjp(_gr_fwd, _gr_bwd)


def make_slice_replicated(n_shards: int):
    """Factory: custom slice-with-allgather-adjoint for a static shard
    count (the TP size is static at model build time)."""

    @partial(jax.custom_vjp, nondiff_argnums=(1, 2))
    def slice_rep(x, axis, dim):
        local = x.shape[dim] // n_shards
        r = jax.lax.axis_index(axis)
        return jax.lax.dynamic_slice_in_dim(x, r * local, local, axis=dim)

    def fwd(x, axis, dim):
        return slice_rep(x, axis, dim), None

    def bwd(axis, dim, _, g):
        return (all_gather(g, axis, gather_axis=dim, tiled=True),)

    slice_rep.defvjp(fwd, bwd)
    return slice_rep


def region_in(x, dist: "DistConfig", axis: int = 1):
    """Enter a column-parallel region.

    sp=False: identity fwd / psum bwd (Megatron 'f').
    sp=True : all-gather the seq-sharded residual (bwd = reduce-scatter)."""
    if dist.tp is None:
        return x
    if dist.sp:
        return all_gather(x, dist.tp, gather_axis=axis, tiled=True)
    return tp_region_in(x, dist.tp)


def region_out(x, dist: "DistConfig", axis: int = 1):
    """Exit a row-parallel region: psum (sp=False) or reduce-scatter back to
    the seq-sharded residual (sp=True)."""
    if dist.tp is None:
        return x
    if dist.sp:
        return jax.lax.psum_scatter(x, dist.tp, scatter_dimension=axis,
                                    tiled=True)
    return tp_region_out(x, dist.tp)


# --------------------------------------------------------------------------
# grad-sync marker for TP-replicated params with per-rank partial grads
# (kv projections, MoE routers, MLA down-projections, ...)
# --------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(1,))
def tp_shared(w, axis):
    """Identity forward / psum backward on a *parameter* that is replicated
    across TP but used differently by each rank (e.g. GQA kv projections:
    each rank backprops only through its q-head group)."""
    return w


def _tps_fwd(w, axis):
    return w, None


def _tps_bwd(axis, _, g):
    return (psum(g, axis),)


tp_shared.defvjp(_tps_fwd, _tps_bwd)


# --------------------------------------------------------------------------
# FSDP parameter gather with compressed-gradient backward
# --------------------------------------------------------------------------

def _hook_compress(g: Array, key_bits: Array, cfg: Optional[CompressionConfig],
                   dist: "DistConfig"):
    """Worker-side Q_W on the local (pre-reduction) gradient of one leaf —
    the layer-wise unit in the FSDP path. Each DP worker folds its mesh
    index into the key (independent compressor randomness per worker)."""
    if cfg is None or cfg.strategy in ("dense",):
        return g
    key = jax.random.wrap_key_data(
        jax.lax.bitcast_convert_type(key_bits, jnp.uint32))
    for ax in dist.dp:
        key = jax.random.fold_in(key, jax.lax.axis_index(ax))
    shape = g.shape
    out = cfg.qw.sim(g.reshape(-1).astype(jnp.float32), key)
    return out.reshape(shape).astype(g.dtype)


@partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def fsdp_param(w: Array, key_bits: Array, dim: int, dist: DistConfig,
               comp: Optional[CompressionConfig]) -> Array:
    """Gather an FSDP-sharded parameter leaf for compute.

    forward : all_gather over dist.fsdp along `dim`
    backward: Q_W(local grad)  ->  reduce-scatter over fsdp axis
              ->  psum over remaining dp axes  -> mean over all dp
    so the parameter gradient arrives *compressed per Algorithm 1* and
    already scattered to match the parameter shard (ZeRO-style).

    `key_bits` is the PRNG key bit-cast to float32 so it can ride through
    custom_vjp as a differentiable arg (cotangent discarded).
    """
    return all_gather(w, dist.fsdp, gather_axis=dim, tiled=True)


def _fsdp_fwd(w, key_bits, dim, dist, comp):
    return fsdp_param(w, key_bits, dim, dist, comp), (w.shape, key_bits)


def _fsdp_bwd(dim, dist, comp, res, g):
    shape, key_bits = res
    g = _hook_compress(g, key_bits, comp, dist)
    if dist.fsdp is not None:
        g = jax.lax.psum_scatter(g, dist.fsdp, scatter_dimension=dim,
                                 tiled=True)
    g = psum(g, dist.extra_dp) if dist.extra_dp else g
    # mean over the DP group (matches the dense path's pmean semantics)
    if dist.dp:
        n = jax.lax.psum(jnp.ones((), g.dtype), tuple(dist.dp))
        g = g / n
    return g, jnp.zeros_like(key_bits)


fsdp_param.defvjp(_fsdp_fwd, _fsdp_bwd)


def fdot(x: Array, w: Array, fsdp_dim, dist: DistConfig) -> Array:
    """Matmul against a weight that stays FSDP-sharded (2D tensor parallel).

    Used on DECODE paths of the >100B architectures: activations are a few
    KB per token, so contracting against the weight shard and reducing the
    tiny activation over the fsdp axis is far cheaper than all-gathering
    6+ GB of layer weights per step (which would also blow HBM).

      fsdp_dim == w.ndim-2 (input dim sharded):
          slice x's features to this rank's rows -> partial matmul ->
          psum over fsdp  (column-parallel over the data axis)
      fsdp_dim == w.ndim-1 (output dim sharded):
          full matmul against the column shard -> all_gather the (tiny)
          output features  (row-parallel over the data axis)
    """
    if fsdp_dim is None or dist.fsdp is None:
        return x @ w
    if fsdp_dim == w.ndim - 2:
        d_local = w.shape[-2]
        r = jax.lax.axis_index(dist.fsdp)
        xs = jax.lax.dynamic_slice_in_dim(x, r * d_local, d_local, axis=-1)
        return psum(xs @ w, dist.fsdp)
    if fsdp_dim == w.ndim - 1:
        return all_gather(x @ w, dist.fsdp, gather_axis=x.ndim - 1,
                          tiled=True)
    raise ValueError(f"unsupported fsdp_dim {fsdp_dim} for w rank {w.ndim}")


def key_to_bits(key: Array) -> Array:
    return jax.lax.bitcast_convert_type(jax.random.key_data(key), jnp.float32)


# --------------------------------------------------------------------------
# vocab-parallel embedding & cross-entropy
# --------------------------------------------------------------------------

def vp_embed(table_local: Array, ids: Array, tp_axis, vocab_global: int) -> Array:
    """Embedding lookup with the vocab dimension sharded over tp_axis.

    table_local: (V_local, d); ids: (...,) int32 global ids."""
    v_local = table_local.shape[0]
    offset = axis_index(tp_axis) * v_local
    local = ids - offset
    ok = (local >= 0) & (local < v_local)
    emb = jnp.take(table_local, jnp.clip(local, 0, v_local - 1), axis=0)
    emb = jnp.where(ok[..., None], emb, 0)
    return tp_region_out(emb, tp_axis)  # identity bwd: see tp_region_out


def vp_xent(logits_local: Array, targets: Array, tp_axis,
            valid: Optional[Array] = None,
            vocab: Optional[int] = None) -> Array:
    """Mean cross-entropy with vocab-sharded logits (T, V_local).

    Numerically stable distributed log-softmax: global max via pmax, global
    log-sum-exp and the target logit via psum. `vocab`: true vocab size —
    padding columns (sharding round-up) are masked out."""
    t = logits_local.astype(jnp.float32)
    v_local = t.shape[-1]
    offset = axis_index(tp_axis) * v_local
    if vocab is not None:
        col = offset + jnp.arange(v_local)
        t = jnp.where(col[None, :] < vocab, t, -1e30)
    # max is a stabilizer only — cut its (unimplemented) pmax grad
    m = pmax_sg(jnp.max(t, axis=-1), tp_axis)
    se = tp_region_out(jnp.sum(jnp.exp(t - m[..., None]), axis=-1), tp_axis)
    local_tgt = targets - offset
    ok = (local_tgt >= 0) & (local_tgt < v_local)
    tl = jnp.take_along_axis(
        t, jnp.clip(local_tgt, 0, v_local - 1)[..., None], axis=-1)[..., 0]
    tgt_logit = tp_region_out(jnp.where(ok, tl, 0.0), tp_axis)
    nll = jnp.log(se) + m - tgt_logit
    if valid is None:
        return jnp.mean(nll)
    w = valid.astype(jnp.float32)
    return jnp.sum(nll * w) / jnp.maximum(jnp.sum(w), 1.0)


def vp_xent_chunked(x: Array, w: Array, targets: Array, tp_axis,
                    vocab: int, chunk: int = 8192) -> Array:
    """Fused head-matmul + vocab-parallel cross-entropy, chunked over
    tokens with per-chunk rematerialization — the full (T, V_local) logits
    are never materialized (they are multi-GB at train_4k).

    x (T_local, d); w (d, V_local); targets (T_local,).
    Returns the SUM of per-token NLL over the local tokens (targets < 0
    are padding). The caller normalizes: under SP, psum the sums over tp
    (tp_region_out) then divide by the global token count."""
    T, d = x.shape
    v_local = w.shape[-1]
    offset = axis_index(tp_axis) * v_local
    col_ok = (offset + jnp.arange(v_local)) < vocab
    c = min(chunk, T)
    pad = (-T) % c
    xp = jnp.pad(x, ((0, pad), (0, 0)))
    tp_ = jnp.pad(targets, (0, pad), constant_values=-1)
    nchunks = xp.shape[0] // c

    def chunk_nll(xc, tc):
        t = (xc @ w).astype(jnp.float32)
        t = jnp.where(col_ok[None, :], t, -1e30)
        m = pmax_sg(jnp.max(t, axis=-1), tp_axis)
        se = tp_region_out(jnp.sum(jnp.exp(t - m[..., None]), axis=-1),
                           tp_axis)
        lt = tc - offset
        ok = (lt >= 0) & (lt < v_local)
        tl = jnp.take_along_axis(t, jnp.clip(lt, 0, v_local - 1)[..., None],
                                 axis=-1)[..., 0]
        tgt = tp_region_out(jnp.where(ok, tl, 0.0), tp_axis)
        nll = jnp.log(se) + m - tgt
        return jnp.sum(jnp.where(tc >= 0, nll, 0.0))

    chunk_nll = jax.checkpoint(chunk_nll)

    def body(acc, xs):
        xc, tc = xs
        return acc + chunk_nll(xc, tc), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32),
                            (xp.reshape(nchunks, c, d),
                             tp_.reshape(nchunks, c)))
    return total  # caller normalizes (and psums over tp under SP)
