from repro.models.config import ModelConfig, InputShape, INPUT_SHAPES
from repro.models.dist import DistConfig
from repro.models.model import Model, declare_params
