"""Shared neural layers: norms, RoPE, MLPs, memory-bounded attention
(chunked flash-style reference), and split-KV decode attention.

All functions are TP-aware (axis=None degrades to local)."""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.dist import (DistConfig, all_gather, axis_index, pmax, psum,
                               region_in, region_out, tp_region_in,
                               tp_region_out, tp_shared)

Array = jax.Array
NEG_INF = -1e30


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------

def rmsnorm(x: Array, gamma: Array, eps: float = 1e-5) -> Array:
    """f32 accumulation via the reduce's accumulator — NOT via converting
    the whole tensor (a whole-tensor convert at block entry makes XLA save
    the scan residual stack in f32: +0.5 GB/layer/device at train_4k)."""
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True, dtype=jnp.float32)
    inv = jax.lax.rsqrt(ms + eps).astype(x.dtype)
    return x * inv * gamma.astype(x.dtype)


def layernorm(x: Array, gamma: Array, beta: Array, eps: float = 1e-5) -> Array:
    mu = jnp.mean(x, axis=-1, keepdims=True, dtype=jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True,
                   dtype=jnp.float32) - jnp.square(mu)
    inv = jax.lax.rsqrt(jnp.maximum(var, 0.0) + eps)
    out = (x - mu.astype(x.dtype)) * inv.astype(x.dtype)
    return out * gamma.astype(x.dtype) + beta.astype(x.dtype)


def apply_norm(p: dict, name: str, x: Array, cfg, dist=None) -> Array:
    """dist (with sp=True) marks a norm inside the sequence-parallel
    region: each TP rank sees a different seq shard, so the (replicated)
    norm params need their grads psum'd over tp (tp_shared)."""
    g = p[f"{name}_g"]
    if dist is not None and dist.sp:
        g = tp_shared(g, dist.tp)
    if cfg.norm == "layernorm":
        b = p[f"{name}_b"]
        if dist is not None and dist.sp:
            b = tp_shared(b, dist.tp)
        return layernorm(x, g, b, cfg.norm_eps)
    return rmsnorm(x, g, cfg.norm_eps)


# --------------------------------------------------------------------------
# RoPE (split-half convention)
# --------------------------------------------------------------------------

def rope(x: Array, pos: Array, theta: float) -> Array:
    """x: (..., S, H, dh) or (..., H, dh) with pos broadcastable to (..., S)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = pos[..., None].astype(jnp.float32) * freqs      # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]                       # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    return jnp.concatenate([x1 * cos - x2 * sin,
                            x1 * sin + x2 * cos], axis=-1).astype(x.dtype)


# --------------------------------------------------------------------------
# MLP (column->row parallel)
# --------------------------------------------------------------------------

def mlp(p: dict, x: Array, cfg, dist: DistConfig, fd=None) -> Array:
    """fd: per-leaf fsdp dims for 2D-TP decode (see dist.fdot); None on the
    train path where weights arrive FSDP-gathered."""
    from repro.models.dist import fdot  # local import (cycle-free)
    fd = fd or {}
    xi = region_in(x, dist)
    if cfg.mlp == "swiglu":
        h = jax.nn.silu(fdot(xi, p["w_gate"], fd.get("w_gate"), dist)) * \
            fdot(xi, p["w_in"], fd.get("w_in"), dist)
    else:
        h = jax.nn.gelu(fdot(xi, p["w_in"], fd.get("w_in"), dist))
    return region_out(fdot(h, p["w_out"], fd.get("w_out"), dist), dist)


# --------------------------------------------------------------------------
# memory-bounded attention (flash-style two-level chunking, pure JAX)
# --------------------------------------------------------------------------

def chunked_attention(q: Array, k: Array, v: Array, *, causal: bool,
                      window: int = 0, q_offset: int = 0,
                      q_chunk: int = 1024, kv_chunk: int = 1024) -> Array:
    """q (B,Sq,H,dh); k,v (B,Sk,H,dh) — H already matched (GQA groups
    expanded). Running-softmax over kv chunks keeps peak memory at
    O(q_chunk·kv_chunk) per (B,H). window>0 = sliding-window mask.
    q_offset: global position of q[0] (cross-chunk causality in prefill)."""
    B, Sq, H, dh = q.shape
    dv = v.shape[-1]  # may differ from dh (MLA: qk dims != v dim)
    Sk = k.shape[1]
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Sk)
    pad_q = (-Sq) % q_chunk
    pad_k = (-Sk) % kv_chunk
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))

    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    nq, nk = qp.shape[1] // q_chunk, kp.shape[1] // kv_chunk
    qb = qp.reshape(B, nq, q_chunk, H, dh).transpose(1, 0, 3, 2, 4)  # (nq,B,H,qc,dh)
    kb = kp.reshape(B, nk, kv_chunk, H, dh).transpose(1, 0, 3, 2, 4)
    vb = vp.reshape(B, nk, kv_chunk, H, dv).transpose(1, 0, 3, 2, 4)

    q_pos = q_offset + jnp.arange(nq * q_chunk).reshape(nq, q_chunk)
    k_pos = jnp.arange(nk * kv_chunk).reshape(nk, kv_chunk)
    k_valid = k_pos < Sk

    def q_block(args):
        qi, qpos = args  # (B,H,qc,dh), (qc,)

        def kv_step(carry, kv):
            m, l, acc = carry
            ki, vi, kpos, kval = kv
            s = jnp.einsum("bhqd,bhkd->bhqk", qi.astype(jnp.float32),
                           ki.astype(jnp.float32)) * scale
            mask = kval[None, :]
            if causal:
                mask = mask & (kpos[None, :] <= qpos[:, None])
            # window may be a traced per-layer value; <=0 disables it
            w = jnp.asarray(window)
            mask = mask & ((qpos[:, None] - kpos[None, :] < w) | (w <= 0))
            s = jnp.where(mask[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p, vi.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, H, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, H, q_chunk, dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                      (kb, vb, k_pos, k_valid))
        return acc / jnp.maximum(l[..., None], 1e-30)

    out = jax.lax.map(q_block, (qb, q_pos))          # (nq,B,H,qc,dv)
    out = out.transpose(1, 0, 3, 2, 4).reshape(B, nq * q_chunk, H, dv)
    return out[:, :Sq].astype(q.dtype)


def expand_kv(k: Array, n_q_heads_local: int, tp_rank: Array,
              n_heads: int, n_kv: int) -> Array:
    """Map full kv heads (B,S,Hkv,dh) to the local q heads' kv
    (B,S,Hl,dh) given GQA grouping. tp_rank is the device's TP index.
    Padded q heads (global id >= n_heads) clip to the last kv head; their
    outputs are masked by head_mask()."""
    group = max(1, n_heads // max(1, n_kv))
    q_global = tp_rank * n_q_heads_local + jnp.arange(n_q_heads_local)
    kv_idx = jnp.clip(q_global // group, 0, n_kv - 1)
    return jnp.take(k, kv_idx, axis=2)


def head_mask(o: Array, cfg, dist: DistConfig, axis: int) -> Array:
    """Zero the outputs of TP-padding heads (n_heads rounded up to a
    multiple of the TP size so heads divide the mesh axis)."""
    Hl = o.shape[axis]
    gid = axis_index(dist.tp) * Hl + jnp.arange(Hl)
    m = (gid < cfg.n_heads).astype(o.dtype)
    shape = [1] * o.ndim
    shape[axis] = Hl
    return o * m.reshape(shape)


def sinusoid_positions(pos: Array, d: int) -> Array:
    """Sinusoidal absolute position embeddings, (...,) -> (..., d)."""
    half = d // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32)
                    * (jnp.log(10000.0) / max(1, half - 1)))
    ang = pos[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# --------------------------------------------------------------------------
# split-KV decode attention: cache sequence-sharded over the TP axis
# --------------------------------------------------------------------------

def quantize_kv(x: Array):
    """Per-vector int8 quantization of one token's k or v (B,Hkv,dh):
    returns (q int8, scale f32 (B,Hkv))."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def splitkv_decode(q_local: Array, k_cache: Array, v_cache: Array,
                   slot_pos: Array, pos: Array, *, dist: DistConfig,
                   n_heads: int, n_kv: int, window: int = 0,
                   k_scale: Array = None, v_scale: Array = None) -> Array:
    """One-token attention against a cache whose sequence dim is sharded
    over dist.tp.

    q_local  (B, Hl, dh)   — this rank's q heads
    k_cache  (B, Hkv, Ss, dh), v_cache same — this rank's seq slice, ALL kv heads
    slot_pos (Ss,) int32   — global position stored in each slot (-1 empty)
    pos      ()            — current decode position
    Returns the LOCAL q heads' attention output (B, Hl, dh).

    Combine: all-gather q heads (tiny), partial softmax per rank over its
    slice, pmax/psum merge, then slice back the local heads.
    """
    B, Hl, dh = q_local.shape
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    # all q heads everywhere (one token: this is a few KB)
    q_all = all_gather(q_local, dist.tp, gather_axis=1, tiled=True)  # (B,H,dh)
    H = q_all.shape[1]
    group = max(1, n_heads // max(1, n_kv))
    kv_of_q = jnp.arange(H) // group

    k_q = jnp.take(k_cache, kv_of_q, axis=1).astype(jnp.float32)
    v_q = jnp.take(v_cache, kv_of_q, axis=1).astype(jnp.float32)
    if k_scale is not None:  # int8 cache: dequantize with per-vector scales
        k_q = k_q * jnp.take(k_scale, kv_of_q, axis=1)[..., None]
        v_q = v_q * jnp.take(v_scale, kv_of_q, axis=1)[..., None]
    s = jnp.einsum("bhd,bhsd->bhs", q_all.astype(jnp.float32),
                   k_q) * scale
    valid = (slot_pos >= 0) & (slot_pos <= pos)
    w = jnp.asarray(window)
    valid = valid & ((slot_pos > pos - w) | (w <= 0))
    s = jnp.where(valid[None, None, :], s, NEG_INF)

    m_l = jnp.maximum(jnp.max(s, axis=-1), 2 * NEG_INF)         # (B,H)
    p = jnp.exp(s - m_l[..., None])
    den_l = jnp.sum(p, axis=-1)
    num_l = jnp.einsum("bhs,bhsd->bhd", p, v_q)

    m = pmax(m_l, dist.tp)
    corr = jnp.exp(m_l - m)
    num = psum(num_l * corr[..., None], dist.tp)
    den = psum(den_l * corr, dist.tp)
    o_all = num / jnp.maximum(den[..., None], 1e-30)            # (B,H,dh)

    r = axis_index(dist.tp)
    start = r * Hl
    o_local = jax.lax.dynamic_slice_in_dim(o_all, start, Hl, axis=1)
    return o_local.astype(q_local.dtype)


def cache_write(cache: Array, slot_pos: Array, new: Array, pos: Array,
                dist: DistConfig, ring_size: int = 0) -> Tuple[Array, Array]:
    """Write one token's k or v (B, Hkv, dh) into the seq-sharded cache.

    ring_size=0: contiguous layout — rank r owns [r·Ss, (r+1)·Ss).
    ring_size>0 (pure sliding-window archs): ring layout over the global
    window — global slot g = pos % ring lives on rank g // Ss. ring_size
    is STATIC (it fixes the cache allocation); the attention mask window
    may still be traced."""
    B, Hkv, Ss, dh = cache.shape
    r = axis_index(dist.tp)
    if ring_size > 0:
        g = pos % ring_size
    else:
        g = pos
    owner = g // Ss
    local = g - owner * Ss
    mine = owner == r
    upd = jax.lax.dynamic_update_slice_in_dim(
        cache, new[:, :, None, :].astype(cache.dtype), local, axis=2)
    cache = jnp.where(mine, upd, cache)
    spos = jnp.where(mine, slot_pos.at[local].set(pos), slot_pos)
    return cache, spos
