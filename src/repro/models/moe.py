"""Mixture-of-Experts with top-k routing, static capacity, and
expert-parallelism over the TP axis ("model").

Inside shard_map every (data, model) cell sees the SAME local tokens
(activations are TP-replicated) and owns E/tp experts. Each rank:
  1. routes all local tokens (router weights replicated -> tp_shared),
  2. gathers the tokens assigned to ITS experts into a (E_local, C, d)
     capacity buffer (rank-within-expert via one-hot cumsum; overflow drops),
  3. runs the expert FFN as one batched matmul (MXU-friendly),
  4. scatters back weighted outputs; a single psum over the TP axis combines
     expert outputs across ranks (and doubles as the TP reduction).

Capacity C = ceil(T·k/E · capacity_factor) is static. Aux losses: standard
load-balance + router z-loss.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.dist import (DistConfig, axis_index, psum, region_in,
                               region_out, tp_region_in, tp_region_out,
                               tp_shared)

Array = jax.Array


def capacity(tokens: int, top_k: int, n_experts: int, cf: float) -> int:
    return max(1, int(math.ceil(tokens * top_k / n_experts * cf)))


def moe_ffn(p: dict, x: Array, cfg, dist: DistConfig,
            fd=None) -> Tuple[Array, Array]:
    """x: (T, d) local tokens (TP-replicated). Returns (out (T,d), aux_loss).

    fd: per-leaf fsdp dims — on decode paths of FSDP archs the expert
    weights stay sharded over the data axis (w_in/w_gate input-dim sharded
    -> slice+psum; w_out output-dim sharded -> all_gather features)."""
    fd = fd or {}
    d = x.shape[-1]
    E, K = cfg.n_experts, cfg.experts_per_token
    tp = dist.tp
    # local expert count: weights arrive sliced by shard_map
    E_l = p["w_in"].shape[0]
    r = axis_index(tp)

    xi = region_in(x, dist, axis=0)   # sp: gather seq-sharded tokens
    T = xi.shape[0]
    logits = (xi @ tp_shared(p["router"], tp)).astype(jnp.float32)  # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, K)                 # (T,K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # ---- aux losses (computed identically on all ranks) ----
    density = jnp.mean(jax.nn.one_hot(eidx, E, dtype=jnp.float32), axis=(0, 1))
    mean_prob = jnp.mean(probs, axis=0)
    lb_loss = E * jnp.sum(density * mean_prob)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    aux = lb_loss + 1e-3 * z_loss
    if tp is not None:
        # aux is computed replicated on every TP rank; its gradient paths
        # (router via tp_shared, xi via the region boundary) SUM over ranks,
        # so scale by 1/n to keep the aux gradient exact.
        aux = aux / jax.lax.psum(1.0, tp)

    # ---- dispatch to local experts ----
    C = capacity(T, K, E, cfg.moe_capacity_factor)
    flat_e = eidx.reshape(-1)                            # (T*K,)
    flat_g = gate.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T), K)
    local_e = flat_e - r * E_l
    sel = (local_e >= 0) & (local_e < E_l)
    le = jnp.clip(local_e, 0, E_l - 1)
    onehot = jax.nn.one_hot(jnp.where(sel, le, E_l), E_l + 1, dtype=jnp.int32)
    rank_in_e = jnp.cumsum(onehot, axis=0) - onehot      # pre-count
    slot = jnp.take_along_axis(rank_in_e, jnp.where(sel, le, E_l)[:, None],
                               axis=1)[:, 0]
    keep = sel & (slot < C)
    dest = jnp.where(keep, le * C + slot, E_l * C)       # overflow -> dump row

    buf = jnp.zeros((E_l * C + 1, d), x.dtype)
    buf = buf.at[dest].add(jnp.where(keep[:, None], xi[flat_t], 0))
    eb = buf[:-1].reshape(E_l, C, d)

    # ---- expert FFN (batched over local experts) ----
    from repro.models.dist import all_gather, fdot, psum as _psum
    eb_in = eb
    if fd.get("w_in") is not None and dist.fsdp is not None:
        dl = p["w_in"].shape[1]
        rf = axis_index(dist.fsdp)
        eb_in = jax.lax.dynamic_slice_in_dim(eb, rf * dl, dl, axis=-1)

    def _ein_in(w):
        h = jnp.einsum("ecd,edf->ecf", eb_in, w)
        if fd.get("w_in") is not None and dist.fsdp is not None:
            h = _psum(h, dist.fsdp)
        return h

    if cfg.mlp == "swiglu":
        h = jax.nn.silu(_ein_in(p["w_gate"])) * _ein_in(p["w_in"])
    else:
        h = jax.nn.gelu(_ein_in(p["w_in"]))
    eo = jnp.einsum("ecf,efd->ecd", h, p["w_out"])       # (E_l,C,d[/fsdp])

    # ---- combine back ----
    d_out = eo.shape[-1]
    flat_out = eo.reshape(E_l * C, d_out)
    picked = jnp.where(keep[:, None],
                       jnp.take(flat_out, jnp.where(keep, le * C + slot, 0),
                                axis=0), 0)
    contrib = picked * flat_g[:, None].astype(x.dtype)
    out = jnp.zeros((T, d_out), x.dtype).at[flat_t].add(contrib)
    if fd.get("w_out") is not None and dist.fsdp is not None:
        out = all_gather(out, dist.fsdp, gather_axis=out.ndim - 1, tiled=True)

    if cfg.moe_shared_expert:
        # shared expert is TP-sharded (column/row parallel); its partial sum
        # rides the same psum as the expert combine.
        hs = jax.nn.silu(fdot(xi, p["shared_w_gate"], fd.get("shared_w_gate"),
                              dist)) * \
            fdot(xi, p["shared_w_in"], fd.get("shared_w_in"), dist)
        out = out + fdot(hs, p["shared_w_out"], fd.get("shared_w_out"), dist)
    out = region_out(out, dist, axis=0)
    return out, aux
