"""Architecture configuration schema (one instance per --arch)."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str              # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    vocab: int
    # attention
    attention: str = "gqa"      # gqa | mla | none
    n_heads: int = 0
    n_kv_heads: int = 0
    d_head: int = 0
    rope_theta: float = 1e6
    sliding_window: int = 0     # 0 = full attention
    swa_pattern: int = 0        # >0: every swa_pattern-th layer is FULL attn,
                                # the rest sliding-window (llama4 iRoPE style)
    # mlp
    d_ff: int = 0
    mlp: str = "swiglu"         # swiglu | gelu
    # moe
    n_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    moe_shared_expert: bool = False
    moe_every: int = 1          # 2 = MoE on every 2nd layer (llama4 style)
    # mla (minicpm3 / deepseek-style)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # ssm (mamba2 SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 64
    ssm_conv: int = 4
    ssm_groups: int = 1
    # hybrid (zamba2): shared attention block every attn_every mamba layers
    attn_every: int = 0
    # encoder-decoder (whisper)
    encoder_layers: int = 0
    frontend: str = "none"      # none | audio_stub | vision_stub
    frontend_seq: int = 0       # audio frames / vision patches per sample
    # misc
    use_rope: bool = True       # False: sinusoidal absolute positions
    norm: str = "rmsnorm"       # rmsnorm | layernorm
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    kv_cache_dtype: str = "bfloat16"  # bfloat16 | int8 (quantized KV cache:
                                      # the paper's quantizers applied to
                                      # inference state; per-vector scales)
    use_fsdp: bool = False      # >100B archs: shard params over the data axis
    train_microbatch: int = 1   # gradient-accumulation steps per train step

    @property
    def d_q(self) -> int:
        return self.n_heads * self.d_head

    @property
    def d_kv(self) -> int:
        return self.n_kv_heads * self.d_head

    @property
    def is_causal_lm(self) -> bool:
        return self.arch_type in ("dense", "moe", "ssm", "hybrid", "vlm")

    def supports_decode(self) -> bool:
        return True  # all assigned archs have a decoder

    def supports_long_context(self) -> bool:
        """long_500k eligibility per the assignment: SSM/hybrid natively,
        dense/moe only with a sliding-window variant."""
        if self.arch_type in ("ssm", "hybrid"):
            return True
        return self.sliding_window > 0

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS = 6·N·D)."""
        d = self.d_model
        n = 0
        # embeddings (+ head unless tied)
        n += self.vocab * d * (1 if self.tie_embeddings else 2)
        L = self.n_layers

        def attn_params() -> int:
            if self.attention == "none":
                return 0
            if self.attention == "mla":
                a = d * self.q_lora_rank
                a += self.q_lora_rank * self.n_heads * (self.qk_nope_dim + self.qk_rope_dim)
                a += d * (self.kv_lora_rank + self.qk_rope_dim)
                a += self.kv_lora_rank * self.n_heads * (self.qk_nope_dim + self.v_head_dim)
                a += self.n_heads * self.v_head_dim * d
                return a
            return d * self.d_q + 2 * d * self.d_kv + self.d_q * d

        def dense_mlp_params() -> int:
            mult = 3 if self.mlp == "swiglu" else 2
            return mult * d * self.d_ff

        def mlp_params() -> int:
            mult = 3 if self.mlp == "swiglu" else 2
            if self.n_experts:
                e = self.n_experts * mult * d * self.d_ff + d * self.n_experts
                if self.moe_shared_expert:
                    e += mult * d * self.d_ff
                # interleaved MoE: only L/moe_every layers are MoE
                if self.moe_every > 1:
                    frac = 1.0 / self.moe_every
                    return int(e * frac + dense_mlp_params() * (1 - frac))
                return e
            return mult * d * self.d_ff

        def ssm_params() -> int:
            d_in = self.ssm_expand * d
            nh = d_in // self.ssm_head_dim
            conv_dim = d_in + 2 * self.ssm_groups * self.ssm_state
            p = d * (2 * d_in + 2 * self.ssm_groups * self.ssm_state + nh)
            p += conv_dim * self.ssm_conv
            p += 3 * nh          # A_log, D, dt_bias
            p += d_in            # gated norm
            p += d_in * d        # out_proj
            return p

        if self.arch_type == "ssm":
            n += L * (ssm_params() + d)
        elif self.arch_type == "hybrid":
            n += L * (ssm_params() + d)
            if self.attn_every:
                n += attn_params() + 2 * d  # one shared attention block
        elif self.arch_type == "audio":
            n += self.encoder_layers * (attn_params() + mlp_params() + 4 * d)
            n += L * (2 * attn_params() + mlp_params() + 6 * d)  # self+cross
            n += self.frontend_seq * d  # learned positions (encoder)
        else:
            n += L * (attn_params() + mlp_params() + 4 * d)
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed experts) for
        MODEL_FLOPS = 6·N_active·D."""
        if not self.n_experts:
            return self.param_count()
        d = self.d_model
        mult = 3 if self.mlp == "swiglu" else 2
        full = self.param_count()
        n_moe_layers = self.n_layers // max(1, self.moe_every)
        all_experts = n_moe_layers * self.n_experts * mult * d * self.d_ff
        active = n_moe_layers * self.experts_per_token * mult * d * self.d_ff
        return full - all_experts + active


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}
