"""Parameter trees with sharding metadata.

Every architecture declares its parameters through ParamBuilder, attaching
per-leaf logical axes:

  "tp"   -> tensor/expert-parallel mesh axis (dist.tp, usually "model")
  "fsdp" -> parameter-sharding mesh axis (dist.fsdp, "data", big archs only)
  None   -> replicated

From one declaration we derive: global ShapeDtypeStructs (dry-run),
PartitionSpecs (shard_map in_specs / jit shardings), init functions (smoke
tests), the stacked-layer mask (compression granularity), and the
tp_grad_sync mask (TP-replicated params with per-rank partial grads).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec

from repro.models.dist import DistConfig

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class LeafMeta:
    axes: Tuple[Optional[str], ...]   # logical axis per GLOBAL dim
    stacked: bool = False             # leading dim is a lax.scan layer stack
    tp_grad_sync: bool = False        # needs grad psum over dist.tp
    init: str = "normal"              # normal | zeros | ones
    fan_in_dim: Optional[int] = None  # dim index used for 1/sqrt(fan_in) scale
    scale: float = 1.0

    def fsdp_dim(self) -> Optional[int]:
        return self.axes.index("fsdp") if "fsdp" in self.axes else None

    def pspec(self, dist: DistConfig) -> PartitionSpec:
        names = []
        for a in self.axes:
            if a == "tp":
                names.append(dist.tp)
            elif a == "fsdp":
                names.append(dist.fsdp)
            else:
                names.append(None)
        return PartitionSpec(*names)


def _nested_set(d: Dict, path: str, value: Any):
    keys = path.split("/")
    for k in keys[:-1]:
        d = d.setdefault(k, {})
    d[keys[-1]] = value


class ParamBuilder:
    def __init__(self, dtype: str = "bfloat16"):
        self.dtype = jnp.dtype(dtype)
        self._shapes: Dict[str, Tuple[int, ...]] = {}
        self._meta: Dict[str, LeafMeta] = {}

    def add(self, path: str, shape: Tuple[int, ...],
            axes: Tuple[Optional[str], ...], *, stacked: bool = False,
            tp_grad_sync: bool = False, init: str = "normal",
            fan_in_dim: Optional[int] = None, scale: float = 1.0):
        assert len(axes) == len(shape), (path, shape, axes)
        self._shapes[path] = tuple(int(s) for s in shape)
        self._meta[path] = LeafMeta(tuple(axes), stacked, tp_grad_sync, init,
                                    fan_in_dim, scale)
        return self

    # ------------------------------------------------------------------
    def shapes(self):
        out: Dict = {}
        for p, s in self._shapes.items():
            _nested_set(out, p, jax.ShapeDtypeStruct(s, self.dtype))
        return out

    def meta(self):
        out: Dict = {}
        for p, m in self._meta.items():
            _nested_set(out, p, m)
        return out

    def pspecs(self, dist: DistConfig):
        out: Dict = {}
        for p, m in self._meta.items():
            _nested_set(out, p, m.pspec(dist))
        return out

    def stacked_mask(self):
        out: Dict = {}
        for p, m in self._meta.items():
            _nested_set(out, p, m.stacked)
        return out

    def tp_sync_mask(self):
        out: Dict = {}
        for p, m in self._meta.items():
            _nested_set(out, p, m.tp_grad_sync)
        return out

    def init(self, key: Array):
        """Materialize GLOBAL parameters (single-host smoke tests / examples)."""
        out: Dict = {}
        for i, (p, shape) in enumerate(self._shapes.items()):
            m = self._meta[p]
            k = jax.random.fold_in(key, i)
            if m.init == "zeros":
                val = jnp.zeros(shape, self.dtype)
            elif m.init == "ones":
                val = jnp.ones(shape, self.dtype)
            else:
                fan_dim = m.fan_in_dim
                if fan_dim is None:
                    fan_dim = len(shape) - 2 if len(shape) >= 2 else 0
                fan_in = shape[fan_dim]
                std = m.scale / math.sqrt(max(1, fan_in))
                val = (std * jax.random.normal(k, shape)).astype(self.dtype)
            _nested_set(out, p, val)
        return out
