"""Mamba2 / SSD (state-space duality, arXiv:2405.21060) in pure JAX.

Chunked SSD algorithm: within a chunk the recurrence is computed as a
masked quadratic form (MXU-friendly); across chunks a small lax.scan carries
the (heads, head_dim, state) SSM state. Heads and inner channels are
TP-sharded; B/C projections are group-shared (G=1 ⇒ MQA-like) and therefore
TP-replicated with tp_shared grad sync.

Decode is the O(1) recurrent step on the carried state (this is why the SSM
architectures run long_500k natively).

The gated output RMSNorm is PER-HEAD (group size = head_dim) so its
statistics are invariant to how heads are sharded over TP — the same
reason Mamba-2 uses GroupNorm with ngroups = tp_size in Megatron.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.dist import (DistConfig, region_in, region_out,
                               tp_region_in, tp_region_out, tp_shared)
from repro.models.layers import rmsnorm

Array = jax.Array


def segsum(x: Array) -> Array:
    """x (..., Q) -> (..., Q, Q) with out[i,j] = sum_{l=j+1..i} x_l (i>=j),
    -inf above the diagonal."""
    Q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    d = cs[..., :, None] - cs[..., None, :]
    i = jnp.arange(Q)
    mask = i[:, None] >= i[None, :]
    return jnp.where(mask, d, -jnp.inf)


def _causal_conv(x: Array, w: Array, state: Array = None):
    """Depthwise causal conv along seq. x (B,S,C), w (C,K).
    If state (B,K-1,C) is given it is prepended (decode/prefill carry).
    Returns (y (B,S,C), new_state (B,K-1,C))."""
    K = w.shape[1]
    if state is None:
        state = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, k:k + x.shape[1], :] * w[:, k][None, None, :]
            for k in range(K))
    new_state = xp[:, -(K - 1):, :] if K > 1 else state
    return y, new_state


def ssd_chunked(xh: Array, dt: Array, A: Array, Bm: Array, Cm: Array,
                D: Array, chunk: int, init_state: Array = None
                ) -> Tuple[Array, Array]:
    """Chunked SSD scan.

    xh (B,S,H,P) values; dt (B,S,H) softplus'd step; A (H,) negative;
    Bm/Cm (B,S,N) group-shared input/output projections; D (H,) skip.
    Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    Bsz, S, H, P = xh.shape
    N = Bm.shape[-1]
    pad = (-S) % chunk
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    nc = xh.shape[1] // chunk
    xc = xh.reshape(Bsz, nc, chunk, H, P).astype(jnp.float32)
    dtc = dt.reshape(Bsz, nc, chunk, H).astype(jnp.float32)
    Bc = Bm.reshape(Bsz, nc, chunk, N).astype(jnp.float32)
    Cc = Cm.reshape(Bsz, nc, chunk, N).astype(jnp.float32)

    dA = dtc * A[None, None, None, :]                     # (B,nc,Q,H) ≤ 0
    dA_h = dA.transpose(0, 1, 3, 2)                       # (B,nc,H,Q)
    dA_cum = jnp.cumsum(dA_h, axis=-1)                    # (B,nc,H,Q)

    # 1) intra-chunk (quadratic, masked).  NB: keep every einsum a
    # 2-operand contraction — multi-operand forms materialize 6-D
    # outer-product temporaries (4+ GB/device at train_4k).
    L = jnp.exp(segsum(dA_h))                             # (B,nc,H,Q,Q)
    CB = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc)            # (B,nc,Q,Q)
    M = CB[:, :, None, :, :] * L * dtc.transpose(0, 1, 3, 2)[:, :, :, None, :]
    y_intra = jnp.einsum("bchqk,bckhp->bcqhp", M, xc)

    # 2) per-chunk input states
    decay_to_end = jnp.exp(dA_cum[..., -1:] - dA_cum)     # (B,nc,H,Q)
    xw = xc * (decay_to_end.transpose(0, 1, 3, 2) * dtc)[..., None]
    S_chunk = jnp.einsum("bckn,bckhp->bchpn", Bc, xw)     # (B,nc,H,P,N)

    # 3) inter-chunk recurrence
    chunk_decay = jnp.exp(dA_cum[..., -1])                # (B,nc,H)
    if init_state is None:
        init_state = jnp.zeros((Bsz, H, P, N), jnp.float32)

    def step(state, inp):
        s_c, g_c = inp                                    # (B,H,P,N), (B,H)
        prev = state
        state = g_c[..., None, None] * state + s_c
        return state, prev

    final, prev_states = jax.lax.scan(
        step, init_state.astype(jnp.float32),
        (S_chunk.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)    # (B,nc,H,P,N)

    # 4) inter-chunk output
    state_decay = jnp.exp(dA_cum)                          # (B,nc,H,Q)
    y_inter = jnp.einsum("bcqn,bchpn->bcqhp", Cc, prev_states) * \
        state_decay.transpose(0, 1, 3, 2)[..., None]

    y = y_intra + y_inter + D[None, None, None, :, None] * xc
    y = y.reshape(Bsz, nc * chunk, H, P)[:, :S]
    return y.astype(xh.dtype), final


def mamba2_block(p: Dict[str, Array], x: Array, cfg, dist: DistConfig,
                 conv_state=None, ssm_state=None, return_state: bool = False):
    """Full Mamba2 block (train / prefill). x (B,S,d) -> (B,S,d)."""
    N, K = cfg.ssm_state, cfg.ssm_conv
    hd = cfg.ssm_head_dim
    xi = region_in(x, dist)
    z = xi @ p["w_z"]                                      # (B,S,d_in_l)
    xr = xi @ p["w_x"]
    bc = xi @ tp_shared(p["w_bc"], dist.tp)                # (B,S,2N)
    dt = xi @ p["w_dt"] + p["dt_bias"][None, None, :]      # (B,S,H_l)
    dt = jax.nn.softplus(dt.astype(jnp.float32))

    cx0 = conv_state[0] if conv_state is not None else None
    cbc0 = conv_state[1] if conv_state is not None else None
    xr, new_cx = _causal_conv(xr, p["conv_x"], cx0)
    bc, new_cbc = _causal_conv(bc, tp_shared(p["conv_bc"], dist.tp), cbc0)
    xr = jax.nn.silu(xr)
    bc = jax.nn.silu(bc)
    Bm, Cm = bc[..., :N], bc[..., N:]

    H_l = p["A_log"].shape[0]
    xh = xr.reshape(*xr.shape[:2], H_l, hd)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, final_state = ssd_chunked(xh, dt, A, Bm, Cm,
                                 p["D"].astype(jnp.float32), cfg.ssm_chunk,
                                 init_state=ssm_state)
    y = rmsnorm(y, p["norm_g"].reshape(H_l, hd), cfg.norm_eps)
    y = y.reshape(*xr.shape) * jax.nn.silu(z)
    out = region_out(y @ p["w_out"], dist)
    if return_state:
        return out, ((new_cx, new_cbc), final_state)
    return out


def mamba2_decode(p: Dict[str, Array], x: Array, conv_state, ssm_state,
                  cfg, dist: DistConfig):
    """One-token recurrent step. x (B,1,d); conv_state = (cx (B,K-1,d_in_l),
    cbc (B,K-1,2N)); ssm_state (B,H_l,P,N). Returns (out, new_states)."""
    N = cfg.ssm_state
    hd = cfg.ssm_head_dim
    xi = tp_region_in(x, dist.tp)
    z = xi @ p["w_z"]
    xr = xi @ p["w_x"]
    bc = xi @ tp_shared(p["w_bc"], dist.tp)
    dt = xi @ p["w_dt"] + p["dt_bias"][None, None, :]
    dt = jax.nn.softplus(dt.astype(jnp.float32))[:, 0]     # (B,H_l)

    xr, new_cx = _causal_conv(xr, p["conv_x"], conv_state[0])
    bc, new_cbc = _causal_conv(bc, tp_shared(p["conv_bc"], dist.tp),
                               conv_state[1])
    xr = jax.nn.silu(xr)[:, 0]                             # (B,d_in_l)
    bc = jax.nn.silu(bc)[:, 0]
    Bm, Cm = bc[..., :N], bc[..., N:]                      # (B,N)

    H_l = p["A_log"].shape[0]
    xh = xr.reshape(-1, H_l, hd).astype(jnp.float32)       # (B,H,P)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    g = jnp.exp(dt * A[None, :])                           # (B,H)
    upd = jnp.einsum("bn,bh,bhp->bhpn", Bm.astype(jnp.float32), dt, xh)
    new_state = g[..., None, None] * ssm_state + upd
    y = jnp.einsum("bn,bhpn->bhp", Cm.astype(jnp.float32), new_state)
    y = y + p["D"].astype(jnp.float32)[None, :, None] * xh
    y = rmsnorm(y.astype(x.dtype), p["norm_g"].reshape(H_l, hd),
                cfg.norm_eps)
    y = y.reshape(x.shape[0], 1, -1) * jax.nn.silu(z)
    out = tp_region_out(y @ p["w_out"], dist.tp)
    return out, ((new_cx, new_cbc), new_state)
