"""Transformer block variants (train + one-token decode paths)."""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.dist import (DistConfig, all_gather, axis_index, fdot,
                               pmax, psum, region_in, region_out,
                               tp_region_in, tp_region_out, tp_shared)
from repro.models.layers import (apply_norm, cache_write, chunked_attention,
                                 expand_kv, head_mask, mlp, quantize_kv,
                                 rmsnorm, rope, splitkv_decode)
from repro.models.flash import flash_attention
from repro.models.moe import moe_ffn

Array = jax.Array


# --------------------------------------------------------------------------
# GQA attention
# --------------------------------------------------------------------------

def gqa_attention(p: Dict, x: Array, cfg, dist: DistConfig, *, causal=True,
                  window=0, pos_offset=0, use_rope=True, prefix="",
                  collect_cache: int = 0, tp_size: int = 1):
    """x (B,S,d) -> (B,S,d) attention residual branch (norm included).

    collect_cache>0: also return this rank's seq-sharded decode cache of
    total length collect_cache (prefill), else cache=None."""
    dh = cfg.d_head
    h = apply_norm(p, f"{prefix}attn_norm", x, cfg, dist)
    hq = region_in(h, dist)
    B, S, _ = hq.shape
    q = (hq @ p[f"{prefix}wq"])
    Hl = q.shape[-1] // dh
    q = q.reshape(B, S, Hl, dh)
    k = (hq @ tp_shared(p[f"{prefix}wk"], dist.tp)).reshape(B, S, -1, dh)
    v = (hq @ tp_shared(p[f"{prefix}wv"], dist.tp)).reshape(B, S, -1, dh)
    pos = pos_offset + jnp.arange(S)
    if use_rope:
        q = rope(q, pos, cfg.rope_theta)
        k = rope(k, pos, cfg.rope_theta)
    r = axis_index(dist.tp)
    ke = expand_kv(k, Hl, r, cfg.n_heads, cfg.n_kv_heads)
    ve = expand_kv(v, Hl, r, cfg.n_heads, cfg.n_kv_heads)
    o = flash_attention(q, ke, ve, jnp.float32(window), causal, pos_offset)
    o = head_mask(o, cfg, dist, axis=2)
    out = region_out(o.reshape(B, S, -1) @ p[f"{prefix}wo"], dist)
    cache = None
    if collect_cache:
        Ss = collect_cache // tp_size
        kp = jnp.pad(k, ((0, 0), (0, max(0, collect_cache - S)), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, max(0, collect_cache - S)), (0, 0), (0, 0)))
        start = r * Ss
        ks = jax.lax.dynamic_slice_in_dim(kp, start, Ss, axis=1)
        vs = jax.lax.dynamic_slice_in_dim(vp, start, Ss, axis=1)
        spos = start + jnp.arange(Ss)
        spos = jnp.where(spos < S, spos, -1)
        kt = ks.transpose(0, 2, 1, 3)
        vt = vs.transpose(0, 2, 1, 3)
        if cfg.kv_cache_dtype == "int8":
            ksc = jnp.max(jnp.abs(kt.astype(jnp.float32)), -1) / 127.0 + 1e-12
            vsc = jnp.max(jnp.abs(vt.astype(jnp.float32)), -1) / 127.0 + 1e-12
            kq = jnp.clip(jnp.round(kt.astype(jnp.float32) / ksc[..., None]),
                          -127, 127).astype(jnp.int8)
            vq = jnp.clip(jnp.round(vt.astype(jnp.float32) / vsc[..., None]),
                          -127, 127).astype(jnp.int8)
            cache = {"k": kq, "v": vq, "k_scale": ksc.astype(jnp.float32),
                     "v_scale": vsc.astype(jnp.float32),
                     "slot_pos": spos.astype(jnp.int32)}
        else:
            cache = {"k": kt, "v": vt, "slot_pos": spos.astype(jnp.int32)}
    return out, cache


def gqa_cross_attention(p: Dict, x: Array, memory: Array, cfg,
                        dist: DistConfig) -> Array:
    """Cross-attention (whisper decoder): q from x, kv from encoder memory."""
    dh = cfg.d_head
    h = apply_norm(p, "cross_norm", x, cfg, dist)
    hq = region_in(h, dist)
    B, S, _ = hq.shape
    mq = tp_region_in(memory, dist.tp)
    q = (hq @ p["cwq"])
    Hl = q.shape[-1] // dh
    q = q.reshape(B, S, Hl, dh)
    k = (mq @ tp_shared(p["cwk"], dist.tp)).reshape(B, memory.shape[1], -1, dh)
    v = (mq @ tp_shared(p["cwv"], dist.tp)).reshape(B, memory.shape[1], -1, dh)
    r = axis_index(dist.tp)
    ke = expand_kv(k, Hl, r, cfg.n_heads, cfg.n_kv_heads)
    ve = expand_kv(v, Hl, r, cfg.n_heads, cfg.n_kv_heads)
    o = flash_attention(q, ke, ve, jnp.float32(0), False, 0)
    o = head_mask(o, cfg, dist, axis=2)
    return region_out(o.reshape(B, S, -1) @ p["cwo"], dist)


def gqa_attention_decode(p: Dict, x: Array, cache: Dict, pos: Array, cfg,
                         dist: DistConfig, *, window=0, use_rope=True,
                         prefix="", fd=None) -> Tuple[Array, Dict]:
    """One-token attention with a seq-sharded cache.

    x (B,1,d); cache {k (B,Hkv,Ss,dh), v, slot_pos (Ss,)}.
    fd: per-leaf fsdp dim (2D-TP decode for FSDP-sharded archs).
    """
    fd = fd or {}
    B = x.shape[0]
    dh = cfg.d_head
    h = apply_norm(p, f"{prefix}attn_norm", x, cfg)
    hq = tp_region_in(h, dist.tp)
    q = fdot(hq, p[f"{prefix}wq"], fd.get(f"{prefix}wq"), dist)
    Hl = q.shape[-1] // dh
    q = q.reshape(B, 1, Hl, dh)
    k = fdot(hq, tp_shared(p[f"{prefix}wk"], dist.tp),
             fd.get(f"{prefix}wk"), dist).reshape(B, 1, -1, dh)
    v = fdot(hq, tp_shared(p[f"{prefix}wv"], dist.tp),
             fd.get(f"{prefix}wv"), dist).reshape(B, 1, -1, dh)
    if use_rope:
        pvec = pos[None] if pos.ndim == 0 else pos
        q = rope(q, pvec[None, :], cfg.rope_theta)
        k = rope(k, pvec[None, :], cfg.rope_theta)
    q1 = q[:, 0]                                       # (B,Hl,dh)
    k1, v1 = k[:, 0], v[:, 0]                          # (B,Hkv,dh)
    ring = (cfg.sliding_window if (cfg.sliding_window > 0
                                   and cfg.swa_pattern == 0) else 0)
    new_cache = dict(cache)
    if cfg.kv_cache_dtype == "int8":
        k1q, k1s = quantize_kv(k1)
        v1q, v1s = quantize_kv(v1)
        ck, spos = cache_write(cache["k"], cache["slot_pos"], k1q, pos,
                               dist, ring_size=ring)
        cv, _ = cache_write(cache["v"], cache["slot_pos"], v1q, pos, dist,
                            ring_size=ring)
        cks, _ = cache_write(cache["k_scale"][..., None],
                             cache["slot_pos"], k1s[..., None], pos, dist,
                             ring_size=ring)
        cvs, _ = cache_write(cache["v_scale"][..., None],
                             cache["slot_pos"], v1s[..., None], pos, dist,
                             ring_size=ring)
        cks, cvs = cks[..., 0], cvs[..., 0]
        o = splitkv_decode(q1, ck, cv, spos, pos, dist=dist,
                           n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                           window=window, k_scale=cks, v_scale=cvs)
        new_cache.update(k=ck, v=cv, k_scale=cks, v_scale=cvs,
                         slot_pos=spos)
    else:
        ck, spos = cache_write(cache["k"], cache["slot_pos"], k1, pos, dist,
                               ring_size=ring)
        cv, _ = cache_write(cache["v"], cache["slot_pos"], v1, pos, dist,
                            ring_size=ring)
        o = splitkv_decode(q1, ck, cv, spos, pos, dist=dist,
                           n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                           window=window)
        new_cache.update(k=ck, v=cv, slot_pos=spos)
    o = head_mask(o, cfg, dist, axis=1)
    out = tp_region_out(
        fdot(o.reshape(B, 1, -1).astype(x.dtype), p[f"{prefix}wo"],
             fd.get(f"{prefix}wo"), dist), dist.tp)
    return out, new_cache


# --------------------------------------------------------------------------
# MLA (multi-head latent attention, MiniCPM3/DeepSeek style)
# --------------------------------------------------------------------------

def _mla_qkv(p, hq, cfg, dist, pos, fd=None):
    fd = fd or {}
    B, S, _ = hq.shape
    nope, rdim, vdim = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    cq = rmsnorm(fdot(hq, tp_shared(p["wq_down"], dist.tp),
                      fd.get("wq_down"), dist),
                 tp_shared(p["q_norm_g"], dist.tp), cfg.norm_eps)
    qf = cq @ p["wq_up"]                               # (B,S,Hl*(nope+rdim))
    Hl = qf.shape[-1] // (nope + rdim)
    qf = qf.reshape(B, S, Hl, nope + rdim)
    q_nope, q_rope = qf[..., :nope], qf[..., nope:]
    q_rope = rope(q_rope, pos, cfg.rope_theta)

    kvd = fdot(hq, tp_shared(p["wkv_down"], dist.tp), fd.get("wkv_down"),
               dist)                                   # (B,S,r+rdim)
    c_kv = rmsnorm(kvd[..., :cfg.kv_lora_rank],
                   tp_shared(p["kv_norm_g"], dist.tp), cfg.norm_eps)
    k_rope = rope(kvd[..., None, cfg.kv_lora_rank:], pos, cfg.rope_theta)
    return q_nope, q_rope, c_kv, k_rope, Hl


def mla_attention(p: Dict, x: Array, cfg, dist: DistConfig, *,
                  pos_offset=0, collect_cache: int = 0, tp_size: int = 1):
    nope, rdim, vdim = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    h = apply_norm(p, "attn_norm", x, cfg, dist)
    hq = region_in(h, dist)
    B, S, _ = hq.shape
    pos = pos_offset + jnp.arange(S)
    q_nope, q_rope, c_kv, k_rope, Hl = _mla_qkv(p, hq, cfg, dist, pos)

    k_nope = (c_kv @ p["wk_up"]).reshape(B, S, Hl, nope)
    vv = (c_kv @ p["wv_up"]).reshape(B, S, Hl, vdim)
    k = jnp.concatenate([k_nope,
                         jnp.broadcast_to(k_rope, (B, S, Hl, rdim))], -1)
    q = jnp.concatenate([q_nope, q_rope], -1)
    o = flash_attention(q, k, vv, jnp.float32(0), True, pos_offset)
    o = head_mask(o, cfg, dist, axis=2)
    out = region_out(o.reshape(B, S, -1) @ p["wo"], dist)
    cache = None
    if collect_cache:
        r = axis_index(dist.tp)
        Ss = collect_cache // tp_size
        cp = jnp.pad(c_kv, ((0, 0), (0, max(0, collect_cache - S)), (0, 0)))
        rp = jnp.pad(k_rope[:, :, 0, :],
                     ((0, 0), (0, max(0, collect_cache - S)), (0, 0)))
        start = r * Ss
        cs = jax.lax.dynamic_slice_in_dim(cp, start, Ss, axis=1)
        rs = jax.lax.dynamic_slice_in_dim(rp, start, Ss, axis=1)
        spos = start + jnp.arange(Ss)
        spos = jnp.where(spos < S, spos, -1)
        cache = {"ckv": cs[:, None], "krope": rs[:, None],
                 "slot_pos": spos.astype(jnp.int32)}
    return out, cache


def mla_attention_decode(p: Dict, x: Array, cache: Dict, pos: Array, cfg,
                         dist: DistConfig, fd=None) -> Tuple[Array, Dict]:
    """Absorbed MLA decode against a seq-sharded LATENT cache:
    cache {ckv (B,1,Ss,r), krope (B,1,Ss,rdim), slot_pos (Ss,)}."""
    B = x.shape[0]
    nope, rdim, vdim = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    r_lat = cfg.kv_lora_rank
    h = apply_norm(p, "attn_norm", x, cfg)
    hq = tp_region_in(h, dist.tp)
    pvec = pos[None]
    q_nope, q_rope, c_kv, k_rope, Hl = _mla_qkv(p, hq, cfg, dist,
                                                pvec[None, :], fd=fd)

    # absorb k_up into q:  q_eff_h = q_nope_h · W_kup_h^T  -> latent space
    wk = p["wk_up"].reshape(r_lat, Hl, nope)
    q_eff = jnp.einsum("bhn,rhn->bhr", q_nope[:, 0].astype(jnp.float32),
                       wk.astype(jnp.float32))          # (B,Hl,r)
    qr = q_rope[:, 0].astype(jnp.float32)               # (B,Hl,rdim)

    ck, spos = cache_write(cache["ckv"], cache["slot_pos"],
                           c_kv[:, :, None, :][:, 0], pos, dist)
    kr, _ = cache_write(cache["krope"], cache["slot_pos"],
                        k_rope[:, 0].transpose(0, 1, 2), pos, dist)

    # gather all heads' latent queries (tiny), split-KV over the cache
    q_all = all_gather(jnp.concatenate([q_eff, qr], -1), dist.tp,
                       gather_axis=1, tiled=True)        # (B,H,r+rdim)
    lat = jnp.concatenate([ck[:, 0], kr[:, 0]], -1)      # (B,Ss,r+rdim)
    s = jnp.einsum("bhr,bsr->bhs", q_all,
                   lat.astype(jnp.float32)) / jnp.sqrt(float(nope + rdim))
    valid = (spos >= 0) & (spos <= pos)
    s = jnp.where(valid[None, None, :], s, -1e30)
    m_l = jnp.maximum(jnp.max(s, axis=-1), -2e30)
    pr = jnp.exp(s - m_l[..., None])
    den_l = pr.sum(-1)
    num_l = jnp.einsum("bhs,bsr->bhr", pr, ck[:, 0].astype(jnp.float32))
    m = pmax(m_l, dist.tp)
    corr = jnp.exp(m_l - m)
    num = psum(num_l * corr[..., None], dist.tp)
    den = psum(den_l * corr, dist.tp)
    ctx = num / jnp.maximum(den[..., None], 1e-30)       # (B,H,r) latent ctx
    rk = axis_index(dist.tp)
    ctx_l = jax.lax.dynamic_slice_in_dim(ctx, rk * Hl, Hl, axis=1)
    wv = p["wv_up"].reshape(r_lat, Hl, vdim)
    o = jnp.einsum("bhr,rhv->bhv", ctx_l, wv.astype(jnp.float32))
    o = head_mask(o, cfg, dist, axis=1)
    fd = fd or {}
    out = tp_region_out(
        fdot(o.reshape(B, 1, -1).astype(x.dtype), p["wo"], fd.get("wo"),
             dist), dist.tp)
    return out, {"ckv": ck, "krope": kr, "slot_pos": spos}


# --------------------------------------------------------------------------
# full blocks (attention/ssm + mlp/moe), train path
# --------------------------------------------------------------------------

def decoder_block(p: Dict, x: Array, cfg, dist: DistConfig, *, window=0,
                  pos_offset=0, causal=True, use_rope=True,
                  memory: Optional[Array] = None, collect_cache: int = 0,
                  tp_size: int = 1):
    """Generic transformer block. Returns (x, aux_loss, cache|None)."""
    aux = jnp.zeros((), jnp.float32)
    cache = None
    if cfg.attention == "mla":
        a, cache = mla_attention(p, x, cfg, dist, pos_offset=pos_offset,
                                 collect_cache=collect_cache, tp_size=tp_size)
        x = x + a
    elif cfg.attention != "none":
        a, cache = gqa_attention(p, x, cfg, dist, causal=causal,
                                 window=window, pos_offset=pos_offset,
                                 use_rope=use_rope,
                                 collect_cache=collect_cache, tp_size=tp_size)
        x = x + a
    if memory is not None:
        x = x + gqa_cross_attention(p, x, memory, cfg, dist)
    h = apply_norm(p, "mlp_norm", x, cfg, dist)
    if cfg.n_experts:
        B, S, d = h.shape
        out, aux = moe_ffn(p, h.reshape(B * S, d), cfg, dist)
        x = x + out.reshape(B, S, d)
    else:
        x = x + mlp(p, h, cfg, dist)
    return x, aux, cache


def decoder_block_decode(p: Dict, x: Array, cache: Dict, pos: Array, cfg,
                         dist: DistConfig, *, window=0,
                         memory: Optional[Array] = None, fd=None):
    """One-token version of decoder_block. Returns (x, new_cache)."""
    if cfg.attention == "mla":
        a, new_cache = mla_attention_decode(p, x, cache, pos, cfg, dist,
                                            fd=fd)
    elif cfg.attention != "none":
        a, new_cache = gqa_attention_decode(p, x, cache, pos, cfg, dist,
                                            window=window,
                                            use_rope=cfg.use_rope, fd=fd)
    else:
        a, new_cache = 0.0, cache
    x = x + a
    if memory is not None:
        x = x + gqa_cross_attention(p, x, memory, cfg, dist)
    h = apply_norm(p, "mlp_norm", x, cfg)
    if cfg.n_experts:
        B, S, d = h.shape
        out, _ = moe_ffn(p, h.reshape(B * S, d), cfg, dist, fd=fd)
        x = x + out.reshape(B, S, d)
    else:
        x = x + mlp(p, h, cfg, dist, fd=fd)
    return x, new_cache
