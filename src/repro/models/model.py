"""Model assembly: parameter declaration, train/prefill/decode forward
passes, and cache layouts for every assigned architecture family.

All forward code runs either plainly (single device, all axes None) or
inside shard_map on the production mesh — the DistConfig decides.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.aggregation import CompressionConfig
from repro.models import blocks as B
from repro.models.config import InputShape, ModelConfig
from repro.models.dist import (DistConfig, all_gather, axis_index,
                               fsdp_param, key_to_bits, psum, tp_region_in,
                               tp_shared, vp_embed, vp_xent)
from repro.models.layers import apply_norm, sinusoid_positions
from repro.models.mamba2 import mamba2_block, mamba2_decode
from repro.models.params import LeafMeta, ParamBuilder

Array = jax.Array


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


# jax 0.4.x ships optimization_barrier with no batching rule; the shared
# shim lives in core.schedule (the other barrier user) — idempotent, so
# calling it again here keeps this module import-order independent.
from repro.core.schedule import register_barrier_batching_rule

register_barrier_batching_rule()


@jax.custom_vjp
def _grad_barrier(x):
    """optimization_barrier with a defined gradient (jax 0.4.x has no
    differentiation rule for the primitive). The cotangent is barriered
    too, preserving the hoisting protection in the backward scan."""
    return jax.lax.optimization_barrier(x)


def _grad_barrier_fwd(x):
    return jax.lax.optimization_barrier(x), None


def _grad_barrier_bwd(_, g):
    return (jax.lax.optimization_barrier(g),)


_grad_barrier.defvjp(_grad_barrier_fwd, _grad_barrier_bwd)


# ==========================================================================
# parameter declaration
# ==========================================================================

def _add_norm(pb: ParamBuilder, path: str, shape, cfg, stacked):
    pb.add(path + "_g", shape, (None,) * len(shape), stacked=stacked,
           init="ones")
    if cfg.norm == "layernorm":
        pb.add(path + "_b", shape, (None,) * len(shape), stacked=stacked,
               init="zeros")


def _add_attn(pb: ParamBuilder, base: str, cfg: ModelConfig, tp_size: int,
              L: Optional[int], F, prefix: str = ""):
    """GQA attention tensors. L=None -> non-stacked (shared block)."""
    d = cfg.d_model
    Hp = _ceil_to(cfg.n_heads, tp_size)
    dh = cfg.d_head
    stk = L is not None
    lead = (L,) if stk else ()
    la = (None,) if stk else ()
    _add_norm(pb, f"{base}/{prefix}attn_norm", lead + (d,), cfg, stk)
    pb.add(f"{base}/{prefix}wq", lead + (d, Hp * dh), la + (F, "tp"),
           stacked=stk, fan_in_dim=len(lead))
    pb.add(f"{base}/{prefix}wk", lead + (d, cfg.n_kv_heads * dh),
           la + (F, None), stacked=stk, tp_grad_sync=True,
           fan_in_dim=len(lead))
    pb.add(f"{base}/{prefix}wv", lead + (d, cfg.n_kv_heads * dh),
           la + (F, None), stacked=stk, tp_grad_sync=True,
           fan_in_dim=len(lead))
    pb.add(f"{base}/{prefix}wo", lead + (Hp * dh, d), la + ("tp", F),
           stacked=stk, fan_in_dim=len(lead))


def _add_mla(pb: ParamBuilder, base: str, cfg: ModelConfig, tp_size: int,
             L: int, F):
    d = cfg.d_model
    Hp = _ceil_to(cfg.n_heads, tp_size)
    qr, r = cfg.q_lora_rank, cfg.kv_lora_rank
    nope, rd, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    _add_norm(pb, f"{base}/attn_norm", (L, d), cfg, True)
    pb.add(f"{base}/wq_down", (L, d, qr), (None, F, None), stacked=True,
           tp_grad_sync=True, fan_in_dim=1)
    pb.add(f"{base}/q_norm_g", (L, qr), (None, None), stacked=True,
           init="ones")
    pb.add(f"{base}/wq_up", (L, qr, Hp * (nope + rd)), (None, None, "tp"),
           stacked=True, fan_in_dim=1)
    pb.add(f"{base}/wkv_down", (L, d, r + rd), (None, F, None), stacked=True,
           tp_grad_sync=True, fan_in_dim=1)
    pb.add(f"{base}/kv_norm_g", (L, r), (None, None), stacked=True,
           init="ones")
    pb.add(f"{base}/wk_up", (L, r, Hp * nope), (None, None, "tp"),
           stacked=True, fan_in_dim=1)
    pb.add(f"{base}/wv_up", (L, r, Hp * vd), (None, None, "tp"),
           stacked=True, fan_in_dim=1)
    pb.add(f"{base}/wo", (L, Hp * vd, d), (None, "tp", F), stacked=True,
           fan_in_dim=1)


def _add_mlp(pb: ParamBuilder, base: str, cfg: ModelConfig, L: Optional[int],
             F, names=("w_gate", "w_in", "w_out"), d_ff=None,
             prefix: str = ""):
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    stk = L is not None
    lead = (L,) if stk else ()
    la = (None,) if stk else ()
    _add_norm(pb, f"{base}/{prefix}mlp_norm", lead + (d,), cfg, stk)
    if cfg.mlp == "swiglu":
        pb.add(f"{base}/{prefix}{names[0]}", lead + (d, ff), la + (F, "tp"),
               stacked=stk, fan_in_dim=len(lead))
    pb.add(f"{base}/{prefix}{names[1]}", lead + (d, ff), la + (F, "tp"),
           stacked=stk, fan_in_dim=len(lead))
    pb.add(f"{base}/{prefix}{names[2]}", lead + (ff, d), la + ("tp", F),
           stacked=stk, fan_in_dim=len(lead))


def _add_moe(pb: ParamBuilder, base: str, cfg: ModelConfig, L: int, F,
             prefix: str = ""):
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    _add_norm(pb, f"{base}/{prefix}mlp_norm", (L, d), cfg, True)
    pb.add(f"{base}/{prefix}router", (L, d, E), (None, None, None),
           stacked=True, tp_grad_sync=True, fan_in_dim=1)
    if cfg.mlp == "swiglu":
        pb.add(f"{base}/{prefix}w_gate", (L, E, d, ff), (None, "tp", F, None),
               stacked=True, fan_in_dim=2)
    pb.add(f"{base}/{prefix}w_in", (L, E, d, ff), (None, "tp", F, None),
           stacked=True, fan_in_dim=2)
    pb.add(f"{base}/{prefix}w_out", (L, E, ff, d), (None, "tp", None, F),
           stacked=True, fan_in_dim=2)
    if cfg.moe_shared_expert:
        pb.add(f"{base}/{prefix}shared_w_gate", (L, d, ff), (None, F, "tp"),
               stacked=True, fan_in_dim=1)
        pb.add(f"{base}/{prefix}shared_w_in", (L, d, ff), (None, F, "tp"),
               stacked=True, fan_in_dim=1)
        pb.add(f"{base}/{prefix}shared_w_out", (L, ff, d), (None, "tp", F),
               stacked=True, fan_in_dim=1)


def _add_ssm(pb: ParamBuilder, base: str, cfg: ModelConfig, L: int, F):
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    nh = d_in // cfg.ssm_head_dim
    N, K, G = cfg.ssm_state, cfg.ssm_conv, cfg.ssm_groups
    _add_norm(pb, f"{base}/norm_in", (L, d), cfg, True)
    pb.add(f"{base}/w_z", (L, d, d_in), (None, F, "tp"), stacked=True,
           fan_in_dim=1)
    pb.add(f"{base}/w_x", (L, d, d_in), (None, F, "tp"), stacked=True,
           fan_in_dim=1)
    pb.add(f"{base}/w_bc", (L, d, 2 * G * N), (None, F, None), stacked=True,
           tp_grad_sync=True, fan_in_dim=1)
    pb.add(f"{base}/w_dt", (L, d, nh), (None, F, "tp"), stacked=True,
           fan_in_dim=1)
    pb.add(f"{base}/conv_x", (L, d_in, K), (None, "tp", None), stacked=True,
           scale=0.5, fan_in_dim=2)
    pb.add(f"{base}/conv_bc", (L, 2 * G * N, K), (None, None, None),
           stacked=True, tp_grad_sync=True, scale=0.5, fan_in_dim=2)
    pb.add(f"{base}/A_log", (L, nh), (None, "tp"), stacked=True, init="zeros")
    pb.add(f"{base}/D", (L, nh), (None, "tp"), stacked=True, init="ones")
    pb.add(f"{base}/dt_bias", (L, nh), (None, "tp"), stacked=True,
           init="zeros")
    pb.add(f"{base}/norm_g", (L, d_in), (None, "tp"), stacked=True,
           init="ones")
    pb.add(f"{base}/w_out", (L, d_in, d), (None, "tp", F), stacked=True,
           fan_in_dim=1)


def declare_params(cfg: ModelConfig, tp_size: int) -> ParamBuilder:
    pb = ParamBuilder(cfg.dtype)
    F = "fsdp" if cfg.use_fsdp else None
    d, L = cfg.d_model, cfg.n_layers
    Vp = _ceil_to(cfg.vocab, 128)

    pb.add("embed", (Vp, d), ("tp", F), fan_in_dim=1)
    if not cfg.tie_embeddings:
        pb.add("head", (d, Vp), (F, "tp"), fan_in_dim=0)
    _add_norm(pb, "final_norm", (d,), cfg, False)

    if cfg.arch_type in ("dense", "vlm", "moe"):
        if cfg.n_experts and cfg.moe_every > 1:
            # interleaved MoE (llama4): one scan unit = dense block + MoE
            # block; params carry a_/b_ prefixes within the unit.
            assert cfg.moe_every == 2 and L % 2 == 0
            Lu = L // 2
            _add_attn(pb, "blocks", cfg, tp_size, Lu, F, prefix="a_")
            _add_mlp(pb, "blocks", cfg, Lu, F, prefix="a_")
            _add_attn(pb, "blocks", cfg, tp_size, Lu, F, prefix="b_")
            _add_moe(pb, "blocks", cfg, Lu, F, prefix="b_")
        else:
            _add_attn(pb, "blocks", cfg, tp_size, L, F) \
                if cfg.attention == "gqa" else _add_mla(pb, "blocks", cfg,
                                                        tp_size, L, F)
            if cfg.n_experts:
                _add_moe(pb, "blocks", cfg, L, F)
            else:
                _add_mlp(pb, "blocks", cfg, L, F)
    elif cfg.arch_type == "ssm":
        _add_ssm(pb, "blocks", cfg, L, F)
    elif cfg.arch_type == "hybrid":
        G = L // cfg.attn_every
        tail = L - G * cfg.attn_every
        _add_ssm(pb, "blocks", cfg, G * cfg.attn_every, F)
        if tail:
            _add_ssm(pb, "tail_blocks", cfg, tail, F)
        _add_attn(pb, "shared", cfg, tp_size, None, F)
        _add_mlp(pb, "shared", cfg, None, F)
    elif cfg.arch_type == "audio":
        Le = cfg.encoder_layers
        pb.add("enc_pos", (cfg.frontend_seq, d), (None, None), scale=0.02,
               fan_in_dim=1)
        _add_attn(pb, "encoder_blocks", cfg, tp_size, Le, F)
        _add_mlp(pb, "encoder_blocks", cfg, Le, F)
        _add_norm(pb, "enc_final_norm", (d,), cfg, False)
        _add_attn(pb, "decoder_blocks", cfg, tp_size, L, F)
        _add_norm(pb, "decoder_blocks/cross_norm", (L, d), cfg, True)
        pb.add("decoder_blocks/cwq",
               (L, d, _ceil_to(cfg.n_heads, tp_size) * cfg.d_head),
               (None, F, "tp"), stacked=True, fan_in_dim=1)
        pb.add("decoder_blocks/cwk", (L, d, cfg.d_kv), (None, F, None),
               stacked=True, tp_grad_sync=True, fan_in_dim=1)
        pb.add("decoder_blocks/cwv", (L, d, cfg.d_kv), (None, F, None),
               stacked=True, tp_grad_sync=True, fan_in_dim=1)
        pb.add("decoder_blocks/cwo",
               (L, _ceil_to(cfg.n_heads, tp_size) * cfg.d_head, d),
               (None, "tp", F), stacked=True, fan_in_dim=1)
        _add_mlp(pb, "decoder_blocks", cfg, L, F)
    else:
        raise ValueError(cfg.arch_type)
    return pb


# ==========================================================================
# the Model
# ==========================================================================

class Model:
    def __init__(self, cfg: ModelConfig, dist: DistConfig,
                 mesh_axis_sizes: Optional[Dict[str, int]] = None):
        self.cfg = cfg
        self.dist = dist
        sizes = mesh_axis_sizes or {}
        self.tp_size = sizes.get(dist.tp, 1) if dist.tp else 1
        self.dp_size = 1
        for a in dist.dp:
            self.dp_size *= sizes.get(a, 1)
        self.pb = declare_params(cfg, self.tp_size)
        self.meta = self.pb.meta()
        self.vocab_padded = _ceil_to(cfg.vocab, 128)
        self.dist_nosp = dataclasses.replace(dist, sp=False)

    def _eff(self, seq_len: int) -> DistConfig:
        """Sequence parallelism applies when enabled, tp>1, the seq divides
        the TP axis, and the arch is not enc-dec (whisper frames=1500)."""
        if (not self.dist.sp or self.dist.tp is None or self.tp_size <= 1
                or seq_len % self.tp_size != 0
                or self.cfg.arch_type == "audio"):
            return self.dist_nosp
        return self.dist

    def _sp_slice(self, x, dist):
        if not dist.sp:
            return x
        from repro.models.dist import make_slice_replicated
        return make_slice_replicated(self.tp_size)(x, dist.tp, 1)

    def _sp_gather(self, x, dist):
        if not dist.sp:
            return x
        from repro.models.dist import gather_replicated
        return gather_replicated(x, dist.tp, 1)

    # ---- plumbing ------------------------------------------------------
    def init(self, key):
        return self.pb.init(key)

    def param_shapes(self):
        return self.pb.shapes()

    def param_pspecs(self):
        return self.pb.pspecs(self.dist)

    def stacked(self):
        return self.pb.stacked_mask()

    def fsdp_mask(self):
        """True for leaves whose grads are aggregated inside backward
        (fsdp hook); False for leaves needing post-grad compressed_allreduce."""
        return jax.tree_util.tree_map(
            lambda m: m.fsdp_dim() is not None and self.dist.fsdp is not None,
            self.meta, is_leaf=lambda x: isinstance(x, LeafMeta))

    def _gather_leaf(self, w, meta: LeafMeta, kb, comp, consumed_lead=1):
        fd = meta.fsdp_dim()
        if fd is not None and self.dist.fsdp is not None:
            return fsdp_param(w, kb, fd - consumed_lead, self.dist, comp)
        return w

    def _gather_layer(self, p_layer: Dict, meta_layer: Dict, kb, comp,
                      consumed_lead=1):
        return {k: self._gather_leaf(w, meta_layer[k], kb, comp,
                                     consumed_lead)
                for k, w in p_layer.items()}

    def _decode_fd(self, meta_layer: Dict, consumed_lead=1):
        """fsdp-dim map for 2D-TP decode (weights stay sharded)."""
        if self.dist.fsdp is None:
            return {}
        out = {}
        for k, m in meta_layer.items():
            f = m.fsdp_dim()
            out[k] = None if f is None else f - consumed_lead
        return out

    def _layer_window(self, idx):
        cfg = self.cfg
        if cfg.swa_pattern > 0:
            return jnp.where((idx + 1) % cfg.swa_pattern == 0,
                             0, cfg.sliding_window)
        return cfg.sliding_window

    def _layer_keys(self, key, L):
        ks = jax.vmap(lambda i: jax.random.fold_in(key, i))(jnp.arange(L))
        return key_to_bits(ks)

    # ---- embedding / head ----------------------------------------------
    def _embed(self, params, tokens, kb, comp, dist=None):
        w = self._gather_leaf(params["embed"], self.meta["embed"], kb, comp,
                              consumed_lead=0)
        # NB: under SP the seq slice after the embedding uses an
        # all-gather adjoint (make_slice_replicated), so the vocab-sharded
        # table receives full-sequence cotangents — no extra sync needed.
        return vp_embed(w, tokens, self.dist.tp, self.vocab_padded)

    def _head_weight(self, params, kb, comp):
        """(d, V_local) head matrix, FSDP-gathered / tied-transposed."""
        if self.cfg.tie_embeddings:
            w = self._gather_leaf(params["embed"], self.meta["embed"], kb,
                                  comp, consumed_lead=0)
            return jnp.swapaxes(w, 0, 1)
        return self._gather_leaf(params["head"], self.meta["head"], kb,
                                 comp, consumed_lead=0)

    def _lm_loss(self, params, x, targets, kb, comp, eff):
        """Chunked fused head+xent (full logits never materialized).

        Cross-entropy needs every vocab shard per token, so SP exits first:
        x arrives GATHERED (replicated over tp) — the Megatron layout."""
        from repro.models.dist import vp_xent_chunked
        cfg = self.cfg
        Bt, S_tot = targets.shape
        x = apply_norm(params, "final_norm", x, cfg)
        w = self._head_weight(params, kb, comp)
        xi = tp_region_in(x, eff.tp)
        s = vp_xent_chunked(xi.reshape(-1, cfg.d_model), w,
                            targets.reshape(-1), eff.tp, cfg.vocab)
        return s / (Bt * S_tot)

    def _logits(self, params, x, kb, comp):
        if self.cfg.tie_embeddings:
            w = self._gather_leaf(params["embed"], self.meta["embed"], kb,
                                  comp, consumed_lead=0)
            return tp_region_in(x, self.dist.tp) @ w.T
        w = self._gather_leaf(params["head"], self.meta["head"], kb, comp,
                              consumed_lead=0)
        return tp_region_in(x, self.dist.tp) @ w

    # ---- decoder stacks (train / prefill) -------------------------------
    def _run_stack(self, p_blocks, meta_blocks, x, comp, key, *, block_kind,
                   pos_offset=0, causal=True, memory=None, collect_cache=0,
                   remat=True, dist=None):
        cfg = self.cfg
        dist = dist if dist is not None else self.dist_nosp
        leaves = jax.tree_util.tree_leaves(p_blocks)
        L = leaves[0].shape[0]
        kbs = self._layer_keys(key, L)

        interleaved = (block_kind == "decoder" and cfg.n_experts
                       and cfg.moe_every > 1)

        def apply(p_layer, x, kb, idx):
            g = self._gather_layer(p_layer, meta_blocks, kb, comp)
            if interleaved:
                ga = {k[2:]: v for k, v in g.items() if k.startswith("a_")}
                gb = {k[2:]: v for k, v in g.items() if k.startswith("b_")}
                cfg_a = dataclasses.replace(cfg, n_experts=0)
                x, aux_a, ca = B.decoder_block(
                    ga, x, cfg_a, dist, window=self._layer_window(2 * idx),
                    pos_offset=pos_offset, causal=causal,
                    use_rope=cfg.use_rope,
                    collect_cache=collect_cache, tp_size=self.tp_size)
                x, aux_b, cb = B.decoder_block(
                    gb, x, cfg, dist, window=self._layer_window(2 * idx + 1),
                    pos_offset=pos_offset, causal=causal,
                    use_rope=cfg.use_rope,
                    collect_cache=collect_cache, tp_size=self.tp_size)
                cache = (ca, cb) if collect_cache else None
                return x, aux_a + aux_b, cache
            if block_kind == "decoder":
                return B.decoder_block(
                    g, x, cfg, dist, window=self._layer_window(idx),
                    pos_offset=pos_offset, causal=causal,
                    use_rope=cfg.use_rope, memory=memory,
                    collect_cache=collect_cache, tp_size=self.tp_size)
            elif block_kind == "ssm":
                h = apply_norm(g, "norm_in", x, cfg, dist)
                if collect_cache:
                    out, (cstate, sstate) = mamba2_block(
                        g, h, cfg, dist, return_state=True)
                    cache = {"conv_x": cstate[0], "conv_bc": cstate[1],
                             "ssm": sstate}
                    return x + out, jnp.zeros((), jnp.float32), cache
                return (x + mamba2_block(g, h, cfg, dist),
                        jnp.zeros((), jnp.float32), None)
            raise ValueError(block_kind)

        if remat:
            apply = jax.checkpoint(
                apply, policy=jax.checkpoint_policies.nothing_saveable,
                static_argnums=())

        def body(carry, xs):
            x, aux = carry
            p_layer, kb, idx = xs
            # barrier: stops XLA from hoisting a convert of the whole saved
            # residual stack to f32 outside the backward loop (0.5 GB/layer)
            x = _grad_barrier(x)
            x, aux_l, cache = apply(p_layer, x, kb, idx)
            return (x, aux + aux_l), cache

        (x, aux), caches = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)),
            (p_blocks, kbs, jnp.arange(L)))
        return x, aux, caches

    # ---- hybrid (zamba2) stack ------------------------------------------
    def _run_hybrid(self, params, x, comp, key, *, collect_cache=0,
                    remat=True, dist=None):
        cfg = self.cfg
        dist = dist if dist is not None else self.dist_nosp
        k_per = cfg.attn_every
        Gn = cfg.n_layers // k_per
        meta_b = self.meta["blocks"]
        # reshape (G*k, ...) -> (G, k, ...)
        pg = jax.tree_util.tree_map(
            lambda w: w.reshape((Gn, k_per) + w.shape[1:]), params["blocks"])
        kbs = self._layer_keys(key, Gn)
        shared_meta = self.meta["shared"]

        def group(carry, xs):
            x = carry
            p_group, kb, gidx = xs

            def apply(p_group, x):
                def inner(carry2, xs2):
                    x2 = _grad_barrier(carry2)
                    p_layer, j = xs2
                    g = self._gather_layer(p_layer, meta_b, kb, comp)
                    h = apply_norm(g, "norm_in", x2, cfg, dist)
                    if collect_cache:
                        out, (cs, ss) = mamba2_block(g, h, cfg, dist,
                                                     return_state=True)
                        return x2 + out, {"conv_x": cs[0], "conv_bc": cs[1],
                                          "ssm": ss}
                    return x2 + mamba2_block(g, h, cfg, dist), None

                x, mcaches = jax.lax.scan(inner, x,
                                          (p_group, jnp.arange(k_per)))
                gs = self._gather_layer(params["shared"], shared_meta, kb,
                                        comp, consumed_lead=0)
                x, aux, acache = B.decoder_block(
                    gs, x, dataclasses.replace(cfg, n_experts=0), dist,
                    window=cfg.sliding_window, causal=True,
                    use_rope=cfg.use_rope, collect_cache=collect_cache,
                    tp_size=self.tp_size)
                return x, (mcaches, acache)

            if remat:
                apply = jax.checkpoint(
                    apply, policy=jax.checkpoint_policies.nothing_saveable)
            x, caches = apply(p_group, x)
            return x, caches

        x, (mcaches, acaches) = jax.lax.scan(group, x,
                                             (pg, kbs, jnp.arange(Gn)))
        tail_caches = None
        if "tail_blocks" in params:
            x, _, tail_caches = self._run_stack(
                params["tail_blocks"], self.meta["tail_blocks"], x, comp,
                jax.random.fold_in(key, 7777), block_kind="ssm",
                collect_cache=collect_cache, remat=remat, dist=dist)
        if collect_cache:
            return x, {"mamba": mcaches, "attn": acaches,
                       "tail": tail_caches}
        return x, None

    # ---- top-level forward: train loss ----------------------------------
    def loss(self, params, batch, key, comp: Optional[CompressionConfig] = None,
             remat: bool = True):
        cfg = self.cfg
        kb = key_to_bits(key)
        if cfg.arch_type == "audio":
            return self._loss_audio(params, batch, key, comp, remat)
        eff = self._eff(batch["tokens"].shape[1])
        x = self._embed(params, batch["tokens"], kb, comp, dist=eff)
        if cfg.arch_type == "vlm":
            patches = batch["patch_embeds"].astype(x.dtype)
            x = jnp.concatenate([patches, x[:, patches.shape[1]:]], axis=1)
        if not cfg.use_rope:
            x = x + sinusoid_positions(jnp.arange(x.shape[1]),
                                       cfg.d_model).astype(x.dtype)[None]
        x = self._sp_slice(x, eff)
        if cfg.arch_type in ("dense", "moe", "vlm"):
            x, aux, _ = self._run_stack(params["blocks"], self.meta["blocks"],
                                        x, comp, key, block_kind="decoder",
                                        remat=remat, dist=eff)
        elif cfg.arch_type == "ssm":
            x, aux, _ = self._run_stack(params["blocks"], self.meta["blocks"],
                                        x, comp, key, block_kind="ssm",
                                        remat=remat, dist=eff)
        elif cfg.arch_type == "hybrid":
            x, _ = self._run_hybrid(params, x, comp, key, remat=remat,
                                    dist=eff)
            aux = jnp.zeros((), jnp.float32)
        else:
            raise ValueError(cfg.arch_type)
        x = self._sp_gather(x, eff)
        l = self._lm_loss(params, x, batch["targets"], kb, comp, eff)
        return l + 0.01 * aux

    def _loss_audio(self, params, batch, key, comp, remat):
        cfg = self.cfg
        kb = key_to_bits(key)
        mem = self._encode_audio(params, batch["frames"], comp, key, remat)
        x = self._embed(params, batch["tokens"], kb, comp)
        x = x + sinusoid_positions(jnp.arange(x.shape[1]),
                                   cfg.d_model).astype(x.dtype)[None]
        x, aux, _ = self._run_stack(params["decoder_blocks"],
                                    self.meta["decoder_blocks"], x, comp,
                                    key, block_kind="decoder", memory=mem,
                                    remat=remat)
        return self._lm_loss(params, x, batch["targets"], kb, comp,
                             self.dist_nosp)

    def _encode_audio(self, params, frames, comp, key, remat):
        cfg = self.cfg
        x = frames.astype(jnp.dtype(cfg.dtype)) + params["enc_pos"][None]
        x, _, _ = self._run_stack(params["encoder_blocks"],
                                  self.meta["encoder_blocks"], x, comp,
                                  jax.random.fold_in(key, 99),
                                  block_kind="decoder", causal=False,
                                  remat=remat)
        return apply_norm(params, "enc_final_norm", x, cfg)

    # ---- prefill ---------------------------------------------------------
    def prefill(self, params, batch, key, remat: bool = True,
                cache_len: int = None):
        """Forward over the prompt; returns (last_logits, cache).

        cache_len: total cache capacity (>= prompt length) so generated
        tokens have slots; defaults to the prompt length (the dry-run's
        decode shapes supply a full-size cache as input instead)."""
        cfg = self.cfg
        kb = key_to_bits(key)
        comp = None
        S = batch["tokens"].shape[1]
        clen = self.cache_len(cache_len or S)
        if cfg.arch_type == "audio":
            mem = self._encode_audio(params, batch["frames"], comp, key,
                                     remat)
            x = self._embed(params, batch["tokens"], kb, comp)
            x = x + sinusoid_positions(jnp.arange(S),
                                       cfg.d_model).astype(x.dtype)[None]
            x, _, caches = self._run_stack(
                params["decoder_blocks"], self.meta["decoder_blocks"], x,
                comp, key, block_kind="decoder", memory=mem,
                collect_cache=clen, remat=remat)
            caches = {"self": caches, "memory": mem}
        else:
            eff = self._eff(S)
            x = self._embed(params, batch["tokens"], kb, comp, dist=eff)
            if cfg.arch_type == "vlm":
                patches = batch["patch_embeds"].astype(x.dtype)
                x = jnp.concatenate([patches, x[:, patches.shape[1]:]],
                                    axis=1)
            if not cfg.use_rope:
                x = x + sinusoid_positions(jnp.arange(S),
                                           cfg.d_model).astype(x.dtype)[None]
            x = self._sp_slice(x, eff)
            if cfg.arch_type in ("dense", "moe", "vlm"):
                x, _, caches = self._run_stack(
                    params["blocks"], self.meta["blocks"], x, comp, key,
                    block_kind="decoder", collect_cache=clen, remat=remat,
                    dist=eff)
            elif cfg.arch_type == "ssm":
                x, _, caches = self._run_stack(
                    params["blocks"], self.meta["blocks"], x, comp, key,
                    block_kind="ssm", collect_cache=clen, remat=remat,
                    dist=eff)
            elif cfg.arch_type == "hybrid":
                x, caches = self._run_hybrid(params, x, comp, key,
                                             collect_cache=clen, remat=remat,
                                             dist=eff)
            x = self._sp_gather(x, eff)
        x = apply_norm(params, "final_norm", x, cfg)
        logits = self._logits(params, x[:, -1:], kb, comp)[:, 0]
        return logits, caches

    # ---- decode ----------------------------------------------------------
    def decode_step(self, params, token: Array, pos: Array, cache,
                    memory: Optional[Array] = None):
        """token (B,) int32, pos () int32. Returns (logits (B,Vl), cache)."""
        cfg, dist = self.cfg, self.dist_nosp
        zkb = jnp.zeros((2,), jnp.float32)
        key = jax.random.key(0)
        comp = None
        x = self._embed_decode(params, token[:, None])
        if not cfg.use_rope:
            x = x + sinusoid_positions(pos[None], cfg.d_model
                                       ).astype(x.dtype)[None]

        if cfg.arch_type in ("dense", "moe", "vlm", "audio"):
            bname = "decoder_blocks" if cfg.arch_type == "audio" else "blocks"
            p_blocks = params[bname]
            meta_b = self.meta[bname]
            L = jax.tree_util.tree_leaves(p_blocks)[0].shape[0]
            kbs = self._layer_keys(key, L)
            mem = cache.get("memory") if isinstance(cache, dict) and \
                "memory" in cache else memory
            layer_caches = cache["self"] if cfg.arch_type == "audio" else cache
            fd = self._decode_fd(meta_b)
            interleaved = cfg.n_experts and cfg.moe_every > 1

            def body(x, xs):
                p_layer, c_layer, kb, idx = xs
                if interleaved:
                    ga = {k[2:]: v for k, v in p_layer.items()
                          if k.startswith("a_")}
                    gb = {k[2:]: v for k, v in p_layer.items()
                          if k.startswith("b_")}
                    fda = {k[2:]: v for k, v in fd.items()
                           if k.startswith("a_")}
                    fdb = {k[2:]: v for k, v in fd.items()
                           if k.startswith("b_")}
                    cfg_a = dataclasses.replace(cfg, n_experts=0)
                    ca, cb = c_layer
                    x, nca = B.decoder_block_decode(
                        ga, x, ca, pos, cfg_a, dist,
                        window=self._layer_window(2 * idx), fd=fda)
                    x, ncb = B.decoder_block_decode(
                        gb, x, cb, pos, cfg, dist,
                        window=self._layer_window(2 * idx + 1), fd=fdb)
                    return x, (nca, ncb)
                x, new_c = B.decoder_block_decode(
                    p_layer, x, c_layer, pos, cfg, dist,
                    window=self._layer_window(idx), memory=mem, fd=fd)
                return x, new_c

            x, new_caches = jax.lax.scan(body, x,
                                         (p_blocks, layer_caches, kbs,
                                          jnp.arange(L)))
            new_cache = ({"self": new_caches, "memory": mem}
                         if cfg.arch_type == "audio" else new_caches)
        elif cfg.arch_type == "ssm":
            p_blocks = params["blocks"]
            meta_b = self.meta["blocks"]
            L = jax.tree_util.tree_leaves(p_blocks)[0].shape[0]
            kbs = self._layer_keys(key, L)

            def body(x, xs):
                p_layer, c_layer, kb = xs
                g = self._gather_layer(p_layer, meta_b, kb, comp)
                h = apply_norm(g, "norm_in", x, cfg)
                out, ((cx, cbc), ss) = mamba2_decode(
                    g, h, (c_layer["conv_x"], c_layer["conv_bc"]),
                    c_layer["ssm"], cfg, dist)
                return x + out, {"conv_x": cx, "conv_bc": cbc, "ssm": ss}

            x, new_cache = jax.lax.scan(body, x, (p_blocks, cache, kbs))
        elif cfg.arch_type == "hybrid":
            x, new_cache = self._decode_hybrid(params, x, pos, cache, key)
        else:
            raise ValueError(cfg.arch_type)

        x = apply_norm(params, "final_norm", x, cfg)
        logits = self._logits_decode(params, x)[:, 0]
        return logits, new_cache

    def _embed_decode(self, params, tokens):
        """Vocab-parallel lookup with the d dim left fsdp-sharded, then a
        tiny all_gather of the embedding features (2D-TP decode)."""
        from repro.models.dist import all_gather
        w = params["embed"]
        x = vp_embed(w, tokens, self.dist.tp, self.vocab_padded)
        if self.dist.fsdp is not None and \
                self.meta["embed"].fsdp_dim() is not None:
            x = all_gather(x, self.dist.fsdp, gather_axis=x.ndim - 1,
                           tiled=True)
        return x

    def _logits_decode(self, params, x):
        from repro.models.dist import fdot
        xi = tp_region_in(x, self.dist.tp)
        if self.cfg.tie_embeddings:
            w = params["embed"]  # (V_tp, d[/fsdp])
            fdim = self.meta["embed"].fsdp_dim()
            return fdot(xi, jnp.swapaxes(w, 0, 1),
                        0 if (fdim is not None and self.dist.fsdp) else None,
                        self.dist)
        w = params["head"]       # (d[/fsdp], V_tp)
        fdim = self.meta["head"].fsdp_dim()
        return fdot(xi, w,
                    0 if (fdim is not None and self.dist.fsdp) else None,
                    self.dist)

    def _decode_hybrid(self, params, x, pos, cache, key):
        cfg, dist = self.cfg, self.dist_nosp
        k_per = cfg.attn_every
        Gn = cfg.n_layers // k_per
        meta_b = self.meta["blocks"]
        pg = jax.tree_util.tree_map(
            lambda w: w.reshape((Gn, k_per) + w.shape[1:]), params["blocks"])
        kbs = self._layer_keys(key, Gn)
        mcache, acache, tail_cache = cache["mamba"], cache["attn"], \
            cache.get("tail")

        def group(x, xs):
            p_group, mc_group, ac, kb = xs

            def inner(x2, xs2):
                p_layer, c_layer = xs2
                g = self._gather_layer(p_layer, meta_b, kb, None)
                h = apply_norm(g, "norm_in", x2, cfg)
                out, ((cx, cbc), ss) = mamba2_decode(
                    g, h, (c_layer["conv_x"], c_layer["conv_bc"]),
                    c_layer["ssm"], cfg, dist)
                return x2 + out, {"conv_x": cx, "conv_bc": cbc, "ssm": ss}

            x, new_mc = jax.lax.scan(inner, x, (p_group, mc_group))
            gs = self._gather_layer(params["shared"], self.meta["shared"],
                                    kb, None, consumed_lead=0)
            x, new_ac = B.decoder_block_decode(
                gs, x, ac, pos, dataclasses.replace(cfg, n_experts=0), dist,
                window=cfg.sliding_window)
            return x, (new_mc, new_ac)

        x, (new_mc, new_ac) = jax.lax.scan(group, x, (pg, mcache, acache, kbs))
        new_tail = None
        if tail_cache is not None:
            p_tail = params["tail_blocks"]
            meta_t = self.meta["tail_blocks"]
            Lt = jax.tree_util.tree_leaves(p_tail)[0].shape[0]
            kbt = self._layer_keys(jax.random.fold_in(key, 7777), Lt)

            def tbody(x2, xs2):
                p_layer, c_layer, kb = xs2
                g = self._gather_layer(p_layer, meta_t, kb, None)
                h = apply_norm(g, "norm_in", x2, cfg)
                out, ((cx, cbc), ss) = mamba2_decode(
                    g, h, (c_layer["conv_x"], c_layer["conv_bc"]),
                    c_layer["ssm"], cfg, dist)
                return x2 + out, {"conv_x": cx, "conv_bc": cbc, "ssm": ss}

            x, new_tail = jax.lax.scan(tbody, x, (p_tail, tail_cache, kbt))
        return x, {"mamba": new_mc, "attn": new_ac, "tail": new_tail}

    # ---- cache layouts ----------------------------------------------------
    def cache_len(self, seq_len: int) -> int:
        cfg = self.cfg
        if cfg.sliding_window > 0 and cfg.swa_pattern == 0:
            return min(seq_len, cfg.sliding_window)
        return seq_len

    def _attn_cache_sds(self, L, batch, clen, dtype):
        cfg = self.cfg
        if cfg.attention == "mla":
            return {
                "ckv": jax.ShapeDtypeStruct(
                    (L, batch, 1, clen, cfg.kv_lora_rank), dtype),
                "krope": jax.ShapeDtypeStruct(
                    (L, batch, 1, clen, cfg.qk_rope_dim), dtype),
                "slot_pos": jax.ShapeDtypeStruct((L, clen), jnp.int32),
            }
        kdt = jnp.int8 if cfg.kv_cache_dtype == "int8" else dtype
        out = {
            "k": jax.ShapeDtypeStruct(
                (L, batch, cfg.n_kv_heads, clen, cfg.d_head), kdt),
            "v": jax.ShapeDtypeStruct(
                (L, batch, cfg.n_kv_heads, clen, cfg.d_head), kdt),
            "slot_pos": jax.ShapeDtypeStruct((L, clen), jnp.int32),
        }
        if cfg.kv_cache_dtype == "int8":
            out["k_scale"] = jax.ShapeDtypeStruct(
                (L, batch, cfg.n_kv_heads, clen), jnp.float32)
            out["v_scale"] = jax.ShapeDtypeStruct(
                (L, batch, cfg.n_kv_heads, clen), jnp.float32)
        return out

    def _attn_cache_pspec(self, shard_batch: bool = True):
        dp = (tuple(self.dist.dp) or None) if shard_batch else None
        tp = self.dist.tp
        base = {"slot_pos": P(None, tp)}
        if self.cfg.attention == "mla":
            base.update(ckv=P(None, dp, None, tp, None),
                        krope=P(None, dp, None, tp, None))
        else:
            base.update(k=P(None, dp, None, tp, None),
                        v=P(None, dp, None, tp, None))
            if self.cfg.kv_cache_dtype == "int8":
                base.update(k_scale=P(None, dp, None, tp),
                            v_scale=P(None, dp, None, tp))
        return base

    def _ssm_cache_sds(self, L, batch, dtype):
        cfg = self.cfg
        d_in = cfg.ssm_expand * cfg.d_model
        nh = d_in // cfg.ssm_head_dim
        N, K, G = cfg.ssm_state, cfg.ssm_conv, cfg.ssm_groups
        return {
            "conv_x": jax.ShapeDtypeStruct((L, batch, K - 1, d_in), dtype),
            "conv_bc": jax.ShapeDtypeStruct((L, batch, K - 1, 2 * G * N),
                                            dtype),
            "ssm": jax.ShapeDtypeStruct(
                (L, batch, nh, cfg.ssm_head_dim, N), jnp.float32),
        }

    def _ssm_cache_pspec(self, shard_batch: bool = True):
        dp = (tuple(self.dist.dp) or None) if shard_batch else None
        tp = self.dist.tp
        return {"conv_x": P(None, dp, None, tp),
                "conv_bc": P(None, dp, None, None),
                "ssm": P(None, dp, tp, None, None)}

    def cache_shapes(self, seq_len: int, batch: int):
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        clen = self.cache_len(seq_len)
        L = cfg.n_layers
        if cfg.arch_type in ("dense", "moe", "vlm"):
            if cfg.n_experts and cfg.moe_every > 1:
                half = self._attn_cache_sds(L // 2, batch, clen, dtype)
                return (half, half)
            return self._attn_cache_sds(L, batch, clen, dtype)
        if cfg.arch_type == "ssm":
            return self._ssm_cache_sds(L, batch, dtype)
        if cfg.arch_type == "hybrid":
            Gn = L // cfg.attn_every
            out = {"mamba": jax.tree_util.tree_map(
                lambda s: jax.ShapeDtypeStruct(
                    (Gn, cfg.attn_every) + s.shape[1:], s.dtype),
                self._ssm_cache_sds(1, batch, dtype)),
                "attn": self._attn_cache_sds(Gn, batch, clen, dtype)}
            tail = L - Gn * cfg.attn_every
            out["tail"] = (self._ssm_cache_sds(tail, batch, dtype)
                           if tail else None)
            return out
        if cfg.arch_type == "audio":
            out = {"self": self._attn_cache_sds(L, batch, clen, dtype),
                   "memory": jax.ShapeDtypeStruct(
                       (batch, cfg.frontend_seq, cfg.d_model), dtype)}
            return out
        raise ValueError(cfg.arch_type)

    def cache_pspecs(self, shard_batch: bool = True):
        """shard_batch=False: global batch < dp size (long_500k) — the
        cache replicates over the dp axes instead."""
        cfg = self.cfg
        dp = (tuple(self.dist.dp) or None) if shard_batch else None
        sb = shard_batch
        if cfg.arch_type in ("dense", "moe", "vlm"):
            if cfg.n_experts and cfg.moe_every > 1:
                return (self._attn_cache_pspec(sb), self._attn_cache_pspec(sb))
            return self._attn_cache_pspec(sb)
        if cfg.arch_type == "ssm":
            return self._ssm_cache_pspec(sb)
        if cfg.arch_type == "hybrid":
            m = {k: P(*((None,) + tuple(v)))
                 for k, v in self._ssm_cache_pspec(sb).items()}
            tail = (self._ssm_cache_pspec(sb)
                    if cfg.n_layers % cfg.attn_every else None)
            return {"mamba": m, "attn": self._attn_cache_pspec(sb),
                    "tail": tail}
        if cfg.arch_type == "audio":
            return {"self": self._attn_cache_pspec(sb),
                    "memory": P(dp, None, None)}
        raise ValueError(cfg.arch_type)

    def init_cache(self, seq_len: int, batch: int):
        """Materialize an empty cache (slot_pos = -1). Single-host sizes."""
        def mk(s):
            if s is None:
                return None
            arr = jnp.zeros(s.shape, s.dtype)
            return arr
        shapes = self.cache_shapes(seq_len, batch)
        cache = jax.tree_util.tree_map(mk, shapes)

        def fix_slots(path, x):
            if x is not None and path and getattr(path[-1], "key", "") == \
                    "slot_pos":
                return jnp.full(x.shape, -1, jnp.int32)
            return x
        return jax.tree_util.tree_map_with_path(fix_slots, cache)
