"""Quickstart: the paper in ~60 lines.

Trains a small causal LM with bidirectional compressed gradient
aggregation (Algorithm 1) over 4 simulated workers, comparing LAYER-WISE
vs ENTIRE-MODEL Top-k compression — the paper's central experiment.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import (CompressionConfig, Granularity,
                        aggregate_simulated_workers, make_compressor)
from repro.data import lm_batches
from repro.models import DistConfig, Model, ModelConfig

CFG = ModelConfig(name="quickstart-lm", arch_type="dense", n_layers=2,
                  d_model=64, vocab=128, n_heads=4, n_kv_heads=2,
                  d_head=16, d_ff=128, dtype="float32")
WORKERS, STEPS, LR = 4, 40, 0.3


def train(granularity: str):
    model = Model(CFG, DistConfig())
    params = model.init(jax.random.key(0))
    comp = CompressionConfig(
        qw=make_compressor("topk", ratio=0.1),       # worker-side Q_W
        qm=make_compressor("identity"),              # master-side Q_M
        granularity=Granularity(granularity))
    stacked = model.stacked()

    @jax.jit
    def step(params, batch, key):
        # each worker computes grads on its batch shard ...
        wb = jax.tree_util.tree_map(
            lambda x: x.reshape((WORKERS, -1) + x.shape[1:]), batch)
        wgrads = jax.vmap(lambda b: jax.grad(
            lambda p: model.loss(p, b, key))(params))(wb)
        # ... compresses them per Algorithm 1, the master aggregates ...
        g, _ = aggregate_simulated_workers(wgrads, stacked, comp, key)
        # ... and everyone applies the same update.
        return jax.tree_util.tree_map(lambda p, gg: p - LR * gg, params, g)

    data = lm_batches(CFG.vocab, 8, 32, seed=1)
    first = last = None
    for i in range(STEPS):
        batch = next(data)
        loss = float(model.loss(params, batch, jax.random.key(9)))
        first = loss if first is None else first
        last = loss
        params = step(params, batch, jax.random.fold_in(jax.random.key(2), i))
    return first, last


def show_schedule():
    """The practical-timing side of the paper's gap: what the wire sees.
    Layer-wise compression without scheduling pays per-unit message
    latency; a CommSchedule streams backward-ordered fused messages —
    same numerics (bit-identical, tests/test_schedule.py), different
    latency picture (modeled; trust the counts, not microseconds)."""
    from repro.core import build_plan, build_schedule, simulate_schedule
    model = Model(CFG, DistConfig())
    shapes = jax.eval_shape(lambda k: model.init(k), jax.random.key(0))
    plan = build_plan(shapes, model.stacked(), Granularity("layerwise"))
    qw = make_compressor("topk", ratio=0.1)
    for label, fb in (("per-bucket", 0.0), ("fused 64KiB", 65536.0)):
        sched = build_schedule(plan, fb)
        sim = simulate_schedule(sched, qw=qw)
        print(f"  {label:12s}: {sched.num_messages:2d} messages, modeled "
              f"exposed comm {sim['exposed_comm_us']:7.1f}us "
              f"(overlap {sim['overlap_frac']:.0%})")


def show_wire():
    """The other half of the gap: are the accounted bits ACHIEVABLE?
    Every compressor has a WireCodec whose bit-packed payload round-trips
    bit-exactly to the simulated operator, so the measured number below
    is real bytes, not an estimate (`launch/train.py --wire` runs whole
    training steps on these buffers; tests/test_wire.py is the
    differential suite holding accounted == measured)."""
    from repro.core import build_plan, wire_codec
    model = Model(CFG, DistConfig())
    shapes = jax.eval_shape(lambda k: model.init(k), jax.random.key(0))
    plan = build_plan(shapes, model.stacked(), Granularity("layerwise"))
    qw = make_compressor("topk", ratio=0.1)
    codec = wire_codec(qw)
    acct = sum(qw.payload_bits(d) for d in plan.unit_dims)
    meas = sum(codec.wire_bits(d) for d in plan.unit_dims)
    print(f"  topk 10% layer-wise: accounted {acct} bits/step, measured "
          f"{meas} bits of packed payload (word padding {meas - acct})")


def show_trace():
    """One traced step of the real wire pipeline: the schedule above is
    a MODEL; the TraceRecorder stamps what execution actually did — one
    span per wire message plus compress/pack/decode stage spans, Chrome
    trace-event exportable (obs.TraceRecorder.export -> Perfetto).
    Counts are the trustworthy part; microseconds are host noise."""
    from repro.core import build_plan, build_schedule, wire_codec
    from repro.obs import TraceRecorder, format_step_summary
    model = Model(CFG, DistConfig())
    params = model.init(jax.random.key(0))
    plan = build_plan(params, model.stacked(), Granularity("layerwise"))
    sched = build_schedule(plan, 65536.0)
    codec = wire_codec(make_compressor("qsgd", levels=16))
    rec = TraceRecorder()
    out, bufs = jax.jit(lambda t, k: sched.execute(
        None, t, k, wire=codec, recorder=rec))(params, jax.random.key(3))
    jax.block_until_ready((out, bufs))
    print("  " + format_step_summary(rec.finalize_step(0)))
    print(f"  ({sched.num_messages} wire messages -> "
          f"{len(rec.message_spans(0))} message spans; "
          f"rec.export('trace.json') opens in Perfetto)")


if __name__ == "__main__":
    for gran in ("layerwise", "entire_model"):
        first, last = train(gran)
        print(f"{gran:13s}: loss {first:.3f} -> {last:.3f}")
    print("Both converge; see benchmarks/figures.py for the full paper-style "
          "accuracy comparison across six compressors.")
    print("Comm schedule (what the wire sees for the layer-wise run):")
    show_schedule()
    print("Wire formats (what the wire actually carries):")
    show_wire()
    print("Trace (what one executed wire step actually did):")
    show_trace()
