"""Batched serving example: prefill a batch of prompts, then decode new
tokens with the sequence-sharded KV cache on a device mesh (the same
serve_step the decode_32k / long_500k dry-run shapes lower).

Run:
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
  PYTHONPATH=src python examples/serve_batched.py
"""
import os

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax                  # noqa: E402
import jax.numpy as jnp     # noqa: E402

from repro.launch.engine import Engine       # noqa: E402
from repro.launch.mesh import make_host_mesh  # noqa: E402
from repro.models import ModelConfig          # noqa: E402
from repro.models.config import InputShape    # noqa: E402

CFG = ModelConfig(name="serve-lm", arch_type="dense", n_layers=4,
                  d_model=128, vocab=512, n_heads=8, n_kv_heads=2,
                  d_head=16, d_ff=256, dtype="float32")
BATCH, PROMPT, GEN, CACHE = 8, 24, 12, 64


def main():
    mesh = make_host_mesh(data=4, model=2)
    eng = Engine(CFG, mesh)
    params, _ = eng.init_state(seed=1)
    serve = eng.build_serve_step(InputShape("d", CACHE, BATCH, "decode"))
    # the engine's shard_map'd prefill — a bare jit(model.prefill) has no
    # bound TP axes — with the cache sized for the generation budget
    prefill = eng.build_prefill(InputShape("p", PROMPT, BATCH, "prefill"),
                                cache_len=CACHE)

    prompts = jax.random.randint(jax.random.key(0), (BATCH, PROMPT), 0,
                                 CFG.vocab)
    with mesh:
        logits, cache = prefill(params, {"tokens": prompts})
        # now decode greedily with the seq-sharded cache
        toks = jnp.argmax(logits, -1).astype(jnp.int32)
        out = [toks]
        for t in range(GEN):
            logits, cache = serve(params, {"token": toks,
                                           "pos": jnp.int32(PROMPT + t)},
                                  cache)
            toks = jnp.argmax(logits, -1).astype(jnp.int32)
            out.append(toks)
    gen = jnp.stack(out, axis=1)
    print("prompts:", prompts[:2])
    print("generated continuations:", gen[:2])
    print(f"served {BATCH} sequences x {GEN} tokens on "
          f"{mesh.devices.size} devices (seq-sharded KV cache)")


if __name__ == "__main__":
    main()
