"""Mini reproduction of the paper's empirical study (Table view):
layer-wise vs entire-model accuracy for several compressors on the
CPU-scale DAWNBench stand-ins — driven through the adaptive-control
subsystem: ONE Controller per model sweeps every (compressor,
granularity) as a CompressionDecision, reusing cached UnitPlans and
compiled steps across the whole sweep (the baseline step compiles once,
not once per row). `--adaptive` appends rows where the framework itself
picks the configuration (the paper's closing recommendation).

Run:  PYTHONPATH=src python examples/granularity_study.py [--steps 60]
"""
import argparse
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _ROOT)
sys.path.insert(0, os.path.join(_ROOT, "src"))

from benchmarks.common import (cnn_controller, dense_decision,  # noqa: E402
                               train_cnn_with_controller)
from repro.control import (CompressionDecision, StaticPolicy,  # noqa: E402
                           make_policy)
from repro.core import Granularity, make_compressor  # noqa: E402

RUNS = [
    ("topk", {"ratio": 0.01}),
    ("randomk", {"ratio": 0.01}),
    ("terngrad", {}),
    ("qsgd", {"levels": 4}),
    ("adaptive_threshold", {"alpha": 0.05}),
    ("threshold_v", {"v": 1e-3}),
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--model", default="resnet9",
                    choices=["resnet9", "alexnet", "mlp"])
    ap.add_argument("--adaptive", action="store_true",
                    help="also run the adaptive policies (the framework "
                         "picks granularity/ratio from telemetry)")
    ap.add_argument("--replan-every", type=int, default=15)
    args = ap.parse_args()

    cache: dict = {}  # shared decision -> compiled-step cache for the sweep
    ctrl = cnn_controller(args.model, StaticPolicy(), cache=cache)

    def run(decision):
        ctrl.set_decision(decision)
        acc, _ = train_cnn_with_controller(args.model, ctrl,
                                           steps=args.steps)
        return acc

    print(f"model={args.model} steps={args.steps}")
    print(f"{'compressor':22s} {'layer-wise':>10s} {'entire':>10s} "
          f"{'baseline':>10s}  verdict")
    baseline = run(dense_decision())
    for name, kw in RUNS:
        acc = {}
        for gran in ("layerwise", "entire_model"):
            acc[gran] = run(CompressionDecision(
                qw=make_compressor(name, **kw),
                granularity=Granularity(gran)))
        verdict = ("layer-wise better"
                   if acc["layerwise"] > acc["entire_model"] + 0.02 else
                   "entire-model better"
                   if acc["entire_model"] > acc["layerwise"] + 0.02
                   else "comparable")
        print(f"{name:22s} {acc['layerwise']:10.3f} "
              f"{acc['entire_model']:10.3f} {baseline:10.3f}  {verdict}")
    print(f"[cache] {len(cache)} compiled steps for "
          f"{1 + 2 * len(RUNS)} sweep rows ({ctrl.builds} builds)")

    if not args.adaptive:
        return
    print("\nadaptive policies (framework picks the configuration):")
    base = CompressionDecision(qw=make_compressor("topk", ratio=0.01),
                               granularity=Granularity("layerwise"))
    for pname, kw in [("granularity_switch", {}),
                      ("variance_budget", {"budget": 0.3})]:
        actrl = cnn_controller(args.model, make_policy(pname, **kw),
                               base=base, replan_every=args.replan_every,
                               cache=cache)
        acc, _ = train_cnn_with_controller(args.model, actrl,
                                           steps=args.steps)
        print(f"{pname:22s} {acc:10.3f}  final={actrl.decision.describe()} "
              f"switches={len(actrl.switches)} builds={actrl.builds}")


if __name__ == "__main__":
    main()
