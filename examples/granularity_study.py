"""Mini reproduction of the paper's empirical study (Table view):
layer-wise vs entire-model accuracy for several compressors on the
CPU-scale DAWNBench stand-ins. ~10 minutes on one CPU core.

Run:  PYTHONPATH=src python examples/granularity_study.py [--steps 60]
"""
import argparse
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _ROOT)
sys.path.insert(0, os.path.join(_ROOT, "src"))

from benchmarks.common import compare_granularities  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--model", default="resnet9",
                    choices=["resnet9", "alexnet", "mlp"])
    args = ap.parse_args()

    runs = [
        ("topk", {"ratio": 0.01}),
        ("randomk", {"ratio": 0.01}),
        ("terngrad", {}),
        ("qsgd", {"levels": 4}),
        ("adaptive_threshold", {"alpha": 0.05}),
        ("threshold_v", {"v": 1e-3}),
    ]
    print(f"model={args.model} steps={args.steps}")
    print(f"{'compressor':22s} {'layer-wise':>10s} {'entire':>10s} "
          f"{'baseline':>10s}  verdict")
    for name, kw in runs:
        r = compare_granularities(args.model, name, steps=args.steps, **kw)
        verdict = ("layer-wise better" if r["layerwise"] > r["entire_model"]
                   + 0.02 else
                   "entire-model better" if r["entire_model"] >
                   r["layerwise"] + 0.02 else "comparable")
        print(f"{name:22s} {r['layerwise']:10.3f} {r['entire_model']:10.3f} "
              f"{r['baseline']:10.3f}  {verdict}")


if __name__ == "__main__":
    main()
