"""End-to-end driver (deliverable b): train a ~small LM for a few hundred
steps on a real device mesh with compressed communication — the same
Engine/shard_map path the production dry-run lowers.

Run (8 virtual CPU devices, ~100M-param model would need --smoke off and
patience; the smoke variant finishes in minutes):

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
  PYTHONPATH=src python examples/train_lm_distributed.py
"""
import os

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax                      # noqa: E402
import jax.numpy as jnp         # noqa: E402

from repro.core import CompressionConfig, Granularity, make_compressor  # noqa
from repro.data import lm_batches                   # noqa: E402
from repro.launch.engine import Engine              # noqa: E402
from repro.launch.mesh import make_host_mesh        # noqa: E402
from repro.models import ModelConfig                # noqa: E402
from repro.optim import OptConfig, piecewise_linear  # noqa: E402

STEPS = 300

CFG = ModelConfig(name="lm-8m", arch_type="dense", n_layers=4, d_model=256,
                  vocab=2048, n_heads=8, n_kv_heads=4, d_head=32, d_ff=512,
                  dtype="float32")


def main():
    mesh = make_host_mesh(data=4, model=2)
    comp = CompressionConfig(qw=make_compressor("topk", ratio=0.05),
                             granularity=Granularity("layerwise"),
                             strategy="allgather")
    eng = Engine(CFG, mesh, comp=comp,
                 opt=OptConfig(name="momentum", lr=0.3, nesterov=True))
    step = eng.build_train_step(piecewise_linear(0.3, STEPS, STEPS // 10))
    params, opt_state = eng.init_state()
    n = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"{CFG.name}: {n/1e6:.1f}M params on mesh {dict(eng.sizes)}; "
          f"wire strategy={comp.strategy} (payload actually shrinks)")
    data = lm_batches(CFG.vocab, 32, 128, seed=0)
    with mesh:
        for i in range(STEPS):
            params, opt_state, m = step(params, opt_state, next(data),
                                        jnp.int32(i))
            if i % 25 == 0 or i == STEPS - 1:
                print(f"step {i:4d}  loss {float(m['loss']):.4f}  "
                      f"lr {float(m['lr']):.3f}")


if __name__ == "__main__":
    main()
