# Repro of "On the Discrepancy between the Theoretical Analysis and
# Practical Implementations of Compressed Communication for Distributed
# Deep Learning" (AAAI'20). See README.md / ROADMAP.md.

# Tier-1 verification — the exact command from ROADMAP.md.
verify:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python -m pytest -x -q

# Tier-1 minus the long-running suites (distributed subprocess, system
# end-to-end, per-arch smoke) and the full equivalence sweeps (`sched` /
# `wire` markers — tests/test_schedule.py and tests/test_wire.py keep
# unmarked smoke subsets in the inner loop) — the inner-loop command.
# Full `make verify` before shipping.
verify-fast:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python -m pytest -x -q -m "not slow and not sched and not wire and not obs and not stream and not scenario and not fault"

# Full microbenchmarks (operators x granularity, Pallas kernels, UnitPlan
# dispatches, adaptive controller). Writes BENCH_unitplan.json and
# BENCH_controller.json, so it refuses to run on a dirty tree: committed
# BENCH files must be attributable to a commit (BENCH_FORCE=1 overrides).
bench: bench-guard
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH}:. python -m benchmarks.run --only micro

bench-guard:
	@if [ -z "$$BENCH_FORCE" ] && [ -n "$$(git status --porcelain 2>/dev/null)" ]; then \
	  echo "refusing to overwrite BENCH_*.json on a dirty tree (untracked files count);"; \
	  echo "commit first, or override with BENCH_FORCE=1 make bench"; \
	  exit 1; fi

# Just the per-leaf-vs-planned dispatch benchmark -> BENCH_unitplan.json.
bench-unitplan:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH}:. python -c \
	  "from benchmarks.microbench import unitplan; unitplan()"

# Just the controller benchmark -> BENCH_controller.json.
bench-controller:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH}:. python -c \
	  "from benchmarks.microbench import controller; controller()"

# Just the comm-schedule benchmark (message fusion counts + modeled
# exposed comm) -> BENCH_schedule.json. Same clean-tree guard as `bench`:
# committed BENCH files must be attributable to a commit.
bench-schedule: bench-guard
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH}:. python -c \
	  "from benchmarks.microbench import schedule; schedule()"

# Just the wire benchmark (accounted vs measured packed bits per model
# config x codec x fusion threshold) -> BENCH_wire.json. Clean-tree
# guarded like every BENCH artifact.
bench-wire: bench-guard
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH}:. python -c \
	  "from benchmarks.microbench import wire; wire()"

# Just the fused-kernel traffic benchmark (per-codec encode/decode bytes
# moved + measured pallas dispatch counts, jnp vs fused single-launch)
# -> BENCH_kernels.json. Deterministic counts, no wall clocks; clean-tree
# guarded like every BENCH artifact.
bench-kernels: bench-guard
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH}:. python -c \
	  "from benchmarks.microbench import kernels_bench; kernels_bench()"

# Just the observability calibration benchmark (TraceRecorder-measured
# exposed comm vs the alpha-beta model, default + per-host fitted
# parameters) -> BENCH_obs.json. Wall-clock based by nature — trust the
# counts/bytes and the RELATIVE ratio shape, not absolute us (the report
# embeds the caveat). Clean-tree guarded like every BENCH artifact.
bench-obs: bench-guard
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH}:. python -c \
	  "from benchmarks.microbench import obs_bench; obs_bench()"

# Just the streaming-collective benchmark (ring vs serialized allgather
# stream on an 8-virtual-device host ring: hop counts, bytes per hop,
# measured exposed comm) -> BENCH_stream.json. The hop/byte COUNTS are
# the gate — deterministic; the ring-vs-serialized wall clocks carry the
# container-noise caveat the report embeds. XLA_FLAGS must be set before
# jax initializes, hence on the recipe line. Clean-tree guarded like
# every BENCH artifact.
bench-stream: bench-guard
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH}:. \
	  XLA_FLAGS=--xla_force_host_platform_device_count=8 python -c \
	  "from benchmarks.microbench import stream; stream()"

# The fault-injected scenario campaign (SimCluster): registry configs x
# scenarios x top-k ratios x granularities -> convergence + exposed-comm
# telemetry + the per-cell layerwise-vs-entire-model verdict ->
# BENCH_scenarios.json. Deterministic model numbers (no wall clocks).
# SCENARIO_STEPS=n shrinks the per-cell step count for quick looks.
# Clean-tree guarded like every BENCH artifact.
bench-scenarios: bench-guard
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH}:. python -c \
	  "from benchmarks.scenarios import scenarios; scenarios()"

# The resilience-plane benchmark: the Fletcher-32 detection matrix (six
# codecs x granularities x {serialized, ring} collectives: detection and
# false-positive rates under single-bit flips), per-message integrity
# overhead in bytes, the faulted-campaign recovery verdict (corrupted
# cell + resend recovers the clean cell's layerwise-vs-entire_model
# verdict), and the kill-and-resume bitwise gate -> BENCH_faults.json.
# Deterministic (seeded corruption, no wall clocks); the gates are
# ASSERTED, not just recorded. The ring leg needs virtual devices, so
# XLA_FLAGS rides the recipe line. Clean-tree guarded like every BENCH
# artifact. FAULT_STEPS=n shrinks the training legs for quick looks.
bench-faults: bench-guard
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH}:. \
	  XLA_FLAGS=--xla_force_host_platform_device_count=8 python -c \
	  "from benchmarks.faults import faults; faults()"

.PHONY: verify verify-fast bench bench-guard bench-unitplan \
	bench-controller bench-schedule bench-wire bench-kernels bench-obs \
	bench-stream bench-scenarios bench-faults
