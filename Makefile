# Repro of "On the Discrepancy between the Theoretical Analysis and
# Practical Implementations of Compressed Communication for Distributed
# Deep Learning" (AAAI'20). See README.md / ROADMAP.md.

# Tier-1 verification — the exact command from ROADMAP.md.
verify:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python -m pytest -x -q

# Full microbenchmarks (operators x granularity, Pallas kernels, UnitPlan).
bench:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH}:. python -m benchmarks.run --only micro

# Just the per-leaf-vs-planned dispatch benchmark -> BENCH_unitplan.json.
bench-unitplan:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH}:. python -c \
	  "from benchmarks.microbench import unitplan; unitplan()"

.PHONY: verify bench bench-unitplan
