"""Per-architecture smoke tests (deliverable f): reduced variant of each
assigned arch family — one forward/train step + one decode step on CPU,
asserting output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import pytest

pytestmark = pytest.mark.slow

from repro.configs.registry import ARCH_NAMES, get_smoke
from repro.data import frames_stub, patches_stub
from repro.models import DistConfig, Model

KEY = jax.random.key(0)
B, S = 2, 16


def _batch(cfg):
    b = {"tokens": jnp.ones((B, S), jnp.int32) * 3,
         "targets": jnp.ones((B, S), jnp.int32) * 5}
    if cfg.arch_type == "vlm":
        b["patch_embeds"] = patches_stub(KEY, B, cfg.frontend_seq,
                                         cfg.d_model)
    if cfg.arch_type == "audio":
        b["frames"] = frames_stub(KEY, B, cfg.frontend_seq, cfg.d_model)
    return b


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_train_step(arch):
    cfg = get_smoke(arch)
    assert cfg.n_layers <= 5 and cfg.d_model <= 512 and cfg.n_experts <= 4
    m = Model(cfg, DistConfig())
    params = m.init(KEY)
    batch = _batch(cfg)
    loss, grads = jax.value_and_grad(
        lambda p: m.loss(p, batch, jax.random.key(1)))(params)
    assert loss.shape == () and jnp.isfinite(loss)
    for leaf in jax.tree_util.tree_leaves(grads):
        assert not bool(jnp.isnan(leaf).any())
    # one SGD step decreases nothing catastrophically
    p2 = jax.tree_util.tree_map(lambda p, g: p - 0.01 * g, params, grads)
    loss2 = m.loss(p2, batch, jax.random.key(1))
    assert jnp.isfinite(loss2)


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_prefill_then_decode(arch):
    cfg = get_smoke(arch)
    m = Model(cfg, DistConfig())
    params = m.init(KEY)
    batch = _batch(cfg)
    logits, cache = m.prefill(params, batch, jax.random.key(2),
                              cache_len=S + 4)
    vocab_padded = ((cfg.vocab + 127) // 128) * 128
    assert logits.shape == (B, vocab_padded)
    assert not bool(jnp.isnan(logits).any())
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    lg, cache = m.decode_step(params, tok, jnp.int32(S), cache)
    assert lg.shape == (B, vocab_padded)
    assert not bool(jnp.isnan(lg).any())
    # a second decode step continues from the updated cache
    lg2, _ = m.decode_step(params, jnp.argmax(lg, -1).astype(jnp.int32),
                           jnp.int32(S + 1), cache)
    assert not bool(jnp.isnan(lg2).any())


@pytest.mark.parametrize("arch", ["mamba2-1.3b", "qwen3-moe-235b-a22b",
                                  "whisper-base"])
def test_smoke_wire_train_step(arch):
    """The non-transformer-dense registry families (SSM, MoE, audio
    encoder-decoder) each run one ENGINE train step over the wire path —
    compressed gradients materialized as packed payloads, not just
    sim()'d — so the scenario campaign's config zoo is exercised
    end-to-end before the campaign prices it."""
    from repro.core import CompressionConfig, Granularity, make_compressor
    from repro.launch.engine import Engine
    from repro.launch.mesh import make_host_mesh

    cfg = get_smoke(arch)
    comp = CompressionConfig(qw=make_compressor("qsgd", levels=16),
                             granularity=Granularity("layerwise"))
    eng = Engine(cfg, make_host_mesh(1, 1), comp=comp)
    batch = {"tokens": jnp.ones((4, S), jnp.int32) * 3,
             "targets": jnp.ones((4, S), jnp.int32) * 5}
    if cfg.arch_type == "audio":
        batch["frames"] = frames_stub(KEY, 4, cfg.frontend_seq,
                                      cfg.d_model).astype(
                                          jnp.dtype(cfg.dtype))
    step = eng.build_train_step(wire=True, collective="allgather")
    params, opt_state = eng.init_state(0)
    params, opt_state, m = step(params, opt_state, batch, jnp.int32(0))
    assert jnp.isfinite(m["loss"])
    for leaf in jax.tree_util.tree_leaves(params):
        assert not bool(jnp.isnan(leaf).any())


def test_decode_matches_prefill_continuation():
    """Teacher-forced decode after prefill reproduces the prefill logits
    of the next position (cache consistency, dense arch)."""
    cfg = get_smoke("llama3-405b")
    m = Model(cfg, DistConfig())
    params = m.init(KEY)
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    full = {"tokens": toks, "targets": toks}
    # prefill on the first S-1 tokens, then decode token S-1
    short = {"tokens": toks[:, :S - 1]}
    _, cache = m.prefill(params, short, jax.random.key(2), cache_len=S + 1)
    lg_dec, _ = m.decode_step(params, toks[:, S - 1], jnp.int32(S - 1), cache)
    # reference: last-position logits of the full prefill
    lg_full, _ = m.prefill(params, {"tokens": toks}, jax.random.key(2))
    assert jnp.allclose(lg_dec, lg_full, atol=2e-2), \
        float(jnp.max(jnp.abs(lg_dec - lg_full)))
