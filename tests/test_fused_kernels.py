"""Fused single-launch compress+pack kernels: the bit-exactness contract.

Three load-bearing properties:

  1. BYTE IDENTITY: the fused batch paths (kernels/ops.py *_pack_units /
     *_unpack_units, and the WireCodec fused=True batch entry points)
     produce payload bytes and decoded gradients BIT-identical to the
     legacy three-pass per-unit pipeline — on both the pallas and the
     pure-jnp fallback paths, at word-aligned and word-straddling sizes.
  2. SINGLE LAUNCH: a whole bucket's encode (or decode) is ONE
     pallas_call in the jaxpr — asserted structurally via
     ops.count_pallas_calls, not inferred from timings.
  3. TRAFFIC GATE: the kernel-spec bytes-moved accounting says the fused
     encode moves <= 1 f32 read + 1 packed-word write per element with
     ZERO intermediate bytes (the {0,1} bit tensor of the legacy path
     never exists), and the majority vote never unpacks.

Smoke subsets run unmarked; the full sweeps carry the `wire` marker
(tier-1 only, excluded by `make verify-fast`).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import make_compressor, wire_codec
from repro.kernels import ops, prng, ref

KEY = jax.random.key(3)

# word-straddling and word-aligned unit dims, odd bucket sizes
SMOKE_SHAPES = [(64, 4), (513, 2), (700, 3)]
FULL_SHAPES = SMOKE_SHAPES + [(1, 1), (31, 7), (512, 1), (1300, 5),
                              (4096, 2)]

SIX = [
    ("topk", {"ratio": 0.25}),
    ("randomk", {"ratio": 0.3, "scale": True}),
    ("qsgd", {"levels": 16}),
    ("terngrad", {}),
    ("signsgd", {}),
    ("natural", {}),
]


def _bucket(d, n, seed=7):
    x = jax.random.normal(jax.random.fold_in(KEY, seed), (n, d))
    keys = jax.vmap(lambda i: jax.random.fold_in(KEY, i))(jnp.arange(n))
    return x, keys


def _legacy(codec):
    import dataclasses
    return dataclasses.replace(codec, fused=False)


def _assert_bitwise(a, b, ctx):
    a, b = np.asarray(a), np.asarray(b)
    assert a.shape == b.shape and a.dtype == b.dtype, (ctx, a.shape, b.shape)
    assert np.array_equal(a, b), ctx


# ---------------------------------------------------------------------------
# in-kernel PRNG == jax.random (the uniforms the pack kernels draw)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("d", [1, 31, 512, 513, 1025])
def test_uniform_at_matches_jax_random(d):
    key = jax.random.fold_in(KEY, d)
    kd = jax.random.key_data(key).astype(jnp.uint32)
    pos = jnp.arange(d, dtype=jnp.int32)[None, :]
    u = prng.uniform_at(kd[0][None, None], kd[1][None, None], pos, d)
    _assert_bitwise(u[0], jax.random.uniform(key, (d,)), d)


# ---------------------------------------------------------------------------
# word-wise field packing == the legacy bit-expansion oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("width", [1, 2, 4, 9, 17])
@pytest.mark.parametrize("k", [5, 31, 32, 33, 700])
def test_pack_fields_matches_bitexpand(width, k):
    vals = jax.random.randint(jax.random.fold_in(KEY, k), (k,), 0,
                              1 << min(width, 30), dtype=jnp.int32)
    oracle = ref.pack_fields_bitexpand_ref(vals, width)
    for up in (False, True):
        words = ops.pack_fields(vals, width, use_pallas=up)
        _assert_bitwise(words, oracle, (width, k, up))
        _assert_bitwise(ops.unpack_fields(words, k, width, use_pallas=up),
                        vals, (width, k, up))


@settings(max_examples=30, deadline=None)
@given(st.integers(min_value=1, max_value=900),
       st.sampled_from([1, 2, 3, 4, 5, 9, 13, 17]),
       st.integers(min_value=0, max_value=10_000))
def test_property_pack_fields_roundtrip(k, width, seed):
    vals = jax.random.randint(jax.random.fold_in(KEY, seed), (k,), 0,
                              1 << min(width, 30), dtype=jnp.int32)
    words = ops.pack_fields(vals, width)
    _assert_bitwise(words, ref.pack_fields_bitexpand_ref(vals, width),
                    (k, width, seed))
    _assert_bitwise(ops.unpack_fields(words, k, width), vals,
                    (k, width, seed))


# ---------------------------------------------------------------------------
# fused ops == legacy per-unit wire pipeline, byte for byte
# ---------------------------------------------------------------------------

def _codec_roundtrip_identity(name, kw, d, n, use_pallas):
    comp = make_compressor(name, **kw)
    fused = wire_codec(comp, use_pallas=use_pallas, fused=True)
    legacy = wire_codec(comp, use_pallas=False, fused=False)
    x, keys = _bucket(d, n)
    pay_l = legacy.encode_batch(x, keys)
    pay_f = fused.encode_batch(x, keys)
    _assert_bitwise(pay_f, pay_l, (name, d, n, use_pallas, "payload"))
    xhat_l = legacy.decode_batch(pay_l, d)
    xhat_f = fused.decode_batch(pay_f, d)
    _assert_bitwise(xhat_f, xhat_l, (name, d, n, use_pallas, "decode"))
    e = x * 1.5
    eh_l, m_l = legacy.decode_ef_batch(pay_l, e, d)
    eh_f, m_f = fused.decode_ef_batch(pay_f, e, d)
    _assert_bitwise(eh_f, eh_l, (name, d, n, use_pallas, "ef xhat"))
    _assert_bitwise(m_f, m_l, (name, d, n, use_pallas, "ef residual"))


@pytest.mark.parametrize("name,kw", SIX + [("identity", {})])
def test_fused_codec_byte_identity_smoke(name, kw):
    for d, n in SMOKE_SHAPES:
        _codec_roundtrip_identity(name, kw, d, n, use_pallas=False)
    _codec_roundtrip_identity(name, kw, 700, 3, use_pallas=True)


@pytest.mark.wire
@pytest.mark.parametrize("use_pallas", [False, True])
@pytest.mark.parametrize("name,kw", SIX + [("identity", {})])
def test_fused_codec_byte_identity_full(name, kw, use_pallas):
    for d, n in FULL_SHAPES:
        _codec_roundtrip_identity(name, kw, d, n, use_pallas)


@pytest.mark.parametrize("d,n", SMOKE_SHAPES)
def test_fused_ops_byte_identity(d, n):
    """ops-layer identity at odd sizes: pallas path == jnp fallback for
    payload words, statistics, decode, and EF residual."""
    x, keys = _bucket(d, n)
    e = x * 1.5
    wq_p, nr_p = ops.qsgd_pack_units(x, keys, 16, 6, use_pallas=True)
    wq_j, nr_j = ops.qsgd_pack_units(x, keys, 16, 6, use_pallas=False)
    _assert_bitwise(wq_p, wq_j, (d, n, "qsgd words"))
    _assert_bitwise(nr_p, nr_j, (d, n, "qsgd norms"))
    for up in (False, True):
        xh = ops.qsgd_unpack_units(wq_p, nr_p, d, 16, 6, use_pallas=up)
        xh2, m = ops.qsgd_unpack_ef_units(wq_p, nr_p, e, d, 16, 6,
                                          use_pallas=up)
        _assert_bitwise(xh2, xh, (d, n, up, "qsgd ef xhat"))
        _assert_bitwise(m, np.asarray(e) - np.asarray(xh),
                        (d, n, up, "qsgd residual"))
    wt_p, sc_p = ops.terngrad_pack_units(x, keys, use_pallas=True)
    wt_j, sc_j = ops.terngrad_pack_units(x, keys, use_pallas=False)
    _assert_bitwise(wt_p, wt_j, (d, n, "tern words"))
    _assert_bitwise(sc_p, sc_j, (d, n, "tern scales"))
    ws_p = ops.sign_pack_units(x, use_pallas=True)
    ws_j = ops.sign_pack_units(x, use_pallas=False)
    _assert_bitwise(ws_p, ws_j, (d, n, "sign words"))


# ---------------------------------------------------------------------------
# single launch: one pallas_call per bucket encode/decode, structurally
# ---------------------------------------------------------------------------

def test_fused_encode_is_single_launch():
    d, n = 700, 3
    x, keys = _bucket(d, n)
    kd = jax.random.key_data(keys).astype(jnp.uint32)
    assert ops.count_pallas_calls(
        lambda a, k: ops.qsgd_pack_units(a, k, 16, 6, use_pallas=True),
        x, kd) == 1
    assert ops.count_pallas_calls(
        lambda a, k: ops.terngrad_pack_units(a, k, use_pallas=True),
        x, kd) == 1
    assert ops.count_pallas_calls(
        lambda a: ops.sign_pack_units(a, use_pallas=True), x) == 1


def test_fused_decode_is_single_launch():
    d, n = 700, 3
    x, keys = _bucket(d, n)
    w, nr = ops.qsgd_pack_units(x, keys, 16, 6, use_pallas=False)
    assert ops.count_pallas_calls(
        lambda a, s: ops.qsgd_unpack_units(a, s, d, 16, 6,
                                           use_pallas=True), w, nr) == 1
    # decode+EF: one unpack launch, the residual subtract is an
    # elementwise caller-regime op, NOT a second kernel
    e = x * 1.5
    assert ops.count_pallas_calls(
        lambda a, s, ee: ops.qsgd_unpack_ef_units(a, s, ee, d, 16, 6,
                                                  use_pallas=True),
        w, nr, e) == 1
    ws = ops.sign_pack_units(x, use_pallas=False)
    assert ops.count_pallas_calls(
        lambda a: ops.majority_words(a, use_pallas=True),
        jnp.tile(ws[:1], (5, 1))) == 1


# ---------------------------------------------------------------------------
# majority vote on packed words == pack(majority(unpack))
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_workers", [1, 2, 3, 5, 8])
def test_majority_on_packed_words(n_workers):
    d = 700
    xs = jax.random.normal(jax.random.fold_in(KEY, n_workers),
                           (n_workers, d))
    words = ops.sign_pack_units(xs, use_pallas=False)
    bits = np.stack([np.asarray(ref.unpack_bits_ref(w[None]))[0, :d]
                     for w in words])
    maj_dense = (2 * bits.sum(axis=0) >= n_workers).astype(np.int32)
    pad = (-d) % 32
    oracle = ref.pack_bits_ref(jnp.asarray(
        np.pad(maj_dense, (0, pad))).reshape(-1, 32)).reshape(-1)
    for up in (False, True):
        _assert_bitwise(ops.majority_words(words, use_pallas=up), oracle,
                        (n_workers, up))


def test_signsgd_codec_majority_fused_matches_legacy():
    d, n = 777, 6
    comp = make_compressor("signsgd")
    x, keys = _bucket(d, n)
    fused = wire_codec(comp, fused=True)
    legacy = _legacy(fused)
    pays = legacy.encode_batch(x, keys)
    _assert_bitwise(fused.majority_vote(pays, d),
                    legacy.majority_vote(pays, d), d)


# ---------------------------------------------------------------------------
# traffic gate: the acceptance numbers, from the kernel specs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("width,stochastic", [(6, True), (2, True),
                                              (1, False)])
def test_fused_encode_traffic_gate(width, stochastic):
    spec = ops.pack_bytes_moved(width, fused=True, stochastic=stochastic)
    # <= 1 f32 read (+ the per-512-lane-row key/stat columns) and exactly
    # 1 packed-word write per element, nothing intermediate, one launch
    assert spec["read_bytes_per_elt"] <= 4.0 + 12 / 512
    assert spec["write_bytes_per_elt"] == width / 8.0
    assert spec["intermediate_bytes_per_elt"] == 0.0
    assert spec["launches_per_bucket"] == 1
    legacy = ops.pack_bytes_moved(width, fused=False, stochastic=stochastic)
    assert legacy["intermediate_bytes_per_elt"] >= 4.0 + 4.0 * width
    assert legacy["launches_per_bucket"] == 3


def test_fused_decode_traffic_gate():
    for width in (1, 2, 6, 9):
        spec = ops.unpack_bytes_moved(width, fused=True)
        assert spec["read_bytes_per_elt"] == width / 8.0
        assert spec["write_bytes_per_elt"] == 4.0
        assert spec["launches_per_bucket"] == 1
        ef = ops.unpack_bytes_moved(width, fused=True, ef=True)
        assert ef["launches_per_bucket"] == 1
        assert ef["passes_over_data"] == 2
