"""Unit + property tests for the compression operators (Assumption 5,
unbiasedness, payload consistency, sparsity counts)."""
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import index_bits, make_compressor, available_compressors
from repro.core.theory import (check_unbiasedness, empirical_omega,
                               empirical_descent_alignment)

KEY = jax.random.key(0)

ALL_SPECS = [
    ("identity", {}),
    ("randomk", {"ratio": 0.1}),
    ("randomk", {"ratio": 0.1, "scale": True}),
    ("topk", {"ratio": 0.1}),
    ("threshold_v", {"v": 0.8}),
    ("adaptive_threshold", {"alpha": 0.3}),
    ("terngrad", {}),
    ("qsgd", {"levels": 16}),
    ("signsgd", {}),
    ("natural", {}),
]


@pytest.mark.parametrize("name,kw", ALL_SPECS)
def test_encode_decode_matches_sim(name, kw):
    """Wire format and mathematical operator agree (threshold ops: wire is
    capacity-bounded, so only where the count fits)."""
    c = make_compressor(name, **kw)
    x = jax.random.normal(KEY, (777,))
    y = c.sim(x, KEY)
    z = c.decode(c.encode(x, KEY), 777)
    if name in ("threshold_v", "adaptive_threshold"):
        # capacity cap may drop smallest-magnitude qualifying entries
        kept = jnp.sum(z != 0)
        assert kept <= jnp.sum(y != 0) + 1
        nz = z != 0
        assert jnp.allclose(z[nz], y[nz])
    else:
        assert jnp.allclose(y, z, atol=1e-6), name


@pytest.mark.parametrize("name,kw", ALL_SPECS)
def test_assumption5(name, kw):
    """E||Q(x)||^2 <= (1+Omega)||x||^2 for the analytic Omega (when known)."""
    c = make_compressor(name, **kw)
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (512,))
    om_emp = empirical_omega(c, x, KEY, trials=128)
    om = c.omega(512)
    if om is not None:
        assert om_emp <= om + 0.25 * (1 + abs(om)), (name, om_emp, om)
    if not c.unbiased and name != "signsgd":
        # biased sparsifiers never grow the norm
        assert om_emp <= 1e-3, (name, om_emp)


@pytest.mark.parametrize("name,kw", [
    ("randomk", {"ratio": 0.25, "scale": True}),
    ("terngrad", {}),
    ("qsgd", {"levels": 8}),
    ("natural", {}),
])
def test_unbiasedness(name, kw):
    c = make_compressor(name, **kw)
    x = jax.random.normal(jax.random.fold_in(KEY, 2), (256,))
    rel = check_unbiasedness(c, x, KEY, trials=3000)
    assert rel < 0.12, (name, rel)


def test_topk_randomk_keep_exact_k():
    x = jax.random.normal(KEY, (1000,))
    for name in ("topk", "randomk"):
        c = make_compressor(name, ratio=0.05)
        y = c.sim(x, KEY)
        assert int(jnp.sum(y != 0)) == 50, name


def test_topk_picks_largest():
    x = jnp.asarray([0.1, -5.0, 0.2, 3.0, -0.05, 0.0, 1.0, -1.5])
    c = make_compressor("topk", ratio=0.25)
    y = c.sim(x, KEY)
    assert set(jnp.nonzero(y)[0].tolist()) == {1, 3}


def test_signsgd_values():
    x = jnp.asarray([0.5, -2.0, 0.0, 3.0])
    y = make_compressor("signsgd").sim(x, KEY)
    assert jnp.array_equal(y, jnp.asarray([1.0, -1.0, 1.0, 1.0]))


def test_natural_powers_of_two():
    x = jax.random.normal(KEY, (256,)) * 10
    y = make_compressor("natural").sim(x, KEY)
    nz = y[y != 0]
    e = jnp.log2(jnp.abs(nz))
    assert jnp.allclose(e, jnp.round(e), atol=1e-5)


def test_payload_bits_sane():
    d = 10000
    assert make_compressor("signsgd").payload_bits(d) == d
    assert make_compressor("terngrad").payload_bits(d) == 2 * d + 32
    # sparse records: 32-bit value + dim-dependent index width
    # (ceil(log2(10000)) = 14 bits — what the packed wire format uses)
    assert index_bits(d) == 14
    assert make_compressor("topk", ratio=0.01).payload_bits(d) == \
        100 * (32 + 14)
    assert make_compressor("qsgd", levels=16).payload_bits(d) < 32 * d
    assert index_bits(1) == 1 and index_bits(2) == 1 and index_bits(8) == 3
    assert index_bits(9) == 4


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=8, max_value=2048),
       st.sampled_from(["topk", "randomk", "terngrad", "qsgd", "signsgd",
                        "natural"]),
       st.integers(min_value=0, max_value=10_000))
def test_property_assumption5_holds(d, name, seed):
    """Hypothesis: Assumption 5 with the operator's worst-case Omega holds
    on random inputs of random dimension (the paper's eq. (5))."""
    kw = {"ratio": 0.2} if name in ("topk", "randomk") else {}
    c = make_compressor(name, **kw)
    key = jax.random.key(seed)
    x = jax.random.normal(key, (d,)) * jax.random.uniform(key, (), minval=0.1,
                                                          maxval=10)
    norm2 = float(jnp.sum(x * x))
    keys = jax.random.split(key, 32)
    qn = float(jnp.mean(jax.vmap(
        lambda k: jnp.sum(jnp.square(c.sim(x, k))))(keys)))
    om = c.omega(d)
    if name == "signsgd":
        q1 = c.sim(x, key)
        assert float(jnp.sum(q1 * q1)) == pytest.approx(d, rel=1e-4)
    elif c.unbiased:
        if om is not None:
            # 32-draw mean of E||Q||^2 vs the Assumption-5 bound (+MC slack)
            assert qn <= (1 + om) * norm2 * 1.8 + 1e-6
        else:
            # TernGrad: E||Q||^2 = s*||x||_1 <= sqrt(d)*||x||^2/||x||*...
            # use the loose sqrt(d) worst case
            assert qn <= (1 + d ** 0.5) * norm2 * 1.8 + 1e-6
    else:
        assert qn <= norm2 * (1 + 1e-5)  # biased sparsifiers contract


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=16, max_value=512),
       st.integers(min_value=0, max_value=10_000))
def test_property_descent_alignment_unbiased(d, seed):
    """Assumption 6 / Lemma 2(i): unbiased ops align with the gradient:
    E[Q(g)^T g] == ||g||^2 (alpha=2)."""
    key = jax.random.key(seed)
    g = jax.random.normal(key, (d,))
    c = make_compressor("qsgd", levels=32)
    a = empirical_descent_alignment(c, g, key, trials=256)
    assert a == pytest.approx(float(jnp.sum(g * g)), rel=0.2)
