"""Observability: TraceRecorder span structure, the zero-overhead
contract, the metrics registry round-trip, the retrace watchdog, and the
measured-vs-modeled calibration machinery.

The load-bearing properties:

  * span STRUCTURE mirrors the executed schedule — per step, the
    per-message span count equals `schedule.num_messages` and the bucket
    attribution concatenates to `plan.readiness_order()` (per-bucket
    threshold), on the simulated path AND the wire path;
  * recording disabled is FREE — the traced graph is bit-identical to
    the uninstrumented one (jaxpr equality, zero debug_callback
    equations) and enabled recording never changes numerics;
  * exports validate: chrome-trace JSON against the schema subset,
    metrics JSON-lines round-trip equal to the in-memory snapshot;
  * the controller's retrace watchdog warns (and counts) exactly when a
    previously-built decision rebuilds, and stays silent on healthy
    cache revisits.

The full sweep (compressors x fusion thresholds x both execution paths)
and the engine-level trace carry the `obs` marker: tier-1 only, excluded
from `make verify-fast`.
"""
import json
import math
import warnings

import jax
import jax.numpy as jnp
import pytest

from repro.core import (CompressionConfig, Granularity, build_plan,
                        build_schedule, make_compressor, stacked_mask,
                        wire_codec)
from repro.obs import (METRICS_SCHEMA_VERSION, TRACE_SCHEMA_VERSION,
                       MetricsRegistry, TraceRecorder, calibrate,
                       count_debug_callbacks, fit_alpha_beta,
                       format_step_summary, measure_schedule, read_jsonl,
                       validate_chrome_trace)

KEY = jax.random.key(0)


def _tree(key=KEY):
    """Mixed pytree with several size classes so readiness order is
    nontrivial (same shape idiom as tests/test_schedule.py)."""
    ks = [jax.random.fold_in(key, i) for i in range(5)]
    return {"blocks": {"w": jax.random.normal(ks[0], (3, 16, 8)),
                       "b": jax.random.normal(ks[1], (3, 8))},
            "embed": jax.random.normal(ks[2], (20, 4)),
            "head": jax.random.normal(ks[3], (4, 2)),
            "scalar_gain": jax.random.normal(ks[4], ())}


def _assert_trees_bitwise(a, b, ctx):
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        assert la.shape == lb.shape and la.dtype == lb.dtype, ctx
        assert bool((la == lb).all()), ctx


def _run_recorded(sched, fn, tree, rec, *, wire=None):
    if wire is not None:
        jit = jax.jit(lambda t, k: sched.execute(None, t, k, wire=wire,
                                                 recorder=rec))
        out, bufs = jit(tree, KEY)
        jax.block_until_ready(bufs)
    else:
        jit = jax.jit(lambda t, k: sched.execute(fn, t, k, recorder=rec))
        out = jit(tree, KEY)
    jax.block_until_ready(out)
    return out


# ---------------------------------------------------------------------------
# span structure == schedule message layout
# ---------------------------------------------------------------------------

def test_message_spans_match_schedule():
    """Per-bucket threshold, simulated path: one message span per
    schedule message, bucket attribution == plan.readiness_order()."""
    tree, sm = _tree(), stacked_mask(_tree())
    comp = make_compressor("qsgd", levels=16)
    plan = build_plan(tree, sm, Granularity("layerwise"))
    sched = build_schedule(plan, 0.0)
    rec = TraceRecorder()
    _run_recorded(sched, lambda x, k: comp.sim(x, k), tree, rec)
    summary = rec.finalize_step(0)
    spans = rec.message_spans(step=0)
    assert len(spans) == sched.num_messages == summary["n_message_spans"]
    ordered = sorted(spans, key=lambda e: e["args"]["message"])
    concat = [b for e in ordered for b in e["args"]["bucket_ids"]]
    assert tuple(concat) == plan.readiness_order()
    for e, msg in zip(ordered, sched.messages):
        assert tuple(e["args"]["bucket_ids"]) == msg.bucket_ids
        assert e["args"]["n_units"] == sum(plan.buckets[bi].n
                                           for bi in msg.bucket_ids)
        assert e["args"]["step"] == 0
        assert e["args"]["schema_version"] == TRACE_SCHEMA_VERSION


def test_plan_dispatch_spans():
    """Bare UnitPlan execution records one dispatch span per bucket."""
    tree, sm = _tree(), stacked_mask(_tree())
    comp = make_compressor("signsgd")
    plan = build_plan(tree, sm, Granularity("layerwise"))
    rec = TraceRecorder()
    jit = jax.jit(lambda t, k: plan.execute(
        lambda x, kk: comp.sim(x, kk), t, k, recorder=rec))
    jax.block_until_ready(jit(tree, KEY))
    rec.finalize_step(0)
    spans = rec.span_events(cat="dispatch", step=0)
    assert len(spans) == plan.num_dispatches
    assert sorted(b for e in spans for b in e["args"]["bucket_ids"]) == \
        list(range(plan.num_dispatches))


def test_wire_stage_spans_and_synthesized_messages():
    """Wire path: per-stage spans carry codec attribution and finalize
    synthesizes exactly num_messages umbrella message spans."""
    tree, sm = _tree(), stacked_mask(_tree())
    comp = make_compressor("qsgd", levels=16)
    plan = build_plan(tree, sm, Granularity("layerwise"))
    sched = build_schedule(plan, float(1 << 10))
    codec = wire_codec(comp)
    rec = TraceRecorder()
    _run_recorded(sched, None, tree, rec, wire=codec)
    summary = rec.finalize_step(0)
    msgs = rec.message_spans(step=0)
    assert len(msgs) == sched.num_messages == summary["n_message_spans"]
    stages = rec.span_events(cat="stage", step=0)
    per_msg_stages = {}
    for e in stages:
        assert e["args"]["codec"] == codec.name
        per_msg_stages.setdefault(e["args"]["message"], set()).add(
            e["args"]["stage"])
    assert set(per_msg_stages) == set(range(sched.num_messages))
    for mi, st in per_msg_stages.items():
        assert {"compress", "pack", "decode"} <= st, (mi, st)
    # the synthesized umbrellas cover their stage spans
    for e in msgs:
        assert e["args"]["stages"] == sorted(
            per_msg_stages[e["args"]["message"]])


def test_multi_step_and_summary_format():
    tree, sm = _tree(), stacked_mask(_tree())
    comp = make_compressor("randomk", ratio=0.5)
    plan = build_plan(tree, sm, Granularity("layerwise"))
    sched = build_schedule(plan, math.inf)
    rec = TraceRecorder()
    fn = lambda x, k: comp.sim(x, k)  # noqa: E731
    for i in range(3):
        _run_recorded(sched, fn, tree, rec)
        s = rec.finalize_step(i)
        assert s["step"] == i and s["n_message_spans"] == 1
        assert "message spans" in format_step_summary(s)
    assert [s["step"] for s in rec.steps] == [0, 1, 2]
    assert len(rec.message_spans()) == 3
    assert len(rec.message_spans(step=1)) == 1


# ---------------------------------------------------------------------------
# exports
# ---------------------------------------------------------------------------

def test_chrome_trace_valid_and_exportable(tmp_path):
    tree, sm = _tree(), stacked_mask(_tree())
    comp = make_compressor("qsgd", levels=16)
    plan = build_plan(tree, sm, Granularity("layerwise"))
    sched = build_schedule(plan, 0.0)
    rec = TraceRecorder()
    _run_recorded(sched, lambda x, k: comp.sim(x, k), tree, rec)
    rec.finalize_step(0)
    with rec.host_span("compile", note="host side"):
        pass
    obj = rec.chrome_trace()
    assert validate_chrome_trace(obj)
    assert obj["metadata"]["schema_version"] == TRACE_SCHEMA_VERSION
    assert obj["metadata"]["steps"] == rec.steps
    path = tmp_path / "trace.json"
    rec.export(str(path))
    assert validate_chrome_trace(json.loads(path.read_text()))
    # the validator actually rejects malformed traces
    with pytest.raises(ValueError):
        validate_chrome_trace({"traceEvents": [{"ph": "Z", "name": "x",
                                                "pid": 0, "tid": 0}]})
    with pytest.raises(ValueError):
        validate_chrome_trace({"traceEvents": [{"ph": "X", "name": "x",
                                                "pid": 0, "tid": 0,
                                                "ts": -1.0, "dur": 0}]})
    with pytest.raises(ValueError):
        validate_chrome_trace([])


def test_metrics_roundtrip(tmp_path):
    reg = MetricsRegistry()
    reg.inc("train/steps")
    reg.inc("train/steps", 2)
    reg.gauge("engine/n_messages", 7)
    for v in (1.0, 5.0, 3.0, 9.0, 7.0):
        reg.observe("serve/decode_us", v)
    line = reg.record(step=0)
    assert line["schema_version"] == METRICS_SCHEMA_VERSION
    assert line["counters"]["train/steps"] == 3.0
    assert line["gauges"]["engine/n_messages"] == 7.0
    h = line["histograms"]["serve/decode_us"]
    assert h["count"] == 5 and h["min"] == 1.0 and h["max"] == 9.0
    assert h["p50"] == 5.0 and h["sum"] == 25.0
    path = tmp_path / "metrics.jsonl"
    assert reg.export_jsonl(str(path)) == 1
    parsed = read_jsonl(str(path))
    assert parsed == [line] == [reg.snapshot(step=0)]
    # a registry with no recorded lines exports one final snapshot
    reg2 = MetricsRegistry()
    reg2.inc("a")
    assert reg2.export_jsonl(str(path)) == 1
    assert read_jsonl(str(path))[0]["labels"] == {"final": True}


def test_disabled_metrics_noop(tmp_path):
    reg = MetricsRegistry(enabled=False)
    reg.inc("a")
    reg.gauge("b", 1.0)
    reg.observe("c", 2.0)
    reg.record(step=0)
    assert reg.counters == {} and reg.gauges == {} and reg.histograms == {}
    snap = reg.snapshot()
    assert snap["counters"] == {} and snap["kind"] == "snapshot"


# ---------------------------------------------------------------------------
# the zero-overhead contract
# ---------------------------------------------------------------------------

def test_zero_overhead_when_disabled():
    """recorder=None, recorder=disabled, and no recorder at all stage
    IDENTICAL jaxprs with zero debug_callback equations; enabling the
    recorder adds callbacks but never changes numerics."""
    tree, sm = _tree(), stacked_mask(_tree())
    comp = make_compressor("qsgd", levels=16)
    plan = build_plan(tree, sm, Granularity("layerwise"))
    sched = build_schedule(plan, float(1 << 10))
    fn = lambda x, k: comp.sim(x, k)  # noqa: E731
    off = TraceRecorder(enabled=False)

    bare = lambda t, k: sched.execute(fn, t, k)                 # noqa: E731
    none = lambda t, k: sched.execute(fn, t, k, recorder=None)  # noqa: E731
    dis = lambda t, k: sched.execute(fn, t, k, recorder=off)    # noqa: E731
    jx_bare = str(jax.make_jaxpr(bare)(tree, KEY))
    assert jx_bare == str(jax.make_jaxpr(none)(tree, KEY))
    assert jx_bare == str(jax.make_jaxpr(dis)(tree, KEY))
    assert count_debug_callbacks(bare, tree, KEY) == 0
    assert count_debug_callbacks(dis, tree, KEY) == 0

    rec = TraceRecorder()
    on = lambda t, k: sched.execute(fn, t, k, recorder=rec)  # noqa: E731
    # begin + one mark per message
    assert count_debug_callbacks(on, tree, KEY) == 1 + sched.num_messages
    ref = jax.jit(bare)(tree, KEY)
    got = jax.jit(on)(tree, KEY)
    jax.block_until_ready(got)
    rec.finalize_step(0)
    _assert_trees_bitwise(ref, got, "recorded-vs-bare")


def test_zero_overhead_wire_path():
    tree, sm = _tree(), stacked_mask(_tree())
    comp = make_compressor("signsgd")
    plan = build_plan(tree, sm, Granularity("layerwise"))
    sched = build_schedule(plan, 0.0)
    codec = wire_codec(comp)
    off = TraceRecorder(enabled=False)
    bare = lambda t, k: sched.execute(None, t, k, wire=codec)  # noqa: E731
    dis = lambda t, k: sched.execute(None, t, k, wire=codec,   # noqa: E731
                                     recorder=off)
    assert str(jax.make_jaxpr(bare)(tree, KEY)) == \
        str(jax.make_jaxpr(dis)(tree, KEY))
    assert count_debug_callbacks(dis, tree, KEY) == 0
    ref, refb = jax.jit(bare)(tree, KEY)
    rec = TraceRecorder()
    on = jax.jit(lambda t, k: sched.execute(None, t, k, wire=codec,
                                            recorder=rec))
    got, gotb = on(tree, KEY)
    jax.block_until_ready(got)
    rec.finalize_step(0)
    _assert_trees_bitwise(ref, got, "wire-recorded-vs-bare")
    _assert_trees_bitwise(refb, gotb, "wire-buffers")


# ---------------------------------------------------------------------------
# retrace watchdog
# ---------------------------------------------------------------------------

def _tiny_controller(metrics=None):
    from repro.control import CompressionDecision, Controller, StaticPolicy
    tree = _tree()
    sm = stacked_mask(tree)
    mplan = build_plan(tree, sm, Granularity("layerwise"))
    base = CompressionDecision(qw=make_compressor("randomk", ratio=0.5),
                               granularity=Granularity("layerwise"))
    build = lambda decision: jax.jit(lambda x: x + 1)  # noqa: E731
    return Controller(StaticPolicy(), build, base, mplan,
                      collect_telemetry=False, metrics=metrics)


def test_retrace_watchdog_silent_on_healthy_revisits():
    from repro.control import CompressionDecision
    reg = MetricsRegistry()
    ctrl = _tiny_controller(metrics=reg)
    base = ctrl.decision
    alt = CompressionDecision(qw=make_compressor("randomk", ratio=0.5),
                              granularity=Granularity("entire_model"))
    with warnings.catch_warnings():
        warnings.simplefilter("error", RuntimeWarning)
        f_base = ctrl.step_fn()
        ctrl.set_decision(alt)
        f_alt = ctrl.step_fn()
        # revisits of both decisions: cache hits, no warning, no build
        ctrl.set_decision(base)
        assert ctrl.step_fn() is f_base
        ctrl.set_decision(alt)
        assert ctrl.step_fn() is f_alt
    assert ctrl.builds == 2
    assert ctrl.retraces_unexpected == 0
    assert ctrl.check_retraces() == 0
    assert reg.counters["controller/builds"] == 2.0
    assert "controller/retraces_unexpected" not in reg.counters


def test_retrace_watchdog_fires_on_evicted_cache():
    reg = MetricsRegistry()
    ctrl = _tiny_controller(metrics=reg)
    ctrl.step_fn()
    assert ctrl.builds == 1
    ctrl._cache.clear()  # simulate eviction behind the controller's back
    with pytest.warns(RuntimeWarning, match="unexpected retrace"):
        ctrl.step_fn()
    assert ctrl.builds == 2
    assert ctrl.retraces_unexpected == 1
    assert ctrl.check_retraces() == 1
    assert reg.counters["controller/retraces_unexpected"] == 1.0
    assert reg.gauges["controller/retraces_unexpected_total"] == 1.0


def test_controller_report_self_describing():
    ctrl = _tiny_controller()
    rep = ctrl.report()
    from repro.control.telemetry import TELEMETRY_SCHEMA_VERSION
    assert rep["schema_version"] == TELEMETRY_SCHEMA_VERSION == 2
    act = rep["active"]
    assert act["policy"] == "static"
    assert act["compressor"] == "randomk"
    assert act["granularity"] == "layerwise"
    assert act["fusion_bytes"] is None
    assert act["ratio"] == 0.5
    assert isinstance(act["ratio_overrides"], dict)
    assert rep["retraces_unexpected"] == 0
    assert "jit_recompiles" in rep
    json.dumps(rep)


# ---------------------------------------------------------------------------
# calibration
# ---------------------------------------------------------------------------

def test_fit_alpha_beta():
    # exact synthetic line: t = 100 + b / (10 gbps * 1e3)
    beta = 1.0 / (10.0 * 1e3)
    samples = [(b, 100.0 + b * beta)
               for b in (1e3, 1e4, 1e5, 1e6)]
    fit = fit_alpha_beta(samples)
    assert fit["n_samples"] == 4
    assert abs(fit["alpha_us"] - 100.0) < 1.0
    assert abs(fit["gbps"] - 10.0) < 0.1
    assert fit["resid_rms_us"] < 1.0
    # degenerate inputs stay well-defined
    assert fit_alpha_beta([])["gbps"] is None
    flat = fit_alpha_beta([(1e3, 50.0), (1e6, 50.0)])
    assert flat["gbps"] is None and flat["alpha_us"] == 50.0


def test_calibration_smoke():
    tree, sm = _tree(), stacked_mask(_tree())
    comp = make_compressor("qsgd", levels=16)
    meas = measure_schedule(tree, sm, comp, 0.0, reps=1, warmup=1)
    plan = build_plan(tree, sm, Granularity("layerwise"))
    sched = build_schedule(plan, 0.0)
    assert meas["n_messages"] == sched.num_messages
    assert len(meas["per_message"]) == sched.num_messages
    assert meas["total_us"] > 0.0
    assert all(m["wire_bytes"] > 0 for m in meas["per_message"])

    cal = calibrate("tiny", tree, sm, comp, reps=1)
    assert cal["codec"] == "qsgd"
    assert set(cal["thresholds"]) == {"per_bucket", "fused_64kib",
                                      "one_shot"}
    for label, t in cal["thresholds"].items():
        for k in ("model_error_ratio_default", "model_error_ratio_fitted"):
            assert t[k] > 0.0 and math.isfinite(t[k]), (label, k, t[k])
        assert t["exposed_comm_us_measured"] > 0.0
    fit = next(iter(cal["fit_by_host"].values()))
    assert fit["n_samples"] == sum(
        t["n_messages"] for t in cal["thresholds"].values())
    json.dumps(cal)


# ---------------------------------------------------------------------------
# engine-level trace + the full sweep (`obs` marker: tier-1 only)
# ---------------------------------------------------------------------------

@pytest.mark.obs
def test_engine_train_step_trace_and_zero_overhead():
    """The sharded train step on a 1-device mesh: enabled tracing yields
    exactly schedule.num_messages message spans per step, static metrics
    gauges match the schedule, and a disabled tracer keeps the step
    bit-identical with zero staged callbacks."""
    from repro.configs.registry import get_smoke
    from repro.launch.comm_sched import engine_schedule
    from repro.launch.engine import Engine
    from repro.launch.mesh import make_host_mesh
    cfg = get_smoke("mamba2-1.3b")
    mesh = make_host_mesh(1, 1)
    comp = CompressionConfig(qw=make_compressor("qsgd", levels=16),
                             granularity=Granularity("layerwise"))
    eng = Engine(cfg, mesh, comp=comp)
    sched = engine_schedule(eng, 0.0)
    batch = {"tokens": jnp.ones((4, 16), jnp.int32) * 3,
             "targets": jnp.ones((4, 16), jnp.int32) * 5}
    rec, reg = TraceRecorder(), MetricsRegistry()
    fn = eng.build_train_step(schedule=sched, tracer=rec, metrics=reg)
    params, opt_state = eng.init_state(0)
    for i in range(2):
        params, opt_state, m = fn(params, opt_state, batch, jnp.int32(i))
        jax.block_until_ready(m["loss"])
        s = rec.finalize_step(i)
        assert s["n_message_spans"] == sched.num_messages
        assert len(rec.message_spans(step=i)) == sched.num_messages
    assert reg.gauges["engine/n_messages"] == sched.num_messages
    assert reg.gauges["engine/n_dispatches"] == \
        eng.comm_plans(comp)[0].num_dispatches
    assert validate_chrome_trace(rec.chrome_trace())

    # zero overhead: disabled tracer == no tracer, bit for bit
    fn_bare = eng.build_train_step(schedule=sched)
    fn_off = eng.build_train_step(
        schedule=sched, tracer=TraceRecorder(enabled=False))
    p0, o0 = eng.init_state(0)
    p_bare, _, m_bare = fn_bare(p0, o0, batch, jnp.int32(0))
    p1, o1 = eng.init_state(0)
    p_off, _, m_off = fn_off(p1, o1, batch, jnp.int32(0))
    _assert_trees_bitwise(p_bare, p_off, "engine-disabled-tracer")
    assert float(m_bare["loss"]) == float(m_off["loss"])


@pytest.mark.obs
@pytest.mark.parametrize("cname,kw", [("qsgd", {"levels": 16}),
                                      ("terngrad", {}),
                                      ("signsgd", {})])
@pytest.mark.parametrize("fb", [0.0, 4096.0, math.inf])
def test_obs_sweep_span_counts(cname, kw, fb):
    """Full sweep: on both execution paths, per-step message-span count
    == schedule.num_messages and recording never changes numerics."""
    tree, sm = _tree(), stacked_mask(_tree())
    comp = make_compressor(cname, **kw)
    plan = build_plan(tree, sm, Granularity("layerwise"))
    sched = build_schedule(plan, fb)
    fn = lambda x, k: comp.sim(x, k)  # noqa: E731

    rec = TraceRecorder()
    got = _run_recorded(sched, fn, tree, rec)
    assert rec.finalize_step(0)["n_message_spans"] == sched.num_messages
    ref = jax.jit(lambda t, k: sched.execute(fn, t, k))(tree, KEY)
    _assert_trees_bitwise(ref, got, (cname, fb, "sim"))

    codec = wire_codec(comp)
    recw = TraceRecorder()
    goww = _run_recorded(sched, None, tree, recw, wire=codec)
    assert recw.finalize_step(0)["n_message_spans"] == sched.num_messages
    refw, _ = jax.jit(
        lambda t, k: sched.execute(None, t, k, wire=codec))(tree, KEY)
    _assert_trees_bitwise(refw, goww, (cname, fb, "wire"))
