"""Optional-dependency shim for `hypothesis`.

The property tests decorate with `@given(st....)`. When hypothesis is not
installed in the container, importing those modules used to kill collection
of the WHOLE file (ModuleNotFoundError), hiding every plain unit test in it.

`install()` registers a minimal stand-in module under the name `hypothesis`
whose `@given` replaces the test with a zero-argument function that calls
`pytest.skip(...)` — the property tests report as skipped, everything else
in the module collects and runs normally. With real hypothesis installed
this module is never imported.
"""
from __future__ import annotations

import sys
import types


def _strategy(*_args, **_kwargs):
    """Opaque placeholder strategy (never drawn from: the test skips)."""
    return None


def install() -> None:
    if "hypothesis" in sys.modules:  # real package (or stub) already present
        return

    import pytest

    hyp = types.ModuleType("hypothesis")
    hyp.__doc__ = "stub: hypothesis is not installed; property tests skip"
    st = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "lists", "sampled_from", "booleans",
                 "text", "tuples", "one_of", "just", "dictionaries",
                 "composite", "data"):
        setattr(st, name, _strategy)

    def given(*_args, **_kwargs):
        def deco(fn):
            # Zero-arg wrapper: pytest must not see the strategy-filled
            # parameters of `fn` (it would demand fixtures for them).
            def skipper():
                pytest.skip("hypothesis not installed; property test skipped")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            skipper.__module__ = fn.__module__
            return skipper
        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    settings.register_profile = lambda *a, **k: None
    settings.load_profile = lambda *a, **k: None

    hyp.given = given
    hyp.settings = settings
    hyp.strategies = st
    hyp.HealthCheck = types.SimpleNamespace(all=lambda: [])
    hyp.assume = lambda *a, **k: True
    hyp.note = lambda *a, **k: None
    hyp.example = lambda *a, **k: (lambda fn: fn)

    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st
