"""Granularity partitioning: unit dims, identity roundtrips, semantic
difference between layer-wise and entire-model statistics."""
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (Granularity, Identity, apply_unitwise,
                        make_compressor, stacked_mask, unit_dims)

KEY = jax.random.key(0)


def _tree():
    return {"blocks": {"w": jax.random.normal(KEY, (3, 16, 8)),
                       "b": jax.random.normal(KEY, (3, 8))},
            "embed": jax.random.normal(KEY, (20, 4))}


def test_unit_dims():
    t = _tree()
    sm = stacked_mask(t)
    assert unit_dims(t, sm, Granularity("entire_model")) == [3 * 16 * 8 + 3 * 8
                                                             + 80]
    assert unit_dims(t, sm, Granularity("layerwise")) == [8, 8, 8, 128, 128,
                                                          128, 80]
    bd = unit_dims(t, sm, Granularity("blockwise", 100))
    assert sum(bd) == 488 and all(b == 100 for b in bd[:-1])


@pytest.mark.parametrize("kind", ["entire_model", "layerwise", "blockwise"])
def test_identity_roundtrip(kind):
    t = _tree()
    sm = stacked_mask(t)
    out = apply_unitwise(lambda x, k: x, Granularity(kind, 64), t, sm, KEY)
    for a, b in zip(jax.tree_util.tree_leaves(out),
                    jax.tree_util.tree_leaves(t)):
        assert jnp.allclose(a, b)


def test_layerwise_vs_entire_model_differ_for_topk():
    """The paper's Figure 1: with heterogeneous layer magnitudes,
    entire-model Top-k starves the small-magnitude layer while layer-wise
    keeps k% of EVERY layer."""
    big = 100.0 * jax.random.normal(KEY, (1, 64))
    small = 0.01 * jax.random.normal(jax.random.fold_in(KEY, 1), (1, 64))
    t = {"blocks": {"w": jnp.concatenate([big, small])}}
    sm = stacked_mask(t)
    c = make_compressor("topk", ratio=0.5)
    lw = apply_unitwise(lambda x, k: c.sim(x, k), Granularity("layerwise"),
                        t, sm, KEY)["blocks"]["w"]
    em = apply_unitwise(lambda x, k: c.sim(x, k),
                        Granularity("entire_model"), t, sm, KEY)["blocks"]["w"]
    # layer-wise: the small layer keeps 32 of its own entries
    assert int(jnp.sum(lw[1] != 0)) == 32
    # entire-model: ALL kept entries come from the big layer
    assert int(jnp.sum(em[1] != 0)) == 0
    assert int(jnp.sum(em[0] != 0)) == 64


def test_layerwise_statistics_are_per_layer():
    """TernGrad's scale is per-unit: layer-wise output magnitudes match
    each layer's own max (the paper's §5.3 explanation)."""
    t = {"blocks": {"w": jnp.stack([jnp.full((32,), 10.0),
                                    jnp.full((32,), 0.1)])}}
    sm = stacked_mask(t)
    c = make_compressor("terngrad")
    lw = apply_unitwise(lambda x, k: c.sim(x, k), Granularity("layerwise"),
                        t, sm, KEY)["blocks"]["w"]
    nz0 = jnp.abs(lw[0][lw[0] != 0])
    nz1 = jnp.abs(lw[1][lw[1] != 0])
    assert jnp.allclose(nz0, 10.0) and jnp.allclose(nz1, 0.1)
    em = apply_unitwise(lambda x, k: c.sim(x, k),
                        Granularity("entire_model"), t, sm, KEY)["blocks"]["w"]
    nz1e = jnp.abs(em[1][em[1] != 0])
    if nz1e.size:  # entire-model scale is the GLOBAL max
        assert jnp.allclose(nz1e, 10.0)


@settings(max_examples=15, deadline=None)
@given(st.integers(min_value=1, max_value=5),
       st.integers(min_value=1, max_value=64),
       st.integers(min_value=8, max_value=200))
def test_property_unit_dims_partition(L, rows, block):
    """Every granularity partitions the exact total dimension."""
    t = {"blocks": {"w": jnp.zeros((L, rows, 4))},
         "head": jnp.zeros((rows,))}
    sm = stacked_mask(t)
    total = L * rows * 4 + rows
    for g in [Granularity("entire_model"), Granularity("layerwise"),
              Granularity("blockwise", block)]:
        assert sum(unit_dims(t, sm, g)) == total
