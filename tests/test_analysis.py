"""The scan-aware HLO cost model and roofline plumbing (deliverable g)."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.analysis import Roofline, model_flops
from repro.launch.hlo_cost import (_shape_bytes, _wire_bytes,
                                   scan_scaled_costs)
from repro.models.config import INPUT_SHAPES
from repro.configs.registry import get_config


def test_scan_trip_scaling_exact():
    def f(x, w):
        def body(c, wl):
            return jnp.tanh(c @ wl), None
        y, _ = jax.lax.scan(body, x, w)
        return y

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)
    c = jax.jit(f).lower(x, w).compile()
    r = scan_scaled_costs(c.as_text(), 1)
    assert r["flops"] == 8 * 2 * 128 ** 3


def test_nested_scan_trip_scaling_exact():
    def f(x, w):
        def outer(c, wl):
            def inner(c2, _):
                return jnp.tanh(c2 @ wl), None
            c2, _ = jax.lax.scan(inner, c, jnp.arange(3))
            return c2, None
        y, _ = jax.lax.scan(outer, x, w)
        return y

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((5, 64, 64), jnp.float32)
    c = jax.jit(f).lower(x, w).compile()
    r = scan_scaled_costs(c.as_text(), 1)
    assert r["flops"] == 5 * 3 * 2 * 64 ** 3


def test_shape_bytes_parsing():
    assert _shape_bytes("f32[16,4]{1,0}") == 256
    assert _shape_bytes("bf16[8]") == 16
    assert _shape_bytes("(s32[], f32[2,2]{1,0}, pred[3])") == 4 + 16 + 3
    assert _shape_bytes("s8[100]") == 100


def test_wire_model():
    # ring all-reduce moves ~2x payload across (g-1)/g links
    assert _wire_bytes("all-reduce", 1000, 2) == 1000.0
    assert _wire_bytes("all-gather", 1600, 16) == 1600 * 15 / 16
    assert _wire_bytes("reduce-scatter", 100, 4) == 300.0
    assert _wire_bytes("all-reduce", 1000, 1) == 0.0


def test_model_flops_formulas():
    cfg = get_config("mamba2-1.3b")
    n = cfg.active_param_count()
    tr = INPUT_SHAPES["train_4k"]
    assert model_flops(cfg, tr) == 6.0 * n * 256 * 4096
    de = INPUT_SHAPES["decode_32k"]
    assert model_flops(cfg, de) == 2.0 * n * 128


def test_moe_active_params_much_smaller():
    cfg = get_config("qwen3-moe-235b-a22b")
    assert cfg.param_count() > 2e11          # ~235B total
    assert cfg.active_param_count() < 0.3e11  # ~22B active
    l4 = get_config("llama4-maverick-400b-a17b")
    assert 3.5e11 < l4.param_count() < 4.5e11
    assert l4.active_param_count() < 0.25e11


def test_roofline_bottleneck_classification():
    r = Roofline(arch="a", shape="s", mesh="m", chips=256,
                 hlo_flops_per_device=197e12,      # 1 s compute
                 hlo_bytes_per_device=819e9 * 3,   # 3 s memory
                 collective_bytes_per_device=50e9 * 2,  # 2 s collective
                 collective_breakdown={}, model_flops_global=197e12 * 256,
                 memory_per_device={})
    assert r.bottleneck == "memory"
    assert r.t_compute == pytest.approx(1.0)
    assert r.useful_flops_ratio == pytest.approx(1.0)


def test_collectives_detected_in_shardmap_hlo():
    try:
        from jax import shard_map as sm
        kw = {"check_vma": False}
    except ImportError:
        from jax.experimental.shard_map import shard_map as sm
        kw = {"check_rep": False}
    mesh = jax.make_mesh((1,), ("data",))
    from jax.sharding import PartitionSpec as P

    def f(x):
        return jax.lax.psum(x, "data")

    c = jax.jit(sm(f, mesh=mesh, in_specs=(P("data"),),
                   out_specs=P(None), **kw)).lower(
        jax.ShapeDtypeStruct((8,), jnp.float32)).compile()
    r = scan_scaled_costs(c.as_text(), 1)
    # group size 1 -> zero wire cost, but parse must not crash
    assert isinstance(r["collectives"], dict)
