"""Multi-device fault-injection checks (subprocess body).

Run by tests/test_resil.py with 4 virtual CPU devices — XLA device count
must be set before jax initializes, hence the subprocess. What a
single-device run cannot witness — corruption of REAL ring hops between
distinct workers:

  1. bit flips on every ring hop (prob=1) are all detected by the
     Fletcher-32 header word, and resend recovers the clean aggregate
     BITWISE (chunked and unchunked hops, and the serialized allgather
     wire path for the same contract off the ring);
  2. dropped (zeroed) hops are all detected — the init=1 checksum of an
     all-zero span never matches — and resend recovers clean bits;
  3. a duplicated (stale) hop is a VALID message: the checksum passes
     (zero detections), the aggregate silently differs — the documented
     sequence-number gap;
  4. a prob=0 injector is the byte-identical pass-through (det == 0,
     same bits as faults=None);
  5. threaded error feedback under per-hop bit flips with resend stays
     bitwise on the clean trajectory across steps.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import jax                     # noqa: E402
import jax.numpy as jnp        # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.core import (CompressionConfig, Granularity,  # noqa: E402
                        compressed_allreduce, make_compressor,
                        stacked_mask)
from repro.launch.engine import shard_map  # noqa: E402
from repro.launch.mesh import make_host_mesh  # noqa: E402
from repro.resil import FaultInjector  # noqa: E402
from repro.sim import CorruptionSpec  # noqa: E402

KEY = jax.random.key(7)
N = jax.local_device_count()
assert N == 4, f"expected 4 virtual devices, got {N}"
MESH = make_host_mesh(N, 1)


def _tree():
    ks = [jax.random.fold_in(jax.random.key(3), i) for i in range(4)]
    return {"dense": jax.random.normal(ks[0], (8, 16)),
            "blocks": jax.random.normal(ks[1], (3, 4, 10)),
            "odd": jax.random.normal(ks[2], (7,)),
            "scalar": jax.random.normal(ks[3], ())}


def _per_worker(g):
    i = jax.lax.axis_index("data").astype(jnp.float32)
    return jax.tree_util.tree_map(lambda x: x * (1.0 + i), g)


def _bitwise(a, b, ctx):
    for x, y in zip(jax.tree_util.tree_leaves(a),
                    jax.tree_util.tree_leaves(b)):
        assert x.shape == y.shape and x.dtype == y.dtype, ctx
        assert bool((x == y).all()), (
            ctx, float(jnp.max(jnp.abs(x - y))))


def _differs(a, b):
    return any(not bool((x == y).all())
               for x, y in zip(jax.tree_util.tree_leaves(a),
                               jax.tree_util.tree_leaves(b)))


def _cfg(strat):
    return CompressionConfig(qw=make_compressor("topk", ratio=0.25),
                             granularity=Granularity("layerwise"),
                             strategy=strat, error_feedback=False,
                             integrity=True)


def _run(strat, spec, *, resend=True, chunk=None, ef_steps=0):
    """One shard_map'd compressed_allreduce under an injector. Returns
    (out, total_detections, messages_per_device) — or (outs, ef, det)
    with `ef_steps` threaded error feedback."""
    t = _tree()
    sm = stacked_mask(t)
    cfg = _cfg(strat)
    if ef_steps:
        cfg = CompressionConfig(qw=cfg.qw, granularity=cfg.granularity,
                                strategy=strat, error_feedback=True,
                                integrity=True)
    inj = None if spec is None else FaultInjector(spec, resend=resend)

    def f(g, ef, key):
        g = _per_worker(g)
        if cfg.error_feedback:
            out, ef = compressed_allreduce(g, sm, cfg, ("data",), key, N,
                                           wire=True, ef_state=ef,
                                           faults=inj,
                                           stream_chunk_bytes=chunk)
        else:
            out, _ = compressed_allreduce(g, sm, cfg, ("data",), key, N,
                                          wire=True, faults=inj,
                                          stream_chunk_bytes=chunk)
        if inj is None:
            det = jnp.zeros((), jnp.int32)
            msgs = jnp.zeros((), jnp.int32)
        else:
            # drain the verdicts INSIDE this trace (they are its tracers)
            flags = inj.take_flags()
            det = (jnp.sum(~flags).astype(jnp.int32) if flags.size
                   else jnp.zeros((), jnp.int32))
            msgs = jnp.asarray(flags.size, jnp.int32)
        det = jax.lax.psum(det, ("data",))
        return out, ef, det, msgs

    fn = jax.jit(shard_map(f, MESH, in_specs=(P(), P(), P()),
                           out_specs=(P(), P(), P(), P())))
    if not ef_steps:
        out, _ef, det, msgs = fn(t, t, KEY)   # ef arg unused
        return out, int(det), int(msgs)
    ef = jax.tree_util.tree_map(jnp.zeros_like, t)
    outs, det_total = [], 0
    for i in range(ef_steps):
        out, ef, det, _ = fn(t, ef, jax.random.fold_in(KEY, i))
        outs.append(out)
        det_total += int(det)
    return outs, ef, det_total


def check_ring_bitflip_resend():
    clean, _, _ = _run("ring", None)
    for chunk in (None, 64.0):
        out, det, msgs = _run("ring", CorruptionSpec(prob=1.0, seed=5),
                              resend=True, chunk=chunk)
        assert msgs > 0 and det == N * msgs, (det, msgs, chunk)
        _bitwise(out, clean, ("ring-bitflip-resend", chunk))
        # without resend the corrupted hops poison the aggregate
        bad, det2, _ = _run("ring", CorruptionSpec(prob=1.0, seed=5),
                            resend=False, chunk=chunk)
        assert det2 == det and _differs(bad, clean), chunk
    print("ring bit flips: all detected, resend == clean bitwise: OK")


def check_ring_drop_hop():
    clean, _, _ = _run("ring", None)
    out, det, msgs = _run("ring",
                          CorruptionSpec(prob=1.0, mode="drop_hop",
                                         seed=6), resend=True)
    assert msgs > 0 and det == N * msgs, (det, msgs)
    _bitwise(out, clean, "ring-drop-resend")
    print("ring dropped hops: init=1 catches zeros, resend == clean: OK")


def check_ring_dup_hop_limitation():
    clean, _, _ = _run("ring", None)
    out, det, msgs = _run("ring",
                          CorruptionSpec(prob=1.0, mode="dup_hop",
                                         seed=8), resend=True)
    assert msgs > 0 and det == 0, (det, msgs)
    assert _differs(out, clean)
    print("ring duplicated hop: valid stale message passes the checksum "
          "(needs sequence numbers) — documented gap holds: OK")


def check_allgather_bitflip_resend():
    clean, _, _ = _run("allgather", None)
    out, det, msgs = _run("allgather", CorruptionSpec(prob=1.0, seed=9),
                          resend=True)
    assert msgs > 0 and det == N * msgs, (det, msgs)
    _bitwise(out, clean, "allgather-bitflip-resend")
    print("allgather wire bit flips: all detected, resend == clean: OK")


def check_prob0_passthrough():
    clean, _, _ = _run("ring", None)
    out, det, msgs = _run("ring", CorruptionSpec(prob=0.0))
    assert det == 0 and msgs == 0
    _bitwise(out, clean, "prob0-passthrough")
    print("prob=0 injector: byte-identical pass-through: OK")


def check_ring_ef_resend():
    clean_outs, clean_ef, _ = _run("ring", None, ef_steps=3)
    outs, ef, det = _run("ring", CorruptionSpec(prob=1.0, seed=12),
                         resend=True, ef_steps=3)
    assert det > 0
    for i, (r, g) in enumerate(zip(clean_outs, outs)):
        _bitwise(r, g, ("ring-ef-resend", i))
    _bitwise(ef, clean_ef, "ring-ef-resend-state")
    print("ring 3-step EF under bit flips with resend == clean: OK")


if __name__ == "__main__":
    check_ring_bitflip_resend()
    check_ring_drop_hop()
    check_ring_dup_hop_limitation()
    check_allgather_bitflip_resend()
    check_prob0_passthrough()
    check_ring_ef_resend()
    print("ALL FAULT CHECKS PASSED")
