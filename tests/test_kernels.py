"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp
oracles (assert_allclose)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref
from repro.kernels.qsgd import BLOCK_C, BLOCK_R, qsgd_pallas
from repro.kernels.rmsnorm import rmsnorm_pallas
from repro.kernels.terngrad import terngrad_pallas
from repro.kernels.topk_mask import topk_mask_pallas

KEY = jax.random.key(7)

SHAPES = [(37,), (512,), (4096,), (3, 700), (8, 8, 33)]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("levels", [4, 64])
def test_qsgd_kernel_matches_ref(shape, dtype, levels):
    x = (10 * jax.random.normal(KEY, shape)).astype(dtype)
    a = ops.qsgd_compress(x, KEY, levels, use_pallas=True)
    b = ops.qsgd_compress(x, KEY, levels, use_pallas=False)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), atol=1e-5)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_terngrad_kernel_matches_ref(shape, dtype):
    x = jax.random.normal(KEY, shape).astype(dtype)
    a = ops.terngrad_compress(x, KEY, use_pallas=True)
    b = ops.terngrad_compress(x, KEY, use_pallas=False)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), atol=1e-6)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("k", [1, 16, 128])
def test_topk_kernel_matches_ref(shape, k):
    x = jax.random.normal(KEY, shape)
    a = ops.blockwise_topk(x, k, use_pallas=True)
    b = ops.blockwise_topk(x, k, use_pallas=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=0)


def test_topk_keeps_approximately_k():
    x = jax.random.normal(KEY, (BLOCK_R, BLOCK_C))
    for k in (8, 32, 100):
        y = topk_mask_pallas(x, k, interpret=True)
        nnz = np.asarray((y != 0).sum(axis=-1))
        assert (nnz >= k).all() and (nnz <= k + 4).all(), (k, nnz)


@pytest.mark.parametrize("rows", [64, 128])
@pytest.mark.parametrize("d", [128, 384])
@pytest.mark.parametrize("dtype", DTYPES)
def test_rmsnorm_kernel_matches_ref(rows, d, dtype):
    x = jax.random.normal(KEY, (rows, d)).astype(dtype)
    g = jax.random.normal(jax.random.fold_in(KEY, 1), (d,)).astype(dtype)
    a = rmsnorm_pallas(x, g, interpret=True)
    b = ref.rmsnorm_ref(x, g)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               atol=3e-2 if dtype == jnp.bfloat16 else 1e-5)


def test_qsgd_kernel_direct_tiles():
    """Direct pallas_call on pre-tiled input (no wrapper padding)."""
    x = jax.random.normal(KEY, (BLOCK_R * 2, BLOCK_C))
    u = jax.random.uniform(jax.random.fold_in(KEY, 2), x.shape)
    nrm = jnp.linalg.norm(x)
    a = qsgd_pallas(x, u, nrm, 16, interpret=True)
    b = ref.qsgd_ref(x, u, nrm, 16)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    c = terngrad_pallas(x, u, jnp.max(jnp.abs(x)), interpret=True)
    d = ref.terngrad_ref(x, u, jnp.max(jnp.abs(x)))
    np.testing.assert_allclose(np.asarray(c), np.asarray(d), atol=1e-6)
