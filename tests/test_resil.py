"""The resilience plane: wire integrity, fault injection, recovery.

Contracts held here (single-device; the genuinely-multi-device ring-hop
checks run in the tests/fault_checks.py subprocess via test_resil_
multidevice_checks):

  1. FLETCHER-32: the vectorized in-graph checksum equals the byte-serial
     reference loop (sizes crossing every chunk boundary + a hypothesis
     property when installed); the init=1 variant gives an all-zero
     buffer a NONZERO checksum (a dropped/zeroed message never
     verifies); any single-bit flip is detected (exhaustive small sweep).
  2. LAYOUT: codec.integrity reserves exactly one extra uint32 header
     word per fused message; the checksummed span starts past
     [n_buckets, fletcher32]; integrity never changes decoded numerics.
  3. DETECTION GATE: across the six-codec zoo x granularities on the
     serialized wire path, every clean message verifies (zero false
     positives) and every single-bit-flipped message fails verification.
  4. HARDENED PARSE: parse_message_header accepts exactly the buffers
     _message_buffer emits and raises ValueError on every mutated
     header (truncation, zero/oversized bucket count, misplaced or
     decreasing or out-of-range offsets, ragged byte length).
  5. CHECKPOINTS: atomic (no partial file at the final path, tmp never
     matches latest_checkpoint), digest-verified (a flipped byte
     raises ValueError), bitwise round-trip.
  6. RECOVERY: faulted-with-resend training == clean training bitwise;
     EF residuals are SENDER-side state and stay bitwise clean under
     receive corruption; a guarded non-finite step skips the update AND
     conserves the EF residual; repeated corruption flips the dense
     fallback; partial participation renormalizes the mean over
     survivors and freezes dead workers' EF rows; train_resilient
     resume is leaf-for-leaf bitwise (train N == train k, kill, resume).

The heavy sweeps carry the `fault` marker: tier-1 (`make verify`) only,
excluded from the `make verify-fast` inner loop.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (CompressionConfig, Granularity,
                        aggregate_simulated_workers, build_plan,
                        build_schedule, make_compressor, stacked_mask)
from repro.core.wire import (execute_schedule_wire, fletcher32,
                             message_layouts, parse_message_header,
                             verify_message, wire_codec)
from repro.ckpt import latest_checkpoint, load_checkpoint, save_checkpoint
from repro.resil import (FaultInjector, RecoveryConfig, RecoveryManager,
                         train_resilient)
from repro.sim import CorruptionSpec, Scenario, StragglerSpec, init_ef

KEY = jax.random.key(0)

SIX = [
    ("topk", {"ratio": 0.25}),
    ("randomk", {"ratio": 0.3, "scale": True}),
    ("qsgd", {"levels": 16}),
    ("terngrad", {}),
    ("signsgd", {}),
    ("natural", {}),
]

GRANS = [Granularity("layerwise"), Granularity("entire_model")]


def _tree(key=KEY):
    ks = [jax.random.fold_in(key, i) for i in range(5)]
    return {"blocks": {"w": jax.random.normal(ks[0], (3, 16, 8)),
                       "b": jax.random.normal(ks[1], (3, 8))},
            "embed": jax.random.normal(ks[2], (20, 4)),
            "head": jax.random.normal(ks[3], (4, 2)),
            "scalar_gain": jax.random.normal(ks[4], ())}


def _worker_grads(n=4, key=KEY):
    trees = [_tree(jax.random.fold_in(key, 100 + i)) for i in range(n)]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def _assert_trees_bitwise(a, b, ctx):
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        assert la.shape == lb.shape and la.dtype == lb.dtype, ctx
        assert bool((la == lb).all()), (
            ctx, float(jnp.max(jnp.abs(la - lb))))


def _trees_differ(a, b):
    return any(not bool((x == y).all())
               for x, y in zip(jax.tree_util.tree_leaves(a),
                               jax.tree_util.tree_leaves(b)))


# ==========================================================================
# 1. Fletcher-32 vs the byte-serial reference
# ==========================================================================

def _fletcher_ref(data: bytes) -> int:
    """The byte-serial reference loop (init=1 variant, LE 16-bit words,
    odd tail zero-padded)."""
    if len(data) % 2:
        data = data + b"\x00"
    s1, s2 = 1, 0
    for i in range(0, len(data), 2):
        w = data[i] | (data[i + 1] << 8)
        s1 = (s1 + w) % 65535
        s2 = (s2 + s1) % 65535
    return (s2 << 16) | s1


@pytest.mark.parametrize("size", [0, 1, 2, 3, 7, 100, 255, 4097])
def test_fletcher32_matches_reference(size):
    rng = np.random.default_rng(size)
    data = rng.integers(0, 256, size, dtype=np.uint8)
    got = int(fletcher32(jnp.asarray(data)))
    assert got == _fletcher_ref(data.tobytes()), size


@pytest.mark.fault
@pytest.mark.parametrize("size", [65534, 65535, 65536, 65537, 200001])
def test_fletcher32_matches_reference_chunk_boundaries(size):
    """Sizes straddling the staged mod-65535 chunk reduction."""
    rng = np.random.default_rng(size)
    data = rng.integers(0, 256, size, dtype=np.uint8)
    got = int(fletcher32(jnp.asarray(data)))
    assert got == _fletcher_ref(data.tobytes()), size


@given(st.lists(st.integers(0, 255), min_size=0, max_size=512))
@settings(max_examples=50, deadline=None)
def test_fletcher32_property(byte_list):
    data = np.asarray(byte_list, np.uint8)
    assert int(fletcher32(jnp.asarray(data))) == \
        _fletcher_ref(data.tobytes())


def test_fletcher32_zeros_nonzero():
    """init=1: the checksum of an all-zero buffer is NONZERO and length-
    dependent — a dropped (zeroed) message can never verify against a
    zeroed header word, and truncation-to-zeros shifts sum2."""
    for size in (2, 64, 100):
        c = int(fletcher32(jnp.zeros((size,), jnp.uint8)))
        assert c != 0, size
    assert int(fletcher32(jnp.zeros((2,), jnp.uint8))) != \
        int(fletcher32(jnp.zeros((64,), jnp.uint8)))


def test_fletcher32_single_bit_flip_always_detected():
    """A single flipped bit changes one 16-bit word by ±2^k, never ≡ 0
    mod 65535 — exhaustively over a small buffer, every (byte, bit)
    flip changes the checksum."""
    rng = np.random.default_rng(7)
    data = rng.integers(0, 256, 64, dtype=np.uint8)
    clean = int(fletcher32(jnp.asarray(data)))
    for pos in range(data.size):
        for bit in range(8):
            d = data.copy()
            d[pos] ^= np.uint8(1 << bit)
            assert int(fletcher32(jnp.asarray(d))) != clean, (pos, bit)


# ==========================================================================
# 2. layout: the integrity header word
# ==========================================================================

def _wire_parts(name, kw, gran, integrity=True):
    t = _tree()
    sm = stacked_mask(t)
    codec = wire_codec(make_compressor(name, **kw), integrity=integrity)
    plan = build_plan(t, sm, gran)
    sched = build_schedule(plan, 0.0)
    return t, codec, sched, message_layouts(sched, codec)


def test_integrity_header_reserves_one_word():
    for gran in GRANS:
        _, _, sched, lays = _wire_parts("topk", {"ratio": 0.25}, gran)
        _, _, _, plain = _wire_parts("topk", {"ratio": 0.25}, gran,
                                     integrity=False)
        for li, lp in zip(lays, plain):
            assert li.checksum and not lp.checksum
            assert li.header_nbytes == lp.header_nbytes + 4
            assert li.checksum_span_start == 8
        assert not any(getattr(lp, "checksum") for lp in plain)


def test_verify_message_requires_checksum_layout():
    _, codec, sched, lays = _wire_parts("topk", {"ratio": 0.25}, GRANS[0],
                                        integrity=False)
    buf = jnp.zeros((lays[0].total_nbytes,), jnp.uint8)
    with pytest.raises(ValueError, match="checksum layout"):
        verify_message(buf, lays[0])


def test_integrity_decode_bit_identical():
    """The checksum word changes the header, never the numerics."""
    for gran in GRANS:
        t, codec, sched, _ = _wire_parts("qsgd", {"levels": 16}, gran)
        _, plain_codec, _, _ = _wire_parts("qsgd", {"levels": 16}, gran,
                                           integrity=False)
        out_i, bufs_i = execute_schedule_wire(sched, codec, None, t, KEY)
        out_p, bufs_p = execute_schedule_wire(sched, plain_codec, None, t,
                                              KEY)
        _assert_trees_bitwise(out_i, out_p, gran.kind)
        for bi, bp in zip(bufs_i, bufs_p):
            assert bi.size == bp.size + 4, gran.kind


# ==========================================================================
# 3. the detection gate: six codecs x granularities, serialized path
# ==========================================================================

def _detection_case(name, kw, gran):
    t, codec, sched, lays = _wire_parts(name, kw, gran)
    _, bufs = execute_schedule_wire(sched, codec, None, t, KEY)
    assert len(bufs) == len(lays) and len(bufs) >= 1
    rng = np.random.default_rng(11)
    for buf, lay in zip(bufs, lays):
        # zero false positives: the clean buffer always verifies
        assert bool(verify_message(buf, lay)), (name, gran.kind)
        b = np.asarray(buf)
        # every sampled single-bit flip in the covered span is caught
        span = b.size - lay.checksum_span_start
        for _ in range(8):
            pos = lay.checksum_span_start + int(rng.integers(span))
            bit = int(rng.integers(8))
            c = b.copy()
            c[pos] ^= np.uint8(1 << bit)
            assert not bool(verify_message(jnp.asarray(c), lay)), \
                (name, gran.kind, pos, bit)
        # a zeroed (dropped) message is caught too
        z = np.zeros_like(b)
        z[:lay.checksum_span_start] = b[:lay.checksum_span_start]
        assert not bool(verify_message(jnp.asarray(z), lay)), \
            (name, gran.kind)


def test_detection_gate_smoke():
    _detection_case("topk", {"ratio": 0.25}, GRANS[0])


@pytest.mark.fault
@pytest.mark.parametrize("gran", GRANS, ids=lambda g: g.kind)
@pytest.mark.parametrize("name,kw", SIX, ids=[n for n, _ in SIX])
def test_detection_gate_zoo(name, kw, gran):
    _detection_case(name, kw, gran)


# ==========================================================================
# 4. hardened header parse
# ==========================================================================

def _valid_message():
    t, codec, sched, lays = _wire_parts("topk", {"ratio": 0.25}, GRANS[0])
    _, bufs = execute_schedule_wire(sched, codec, None, t, KEY)
    return np.asarray(bufs[0]), lays[0]


def test_parse_message_header_accepts_real_buffers():
    b, lay = _valid_message()
    n_buckets, offsets = parse_message_header(b, checksum=True)
    assert n_buckets == len(lay.offsets)
    assert offsets == tuple(lay.offsets)
    assert offsets[0] == lay.header_nbytes


def test_parse_message_header_rejects_mutations():
    b, _ = _valid_message()
    words = b.view("<u4").copy()

    def parse(w):
        parse_message_header(w.view(np.uint8), checksum=True)

    with pytest.raises(ValueError, match="whole number"):
        parse_message_header(b[:-1], checksum=True)
    with pytest.raises(ValueError, match="at least"):
        parse_message_header(np.zeros((0,), np.uint8), checksum=True)
    w = words.copy()
    w[0] = 0                      # zero bucket count
    with pytest.raises(ValueError, match="n_buckets"):
        parse(w)
    w = words.copy()
    w[0] = 1 << 24                # bucket count beyond the buffer
    with pytest.raises(ValueError, match="n_buckets"):
        parse(w)
    w = words.copy()
    w[2] += 4                     # first offset off the header end
    with pytest.raises(ValueError, match="first bucket offset"):
        parse(w)
    if words[0] >= 2:
        w = words.copy()
        w[3] = w[2] - 4           # decreasing offsets
        with pytest.raises(ValueError, match="non-decreasing"):
            parse(w)
    w = words.copy()
    w[2 + int(words[0]) - 1] = b.size + 64   # last offset out of range
    with pytest.raises(ValueError):
        parse(w)


# ==========================================================================
# 5. checkpoints: atomic, digest-verified
# ==========================================================================

def _ck_tree():
    return {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": jnp.ones((4,), jnp.bfloat16),
            "n": jnp.int32(7)}


def test_checkpoint_roundtrip_and_atomicity(tmp_path):
    d = str(tmp_path)
    t = _ck_tree()
    path = save_checkpoint(d, 5, t)
    assert os.path.exists(path)
    # no staging residue, and a stray tmp file never wins latest
    assert not [f for f in os.listdir(d) if ".tmp" in f]
    open(os.path.join(d, "ckpt_00000099_s0.npz.tmp.npz"), "wb").close()
    assert latest_checkpoint(d) == path
    step, got = load_checkpoint(path, like=t)
    assert step == 5
    _assert_trees_bitwise(got, t, "roundtrip")


def test_checkpoint_rejects_truncation(tmp_path):
    d = str(tmp_path)
    path = save_checkpoint(d, 1, _ck_tree())
    raw = open(path, "rb").read()
    open(path, "wb").write(raw[:len(raw) // 2])
    with pytest.raises(ValueError, match="corrupt or truncated"):
        load_checkpoint(path, like=_ck_tree())


def test_checkpoint_rejects_flipped_payload_byte(tmp_path):
    d = str(tmp_path)
    path = save_checkpoint(d, 1, _ck_tree())
    raw = bytearray(open(path, "rb").read())
    # flip a byte in the stored-array region (past the zip local header)
    raw[len(raw) // 2] ^= 0xFF
    open(path, "wb").write(bytes(raw))
    with pytest.raises(ValueError, match="corrupt"):
        load_checkpoint(path, like=_ck_tree())


def test_checkpoint_rejects_missing_keys(tmp_path):
    d = str(tmp_path)
    path = save_checkpoint(d, 1, {"w": jnp.ones((2,))})
    with pytest.raises(ValueError, match="missing keys"):
        load_checkpoint(path, like={"w": jnp.ones((2,)),
                                    "extra": jnp.ones((3,))})


# ==========================================================================
# 6. CorruptionSpec / scenario plumbing
# ==========================================================================

def test_corruption_spec_validation():
    with pytest.raises(ValueError, match="prob"):
        CorruptionSpec(prob=1.5)
    with pytest.raises(ValueError, match="mode"):
        CorruptionSpec(mode="cosmic_ray")
    with pytest.raises(ValueError, match="n_bits"):
        CorruptionSpec(n_bits=0)
    duck = type("S", (), {"prob": 1.0, "mode": "bad", "n_bits": 1,
                          "seed": 0})()
    with pytest.raises(ValueError, match="mode"):
        FaultInjector(duck)
    assert CorruptionSpec().is_identity()
    assert not CorruptionSpec(prob=0.5).is_identity()


def test_scenario_corruption_identity_and_describe():
    assert Scenario(name="x").is_identity()
    s = Scenario(name="x", corruption=CorruptionSpec(prob=0.3,
                                                     mode="truncate"))
    assert not s.is_identity()
    assert "truncate" in s.describe()


def test_injector_passthrough_is_same_object():
    buf = jnp.arange(64, dtype=jnp.uint8)
    inj = FaultInjector(CorruptionSpec(prob=0.0))
    assert inj.corrupt(buf, KEY, tag=0) is buf
    hop_only = FaultInjector(CorruptionSpec(prob=1.0, mode="drop_hop"))
    assert hop_only.corrupt(buf, KEY, tag=0) is buf  # serialized path


# ==========================================================================
# 7. corruption through the aggregation path: detect / resend / EF
# ==========================================================================

def _agg_case(name, kw, gran):
    """Corrupted-with-resend == clean bitwise; EF residuals (sender-side
    state) stay bitwise clean under receive corruption; detection
    counters cover every message."""
    grads = _worker_grads()
    sm = stacked_mask(_tree())
    cfg = CompressionConfig(qw=make_compressor(name, **kw),
                            granularity=gran, error_feedback=True,
                            integrity=True)
    ef = init_ef(_tree(), 4)
    clean_out, clean_ef = aggregate_simulated_workers(
        grads, sm, cfg, KEY, ef_state=ef, wire=True)
    spec = CorruptionSpec(prob=1.0, mode="bitflip", n_bits=1, seed=3)

    corrupt = FaultInjector(spec, resend=False)
    out, new_ef, info = aggregate_simulated_workers(
        grads, sm, cfg, KEY, ef_state=ef, wire=True, faults=corrupt)
    assert int(info["messages"]) > 0
    assert int(info["corrupt_detected"]) == int(info["messages"]), \
        (name, gran.kind)  # prob=1 single-bit flips: all detected
    assert int(info["resends"]) == 0
    # sender-side discipline: EF never sees the receiver's corruption
    _assert_trees_bitwise(new_ef, clean_ef, (name, gran.kind, "ef"))
    assert _trees_differ(out, clean_out), (name, gran.kind)

    resend = FaultInjector(spec, resend=True)
    out_r, ef_r, info_r = aggregate_simulated_workers(
        grads, sm, cfg, KEY, ef_state=ef, wire=True, faults=resend)
    assert int(info_r["resends"]) == int(info_r["corrupt_detected"])
    _assert_trees_bitwise(out_r, clean_out, (name, gran.kind, "resend"))
    _assert_trees_bitwise(ef_r, clean_ef, (name, gran.kind, "resend-ef"))


def test_corruption_resend_smoke():
    _agg_case("topk", {"ratio": 0.25}, GRANS[0])


@pytest.mark.fault
@pytest.mark.parametrize("gran", GRANS, ids=lambda g: g.kind)
@pytest.mark.parametrize("name,kw", SIX, ids=[n for n, _ in SIX])
def test_corruption_resend_zoo(name, kw, gran):
    _agg_case(name, kw, gran)


def test_faults_require_wire():
    grads = _worker_grads()
    sm = stacked_mask(_tree())
    cfg = CompressionConfig(qw=make_compressor("topk", ratio=0.25),
                            granularity=GRANS[0], integrity=True)
    inj = FaultInjector(CorruptionSpec(prob=1.0))
    with pytest.raises(ValueError, match="wire"):
        aggregate_simulated_workers(grads, sm, cfg, KEY, wire=False,
                                    faults=inj)


# ==========================================================================
# 8. partial participation: survivor mean + EF freeze
# ==========================================================================

def test_partial_participation_hand_computed():
    """With the identity compressor, the aggregate under an alive mask
    is exactly the plain mean over survivors."""
    grads = _worker_grads()
    sm = stacked_mask(_tree())
    cfg = CompressionConfig(qw=make_compressor("identity"),
                            granularity=GRANS[0])
    alive = np.array([True, False, True, True])
    out, _ = aggregate_simulated_workers(grads, sm, cfg, KEY,
                                         alive=alive)
    w = jnp.asarray(alive, jnp.float32)
    w = w / jnp.sum(w)
    want = jax.tree_util.tree_map(
        lambda g: jnp.tensordot(w, g, axes=1), grads)
    _assert_trees_bitwise(out, want, "survivor-mean")


def test_partial_participation_freezes_dead_ef():
    grads = _worker_grads()
    sm = stacked_mask(_tree())
    cfg = CompressionConfig(qw=make_compressor("topk", ratio=0.25),
                            granularity=GRANS[0], error_feedback=True)
    ef = jax.tree_util.tree_map(
        lambda x: jnp.ones_like(x), init_ef(_tree(), 4))
    alive = np.array([True, False, True, True])
    _, new_ef = aggregate_simulated_workers(grads, sm, cfg, KEY,
                                            ef_state=ef, alive=alive)
    for le, ln in zip(jax.tree_util.tree_leaves(ef),
                      jax.tree_util.tree_leaves(new_ef)):
        assert bool((ln[1] == le[1]).all())          # dead row frozen
        if le[0].size:                               # alive rows advanced
            assert not bool((ln[0] == le[0]).all()) or \
                not bool((ln[2] == le[2]).all())


# ==========================================================================
# 9. recovery manager + the resilient training loop
# ==========================================================================

class ToyRunner:
    """Tiny linear softmax classifier on the non-IID synthetic shard
    sampler — the campaign runner protocol at smoke scale."""
    categories = 4
    global_batch = 8
    _hw, _ch = 4, 1

    def init(self, key):
        d = self._hw * self._hw * self._ch
        return {"w": 0.1 * jax.random.normal(key, (d, self.categories)),
                "b": jnp.zeros((self.categories,))}

    def loss(self, params, batch, key):
        x = batch["images"].reshape(batch["images"].shape[0], -1)
        logits = x @ params["w"] + params["b"]
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(
            logits, batch["labels"][:, None].astype(jnp.int32), 1)[:, 0]
        return jnp.mean(lse - picked)

    def worker_batch(self, key, props, per):
        from repro.data import noniid_classification_batch
        return noniid_classification_batch(key, props, per,
                                           classes=self.categories,
                                           hw=self._hw,
                                           channels=self._ch)


def _comp(ef=True):
    return CompressionConfig(qw=make_compressor("topk", ratio=0.25),
                             granularity=Granularity("layerwise"),
                             error_feedback=ef, integrity=True)


def test_recovery_manager_fallback_and_state_roundtrip():
    cfg = RecoveryConfig(dense_fallback_after=2)
    m = RecoveryManager(cfg)
    m.observe(detected=3, resends=3)
    assert not m.fallback_active and m.consecutive_failures == 1
    m.observe(detected=0)
    assert m.consecutive_failures == 0          # consecutive, not total
    m.observe(detected=1)
    m.observe(detected=2, skipped=1)
    assert m.fallback_active
    assert m.counters["resil/corrupt_detected"] == 6
    assert m.counters["resil/steps_skipped"] == 1
    m2 = RecoveryManager(cfg)
    m2.restore(m.state())
    assert m2.fallback_active and m2.counters == m.counters
    with pytest.raises(ValueError, match="dense_fallback_after"):
        RecoveryConfig(dense_fallback_after=0)
    with pytest.raises(ValueError, match="straggler"):
        RecoveryConfig(straggler_timeout_us=-1.0)


@pytest.mark.fault
def test_train_resilient_resume_bitwise(tmp_path):
    """train 6 == train 3 + kill + resume + train 3, leaf for leaf."""
    runner = ToyRunner()
    scen = Scenario(name="corrupt", n_workers=4,
                    corruption=CorruptionSpec(prob=0.5, seed=5))
    full = train_resilient(runner, scen, _comp(), steps=6, seed=1)
    d = str(tmp_path)
    train_resilient(runner, scen, _comp(), steps=3, seed=1,
                    ckpt_dir=d, ckpt_every=3)
    resumed = train_resilient(runner, scen, _comp(), steps=6, seed=1,
                              ckpt_dir=d, ckpt_every=3, resume=True)
    _assert_trees_bitwise(resumed["params"], full["params"], "params")
    _assert_trees_bitwise(resumed["ef"], full["ef"], "ef")
    assert resumed["losses"] == full["losses"][3:]
    assert resumed["counters"]["resil/corrupt_detected"] == \
        full["counters"]["resil/corrupt_detected"]


@pytest.mark.fault
def test_train_resilient_resend_matches_clean():
    """Recovery contract: a corruption-riddled run WITH resend is
    bitwise the corruption-free run — detection wired to action."""
    runner = ToyRunner()
    clean = train_resilient(runner, Scenario(name="clean", n_workers=4),
                            _comp(), steps=4, seed=2)
    faulted = train_resilient(
        runner,
        Scenario(name="bad", n_workers=4,
                 corruption=CorruptionSpec(prob=1.0, seed=9)),
        _comp(), steps=4, seed=2,
        recovery=RecoveryConfig(resend=True))
    assert faulted["counters"]["resil/corrupt_detected"] > 0
    assert faulted["counters"]["resil/resends"] == \
        faulted["counters"]["resil/corrupt_detected"]
    _assert_trees_bitwise(faulted["params"], clean["params"], "params")
    _assert_trees_bitwise(faulted["ef"], clean["ef"], "ef")
    assert faulted["losses"] == clean["losses"]


@pytest.mark.fault
def test_train_resilient_step_guard_conserves_ef():
    """A poisoned (non-finite) step is skipped and the EF residual rolls
    back: params stay finite and equal the pre-poison trajectory's
    values wherever the guard fired."""
    runner = ToyRunner()
    scen = Scenario(name="clean", n_workers=4)

    def poison(wg, key):
        # nan out every worker's gradient at exactly one step
        hit = jax.random.bernoulli(jax.random.fold_in(key, 0), 0.25)
        return jax.tree_util.tree_map(
            lambda g: jnp.where(hit, jnp.nan, g), wg)

    guarded = train_resilient(runner, scen, _comp(), steps=8, seed=3,
                              recovery=RecoveryConfig(step_guard=True),
                              grad_hook=poison)
    assert guarded["counters"]["resil/steps_skipped"] >= 1
    for leaf in jax.tree_util.tree_leaves(guarded["params"]):
        assert bool(jnp.isfinite(leaf).all())
    for leaf in jax.tree_util.tree_leaves(guarded["ef"]):
        assert bool(jnp.isfinite(leaf).all())
    unguarded = train_resilient(
        runner, scen, _comp(), steps=8, seed=3,
        recovery=RecoveryConfig(step_guard=False), grad_hook=poison)
    assert any(not bool(jnp.isfinite(leaf).all())
               for leaf in jax.tree_util.tree_leaves(unguarded["params"]))


@pytest.mark.fault
def test_train_resilient_dense_fallback():
    """Unrecoverable repeated corruption (no resend) flips the dense
    fallback after N consecutive corrupted steps; training then
    proceeds on the plain mean with finite losses."""
    runner = ToyRunner()
    scen = Scenario(name="bad", n_workers=4,
                    corruption=CorruptionSpec(prob=1.0, seed=4))
    res = train_resilient(
        runner, scen, _comp(), steps=6, seed=4,
        recovery=RecoveryConfig(resend=False, dense_fallback_after=2))
    assert res["fallback_active"]
    assert res["counters"]["resil/corrupt_detected"] > 0
    assert all(np.isfinite(res["losses"]))


@pytest.mark.fault
def test_train_resilient_partial_participation():
    runner = ToyRunner()
    scen = Scenario(name="strag", n_workers=4,
                    straggler=StragglerSpec(prob=0.5, delay_us=1e6,
                                            seed=13))
    res = train_resilient(
        runner, scen, _comp(), steps=6, seed=5,
        recovery=RecoveryConfig(straggler_timeout_us=10.0))
    assert all(np.isfinite(res["losses"]))


# ==========================================================================
# 10. engine step-guard + launcher resume
# ==========================================================================

@pytest.mark.fault
def test_engine_step_guard_clean_step_identical():
    """step_guard on a finite step: same params bitwise, skipped == 0."""
    from repro.configs.registry import get_smoke
    from repro.launch.engine import Engine
    from repro.launch.mesh import make_host_mesh

    cfg = get_smoke("mamba2-1.3b")
    mesh = make_host_mesh(1, 1)
    comp = CompressionConfig(qw=make_compressor("topk", ratio=0.25),
                             granularity=Granularity("layerwise"))
    from repro.data import lm_batches
    batch = next(lm_batches(cfg.vocab, 4, 16, seed=0))
    with mesh:
        eng = Engine(cfg, mesh, comp=comp)
        params, opt_state = eng.init_state(0)
        plain = eng.build_train_step()
        p1, o1, m1 = plain(params, opt_state, batch, jnp.int32(0))
        params, opt_state = eng.init_state(0)
        guarded = eng.build_train_step(step_guard=True)
        p2, o2, m2 = guarded(params, opt_state, batch, jnp.int32(0))
    assert float(m2["skipped"]) == 0.0
    assert "skipped" not in m1
    _assert_trees_bitwise(p1, p2, "step-guard-clean")
    assert float(m1["loss"]) == float(m2["loss"])


@pytest.mark.fault
@pytest.mark.slow
def test_train_launcher_resume_bitwise(tmp_path):
    """launch.train --resume replays to the checkpoint step and lands on
    the uninterrupted run's state bitwise (compared through the step-6
    checkpoints both runs write)."""
    from repro.launch.train import main

    base = ["--arch", "mamba2-1.3b", "--smoke", "--steps", "6",
            "--batch", "4", "--seq", "16", "--compressor", "topk",
            "--ratio", "0.25", "--step-guard", "--ckpt-every", "3"]
    a, c = str(tmp_path / "a"), str(tmp_path / "c")
    assert main(base + ["--ckpt-dir", a]) == 0
    os.makedirs(c)
    import shutil
    shutil.copy(os.path.join(a, "ckpt_00000003_s0.npz"), c)
    assert main(base + ["--ckpt-dir", c, "--resume"]) == 0
    with np.load(os.path.join(a, "ckpt_00000006_s0.npz"),
                 allow_pickle=False) as za, \
            np.load(os.path.join(c, "ckpt_00000006_s0.npz"),
                    allow_pickle=False) as zc:
        for k in za.files:
            if k == "__meta__":
                continue
            assert np.array_equal(np.asarray(za[k]), np.asarray(zc[k])), k


# ==========================================================================
# 11. multi-device ring-hop checks (subprocess)
# ==========================================================================

@pytest.mark.fault
@pytest.mark.timeout(1200)
def test_resil_multidevice_checks():
    """Drives tests/fault_checks.py on 4 virtual devices: bit flips and
    dropped hops on a REAL ring are detected and resend recovers the
    clean bits; a duplicated (stale) hop passes the checksum — the
    documented sequence-number gap."""
    script = os.path.join(os.path.dirname(__file__), "fault_checks.py")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, script], capture_output=True,
                         text=True, env=env, timeout=1200)
    sys.stdout.write(res.stdout[-4000:])
    sys.stderr.write(res.stderr[-4000:])
    assert res.returncode == 0, "fault checks failed"
    assert "ALL FAULT CHECKS PASSED" in res.stdout
