"""Serve-path smoke tests (previously untested by tier-1): the serving
launcher CLI, the batched-serving example, and the packed-request wire
round-trip through a real decode step — so wire-format changes can never
break serving invisibly again."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

pytestmark = pytest.mark.slow

from repro.launch.serve import main as serve_main, pack_request, \
    unpack_request  # noqa: E402

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_packed_request_roundtrips_through_serve_step():
    """One decode request, packed to a uint8 buffer and unpacked on the
    other side, produces bit-identical logits to the unpacked request."""
    from repro.launch.engine import Engine
    from repro.launch.mesh import make_host_mesh
    from repro.models import ModelConfig
    from repro.models.config import InputShape
    cfg = ModelConfig(name="serve-wire", arch_type="dense", n_layers=2,
                      d_model=64, vocab=256, n_heads=4, n_kv_heads=2,
                      d_head=16, d_ff=128, dtype="float32")
    B, PROMPT, CACHE = 2, 4, 8
    eng = Engine(cfg, make_host_mesh(1, 1))
    params, _ = eng.init_state(seed=0)
    serve = eng.build_serve_step(InputShape("d", CACHE, B, "decode"))
    prefill = eng.build_prefill(InputShape("p", PROMPT, B, "prefill"),
                                cache_len=CACHE)
    prompts = jax.random.randint(jax.random.key(0), (B, PROMPT), 0,
                                 cfg.vocab)
    with eng.mesh:
        logits, cache = prefill(params, {"tokens": prompts})
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        # the packed request IS the wire: uint8 in, request out
        buf = pack_request(tok, jnp.int32(PROMPT))
        assert buf.dtype == jnp.uint8 and buf.size == 4 * (2 + B)
        req = unpack_request(buf)
        assert bool((req["token"] == tok).all())
        assert int(req["pos"]) == PROMPT
        lg_packed, _ = serve(params, req, cache)
        # the serve step donates its cache — re-prefill (deterministic)
        # for the direct-request reference
        _, cache2 = prefill(params, {"tokens": prompts})
        lg_direct, _ = serve(params, {"token": tok,
                                      "pos": jnp.int32(PROMPT)}, cache2)
    assert bool((lg_packed == lg_direct).all())
    assert not bool(jnp.isnan(lg_packed).any())


def test_serve_cli_smoke(capsys):
    """launch/serve.py end to end on a 1-device mesh (smoke config):
    prefill + packed-request decode loop, sane output."""
    rc = serve_main(["--arch", "granite-20b", "--smoke", "--batch", "2",
                     "--prompt", "4", "--gen", "3"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "prefill(4 tok)" in out
    assert "sample continuation:" in out


def test_serve_batched_example_runs():
    """examples/serve_batched.py runs to completion (its own 8-device
    host mesh, seq-sharded KV cache) and reports the serve summary."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    env.pop("XLA_FLAGS", None)  # the example sets its own device count
    proc = subprocess.run(
        [sys.executable, os.path.join(_REPO, "examples",
                                      "serve_batched.py")],
        capture_output=True, text=True, env=env, timeout=540)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "served 8 sequences" in proc.stdout
