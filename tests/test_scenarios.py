"""SimCluster: the fault-injection differential suite.

The contract (ISSUE: "every scenario knob at its identity setting is
bit-identical to the un-wrapped path"):

  1. IDENTITY: `SimCluster.aggregate` under an identity scenario (no
     links, zero-delay stragglers, n->n rescales, IID data) returns the
     SAME bits as the bare `aggregate_simulated_workers` — held across
     the six-codec zoo, both granularities, error feedback and the wire
     path. By construction aggregate is a pass-through; this suite pins
     that construction as a regression contract.
  2. ELASTIC EF CONSERVATION: re-bucketing EF residuals 4 -> 2 -> 4
     through a real ckpt/ round-trip conserves residual mass exactly
     (integer-valued residuals => exact fp sums), and a rescale to the
     CURRENT world size is bit-identical (the ckpt round-trip itself is
     lossless).
  3. HAND-COMPUTED ACCOUNTING: straggler delays and heterogeneous link
     alpha/beta feed `simulate_schedule` exactly as the closed-form
     single-message model predicts — exposed = alpha + bits/(8*gbps*1e3)
     per worker, step exposure = max over workers, delays charged on top.
  4. NON-IID DETERMINISM: Dirichlet shard skew is a pure function of the
     key — same key, same shards, bit for bit.

The full codec-zoo sweep carries the `scenario` marker (tier-1 only;
`make verify-fast` keeps the unmarked smoke subset).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CompressionConfig, Granularity,
                        aggregate_simulated_workers, build_plan,
                        make_compressor, simulate_schedule, build_schedule,
                        stacked_mask)
from repro.data import (dirichlet_proportions, noniid_classification_batch,
                        noniid_markov_lm_batch, make_markov)
from repro.sim import (LinkSpec, RescaleEvent, Scenario, SimCluster,
                       StragglerSpec, init_ef)

KEY = jax.random.key(0)

SIX = [
    ("topk", {"ratio": 0.25}),
    ("randomk", {"ratio": 0.3, "scale": True}),
    ("qsgd", {"levels": 16}),
    ("terngrad", {}),
    ("signsgd", {}),
    ("natural", {}),
]

GRANS = [Granularity("layerwise"), Granularity("entire_model")]

#: every knob present, every knob at its identity setting — the hostile
#: shape of the spec with the clean semantics of the default.
IDENTITY = Scenario(
    name="identity", n_workers=4,
    straggler=StragglerSpec(prob=0.5, delay_us=0.0, seed=11),
    rescales=(RescaleEvent(step=3, world_size=4),))


def _tree(key=KEY):
    ks = [jax.random.fold_in(key, i) for i in range(5)]
    return {"blocks": {"w": jax.random.normal(ks[0], (3, 16, 8)),
                       "b": jax.random.normal(ks[1], (3, 8))},
            "embed": jax.random.normal(ks[2], (20, 4)),
            "head": jax.random.normal(ks[3], (4, 2)),
            "scalar_gain": jax.random.normal(ks[4], ())}


def _worker_grads(n=4, key=KEY):
    """Per-worker gradient stack: leading worker axis, distinct draws."""
    trees = [_tree(jax.random.fold_in(key, 100 + i)) for i in range(n)]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def _assert_trees_bitwise(a, b, ctx):
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        assert la.shape == lb.shape and la.dtype == lb.dtype, ctx
        assert bool((la == lb).all()), (
            ctx, float(jnp.max(jnp.abs(la - lb))))


def _identity_case(name, kw, gran, wire):
    grads = _worker_grads()
    stacked = stacked_mask(_tree())
    cfg = CompressionConfig(qw=make_compressor(name, **kw),
                            granularity=gran, error_feedback=True)
    ef = init_ef(_tree(), 4)
    cluster = SimCluster(IDENTITY, cfg)
    assert IDENTITY.is_identity()
    got = cluster.aggregate(grads, stacked, KEY, ef_state=ef, wire=wire)
    want = aggregate_simulated_workers(grads, stacked, cfg, KEY,
                                       ef_state=ef, wire=wire)
    ctx = (name, gran.kind, wire)
    _assert_trees_bitwise(got[0], want[0], ctx)
    _assert_trees_bitwise(got[1], want[1], ctx)


def test_identity_scenario_smoke():
    """Inner-loop subset: topk + EF + wire at both granularities."""
    for gran in GRANS:
        _identity_case("topk", {"ratio": 0.25}, gran, wire=True)


@pytest.mark.scenario
@pytest.mark.parametrize("gran", GRANS, ids=lambda g: g.kind)
@pytest.mark.parametrize("name,kw", SIX, ids=[n for n, _ in SIX])
@pytest.mark.parametrize("wire", [False, True], ids=["sim", "wire"])
def test_identity_scenario_bitwise_zoo(name, kw, gran, wire):
    _identity_case(name, kw, gran, wire)


# ==========================================================================
# elastic world size: EF re-bucketing through ckpt/
# ==========================================================================

def _int_ef(n=4):
    """Integer-valued residuals: fp addition on small ints is exact, so
    conservation sums are EQUALITY checks, not tolerances."""
    tree = _tree()
    i = [0]

    def fill(p):
        leaf = jnp.arange(n * p.size, dtype=jnp.float32) % 13.0 + i[0]
        i[0] += 1
        return leaf.reshape((n,) + p.shape)
    return jax.tree_util.tree_map(fill, tree)


def test_rescale_to_same_size_is_bitwise_noop(tmp_path):
    cfg = CompressionConfig(qw=make_compressor("topk", ratio=0.25),
                            error_feedback=True)
    cluster = SimCluster(IDENTITY, cfg, ckpt_dir=str(tmp_path))
    ef = jax.tree_util.tree_map(
        lambda p: jax.random.normal(jax.random.fold_in(KEY, 7),
                                    (4,) + p.shape), _tree())
    back = cluster.rescale_ef(ef, 4, step=0)
    _assert_trees_bitwise(back, ef, "n->n rescale through ckpt")


def test_ef_conservation_4_2_4(tmp_path):
    cfg = CompressionConfig(qw=make_compressor("topk", ratio=0.25),
                            error_feedback=True)
    sc = Scenario(name="elastic", n_workers=4,
                  rescales=(RescaleEvent(step=10, world_size=2),
                            RescaleEvent(step=20, world_size=4)))
    cluster = SimCluster(sc, cfg, ckpt_dir=str(tmp_path))
    ef4 = _int_ef(4)

    n, ef2, changed = cluster.maybe_rescale(10, ef4)
    assert (n, changed) == (2, True)
    for l4, l2 in zip(jax.tree_util.tree_leaves(ef4),
                      jax.tree_util.tree_leaves(ef2)):
        assert l2.shape[0] == 2
        # worker i folds into slot i % 2 — exact on integer residuals
        assert bool((l2[0] == l4[0] + l4[2]).all())
        assert bool((l2[1] == l4[1] + l4[3]).all())
        assert bool((l2.sum(0) == l4.sum(0)).all())  # mass conserved

    n, ef4b, changed = cluster.maybe_rescale(20, ef2)
    assert (n, changed) == (4, True)
    for l2, l4b in zip(jax.tree_util.tree_leaves(ef2),
                       jax.tree_util.tree_leaves(ef4b)):
        assert l4b.shape[0] == 4
        assert bool((l4b[:2] == l2).all())      # survivors keep rows
        assert bool((l4b[2:] == 0.0).all())     # joiners start at zero
        assert bool((l4b.sum(0) == l2.sum(0)).all())


def test_maybe_rescale_quiet_between_events(tmp_path):
    cfg = CompressionConfig(qw=make_compressor("topk", ratio=0.25))
    sc = Scenario(name="elastic", n_workers=4,
                  rescales=(RescaleEvent(step=10, world_size=2),))
    cluster = SimCluster(sc, cfg, ckpt_dir=str(tmp_path))
    ef = _int_ef(4)
    for step in (0, 5, 9, 11, 15):  # no event due => untouched object
        n, out, changed = cluster.maybe_rescale(step, ef)
        assert not changed and out is ef
        assert n == (4 if step < 10 else 2)
    assert sc.world_size_at(9) == 4
    assert sc.world_size_at(10) == 2
    assert sc.world_size_at(999) == 2


def test_identity_rescale_event_does_not_touch_state(tmp_path):
    cfg = CompressionConfig(qw=make_compressor("topk", ratio=0.25))
    cluster = SimCluster(IDENTITY, cfg, ckpt_dir=str(tmp_path))
    ef = _int_ef(4)
    n, out, changed = cluster.maybe_rescale(3, ef)  # event due, n->n
    assert (n, changed) == (4, False) and out is ef


# ==========================================================================
# straggler + heterogeneous-link accounting: hand-computed
# ==========================================================================

def _single_message_plan():
    """One leaf, entire-model granularity: exactly one bucket, one
    message — the closed-form case of the alpha-beta model."""
    tree = {"w": jnp.zeros((64,), jnp.float32)}
    return build_plan(tree, stacked_mask(tree), Granularity("entire_model"))


def _expected_exposed(bits, alpha_us, gbps):
    """Single message: exposed = send time = alpha + bits/(8*gbps*1e3),
    regardless of backward_us (one message can never overlap itself)."""
    return alpha_us + (bits / 8.0) / (gbps * 1e3)


def test_straggler_accounting_hand_computed():
    qw = make_compressor("topk", ratio=0.25)
    plan = _single_message_plan()
    bits = qw.payload_bits(64)  # one bucket, n=1
    sc = Scenario(name="straggle", n_workers=3,
                  straggler=StragglerSpec(prob=1.0, delay_us=5000.0, seed=3))
    cluster = SimCluster(sc, CompressionConfig(qw=qw))
    entry = cluster.step_accounting(0, plan, backward_us=200.0)

    model = _expected_exposed(bits, 50.0, 12.5)  # default link
    assert entry["straggler_hits"] == 3
    assert entry["world_size"] == 3
    for w in entry["workers"]:
        assert w["straggler_delay_us"] == 5000.0
        assert w["model_exposed_us"] == pytest.approx(model, abs=1e-3)
        assert w["exposed_us"] == pytest.approx(model + 5000.0, abs=1e-3)
    assert entry["exposed_comm_us"] == pytest.approx(model + 5000.0,
                                                     abs=1e-3)
    assert cluster.exposed_comm_total_us() == entry["exposed_comm_us"]

    # pure function of (seed, step): replaying the step replays the draws
    again = SimCluster(sc, CompressionConfig(qw=qw))
    assert (again.step_accounting(0, plan, backward_us=200.0)["workers"]
            == entry["workers"])


def test_zero_prob_straggler_draws_exact_zeros():
    s = StragglerSpec(prob=0.0, delay_us=1e9, seed=1)
    assert (s.draws(0, 8) == 0.0).all()
    s = StragglerSpec(prob=1.0, delay_us=0.0, seed=1)
    assert (s.draws(0, 8) == 0.0).all()


def test_hetero_link_accounting_hand_computed():
    qw = make_compressor("topk", ratio=0.25)
    plan = _single_message_plan()
    bits = qw.payload_bits(64)
    links = (LinkSpec(alpha_us=20.0, gbps=25.0),
             LinkSpec(alpha_us=400.0, gbps=1.25))
    sc = Scenario(name="hetero", n_workers=2, links=links)
    cluster = SimCluster(sc, CompressionConfig(qw=qw))
    entry = cluster.step_accounting(0, plan, backward_us=200.0)

    fast = _expected_exposed(bits, 20.0, 25.0)
    slow = _expected_exposed(bits, 400.0, 1.25)
    got = {w["worker"]: w for w in entry["workers"]}
    assert got[0]["model_exposed_us"] == pytest.approx(fast, abs=1e-3)
    assert got[1]["model_exposed_us"] == pytest.approx(slow, abs=1e-3)
    # synchronous allreduce: the step waits for the slowest worker
    assert entry["exposed_comm_us"] == pytest.approx(slow, abs=1e-3)


def test_link_cycling_covers_elastic_growth():
    links = (LinkSpec(10.0, 10.0), LinkSpec(20.0, 20.0))
    sc = Scenario(name="cyc", n_workers=5, links=links)
    assert sc.link(0) == links[0] and sc.link(1) == links[1]
    assert sc.link(4) == links[0]  # cycles beyond len(links)
    assert Scenario(name="plain").link(3) == LinkSpec()


def test_per_link_fusion_policy_fuses_on_high_alpha_links():
    """A high-latency link should carry fewer (more fused) messages than
    a zero-latency link under the same layerwise plan — the per-link
    FusionPolicy decision the accounting prices."""
    qw = make_compressor("topk", ratio=0.25)
    tree = _tree()
    plan = build_plan(tree, stacked_mask(tree), Granularity("layerwise"))
    sc = Scenario(name="fuse", n_workers=2,
                  links=(LinkSpec(alpha_us=0.0, gbps=12.5),
                         LinkSpec(alpha_us=5000.0, gbps=12.5)))
    cluster = SimCluster(sc, CompressionConfig(qw=qw))
    entry = cluster.step_accounting(0, plan)
    got = {w["worker"]: w for w in entry["workers"]}
    assert got[1]["n_messages"] <= got[0]["n_messages"]
    assert got[1]["n_messages"] < len(plan.buckets) or \
        got[0]["n_messages"] == len(plan.buckets)


# ==========================================================================
# non-IID shards: deterministic, skewed, well-formed
# ==========================================================================

def test_dirichlet_proportions_deterministic_and_stochastic():
    key = jax.random.key(42)
    p1 = dirichlet_proportions(key, 4, 10, alpha=0.1)
    p2 = dirichlet_proportions(key, 4, 10, alpha=0.1)
    assert p1.shape == (4, 10)
    assert bool((p1 == p2).all())  # pure function of the key
    assert np.allclose(np.asarray(p1).sum(1), 1.0, atol=1e-5)
    # hostile alpha => concentrated shards: every worker's modal class
    # holds far more than the uniform 1/10 share
    assert float(np.asarray(p1).max(axis=1).min()) > 0.3
    # workers differ (independent draws)
    assert not bool((p1[0] == p1[1]).all())


def test_noniid_classification_batch_deterministic_and_skewed():
    key = jax.random.key(7)
    props = dirichlet_proportions(key, 4, 10, alpha=0.05)
    b1 = noniid_classification_batch(jax.random.fold_in(key, 1), props, 32)
    b2 = noniid_classification_batch(jax.random.fold_in(key, 1), props, 32)
    _assert_trees_bitwise(b1, b2, "noniid classification determinism")
    assert b1["images"].shape == (4, 32, 32, 32, 3)
    assert b1["labels"].shape == (4, 32)
    labels = np.asarray(b1["labels"])
    assert labels.min() >= 0 and labels.max() < 10
    # at alpha=0.05 each worker's modal class dominates its shard
    for w in range(4):
        _, counts = np.unique(labels[w], return_counts=True)
        assert counts.max() / 32 > 0.5


def test_noniid_lm_batch_deterministic():
    key = jax.random.key(9)
    trans = make_markov(vocab=32, seed=0)
    props = dirichlet_proportions(key, 4, 32, alpha=0.1)
    b1 = noniid_markov_lm_batch(jax.random.fold_in(key, 2), trans, props,
                                8, 16)
    b2 = noniid_markov_lm_batch(jax.random.fold_in(key, 2), trans, props,
                                8, 16)
    _assert_trees_bitwise(b1, b2, "noniid lm determinism")
    assert b1["tokens"].shape == (4, 8, 16)
    assert bool((b1["targets"][:, :, :-1] == b1["tokens"][:, :, 1:]).all())


# ==========================================================================
# Scenario spec: hashable value object, validated
# ==========================================================================

def test_scenario_hashable_value_object():
    a = Scenario(name="x", n_workers=4,
                 links=(LinkSpec(10.0, 5.0),),
                 straggler=StragglerSpec(0.5, 100.0, 3),
                 rescales=(RescaleEvent(5, 2),), dirichlet_alpha=0.3)
    b = Scenario(name="x", n_workers=4,
                 links=(LinkSpec(10.0, 5.0),),
                 straggler=StragglerSpec(0.5, 100.0, 3),
                 rescales=(RescaleEvent(5, 2),), dirichlet_alpha=0.3)
    assert a == b and hash(a) == hash(b)
    assert not a.is_identity()
    assert "straggle" in a.describe() and "rescale" in a.describe()
    assert Scenario().is_identity()


@pytest.mark.parametrize("bad", [
    lambda: Scenario(n_workers=0),
    lambda: Scenario(dirichlet_alpha=0.0),
    lambda: Scenario(rescales=(RescaleEvent(10, 2), RescaleEvent(5, 4))),
    lambda: LinkSpec(alpha_us=-1.0),
    lambda: LinkSpec(gbps=0.0),
    lambda: StragglerSpec(prob=1.5),
    lambda: StragglerSpec(delay_us=-1.0),
    lambda: RescaleEvent(step=-1, world_size=2),
    lambda: RescaleEvent(step=0, world_size=0),
])
def test_scenario_validation(bad):
    with pytest.raises(ValueError):
        bad()
