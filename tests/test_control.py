"""Adaptive compression controller: telemetry, policies, decision cache.

Load-bearing properties:
  * Controller + StaticPolicy is BIT-FOR-BIT the plain Engine path.
  * The decision -> compiled-step cache never retraces a revisited
    decision, and a fresh decision matches a from-scratch Engine.
  * GranularitySwitchPolicy switches to entire-model on a workload whose
    measured omegas favor it (the paper's "framework should choose").
  * VarianceBudgetPolicy is monotone: tighter budget => never fewer bits.
  * Telemetry payload-bit accounting agrees with bits.comm_report.
"""
import json

import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.control import (BitBudgetPolicy, CompressionDecision, Controller,
                           GranularitySwitchPolicy, PerDimRatio,
                           StaticPolicy, VarianceBudgetPolicy, accumulate,
                           init_telemetry, make_policy, measure,
                           measurement_plan, payload_bits_per_step,
                           summarize, unit_omegas)
from repro.core import (CompressionConfig, Granularity, Identity,
                        aggregate_simulated_workers, comm_report,
                        make_compressor, stacked_mask)
from repro.core.theory import noise_bounds_from_plan

KEY = jax.random.key(0)


def _tree(key=KEY):
    ks = [jax.random.fold_in(key, i) for i in range(3)]
    return {"blocks": {"w": jax.random.normal(ks[0], (3, 16, 8))},
            "embed": jax.random.normal(ks[1], (20, 4)),
            "head": jax.random.normal(ks[2], (16, 4))}


def _summary(qw, tree=None, ratio_cfg=None):
    t = tree if tree is not None else _tree()
    sm = stacked_mask(t)
    mplan = measurement_plan(t, sm)
    inc = measure(mplan, qw, t, KEY)
    return summarize(accumulate(init_telemetry(mplan), inc), mplan, qw=qw), \
        mplan


# ---------------------------------------------------------------------------
# telemetry
# ---------------------------------------------------------------------------

def test_telemetry_identity_is_lossless():
    s, mplan = _summary(Identity())
    assert s["steps"] == 1.0
    for b in s["buckets"]:
        assert abs(b["omega_hat"]) < 1e-5
        assert b["rel_err"] < 1e-10
    assert s["entire_model"]["rel_err"] < 1e-10
    json.dumps(s)  # exportable


def test_telemetry_accumulates_and_jits():
    t = _tree()
    sm = stacked_mask(t)
    mplan = measurement_plan(t, sm)
    qw = make_compressor("qsgd", levels=8)
    inc_fn = jax.jit(lambda g, k: measure(mplan, qw, g, k))
    st_ = init_telemetry(mplan)
    for i in range(3):
        st_ = accumulate(st_, inc_fn(t, jax.random.fold_in(KEY, i)))
    s = summarize(st_, mplan, qw=qw)
    assert s["steps"] == 3.0
    for b in s["buckets"]:
        assert b["grad_var"] >= 0.0
        assert b["grad_norm_sq"] > 0.0


def test_telemetry_entire_model_leg_is_gated():
    """entire_model=False skips the flat counterfactual: em_* stay zero,
    summarize omits the entire_model block, and GranularitySwitchPolicy
    falls back to the current decision instead of misreading zeros."""
    t = _tree()
    sm = stacked_mask(t)
    mplan = measurement_plan(t, sm)
    qw = make_compressor("topk", ratio=0.1)
    inc = measure(mplan, qw, t, KEY, entire_model=False)
    assert float(inc.em_sumsq) == 0.0 and float(inc.em_errsq) == 0.0
    s = summarize(accumulate(init_telemetry(mplan), inc), mplan, qw=qw)
    assert not s.get("entire_model")
    base = CompressionDecision(qw=qw)
    assert GranularitySwitchPolicy().decide(s, base, mplan) == base
    assert VarianceBudgetPolicy().needs_entire_model is False
    assert BitBudgetPolicy().needs_entire_model is False
    assert GranularitySwitchPolicy().needs_entire_model is True


def test_telemetry_payload_bits_match_comm_report():
    """Telemetry's bucket-wise payload sum equals comm_report's per-unit
    walk — for a plain config AND a decision with per-bucket ratio
    overrides (the allgather uplink is exactly the payload)."""
    t = _tree()
    sm = stacked_mask(t)
    mplan = measurement_plan(t, sm)
    qw = make_compressor("topk", ratio=0.1)
    cfg = CompressionConfig(qw=qw, granularity=Granularity("layerwise"),
                            strategy="allgather")
    assert payload_bits_per_step(mplan, qw, measured=False) == \
        comm_report(cfg, mplan, 4).uplink_bits_per_worker
    # the measured (real packed bytes) legs agree the same way
    assert payload_bits_per_step(mplan, qw) == \
        comm_report(cfg, mplan, 4, measured=True).uplink_bits_per_worker

    dec = CompressionDecision(qw=qw, granularity=Granularity("layerwise"),
                              strategy="allgather",
                              ratio_overrides=((8, 0.5), (128, 0.02)))
    rep = comm_report(dec, mplan, 4)
    assert payload_bits_per_step(mplan, dec.to_config().qw,
                                 measured=False) == \
        rep.uplink_bits_per_worker
    assert rep.uplink_bits_per_worker != \
        comm_report(cfg, mplan, 4).uplink_bits_per_worker
    assert dec.payload_bits(mplan.unit_dims) == rep.uplink_bits_per_worker


def test_compressed_allreduce_telemetry_wiring():
    """The collective path also grows a TelemetryState increment (device
    mesh of 1, shard_map like the engine) without changing the output."""
    from jax.sharding import PartitionSpec as P
    from repro.core import compressed_allreduce
    from repro.launch.engine import shard_map
    from repro.launch.mesh import make_host_mesh
    t = _tree()
    sm = stacked_mask(t)
    mplan = measurement_plan(t, sm)
    cfg = CompressionConfig(qw=make_compressor("topk", ratio=0.25),
                            granularity=Granularity("layerwise"))
    mesh = make_host_mesh(1, 1)

    def plain(g):
        out, _ = compressed_allreduce(g, sm, cfg, ("data",), KEY, 1)
        return out

    def with_telem(g):
        out, _, inc = compressed_allreduce(g, sm, cfg, ("data",), KEY, 1,
                                           telemetry_plan=mplan)
        return out, inc

    a = jax.jit(shard_map(plain, mesh, in_specs=(P(),), out_specs=P()))(t)
    b, inc = jax.jit(shard_map(with_telem, mesh, in_specs=(P(),),
                               out_specs=(P(), P())))(t)
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        assert jnp.allclose(la, lb)
    assert float(inc.steps) == 1.0
    assert float(jnp.sum(inc.grad_sumsq)) > 0.0
    assert float(inc.em_sumsq) > 0.0


def test_aggregation_telemetry_wiring():
    """aggregate_simulated_workers grows a TelemetryState increment when
    given a telemetry_plan, without changing the aggregate."""
    t = _tree()
    sm = stacked_mask(t)
    mplan = measurement_plan(t, sm)
    wg = jax.tree_util.tree_map(
        lambda x: jnp.stack([x, 2.0 * x]), t)
    cfg = CompressionConfig(qw=make_compressor("qsgd", levels=16),
                            granularity=Granularity("layerwise"))
    a, _ = aggregate_simulated_workers(wg, sm, cfg, KEY)
    b, _, inc = aggregate_simulated_workers(wg, sm, cfg, KEY,
                                            telemetry_plan=mplan)
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        assert jnp.allclose(la, lb)
    assert float(inc.steps) == 1.0
    assert bool(jnp.all(jnp.isfinite(inc.grad_sumsq)))


# ---------------------------------------------------------------------------
# decisions
# ---------------------------------------------------------------------------

def test_decision_roundtrip_and_hashability():
    d = CompressionDecision(qw=make_compressor("topk", ratio=0.05),
                            granularity=Granularity("entire_model"),
                            ratio_overrides=((128, 0.5),))
    cfg = d.to_config()
    assert isinstance(cfg.qw, PerDimRatio)
    assert cfg.qw.for_dim(128).ratio == 0.5
    assert cfg.qw.for_dim(64).ratio == 0.05
    assert CompressionDecision.from_config(cfg) == d
    assert len({d, d}) == 1  # hashable cache key


def test_per_dim_ratio_compressor_semantics():
    base = make_compressor("topk", ratio=0.5)
    c = PerDimRatio(base=base, table=((8, 0.25),))
    x = jnp.arange(8.0)
    # dim 8 -> ratio 0.25 -> k=2 survivors
    assert int(jnp.sum(c.sim(x, KEY) != 0)) == 2
    y = jnp.arange(16.0) + 1.0
    # dim 16 -> base ratio 0.5 -> k=8 survivors
    assert int(jnp.sum(c.sim(y, KEY) != 0)) == 8
    # records are 32-bit value + ceil(log2(d))-bit index: 35 bits at
    # d=8 (k=2), 36 bits at d=16 (k=8)
    assert c.payload_bits(8) == 2 * 35 and c.payload_bits(16) == 8 * 36


def test_shared_random_decision_ignores_ratio_overrides():
    """shared_random needs the bare RandomK (isinstance check in
    CompressionConfig): a decision carrying overrides must still
    materialize, and the ratio policies decline to emit overrides for
    it in the first place."""
    from repro.core import RandomK
    qw = make_compressor("randomk", ratio=0.1)
    d = CompressionDecision(qw=qw, strategy="shared_random",
                            ratio_overrides=((128, 0.5),))
    assert isinstance(d.to_config().qw, RandomK)  # no PerDimRatio wrap
    summary, mplan = _summary(qw)
    base = CompressionDecision(qw=qw, strategy="shared_random")
    assert VarianceBudgetPolicy(budget=0.01).decide(
        summary, base, mplan) == base
    assert BitBudgetPolicy(bits_per_step=1 << 20).decide(
        summary, base, mplan) == base


def test_noise_bounds_from_plan_measured():
    t = _tree()
    mplan = measurement_plan(t, stacked_mask(t))
    n = mplan.num_units
    tr, em = noise_bounds_from_plan(mplan, measured_w=[0.5] * n)
    assert tr == pytest.approx(1.5 * mplan.total)
    assert em == pytest.approx(1.5 * mplan.total)
    with pytest.raises(ValueError):
        noise_bounds_from_plan(mplan, measured_w=[0.5] * (n + 1))
    with pytest.raises(ValueError):  # no closed form, no measurement
        noise_bounds_from_plan(mplan, make_compressor("signsgd"))


# ---------------------------------------------------------------------------
# policies
# ---------------------------------------------------------------------------

def _vb_bits(summary, mplan, base, budget):
    d = VarianceBudgetPolicy(budget=budget).decide(summary, base, mplan)
    return d.payload_bits(mplan.unit_dims)


def test_variance_budget_monotone_deterministic():
    qw = make_compressor("topk", ratio=0.1)
    base = CompressionDecision(qw=qw)
    summary, mplan = _summary(qw)
    prev = None
    for budget in (0.8, 0.4, 0.2, 0.1, 0.05, 0.01, 0.002):
        bits = _vb_bits(summary, mplan, base, budget)
        assert prev is None or bits >= prev, budget
        prev = bits


@settings(max_examples=25, deadline=None)
@given(st.floats(min_value=1e-4, max_value=1.0),
       st.floats(min_value=1e-4, max_value=1.0))
def test_property_variance_budget_monotone(b1, b2):
    """tighter budget => >= bits (any budget pair, either order)."""
    qw = make_compressor("topk", ratio=0.1)
    base = CompressionDecision(qw=qw)
    summary, mplan = _summary(qw)
    lo, hi = min(b1, b2), max(b1, b2)
    assert _vb_bits(summary, mplan, base, lo) >= \
        _vb_bits(summary, mplan, base, hi)


def test_bit_budget_policy_respects_budget():
    qw = make_compressor("topk", ratio=0.1)
    base = CompressionDecision(qw=qw)
    summary, mplan = _summary(qw)
    dims = mplan.unit_dims
    min_bits = BitBudgetPolicy(bits_per_step=0).decide(
        summary, base, mplan).payload_bits(dims)
    for budget in (min_bits, 4 * min_bits, 64 * min_bits):
        d = BitBudgetPolicy(bits_per_step=budget).decide(summary, base,
                                                         mplan)
        assert d.payload_bits(dims) <= budget
    # a looser budget never captures less
    loose = BitBudgetPolicy(bits_per_step=64 * min_bits).decide(
        summary, base, mplan)
    assert loose.payload_bits(dims) >= min_bits


def test_make_policy_factory():
    assert make_policy("static").name == "static"
    assert make_policy("variance_budget", budget=0.2).budget == 0.2
    with pytest.raises(ValueError):
        make_policy("nope")


# ---------------------------------------------------------------------------
# controller cache + granularity switching (simulated-worker harness)
# ---------------------------------------------------------------------------

def _sim_harness(tree, sm, mplan, collect=True):
    """build_step factory: a jitted Algorithm-1 aggregation step over
    fixed 2-worker gradients, threading telemetry."""
    def build(decision):
        cfg = decision.to_config()

        @jax.jit
        def step(wg, key, telem):
            if collect:
                out, _, inc = aggregate_simulated_workers(
                    wg, sm, cfg, key, telemetry_plan=mplan)
                return out, accumulate(telem, inc)
            out, _ = aggregate_simulated_workers(wg, sm, cfg, key)
            return out, telem
        return step
    return build


def _switch_tree(key=KEY):
    """Measured omegas favor entire-model: one leaf with its mass in a
    few spikes (global top-k captures it), one pure-noise leaf (per-layer
    top-k burns its budget on noise). Distinct sizes, so each leaf is its
    own size-class bucket (telemetry resolution is per size class)."""
    spiky = jnp.zeros((512,)).at[:8].set(100.0)
    noise = 0.1 * jax.random.normal(key, (448,))
    return {"spiky": spiky, "noise": noise}


def test_granularity_switch_policy_switches_and_reuses_cache():
    t = _switch_tree()
    sm = stacked_mask(t)
    mplan = measurement_plan(t, sm)
    base = CompressionDecision(qw=make_compressor("topk", ratio=0.1),
                               granularity=Granularity("layerwise"))
    ctrl = Controller(GranularitySwitchPolicy(margin=0.05),
                      _sim_harness(t, sm, mplan), base, mplan,
                      replan_every=2)
    wg = jax.tree_util.tree_map(lambda x: jnp.stack([x, x]), t)
    fns = []
    for i in range(6):
        fn = ctrl.step_fn()
        fns.append(fn)
        _, telem = fn(wg, jax.random.fold_in(KEY, i), ctrl.telemetry)
        ctrl.observe(telem, i)
    # the switch happened, to entire_model, at the first boundary
    assert ctrl.switches and ctrl.switches[0]["step"] == 1
    assert ctrl.decision.granularity.kind == "entire_model"
    # exactly two compiled steps ever built (layerwise + entire_model):
    # the post-switch steps reuse the cached compile, no retrace
    assert ctrl.builds == 2
    assert fns[2] is fns[3] is fns[4] is fns[5]
    # and the decision stays entire_model at later boundaries (its
    # measured trace really is smaller on this workload)
    s = ctrl.windows[-1]["summary"]
    em = s["entire_model"]
    lw_trace, _ = noise_bounds_from_plan(
        mplan, measured_w=unit_omegas(s, mplan))
    assert em["dim"] * (1.0 + em["rel_err"]) < lw_trace


def test_controller_same_decision_same_object_no_retrace():
    t = _tree()
    sm = stacked_mask(t)
    mplan = measurement_plan(t, sm)
    base = CompressionDecision(qw=make_compressor("qsgd", levels=16))
    alt = CompressionDecision(qw=make_compressor("qsgd", levels=16),
                              granularity=Granularity("entire_model"))
    ctrl = Controller(StaticPolicy(), _sim_harness(t, sm, mplan, False),
                      base, mplan, replan_every=10,
                      collect_telemetry=False)
    f1 = ctrl.step_fn()
    assert ctrl.step_fn() is f1 and ctrl.builds == 1
    ctrl.set_decision(alt)
    f2 = ctrl.step_fn()
    assert f2 is not f1 and ctrl.builds == 2
    ctrl.set_decision(base)
    assert ctrl.step_fn() is f1 and ctrl.builds == 2  # cache hit, no build


def test_fusion_decision_revisit_hits_cache():
    """A decision that only changes `fusion_bytes` (the comm schedule's
    fusion threshold) is a distinct cache key the first time, but
    REVISITING a prior threshold must hit the compiled-step cache — no
    retrace. And every scheduled step stays bit-identical to the
    unscheduled base (scheduling never changes numerics)."""
    import dataclasses
    import math
    t = _tree()
    sm = stacked_mask(t)
    mplan = measurement_plan(t, sm)
    base = CompressionDecision(qw=make_compressor("topk", ratio=0.25))
    a = dataclasses.replace(base, fusion_bytes=4096.0)
    b = dataclasses.replace(base, fusion_bytes=math.inf)
    assert len({base, a, b}) == 3           # hashable, distinct keys
    ctrl = Controller(StaticPolicy(), _sim_harness(t, sm, mplan, False),
                      base, mplan, collect_telemetry=False)
    wg = jax.tree_util.tree_map(lambda x: jnp.stack([x, 2.0 * x]), t)
    f_base = ctrl.step_fn()
    out_base, _ = f_base(wg, KEY, None)
    assert ctrl.builds == 1
    ctrl.set_decision(a)
    f_a = ctrl.step_fn()
    assert f_a is not f_base and ctrl.builds == 2
    ctrl.set_decision(b)
    f_b = ctrl.step_fn()
    assert f_b is not f_a and ctrl.builds == 3
    ctrl.set_decision(a)                     # revisit: cache hit
    assert ctrl.step_fn() is f_a and ctrl.builds == 3
    ctrl.set_decision(base)                  # and back to unscheduled
    assert ctrl.step_fn() is f_base and ctrl.builds == 3
    for fn in (f_a, f_b):
        out, _ = fn(wg, KEY, None)
        for la, lb in zip(jax.tree_util.tree_leaves(out_base),
                          jax.tree_util.tree_leaves(out)):
            assert bool((la == lb).all())


def test_fusion_policy_picks_threshold_from_model():
    """FusionPolicy prices the telemetry window's payload bits through
    the alpha-beta pipeline model: a latency-dominated link fuses
    everything into one message, a zero-latency link streams per bucket;
    non-layerwise decisions pass through untouched."""
    from repro.control import FusionPolicy
    from repro.core import build_schedule
    qw = make_compressor("topk", ratio=0.1)
    summary, mplan = _summary(qw)
    base = CompressionDecision(qw=qw)
    hi = FusionPolicy(alpha_us=1e5).decide(summary, base, mplan)
    assert hi.fusion_bytes is not None
    assert build_schedule(mplan, hi.fusion_bytes).num_messages == 1
    lo = FusionPolicy(alpha_us=0.0).decide(summary, base, mplan)
    assert lo.fusion_bytes == 0.0            # per-bucket streaming
    # pure: same window, same decision -> same result (and a revisit of
    # the emitted decision would be a cache hit, per the test above)
    assert FusionPolicy(alpha_us=1e5).decide(summary, base, mplan) == hi
    em = CompressionDecision(qw=qw, granularity=Granularity("entire_model"))
    assert FusionPolicy().decide(summary, em, mplan) == em
    assert make_policy("fusion", alpha_us=3.0).alpha_us == 3.0


# ---------------------------------------------------------------------------
# engine integration: the acceptance regression
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def engine_setup():
    from repro.configs.registry import get_smoke
    from repro.launch.engine import Engine
    from repro.launch.mesh import make_host_mesh
    cfg = get_smoke("mamba2-1.3b")
    mesh = make_host_mesh(1, 1)
    comp = CompressionConfig(qw=make_compressor("qsgd", levels=16),
                             granularity=Granularity("layerwise"))
    eng = Engine(cfg, mesh, comp=comp)
    batch = {"tokens": jnp.ones((4, 16), jnp.int32) * 3,
             "targets": jnp.ones((4, 16), jnp.int32) * 5}
    return eng, comp, batch


def _run_steps(step_fn, eng, batch, n=2, telem=None):
    params, opt_state = eng.init_state(0)
    for i in range(n):
        if telem is not None:
            params, opt_state, m, telem = step_fn(params, opt_state, batch,
                                                  jnp.int32(i), telem)
        else:
            params, opt_state, m = step_fn(params, opt_state, batch,
                                           jnp.int32(i))
    return params, m


def test_static_controller_bit_identical_to_engine(engine_setup):
    """Acceptance: Controller + StaticPolicy == the plain Engine path,
    bit for bit."""
    from repro.control import engine_controller
    eng, comp, batch = engine_setup
    p_ref, m_ref = _run_steps(eng.build_train_step(), eng, batch)
    ctrl = engine_controller(eng, StaticPolicy())
    assert ctrl.decision == CompressionDecision.from_config(comp)
    p_ctl, m_ctl = _run_steps(ctrl.step_fn(), eng, batch)
    for a, b in zip(jax.tree_util.tree_leaves(p_ref),
                    jax.tree_util.tree_leaves(p_ctl)):
        assert bool((a == b).all())
    assert float(m_ref["loss"]) == float(m_ctl["loss"])


def test_new_decision_matches_fresh_engine(engine_setup):
    """A decision the controller compiles on the fly is numerically the
    Engine you would have built from scratch with that config."""
    from repro.control import engine_controller
    from repro.launch.engine import Engine
    eng, comp, batch = engine_setup
    alt = CompressionDecision(qw=make_compressor("topk", ratio=0.25),
                              granularity=Granularity("entire_model"))
    ctrl = engine_controller(eng, StaticPolicy(), collect_telemetry=False)
    ctrl.set_decision(alt)
    p_ctl, _ = _run_steps(ctrl.step_fn(), eng, batch)
    fresh = Engine(eng.cfg, eng.mesh, comp=alt.to_config())
    p_ref, _ = _run_steps(fresh.build_train_step(), fresh, batch)
    for a, b in zip(jax.tree_util.tree_leaves(p_ref),
                    jax.tree_util.tree_leaves(p_ctl)):
        assert jnp.allclose(a, b, atol=1e-6)


def test_engine_telemetry_step_threads_state(engine_setup):
    """The telemetry leg measures without disturbing training: finite
    stats, steps counted, loss still finite."""
    from repro.control import engine_controller
    eng, comp, batch = engine_setup
    ctrl = engine_controller(eng, GranularitySwitchPolicy(),
                             replan_every=2)
    params, opt_state = eng.init_state(0)
    for i in range(2):
        fn = ctrl.step_fn()
        params, opt_state, m, telem = fn(params, opt_state, batch,
                                         jnp.int32(i), ctrl.telemetry)
        ctrl.observe(telem, i)
    assert jnp.isfinite(jnp.asarray(float(m["loss"])))
    assert len(ctrl.windows) == 1
    s = ctrl.windows[0]["summary"]
    assert s["steps"] == 2.0
    assert all(jnp.isfinite(jnp.asarray(b["omega_hat"]))
               for b in s["buckets"])
    json.dumps(ctrl.report())  # --telemetry-out payload is serializable


# ---------------------------------------------------------------------------
# AdaptiveKPolicy (Shi et al. 1911.08727: layer-wise adaptive-k)
# ---------------------------------------------------------------------------

def _energy_split_tree(key=KEY):
    """Two size-class buckets with a lopsided energy split: the (512,)
    leaf carries ~1e4x the gradient norm of the (448,) leaf."""
    hot = 10.0 * jax.random.normal(key, (512,))
    cold = 0.01 * jax.random.normal(jax.random.fold_in(key, 1), (448,))
    return {"hot": hot, "cold": cold}


def test_adaptive_k_policy_allocates_ratio_by_energy():
    from repro.control import AdaptiveKPolicy
    qw = make_compressor("topk", ratio=0.05)
    summary, mplan = _summary(qw, tree=_energy_split_tree())
    base = CompressionDecision(qw=qw)
    d = AdaptiveKPolicy(avg_ratio=0.05).decide(summary, base, mplan)
    ratios = dict(d.ratio_overrides)
    assert set(ratios) == {512, 448}
    assert ratios[512] > ratios[448]  # energy buys ratio
    # pure: same summary in, equal (hashable) decision out
    d2 = AdaptiveKPolicy(avg_ratio=0.05).decide(summary, base, mplan)
    assert d == d2 and hash(d) == hash(d2)
    # guards: no telemetry / ratio-less operator / shared_random
    assert AdaptiveKPolicy().decide({}, base, mplan) is base
    sign = CompressionDecision(qw=make_compressor("signsgd"))
    assert AdaptiveKPolicy().decide(summary, sign, mplan) is sign
    shared = CompressionDecision(qw=make_compressor("randomk", ratio=0.1),
                                 strategy="shared_random")
    assert AdaptiveKPolicy().decide(summary, shared, mplan) is shared


def test_adaptive_k_zero_energy_falls_back_to_flat_ratio():
    from repro.control import AdaptiveKPolicy
    qw = make_compressor("topk", ratio=0.05)
    summary, mplan = _summary(qw, tree=_energy_split_tree())
    dead = dict(summary)
    dead["buckets"] = [dict(b, grad_norm_sq=0.0) for b in summary["buckets"]]
    d = AdaptiveKPolicy(avg_ratio=0.05).decide(
        dead, CompressionDecision(qw=qw), mplan)
    assert all(r == 0.05 for _, r in d.ratio_overrides)


def test_adaptive_k_decision_revisit_hits_cache():
    """Revisiting an adaptive-k allocation (same summary => the SAME
    frozen decision) must hit the controller's compiled-step cache — the
    cache-no-retrace contract for the new policy."""
    from repro.control import AdaptiveKPolicy
    t = _energy_split_tree()
    sm = stacked_mask(t)
    mplan = measurement_plan(t, sm)
    qw = make_compressor("topk", ratio=0.05)
    summary, _ = _summary(qw, tree=t)
    base = CompressionDecision(qw=qw)
    policy = AdaptiveKPolicy(avg_ratio=0.05)
    d1 = policy.decide(summary, base, mplan)
    assert d1 != base
    ctrl = Controller(StaticPolicy(), _sim_harness(t, sm, mplan, False),
                      base, mplan, collect_telemetry=False)
    f_base = ctrl.step_fn()
    assert ctrl.builds == 1
    ctrl.set_decision(d1)
    f_d1 = ctrl.step_fn()
    assert f_d1 is not f_base and ctrl.builds == 2
    ctrl.set_decision(base)
    assert ctrl.step_fn() is f_base and ctrl.builds == 2
    ctrl.set_decision(policy.decide(summary, base, mplan))  # re-decided
    assert ctrl.step_fn() is f_d1 and ctrl.builds == 2      # no retrace


def test_adaptive_k_factory():
    p = make_policy("adaptive_k", avg_ratio=0.1)
    assert p.name == "adaptive_k" and p.avg_ratio == 0.1
    assert p.needs_telemetry and not p.needs_entire_model
