import os
import sys

# NOTE: do NOT set xla_force_host_platform_device_count here — smoke tests
# and benches must see 1 device (the dry-run sets 512 itself, in its own
# process). Distributed tests spawn subprocesses with their own XLA_FLAGS.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

try:  # hypothesis is optional: property tests skip when it is absent
    import hypothesis  # noqa: F401
except ImportError:
    from _hypothesis_stub import install as _install_hypothesis_stub
    _install_hypothesis_stub()
