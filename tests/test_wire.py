"""WireCodec: the differential suite locking accounting to the wire.

Two load-bearing properties:

  1. ROUND-TRIP: codec.decode(codec.encode(x, key)) is BIT-identical to
     compressor.sim(x, key) for every codec-bearing operator — so
     routing execution through materialized payloads never changes
     numerics (held over granularities, fusion thresholds, error
     feedback, the collective strategies and the engine step).
  2. ACCOUNTING == WIRE: 8 * len(packed payload) equals
     compressor.payload_bits(d) + the documented per-codec word-padding
     slack, EXACTLY, for all six compressors at both granularities —
     the analytic accounting can never silently drift from the bytes a
     deployment would put on the links again.

The full sweeps carry the `wire` marker (tier-1 only; `make verify-fast`
keeps the unmarked smoke subset).
"""
import math

import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (CompressionConfig, FUSE_ALL, Granularity,
                        aggregate_simulated_workers, build_plan,
                        build_schedule, comm_report, compressed_allreduce,
                        index_bits, make_compressor,
                        measured_bits_from_payloads, message_layouts,
                        stacked_mask, wire_codec, word_padding)
from repro.core.compressors import _k_of
from repro.core.wire import has_wire_codec

KEY = jax.random.key(0)

# the paper's six operators (ISSUE: "all six compressors"), one codec each
SIX = [
    ("topk", {"ratio": 0.25}),
    ("randomk", {"ratio": 0.3, "scale": True}),
    ("qsgd", {"levels": 16}),
    ("terngrad", {}),
    ("signsgd", {}),
    ("natural", {}),
]

GRANS = [Granularity("layerwise"), Granularity("entire_model")]

# ISSUE fusion matrix: per-bucket messages, 64 KiB buffers, one message
THRESHOLDS = (0.0, float(1 << 16), FUSE_ALL)


def _tree(key=KEY):
    """Mixed pytree: scan-stacked + loose leaves of several size classes
    (odd dims exercise word-boundary padding)."""
    ks = [jax.random.fold_in(key, i) for i in range(5)]
    return {"blocks": {"w": jax.random.normal(ks[0], (3, 16, 8)),
                       "b": jax.random.normal(ks[1], (3, 8))},
            "embed": jax.random.normal(ks[2], (20, 4)),
            "head": jax.random.normal(ks[3], (4, 2)),
            "scalar_gain": jax.random.normal(ks[4], ())}


def _assert_trees_bitwise(a, b, ctx):
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        assert la.shape == lb.shape and la.dtype == lb.dtype, ctx
        assert bool((la == lb).all()), (
            ctx, float(jnp.max(jnp.abs(la - lb))))


def _packed_leg_bits(name, kw, d):
    """The documented packed-leg width per codec (what word-padding
    rounds up): b-bit levels, 2-bit ternary, 1-bit signs, 9-bit natural
    codes, k * ceil(log2(d))-bit sparse indices."""
    if name == "qsgd":
        return max(2, math.ceil(math.log2(2 * kw["levels"] + 1))) * d
    if name == "terngrad":
        return 2 * d
    if name == "signsgd":
        return d
    if name == "natural":
        return 9 * d
    if name in ("topk", "randomk"):
        return _k_of(kw["ratio"], d) * index_bits(d)
    raise AssertionError(name)


# ---------------------------------------------------------------------------
# round-trip: decode(encode(x)) == sim(x), bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,kw", SIX + [("identity", {})])
def test_roundtrip_bitexact(name, kw):
    c = make_compressor(name, **kw)
    codec = wire_codec(c)
    for d in (1, 33, 777):  # word-aligned and word-straddling sizes
        x = jax.random.normal(jax.random.fold_in(KEY, d), (d,))
        payload = codec.encode(x, KEY)
        assert payload.dtype == jnp.uint8
        assert payload.shape == (codec.nbytes(d),)
        y = codec.decode(payload, d)
        _assert_trees_bitwise(y, c.sim(x, KEY), (name, d))


@settings(max_examples=30, deadline=None)
@given(st.integers(min_value=1, max_value=1025),
       st.integers(min_value=0, max_value=10_000),
       st.sampled_from([s[0] for s in SIX]),
       st.sampled_from([0.03, 0.25, 0.9]))
def test_property_roundtrip_bitexact(d, seed, name, ratio):
    """Random shapes (incl. odd sizes straddling uint32 word boundaries)
    and ratios: the packed wire round-trip is the simulated operator."""
    kw = {"ratio": ratio} if name in ("topk", "randomk") else {}
    c = make_compressor(name, **kw)
    codec = wire_codec(c)
    key = jax.random.key(seed)
    x = jax.random.normal(key, (d,)) * 3.0
    y = codec.decode(codec.encode(x, key), d)
    _assert_trees_bitwise(y, c.sim(x, key), (name, d, ratio))


def test_threshold_codecs_are_the_theory_practice_gap():
    """threshold_v / adaptive_threshold: the static wire format is
    capacity-bounded while sim is exact masking — the codec exists
    (round-tripping the compressor's own payload bit-exactly, i.e. the
    allgather wire), is flagged exact_sim=False, and the simulated-
    strategy wire path refuses it instead of silently changing numerics.
    """
    t = _tree()
    sm = stacked_mask(t)
    for name in ("threshold_v", "adaptive_threshold"):
        c = make_compressor(name)
        codec = wire_codec(c)
        assert codec.exact_sim is False
        x = jax.random.normal(KEY, (100,))
        y = codec.decode(codec.encode(x, KEY), 100)
        _assert_trees_bitwise(y, c.decode(c.encode(x, KEY), 100), name)
        cfg = CompressionConfig(qw=c, granularity=Granularity("layerwise"),
                                strategy="simulated")
        with pytest.raises(ValueError, match="capacity-bounded"):
            compressed_allreduce(t, sm, cfg, ("data",), KEY, 1, wire=True)
    assert has_wire_codec(make_compressor("topk"))
    from repro.core.compressors import Compressor
    assert not has_wire_codec(Compressor(name="mystery"))


# ---------------------------------------------------------------------------
# accounting == measured, exactly (modulo documented word padding)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,kw", SIX)
def test_accounted_vs_measured_per_unit(name, kw):
    """8 * len(packed payload) == payload_bits(d) + word_padding(packed
    leg bits), for word-aligned and straddling dims — the slack is never
    anything but the documented pad-to-uint32 rule (< 32 bits/leg)."""
    c = make_compressor(name, **kw)
    codec = wire_codec(c)
    for d in (1, 5, 31, 32, 33, 64, 100, 511, 512, 777):
        x = jax.random.normal(jax.random.fold_in(KEY, d), (d,))
        measured = measured_bits_from_payloads(codec.encode(x, KEY))
        slack = word_padding(_packed_leg_bits(name, kw, d))
        assert measured == c.payload_bits(d) + slack, (name, d)
        assert measured == codec.wire_bits(d), (name, d)
        assert codec.padding_bits(d) == slack < 32, (name, d)


def test_dense_codec_has_zero_padding():
    codec = wire_codec(make_compressor("identity"))
    for d in (1, 37, 512):
        assert codec.padding_bits(d) == 0
        assert codec.wire_bits(d) == 32 * d


def test_comm_report_measured_flag():
    """comm_report(measured=True) - comm_report() == the summed per-unit
    padding slack — the accounting and the wire agree exactly."""
    t = _tree()
    sm = stacked_mask(t)
    plan = build_plan(t, sm, Granularity("layerwise"))
    for name, kw in SIX:
        c = make_compressor(name, **kw)
        codec = wire_codec(c)
        cfg = CompressionConfig(qw=c, granularity=Granularity("layerwise"),
                                strategy="allgather")
        acct = comm_report(cfg, plan, 4)
        meas = comm_report(cfg, plan, 4, measured=True)
        slack = sum(codec.padding_bits(d) for d in plan.unit_dims)
        assert meas.uplink_bits_per_worker == \
            acct.uplink_bits_per_worker + slack, name
        assert meas.downlink_bits_per_worker == \
            acct.downlink_bits_per_worker + 3 * slack, name


# ---------------------------------------------------------------------------
# the differential suite: executed fused messages vs the accounting
# ---------------------------------------------------------------------------

def _check_differential(name, kw, gran, fb):
    t = _tree()
    sm = stacked_mask(t)
    c = make_compressor(name, **kw)
    codec = wire_codec(c)
    plan = build_plan(t, sm, gran)
    sched = build_schedule(plan, fb)

    # numerics: wire streaming == the unscheduled unpacked reference
    ref = plan.execute(lambda x, k: c.sim(x, k), t, KEY)
    got, bufs = sched.execute(None, t, KEY, wire=codec)
    _assert_trees_bitwise(ref, got, (name, gran.kind, fb))

    # wire truth: executed buffer bytes == static layouts == accounting
    layouts = message_layouts(sched, codec)
    assert len(bufs) == sched.num_messages
    for buf, lay in zip(bufs, layouts):
        assert buf.size == lay.total_nbytes
        # the header is readable back out of the buffer
        header = jax.lax.bitcast_convert_type(
            buf[:lay.header_nbytes].reshape(-1, 4), jnp.uint32)
        assert int(header[0]) == len(lay.bucket_ids)
        assert tuple(int(v) for v in header[1:]) == lay.offsets
    measured = measured_bits_from_payloads(bufs)
    header_bits = 8 * sum(l.header_nbytes for l in layouts)
    payload_bits = 8 * sum(l.payload_nbytes for l in layouts)
    assert measured == payload_bits + header_bits

    # accounted == measured payload, exactly (modulo documented padding)
    cfg = CompressionConfig(qw=c, granularity=gran, strategy="allgather")
    acct = comm_report(cfg, plan, 2).uplink_bits_per_worker
    slack = sum(codec.padding_bits(d) for d in plan.unit_dims)
    assert payload_bits == acct + slack, (name, gran.kind, fb)
    assert payload_bits == comm_report(
        cfg, plan, 2, measured=True).uplink_bits_per_worker


def test_differential_smoke():
    """Inner-loop subset of the full `wire`-marked sweep."""
    for name, kw in (("qsgd", {"levels": 16}), ("topk", {"ratio": 0.25})):
        for fb in (0.0, FUSE_ALL):
            _check_differential(name, kw, Granularity("layerwise"), fb)


@pytest.mark.wire
@pytest.mark.parametrize("name,kw", SIX)
def test_differential_full(name, kw):
    """The acceptance sweep: all six compressors x {layerwise,
    entire_model} x fusion {0, 64KiB, inf} — accounted payload bits ==
    measured packed bytes, and wire numerics == unpacked numerics,
    everywhere."""
    for gran in GRANS:
        for fb in THRESHOLDS:
            _check_differential(name, kw, gran, fb)


# ---------------------------------------------------------------------------
# wire execution == unpacked execution through the aggregation stack
# ---------------------------------------------------------------------------

def _run_ef_steps(name, kw, wire, fusion_bytes=None, steps=5):
    t = _tree()
    sm = stacked_mask(t)
    n = 2
    cfg = CompressionConfig(qw=make_compressor(name, **kw),
                            granularity=Granularity("layerwise"),
                            error_feedback=True,
                            fusion_bytes=fusion_bytes)
    ef = jax.tree_util.tree_map(
        lambda x: jnp.zeros((n,) + x.shape, jnp.float32), t)
    out = None
    for i in range(steps):
        wg = jax.tree_util.tree_map(
            lambda x: jnp.stack([x * (1.0 + 0.1 * i), -0.5 * x]), t)
        out, ef = aggregate_simulated_workers(
            wg, sm, cfg, jax.random.fold_in(KEY, i), ef_state=ef,
            wire=wire)
    return out, ef


def test_wire_matches_unpacked_ef_smoke():
    """5 steps of Algorithm 1 with error-feedback threading: the wire
    path's outputs AND residual memories stay bit-identical."""
    ref = _run_ef_steps("topk", {"ratio": 0.1}, wire=False)
    got = _run_ef_steps("topk", {"ratio": 0.1}, wire=True)
    _assert_trees_bitwise(ref, got, "ef-wire-smoke")


@pytest.mark.wire
@pytest.mark.parametrize("name,kw", SIX)
def test_wire_matches_unpacked_ef_full(name, kw):
    """All six compressors x 5 EF steps x {per-bucket, fused} wire
    messages: bit-identical to the unpacked path."""
    ref = _run_ef_steps(name, kw, wire=False)
    for fb in (None, FUSE_ALL):
        got = _run_ef_steps(name, kw, wire=True, fusion_bytes=fb)
        _assert_trees_bitwise(ref, got, (name, fb))


def test_collective_wire_paths_bit_identical():
    """compressed_allreduce inside shard_map: wire=True matches the
    unpacked path for BOTH strategies — under `allgather` the packed
    uint8 payload itself crosses the collective."""
    from jax.sharding import PartitionSpec as P
    from repro.launch.engine import shard_map
    from repro.launch.mesh import make_host_mesh
    t = _tree()
    sm = stacked_mask(t)
    mesh = make_host_mesh(1, 1)
    for strat in ("simulated", "allgather"):
        cfg = CompressionConfig(qw=make_compressor("qsgd", levels=16),
                                granularity=Granularity("layerwise"),
                                strategy=strat)

        def run(wire):
            def f(g):
                out, _ = compressed_allreduce(g, sm, cfg, ("data",), KEY,
                                              1, wire=wire)
                return out
            return jax.jit(shard_map(f, mesh, in_specs=(P(),),
                                     out_specs=P()))(t)

        _assert_trees_bitwise(run(False), run(True), strat)


def test_engine_wire_step_bit_identical():
    """Acceptance: the sharded train step with wire=True is bit-for-bit
    the unpacked step (real message buffers in the compiled graph)."""
    from repro.configs.registry import get_smoke
    from repro.launch.engine import Engine
    from repro.launch.mesh import make_host_mesh
    cfg = get_smoke("mamba2-1.3b")
    mesh = make_host_mesh(1, 1)
    comp = CompressionConfig(qw=make_compressor("qsgd", levels=16),
                             granularity=Granularity("layerwise"))
    eng = Engine(cfg, mesh, comp=comp)
    batch = {"tokens": jnp.ones((4, 16), jnp.int32) * 3,
             "targets": jnp.ones((4, 16), jnp.int32) * 5}

    def run(step_fn):
        params, opt_state = eng.init_state(0)
        for i in range(2):
            params, opt_state, m = step_fn(params, opt_state, batch,
                                           jnp.int32(i))
        return params, m

    p_ref, m_ref = run(eng.build_train_step())
    p_w, m_w = run(eng.build_train_step(wire=True))
    _assert_trees_bitwise(p_ref, p_w, "engine-wire")
    assert float(m_ref["loss"]) == float(m_w["loss"])


# ---------------------------------------------------------------------------
# codec specifics
# ---------------------------------------------------------------------------

def test_signsgd_majority_vote_on_packed_words():
    """The real signSGD aggregation protocol: majority vote computed on
    packed payloads (dense worker vectors never materialize on the
    master) equals the dense sign-of-sum, ties resolving to +1."""
    codec = wire_codec(make_compressor("signsgd"))
    d = 77
    for n in (2, 3, 5):  # even n exercises the tie
        xs = jax.random.normal(jax.random.fold_in(KEY, n), (n, d))
        payloads = jax.vmap(lambda x: codec.encode(x, KEY))(xs)
        assert payloads.shape == (n, codec.nbytes(d))
        maj = codec.decode(codec.majority_vote(payloads, d), d)
        signs = jnp.where(xs >= 0, 1.0, -1.0)
        dense = jnp.where(jnp.sum(signs, axis=0) >= 0, 1.0, -1.0)
        _assert_trees_bitwise(maj, dense, n)


def test_pallas_pack_kernels_match_oracle():
    """kernels/pack.py vs kernels/ref.py: bit-for-bit, both directions,
    and the ops wrappers' pallas/jnp paths agree on odd lengths."""
    from repro.kernels import ops
    from repro.kernels.pack import pack_bits_pallas, unpack_bits_pallas
    from repro.kernels.ref import pack_bits_ref, unpack_bits_ref
    bits = jax.random.bernoulli(KEY, 0.4, (16, 512)).astype(jnp.int32)
    w_ref = pack_bits_ref(bits)
    w_pal = pack_bits_pallas(bits, interpret=True)
    assert bool((w_ref == w_pal).all())
    assert bool((unpack_bits_pallas(w_pal, interpret=True) == bits).all())
    assert bool((unpack_bits_ref(w_ref) == bits).all())
    for n in (1, 31, 33, 777, 4096):
        flat = jax.random.bernoulli(jax.random.fold_in(KEY, n), 0.5,
                                    (n,)).astype(jnp.int32)
        a = ops.pack_words(flat, use_pallas=False)
        b = ops.pack_words(flat, use_pallas=True)
        assert a.shape == (-(-n // 32),) and bool((a == b).all()), n
        assert bool((ops.unpack_words(a, n, use_pallas=True) == flat).all())


def test_pallas_codec_entire_model():
    """A use_pallas codec through the 1-unit entire-model schedule (the
    non-vmapped hot path): still bit-identical to sim."""
    t = _tree()
    sm = stacked_mask(t)
    c = make_compressor("qsgd", levels=16)
    codec = wire_codec(c, use_pallas=True)
    plan = build_plan(t, sm, Granularity("entire_model"))
    sched = build_schedule(plan, 0.0)
    ref = plan.execute(lambda x, k: c.sim(x, k), t, KEY)
    got, bufs = sched.execute(None, t, KEY, wire=codec)
    _assert_trees_bitwise(ref, got, "pallas-codec")
    assert measured_bits_from_payloads(bufs) == \
        8 * message_layouts(sched, codec)[0].total_nbytes


def test_telemetry_wire_bits_leg():
    """summarize() reports both the accounted and the measured
    (wire_bits) payload legs; payload_bits_per_step defaults to the
    measured one and the two differ by exactly the padding slack."""
    from repro.control.telemetry import (measure, measurement_plan,
                                         payload_bits_per_step, summarize)
    t = _tree()
    sm = stacked_mask(t)
    mplan = measurement_plan(t, sm)
    qw = make_compressor("signsgd")
    codec = wire_codec(qw)
    inc = measure(mplan, qw, t, KEY)
    s = summarize(inc, mplan, qw=qw)
    slack = sum(b.n * codec.padding_bits(b.dim) for b in mplan.buckets)
    assert s["wire_bits_per_step"] == s["payload_bits_per_step"] + slack
    for e in s["buckets"]:
        assert e["wire_bits"] >= e["payload_bits"]
    assert payload_bits_per_step(mplan, qw) == s["wire_bits_per_step"]
    assert payload_bits_per_step(mplan, qw, measured=False) == \
        s["payload_bits_per_step"]


def test_wire_refuses_unwireable_configs():
    t = _tree()
    sm = stacked_mask(t)
    cfg = CompressionConfig(qw=make_compressor("randomk", ratio=0.1),
                            strategy="shared_random")
    with pytest.raises(ValueError, match="simulated/allgather"):
        compressed_allreduce(t, sm, cfg, ("data",), KEY, 1, wire=True)
    # bf16 value legs exist only on the dense/sparse codecs — a
    # quantized-code codec has no f32 records to halve
    with pytest.raises(ValueError, match="bfloat16"):
        wire_codec(make_compressor("qsgd", levels=16),
                   wire_dtype="bfloat16")
    # and the lossy cast breaks strategy='simulated''s exact-operator
    # promise (allgather carries it fine — see the bf16 suite below)
    bf_sim = CompressionConfig(qw=make_compressor("topk", ratio=0.1),
                               strategy="simulated",
                               wire_dtype="bfloat16")
    with pytest.raises(ValueError, match="bit-exact"):
        compressed_allreduce(t, sm, bf_sim, ("data",), KEY, 1, wire=True)
    with pytest.raises(ValueError, match="dense"):  # not silently ignored
        compressed_allreduce(t, sm, CompressionConfig(strategy="dense"),
                             ("data",), KEY, 1, wire=True)


# ==========================================================================
# bfloat16 wire payloads (wire_dtype="bfloat16"): the value legs of the
# dense and sparse codecs ship as bf16 — HALF the f32 value bits — via
# the to_f32/to_bf16 cast idiom. The wire contract becomes decode(x) ==
# sim(x).astype(bf16).astype(f32) BIT for bit (a well-defined lossy
# reference), and the accounting contract stays exact: 8 * packed bytes
# == wire_bits(d) with the bf16 leg padded to the uint32 word.
# ==========================================================================

BF16_CODECS = [
    ("identity", {}),                       # dense f32 values -> bf16
    ("topk", {"ratio": 0.25}),              # sparse values + f32 indices
    ("randomk", {"ratio": 0.3, "scale": True}),
]


def _bf16_reference(comp, x, key):
    return comp.sim(x, key).astype(jnp.bfloat16).astype(jnp.float32)


@pytest.mark.parametrize("d", [8, 33, 256])
@pytest.mark.parametrize("name,kw", BF16_CODECS, ids=[n for n, _ in
                                                      BF16_CODECS])
def test_bf16_roundtrip_is_the_cast_reference(name, kw, d):
    comp = make_compressor(name, **kw)
    c16 = wire_codec(comp, wire_dtype="bfloat16")
    x = jax.random.normal(jax.random.fold_in(KEY, d), (d,))
    p = c16.encode(x, KEY)
    y = c16.decode(p, d)
    ref = _bf16_reference(comp, x, KEY)
    assert y.dtype == jnp.float32
    assert bool((y == ref).all()), (name, d,
                                    float(jnp.max(jnp.abs(y - ref))))
    # accounting == wire, exactly, at the halved width
    assert 8 * p.size == c16.wire_bits(d)
    # the lossy cast stays within bf16 precision of the f32 operator
    exact = comp.sim(x, KEY)
    tol = 2.0 ** -8 * jnp.abs(exact) + 1e-30
    assert bool((jnp.abs(y - exact) <= tol).all())


@pytest.mark.parametrize("name,kw", BF16_CODECS, ids=[n for n, _ in
                                                      BF16_CODECS])
def test_bf16_halves_value_payload_bits(name, kw):
    d = 256
    comp = make_compressor(name, **kw)
    c32 = wire_codec(comp)
    c16 = wire_codec(comp, wire_dtype="bfloat16")
    assert c32.exact_sim and not c16.exact_sim
    if name == "identity":
        assert c16.payload_bits(d) == 16 * d == c32.payload_bits(d) // 2
    else:
        k = _k_of(kw["ratio"], d)
        assert c32.payload_bits(d) == k * (32 + index_bits(d))
        assert c16.payload_bits(d) == k * (16 + index_bits(d))
    assert c16.wire_bits(d) < c32.wire_bits(d)


@pytest.mark.wire
@pytest.mark.parametrize("name,kw", BF16_CODECS, ids=[n for n, _ in
                                                      BF16_CODECS])
def test_bf16_batch_entry_points_match_per_unit(name, kw):
    comp = make_compressor(name, **kw)
    c16 = wire_codec(comp, wire_dtype="bfloat16")
    d, n = 48, 5
    xs = jax.random.normal(KEY, (n, d))
    keys = jax.vmap(lambda i: jax.random.fold_in(KEY, i))(jnp.arange(n))
    pb = c16.encode_batch(xs, keys)
    yb = c16.decode_batch(pb, d)
    for i in range(n):
        p = c16.encode(xs[i], keys[i])
        assert bool((pb[i] == p).all()), (name, i)
        assert bool((yb[i] == c16.decode(p, d)).all()), (name, i)


def test_bf16_collective_matches_f32_path_cast():
    """End-to-end: the allgather collective with wire_dtype='bfloat16'
    returns exactly the bf16-cast of the f32 wire path's output on a
    1-worker mesh (mean over one worker is the identity, so the cast is
    the ONLY difference)."""
    from jax.sharding import PartitionSpec as P
    from repro.launch.engine import shard_map
    from repro.launch.mesh import make_host_mesh
    t = _tree()
    sm = stacked_mask(t)
    mesh = make_host_mesh(1, 1)
    qw = make_compressor("topk", ratio=0.25)

    def run(cfg):
        def f(g, key):
            out, _ = compressed_allreduce(g, sm, cfg, ("data",), key, 1,
                                          wire=True)
            return out
        return jax.jit(shard_map(f, mesh, in_specs=(P(), P()),
                                 out_specs=P()))(t, KEY)

    o32 = run(CompressionConfig(qw=qw, strategy="allgather"))
    o16 = run(CompressionConfig(qw=qw, strategy="allgather",
                                wire_dtype="bfloat16"))
    for l32, l16 in zip(jax.tree_util.tree_leaves(o32),
                        jax.tree_util.tree_leaves(o16)):
        ref = l32.astype(jnp.bfloat16).astype(jnp.float32)
        assert bool((ref == l16).all()), \
            float(jnp.max(jnp.abs(ref - l16)))


def test_bf16_cast_helpers_round_trip_exact_on_bf16_grid():
    """to_f32(to_bf16(x)) is exact when x already sits on the bf16 grid
    (the idiom's contract: casting down then up is a projection)."""
    from repro.core import to_bf16, to_f32
    t = _tree()
    once = to_f32(to_bf16(t))
    twice = to_f32(to_bf16(once))
    _assert_trees_bitwise(once, twice, "bf16 projection idempotent")
