"""Distributed correctness checks, run in a SUBPROCESS with 8 virtual CPU
devices (tests/test_distributed.py drives this; the flag must be set before
jax initializes, which pytest's main process must not do).

Checks:
  1. mesh train grads == single-device reference for every arch family,
     with sequence parallelism ON and OFF
  2. compression strategies (simulated / allgather / rs_compress_ag /
     shared_random) produce the correct aggregation semantics
  3. end-to-end: compressed training decreases the loss on a mesh
  4. serve path: prefill -> decode on a mesh
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import dataclasses  # noqa: E402
import sys          # noqa: E402

import jax          # noqa: E402
import jax.numpy as jnp  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import (CompressionConfig, Granularity, Identity,  # noqa
                        make_compressor, stacked_mask)
from repro.core.aggregation import compressed_allreduce  # noqa: E402
from repro.data import lm_batches  # noqa: E402
from repro.launch.engine import Engine, shard_map  # noqa: E402
from repro.launch.mesh import make_host_mesh  # noqa: E402
from repro.models import DistConfig, Model, ModelConfig  # noqa: E402
from repro.models.config import InputShape  # noqa: E402
from repro.optim import OptConfig  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

FAMILIES = {
    "dense": ModelConfig(name="dense", arch_type="dense", n_layers=2,
                         d_model=64, vocab=256, n_heads=4, n_kv_heads=2,
                         d_head=16, d_ff=128, dtype="float32"),
    "moe": ModelConfig(name="moe", arch_type="moe", n_layers=2, d_model=64,
                       vocab=256, n_heads=4, n_kv_heads=2, d_head=16,
                       d_ff=96, n_experts=4, experts_per_token=2,
                       moe_capacity_factor=8.0, dtype="float32"),
    "mla": ModelConfig(name="mla", arch_type="dense", attention="mla",
                       n_layers=2, d_model=64, vocab=256, n_heads=4,
                       n_kv_heads=4, d_head=48, d_ff=128, q_lora_rank=48,
                       kv_lora_rank=32, qk_nope_dim=32, qk_rope_dim=16,
                       v_head_dim=32, dtype="float32"),
    "ssm": ModelConfig(name="ssm", arch_type="ssm", attention="none",
                       n_layers=2, d_model=64, vocab=256, d_ff=0,
                       ssm_state=16, ssm_expand=2, ssm_head_dim=16,
                       ssm_chunk=8, dtype="float32"),
    "hybrid": ModelConfig(name="hybrid", arch_type="hybrid", n_layers=5,
                          d_model=64, vocab=256, n_heads=4, n_kv_heads=4,
                          d_head=16, d_ff=128, ssm_state=16, ssm_expand=2,
                          ssm_head_dim=16, ssm_chunk=8, attn_every=2,
                          dtype="float32"),
}

TOL = {"dense": 1e-4, "moe": 2e-2, "mla": 1e-4, "ssm": 1e-4, "hybrid": 1e-4}


def check_grad_equivalence():
    batch = next(lm_batches(256, 16, 32, seed=3))
    key = jax.random.key(7)
    for fam, cfg in FAMILIES.items():
        m0 = Model(cfg, DistConfig())
        params = m0.init(jax.random.key(0))
        g0 = jax.grad(lambda p: m0.loss(p, batch, key))(params)
        for SP in (False, True):
            mesh = make_host_mesh(data=4, model=2)
            eng = Engine(cfg, mesh, comp=CompressionConfig(strategy="dense"),
                         opt=OptConfig())
            if not SP:
                eng.dist = dataclasses.replace(eng.dist, sp=False)
                eng.model.dist = eng.dist
            model = eng.model

            def gfn(p, b):
                g = jax.grad(lambda pp: model.loss(pp, b, key))(p)
                return eng._aggregate_grads(g, key)

            pp = model.param_pspecs()
            bs = eng.batch_pspecs(InputShape("t", 32, 16, "train"))
            mapped = shard_map(gfn, mesh, in_specs=(pp, bs), out_specs=pp)
            with mesh:
                g1 = jax.jit(mapped)(params, batch)
            worst = 0.0
            for a, b in zip(jax.tree_util.tree_leaves(g1),
                            jax.tree_util.tree_leaves(g0)):
                rel = float(jnp.max(jnp.abs(a - b))
                            / (jnp.max(jnp.abs(b)) + 1e-9))
                worst = max(worst, rel)
            assert worst < TOL[fam], (fam, SP, worst)
            print(f"grad-equiv {fam} SP={SP}: worst rel {worst:.2e} OK")


def check_strategies():
    """allgather / rs / shared_random reproduce correct aggregation.

    identity compressor: every strategy must equal the plain mean.
    shared_random: the output support is the shared index set.
    """
    mesh = make_host_mesh(data=8, model=1)
    g = {"blocks": {"w": jax.random.normal(jax.random.key(1), (3, 8, 16))},
         "head": jax.random.normal(jax.random.key(2), (8, 4))}
    sm = stacked_mask(g)
    ref = None
    for strat in ("dense", "simulated", "allgather", "rs_compress_ag"):
        cfg = CompressionConfig(qw=Identity(), strategy=strat)

        def f(gl):
            out, _ = compressed_allreduce(gl, sm, cfg, ("data",),
                                          jax.random.key(0), 8)
            return out

        specs = {"blocks": {"w": P(None, "data", None)},
                 "head": P("data", None)}
        with mesh:
            out = jax.jit(shard_map(f, mesh, in_specs=(specs,),
                                    out_specs=specs))(g)
        if ref is None:
            ref = out
        else:
            for a, b in zip(jax.tree_util.tree_leaves(out),
                            jax.tree_util.tree_leaves(ref)):
                assert jnp.allclose(a, b, atol=1e-5), strat
        print(f"strategy {strat}: identity == mean OK")

    cfg = CompressionConfig(qw=make_compressor("randomk", ratio=0.25),
                            strategy="shared_random")

    def f2(gl):
        out, _ = compressed_allreduce(gl, sm, cfg, ("data",),
                                      jax.random.key(0), 8)
        return out

    specs = {"blocks": {"w": P(None, "data", None)},
             "head": P("data", None)}
    with mesh:
        out = jax.jit(shard_map(f2, mesh, in_specs=(specs,),
                                out_specs=specs))(g)
    frac = float(jnp.mean((out["blocks"]["w"] != 0).astype(jnp.float32)))
    assert 0.1 < frac <= 0.35, frac
    print(f"strategy shared_random: sparsity {frac:.2f} OK")


def check_training_decreases_loss():
    cfg = FAMILIES["dense"]
    mesh = make_host_mesh(data=4, model=2)
    comp = CompressionConfig(qw=make_compressor("topk", ratio=0.25),
                             granularity=Granularity("layerwise"),
                             strategy="allgather")
    eng = Engine(cfg, mesh, comp=comp, opt=OptConfig(name="momentum", lr=0.3))
    step = eng.build_train_step()
    params, opt_state = eng.init_state()
    it = lm_batches(256, 16, 32, seed=3)
    losses = []
    with mesh:
        for i in range(12):
            params, opt_state, m = step(params, opt_state, next(it),
                                        jnp.int32(i))
            losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses
    print(f"training: loss {losses[0]:.3f} -> {losses[-1]:.3f} OK")


def check_serve():
    cfg = FAMILIES["dense"]
    mesh = make_host_mesh(data=4, model=2)
    eng = Engine(cfg, mesh)
    params, _ = eng.init_state()
    pshape = InputShape("p", 64, 8, "prefill")
    dshape = InputShape("d", 64, 8, "decode")
    pre = eng.build_prefill(pshape)
    srv = eng.build_serve_step(dshape)
    with mesh:
        lg, cache = pre(params, {"tokens": jnp.ones((8, 32), jnp.int32)})
        lg2, cache = srv(params, {"token": jnp.ones((8,), jnp.int32),
                                  "pos": jnp.int32(32)}, cache)
    assert lg2.shape[0] == 8 and not bool(jnp.isnan(lg2).any())
    print("serve: prefill->decode OK")


if __name__ == "__main__":
    check_grad_equivalence()
    check_strategies()
    check_training_decreases_loss()
    check_serve()
    print("ALL DISTRIBUTED CHECKS PASSED")
