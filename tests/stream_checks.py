"""Multi-device streaming-collective checks (subprocess body).

Run by tests/test_stream.py with 4 virtual CPU devices — XLA device
count must be set before jax initializes, hence the subprocess. What a
single-device run cannot witness, this does:

  1. ring == allgather BITWISE at n_workers = 4 — distinct per-worker
     gradients, with and without chunked hops, including 5 steps of
     threaded error feedback. The streaming correctness contract on a
     real ring.
  2. the double-buffer jaxpr proof: in the traced program the first
     `ppermute` (message 0's first hop) appears AFTER the first
     `optimization_barrier` (message 1's gathers ordered on message 0's
     buffer) — compress(i+1) interleaves before collective(i) — and the
     ppermute count is exactly sum_msgs (n-1) x n_chunks(msg).
  3. per-hop observability: measure_stream reports hop-span count ==
     n_messages x (n-1) for both modes (its trace validates against the
     Chrome schema internally; multi-device stamps collapse under
     finalize_step(dedupe=True)).
  4. the rs paths on NON-DIVISIBLE dims: rs_stream (wire) and
     rs_compress_ag (unpacked) with the identity compressor reproduce
     the dense mean — the padding-mask fix (phantom capacity-tail
     values used to leak into encode).
  5. `_mean_psum` static-n bit-identity: psum(x)/n_static equals the
     legacy psum(x)/psum(ones) bitwise (psum of ones is exactly
     float(n)).
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import jax                     # noqa: E402
import jax.numpy as jnp        # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.core import (CompressionConfig, FUSE_ALL, Granularity,  # noqa: E402
                        build_plan, build_schedule, compressed_allreduce,
                        make_compressor, stacked_mask)
from repro.core.wire import layout_chunks, message_layouts, wire_codec  # noqa: E402
from repro.launch.engine import shard_map  # noqa: E402
from repro.launch.mesh import make_host_mesh  # noqa: E402

KEY = jax.random.key(7)
N = jax.local_device_count()
assert N == 4, f"expected 4 virtual devices, got {N}"
MESH = make_host_mesh(N, 1)


def _tree():
    ks = [jax.random.fold_in(jax.random.key(3), i) for i in range(4)]
    return {"dense": jax.random.normal(ks[0], (8, 16)),
            "blocks": jax.random.normal(ks[1], (3, 4, 10)),
            "odd": jax.random.normal(ks[2], (7,)),       # non-divisible
            "scalar": jax.random.normal(ks[3], ())}


def _per_worker(g):
    """Distinct gradients per ring position."""
    i = jax.lax.axis_index("data").astype(jnp.float32)
    return jax.tree_util.tree_map(lambda x: x * (1.0 + i), g)


def _bitwise(a, b, ctx):
    for x, y in zip(jax.tree_util.tree_leaves(a),
                    jax.tree_util.tree_leaves(b)):
        assert x.shape == y.shape and x.dtype == y.dtype, ctx
        assert bool((x == y).all()), (
            ctx, float(jnp.max(jnp.abs(x - y))))


def _run(strat, qw, fb, *, ef_steps=0, chunk=None):
    t = _tree()
    sm = stacked_mask(t)
    cfg = CompressionConfig(qw=qw, granularity=Granularity("layerwise"),
                            strategy=strat, error_feedback=ef_steps > 0,
                            fusion_bytes=fb)

    def f(g, ef, key):
        g = _per_worker(g)
        if ef_steps:
            return compressed_allreduce(g, sm, cfg, ("data",), key, N,
                                        wire=True, ef_state=ef,
                                        stream_chunk_bytes=chunk)
        out, _ = compressed_allreduce(g, sm, cfg, ("data",), key, N,
                                      wire=True, stream_chunk_bytes=chunk)
        return out

    fn = jax.jit(shard_map(f, MESH, in_specs=(P(), P(), P()),
                           out_specs=(P(), P()) if ef_steps else P()))
    if not ef_steps:
        return fn(t, t, KEY)   # ef arg unused
    ef = jax.tree_util.tree_map(jnp.zeros_like, t)
    outs = []
    for i in range(ef_steps):
        out, ef = fn(t, ef, jax.random.fold_in(KEY, i))
        outs.append(out)
    return outs, ef


def check_ring_bitwise():
    for name, kw in (("topk", {"ratio": 0.25}), ("qsgd", {"levels": 16}),
                     ("natural", {})):
        qw = make_compressor(name, **kw)
        for fb in (0.0, FUSE_ALL):
            ref = _run("allgather", qw, fb)
            for chunk in (None, 64.0):
                got = _run("ring", qw, fb, chunk=chunk)
                _bitwise(ref, got, ("ring", name, fb, chunk))
    print("ring == allgather bitwise at n=4: OK")


def check_ring_ef_bitwise():
    qw = make_compressor("topk", ratio=0.25)
    for fb in (0.0, FUSE_ALL):
        ref_outs, ref_ef = _run("allgather", qw, fb, ef_steps=5)
        got_outs, got_ef = _run("ring", qw, fb, ef_steps=5, chunk=64.0)
        for i, (r, g) in enumerate(zip(ref_outs, got_outs)):
            _bitwise(r, g, ("ring-ef", fb, "step", i))
        _bitwise(ref_ef, got_ef, ("ring-ef-state", fb))
    print("ring 5-step EF == allgather at n=4: OK")


def _prim_seq(jx, out):
    for eqn in jx.eqns:
        out.append(eqn.primitive.name)
        for v in eqn.params.values():
            vs = v if isinstance(v, (list, tuple)) else [v]
            for u in vs:
                if hasattr(u, "jaxpr") and hasattr(u.jaxpr, "eqns"):
                    _prim_seq(u.jaxpr, out)
                elif hasattr(u, "eqns"):
                    _prim_seq(u, out)


def check_double_buffer_jaxpr():
    t = _tree()
    sm = stacked_mask(t)
    comp = make_compressor("qsgd", levels=16)
    plan = build_plan(t, sm, Granularity("layerwise"))
    sched = build_schedule(plan, 0.0)
    codec = wire_codec(comp)
    assert sched.num_messages > 1, "need >= 2 messages for the pipeline"

    def f(g):
        out, _ = sched.execute_streaming(None, g, KEY, wire=codec,
                                         axis_names=("data",), n_workers=N)
        return out

    jaxpr = jax.make_jaxpr(shard_map(f, MESH, in_specs=(P(),),
                                     out_specs=P()))(t)
    seq = []
    _prim_seq(jaxpr.jaxpr, seq)
    assert "ppermute" in seq and "optimization_barrier" in seq, seq[:20]
    i_ob = seq.index("optimization_barrier")
    i_pp = seq.index("ppermute")
    # message 1's gathers are barriered on message 0's buffer BEFORE
    # message 0's first hop: compress(i+1) precedes collective(i).
    assert i_ob < i_pp, (i_ob, i_pp)
    expected = sum((N - 1) * len(layout_chunks(l, None))
                   for l in message_layouts(sched, codec))
    got = sum(1 for p in seq if p == "ppermute")
    assert got == expected, (got, expected)
    print(f"double-buffer jaxpr: barrier@{i_ob} < ppermute@{i_pp}, "
          f"{got} ppermutes: OK")


def check_hop_spans():
    from repro.obs.calibrate import measure_stream
    t = _tree()
    sm = stacked_mask(t)
    comp = make_compressor("qsgd", levels=16)
    for mode in ("ring", "rs"):
        r = measure_stream(t, sm, comp, 0.0, mode=mode, reps=2, warmup=1,
                           chunk_bytes=64.0)
        assert r["n_workers"] == N, r
        assert r["n_hops"] == r["n_messages"] * (N - 1), r
        assert r["n_hop_spans_measured"] == r["n_hops"], r
        assert r["hop_bytes_total"] == (N - 1) * r["wire_bytes"], r
    print("per-hop spans (counts, bytes, chrome-trace schema): OK")


def check_rs_nondivisible():
    t = _tree()   # 'odd' (7,) and blocks dim 10: both non-divisible by 4
    sm = stacked_mask(t)
    qw = make_compressor("identity")
    mesh = MESH

    def dense_mean(g):
        g = _per_worker(g)
        return jax.tree_util.tree_map(
            lambda x: jax.lax.psum(x, ("data",)) / 4.0, g)

    ref = jax.jit(shard_map(dense_mean, mesh, in_specs=(P(),),
                            out_specs=P()))(t)
    for strat, wire in (("rs_stream", True), ("rs_compress_ag", False)):
        cfg = CompressionConfig(qw=qw,
                                granularity=Granularity("layerwise"),
                                strategy=strat)

        def f(g):
            g = _per_worker(g)
            out, _ = compressed_allreduce(g, sm, cfg, ("data",), KEY, N,
                                          wire=wire)
            return out

        got = jax.jit(shard_map(f, mesh, in_specs=(P(),),
                                out_specs=P()))(t)
        for x, y in zip(jax.tree_util.tree_leaves(ref),
                        jax.tree_util.tree_leaves(got)):
            err = float(jnp.max(jnp.abs(x - y)))
            # identity codec: any deviation beyond reduction reordering
            # means padding leaked into the payloads (the fixed bug)
            assert err <= 1e-5 * (1.0 + float(jnp.max(jnp.abs(x)))), (
                strat, err)
    print("rs paths on non-divisible dims == dense mean (identity): OK")


def check_mean_psum_static():
    from repro.core.aggregation import _mean_psum
    x = jax.random.normal(jax.random.key(9), (64,))

    def new(v):
        return _mean_psum(v, ("data",), N)

    def legacy(v):
        return jax.lax.psum(v, ("data",)) / jax.lax.psum(
            jnp.ones((), v.dtype), ("data",))

    a = jax.jit(shard_map(new, MESH, in_specs=(P(),), out_specs=P()))(x)
    b = jax.jit(shard_map(legacy, MESH, in_specs=(P(),), out_specs=P()))(x)
    assert bool((a == b).all()), float(jnp.max(jnp.abs(a - b)))
    print("_mean_psum static-n == legacy psum-of-ones bitwise: OK")


if __name__ == "__main__":
    check_ring_bitwise()
    check_ring_ef_bitwise()
    check_double_buffer_jaxpr()
    check_hop_spans()
    check_rs_nondivisible()
    check_mean_psum_static()
    print("ALL STREAM CHECKS PASSED")
