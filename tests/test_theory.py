"""Executable checks of the paper's theory (Lemma 1, the Trace(A) vs
L·max noise bound, Lemma 2 instances)."""
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import make_compressor
from repro.core.theory import (empirical_omega, entire_model_bound,
                               layerwise_tighter, lemma1_check, trace_A)

KEY = jax.random.key(3)


def test_lemma1_inequality_chain():
    """E||Q(x)||^2 <= sum_j (1+Om_j)||x_j||^2 <= max_j(1+Om_j)||x||^2."""
    parts = [jax.random.normal(jax.random.fold_in(KEY, j), (64 * (j + 1),))
             for j in range(4)]
    c = make_compressor("qsgd", levels=4)
    lhs, mid, rhs = lemma1_check(c, parts, KEY, trials=96)
    assert lhs <= mid * 1.15  # Monte-Carlo slack on the expectation
    assert mid <= rhs + 1e-6


@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(min_value=0.0, max_value=50.0), min_size=2,
                max_size=12),
       st.lists(st.floats(min_value=0.0, max_value=50.0), min_size=2,
                max_size=12),
       st.integers(min_value=1, max_value=10_000))
def test_property_layerwise_bound_tighter(oms_w, oms_m, seed):
    """The paper's headline claim: Trace(A) <= d * max_j(1+Om_W)(1+Om_M)
    for ANY per-layer omegas and dimensions."""
    L = min(len(oms_w), len(oms_m))
    oms_w, oms_m = oms_w[:L], oms_m[:L]
    import numpy as np
    rng = np.random.default_rng(seed)
    dims = rng.integers(1, 1000, size=L).tolist()
    assert layerwise_tighter(oms_w, oms_m, dims)
    assert trace_A(oms_w, oms_m, dims) <= entire_model_bound(
        oms_w, oms_m, dims) + 1e-6


def test_layerwise_noise_strictly_smaller_when_heterogeneous():
    """With heterogeneous per-layer omegas the layer-wise factor is
    STRICTLY smaller — the quantitative advantage the paper proves."""
    oms_w = [0.1, 5.0, 0.5]
    oms_m = [0.0, 0.0, 0.0]
    dims = [1000, 10, 100]
    t = trace_A(oms_w, oms_m, dims)
    e = entire_model_bound(oms_w, oms_m, dims)
    assert t < 0.5 * e


def test_lemma2_randomk_scaling():
    """Lemma 2(ii): unscaled Random-k gives E[q^T g] = (k/d)||g||^2 per
    side (k_M k_W / d^2 bidirectionally)."""
    d, ratio = 600, 0.2
    g = jax.random.normal(KEY, (d,))
    c = make_compressor("randomk", ratio=ratio)
    keys = jax.random.split(KEY, 512)
    vals = jax.vmap(lambda k: jnp.dot(c.sim(g, k), g))(keys)
    expect = ratio * float(jnp.sum(g * g))
    assert float(jnp.mean(vals)) == pytest.approx(expect, rel=0.1)


def test_omega_identity_zero():
    c = make_compressor("identity")
    x = jax.random.normal(KEY, (128,))
    assert abs(empirical_omega(c, x, KEY, trials=4)) < 1e-6
