"""End-to-end behaviour tests for the paper's system (single device):
Algorithm 1 semantics, error feedback, and the layer-wise vs entire-model
empirical effect the paper studies."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import (CompressionConfig, Granularity, Identity,
                        aggregate_simulated_workers, comm_report,
                        make_compressor, stacked_mask, unit_dims)
from repro.data import lm_batches
from repro.models import DistConfig, Model, ModelConfig

pytestmark = pytest.mark.slow

KEY = jax.random.key(0)


def _worker_grads(n=4):
    g = {"blocks": {"w": jax.random.normal(KEY, (2, 32, 16))},
         "head": jax.random.normal(KEY, (16, 8))}
    wg = jax.tree_util.tree_map(
        lambda x: x[None] + 0.1 * jax.random.normal(
            jax.random.fold_in(KEY, 9), (n,) + x.shape), g)
    return wg


def test_algorithm1_identity_is_mean():
    wg = _worker_grads()
    sm = stacked_mask(jax.tree_util.tree_map(lambda x: x[0], wg))
    cfg = CompressionConfig(qw=Identity(), qm=Identity())
    out, _ = aggregate_simulated_workers(wg, sm, cfg, KEY)
    ref = jax.tree_util.tree_map(lambda x: jnp.mean(x, 0), wg)
    for a, b in zip(jax.tree_util.tree_leaves(out),
                    jax.tree_util.tree_leaves(ref)):
        assert jnp.allclose(a, b, atol=1e-6)


def test_bidirectional_master_compression_applied():
    """Q_M sparsifies the aggregated gradient (bidirectional, Algorithm 1
    line 3 of the master loop)."""
    wg = _worker_grads()
    sm = stacked_mask(jax.tree_util.tree_map(lambda x: x[0], wg))
    cfg = CompressionConfig(qw=Identity(),
                            qm=make_compressor("topk", ratio=0.1),
                            granularity=Granularity("layerwise"))
    out, _ = aggregate_simulated_workers(wg, sm, cfg, KEY)
    w = out["blocks"]["w"]
    for layer in range(2):
        nnz = int(jnp.sum(w[layer] != 0))
        assert nnz == int(round(0.1 * 32 * 16))


def test_error_feedback_accumulates_residual():
    wg = _worker_grads()
    sm = stacked_mask(jax.tree_util.tree_map(lambda x: x[0], wg))
    cfg = CompressionConfig(qw=make_compressor("topk", ratio=0.05),
                            error_feedback=True)
    ef = jax.tree_util.tree_map(jnp.zeros_like, wg)
    out, ef2 = aggregate_simulated_workers(wg, sm, cfg, KEY, ef_state=ef)
    r = ef2["blocks"]["w"]
    assert float(jnp.sum(jnp.abs(r))) > 0
    assert float(jnp.max(jnp.abs(r))) <= float(jnp.max(jnp.abs(
        wg["blocks"]["w"]))) + 1e-6


def test_comm_report_compression_ratio():
    g = {"blocks": {"w": jnp.zeros((2, 512, 16))}}
    sm = stacked_mask(g)
    dims = unit_dims(g, sm, Granularity("layerwise"))
    cfg = CompressionConfig(qw=make_compressor("topk", ratio=0.01),
                            strategy="allgather")
    rep = comm_report(cfg, dims, 16)
    # allgather: n·payload received — ratio bounded by n at high sparsity
    assert rep.compression_ratio > 5
    sr = comm_report(CompressionConfig(
        qw=make_compressor("randomk", ratio=0.01),
        strategy="shared_random"), dims, 16)
    assert sr.compression_ratio > 50
    dense = comm_report(CompressionConfig(strategy="dense"), dims, 16)
    assert dense.compression_ratio == pytest.approx(1.0)


@pytest.mark.parametrize("granularity", ["layerwise", "entire_model"])
def test_single_device_compressed_training_converges(granularity):
    """The paper's core experiment at test scale: train a small LM with
    simulated multi-worker Top-k compression in BOTH granularities; loss
    must decrease for each."""
    cfg = ModelConfig(name="t", arch_type="dense", n_layers=2, d_model=64,
                      vocab=128, n_heads=4, n_kv_heads=2, d_head=16,
                      d_ff=128, dtype="float32")
    m = Model(cfg, DistConfig())
    params = m.init(KEY)
    comp = CompressionConfig(qw=make_compressor("topk", ratio=0.25),
                             granularity=Granularity(granularity))
    sm = m.stacked()
    n_workers = 4
    it = lm_batches(128, 8, 32, seed=1)

    @jax.jit
    def step(params, batch, key):
        wb = jax.tree_util.tree_map(
            lambda x: x.reshape((n_workers, -1) + x.shape[1:]), batch)
        wg = jax.vmap(lambda b: jax.grad(
            lambda p: m.loss(p, b, key))(params))(wb)
        g, _ = aggregate_simulated_workers(wg, sm, comp, key)
        return jax.tree_util.tree_map(lambda p, gg: p - 0.3 * gg, params, g)

    losses = []
    for i in range(15):
        b = next(it)
        losses.append(float(m.loss(params, b, jax.random.key(5))))
        params = step(params, b, jax.random.fold_in(KEY, i))
    assert losses[-1] < losses[0] - 0.3, (granularity, losses)
