"""Runs the distributed correctness suite in a subprocess with 8 virtual
devices (XLA device count must be set before jax initializes)."""
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow


@pytest.mark.timeout(1200)
def test_distributed_checks():
    script = os.path.join(os.path.dirname(__file__), "dist_checks.py")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, script], capture_output=True,
                         text=True, env=env, timeout=1200)
    sys.stdout.write(res.stdout[-4000:])
    sys.stderr.write(res.stderr[-4000:])
    assert res.returncode == 0, "distributed checks failed"
    assert "ALL DISTRIBUTED CHECKS PASSED" in res.stdout
