"""CommSchedule: the equivalence harness locking down comm scheduling.

The load-bearing property: scheduling NEVER changes numerics. A
CommSchedule reorders the plan's per-bucket dispatches into backward-ready
fused wire messages and pins that order with barriers — but every bucket
runs the identical batched compressor call with the identical PRNG keys,
so `schedule.execute` must be BIT-identical to `UnitPlan.execute` /
`apply_unitwise` for every compressor, granularity, fusion threshold and
key-derivation mode. Plus: error-feedback state is neither dropped nor
double-applied under fusion/reordering, message construction invariants,
the alpha-beta model's sanity, and the comm_report message/latency
accounting against hand-computed values.

The full sweep (six compressors x granularities x thresholds x key modes)
carries the `sched` marker: it runs in tier-1 (`make verify`) and is
excluded from the `make verify-fast` inner loop.
"""
import json
import math

import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (CompressionConfig, FUSE_ALL, Granularity,
                        aggregate_simulated_workers, build_plan,
                        build_schedule, comm_report, compressed_allreduce,
                        make_compressor, message_wire_bits,
                        simulate_schedule, stacked_mask)
from repro.core.granularity import apply_unitwise
from repro.core.plan import UnitPlan

KEY = jax.random.key(0)

# fusion thresholds the harness sweeps: per-bucket messages, Horovod-ish
# small buffer, large buffer, one fused message.
THRESHOLDS = (0.0, 4096.0, float(1 << 20), FUSE_ALL)

# the paper's six operators (ISSUE: "all six compressors")
SIX = [
    ("topk", {"ratio": 0.25}),
    ("randomk", {"ratio": 0.3, "scale": True}),
    ("qsgd", {"levels": 16}),
    ("terngrad", {}),
    ("signsgd", {}),
    ("natural", {}),
]

GRANS = [Granularity("layerwise"), Granularity("entire_model")]


def _tree(key=KEY):
    """Mixed pytree: scan-stacked leaves + loose leaves of several size
    classes, chosen so readiness order != plan bucket order."""
    ks = [jax.random.fold_in(key, i) for i in range(5)]
    return {"blocks": {"w": jax.random.normal(ks[0], (3, 16, 8)),
                       "b": jax.random.normal(ks[1], (3, 8))},
            "embed": jax.random.normal(ks[2], (20, 4)),
            "head": jax.random.normal(ks[3], (4, 2)),
            "scalar_gain": jax.random.normal(ks[4], ())}


def _assert_trees_bitwise(a, b, ctx):
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        assert la.shape == lb.shape and la.dtype == lb.dtype, ctx
        assert bool((la == lb).all()), (
            ctx, float(jnp.max(jnp.abs(la - lb))))


def _fns(comp, key_mode):
    """The per-unit closure under both PRNG disciplines: `per_unit` uses
    the plan-derived unit key (the production path), `shared` ignores it
    and closes over ONE key (frameworks that seed per step, not per
    tensor). Equivalence must hold for both."""
    if key_mode == "per_unit":
        return lambda x, k: comp.sim(x, k)
    shared = jax.random.fold_in(KEY, 0xF00D)
    return lambda x, k: comp.sim(x, shared)


def _check_equivalence(tree, sm, comp, gran, fusion_bytes, key_mode,
                       key=KEY):
    plan = build_plan(tree, sm, gran)
    sched = build_schedule(plan, fusion_bytes)
    fn = _fns(comp, key_mode)
    ref = plan.execute(fn, tree, key)
    got = sched.execute(fn, tree, key)
    _assert_trees_bitwise(ref, got,
                          (comp.name, gran.kind, fusion_bytes, key_mode))


# ---------------------------------------------------------------------------
# equivalence: scheduled execution == UnitPlan reference, bit for bit
# ---------------------------------------------------------------------------

def test_schedule_matches_plan_smoke():
    """Inner-loop subset of the full sweep (which carries the `sched`
    marker): two operators x layerwise x {no fusion, full fusion}."""
    t = _tree()
    sm = stacked_mask(t)
    for name, kw in (("qsgd", {"levels": 16}), ("topk", {"ratio": 0.25})):
        for fb in (0.0, FUSE_ALL):
            _check_equivalence(t, sm, make_compressor(name, **kw),
                               Granularity("layerwise"), fb, "per_unit")


@pytest.mark.sched
@pytest.mark.parametrize("name,kw", SIX)
def test_schedule_matches_plan_full(name, kw):
    """The acceptance sweep: all six compressors x {layerwise,
    entire_model} x fusion thresholds {0, 4KiB, 1MiB, inf} x
    {per-unit, shared} PRNG keys — bit-identical everywhere."""
    t = _tree()
    sm = stacked_mask(t)
    comp = make_compressor(name, **kw)
    for gran in GRANS:
        for key_mode in ("per_unit", "shared"):
            for fb in THRESHOLDS:
                _check_equivalence(t, sm, comp, gran, fb, key_mode)


def test_schedule_matches_plan_blockwise():
    """Beyond the ISSUE matrix: blockwise plans schedule too (single
    size-class bucket — scheduling degenerates to one message)."""
    t = _tree()
    sm = stacked_mask(t)
    _check_equivalence(t, sm, make_compressor("qsgd", levels=8),
                       Granularity("blockwise", 100), 0.0, "per_unit")


def test_schedule_matches_plan_raw_key():
    """Old-style uint32 keys take the raw fold path through the schedule
    exactly as through the plan."""
    t = _tree()
    sm = stacked_mask(t)
    rk = jax.random.PRNGKey(11)
    _check_equivalence(t, sm, make_compressor("qsgd", levels=8),
                       Granularity("layerwise"), 4096.0, "per_unit", key=rk)


def test_schedule_matches_apply_unitwise():
    """The harness's second oracle: `apply_unitwise` (the public plan
    entry point) agrees with scheduled execution under jit."""
    t = _tree()
    sm = stacked_mask(t)
    g = Granularity("layerwise")
    c = make_compressor("natural")
    plan = build_plan(t, sm, g)
    sched = build_schedule(plan, FUSE_ALL)
    fn = lambda x, k: c.sim(x, k)  # noqa: E731
    ref = jax.jit(lambda tt: apply_unitwise(fn, g, tt, sm, KEY))(t)
    got = jax.jit(lambda tt: sched.execute(fn, tt, KEY))(t)
    _assert_trees_bitwise(ref, got, "apply_unitwise-vs-schedule")


# ---------------------------------------------------------------------------
# error feedback: state neither dropped nor double-applied
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fb", [0.0, 4096.0, FUSE_ALL],
                         ids=["per_bucket", "fuse4k", "fuse_all"])
def test_schedule_with_state_matches_plan(fb):
    """Outputs AND residual memories are bit-identical when buckets are
    fused or reordered."""
    t = _tree()
    sm = stacked_mask(t)
    m0 = jax.tree_util.tree_map(lambda x: 0.3 * jnp.ones_like(x), t)
    c = make_compressor("topk", ratio=0.1)

    def ef(x, m, k):
        e = x + m
        q = c.sim(e, k)
        return q, e - q

    for gran in GRANS:
        plan = build_plan(t, sm, gran)
        sched = build_schedule(plan, fb)
        y_p, m_p = plan.execute_with_state(ef, t, m0, KEY)
        y_s, m_s = sched.execute_with_state(ef, t, m0, KEY)
        _assert_trees_bitwise(y_p, y_s, (gran.kind, fb, "out"))
        _assert_trees_bitwise(m_p, m_s, (gran.kind, fb, "mem"))


def test_ef_conservation_over_steps():
    """5 steps of Algorithm 1 with error feedback, fused vs unscheduled:
    the EF residual trees stay bit-identical step after step (nothing
    dropped, nothing double-applied), so their element sums match the
    unscheduled reference exactly."""
    t = _tree()
    sm = stacked_mask(t)
    n = 2

    def run(fusion_bytes):
        cfg = CompressionConfig(qw=make_compressor("topk", ratio=0.1),
                                granularity=Granularity("layerwise"),
                                error_feedback=True,
                                fusion_bytes=fusion_bytes)
        ef = jax.tree_util.tree_map(
            lambda x: jnp.zeros((n,) + x.shape, jnp.float32), t)
        out = None
        for i in range(5):
            gkey = jax.random.fold_in(KEY, 100 + i)
            wg = jax.tree_util.tree_map(
                lambda x: jnp.stack([x * (1.0 + 0.1 * i), -0.5 * x]), t)
            out, ef = aggregate_simulated_workers(
                wg, sm, cfg, jax.random.fold_in(gkey, i), ef_state=ef)
        return out, ef

    out_ref, ef_ref = run(None)
    for fb in (0.0, 4096.0, FUSE_ALL):
        out_s, ef_s = run(fb)
        _assert_trees_bitwise(out_s, out_ref, (fb, "out"))
        _assert_trees_bitwise(ef_s, ef_ref, (fb, "ef"))
        ref_sum = sum(float(jnp.sum(l))
                      for l in jax.tree_util.tree_leaves(ef_ref))
        s_sum = sum(float(jnp.sum(l))
                    for l in jax.tree_util.tree_leaves(ef_s))
        assert s_sum == ref_sum, fb


# ---------------------------------------------------------------------------
# scheduling semantics: order, fusion, barriers
# ---------------------------------------------------------------------------

def test_readiness_order_streams_backward():
    """Scheduled tracing dispatches buckets in backward-readiness order
    (late layers first — head before embed before the stacked blocks),
    NOT in the plan's size-class discovery order; and every bucket still
    traces exactly once (dispatch count preserved)."""
    t = _tree()
    sm = stacked_mask(t)
    plan = build_plan(t, sm, Granularity("layerwise"))
    sched = build_schedule(plan, 0.0)

    seen = []

    def counting(x, k):
        seen.append(x.shape[-1])
        return x

    jax.make_jaxpr(lambda tt: sched.execute(counting, tt, KEY))(t)
    expect = [plan.buckets[i].dim for m in sched.messages
              for i in m.bucket_ids]
    plan_order = [b.dim for b in plan.buckets]
    assert seen == expect
    assert seen != plan_order           # scheduling really reorders
    assert len(seen) == plan.num_dispatches
    # the tree's last leaves (head dim 8 via the shared dim-8 bucket is
    # held back by blocks.b at leaf 0 — the scalar and embed go first)
    ready = [plan.buckets[i].ready for i in sched.order]
    assert ready == sorted(ready)       # ascending readiness


def test_message_construction_invariants():
    """Messages partition the buckets exactly once, in readiness order;
    fusion is monotone in the threshold; 0 => one message per bucket;
    inf => one message; dense bytes add up."""
    t = _tree()
    sm = stacked_mask(t)
    for gran in GRANS + [Granularity("blockwise", 100)]:
        plan = build_plan(t, sm, gran)
        prev_n = None
        for fb in (0.0, 1024.0, 4096.0, float(1 << 20), FUSE_ALL):
            sched = build_schedule(plan, fb)
            ids = [bi for m in sched.messages for bi in m.bucket_ids]
            assert sorted(ids) == list(range(len(plan.buckets)))
            assert tuple(ids) == sched.order
            assert sum(m.nbytes for m in sched.messages) == \
                sum(b.nbytes for b in plan.buckets)
            for m in sched.messages:
                assert m.ready == max(plan.buckets[bi].ready
                                      for bi in m.bucket_ids)
            if prev_n is not None:      # larger threshold never splits
                assert sched.num_messages <= prev_n
            prev_n = sched.num_messages
        assert build_schedule(plan, 0.0).num_messages == len(plan.buckets)
        assert build_schedule(plan, FUSE_ALL).num_messages == 1
    with pytest.raises(ValueError):
        build_schedule(build_plan(t, sm, GRANS[0]), -1.0)


def test_build_schedule_cached_and_hashable():
    t = _tree()
    sm = stacked_mask(t)
    plan = build_plan(t, sm, Granularity("layerwise"))
    s1 = build_schedule(plan, 4096.0)
    assert build_schedule(plan, 4096.0) is s1       # lru_cache hit
    assert build_schedule(plan, 0.0) is not s1
    assert len({s1, build_schedule(plan, 4096.0)}) == 1  # hashable key
    assert "messages" in s1.summary() or "message" in s1.summary()


def test_streaming_barriers_in_jaxpr():
    """One ordering barrier between consecutive messages — message i+1's
    gathers depend on message i's output, which is what forbids the
    compiler from hoisting later compression above earlier collectives.
    The unscheduled plan path has none."""
    t = _tree()
    sm = stacked_mask(t)
    plan = build_plan(t, sm, Granularity("layerwise"))
    c = make_compressor("signsgd")
    fn = lambda x, k: c.sim(x, k)  # noqa: E731

    def count_barriers(jaxpr):
        return sum(1 for eq in jaxpr.eqns
                   if eq.primitive.name == "optimization_barrier")

    for fb, want_msgs in ((0.0, len(plan.buckets)), (FUSE_ALL, 1)):
        sched = build_schedule(plan, fb)
        assert sched.num_messages == want_msgs
        jx = jax.make_jaxpr(lambda tt: sched.execute(fn, tt, KEY))(t)
        assert count_barriers(jx.jaxpr) == sched.num_messages - 1
    jx = jax.make_jaxpr(lambda tt: plan.execute(fn, tt, KEY))(t)
    assert count_barriers(jx.jaxpr) == 0


# ---------------------------------------------------------------------------
# alpha-beta cost model
# ---------------------------------------------------------------------------

def test_simulate_schedule_model():
    """Deterministic sanity of the cost model: entire-model (one late
    message) exposes ALL its comm; per-bucket layerwise streaming
    overlaps some of it behind backward; the alpha term makes many small
    messages expensive when latency dominates. JSON-exportable."""
    t = _tree()
    sm = stacked_mask(t)
    lw = build_plan(t, sm, Granularity("layerwise"))
    em = build_plan(t, sm, Granularity("entire_model"))
    qw = make_compressor("topk", ratio=0.1)
    kw = dict(qw=qw, alpha_us=50.0, gbps=12.5, compress_gbps=25.0,
              backward_us=500.0)

    sim_em = simulate_schedule(build_schedule(em, FUSE_ALL), **kw)
    sim_pb = simulate_schedule(build_schedule(lw, 0.0), **kw)
    sim_fa = simulate_schedule(build_schedule(lw, FUSE_ALL), **kw)

    # identical inputs => identical outputs (pure function of statics)
    assert sim_pb == simulate_schedule(build_schedule(lw, 0.0), **kw)
    for s in (sim_em, sim_pb, sim_fa):
        json.dumps(s)
        assert 0.0 <= s["overlap_frac"] <= 1.0
        assert s["exposed_comm_us"] <= s["comm_us_total"] + 1e-9
    # the entire-model message departs only at backward end: zero overlap
    assert sim_em["overlap_frac"] == 0.0
    assert sim_em["n_messages"] == 1
    # per-bucket streaming starts mid-backward: it finishes no later than
    # waiting for the whole gradient would
    assert sim_pb["t_total_us"] <= sim_em["t_total_us"] + \
        (sim_pb["n_messages"] - 1) * kw["alpha_us"] + 1e-6
    # alpha scaling: with latency 100x, fewer messages must not lose
    hi = dict(kw, alpha_us=5000.0)
    assert simulate_schedule(build_schedule(lw, FUSE_ALL), **hi)[
        "t_total_us"] < simulate_schedule(build_schedule(lw, 0.0), **hi)[
        "t_total_us"]


def test_message_wire_bits_accounting():
    """Per-message wire bits = sum of member buckets' payload bits, under
    the compressor view, the measured-override view, and the dense
    fallback."""
    t = _tree()
    sm = stacked_mask(t)
    plan = build_plan(t, sm, Granularity("layerwise"))
    sched = build_schedule(plan, FUSE_ALL)
    qw = make_compressor("topk", ratio=0.25)
    total = sum(b.n * qw.payload_bits(b.dim) for b in plan.buckets)
    assert message_wire_bits(sched, qw=qw) == [total]
    dense = sum(32 * b.n * b.dim for b in plan.buckets)
    assert message_wire_bits(sched) == [dense]
    override = [7] * len(plan.buckets)
    assert message_wire_bits(sched, bucket_bits=override) == \
        [7 * len(plan.buckets)]
    with pytest.raises(ValueError):
        message_wire_bits(sched, bucket_bits=[1])


# ---------------------------------------------------------------------------
# bits.comm_report: message count + alpha (latency) line, hand-computed
# ---------------------------------------------------------------------------

def test_comm_report_messages_and_alpha_hand_computed():
    """Regression against hand-computed values on a 3-unit partition:
    dims (8, 8, 4), Top-k ratio 0.5, allgather, 2 workers.

      per-unit k = max(1, round(0.5*d)) -> (4, 4, 2)
      record = 32-bit value + ceil(log2(d))-bit index (the packed wire
      format's dim-dependent index width): 35 bits at d=8, 34 at d=4
      uplink   = 4*35 + 4*35 + 2*34     = 348
      downlink = (n-1)*uplink           = 348
      unscheduled: one message per unit -> 3; alpha=1000 -> latency 3000
      fully fused:  one message         -> 1; alpha=1000 -> latency 1000
    """
    t = {"a": jnp.zeros((2, 8)), "c": jnp.zeros((4,))}
    sm = jax.tree_util.tree_map(lambda _: False, t)
    sm["a"] = True  # stacked: two dim-8 units
    g = Granularity("layerwise")
    plan = build_plan(t, sm, g)
    assert list(plan.unit_dims) == [8, 8, 4]
    qw = make_compressor("topk", ratio=0.5)

    cfg = CompressionConfig(qw=qw, granularity=g, strategy="allgather")
    rep = comm_report(cfg, plan, 2, alpha_bits_per_message=1000)
    assert rep.uplink_bits_per_worker == 348
    assert rep.downlink_bits_per_worker == 348
    assert rep.n_messages == 3
    assert rep.latency_bits() == 3000
    assert rep.total_bits_with_latency() == 348 + 348 + 3000
    assert rep.dense_bits == 2 * 32 * 20

    fused = CompressionConfig(qw=qw, granularity=g, strategy="allgather",
                              fusion_bytes=FUSE_ALL)
    repf = comm_report(fused, plan, 2, alpha_bits_per_message=1000)
    assert repf.n_messages == 1
    assert repf.latency_bits() == 1000
    # payload (beta) terms are schedule-independent
    assert repf.uplink_bits_per_worker == rep.uplink_bits_per_worker
    assert repf.total_bits_with_latency() == 348 + 348 + 1000
    # entire-model vs layerwise vs fused layerwise are now distinguishable
    em = comm_report(
        CompressionConfig(qw=qw, granularity=Granularity("entire_model"),
                          strategy="allgather"),
        build_plan(t, sm, Granularity("entire_model")), 2,
        alpha_bits_per_message=1000)
    assert em.n_messages == 1
    assert (em.n_messages, rep.n_messages, repf.n_messages) == (1, 3, 1)
    # entire-model pays WIDER indices (5 bits at d=20 -> 10*37 = 370
    # uplink vs layerwise's 348) but one alpha; latency dominates here
    assert em.uplink_bits_per_worker == 370
    assert em.total_bits_with_latency() < rep.total_bits_with_latency()


def test_comm_report_schedule_from_plan_only():
    """The schedule auto-build needs a UnitPlan; a plain dim list keeps
    the per-unit message count even when the config asks for fusion."""
    dims = [8, 8, 4]
    qw = make_compressor("topk", ratio=0.5)
    cfg = CompressionConfig(qw=qw, strategy="allgather",
                            fusion_bytes=FUSE_ALL)
    rep = comm_report(cfg, dims, 2)
    assert rep.n_messages == 3  # no plan -> no schedule -> per-unit


# ---------------------------------------------------------------------------
# aggregation + engine integration
# ---------------------------------------------------------------------------

def test_scheduled_allreduce_collective_path():
    """compressed_allreduce inside shard_map: cfg.fusion_bytes routes
    through the schedule without changing the aggregate (1-device mesh,
    psum-bearing closures under the ordering barriers)."""
    from jax.sharding import PartitionSpec as P
    from repro.launch.engine import shard_map
    from repro.launch.mesh import make_host_mesh
    t = _tree()
    sm = stacked_mask(t)
    mesh = make_host_mesh(1, 1)

    def run(fusion_bytes):
        cfg = CompressionConfig(qw=make_compressor("qsgd", levels=16),
                                granularity=Granularity("layerwise"),
                                fusion_bytes=fusion_bytes)

        def f(g):
            out, _ = compressed_allreduce(g, sm, cfg, ("data",), KEY, 1)
            return out
        return jax.jit(shard_map(f, mesh, in_specs=(P(),),
                                 out_specs=P()))(t)

    ref = run(None)
    for fb in (0.0, FUSE_ALL):
        _assert_trees_bitwise(run(fb), ref, fb)


def test_aggregate_simulated_workers_schedule_arg():
    """An explicit prebuilt CommSchedule is honored (and equals the
    cfg.fusion_bytes route)."""
    t = _tree()
    sm = stacked_mask(t)
    wg = jax.tree_util.tree_map(lambda x: jnp.stack([x, 2.0 * x]), t)
    cfg = CompressionConfig(qw=make_compressor("terngrad"),
                            granularity=Granularity("layerwise"))
    plan = build_plan(t, sm, cfg.granularity)
    sched = build_schedule(plan, 4096.0)
    a, _ = aggregate_simulated_workers(wg, sm, cfg, KEY)
    b, _ = aggregate_simulated_workers(wg, sm, cfg, KEY, schedule=sched)
    import dataclasses as _dc
    c, _ = aggregate_simulated_workers(
        wg, sm, _dc.replace(cfg, fusion_bytes=4096.0), KEY)
    _assert_trees_bitwise(a, b, "explicit-schedule")
    _assert_trees_bitwise(b, c, "fusion-bytes-route")


def test_resolve_schedule_validation():
    from repro.launch.comm_sched import resolve_schedule
    t = _tree()
    sm = stacked_mask(t)
    plan = build_plan(t, sm, Granularity("layerwise"))
    other = build_plan(t, sm, Granularity("entire_model"))
    s = build_schedule(plan, 0.0)
    assert resolve_schedule(None, None) is None
    assert resolve_schedule(plan, 4096) is build_schedule(plan, 4096.0)
    assert resolve_schedule(plan, s) is s
    assert resolve_schedule(None, 4096.0) is None  # nothing to schedule
    with pytest.raises(ValueError):
        resolve_schedule(other, s)  # schedule from a different plan


def test_engine_scheduled_step_bit_identical():
    """Acceptance: the sharded train step with schedule= (and with the
    decision-carried fusion_bytes) is bit-for-bit the unscheduled step."""
    from repro.configs.registry import get_smoke
    from repro.launch.engine import Engine
    from repro.launch.mesh import make_host_mesh
    cfg = get_smoke("mamba2-1.3b")
    mesh = make_host_mesh(1, 1)
    comp = CompressionConfig(qw=make_compressor("qsgd", levels=16),
                             granularity=Granularity("layerwise"))
    eng = Engine(cfg, mesh, comp=comp)
    batch = {"tokens": jnp.ones((4, 16), jnp.int32) * 3,
             "targets": jnp.ones((4, 16), jnp.int32) * 5}

    def run(step_fn):
        params, opt_state = eng.init_state(0)
        for i in range(2):
            params, opt_state, m = step_fn(params, opt_state, batch,
                                           jnp.int32(i))
        return params, m

    p_ref, m_ref = run(eng.build_train_step())
    p_s, m_s = run(eng.build_train_step(schedule=4096.0))
    _assert_trees_bitwise(p_ref, p_s, "engine-schedule")
    assert float(m_ref["loss"]) == float(m_s["loss"])
    # schedule_report joins message accounting + the cost model
    from repro.launch.comm_sched import engine_schedule, schedule_report
    s = engine_schedule(eng, 4096.0)
    rep = schedule_report(s, comp, eng.dp_size)
    assert rep["n_messages"] <= rep["n_dispatches"]
    assert rep["latency_bits"] == rep["n_messages"] * int(50.0 * 12.5 * 8e3)
    json.dumps(rep)


def test_resnet9_fused_messages_below_dispatches():
    """The benchmark acceptance property, statically: on the resnet9
    gradient tree, a 1 MiB fusion buffer yields strictly fewer wire
    messages than the per-bucket dispatch count."""
    from repro.configs.resnet9_cifar import RESNET9
    from repro.models.cnn import init_cnn
    shapes = jax.eval_shape(lambda k: init_cnn(RESNET9, k),
                            jax.random.key(0))
    sm = stacked_mask(shapes)
    plan = build_plan(shapes, sm, Granularity("layerwise"))
    sched = build_schedule(plan, float(1 << 20))
    assert sched.num_messages < plan.num_dispatches
    assert build_schedule(plan, 0.0).num_messages == plan.num_dispatches


# ---------------------------------------------------------------------------
# property test (runs when hypothesis is installed; skips otherwise)
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=1, max_value=6),
       st.integers(min_value=1, max_value=40),
       st.integers(min_value=1, max_value=30),
       st.integers(min_value=0, max_value=10_000),
       st.sampled_from([0.0, 4096.0, float(1 << 20), FUSE_ALL]))
def test_property_schedule_equivalence(L, rows, loose, seed, fb):
    """Random stacked/loose shapes x any threshold: scheduled == planned
    for both ISSUE granularities, bit for bit."""
    key = jax.random.key(seed)
    t = {"blocks": {"w": jax.random.normal(key, (L, rows, 4))},
         "head": jax.random.normal(jax.random.fold_in(key, 1), (loose,))}
    sm = stacked_mask(t)
    c = make_compressor("qsgd", levels=8)
    for gran in GRANS:
        _check_equivalence(t, sm, c, gran, fb, "per_unit", key=key)
