"""Streaming ring collectives: the differential suite.

The correctness contract: `CommSchedule.execute_streaming` (chunked-
ppermute ring, mode='ring') is BIT-identical to the serialized allgather
wire path — same packed payloads, same decode-then-mean in the same
worker order — for every codec, granularity, fusion threshold and
chunking, including threaded error feedback; and `rs_stream` (compress →
reduce-scatter → allgather) degenerates to exactly the same contract at
one worker. The single-device sweep here holds that differential; the
genuinely-multi-worker properties (ring == allgather at n=4, the
double-buffer jaxpr interleave proof, per-hop span counts, rs padding on
non-divisible dims, `_mean_psum` static-n bit-identity) run in a
4-virtual-device subprocess (tests/stream_checks.py — XLA device count
must be set before jax initializes).

Also here: the collective-path bugfix regressions this PR's streaming
work flushed out — rs bits accounted on the true d (hand-computed
non-divisible case), `_mean_psum`'s psum-of-ones collective dropped from
every message, and `fit_alpha_beta`'s degenerate-fit clamp.

The full sweep carries the `stream` marker: tier-1 (`make verify`) only,
excluded from the `make verify-fast` inner loop.
"""
import math
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import (CompressionConfig, FUSE_ALL, Granularity,
                        build_plan, comm_report, compressed_allreduce,
                        make_compressor, stacked_mask)
from repro.launch.engine import shard_map
from repro.launch.mesh import make_host_mesh

KEY = jax.random.key(0)

THRESHOLDS = (0.0, float(1 << 16), FUSE_ALL)

SIX = [
    ("topk", {"ratio": 0.25}),
    ("randomk", {"ratio": 0.3, "scale": True}),
    ("qsgd", {"levels": 16}),
    ("terngrad", {}),
    ("signsgd", {}),
    ("natural", {}),
]

GRANS = [Granularity("layerwise"), Granularity("entire_model")]


def _tree(key=KEY):
    ks = [jax.random.fold_in(key, i) for i in range(5)]
    return {"blocks": {"w": jax.random.normal(ks[0], (3, 16, 8)),
                       "b": jax.random.normal(ks[1], (3, 8))},
            "embed": jax.random.normal(ks[2], (20, 4)),
            "head": jax.random.normal(ks[3], (4, 2)),
            "scalar_gain": jax.random.normal(ks[4], ())}


def _assert_trees_bitwise(a, b, ctx):
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        assert la.shape == lb.shape and la.dtype == lb.dtype, ctx
        assert bool((la == lb).all()), (
            ctx, float(jnp.max(jnp.abs(la - lb))))


def _collective_fn(cfg, ef: bool, chunk=None):
    """jitted shard_map'd compressed_allreduce on a 1-worker mesh — the
    in-process realization where ring/rs_stream must reproduce the
    allgather wire path exactly (rs_stream's shard partition degenerates
    to the whole unit at n=1)."""
    t = _tree()
    sm = stacked_mask(t)
    mesh = make_host_mesh(1, 1)

    if ef:
        def f(g, m, key):
            return compressed_allreduce(g, sm, cfg, ("data",), key, 1,
                                        wire=True, ef_state=m,
                                        stream_chunk_bytes=chunk)
        return t, jax.jit(shard_map(f, mesh, in_specs=(P(), P(), P()),
                                    out_specs=(P(), P())))

    def f(g, key):
        out, _ = compressed_allreduce(g, sm, cfg, ("data",), key, 1,
                                      wire=True, stream_chunk_bytes=chunk)
        return out
    return t, jax.jit(shard_map(f, mesh, in_specs=(P(), P()),
                                out_specs=P()))


def _run_once(strat, qw, gran, fb, chunk=None):
    cfg = CompressionConfig(qw=qw, granularity=gran, strategy=strat,
                            fusion_bytes=fb)
    t, fn = _collective_fn(cfg, ef=False, chunk=chunk)
    return fn(t, KEY)


def _run_ef_steps(strat, qw, gran, fb, chunk=None, steps=5):
    cfg = CompressionConfig(qw=qw, granularity=gran, strategy=strat,
                            error_feedback=True, fusion_bytes=fb)
    t, fn = _collective_fn(cfg, ef=True, chunk=chunk)
    m = jax.tree_util.tree_map(jnp.zeros_like, t)
    outs = []
    for i in range(steps):
        g = jax.tree_util.tree_map(lambda x: x * (1.0 + 0.1 * i), t)
        out, m = fn(g, m, jax.random.fold_in(KEY, i))
        outs.append(out)
    return outs, m


# ---------------------------------------------------------------------------
# streaming == serialized allgather wire path, bit for bit
# ---------------------------------------------------------------------------

def test_stream_matches_allgather_smoke():
    """Inner-loop subset of the full `stream` sweep: two operators x
    layerwise x {no fusion, full fusion} x both streaming strategies."""
    gran = Granularity("layerwise")
    for name, kw in (("qsgd", {"levels": 16}), ("topk", {"ratio": 0.25})):
        qw = make_compressor(name, **kw)
        for fb in (0.0, FUSE_ALL):
            ref = _run_once("allgather", qw, gran, fb)
            for strat in ("ring", "rs_stream"):
                got = _run_once(strat, qw, gran, fb)
                _assert_trees_bitwise(ref, got, (name, fb, strat))


@pytest.mark.stream
@pytest.mark.parametrize("name,kw", SIX)
def test_stream_matches_allgather_full(name, kw):
    """The acceptance sweep: all six codecs x {layerwise, entire_model}
    x fusion {0, 64KiB, inf} x {ring, rs_stream} — bit-identical to the
    serialized allgather wire path (chunked hops exercised on the fused
    one-shot message, where chunks are real)."""
    qw = make_compressor(name, **kw)
    for gran in GRANS:
        for fb in THRESHOLDS:
            ref = _run_once("allgather", qw, gran, fb)
            chunk = 64.0 if fb == FUSE_ALL else None
            for strat in ("ring", "rs_stream"):
                got = _run_once(strat, qw, gran, fb, chunk=chunk)
                _assert_trees_bitwise(ref, got,
                                      (name, gran.kind, fb, strat))


@pytest.mark.stream
@pytest.mark.parametrize("name,kw", SIX)
def test_stream_ef_conservation_full(name, kw):
    """5 steps of Algorithm 1 with threaded error feedback: the
    streaming paths' outputs AND residual memories stay bit-identical to
    the serialized wire path at every step — EF is neither dropped nor
    double-applied by the ring reordering."""
    qw = make_compressor(name, **kw)
    gran = Granularity("layerwise")
    for fb in (0.0, FUSE_ALL):
        ref_outs, ref_m = _run_ef_steps("allgather", qw, gran, fb)
        for strat in ("ring", "rs_stream"):
            got_outs, got_m = _run_ef_steps(strat, qw, gran, fb,
                                            chunk=64.0)
            for i, (r, g) in enumerate(zip(ref_outs, got_outs)):
                _assert_trees_bitwise(r, g, (name, fb, strat, "step", i))
            _assert_trees_bitwise(ref_m, got_m, (name, fb, strat, "m"))


def test_stream_requires_wire_and_single_axis():
    t = _tree()
    sm = stacked_mask(t)
    cfg = CompressionConfig(qw=make_compressor("qsgd", levels=16),
                            granularity=Granularity("layerwise"),
                            strategy="ring")
    with pytest.raises(ValueError, match="wire"):
        compressed_allreduce(t, sm, cfg, ("data",), KEY, 1, wire=False)

    mesh = make_host_mesh(1, 1)

    def f(g):
        out, _ = compressed_allreduce(g, sm, cfg, ("data", "model"), KEY,
                                      1, wire=True)
        return out

    with pytest.raises(ValueError, match="ONE data-parallel axis"):
        jax.jit(shard_map(f, mesh, in_specs=(P(),), out_specs=P()))(t)


# ---------------------------------------------------------------------------
# multi-device properties (4 virtual devices, subprocess)
# ---------------------------------------------------------------------------

@pytest.mark.stream
@pytest.mark.timeout(1200)
def test_stream_multidevice_checks():
    """Drives tests/stream_checks.py: ring == allgather bitwise at n=4
    (incl. 5-step EF), the double-buffer jaxpr interleave proof, per-hop
    span counts, rs padding on non-divisible dims, `_mean_psum`
    static-n bit-identity."""
    script = os.path.join(os.path.dirname(__file__), "stream_checks.py")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, script], capture_output=True,
                         text=True, env=env, timeout=1200)
    sys.stdout.write(res.stdout[-4000:])
    sys.stderr.write(res.stderr[-4000:])
    assert res.returncode == 0, "stream checks failed"
    assert "ALL STREAM CHECKS PASSED" in res.stdout


# ---------------------------------------------------------------------------
# engine integration: --collective routes the step through the ring
# ---------------------------------------------------------------------------

@pytest.mark.stream
def test_engine_collective_ring_bit_identical():
    """build_train_step(collective='ring') is bit-for-bit the
    collective='allgather' step — the streaming ring behind the engine
    changes program order, never numerics."""
    from repro.configs.registry import get_smoke
    from repro.launch.engine import Engine

    cfg = get_smoke("mamba2-1.3b")
    mesh = make_host_mesh(1, 1)
    comp = CompressionConfig(qw=make_compressor("qsgd", levels=16),
                             granularity=Granularity("layerwise"))
    eng = Engine(cfg, mesh, comp=comp)
    batch = {"tokens": jnp.ones((4, 16), jnp.int32) * 3,
             "targets": jnp.ones((4, 16), jnp.int32) * 5}

    def run(step_fn):
        params, opt_state = eng.init_state(0)
        for i in range(2):
            params, opt_state, m = step_fn(params, opt_state, batch,
                                           jnp.int32(i))
        return params, m

    p_ref, m_ref = run(eng.build_train_step(wire=True,
                                            collective="allgather"))
    p_ring, m_ring = run(eng.build_train_step(wire=True,
                                              collective="ring"))
    _assert_trees_bitwise(p_ref, p_ring, "engine-collective-ring")
    assert float(m_ref["loss"]) == float(m_ring["loss"])


def test_engine_collective_validation():
    from repro.configs.registry import get_smoke
    from repro.launch.engine import Engine

    cfg = get_smoke("mamba2-1.3b")
    mesh = make_host_mesh(1, 1)
    comp = CompressionConfig(qw=make_compressor("qsgd", levels=16),
                             granularity=Granularity("layerwise"))
    eng = Engine(cfg, mesh, comp=comp)
    with pytest.raises(ValueError, match="wire=True"):
        eng.build_train_step(collective="ring")           # needs wire
    with pytest.raises(ValueError, match="collective"):
        eng.build_train_step(wire=True, collective="butterfly")
    dense = Engine(cfg, mesh, comp=None)
    with pytest.raises(ValueError, match="compression config"):
        dense.build_train_step(wire=True, collective="ring")


# ---------------------------------------------------------------------------
# bugfix regressions the streaming work flushed out
# ---------------------------------------------------------------------------

def test_rs_bits_true_d_hand_computed():
    """rs accounting on the TRUE d, hand-computed: d=10 on n=4 workers
    shards as ceil(10/4)=3 -> per-worker shard sizes (3, 3, 3, 1). TopK
    ratio 0.5 payload_bits: k(3)=2 keeps 2x(32+2)=68 bits, k(1)=1 keeps
    1x(32+1)=33 -> payload_all = 3x68 + 33 = 237. Worker average: own
    contribute leg ceil(237/4) = 60, receive leg 237 - 60 = 177; dense
    reduce-scatter leg 32x10 = 320. The legacy formula charged every
    worker floor(10/4)=2 entries — neither the wire nor the data."""
    qw = make_compressor("topk", ratio=0.5)
    assert qw.payload_bits(3) == 68 and qw.payload_bits(1) == 33
    for strat in ("rs_compress_ag", "rs_stream"):
        cfg = CompressionConfig(qw=qw,
                                granularity=Granularity("layerwise"),
                                strategy=strat)
        r = comm_report(cfg, [10], 4)
        assert r.uplink_bits_per_worker == 32 * 10 + 60, strat
        assert r.downlink_bits_per_worker == 237 - 60, strat

    # divisible case unchanged by the fix: d=8, n=4 -> shards all 2
    r = comm_report(CompressionConfig(
        qw=qw, granularity=Granularity("layerwise"),
        strategy="rs_compress_ag"), [8], 4)
    per_shard = qw.payload_bits(2)
    own = -(-4 * per_shard // 4)
    assert r.uplink_bits_per_worker == 32 * 8 + own
    assert r.downlink_bits_per_worker == 4 * per_shard - own


def _count_psums(fn, *args):
    jaxpr = jax.make_jaxpr(fn)(*args)

    def walk(jx):
        n = 0
        for eqn in jx.eqns:
            if eqn.primitive.name == "psum":
                n += 1
            for v in eqn.params.values():
                vs = v if isinstance(v, (list, tuple)) else [v]
                for u in vs:
                    if hasattr(u, "jaxpr") and hasattr(u.jaxpr, "eqns"):
                        n += walk(u.jaxpr)
                    elif hasattr(u, "eqns"):
                        n += walk(u)
        return n

    return walk(jaxpr.jaxpr)


def test_mean_psum_static_drops_collective():
    """`_mean_psum` resolves the world size statically: the simulated
    strategy's traced graph carries exactly ONE psum per compressor
    dispatch, where the legacy psum-of-ones mean carried two — one
    whole collective per message gone, proven on the jaxpr."""
    from repro.core.aggregation import _mean_psum
    t = _tree()
    sm = stacked_mask(t)
    qw = make_compressor("qsgd", levels=16)
    plan = build_plan(t, sm, Granularity("layerwise"))
    n_dispatch = plan.num_dispatches
    mesh = make_host_mesh(1, 1)
    cfg = CompressionConfig(qw=qw, granularity=Granularity("layerwise"),
                            strategy="simulated")

    def prod(g):
        out, _ = compressed_allreduce(g, sm, cfg, ("data",), KEY, 1,
                                      plan=plan)
        return out

    def legacy_unit(x, k):
        s = jax.lax.psum(qw.sim(x, k), ("data",))
        return s / jax.lax.psum(jnp.ones((), s.dtype), ("data",))

    def legacy(g):
        return plan.execute(legacy_unit, g, KEY)

    n_prod = _count_psums(shard_map(prod, mesh, in_specs=(P(),),
                                    out_specs=P()), t)
    n_leg = _count_psums(shard_map(legacy, mesh, in_specs=(P(),),
                                   out_specs=P()), t)
    assert n_dispatch > 0
    assert n_prod == n_dispatch, (n_prod, n_dispatch)
    assert n_leg == 2 * n_dispatch, (n_leg, n_dispatch)


def test_fit_alpha_beta_degenerate_clamps_to_prior():
    """Fewer than two distinct message sizes cannot identify alpha AND
    beta: the fit returns the default prior with an explicit flag
    instead of silently dumping the whole duration into alpha."""
    from repro.obs.calibrate import fit_alpha_beta

    one = fit_alpha_beta([(4096, 120.0), (4096, 130.0), (4096, 125.0)])
    assert one["fit_degenerate"] is True
    assert one["alpha_us"] == 50.0 and one["gbps"] == 12.5
    assert one["resid_rms_us"] > 0.0          # honest misfit vs the prior

    custom = fit_alpha_beta([(4096, 120.0)], prior_alpha_us=10.0,
                            prior_gbps=100.0)
    assert custom["fit_degenerate"] is True
    assert custom["alpha_us"] == 10.0 and custom["gbps"] == 100.0

    bad = fit_alpha_beta([(1e3, float("nan")), (1e6, 50.0)])
    assert bad["fit_degenerate"] is True and bad["gbps"] == 12.5

    # legacy shapes preserved: empty -> gbps None (flagged degenerate);
    # two DISTINCT sizes with a flat line is a VALID latency-dominated
    # fit, not a degenerate one
    empty = fit_alpha_beta([])
    assert empty["gbps"] is None and empty["fit_degenerate"] is True
    flat = fit_alpha_beta([(1e3, 50.0), (1e6, 50.0)])
    assert flat["fit_degenerate"] is False
    assert flat["gbps"] is None and flat["alpha_us"] == 50.0

    good = fit_alpha_beta([(1e3, 51.0), (1e6, 130.0)])
    assert good["fit_degenerate"] is False and good["gbps"] is not None


def test_chunk_runs():
    """The hop-granularity grouping: greedy fusion of consecutive
    regions under the chunk budget; regions never split; edge cases."""
    from repro.kernels.ops import chunk_runs

    assert chunk_runs([10, 20, 30], None) == ((0, 1, 2),)
    assert chunk_runs([10, 20, 30], math.inf) == ((0, 1, 2),)
    assert chunk_runs([10, 20, 30], 0) == ((0,), (1,), (2,))
    assert chunk_runs([10, 20, 30], 30.0) == ((0, 1), (2,))
    assert chunk_runs([100, 20, 30], 30.0) == ((0,), (1, 2))
    assert chunk_runs([], 64.0) == ()
    with pytest.raises(ValueError):
        chunk_runs([10], -1.0)


def test_trace_dedupe_collapses_multidevice_stamps():
    """finalize_step(dedupe=True): n-device shard_map stamps each mark
    once per device; dedupe keeps the LAST arrival per mark, restoring
    the one-stamp-per-stage timeline."""
    from repro.obs.trace import TraceRecorder

    rec = TraceRecorder()
    for stage in ("compress", "pack", "collective"):
        rec._meta.append({"stage": stage, "message": 0})
    # 3 marks x 4 "devices", interleaved arrivals
    t = 1000
    for rep in range(4):
        for mid in range(3):
            rec._marks.append((mid, t + mid * 100 + rep))
            t += 1
    s = rec.finalize_step(0, dedupe=True)
    assert s["n_spans"] == 3, s
    spans = [e for e in rec.span_events(step=0) if e["cat"] == "stage"]
    assert len(spans) == 3

    rec2 = TraceRecorder()
    for stage in ("compress", "pack", "collective"):
        rec2._meta.append({"stage": stage, "message": 0})
    for rep in range(4):
        for mid in range(3):
            rec2._marks.append((mid, 1000 + mid * 100 + rep))
    s2 = rec2.finalize_step(0)        # without dedupe: every stamp a span
    assert s2["n_spans"] == 12
