"""Flash attention and chunked-SSD against their pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.flash import flash_attention
from repro.models.layers import chunked_attention
from repro.models.mamba2 import segsum, ssd_chunked

KEY = jax.random.key(0)


@pytest.mark.parametrize("causal,window,off", [
    (True, 0, 0), (True, 7, 0), (False, 0, 0), (True, 0, 5), (True, 3, 11)])
@pytest.mark.parametrize("chunk", [8, 16, 64])
def test_flash_matches_oracle(causal, window, off, chunk):
    B, S, H, dh, dv = 2, 40, 3, 16, 12
    q = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, H, dh))
    k = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, H, dh))
    v = jax.random.normal(jax.random.fold_in(KEY, 3), (B, S, H, dv))
    a = flash_attention(q, k, v, jnp.float32(window), causal, off, chunk)
    b = chunked_attention(q, k, v, causal=causal, window=window,
                          q_offset=off, q_chunk=chunk, kv_chunk=chunk)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_flash_grads_match_oracle():
    B, S, H, dh = 1, 24, 2, 8
    q = jax.random.normal(jax.random.fold_in(KEY, 4), (B, S, H, dh))
    k = jax.random.normal(jax.random.fold_in(KEY, 5), (B, S, H, dh))
    v = jax.random.normal(jax.random.fold_in(KEY, 6), (B, S, H, dh))

    def f1(q, k, v):
        return jnp.sum(jnp.tanh(flash_attention(q, k, v, jnp.float32(0),
                                                True, 0, 8)))

    def f2(q, k, v):
        return jnp.sum(jnp.tanh(chunked_attention(q, k, v, causal=True,
                                                  q_chunk=8, kv_chunk=8)))

    g1 = jax.grad(f1, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f2, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-6)


def _naive_ssd(xh, dt, A, Bm, Cm, D):
    Bsz, S, H, P = xh.shape
    N = Bm.shape[-1]

    def step(h, inp):
        x_t, dt_t, B_t, C_t = inp
        g = jnp.exp(dt_t * A[None, :])
        h = g[..., None, None] * h + jnp.einsum("bn,bh,bhp->bhpn",
                                                B_t, dt_t, x_t)
        return h, jnp.einsum("bn,bhpn->bhp", C_t, h)

    h0 = jnp.zeros((Bsz, H, P, N))
    hT, ys = jax.lax.scan(step, h0, (xh.transpose(1, 0, 2, 3),
                                     dt.transpose(1, 0, 2),
                                     Bm.transpose(1, 0, 2),
                                     Cm.transpose(1, 0, 2)))
    return ys.transpose(1, 0, 2, 3) + D[None, None, :, None] * xh, hT


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=1, max_value=3),
       st.integers(min_value=4, max_value=40),
       st.sampled_from([2, 4, 8]),
       st.integers(min_value=0, max_value=1000))
def test_property_ssd_equals_recurrence(B, S, chunk, seed):
    """State-space duality: the chunked quadratic form equals the linear
    recurrence for any (B, S, chunk)."""
    key = jax.random.key(seed)
    H, P, N = 2, 4, 3
    xh = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 2),
                                           (B, S, H)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 3), (H,)))
    Bm = jax.random.normal(jax.random.fold_in(key, 4), (B, S, N))
    Cm = jax.random.normal(jax.random.fold_in(key, 5), (B, S, N))
    D = jax.random.normal(jax.random.fold_in(key, 6), (H,))
    y1, s1 = ssd_chunked(xh, dt, A, Bm, Cm, D, chunk=chunk)
    y2, s2 = _naive_ssd(xh, dt, A, Bm, Cm, D)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=2e-4)


def test_ssd_respects_initial_state():
    B, S, H, P, N = 1, 12, 2, 4, 3
    key = jax.random.key(1)
    xh = jax.random.normal(key, (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(key, (B, S, H)))
    A = -jnp.exp(jnp.zeros((H,)))
    Bm = jax.random.normal(key, (B, S, N))
    Cm = jax.random.normal(key, (B, S, N))
    D = jnp.zeros((H,))
    # split S into two halves, thread the state
    y_a, s_a = ssd_chunked(xh[:, :6], dt[:, :6], A, Bm[:, :6], Cm[:, :6],
                           D, chunk=4)
    y_b, s_b = ssd_chunked(xh[:, 6:], dt[:, 6:], A, Bm[:, 6:], Cm[:, 6:],
                           D, chunk=4, init_state=s_a)
    y_full, s_full = ssd_chunked(xh, dt, A, Bm, Cm, D, chunk=4)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y_a, y_b], 1)),
                               np.asarray(y_full), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_b), np.asarray(s_full),
                               atol=1e-4)


def test_segsum():
    x = jnp.asarray([1.0, 2.0, 3.0])
    out = segsum(x)
    assert out[0, 0] == 0 and out[2, 1] == 3 and out[2, 0] == 5
    assert jnp.isneginf(out[0, 2])
