"""UnitPlan: the static bucketed compression-execution engine.

The load-bearing property: `plan.execute` (one batched compressor dispatch
per unit size class) is numerically equivalent to the legacy per-leaf path
`apply_unitwise_reference` — same granularity semantics, same PRNG stream —
for every granularity and the whole operator zoo. Plus: plan.unit_dims
matches granularity.unit_dims on every model config, dispatch counts are
O(#size-classes) not O(#leaves), and the bucket Pallas kernels agree with
their jnp oracles.
"""
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (CompressionConfig, Granularity, build_plan,
                        aggregate_simulated_workers, comm_report,
                        make_compressor, stacked_mask, unit_dims)
from repro.core.granularity import (apply_unitwise, apply_unitwise_reference,
                                    apply_unitwise_with_state,
                                    apply_unitwise_with_state_reference)
from repro.core.plan import UnitPlan
from repro.core.theory import noise_bounds_from_plan
from repro.kernels import ops

KEY = jax.random.key(0)

GRANS = [Granularity("layerwise"), Granularity("entire_model"),
         Granularity("blockwise", 100)]

OPERATORS = [
    ("identity", {}),
    ("topk", {"ratio": 0.25}),
    ("randomk", {"ratio": 0.3, "scale": True}),
    ("terngrad", {}),
    ("qsgd", {"levels": 16}),
    ("signsgd", {}),
    ("natural", {}),
    ("threshold_v", {"v": 0.3}),
    ("adaptive_threshold", {"alpha": 0.2}),
]


def _tree(key=KEY):
    """Mixed pytree: scan-stacked leaves of two sizes + loose leaves."""
    ks = [jax.random.fold_in(key, i) for i in range(5)]
    return {"blocks": {"w": jax.random.normal(ks[0], (3, 16, 8)),
                       "b": jax.random.normal(ks[1], (3, 8))},
            "embed": jax.random.normal(ks[2], (20, 4)),
            "head": jax.random.normal(ks[3], (4, 2)),
            "scalar_gain": jax.random.normal(ks[4], ())}


def _assert_trees_close(a, b, ctx, atol=1e-6):
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        assert la.shape == lb.shape and la.dtype == lb.dtype, ctx
        assert jnp.allclose(la, lb, atol=atol), (
            ctx, float(jnp.max(jnp.abs(la - lb))))


# ---------------------------------------------------------------------------
# numerical equivalence: plan path == legacy per-leaf path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("gran", GRANS, ids=lambda g: g.kind)
@pytest.mark.parametrize("name,kw", OPERATORS)
def test_plan_matches_reference(gran, name, kw):
    t = _tree()
    sm = stacked_mask(t)
    c = make_compressor(name, **kw)

    def fn(x, k):
        return c.sim(x, k)

    planned = apply_unitwise(fn, gran, t, sm, KEY)
    legacy = apply_unitwise_reference(fn, gran, t, sm, KEY)
    _assert_trees_close(planned, legacy, (gran.kind, name))


@pytest.mark.parametrize("gran", GRANS, ids=lambda g: g.kind)
def test_plan_matches_reference_with_state(gran):
    """Error-feedback threading: outputs AND residual memories match."""
    t = _tree()
    sm = stacked_mask(t)
    m0 = jax.tree_util.tree_map(lambda x: 0.3 * jnp.ones_like(x), t)
    c = make_compressor("topk", ratio=0.1)

    def ef(x, m, k):
        e = x + m
        q = c.sim(e, k)
        return q, e - q

    y_p, m_p = apply_unitwise_with_state(ef, gran, t, m0, sm, KEY)
    y_l, m_l = apply_unitwise_with_state_reference(ef, gran, t, m0, sm, KEY)
    _assert_trees_close(y_p, y_l, gran.kind)
    _assert_trees_close(m_p, m_l, gran.kind)


def test_plan_matches_reference_raw_key():
    """Old-style uint32 keys take the raw fold path."""
    t = _tree()
    sm = stacked_mask(t)
    c = make_compressor("qsgd", levels=8)
    rk = jax.random.PRNGKey(11)
    g = Granularity("layerwise")
    planned = apply_unitwise(lambda x, k: c.sim(x, k), g, t, sm, rk)
    legacy = apply_unitwise_reference(lambda x, k: c.sim(x, k), g, t, sm, rk)
    _assert_trees_close(planned, legacy, "raw-key")


def test_plan_under_jit_and_grad():
    """The plan path is traceable and differentiable (psum-free fn)."""
    t = _tree()
    sm = stacked_mask(t)
    g = Granularity("layerwise")

    @jax.jit
    def f(t):
        out = apply_unitwise(lambda x, k: 2.0 * x, g, t, sm, KEY)
        return sum(jnp.sum(l) for l in jax.tree_util.tree_leaves(out))

    grads = jax.grad(f)(t)
    for l in jax.tree_util.tree_leaves(grads):
        assert jnp.allclose(l, 2.0)


# ---------------------------------------------------------------------------
# dispatch complexity: O(#size classes), not O(#leaves)
# ---------------------------------------------------------------------------

def _count_traced_calls(fn_apply, t, sm, gran):
    """Number of times the compressor body is traced for one jit trace."""
    count = 0

    def counting_fn(x, k):
        nonlocal count
        count += 1
        return x

    jax.make_jaxpr(
        lambda tree: fn_apply(counting_fn, gran, tree, sm, KEY))(t)
    return count


def test_layerwise_dispatch_count_is_size_classes():
    """A scan-stacked transformer-like tree has few size classes but many
    units; the plan path traces the compressor once per size class while
    the legacy path traces once per leaf."""
    L = 12
    t = {"blocks": {"wq": jnp.ones((L, 32, 32)), "wk": jnp.ones((L, 32, 32)),
                    "wv": jnp.ones((L, 32, 32)), "norm": jnp.ones((L, 32))},
         "embed": jnp.ones((100, 32)), "head": jnp.ones((32, 100))}
    sm = stacked_mask(t)
    g = Granularity("layerwise")
    plan = build_plan(t, sm, g)
    assert plan.num_units == 4 * L + 2
    # size classes: 32*32 (3 stacked tensors), 32, 3200 (embed+head)
    assert plan.num_dispatches == 3
    planned = _count_traced_calls(apply_unitwise, t, sm, g)
    legacy = _count_traced_calls(apply_unitwise_reference, t, sm, g)
    assert planned == plan.num_dispatches == 3
    assert legacy == 6  # one trace per leaf
    assert planned < legacy


def test_stacked_bucket_is_contiguous():
    """Scan-stacked layers tile a contiguous flat range: gather/scatter
    degrade to reshape (no index arrays)."""
    t = {"blocks": {"w": jnp.ones((8, 64))}}
    plan = build_plan(t, stacked_mask(t), Granularity("layerwise"))
    assert plan.num_dispatches == 1
    assert plan.buckets[0].contiguous
    bplan = build_plan(t, stacked_mask(t), Granularity("blockwise", 128))
    assert all(b.contiguous for b in bplan.buckets)


def test_plan_cache_returns_same_object():
    t = _tree()
    sm = stacked_mask(t)
    g = Granularity("layerwise")
    assert build_plan(t, sm, g) is build_plan(t, sm, g)
    # ShapeDtypeStructs hit the same cache entry as concrete arrays
    sds = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
    assert build_plan(sds, sm, g) is build_plan(t, sm, g)


# ---------------------------------------------------------------------------
# accounting: plan.unit_dims == granularity.unit_dims everywhere
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("gran", GRANS, ids=lambda g: g.kind)
def test_unit_dims_match_on_synthetic_tree(gran):
    t = _tree()
    sm = stacked_mask(t)
    plan = build_plan(t, sm, gran)
    assert list(plan.unit_dims) == unit_dims(t, sm, gran)
    assert sum(plan.unit_dims) == plan.total


@pytest.mark.parametrize("arch", ["mamba2-1.3b", "phi4-mini-3.8b",
                                  "zamba2-7b", "whisper-base",
                                  "internvl2-2b", "granite-20b",
                                  "qwen3-moe-235b-a22b", "minicpm3-4b",
                                  "llama3-405b",
                                  "llama4-maverick-400b-a17b"])
def test_unit_dims_match_on_config_zoo(arch):
    """plan.unit_dims agrees with granularity.unit_dims on every model
    config's parameter tree (shapes only — no allocation)."""
    from repro.configs.registry import get_smoke
    from repro.models import DistConfig, Model
    m = Model(get_smoke(arch), DistConfig())
    shapes = m.param_shapes()
    sm = m.stacked()
    for gran in (Granularity("layerwise"), Granularity("entire_model"),
                 Granularity("blockwise", 1 << 16)):
        plan = build_plan(shapes, sm, gran)
        assert list(plan.unit_dims) == unit_dims(shapes, sm, gran), \
            (arch, gran.kind)
        assert sum(plan.unit_dims) == plan.total


def test_comm_report_accepts_plan():
    t = _tree()
    sm = stacked_mask(t)
    g = Granularity("layerwise")
    plan = build_plan(t, sm, g)
    cfg = CompressionConfig(qw=make_compressor("topk", ratio=0.01),
                            granularity=g, strategy="allgather")
    a = comm_report(cfg, plan, 16)
    b = comm_report(cfg, unit_dims(t, sm, g), 16)
    assert a == b


def test_noise_bounds_from_plan():
    """Theory reads the plan's unit partition: Trace(A) <= d*max bound
    (the paper's headline), with closed-form QSGD omegas."""
    t = _tree()
    sm = stacked_mask(t)
    plan = build_plan(t, sm, Granularity("layerwise"))
    qw = make_compressor("qsgd", levels=4)
    tr, em = noise_bounds_from_plan(plan, qw)
    assert tr <= em + 1e-9
    with pytest.raises(ValueError):
        noise_bounds_from_plan(plan, make_compressor("signsgd"))


# ---------------------------------------------------------------------------
# aggregation through the plan
# ---------------------------------------------------------------------------

def test_aggregate_simulated_workers_accepts_plan():
    """Passing a prebuilt plan changes nothing numerically."""
    n = 4
    t = _tree()
    wg = jax.tree_util.tree_map(
        lambda x: jnp.stack([x * (i + 1) for i in range(n)]), t)
    sm = stacked_mask(t)
    cfg = CompressionConfig(qw=make_compressor("qsgd", levels=16),
                            granularity=Granularity("layerwise"))
    plan = build_plan(t, sm, cfg.granularity)
    a, _ = aggregate_simulated_workers(wg, sm, cfg, KEY)
    b, _ = aggregate_simulated_workers(wg, sm, cfg, KEY, plan=plan)
    _assert_trees_close(a, b, "agg-plan")


# ---------------------------------------------------------------------------
# bucket kernels: one Pallas dispatch per bucket
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("d", [64, 512, 700, 1024])
def test_qsgd_units_kernel_matches_ref(d):
    x = jax.random.normal(KEY, (5, d))
    keys = jax.random.split(KEY, 5)
    a = ops.qsgd_compress_units(x, keys, 16, use_pallas=True)
    b = ops.qsgd_compress_units(x, keys, 16, use_pallas=False)
    assert jnp.allclose(a, b, atol=1e-6)
    # per-row quantization error bound: |q - x| <= norm/levels elementwise
    norms = jnp.linalg.norm(x, axis=1, keepdims=True)
    assert float(jnp.max(jnp.abs(a - x) / norms)) <= 1.0 / 16 + 1e-6


@pytest.mark.parametrize("d", [64, 512, 700])
def test_terngrad_units_kernel_matches_ref(d):
    x = jax.random.normal(KEY, (3, d))
    keys = jax.random.split(KEY, 3)
    a = ops.terngrad_compress_units(x, keys, use_pallas=True)
    b = ops.terngrad_compress_units(x, keys, use_pallas=False)
    assert jnp.allclose(a, b, atol=1e-6)
    # ternary support: every entry is 0 or +-(row max)
    m = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    ratio = jnp.abs(a) / m
    assert bool(jnp.all((ratio < 1e-6) | (jnp.abs(ratio - 1.0) < 1e-6)))


def test_plan_compress_kernel_path():
    """plan_compress: gather -> one kernel dispatch per bucket -> scatter,
    with the plan's PRNG fold tables."""
    t = {"blocks": {"w": jax.random.normal(KEY, (4, 16, 32))},
         "emb": jax.random.normal(jax.random.fold_in(KEY, 1), (10, 8))}
    sm = stacked_mask(t)
    plan = build_plan(t, sm, Granularity("layerwise"))
    out = ops.plan_compress(plan, t, KEY, kind="qsgd", levels=16)
    for la, lb in zip(jax.tree_util.tree_leaves(out),
                      jax.tree_util.tree_leaves(t)):
        assert la.shape == lb.shape and la.dtype == lb.dtype
    with pytest.raises(ValueError):
        ops.plan_compress(plan, t, KEY, kind="nope")


# ---------------------------------------------------------------------------
# property test (runs when hypothesis is installed; skips otherwise)
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=1, max_value=6),
       st.integers(min_value=1, max_value=40),
       st.integers(min_value=1, max_value=30),
       st.integers(min_value=0, max_value=10_000))
def test_property_plan_equivalence(L, rows, loose, seed):
    """Random stacked/loose shapes: plan == legacy for every granularity."""
    key = jax.random.key(seed)
    t = {"blocks": {"w": jax.random.normal(key, (L, rows, 4))},
         "head": jax.random.normal(jax.random.fold_in(key, 1), (loose,))}
    sm = stacked_mask(t)
    c = make_compressor("qsgd", levels=8)

    def fn(x, k):
        return c.sim(x, k)

    for gran in (Granularity("layerwise"), Granularity("entire_model"),
                 Granularity("blockwise", 64)):
        plan = build_plan(t, sm, gran)
        assert list(plan.unit_dims) == unit_dims(t, sm, gran)
        _assert_trees_close(apply_unitwise(fn, gran, t, sm, key),
                            apply_unitwise_reference(fn, gran, t, sm, key),
                            (gran.kind, "property"))
