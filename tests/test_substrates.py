"""Optimizers, schedules, data pipeline, checkpointing."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import latest_checkpoint, load_checkpoint, save_checkpoint
from repro.data import classification_batch, lm_batches, make_markov, \
    markov_lm_batch
from repro.optim import (OptConfig, adam, apply_updates, init_opt_state,
                         momentum, piecewise_linear, sgd)

KEY = jax.random.key(0)


def test_sgd_closed_form():
    p = {"w": jnp.asarray([1.0, 2.0])}
    g = {"w": jnp.asarray([0.5, -1.0])}
    cfg = OptConfig(name="sgd")
    p2, _ = apply_updates(cfg, p, g, {}, jnp.float32(0.1))
    np.testing.assert_allclose(np.asarray(p2["w"]), [0.95, 2.1], atol=1e-6)


def test_momentum_matches_reference():
    cfg = OptConfig(name="momentum", beta1=0.9)
    p = {"w": jnp.zeros(3)}
    st = init_opt_state(cfg, p)
    g = {"w": jnp.ones(3)}
    m_ref, w_ref = np.zeros(3), np.zeros(3)
    for _ in range(4):
        p, st = apply_updates(cfg, p, g, st, jnp.float32(0.1))
        m_ref = 0.9 * m_ref + 1.0
        w_ref = w_ref - 0.1 * m_ref
    np.testing.assert_allclose(np.asarray(p["w"]), w_ref, atol=1e-6)


def test_nesterov_differs_from_plain():
    g = {"w": jnp.ones(2)}
    p = {"w": jnp.zeros(2)}
    outs = []
    for nes in (False, True):
        cfg = OptConfig(name="momentum", nesterov=nes)
        st = init_opt_state(cfg, p)
        q, _ = apply_updates(cfg, p, g, st, jnp.float32(0.1))
        outs.append(float(q["w"][0]))
    assert outs[0] != outs[1]


def test_adam_bias_correction_first_step():
    cfg = OptConfig(name="adam", eps=0.0)
    p = {"w": jnp.zeros(2)}
    st = init_opt_state(cfg, p)
    g = {"w": jnp.asarray([0.3, -7.0])}
    p2, st2 = apply_updates(cfg, p, g, st, jnp.float32(0.01))
    # first Adam step is -lr * sign(g) after bias correction
    np.testing.assert_allclose(np.asarray(p2["w"]), [-0.01, 0.01], atol=1e-5)
    assert int(st2["count"]) == 1


def test_grad_clip():
    cfg = OptConfig(name="sgd", grad_clip=1.0)
    p = {"w": jnp.zeros(4)}
    g = {"w": jnp.full(4, 100.0)}
    p2, _ = apply_updates(cfg, p, g, {}, jnp.float32(1.0))
    assert np.linalg.norm(np.asarray(p2["w"])) == pytest.approx(1.0, rel=1e-4)


def test_piecewise_linear_schedule():
    s = piecewise_linear(0.4, 100, 20)
    assert float(s(0)) == 0.0
    assert float(s(20)) == pytest.approx(0.4)
    assert float(s(100)) == pytest.approx(0.0, abs=1e-6)
    assert 0 < float(s(60)) < 0.4


def test_lm_batches_deterministic_and_learnable():
    a = next(lm_batches(64, 4, 16, seed=5))
    b = next(lm_batches(64, 4, 16, seed=5))
    assert jnp.array_equal(a["tokens"], b["tokens"])
    assert a["tokens"].shape == (4, 16)
    # targets are the next-token shift of the same chain
    trans = make_markov(64, 5)
    batch = markov_lm_batch(jax.random.key(1), trans, 8, 32)
    probs = trans[batch["tokens"].reshape(-1), batch["targets"].reshape(-1)]
    # sampled transitions concentrate on high-probability entries
    assert float(jnp.mean(probs)) > 1.0 / 64 * 2


def test_classification_batch_shapes():
    b = classification_batch(KEY, 8, classes=10)
    assert b["images"].shape == (8, 32, 32, 3)
    assert b["labels"].shape == (8,)
    assert int(b["labels"].max()) < 10


def test_checkpoint_roundtrip_bf16():
    tree = {"a": jnp.ones((3, 4), jnp.bfloat16) * 1.5,
            "b": {"c": jnp.arange(5, dtype=jnp.int32)}}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 12, tree)
        save_checkpoint(d, 30, tree)
        path = latest_checkpoint(d)
        assert "00000030" in path
        step, out = load_checkpoint(path, tree)
        assert step == 30
        assert out["a"].dtype == jnp.bfloat16
        assert jnp.array_equal(out["b"]["c"], tree["b"]["c"])
        assert jnp.allclose(out["a"].astype(jnp.float32), 1.5)


def test_checkpoint_missing_key_raises():
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, {"a": jnp.ones(2)})
        with pytest.raises(ValueError):
            load_checkpoint(latest_checkpoint(d),
                            {"a": jnp.ones(2), "b": jnp.ones(2)})
