"""Beyond-paper extensions: int8 KV cache, microbatch equivalence,
error-feedback convergence recovery."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import get_smoke
from repro.core import (CompressionConfig, Granularity,
                        aggregate_simulated_workers, make_compressor,
                        stacked_mask)
from repro.data import lm_batches
from repro.models import DistConfig, Model, ModelConfig

KEY = jax.random.key(0)


@pytest.mark.parametrize("arch", ["granite-20b", "llama3-405b", "zamba2-7b"])
def test_int8_kv_cache_matches_bf16(arch):
    """Quantized KV cache (the paper's quantizers applied to inference
    state) perturbs decode logits only by quantization noise."""
    cfg = get_smoke(arch)
    cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
    b = {"tokens": jax.random.randint(jax.random.key(3), (2, 16), 0,
                                      cfg.vocab)}
    out = {}
    for name, c in [("ref", cfg), ("int8", cfg8)]:
        m = Model(c, DistConfig())
        params = m.init(KEY)
        lg, cache = m.prefill(params, b, jax.random.key(2), cache_len=20)
        tok = jnp.argmax(lg, -1).astype(jnp.int32)
        lg2, cache = m.decode_step(params, tok, jnp.int32(16), cache)
        lg3, _ = m.decode_step(params, jnp.argmax(lg2, -1).astype(jnp.int32),
                               jnp.int32(17), cache)
        out[name] = lg3
    err = float(jnp.max(jnp.abs(out["ref"] - out["int8"])))
    scale = float(jnp.max(jnp.abs(out["ref"]))) + 1e-6
    assert err / scale < 0.05, (arch, err, scale)
    # greedy decisions preserved
    assert jnp.mean((jnp.argmax(out["ref"], -1) ==
                     jnp.argmax(out["int8"], -1)).astype(jnp.float32)) >= 0.5


def test_microbatch_grads_equivalent():
    """Gradient accumulation over microbatches equals the full-batch
    gradient (f32 params)."""
    cfg = ModelConfig(name="t", arch_type="dense", n_layers=2, d_model=64,
                      vocab=128, n_heads=4, n_kv_heads=2, d_head=16,
                      d_ff=128, dtype="float32")
    m = Model(cfg, DistConfig())
    params = m.init(KEY)
    batch = next(lm_batches(128, 8, 32, seed=2))
    key = jax.random.key(5)
    g_full = jax.grad(lambda p: m.loss(p, batch, key))(params)
    mb = 4
    mbatch = jax.tree_util.tree_map(
        lambda x: x.reshape((mb, x.shape[0] // mb) + x.shape[1:]), batch)

    def body(acc, b_i):
        g = jax.grad(lambda p: m.loss(p, b_i, key))(params)
        return jax.tree_util.tree_map(jnp.add, acc, g), None

    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    g_acc, _ = jax.lax.scan(body, zeros, mbatch)
    g_acc = jax.tree_util.tree_map(lambda g: g / mb, g_acc)
    for a, b in zip(jax.tree_util.tree_leaves(g_acc),
                    jax.tree_util.tree_leaves(g_full)):
        assert jnp.allclose(a, b, atol=2e-5), float(jnp.max(jnp.abs(a - b)))


def test_error_feedback_improves_aggressive_topk():
    """EF-SGD recovers convergence under very aggressive Top-k (0.5%) —
    the residual memory re-injects dropped coordinates over steps."""
    cfg = ModelConfig(name="t", arch_type="dense", n_layers=2, d_model=64,
                      vocab=128, n_heads=4, n_kv_heads=2, d_head=16,
                      d_ff=128, dtype="float32")
    m = Model(cfg, DistConfig())
    sm = m.stacked()
    it = lm_batches(128, 8, 32, seed=7)
    batches = [next(it) for _ in range(20)]

    def run(ef: bool):
        params = m.init(KEY)
        comp = CompressionConfig(qw=make_compressor("topk", ratio=0.005),
                                 granularity=Granularity("layerwise"),
                                 error_feedback=ef)
        efs = jax.tree_util.tree_map(
            lambda x: jnp.zeros((4,) + x.shape, x.dtype), params) if ef \
            else None

        @jax.jit
        def step(params, efs, batch, key):
            wb = jax.tree_util.tree_map(
                lambda x: x.reshape((4, -1) + x.shape[1:]), batch)
            wg = jax.vmap(lambda b: jax.grad(
                lambda p: m.loss(p, b, key))(params))(wb)
            g, efs2 = aggregate_simulated_workers(wg, sm, comp, key,
                                                  ef_state=efs)
            p2 = jax.tree_util.tree_map(lambda p, gg: p - 0.3 * gg, params, g)
            return p2, efs2

        for i, b in enumerate(batches):
            params, efs = step(params, efs, b, jax.random.fold_in(KEY, i))
        return float(m.loss(params, batches[-1], jax.random.key(9)))

    loss_plain = run(False)
    loss_ef = run(True)
    # EF should be at least as good (usually clearly better at 0.5%)
    assert loss_ef <= loss_plain + 0.05, (loss_ef, loss_plain)
