"""Shared harness for the paper-figure benchmarks: train a CNN/MLP with
simulated multi-worker compressed SGD (Algorithm 1), layer-wise vs
entire-model, and report final test accuracy — the paper's evaluation
protocol at CPU scale (synthetic CIFAR-shaped data; the paper's
hyperparameter shape: piecewise-linear LR, global batch split over
workers)."""
from __future__ import annotations

import time
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.resnet9_cifar import ALEXNET, MLP, RESNET9, CNNConfig
from repro.control import (CompressionDecision, Controller, Policy,
                           accumulate, measurement_plan)
from repro.core import (CompressionConfig, Granularity, Identity,
                        aggregate_simulated_workers, make_compressor,
                        stacked_mask)
from repro.data import classification_batch
from repro.models.cnn import cnn_accuracy, cnn_loss, init_cnn
from repro.optim import piecewise_linear

MODELS = {"resnet9": RESNET9, "alexnet": ALEXNET, "mlp": MLP}
# per-model stable peak LRs (paper's 0.4 diverges at this scale/batch)
LR = {"resnet9": 0.01, "alexnet": 0.05, "mlp": 0.01}


def _momentum_step(params, vel, g, lr, momentum, nesterov):
    """The (heavy-ball / nesterov) SGD update shared by train_cnn and the
    controller step — one definition so the two paths cannot drift."""
    vel = jax.tree_util.tree_map(lambda v, gg: momentum * v + gg, vel, g)
    upd = (jax.tree_util.tree_map(lambda gg, v: gg + momentum * v, g, vel)
           if nesterov else vel)
    params = jax.tree_util.tree_map(lambda p, u: p - lr * u, params, upd)
    return params, vel


def train_cnn(model: str, comp: Optional[CompressionConfig], *,
              steps: int = 120, batch: int = 64, workers: int = 4,
              lr_peak: Optional[float] = None, momentum: float = 0.9,
              nesterov: bool = False, seed: int = 0
              ) -> Tuple[float, float]:
    """Returns (final_test_accuracy, final_train_loss)."""
    cfg = MODELS[model]
    lr_peak = LR[model] if lr_peak is None else lr_peak
    key = jax.random.key(seed)
    params = init_cnn(cfg, key)
    sm = stacked_mask(params)
    vel = jax.tree_util.tree_map(jnp.zeros_like, params)
    sched = piecewise_linear(lr_peak, steps, max(1, steps // 8))

    @jax.jit
    def step(params, vel, batch_data, key, lr):
        wb = jax.tree_util.tree_map(
            lambda x: x.reshape((workers, -1) + x.shape[1:]), batch_data)
        wg = jax.vmap(lambda b: jax.grad(
            lambda p: cnn_loss(cfg, p, b))(params))(wb)
        if comp is None:
            g = jax.tree_util.tree_map(lambda x: jnp.mean(x, 0), wg)
        else:
            g, _ = aggregate_simulated_workers(wg, sm, comp, key)
        return _momentum_step(params, vel, g, lr, momentum, nesterov)

    loss = float("nan")
    for i in range(steps):
        b = classification_batch(jax.random.fold_in(key, i), batch)
        params, vel = step(params, vel, b, jax.random.fold_in(key, 10_000 + i),
                           sched(i))
    test = classification_batch(jax.random.fold_in(key, 999_999), 256)
    acc = float(cnn_accuracy(cfg, params, test))
    loss = float(cnn_loss(cfg, params, test))
    return acc, loss


def compare_granularities(model: str, qname: str, *, steps=120, seed=0,
                          nesterov=False, **qkw) -> Dict[str, float]:
    """The paper's core comparison for one (model, compressor, params)."""
    out = {}
    for gran in ("layerwise", "entire_model"):
        comp = CompressionConfig(qw=make_compressor(qname, **qkw),
                                 granularity=Granularity(gran))
        acc, loss = train_cnn(model, comp, steps=steps, seed=seed,
                              nesterov=nesterov)
        out[gran] = acc
    acc0, _ = train_cnn(model, None, steps=steps, seed=seed,
                        nesterov=nesterov)
    out["baseline"] = acc0
    return out


def csv_line(name: str, t_us: float, derived: str):
    print(f"{name},{t_us:.1f},{derived}")


# --------------------------------------------------------------------------
# controller-driven study harness (adaptive control loop over the same
# simulated-worker Algorithm-1 path train_cnn uses)
# --------------------------------------------------------------------------

def dense_decision() -> CompressionDecision:
    """No-compression decision (identity Q_W/Q_M == plain gradient mean)."""
    return CompressionDecision(qw=Identity(), qm=Identity())


def cnn_controller(model: str, policy: Policy, *,
                   base: Optional[CompressionDecision] = None,
                   workers: int = 4, momentum: float = 0.9,
                   nesterov: bool = False, replan_every: int = 10,
                   collect_telemetry: Optional[bool] = None,
                   cache: Optional[dict] = None) -> Controller:
    """A Controller whose data plane is the jitted simulated-worker CNN
    step (numerically the train_cnn step for the decision's config).
    Pass one shared `cache` dict across controllers to reuse compiled
    steps over a whole study sweep."""
    cfg = MODELS[model]
    shapes = jax.eval_shape(lambda k: init_cnn(cfg, k), jax.random.key(0))
    sm = stacked_mask(shapes)
    mplan = measurement_plan(shapes, sm)
    collect = (policy.needs_telemetry if collect_telemetry is None
               else bool(collect_telemetry))
    em = getattr(policy, "needs_entire_model", True)

    def build(decision: CompressionDecision):
        comp = decision.to_config()

        @jax.jit
        def step(params, vel, batch_data, key, lr, telem):
            wb = jax.tree_util.tree_map(
                lambda x: x.reshape((workers, -1) + x.shape[1:]),
                batch_data)
            wg = jax.vmap(lambda b: jax.grad(
                lambda p: cnn_loss(cfg, p, b))(params))(wb)
            if collect:
                g, _, inc = aggregate_simulated_workers(
                    wg, sm, comp, key, telemetry_plan=mplan,
                    telemetry_entire_model=em)
                telem = accumulate(telem, inc)
            else:
                g, _ = aggregate_simulated_workers(wg, sm, comp, key)
            params, vel = _momentum_step(params, vel, g, lr, momentum,
                                         nesterov)
            return params, vel, telem

        return step

    # tag = every build input besides the decision (see engine_controller)
    return Controller(policy, build, base or dense_decision(), mplan,
                      replan_every=replan_every, collect_telemetry=collect,
                      cache=cache,
                      cache_tag=("cnn", model, workers, momentum, nesterov,
                                 em))


def train_cnn_with_controller(model: str, ctrl: Controller, *,
                              steps: int = 120, batch: int = 64,
                              lr_peak: Optional[float] = None,
                              seed: int = 0) -> Tuple[float, float]:
    """train_cnn's loop driven through a Controller: same data stream,
    keys and LR schedule, with the step fetched from the decision cache
    every iteration and telemetry fed back at re-plan boundaries.
    Returns (final_test_accuracy, final_train_loss)."""
    cfg = MODELS[model]
    lr_peak = LR[model] if lr_peak is None else lr_peak
    key = jax.random.key(seed)
    params = init_cnn(cfg, key)
    vel = jax.tree_util.tree_map(jnp.zeros_like, params)
    sched = piecewise_linear(lr_peak, steps, max(1, steps // 8))
    for i in range(steps):
        b = classification_batch(jax.random.fold_in(key, i), batch)
        fn = ctrl.step_fn()
        params, vel, telem = fn(params, vel, b,
                                jax.random.fold_in(key, 10_000 + i),
                                sched(i), ctrl.telemetry)
        ctrl.observe(telem, i)
    test = classification_batch(jax.random.fold_in(key, 999_999), 256)
    return (float(cnn_accuracy(cfg, params, test)),
            float(cnn_loss(cfg, params, test)))
