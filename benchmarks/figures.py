"""One benchmark per paper figure (Figures 2-8): layer-wise vs
entire-model test accuracy for each compression method, CPU-scale.

Each fig*() prints CSV rows  name,us_per_call,derived  where us_per_call
is the wall time per training step and `derived` carries the accuracies:
layerwise|entire_model|baseline.
"""
from __future__ import annotations

import time

from benchmarks.common import compare_granularities, csv_line, train_cnn

STEPS = 100


def _run(tag, model, qname, steps=STEPS, nesterov=False, **qkw):
    t0 = time.time()
    r = compare_granularities(model, qname, steps=steps, nesterov=nesterov,
                              **qkw)
    us = (time.time() - t0) / (3 * steps) * 1e6
    csv_line(tag, us,
             f"lw={r['layerwise']:.3f}|em={r['entire_model']:.3f}"
             f"|base={r['baseline']:.3f}")
    return r


def fig2_randomk():
    """Fig 2: Random-k on AlexNet/ResNet-9 across ratios."""
    for model in ("mlp", "resnet9"):
        for ratio in (0.01, 0.1, 0.5):
            _run(f"fig2_randomk_{model}_r{ratio}", model, "randomk",
                 ratio=ratio)


def fig3_terngrad():
    """Fig 3: TernGrad — per-layer scale beats the single global scale."""
    for model in ("mlp", "resnet9"):
        _run(f"fig3_terngrad_{model}", model, "terngrad")


def fig4_qsgd():
    """Fig 4: QSGD (norm per unit)."""
    for model in ("mlp", "resnet9"):
        _run(f"fig4_qsgd_{model}", model, "qsgd", levels=4)


def fig5_adaptive():
    """Fig 5: Adaptive Threshold (per-unit max-based threshold)."""
    for model in ("mlp", "resnet9"):
        _run(f"fig5_adaptive_{model}", model, "adaptive_threshold",
             alpha=0.05)


def fig6_threshold():
    """Fig 6: Threshold-v — granularity-insensitive by construction."""
    for v in (1e-4, 1e-3, 1e-2):
        _run(f"fig6_threshold_resnet9_v{v}", "resnet9", "threshold_v", v=v)


def fig7_topk():
    """Fig 7(a,b): Top-k across ratios; Fig 7(c): + Nesterov momentum."""
    for model in ("mlp", "resnet9"):
        for ratio in (0.001, 0.01, 0.1):
            _run(f"fig7_topk_{model}_r{ratio}", model, "topk", ratio=ratio)
    _run("fig7c_topk_resnet9_nesterov_r0.01", "resnet9", "topk",
         ratio=0.01, nesterov=True)


def fig8_topk_large():
    """Fig 8 proxy: the paper's 'larger/deeper models favor layer-wise'
    finding — AlexNet-style net (more layers than the MLP) at small k."""
    _run("fig8_topk_alexnet_r0.001", "alexnet", "topk", ratio=0.001)
    _run("fig8_topk_alexnet_r0.01", "alexnet", "topk", ratio=0.01)


def ef_beyond_paper():
    """Beyond-paper: error feedback at aggressive Top-k 0.1% — the EF
    memory re-injects dropped coordinates (not in the paper's design).
    Plain SGD (EF composes poorly with heavyball momentum — a known
    interaction, reported as-is)."""
    import time as _t
    from repro.core import CompressionConfig, Granularity, make_compressor
    from benchmarks.common import train_cnn
    for ef in (False, True):
        comp = CompressionConfig(qw=make_compressor("topk", ratio=0.001),
                                 granularity=Granularity("layerwise"),
                                 error_feedback=ef)
        t0 = _t.time()
        acc, _ = train_cnn_ef("resnet9", comp, steps=STEPS)
        csv_line(f"beyond_ef_topk0.001_resnet9_ef{int(ef)}",
                 (_t.time() - t0) / STEPS * 1e6, f"acc={acc:.3f}")


def train_cnn_ef(model, comp, steps=100):
    """train_cnn variant threading error-feedback state."""
    import jax
    import jax.numpy as jnp
    from benchmarks.common import LR, MODELS
    from repro.core import aggregate_simulated_workers, stacked_mask
    from repro.data import classification_batch
    from repro.models.cnn import cnn_accuracy, cnn_loss, init_cnn
    from repro.optim import piecewise_linear
    cfg = MODELS[model]
    key = jax.random.key(0)
    params = init_cnn(cfg, key)
    sm = stacked_mask(params)
    vel = jax.tree_util.tree_map(jnp.zeros_like, params)
    efs = (jax.tree_util.tree_map(
        lambda x: jnp.zeros((4,) + x.shape, x.dtype), params)
        if comp.error_feedback else None)
    sched = piecewise_linear(LR[model], steps, max(1, steps // 8))

    @jax.jit
    def step(params, vel, efs, batch, key, lr):
        wb = jax.tree_util.tree_map(
            lambda x: x.reshape((4, -1) + x.shape[1:]), batch)
        wg = jax.vmap(lambda b: jax.grad(
            lambda p: cnn_loss(cfg, p, b))(params))(wb)
        g, efs2 = aggregate_simulated_workers(wg, sm, comp, key,
                                              ef_state=efs)
        # plain SGD: error feedback + heavyball momentum double-counts
        # re-injected residuals
        params = jax.tree_util.tree_map(lambda p, gg: p - lr * gg, params, g)
        return params, vel, efs2

    for i in range(steps):
        b = classification_batch(jax.random.fold_in(key, i), 64)
        params, vel, efs = step(params, vel, efs, b,
                                jax.random.fold_in(key, 10_000 + i),
                                sched(i))
    test = classification_batch(jax.random.fold_in(key, 999_999), 256)
    return float(cnn_accuracy(cfg, params, test)), None


def fig_scenarios(path=None):
    """Paper-style rendering of the scenario campaign: one CSV row per
    (config, scenario, ratio) cell of BENCH_scenarios.json — the
    layerwise/entire-model final losses, per-step exposed comm of each
    granularity, and the cell's verdict. Reads the committed artifact
    (run `make bench-scenarios` first); the t_us column carries the
    layerwise exposed comm so the rows sort like the other figures."""
    import json
    import os
    if path is None:
        path = os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), "BENCH_scenarios.json")
    with open(path) as f:
        report = json.load(f)
    for config, scenarios_ in sorted(report["configs"].items()):
        for sname, cells in sorted(scenarios_.items()):
            for rkey, cell in sorted(cells.items()):
                lw, em = cell["layerwise"], cell["entire_model"]
                csv_line(
                    f"scenario_{config}_{sname}_{rkey}",
                    lw["exposed_comm_us_per_step"],
                    f"lw={lw['final_loss']:.4f}"
                    f"|em={em['final_loss']:.4f}"
                    f"|em_exposed_us={em['exposed_comm_us_per_step']:.1f}"
                    f"|verdict={cell['verdict']}")


ALL = [fig2_randomk, fig3_terngrad, fig4_qsgd, fig5_adaptive, fig6_threshold,
       fig7_topk, fig8_topk_large, ef_beyond_paper]
