"""BENCH_faults.json: the resilience-plane benchmark.

Four deterministic legs, with the acceptance gates ASSERTED (a failing
gate kills the bench — committed numbers are proofs, not observations):

  1. DETECTION MATRIX (serialized wire path): for six codecs x both
     granularities, every clean fused message verifies against its
     Fletcher-32 header word (zero false positives) and every injected
     single-bit flip fails verification — measured twice: directly on
     sampled (byte, bit) flips of the packed buffers, and end-to-end
     through a prob=1 FaultInjector on the simulated-worker aggregate
     (detected == messages).
  2. DETECTION MATRIX (streaming ring): the same codecs x granularities
     through real chunked-ppermute ring hops on the virtual-device mesh
     (XLA_FLAGS on the Makefile recipe line): prob=1 per-hop bit flips
     are all detected and resend recovers the clean aggregate BITWISE;
     prob=1 duplicated (stale) hops deliver VALID bytes and none is
     flagged — the ring leg's false-positive probe (and the documented
     sequence-number gap).
  3. RECOVERY VS CLEAN: the campaign CNN cell (resnet9, top-k 0.25,
     both granularities) trained through train_resilient under heavy
     receive corruption WITH resend lands bitwise on the clean cell's
     loss trajectory, so the layerwise-vs-entire-model verdict is the
     clean cell's verdict — detection wired to action recovers the
     paper's comparison, not just the bits.
  4. RESUME: train N == train k + kill + resume + train N-k, leaf for
     leaf (atomic digest-verified checkpoints carrying params, EF
     residuals, the PRNG key, and the recovery manager's decision
     state).

Integrity overhead is exact and static: one uint32 header word per
fused message (recorded per codec x granularity in absolute bytes and
as a fraction of the wire). `FAULT_STEPS` shrinks the training legs.
"""
from __future__ import annotations

import json
import os
import zlib
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (CompressionConfig, Granularity,
                        aggregate_simulated_workers, build_plan,
                        build_schedule, compressed_allreduce,
                        make_compressor, stacked_mask)
from repro.core.wire import (execute_schedule_wire, message_layouts,
                             verify_message, wire_codec)
from repro.resil import FaultInjector, RecoveryConfig, train_resilient
from repro.sim import CorruptionSpec, Scenario

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

STEPS = int(os.environ.get("FAULT_STEPS", "8"))
FLIPS_PER_MESSAGE = 16
RATIO = 0.25
LR = 0.02
TIE_MARGIN = 0.02

SIX = [
    ("topk", {"ratio": 0.25}),
    ("randomk", {"ratio": 0.3, "scale": True}),
    ("qsgd", {"levels": 16}),
    ("terngrad", {}),
    ("signsgd", {}),
    ("natural", {}),
]

GRANS = ("layerwise", "entire_model")


def _tree(key=None):
    key = jax.random.key(0) if key is None else key
    ks = [jax.random.fold_in(key, i) for i in range(5)]
    return {"blocks": {"w": jax.random.normal(ks[0], (3, 16, 8)),
                       "b": jax.random.normal(ks[1], (3, 8))},
            "embed": jax.random.normal(ks[2], (20, 4)),
            "head": jax.random.normal(ks[3], (4, 2)),
            "scalar_gain": jax.random.normal(ks[4], ())}


def _worker_grads(n=4):
    trees = [_tree(jax.random.fold_in(jax.random.key(0), 100 + i))
             for i in range(n)]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def _bitwise_equal(a, b) -> bool:
    return all(bool((x == y).all())
               for x, y in zip(jax.tree_util.tree_leaves(a),
                               jax.tree_util.tree_leaves(b)))


# --------------------------------------------------------------------------
# leg 1: serialized detection matrix
# --------------------------------------------------------------------------

def _serialized_cell(name: str, kw: Dict, gran: str) -> Dict:
    t = _tree()
    sm = stacked_mask(t)
    key = jax.random.key(zlib.crc32(f"faults|{name}|{gran}".encode()))
    comp = make_compressor(name, **kw)
    plan = build_plan(t, sm, Granularity(gran))
    sched = build_schedule(plan, 0.0)
    codec = wire_codec(comp, integrity=True)
    lays = message_layouts(sched, codec)
    plain = message_layouts(sched, wire_codec(comp))
    _, bufs = execute_schedule_wire(sched, codec, None, t, key)

    bytes_total = sum(l.total_nbytes for l in lays)
    overhead = bytes_total - sum(l.total_nbytes for l in plain)
    assert overhead == 4 * len(lays)    # one uint32 word per message

    rng = np.random.default_rng(zlib.crc32(f"{name}|{gran}".encode()))
    clean_fail = flips = undetected = 0
    for buf, lay in zip(bufs, lays):
        if not bool(verify_message(buf, lay)):
            clean_fail += 1
        b = np.asarray(buf)
        span = b.size - lay.checksum_span_start
        for _ in range(FLIPS_PER_MESSAGE):
            pos = lay.checksum_span_start + int(rng.integers(span))
            bit = int(rng.integers(8))
            c = b.copy()
            c[pos] ^= np.uint8(1 << bit)
            flips += 1
            if bool(verify_message(jnp.asarray(c), lay)):
                undetected += 1

    # end-to-end: prob=1 single-bit flips through the aggregate path
    cfg = CompressionConfig(qw=comp, granularity=Granularity(gran),
                            integrity=True)
    inj = FaultInjector(CorruptionSpec(prob=1.0, seed=3), resend=False)
    _, _, info = aggregate_simulated_workers(_worker_grads(), sm, cfg,
                                             key, wire=True, faults=inj)
    return {
        "n_messages": len(lays),
        "wire_bytes": int(bytes_total),
        "integrity_overhead_bytes": int(overhead),
        "integrity_overhead_frac": round(overhead / bytes_total, 6),
        "clean_messages_failed": clean_fail,
        "false_positive_rate": clean_fail / len(lays),
        "bit_flips_injected": flips,
        "bit_flips_undetected": undetected,
        "detection_rate": (flips - undetected) / flips,
        "e2e_messages": int(info["messages"]),
        "e2e_detected": int(info["corrupt_detected"]),
    }


# --------------------------------------------------------------------------
# leg 2: streaming-ring detection matrix (virtual devices)
# --------------------------------------------------------------------------

def _ring_cell(name: str, kw: Dict, gran: str, n: int) -> Dict:
    from jax.sharding import PartitionSpec as P

    from repro.launch.engine import shard_map
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh(n, 1)
    t = _tree()
    sm = stacked_mask(t)
    key = jax.random.key(zlib.crc32(f"ring|{name}|{gran}".encode()))
    cfg = CompressionConfig(qw=make_compressor(name, **kw),
                            granularity=Granularity(gran),
                            strategy="ring", integrity=True)

    def run(spec, resend=True):
        inj = None if spec is None else FaultInjector(spec, resend=resend)

        def f(g, k):
            i = jax.lax.axis_index("data").astype(jnp.float32)
            g = jax.tree_util.tree_map(lambda x: x * (1.0 + i), g)
            out, _ = compressed_allreduce(g, sm, cfg, ("data",), k, n,
                                          wire=True, faults=inj)
            if inj is None:
                det = jnp.zeros((), jnp.int32)
                msgs = jnp.zeros((), jnp.int32)
            else:
                flags = inj.take_flags()
                det = (jnp.sum(~flags).astype(jnp.int32) if flags.size
                       else jnp.zeros((), jnp.int32))
                msgs = jnp.asarray(flags.size, jnp.int32)
            return out, jax.lax.psum(det, ("data",)), msgs

        fn = jax.jit(shard_map(f, mesh, in_specs=(P(), P()),
                               out_specs=(P(), P(), P())))
        out, det, msgs = fn(t, key)
        return out, int(det), int(msgs)

    clean, _, _ = run(None)
    flip_out, flip_det, flip_msgs = run(CorruptionSpec(prob=1.0, seed=5))
    dup_out, dup_det, dup_msgs = run(
        CorruptionSpec(prob=1.0, mode="dup_hop", seed=7))
    return {
        "n_workers": n,
        "hops_verified_per_worker": flip_msgs,
        "bit_flip_hops": n * flip_msgs,
        "bit_flip_detected": flip_det,
        "detection_rate": flip_det / (n * flip_msgs),
        "resend_recovers_clean_bitwise": _bitwise_equal(flip_out, clean),
        "valid_stale_hops": n * dup_msgs,
        "valid_stale_flagged": dup_det,
        "false_positive_rate": dup_det / (n * dup_msgs),
        "stale_hop_passes_checksum": dup_det == 0,
    }


# --------------------------------------------------------------------------
# legs 3+4: recovery-vs-clean verdict and the resume gate
# --------------------------------------------------------------------------

def _cnn_comp(gran: str) -> CompressionConfig:
    return CompressionConfig(qw=make_compressor("topk", ratio=RATIO),
                             granularity=Granularity(gran),
                             error_feedback=True, integrity=True)


def _final(losses) -> float:
    tail = losses[-3:] if len(losses) >= 3 else losses
    return sum(tail) / len(tail)


def _verdict(lw_final: float, em_final: float) -> str:
    if lw_final < em_final * (1.0 - TIE_MARGIN):
        return "layerwise"
    if em_final < lw_final * (1.0 - TIE_MARGIN):
        return "entire_model"
    return "tie"


def _recovery_leg() -> Dict:
    from benchmarks.scenarios import _CnnRunner

    runner = _CnnRunner()
    clean_scen = Scenario(name="clean", n_workers=4)
    bad_scen = Scenario(name="corrupt", n_workers=4,
                        corruption=CorruptionSpec(prob=0.5, n_bits=2,
                                                  seed=21))
    cells = {}
    raw = {}
    for label, scen, rec in (
            ("clean", clean_scen, RecoveryConfig(resend=False)),
            ("faulted_resend", bad_scen, RecoveryConfig(resend=True))):
        entry = {}
        for gran in GRANS:
            res = train_resilient(runner, scen, _cnn_comp(gran),
                                  steps=STEPS, lr=LR, seed=17,
                                  recovery=rec)
            raw[(label, gran)] = res["losses"]
            entry[gran] = {
                "final_loss": round(_final(res["losses"]), 6),
                "loss_curve": [round(v, 4) for v in res["losses"]],
                "corrupt_detected":
                    res["counters"]["resil/corrupt_detected"],
                "resends": res["counters"]["resil/resends"],
            }
            print(f"recovery {label:16s} {gran:13s} "
                  f"final={entry[gran]['final_loss']:.4f} "
                  f"detected={entry[gran]['corrupt_detected']}",
                  flush=True)
        entry["verdict"] = _verdict(entry["layerwise"]["final_loss"],
                                    entry["entire_model"]["final_loss"])
        cells[label] = entry
    cells["verdict_recovered"] = (cells["faulted_resend"]["verdict"]
                                  == cells["clean"]["verdict"])
    cells["losses_bitwise_equal"] = all(
        raw[("faulted_resend", g)] == raw[("clean", g)] for g in GRANS)
    return cells


class _MlpRunner:
    """Tiny linear-softmax runner for the resume gate (the campaign
    protocol at its smallest useful scale)."""
    categories = 4
    global_batch = 8

    def init(self, key):
        return {"w": 0.1 * jax.random.normal(key, (16, 4)),
                "b": jnp.zeros((4,))}

    def loss(self, params, batch, key):
        x = batch["images"].reshape(batch["images"].shape[0], -1)
        logits = x @ params["w"] + params["b"]
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(
            logits, batch["labels"][:, None].astype(jnp.int32), 1)[:, 0]
        return jnp.mean(lse - picked)

    def worker_batch(self, key, props, per):
        from repro.data import noniid_classification_batch
        return noniid_classification_batch(key, props, per, classes=4,
                                           hw=4, channels=1)


def _resume_leg() -> Dict:
    import tempfile

    runner = _MlpRunner()
    scen = Scenario(name="corrupt", n_workers=4,
                    corruption=CorruptionSpec(prob=0.5, seed=5))
    comp = _cnn_comp("layerwise")
    steps, k = max(4, STEPS), max(2, STEPS // 2)
    full = train_resilient(runner, scen, comp, steps=steps, seed=1)
    with tempfile.TemporaryDirectory() as d:
        train_resilient(runner, scen, comp, steps=k, seed=1,
                        ckpt_dir=d, ckpt_every=k)
        resumed = train_resilient(runner, scen, comp, steps=steps,
                                  seed=1, ckpt_dir=d, ckpt_every=k,
                                  resume=True)
    return {
        "steps": steps,
        "kill_at": k,
        "params_bitwise": _bitwise_equal(resumed["params"],
                                         full["params"]),
        "ef_bitwise": _bitwise_equal(resumed["ef"], full["ef"]),
        "losses_replayed": resumed["losses"] == full["losses"][k:],
        "counters_match": resumed["counters"] == full["counters"],
    }


# --------------------------------------------------------------------------
# the bench
# --------------------------------------------------------------------------

def faults(out_path: str = None):
    report = {"steps": STEPS, "flips_per_message": FLIPS_PER_MESSAGE,
              "tie_margin": TIE_MARGIN,
              "integrity_overhead_bytes_per_message": 4,
              "detection": {"serialized": {}, "ring": {}}}

    for name, kw in SIX:
        for gran in GRANS:
            cell = _serialized_cell(name, kw, gran)
            report["detection"]["serialized"][f"{name}/{gran}"] = cell
            print(f"serialized {name:9s} {gran:13s} "
                  f"msgs={cell['n_messages']:2d} "
                  f"fp={cell['false_positive_rate']:.0%} "
                  f"det={cell['detection_rate']:.0%} "
                  f"e2e={cell['e2e_detected']}/{cell['e2e_messages']} "
                  f"+{cell['integrity_overhead_bytes']}B", flush=True)

    n_dev = jax.local_device_count()
    if n_dev >= 2:
        for name, kw in SIX:
            for gran in GRANS:
                cell = _ring_cell(name, kw, gran, n_dev)
                report["detection"]["ring"][f"{name}/{gran}"] = cell
                print(f"ring       {name:9s} {gran:13s} "
                      f"hops={cell['bit_flip_hops']:3d} "
                      f"det={cell['detection_rate']:.0%} "
                      f"fp={cell['false_positive_rate']:.0%} "
                      f"resend_clean="
                      f"{cell['resend_recovers_clean_bitwise']}",
                      flush=True)
    else:
        report["detection"]["ring"] = {
            "skipped": "needs >= 2 devices (run via `make bench-faults`: "
                       "XLA_FLAGS sets 8 virtual devices)"}

    report["recovery"] = _recovery_leg()
    report["resume"] = _resume_leg()

    ser = report["detection"]["serialized"].values()
    ring = [v for v in report["detection"]["ring"].values()
            if isinstance(v, dict) and "detection_rate" in v]
    gates = {
        "zero_false_positives": (
            all(c["false_positive_rate"] == 0.0 for c in ser)
            and all(c["false_positive_rate"] == 0.0 for c in ring)),
        "all_flips_detected": (
            all(c["detection_rate"] == 1.0
                and c["e2e_detected"] == c["e2e_messages"] for c in ser)
            and all(c["detection_rate"] == 1.0 for c in ring)),
        "ring_resend_recovers": all(
            c["resend_recovers_clean_bitwise"] for c in ring),
        "recovery_matches_clean": (
            report["recovery"]["verdict_recovered"]
            and report["recovery"]["losses_bitwise_equal"]),
        "resume_bitwise": (report["resume"]["params_bitwise"]
                           and report["resume"]["ef_bitwise"]
                           and report["resume"]["losses_replayed"]),
    }
    report["gates"] = gates
    for g, ok in gates.items():
        print(f"gate {g}: {'PASS' if ok else 'FAIL'}", flush=True)
        assert ok, f"resilience gate failed: {g}"

    path = out_path or os.path.join(_REPO_ROOT, "BENCH_faults.json")
    with open(path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    print(f"wrote {path}")
    return report


if __name__ == "__main__":
    faults()
