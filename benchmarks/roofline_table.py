"""Render the roofline table from dry-run artifacts (deliverable g)."""
from __future__ import annotations

import glob
import json
import os

from repro.launch.analysis import HBM_BW, ICI_BW, PEAK_FLOPS

HEADERS = ("arch", "shape", "mesh", "t_comp", "t_mem", "t_coll",
           "bottleneck", "useful", "coll_GB/dev", "fits")


def load(art_dir: str):
    rows = []
    for f in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        if f.endswith("summary.json"):
            continue
        with open(f) as fh:
            d = json.load(fh)
        rows.append(d)
    return rows


def render(art_dir: str = "artifacts/dryrun_baseline2"):
    rows = load(art_dir)
    print(",".join(HEADERS))
    for d in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        fits = d.get("memory_per_device", {}).get("tpu_estimate_fits_16g")
        print(f'{d["arch"]},{d["shape"]},{d["mesh"]},'
              f'{d["t_compute"]:.4f},{d["t_memory"]:.4f},'
              f'{d["t_collective"]:.4f},{d["bottleneck"]},'
              f'{d["useful_flops_ratio"]:.3f},'
              f'{d["collective_bytes_per_device"]/1e9:.3f},'
              f'{bool(fits) if fits is not None else "?"}')
    return rows


if __name__ == "__main__":
    render()
