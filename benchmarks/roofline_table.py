"""Render the roofline table from dry-run artifacts (deliverable g),
plus the fused-kernel traffic rows from BENCH_kernels.json."""
from __future__ import annotations

import glob
import json
import os

from repro.launch.analysis import HBM_BW, ICI_BW, PEAK_FLOPS

HEADERS = ("arch", "shape", "mesh", "t_comp", "t_mem", "t_coll",
           "bottleneck", "useful", "coll_GB/dev", "fits")


def load(art_dir: str):
    rows = []
    for f in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        if f.endswith("summary.json"):
            continue
        with open(f) as fh:
            d = json.load(fh)
        rows.append(d)
    return rows


def render(art_dir: str = "artifacts/dryrun_baseline2"):
    rows = load(art_dir)
    print(",".join(HEADERS))
    for d in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        fits = d.get("memory_per_device", {}).get("tpu_estimate_fits_16g")
        print(f'{d["arch"]},{d["shape"]},{d["mesh"]},'
              f'{d["t_compute"]:.4f},{d["t_memory"]:.4f},'
              f'{d["t_collective"]:.4f},{d["bottleneck"]},'
              f'{d["useful_flops_ratio"]:.3f},'
              f'{d["collective_bytes_per_device"]/1e9:.3f},'
              f'{bool(fits) if fits is not None else "?"}')
    return rows


KERNEL_HEADERS = ("codec", "op", "path", "passes", "bytes/elt",
                  "launches")


def render_kernels(bench_path: str = "BENCH_kernels.json"):
    """CSV of the per-codec wire-kernel memory traffic (fused single
    launch vs legacy three-pass) recorded by benchmarks.microbench
    .kernels_bench — the kernel-level rows of the roofline story:
    bytes/elt is the roofline's traffic axis, deterministic on any
    container. Silently skips when the artifact is absent (run
    `make bench-kernels` first)."""
    if not os.path.exists(bench_path):
        print(f"# {bench_path} not found — run `make bench-kernels`")
        return []
    with open(bench_path) as fh:
        d = json.load(fh)
    rows = []
    print(",".join(KERNEL_HEADERS))
    for codec in sorted(k for k, v in d.items() if isinstance(v, dict)
                        and "width_bits" in v):
        for op in ("encode", "decode", "decode_ef"):
            for path in ("fused", "legacy"):
                s = d[codec][f"{op}_{path}"]
                total = (s["read_bytes_per_elt"] + s["write_bytes_per_elt"]
                         + s["intermediate_bytes_per_elt"])
                rows.append((codec, op, path, s["passes_over_data"],
                             total, s["launches_per_bucket"]))
                print(f"{codec},{op},{path},{s['passes_over_data']},"
                      f"{total:.4f},{s['launches_per_bucket']}")
    mv = d.get("majority_vote")
    if mv:
        print(f"signsgd,majority_vote,packed_words,1,"
              f"{mv['read_bytes_per_word'] + mv['write_bytes_per_word']}"
              f"B/word,{mv['launches']}")
    return rows


SCHEDULE_HEADERS = ("config", "threshold", "n_messages", "wire_bits",
                    "exposed_us_model", "exposed_us_measured",
                    "model_error_ratio")


def render_schedule(bench_path: str = "BENCH_schedule.json"):
    """CSV of the per-config x fusion-threshold measured-vs-modeled
    exposed-comm columns recorded by benchmarks.microbench.schedule.
    The ratio column is TraceRecorder-measured stream wall over the
    alpha-beta model's exposed prediction (single-process: nothing
    overlaps, so treat the absolute ratios as host-local and read the
    SHAPE across thresholds). Silently skips when the artifact is
    absent (run `make bench-schedule` first)."""
    if not os.path.exists(bench_path):
        print(f"# {bench_path} not found — run `make bench-schedule`")
        return []
    with open(bench_path) as fh:
        d = json.load(fh)
    rows = []
    print(",".join(SCHEDULE_HEADERS))
    for cfg in sorted(d):
        entry = d[cfg]
        for label in ("per_bucket", "fused_64kib", "fused_1mib",
                      "one_shot"):
            t = entry.get(label)
            if not isinstance(t, dict):
                continue
            meas = t.get("exposed_comm_us_measured", "")
            ratio = t.get("model_error_ratio", "")
            rows.append((cfg, label, t["n_messages"], t["wire_bits"],
                         t["exposed_comm_us_model"], meas, ratio))
            print(f"{cfg},{label},{t['n_messages']},{t['wire_bits']},"
                  f"{t['exposed_comm_us_model']},{meas},{ratio}")
    return rows


OBS_HEADERS = ("config", "threshold", "n_messages", "wire_bytes",
               "exposed_us_measured", "exposed_us_model",
               "ratio_default", "ratio_fitted")


def render_obs(bench_path: str = "BENCH_obs.json"):
    """CSV of the calibration study (BENCH_obs.json): per config x
    threshold, measured exposed comm vs the alpha-beta model under the
    default and the per-host FITTED parameters, plus the fit itself.
    Silently skips when the artifact is absent (run `make bench-obs`
    first)."""
    if not os.path.exists(bench_path):
        print(f"# {bench_path} not found — run `make bench-obs`")
        return []
    with open(bench_path) as fh:
        d = json.load(fh)
    rows = []
    print(",".join(OBS_HEADERS))
    for cfg in sorted(d.get("configs", {})):
        cal = d["configs"][cfg]
        for label in ("per_bucket", "fused_64kib", "one_shot"):
            t = cal["thresholds"].get(label)
            if t is None:
                continue
            rows.append((cfg, label, t["n_messages"],
                         t["wire_bytes_measured"],
                         t["exposed_comm_us_measured"],
                         t["exposed_comm_us_model"],
                         t["model_error_ratio_default"],
                         t["model_error_ratio_fitted"]))
            print(f"{cfg},{label},{t['n_messages']},"
                  f"{t['wire_bytes_measured']},"
                  f"{t['exposed_comm_us_measured']},"
                  f"{t['exposed_comm_us_model']},"
                  f"{t['model_error_ratio_default']},"
                  f"{t['model_error_ratio_fitted']}")
        for host, fit in sorted(cal["fit_by_host"].items()):
            print(f"# {cfg} host {host} fit: alpha_us={fit['alpha_us']} "
                  f"gbps={fit['gbps']} n={fit['n_samples']} "
                  f"resid_rms_us={fit['resid_rms_us']}")
    return rows


FAULT_HEADERS = ("codec", "granularity", "path", "messages",
                 "false_positive_rate", "detection_rate",
                 "overhead_bytes", "resend_recovers")


def render_faults(bench_path: str = "BENCH_faults.json"):
    """CSV of the resilience-plane detection matrix (BENCH_faults.json):
    per codec x granularity x collective path, the Fletcher-32
    false-positive and single-bit-flip detection rates, the per-message
    integrity overhead in bytes, and whether resend recovered the clean
    aggregate bitwise (ring rows), followed by the recovery-verdict and
    resume-gate summary lines. Silently skips when the artifact is
    absent (run `make bench-faults` first)."""
    if not os.path.exists(bench_path):
        print(f"# {bench_path} not found — run `make bench-faults`")
        return []
    with open(bench_path) as fh:
        d = json.load(fh)
    rows = []
    print(",".join(FAULT_HEADERS))
    for path_name in ("serialized", "ring"):
        cells = d["detection"].get(path_name, {})
        for key in sorted(cells):
            c = cells[key]
            if not isinstance(c, dict) or "detection_rate" not in c:
                continue
            codec, gran = key.split("/", 1)
            if path_name == "serialized":
                msgs = c["n_messages"]
                over = c["integrity_overhead_bytes"]
                rec = ""
            else:
                msgs = c["bit_flip_hops"]
                over = 4
                rec = c["resend_recovers_clean_bitwise"]
            rows.append((codec, gran, path_name, msgs,
                         c["false_positive_rate"], c["detection_rate"],
                         over, rec))
            print(f"{codec},{gran},{path_name},{msgs},"
                  f"{c['false_positive_rate']},{c['detection_rate']},"
                  f"{over},{rec}")
    rec = d.get("recovery", {})
    if rec:
        print(f"# recovery verdict: clean={rec['clean']['verdict']} "
              f"faulted_resend={rec['faulted_resend']['verdict']} "
              f"recovered={rec['verdict_recovered']} "
              f"losses_bitwise_equal={rec['losses_bitwise_equal']}")
    res = d.get("resume", {})
    if res:
        print(f"# resume: steps={res['steps']} kill_at={res['kill_at']} "
              f"params_bitwise={res['params_bitwise']} "
              f"ef_bitwise={res['ef_bitwise']} "
              f"losses_replayed={res['losses_replayed']}")
    for g, ok in sorted(d.get("gates", {}).items()):
        print(f"# gate {g}: {'PASS' if ok else 'FAIL'}")
    return rows


if __name__ == "__main__":
    render()
    render_kernels()
    render_schedule()
    render_obs()
    render_faults()
